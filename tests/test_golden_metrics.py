"""Golden-value regression tests freezing ``evaluate_system``'s 4-metric
vector (``METRIC_KEYS``) for one fixed, hand-constructed design on preset
workload graphs.

The perf/energy/cost models are the substrate every optimizer, front
explorer and benchmark ranks on — a silent drift in any of them would
invalidate cached archives and every published front.  These tests pin the
absolute numbers (within a float32 tolerance), so a model change must
consciously update the golden table (and with it, bump/flush the explore
caches) rather than slip through.

The design is built from constants only (no PRNG), so the values are
independent of jax's random-bit generation."""

import numpy as np
import pytest

import jax.numpy as jnp

import repro.core as C
from repro.core.encoding import feasibility_penalty
from repro.core.evaluate import evaluate_system
from repro.core.optimizer import METRIC_KEYS, metric_stack
from repro.core.workload import MAX_LOOPS


def _fixed_design(spec):
    """A deterministic, feasible design: 4x4 PE arrays, 2x2 cores, 2
    chiplets per workload, identity loop orders, unit tiles, no pipeline,
    passive interposer, mesh network, identity placement."""
    W, CH, L = spec.W, spec.CH, MAX_LOOPS
    return dict(
        shape=jnp.asarray(np.tile([4, 4, 2, 2, 1, 2], (W, 1)), jnp.int32),
        spatial=jnp.zeros((W, 6), jnp.int32),
        order=jnp.asarray(np.tile(np.arange(L, dtype=np.int32), (W, 3, 1))),
        tiling=jnp.ones((W, 2, L), jnp.int32),
        pipe=jnp.full((W,), L, jnp.int32),          # L == not pipelined
        logB=jnp.asarray(0, jnp.int32),
        packaging=jnp.asarray(1, jnp.int32),        # passive interposer
        family=jnp.asarray(2, jnp.int32),           # mesh
        placement=jnp.asarray(np.arange(W * CH, dtype=np.int32)))


def _graph(name):
    if name == "att2":
        return C.presets.bert_mms()["att2"]
    if name == "res2":
        return C.presets.resnet_convs()["res2"]
    return C.presets.transformer_block()


# (latency_ns, energy_pj, cost_usd, area_mm2) under DEFAULT_TECH — update
# ONLY on a deliberate model change, never to quiet an unexpected diff.
GOLDEN = {
    "att2": (92995704.0, 20249282560.0,
             9.310935020446777, 3.136559009552002),
    "res2": (1272764416.0, 278478028800.0,
             9.310935020446777, 3.136559009552002),
    "transformer_block": (3324772864.0, 459914838016.0,
                          26.559057235717773, 15.82420825958252),
}


@pytest.mark.parametrize("name", sorted(GOLDEN))
def test_metric_vector_matches_golden(name):
    spec = C.SystemSpec.build(_graph(name), ch_max=2)
    design = _fixed_design(spec)
    metrics = evaluate_system(spec, design)
    got = np.asarray(metric_stack(metrics), np.float64)
    want = np.asarray(GOLDEN[name], np.float64)
    # float32 pipeline: 1e-4 relative absorbs benign reassociation while
    # still catching any real model drift (>0.01%)
    np.testing.assert_allclose(got, want, rtol=1e-4,
                               err_msg=f"METRIC_KEYS={METRIC_KEYS}")
    # the golden design must stay feasible — otherwise penalties, not the
    # models, would be what these numbers pin
    space = C.DesignSpace(spec)
    assert float(feasibility_penalty(space, design, metrics)) \
        == pytest.approx(1.0)


def test_metric_stack_order_is_canonical():
    """The golden vectors above are only meaningful while METRIC_KEYS
    keeps its canonical order — freeze that too."""
    assert METRIC_KEYS == ("latency_ns", "energy_pj", "cost_usd",
                           "area_mm2")
