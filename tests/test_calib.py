"""Tests for ``repro.calib``: differentiability of the analytical model
w.r.t. the fittable tech constants, the stable ``tech_key`` cache
identity, the fit loop itself, and the ``CalibratedTech`` artifact
lifecycle.

The differentiability tests are the load-bearing regression: ``fit``
works only because every metric's gradient w.r.t. its ``METRIC_FIELDS``
flows through ``evaluate_system`` / ``analyze_chiplet``.  A future
``jnp.where``/``lax.stop_gradient``/integer-cast edit that silently
zeroes one of those paths would leave the optimizer spinning on a flat
loss — these tests turn that into a visible failure."""

import dataclasses
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.core as C
from repro.core.constants import (DEFAULT_TECH, FITTABLE_FIELDS,
                                  METRIC_FIELDS, TechConstants,
                                  tech_from_dict, tech_key, tech_to_dict)
from repro.core.evaluate import evaluate_system
from repro.core.optimizer import METRIC_KEYS
from repro.core.workload import MAX_LOOPS

from repro.calib import (CalibratedTech, Measurement, error_report, fit,
                         load_calibrated, load_report, measurements_digest,
                         simulator_sweep)


# ---------------------------------------------------------------------------
# golden design (same construction as tests/test_golden_metrics.py)
# ---------------------------------------------------------------------------
def _fixed_design(spec):
    W, CH, L = spec.W, spec.CH, MAX_LOOPS
    return dict(
        shape=jnp.asarray(np.tile([4, 4, 2, 2, 1, 2], (W, 1)), jnp.int32),
        spatial=jnp.zeros((W, 6), jnp.int32),
        order=jnp.asarray(np.tile(np.arange(L, dtype=np.int32), (W, 3, 1))),
        tiling=jnp.ones((W, 2, L), jnp.int32),
        pipe=jnp.full((W,), L, jnp.int32),
        logB=jnp.asarray(0, jnp.int32),
        packaging=jnp.asarray(1, jnp.int32),
        family=jnp.asarray(2, jnp.int32),
        placement=jnp.asarray(np.arange(W * CH, dtype=np.int32)))


@pytest.fixture(scope="module")
def golden():
    spec = C.SystemSpec.build(C.presets.transformer_block(), ch_max=2)
    return spec, _fixed_design(spec)


def _jacobian(spec, design, base):
    """(len(METRIC_KEYS), len(FITTABLE_FIELDS)) jacobian at ``base``."""
    def metrics_of(vals):
        tech = dataclasses.replace(
            base, **{f: v for f, v in zip(FITTABLE_FIELDS, vals)})
        out = evaluate_system(spec, design, tech=tech)
        return jnp.stack([out[k] for k in METRIC_KEYS])

    v0 = jnp.asarray([float(getattr(base, f)) for f in FITTABLE_FIELDS],
                     jnp.float32)
    return np.asarray(jax.jacfwd(metrics_of)(v0))


# ---------------------------------------------------------------------------
# differentiability
# ---------------------------------------------------------------------------
def test_jacobian_finite_and_mapped_fields_move(golden):
    """All four metrics have finite gradients w.r.t. every fittable field,
    and each METRIC_FIELDS pair is non-zero on the golden design.  The
    base point uses a non-zero tile overhead so its gradient is visible
    (at 0.0 the term still differentiates, but we pin the realistic
    post-calibration operating point)."""
    spec, design = golden
    base = dataclasses.replace(DEFAULT_TECH, t_tile_overhead_ns=8.0)
    J = _jacobian(spec, design, base)
    assert np.isfinite(J).all(), "non-finite metric gradient"
    for metric, fields in METRIC_FIELDS.items():
        row = J[METRIC_KEYS.index(metric)]
        for f in fields:
            g = row[FITTABLE_FIELDS.index(f)]
            assert g != 0.0, f"d {metric} / d {f} vanished on golden design"


def test_metric_fields_cover_every_metric():
    for metric in METRIC_KEYS:
        assert metric in METRIC_FIELDS
        assert set(METRIC_FIELDS[metric]) <= set(FITTABLE_FIELDS)


def test_bandwidth_gradient_binds_when_starved(golden):
    """The bandwidth constants are fittable but regime-dependent: latency
    is a max over compute/memory passes, so a bandwidth moves latency only
    where it binds.  Starving the buffers makes ``core_buf_bw`` the
    bottleneck on the golden design — its gradient must turn on."""
    spec, design = golden
    starved = dataclasses.replace(
        DEFAULT_TECH, t_tile_overhead_ns=8.0,
        dram_bw=DEFAULT_TECH.dram_bw * 0.01,
        core_buf_bw=DEFAULT_TECH.core_buf_bw * 0.01,
        chip_buf_bw=DEFAULT_TECH.chip_buf_bw * 0.01,
        chip_noc_bw=DEFAULT_TECH.chip_noc_bw * 0.01)
    J = _jacobian(spec, design, starved)
    assert np.isfinite(J).all()
    g = J[METRIC_KEYS.index("latency_ns"),
          FITTABLE_FIELDS.index("core_buf_bw")]
    assert g != 0.0, "core_buf_bw gradient stayed zero under starvation"


# ---------------------------------------------------------------------------
# tech_key / cache identity
# ---------------------------------------------------------------------------
def test_tech_key_stable_across_equal_instances():
    a = TechConstants()
    b = dataclasses.replace(TechConstants())
    assert a is not b
    assert tech_key(a) == tech_key(b) == tech_key(DEFAULT_TECH)


def test_tech_key_is_digest_not_repr():
    k = tech_key(DEFAULT_TECH)
    assert len(k) == 64 and all(c in "0123456789abcdef" for c in k)
    assert "TechConstants" not in k


def test_tech_key_distinguishes_calibrated():
    cal = dataclasses.replace(DEFAULT_TECH, corr_latency=1.01)
    assert tech_key(cal) != tech_key(DEFAULT_TECH)
    # round-tripping through the dict form preserves identity exactly
    rt = tech_from_dict(tech_to_dict(cal))
    assert tech_key(rt) == tech_key(cal)


def test_session_cache_key_is_tech_aware(tmp_path):
    from repro.explore.api import Problem, Session
    p = Problem(C.presets.bert_mms()["att2"], ch_max=2)
    s0 = Session(cache_dir=str(tmp_path / "a"))
    cal = dataclasses.replace(DEFAULT_TECH, corr_latency=1.25)
    s1 = Session(cache_dir=str(tmp_path / "b"), tech=cal)
    assert s0._cache_key(p) != s1._cache_key(p)
    # and the default session's key matches a fresh default session's
    s2 = Session(cache_dir=str(tmp_path / "c"))
    assert s0._cache_key(p) == s2._cache_key(p)


# ---------------------------------------------------------------------------
# default path bit-identity
# ---------------------------------------------------------------------------
def test_identity_corrections_are_bitwise_noops(golden):
    """corr_* = 1.0 and t_tile_overhead_ns = 0.0 (the defaults) must leave
    every metric bit-identical to an evaluation that predates the
    calibration fields — pinned here as: explicitly setting the defaults
    changes nothing, and the golden table in test_golden_metrics stays
    green."""
    spec, design = golden
    explicit = dataclasses.replace(
        DEFAULT_TECH, t_tile_overhead_ns=0.0, corr_latency=1.0,
        corr_energy=1.0, corr_area=1.0, corr_cost=1.0)
    out0 = evaluate_system(spec, design, tech=DEFAULT_TECH)
    out1 = evaluate_system(spec, design, tech=explicit)
    for k in METRIC_KEYS:
        a, b = np.asarray(out0[k]), np.asarray(out1[k])
        assert a.tobytes() == b.tobytes(), f"{k} not bit-identical"


# ---------------------------------------------------------------------------
# measurements
# ---------------------------------------------------------------------------
def test_measurement_validation():
    with pytest.raises(ValueError):
        Measurement.make("bogus_kind", "latency_ns", 1.0, "x")
    with pytest.raises(ValueError):
        Measurement.make("system", "latency_ns", -1.0, "x")


def test_measurements_digest_order_insensitive():
    a = Measurement.make("system", "area_mm2", 216.0, "simba")
    b = Measurement.make("system", "cost_usd", 110.0, "simba")
    assert measurements_digest([a, b]) == measurements_digest([b, a])
    assert measurements_digest([a]) != measurements_digest([a, b])


def test_load_report_csv_and_json(tmp_path):
    csv = tmp_path / "r.csv"
    csv.write_text("kind,metric,value,source,pe_budget\n"
                   "system,area_mm2,216.0,simba,1024\n")
    ms = load_report(str(csv))
    assert len(ms) == 1 and ms[0].metric == "area_mm2"
    assert ms[0].info["pe_budget"] == 1024

    js = tmp_path / "r.json"
    js.write_text(json.dumps({"rows": [m.to_dict() for m in ms]}))
    ms2 = load_report(str(js))
    assert measurements_digest(ms2) == measurements_digest(ms)


# ---------------------------------------------------------------------------
# fit
# ---------------------------------------------------------------------------
def test_fit_reduces_simulator_error():
    train = simulator_sweep(shapes=[(64, 64, 64), (128, 128, 128)],
                            bws=(128.0,))
    held = simulator_sweep(shapes=[(100, 100, 100)], bws=(128.0,))
    res = fit(train, free=("t_tile_overhead_ns", "corr_latency"),
              holdout=held, steps=120, lr=0.05, seed=0)
    assert res.errors["train_after"]["mean"] \
        < res.errors["train_before"]["mean"]
    assert res.loss[1] < res.loss[0]
    assert set(res.fitted) == {"t_tile_overhead_ns", "corr_latency"}
    # fitted values land on the tech object itself
    assert res.tech.t_tile_overhead_ns \
        == pytest.approx(res.fitted["t_tile_overhead_ns"])
    # untouched fields stay exactly at their defaults
    assert res.tech.e_mac_pj == DEFAULT_TECH.e_mac_pj


def test_fit_rejects_unknown_free_field():
    ms = simulator_sweep(shapes=[(64, 64, 64)], bws=(128.0,))
    with pytest.raises(ValueError):
        fit(ms, free=("not_a_field",), steps=1)


def test_error_report_keys():
    ms = simulator_sweep(shapes=[(64, 64, 64)], bws=(128.0,))
    rep = error_report(ms, DEFAULT_TECH)
    assert set(rep) == {"latency_ns", "mean"}
    assert rep["mean"] >= 0.0


# ---------------------------------------------------------------------------
# CalibratedTech artifact lifecycle
# ---------------------------------------------------------------------------
def _small_fit():
    train = simulator_sweep(shapes=[(64, 64, 64)], bws=(128.0,))
    return fit(train, free=("t_tile_overhead_ns", "corr_latency"),
               holdout=train, steps=40, lr=0.05, seed=0)


def test_calibrated_tech_round_trip(tmp_path):
    res = _small_fit()
    art = CalibratedTech.from_fit("t_roundtrip", res)
    path = art.save(str(tmp_path))
    loaded = load_calibrated(path)
    assert loaded.digest == art.digest == tech_key(res.tech)
    assert tech_key(loaded.tech) == tech_key(res.tech)
    assert loaded.free == art.free


def test_calibrated_tech_tamper_detected(tmp_path):
    res = _small_fit()
    art = CalibratedTech.from_fit("t_tamper", res)
    path = art.save(str(tmp_path))
    doc = json.loads(open(path).read())
    doc["tech"]["corr_latency"] = 2.0       # silent edit, stale digest
    open(path, "w").write(json.dumps(doc))
    with pytest.raises(ValueError):
        load_calibrated(path)


def test_resolve_tech_accepts_artifact(tmp_path):
    from repro.core.presets import resolve_tech, tech_label
    res = _small_fit()
    art = CalibratedTech.from_fit("t_resolve", res)
    name, tech = resolve_tech(art)
    assert name == "t_resolve"
    assert tech_key(tech) == tech_key(res.tech)
    label = tech_label(art)
    assert label.startswith("t_resolve@") and len(label.split("@")[1]) == 12


# ---------------------------------------------------------------------------
# async payload: tech travels by name only
# ---------------------------------------------------------------------------
def test_query_payload_carries_tech_name():
    from repro.explore.api import Problem, Query
    from repro.serve.executor import query_from_payload, query_to_payload
    p = Problem(C.presets.bert_mms()["att2"], ch_max=2)
    q = Query(problem=p, budget=64, tech="mycal")
    d = query_to_payload(q)
    assert d["tech"] == "mycal"
    assert query_from_payload(d).tech == "mycal"
    # live TechConstants objects do not survive a crash — rejected loudly
    with pytest.raises(ValueError):
        query_to_payload(Query(problem=p, budget=64, tech=DEFAULT_TECH))
