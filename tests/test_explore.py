"""Tests for the ``repro.explore`` subsystem: dominance/archive invariants
(no dominated point survives insertion, capacity pruning keeps boundary
points), NSGA-II front correctness against a brute-force dominance sweep,
and the service's cache round-trip (save -> load -> warm-start yields
identical fronts)."""

from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.core as C
from repro.explore.archive import (HV_LOG_REF, ConvergenceTrace,
                                   ParetoArchive, hypervolume_2d,
                                   hypervolume_2d_jit, objective_pairs,
                                   pareto_front, spec_space_key)
from repro.explore.nsga import NSGAConfig, make_nsga, pmx
from repro.explore.service import (BudgetPolicy, ExplorationService,
                                   ExploreQuery)


def _brute_front(pts):
    """Reference O(n^2) double-loop dominance sweep."""
    pts = np.asarray(pts, np.float64)
    keep = []
    for i in range(len(pts)):
        dom = any(j != i and np.all(pts[j] <= pts[i])
                  and np.any(pts[j] < pts[i]) for j in range(len(pts)))
        if not dom:
            keep.append(i)
    return keep


# this module deliberately exercises the legacy explore/optimize entry
# points (now deprecation shims over repro.api) — expected warnings only
pytestmark = pytest.mark.filterwarnings("ignore:legacy entry point")

TINY_SPACE_KW = dict(max_shape=(16, 16, 4, 4, 1, 2))   # <= 2 chiplets =>
#                      every design satisfies the ch_max=2 node constraint


def _tiny_problem(ch_max=2):
    g = C.presets.bert_mms()["att2"]
    spec = C.SystemSpec.build(g, ch_max=ch_max)
    return g, spec, C.DesignSpace(spec, **TINY_SPACE_KW)


# ---------------------------------------------------------------------------
# canonical dominance math
# ---------------------------------------------------------------------------
def test_pareto_front_matches_bruteforce():
    pts = np.random.default_rng(0).random((64, 3))
    assert sorted(pareto_front(pts)) == sorted(_brute_front(pts))


def test_pareto_front_is_the_optimizer_impl():
    # one canonical implementation: the optimizer re-exports the archive's
    from repro.core.optimizer import pareto_front as pf_opt
    assert pf_opt is pareto_front
    assert sorted(pf_opt([[1, 2], [2, 1], [2, 2], [0.5, 3]])) == [0, 1, 3]
    assert pf_opt([[1, 1]]) == [0]


def test_hypervolume_2d():
    assert hypervolume_2d([(1, 5)], (10, 10)) == pytest.approx(45.0)
    # two staircase points: [1,10]x[5,10] + [2,10]x[3,5]
    assert hypervolume_2d([(1, 5), (2, 3)], (10, 10)) == pytest.approx(61.0)
    # dominated + non-finite points contribute nothing
    assert hypervolume_2d([(1, 5), (2, 6), (np.inf, 0)],
                          (10, 10)) == pytest.approx(45.0)
    assert hypervolume_2d(np.zeros((0, 2)), (1, 1)) == 0.0


def test_hypervolume_2d_jit_matches_host():
    rng = np.random.default_rng(7)
    for n in (1, 2, 17, 64):
        pts = rng.random((n, 2)) * 4
        pts[rng.random(n) < 0.2] = np.inf        # some filtered rows
        ref = (3.0, 3.5)
        assert float(hypervolume_2d_jit(pts, ref)) == pytest.approx(
            hypervolume_2d(pts, ref), rel=1e-5)
    # the validity mask drops points exactly like removing them
    pts = rng.random((8, 2))
    valid = rng.random(8) < 0.5
    assert float(hypervolume_2d_jit(pts, (2, 2), valid=valid)) \
        == pytest.approx(hypervolume_2d(pts[valid], (2, 2)), rel=1e-5)


def test_objective_pairs():
    assert objective_pairs(1) == ()
    assert objective_pairs(2) == ((0, 1),)
    assert objective_pairs(3) == ((0, 1), (0, 2), (1, 2))


def test_archive_projected_hypervolume():
    arc = ParetoArchive(8, {"tag": np.zeros((), np.int32)}, n_obj=2)
    assert arc.projected_hypervolume((0, 1)) == 0.0   # empty archive
    arc.insert({"tag": np.zeros(1, np.int32)}, np.array([[np.e, np.e]]))
    # single point at log-coords (1, 1) against (ref, ref)
    assert arc.projected_hypervolume((0, 1)) == pytest.approx(
        (HV_LOG_REF - 1.0) ** 2, rel=1e-5)
    # inserting a dominating point can only grow the projected hv
    hv0 = arc.projected_hypervolume((0, 1))
    arc.insert({"tag": np.zeros(1, np.int32)}, np.array([[1.0, 1.0]]))
    assert arc.projected_hypervolume((0, 1)) >= hv0


def test_convergence_trace_extend_and_summary():
    tr = lambda hv, best, n0: ConvergenceTrace(
        objectives=("latency_ns", "cost_usd"),
        pairs=(("latency_ns", "cost_usd"),),
        front_size=np.array([2, 3]), hypervolume=np.asarray(hv, float),
        best=np.asarray(best, float), feasible_frac=np.ones(2),
        n_evals=np.array([n0, 2 * n0]))
    a = tr([[1.0], [2.0]], [5.0, 4.0], 8)
    b = tr([[1.5], [2.5]], [4.5, 3.0], 8)   # dips below a's running max
    c = a.extend(b)
    assert c.generations == 4
    np.testing.assert_array_equal(c.n_evals, [8, 16, 24, 32])
    # the seam stays monotone: hv never drops, best never rises
    np.testing.assert_allclose(c.hypervolume.ravel(), [1, 2, 2, 2.5])
    np.testing.assert_allclose(c.best, [5, 4, 4, 3])
    s = c.summary()
    assert s["generations"] == 4 and s["n_evals"] == 32
    assert s["hypervolume_final"] == [2.5] and s["best_final"] == 3.0
    with pytest.raises(ValueError):
        a.extend(ConvergenceTrace.from_history([(0, 1.0)]))


def test_convergence_trace_from_history():
    t = ConvergenceTrace.from_history(
        [(0, 3.0), (1, 5.0), (2, 1.0), ("pareto_kept", 2)],
        evals_per_step=10)
    np.testing.assert_allclose(t.best, [3.0, 3.0, 1.0])   # running best
    np.testing.assert_array_equal(t.n_evals, [10, 20, 30])
    assert t.pairs == () and t.hypervolume.shape == (3, 0)


# ---------------------------------------------------------------------------
# archive invariants
# ---------------------------------------------------------------------------
def _point_archive(capacity, n=0, seed=0):
    arc = ParetoArchive(capacity, {"tag": np.zeros((), np.int32)}, n_obj=2)
    if n:
        pts = np.random.default_rng(seed).random((n, 2))
        arc.insert({"tag": np.arange(n, dtype=np.int32)}, pts)
    return arc


def test_archive_no_dominated_point_survives():
    arc = _point_archive(64)
    rng = np.random.default_rng(1)
    seen = []
    for batch in range(4):                       # incremental insertions
        pts = rng.random((20, 2))
        seen.append(pts)
        arc.insert({"tag": np.arange(20, dtype=np.int32)}, pts)
        _, objs = arc.front()
        # every archived point is mutually nondominated ...
        assert len(pareto_front(objs)) == len(objs)
    # ... and the archive front equals the brute-force front of all inserts
    allpts = np.concatenate(seen)
    expect = np.sort(allpts[_brute_front(allpts)], axis=0)
    np.testing.assert_allclose(np.sort(objs, axis=0), expect, rtol=1e-6)
    assert arc.n_evals == 80


def test_archive_capacity_pruning_keeps_boundary_points():
    x = np.linspace(0.0, 1.0, 50)
    pts = np.stack([x, 1.0 - x], axis=1)         # 50 mutually nondominated
    arc = _point_archive(8)
    arc.insert({"tag": np.arange(50, dtype=np.int32)}, pts)
    _, objs = arc.front()
    assert len(objs) == 8                        # pruned to capacity
    # crowding pruning must preserve the per-objective extremes
    assert objs[:, 0].min() == pytest.approx(0.0)
    assert objs[:, 1].min() == pytest.approx(0.0)


def test_archive_drops_nonfinite_rows():
    arc = _point_archive(8)
    pts = np.array([[0.5, 0.5], [np.nan, 0.1], [0.1, np.inf]])
    arc.insert({"tag": np.zeros(3, np.int32)}, pts)
    _, objs = arc.front()
    np.testing.assert_allclose(objs, [[0.5, 0.5]])


def test_archive_save_load_roundtrip(tmp_path):
    arc = _point_archive(16, n=30)
    arc.searched = ("latency_ns", "cost_usd")
    p = arc.save(tmp_path / "a.npz")
    back = ParetoArchive.load(p)
    assert back.searched == ("latency_ns", "cost_usd")
    np.testing.assert_array_equal(back.objs, arc.objs)
    np.testing.assert_array_equal(back.valid, arc.valid)
    np.testing.assert_array_equal(back.designs["tag"], arc.designs["tag"])
    assert back.n_evals == arc.n_evals == 30
    assert back.capacity == 16 and back.n_obj == 2


def test_spec_space_key_canonical():
    g1, spec1, space1 = _tiny_problem()
    g2, spec2, space2 = _tiny_problem()          # equal content, new objects
    assert spec_space_key(spec1, space1) == spec_space_key(spec2, space2)
    # any DesignSpace bound change => different archive
    assert spec_space_key(spec1, C.DesignSpace(spec1, max_logB=2)) \
        != spec_space_key(spec1, space1)
    # different ch_max changes the padded dims => different archive
    _, spec3, space3 = _tiny_problem(ch_max=3)
    assert spec_space_key(spec3, space3) != spec_space_key(spec1, space1)
    # extra cache-identity (the service folds its TechConstants in here)
    assert spec_space_key(spec1, space1, extra="t") \
        != spec_space_key(spec1, space1)


def test_service_cache_is_tech_keyed(tmp_path):
    from repro.core.constants import DEFAULT_TECH
    import dataclasses as dc
    _, spec, space = _tiny_problem()
    a = ExplorationService(cache_dir=tmp_path)
    b = ExplorationService(cache_dir=tmp_path, tech=DEFAULT_TECH)
    # None normalizes to DEFAULT_TECH: same archive
    assert a.problem_key(spec, space) == b.problem_key(spec, space)
    other = dc.replace(DEFAULT_TECH,
                       dram_bw=DEFAULT_TECH.dram_bw * 2)
    c = ExplorationService(cache_dir=tmp_path, tech=other)
    # different tech constants must never share an archive
    assert c.problem_key(spec, space) != a.problem_key(spec, space)


# ---------------------------------------------------------------------------
# NSGA-II explorer
# ---------------------------------------------------------------------------
def test_nsga_front_correct_vs_bruteforce_sweep():
    _, spec, space = _tiny_problem()
    cfg = NSGAConfig(pop=8, generations=3)
    run = make_nsga(spec, space, ("latency_ns", "cost_usd"), cfg)
    pop0 = jax.vmap(lambda k: C.random_design(k, space))(
        jax.random.split(jax.random.PRNGKey(0), cfg.pop))
    pop, raw, sel, ev_designs, ev_raw, ev_feas, trace = run(
        jax.random.PRNGKey(1), pop0)

    raw = np.asarray(raw, np.float64)
    assert raw.shape == (cfg.pop, 4) and np.all(np.isfinite(raw))
    assert np.asarray(ev_raw).shape == (cfg.generations, cfg.pop, 4)
    assert np.asarray(ev_feas).shape == (cfg.generations, cfg.pop)
    assert np.asarray(ev_feas).dtype == bool
    # final population's latency-cost front == brute-force dominance sweep
    cols = raw[:, [0, 2]]
    assert sorted(pareto_front(cols)) == sorted(_brute_front(cols))
    # elitism: the front is nonempty and every design evaluable
    assert len(pareto_front(cols)) >= 1
    # every returned design stays inside the encoding bounds
    sh = np.asarray(jax.tree.map(np.asarray, pop)["shape"])
    assert sh.min() >= 1 and np.all(sh <= np.asarray(space.max_shape))


def test_nsga_scans_out_convergence_trace():
    """The scan emits per-generation telemetry with zero extra evals: the
    running hypervolume is monotone non-decreasing, the running best is
    monotone non-increasing, and the hv matches the host recomputation."""
    _, spec, space = _tiny_problem()
    cfg = NSGAConfig(pop=8, generations=4)
    objectives = ("latency_ns", "cost_usd")
    run = make_nsga(spec, space, objectives, cfg)
    pop0 = jax.vmap(lambda k: C.random_design(k, space))(
        jax.random.split(jax.random.PRNGKey(0), cfg.pop))
    pop, raw, sel, _d, ev_raw, ev_feas, tr = run(jax.random.PRNGKey(1), pop0)

    t = ConvergenceTrace.from_scan(objectives, tr, cfg.pop)
    assert t.generations == cfg.generations
    assert t.pairs == (("latency_ns", "cost_usd"),)
    assert t.hypervolume.shape == (cfg.generations, 1)
    assert np.all(np.diff(t.hypervolume, axis=0) >= 0)       # monotone
    # the instantaneous per-generation hv is traced alongside: its running
    # max IS the monotone hypervolume column
    assert t.hv_gen is not None and t.hv_gen.shape == t.hypervolume.shape
    np.testing.assert_allclose(np.maximum.accumulate(t.hv_gen, axis=0),
                               t.hypervolume, rtol=1e-6)
    assert np.all(np.diff(t.best) <= 1e-6)
    assert np.all((0 <= t.feasible_frac) & (t.feasible_frac <= 1))
    assert np.all(t.front_size >= 0) and np.all(t.front_size <= cfg.pop)
    np.testing.assert_array_equal(
        t.n_evals, cfg.pop * (np.arange(cfg.generations) + 1))
    # final-generation running hv >= hv of the final population's feasible
    # log-front recomputed on the host (running max can only exceed it)
    logs = np.log(np.maximum(np.asarray(raw, np.float64)[:, [0, 2]], 1e-3))
    hv_host = hypervolume_2d(logs, (HV_LOG_REF, HV_LOG_REF))
    assert t.hypervolume[-1, 0] >= hv_host * (1 - 1e-4)


def test_pmx_always_yields_valid_permutations():
    """The placement crossover must keep children valid permutations for
    every cut-point draw and any parent pair."""
    rng = np.random.default_rng(0)
    for t in range(32):
        n = int(rng.integers(2, 24))
        a = jnp.asarray(rng.permutation(n).astype(np.int32))
        b = jnp.asarray(rng.permutation(n).astype(np.int32))
        c = np.asarray(pmx(jax.random.PRNGKey(t), a, b))
        assert sorted(c.tolist()) == list(range(n))


def test_pmx_mixes_both_parents():
    """Unlike whole-field take (child == one parent), PMX produces children
    carrying genes of BOTH parents for some cut points."""
    a = jnp.arange(10, dtype=jnp.int32)
    b = jnp.asarray(np.arange(10)[::-1].copy().astype(np.int32))
    mixed = 0
    for t in range(40):
        c = np.asarray(pmx(jax.random.PRNGKey(t), a, b))
        if not (np.array_equal(c, np.asarray(a))
                or np.array_equal(c, np.asarray(b))):
            mixed += 1
    assert mixed > 0


def test_nsga_pmx_placement_flag():
    """With ``pmx_placement`` on, the run completes and every evaluated
    design's placement is still a valid permutation."""
    _, spec, space = _tiny_problem()
    cfg = NSGAConfig(pop=8, generations=2, pmx_placement=True,
                     crossover_rate=1.0)     # force crossover every field
    run = make_nsga(spec, space, ("latency_ns", "cost_usd"), cfg)
    pop0 = jax.vmap(lambda k: C.random_design(k, space))(
        jax.random.split(jax.random.PRNGKey(0), cfg.pop))
    pop, raw, sel, ev_designs, ev_raw, ev_feas, trace = run(
        jax.random.PRNGKey(1), pop0)
    n = space.W * space.CH
    places = np.asarray(ev_designs["placement"]).reshape(-1, n)
    for row in places:
        assert sorted(row.tolist()) == list(range(n))
    assert np.all(np.isfinite(np.asarray(raw)))


# ---------------------------------------------------------------------------
# the exploration service: batching + cache
# ---------------------------------------------------------------------------
def test_service_cache_roundtrip_and_warm_start(tmp_path):
    g, spec, space = _tiny_problem()
    mk = lambda: ExplorationService(cache_dir=tmp_path,
                                    nsga=NSGAConfig(pop=8, generations=2))
    svc = mk()
    r1 = svc.explore(g, ("latency_ns", "cost_usd"), budget=16, ch_max=2,
                     space_kwargs=TINY_SPACE_KW)
    assert not r1.from_cache and r1.n_evals_run >= 16
    assert len(r1.front_objs) >= 1
    # the front the service returns is nondominated
    assert len(pareto_front(r1.front_objs)) == len(r1.front_objs)

    # identical query on the warm service: served from the archive
    r2 = svc.explore(g, ("latency_ns", "cost_usd"), budget=16, ch_max=2,
                     space_kwargs=TINY_SPACE_KW)
    assert r2.from_cache and r2.n_evals_run == 0
    np.testing.assert_allclose(r2.front_objs, r1.front_objs)
    assert r2.elapsed_s < r1.elapsed_s

    # fresh service, same cache dir: disk round-trip, identical front
    r3 = mk().explore(g, ("latency_ns", "cost_usd"), budget=16, ch_max=2,
                     space_kwargs=TINY_SPACE_KW)
    assert r3.from_cache and r3.cache_key == r1.cache_key
    np.testing.assert_allclose(r3.front_objs, r1.front_objs)

    # bigger budget invalidates the cache and warm-starts instead
    r4 = svc.explore(g, ("latency_ns", "cost_usd"), budget=48, ch_max=2,
                     space_kwargs=TINY_SPACE_KW)
    assert not r4.from_cache and r4.n_evals_run >= 32

    # objectives never searched for must spend compute, however warm the
    # archive is on other axes
    r5 = svc.explore(g, ("energy_pj", "area_mm2"), budget=16, ch_max=2,
                     space_kwargs=TINY_SPACE_KW)
    assert not r5.from_cache and r5.n_evals_run >= 16


def test_service_batches_same_spec_queries(tmp_path):
    from repro.explore.service import ExploreQuery
    g, _, _ = _tiny_problem()
    svc = ExplorationService(cache_dir=tmp_path,
                             nsga=NSGAConfig(pop=8, generations=2))
    qs = [ExploreQuery(g, ("latency_ns", "cost_usd"), budget=16, ch_max=2,
                       space_kwargs=TINY_SPACE_KW),
          ExploreQuery(g, ("energy_pj", "area_mm2"), budget=16, ch_max=2,
                       space_kwargs=TINY_SPACE_KW)]
    ra, rb = svc.explore_batch(qs)
    # one shared run answered both ...
    assert ra.cache_key == rb.cache_key
    assert not ra.from_cache and not rb.from_cache
    # ... each projected onto its own objectives, each nondominated
    for r in (ra, rb):
        assert r.front_objs.shape[1] == 2
        assert len(pareto_front(r.front_objs)) == len(r.front_objs)
    # both served from one archive: total evals booked once
    assert svc.archive_for(
        *_tiny_problem()[1:], key=ra.cache_key).n_evals == ra.n_evals_run


def test_service_front_contains_only_feasible_designs(tmp_path):
    """NSGA may keep constraint-violating designs in its gene pool (the
    penalty steers them out), but none may be archived or served."""
    g, _, _ = _tiny_problem()
    kw = dict(TINY_SPACE_KW, max_total_pes=2048)   # binding PE budget
    svc = ExplorationService(cache_dir=tmp_path,
                             nsga=NSGAConfig(pop=16, generations=2))
    r = svc.explore(g, ("latency_ns", "cost_usd"), budget=48, ch_max=2,
                    space_kwargs=kw)
    for d in r.front_designs:
        assert int(np.prod(d["shape"], axis=1).sum()) <= 2048
    # the archive itself holds no infeasible point either
    _, spec, _ = _tiny_problem()
    space = C.DesignSpace(spec, **kw)
    designs, _objs = svc.archive_for(spec, space).front()
    for i in range(len(_objs)):
        assert int(np.prod(designs["shape"][i], axis=1).sum()) <= 2048


def test_optimize_records_into_archive():
    """The scalarized BO x SA engine feeds the same Pareto cache the
    service serves from: optimize(archive=...) batch-inserts every
    SA-refined design with its raw metric vector."""
    from repro.core.optimizer import SAConfig, optimize
    g, spec, space = _tiny_problem()
    arc = ParetoArchive(
        32, jax.tree.map(np.asarray,
                         C.random_design(jax.random.PRNGKey(0), space)),
        n_obj=4, obj_keys=C.METRIC_KEYS)
    r = optimize(spec, space, jax.random.PRNGKey(0), bo_fields=(),
                 n_init=3, sa=SAConfig(steps=20, chains=2), archive=arc)
    designs, objs = arc.front()
    assert len(arc) >= 1 and arc.n_evals == 3
    assert objs.shape[1] == 4 and np.all(np.isfinite(objs))
    # archived rows are mutually nondominated designs within bounds
    assert len(pareto_front(objs)) == len(objs)
    assert np.asarray(designs["shape"]).min() >= 1


def test_service_rejects_unknown_objective():
    g, _, _ = _tiny_problem()
    with pytest.raises(ValueError):
        ExploreQuery(g, objectives=("latency_ns", "nope"))


# ---------------------------------------------------------------------------
# convergence-aware exploration: telemetry, plateau stopping, budget ledger
# ---------------------------------------------------------------------------
def test_default_cache_dir_is_repo_anchored(tmp_path, monkeypatch):
    """Regression: the default cache must not fragment across working
    directories — it is anchored to the repo root unless overridden."""
    from repro.explore import service as service_mod
    monkeypatch.delenv("REPRO_EXPLORE_CACHE", raising=False)
    monkeypatch.chdir(tmp_path)                  # CWD must be irrelevant
    svc = ExplorationService()
    assert svc.cache_dir.is_absolute()
    root = Path(service_mod.__file__).resolve().parents[3]
    assert svc.cache_dir == root / "artifacts" / "explore_cache"
    assert svc.cache_dir == Path(service_mod.DEFAULT_CACHE_DIR)
    # the env var and the explicit argument still override, in that order
    monkeypatch.setenv("REPRO_EXPLORE_CACHE", str(tmp_path / "env"))
    assert ExplorationService().cache_dir == tmp_path / "env"
    assert ExplorationService(cache_dir=tmp_path / "arg").cache_dir \
        == tmp_path / "arg"


def test_explore_result_carries_convergence_trace(tmp_path):
    g, _, _ = _tiny_problem()
    svc = ExplorationService(cache_dir=tmp_path, nsga=NSGAConfig(pop=8),
                             policy=BudgetPolicy(chunk_generations=2))
    r = svc.explore(g, ("latency_ns", "cost_usd"), budget=32, ch_max=2,
                    space_kwargs=TINY_SPACE_KW)
    t = r.trace
    assert isinstance(t, ConvergenceTrace)
    assert t.objectives == ("latency_ns", "cost_usd")
    # one generation of telemetry per pop-wide evaluation actually spent
    assert t.n_evals[-1] == r.n_evals_run
    assert len(t.front_size) == t.generations
    # the acceptance gate: per-generation front size + hypervolume, the hv
    # monotone non-decreasing for the archive-backed front
    assert np.all(t.front_size >= 0)
    assert np.all(np.diff(t.hypervolume, axis=0) >= 0)
    assert np.all(np.diff(t.archive_hv, axis=0) >= -1e-6)
    assert t.archive_hv.shape[1] == len(t.pairs)
    # the trace summary is persisted with the archive npz
    back = ParetoArchive.load(svc._path(r.cache_key))
    assert back.trace_summary == t.summary()
    assert back.budget_covered >= 32
    # a warm (cache-served) answer spends nothing and carries no new trace
    r2 = svc.explore(g, ("latency_ns", "cost_usd"), budget=32, ch_max=2,
                     space_kwargs=TINY_SPACE_KW)
    assert r2.from_cache and r2.trace is None


@pytest.mark.slow
def test_explore_deterministic_given_key(tmp_path):
    """Same PRNG key + cold cache => identical fronts AND identical
    convergence traces, bit for bit."""
    g, _, _ = _tiny_problem()
    results = []
    for sub in ("a", "b"):
        svc = ExplorationService(cache_dir=tmp_path / sub,
                                 nsga=NSGAConfig(pop=8),
                                 policy=BudgetPolicy(chunk_generations=2))
        results.append(svc.explore(
            g, ("latency_ns", "cost_usd"), budget=32, ch_max=2,
            space_kwargs=TINY_SPACE_KW, key=jax.random.PRNGKey(7)))
    ra, rb = results
    np.testing.assert_array_equal(ra.front_objs, rb.front_objs)
    np.testing.assert_array_equal(ra.front_metrics, rb.front_metrics)
    assert ra.n_evals_run == rb.n_evals_run
    np.testing.assert_array_equal(ra.trace.front_size, rb.trace.front_size)
    np.testing.assert_array_equal(ra.trace.hypervolume,
                                  rb.trace.hypervolume)
    np.testing.assert_array_equal(ra.trace.best, rb.trace.best)
    np.testing.assert_array_equal(ra.trace.feasible_frac,
                                  rb.trace.feasible_frac)
    np.testing.assert_array_equal(ra.trace.archive_hv, rb.trace.archive_hv)


def test_plateau_early_stop_banks_budget(tmp_path):
    """With an always-satisfied plateau threshold the service stops after
    patience+1 segments and banks the rest of the budget in the ledger."""
    g, _, _ = _tiny_problem()
    svc = ExplorationService(
        cache_dir=tmp_path, nsga=NSGAConfig(pop=8),
        policy=BudgetPolicy(chunk_generations=1, plateau_rel=10.0,
                            patience=1, reallocate=False))
    r = svc.explore(g, ("latency_ns", "cost_usd"), budget=64, ch_max=2,
                    space_kwargs=TINY_SPACE_KW)
    # 8 generations planned (pop 8), stopped after segment 2 of 8
    assert r.plateaued
    assert r.n_evals_run == 16 and r.n_evals_banked == 48
    assert svc.ledger == {r.cache_key: 48}
    # early-stopped or not, the query's budget counts as covered: the
    # identical query is served warm
    r2 = svc.explore(g, ("latency_ns", "cost_usd"), budget=64, ch_max=2,
                     space_kwargs=TINY_SPACE_KW)
    assert r2.from_cache
    # ... and budget coverage survives the disk round-trip
    svc2 = ExplorationService(
        cache_dir=tmp_path, nsga=NSGAConfig(pop=8),
        policy=BudgetPolicy(chunk_generations=1, plateau_rel=10.0,
                            patience=1, reallocate=False))
    r3 = svc2.explore(g, ("latency_ns", "cost_usd"), budget=64, ch_max=2,
                      space_kwargs=TINY_SPACE_KW)
    assert r3.from_cache


def test_plateau_disabled_spends_full_budget(tmp_path):
    g, _, _ = _tiny_problem()
    svc = ExplorationService(
        cache_dir=tmp_path, nsga=NSGAConfig(pop=8),
        policy=BudgetPolicy(chunk_generations=1, plateau_rel=10.0,
                            patience=1, adaptive=False))
    r = svc.explore(g, ("latency_ns", "cost_usd"), budget=64, ch_max=2,
                    space_kwargs=TINY_SPACE_KW)
    assert not r.plateaued and r.n_evals_run == 64
    assert r.n_evals_banked == 0 and svc.ledger == {}
    # chunked and single-scan spending agree on the accounting
    assert r.trace.generations == 8 and r.trace.n_evals[-1] == 64


@pytest.mark.slow
def test_batch_reallocates_banked_budget(tmp_path):
    """A plateaued problem's banked evaluations flow to the batch's
    under-explored, still-improving problem."""
    g1, _, _ = _tiny_problem()
    g2 = C.presets.bert_mms()["att3"]
    svc = ExplorationService(
        cache_dir=tmp_path, nsga=NSGAConfig(pop=8),
        policy=BudgetPolicy(chunk_generations=1, plateau_rel=10.0,
                            patience=1))
    qs = [ExploreQuery(g1, ("latency_ns", "cost_usd"), budget=64, ch_max=2,
                       space_kwargs=TINY_SPACE_KW),
          ExploreQuery(g2, ("latency_ns", "cost_usd"), budget=8, ch_max=2,
                       space_kwargs=TINY_SPACE_KW)]
    ra, rb = svc.explore_batch(qs)
    assert ra.cache_key != rb.cache_key
    # g1 plateaued and banked; g2 ran its whole (1-segment) budget without
    # a plateau verdict, so it is the reallocation taker
    assert ra.plateaued and ra.n_evals_banked > 0
    assert rb.n_evals_realloc > 0
    assert rb.n_evals_run == 8 + rb.n_evals_realloc
    # the taker's archive really recorded the extra evaluations ...
    spec2 = C.SystemSpec.build(g2, ch_max=2)
    space2 = C.DesignSpace(spec2, **TINY_SPACE_KW)
    assert svc.archive_for(spec2, space2).n_evals == rb.n_evals_run
    # ... its trace covers them ...
    assert rb.trace.n_evals[-1] == rb.n_evals_run
    # ... and the spent credit was drained from the ledger
    assert sum(svc.ledger.values()) \
        == ra.n_evals_banked - rb.n_evals_realloc
