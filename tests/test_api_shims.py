"""Deprecation shims over the unified ``repro.api`` path: each of the
four legacy entry points (``ExplorationService.explore`` /
``explore_batch``, ``optimize`` / ``two_stage_optimize``) emits exactly
ONE ``DeprecationWarning`` and returns results bit-identical to the
equivalent ``Session.submit`` call."""

import warnings

import numpy as np
import pytest

import jax

import repro.core as C
from repro.api import Problem, Query, Session
from repro.core.optimizer import SAConfig, optimize, two_stage_optimize
from repro.explore.nsga import NSGAConfig
from repro.explore.service import (BudgetPolicy, ExplorationService,
                                   ExploreQuery)

TINY = dict(max_shape=(16, 16, 4, 4, 1, 2))
OBJ = ("latency_ns", "cost_usd")
KEY = jax.random.PRNGKey(3)


def _graph(k=64):
    return C.WorkloadGraph([C.matmul("mm", 512, 512, k)], [])


def _svc(tmp_path, sub):
    return ExplorationService(cache_dir=tmp_path / sub,
                              nsga=NSGAConfig(pop=8, generations=2),
                              policy=BudgetPolicy(adaptive=False))


def _deprecations(rec):
    return [w for w in rec if issubclass(w.category, DeprecationWarning)
            and str(w.message).startswith("legacy entry point")]


def _assert_identical_fronts(legacy, new_raw):
    np.testing.assert_array_equal(legacy.front_objs, new_raw.front_objs)
    np.testing.assert_array_equal(legacy.front_metrics,
                                  new_raw.front_metrics)
    assert legacy.n_evals_run == new_raw.n_evals_run
    assert legacy.cache_key == new_raw.cache_key
    assert legacy.from_cache == new_raw.from_cache
    for dl, dn in zip(legacy.front_designs, new_raw.front_designs):
        for k in dl:
            np.testing.assert_array_equal(dl[k], dn[k])


def test_explore_shim_warns_once_and_matches_submit(tmp_path):
    with pytest.warns(DeprecationWarning) as rec:
        legacy = _svc(tmp_path, "a").explore(
            _graph(), OBJ, budget=16, ch_max=2, space_kwargs=TINY, key=KEY)
    assert len(_deprecations(rec)) == 1
    new = Session(service=_svc(tmp_path, "b")).submit(
        Query(Problem(_graph(), OBJ, 2, TINY), budget=16, engine="nsga"),
        key=KEY)
    _assert_identical_fronts(legacy, new.raw)
    np.testing.assert_array_equal(legacy.trace.hypervolume,
                                  new.raw.trace.hypervolume)


def test_explore_batch_shim_warns_once_and_matches_submit(tmp_path):
    qs = lambda: [ExploreQuery(_graph(), OBJ, 16, 2, TINY),
                  ExploreQuery(_graph(), ("energy_pj", "area_mm2"),
                               16, 2, TINY)]
    with pytest.warns(DeprecationWarning) as rec:
        legacy = _svc(tmp_path, "a").explore_batch(qs(), key=KEY)
    assert len(_deprecations(rec)) == 1    # one warning per CALL, not per
    #                                        query in the batch
    new = Session(service=_svc(tmp_path, "b")).submit(
        [Query(Problem(q.graph, q.objectives, q.ch_max, q.space_kwargs),
               budget=q.budget, engine="nsga") for q in qs()], key=KEY)
    assert len(legacy) == len(new) == 2
    for lr, nr in zip(legacy, new):
        _assert_identical_fronts(lr, nr.raw)


def test_optimize_shim_warns_once_and_matches_submit(tmp_path):
    spec = C.SystemSpec.build(_graph(), ch_max=2)
    space = C.DesignSpace(spec, **TINY)
    kw = dict(weights=(1.0, 1.0, 0.0, 0.0), bo_fields=(), n_init=2,
              sa=SAConfig(steps=10, chains=2))
    with pytest.warns(DeprecationWarning) as rec:
        legacy = optimize(spec, space, KEY, **kw)
    assert len(_deprecations(rec)) == 1
    new = Session(cache_dir=tmp_path / "b").submit(
        Query(Problem.from_spec(spec, space), engine="bo_sa",
              weights=kw["weights"],
              engine_opts=dict(bo_fields=(), n_init=2, sa=kw["sa"])),
        key=KEY)
    assert legacy.objective == new.raw.objective == new.best_objective
    assert legacy.history == new.raw.history
    for k in legacy.design:
        np.testing.assert_array_equal(np.asarray(legacy.design[k]),
                                      np.asarray(new.raw.design[k]))
    for k in legacy.metrics:
        np.testing.assert_array_equal(np.asarray(legacy.metrics[k]),
                                      np.asarray(new.raw.metrics[k]))


def test_two_stage_shim_warns_once_and_matches_submit(tmp_path):
    spec = C.SystemSpec.build(_graph(), ch_max=2)
    space = C.DesignSpace(spec, **TINY)
    sa = SAConfig(steps=8, chains=2)
    with pytest.warns(DeprecationWarning) as rec:
        legacy = two_stage_optimize(spec, space, KEY, n_candidates=2,
                                    sa=sa)
    assert len(_deprecations(rec)) == 1    # the nested optimize() calls
    #                                        run through the backend impl,
    #                                        not the warning shim
    new = Session(cache_dir=tmp_path / "b").submit(
        Query(Problem.from_spec(spec, space), engine="two_stage",
              engine_opts=dict(n_candidates=2, sa=sa)), key=KEY)
    assert legacy.objective == new.raw.objective
    for k in legacy.design:
        np.testing.assert_array_equal(np.asarray(legacy.design[k]),
                                      np.asarray(new.raw.design[k]))
    assert legacy.history == new.raw.history


def test_module_level_explore_delegates_with_one_warning(tmp_path):
    from repro.explore.service import explore
    svc = _svc(tmp_path, "mod")
    with pytest.warns(DeprecationWarning) as rec:
        r = explore(_graph(), OBJ, budget=16, ch_max=2,
                    space_kwargs=TINY, service=svc, key=KEY)
    assert len(_deprecations(rec)) == 1
    assert r.n_evals_run >= 16
