"""Per-architecture smoke tests (reduced same-family configs, CPU).

For each of the 10 assigned architectures: instantiate the reduced config,
run one forward + loss + grad step, and a prefill + 2 decode steps,
asserting output shapes and absence of NaNs.  The FULL configs are only
exercised via the dry-run (ShapeDtypeStruct, no allocation).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced, cells
from repro.models.model import build_model

B, S = 2, 16


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab)}
    if cfg.family == "encdec":
        batch["audio_embeds"] = jax.random.normal(
            ks[1], (B, cfg.enc_positions, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            ks[1], (B, 4, cfg.d_model), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(S)[None, :, None], (B, S, 3))
        batch["positions"] = pos
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_grad(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    logits, aux = jax.jit(model.forward)(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())

    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(model.loss, has_aux=True))(params, batch)
    assert np.isfinite(float(loss))
    gnorm = jax.tree.reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))),
        grads, jnp.zeros(()))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    max_seq = S + cfg.meta_tokens + 8

    cache = model.init_cache(B, max_seq)
    logits, cache = jax.jit(model.prefill)(params, batch, cache)
    assert logits.shape == (B, 1, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())

    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    start = S + cfg.meta_tokens
    for step in range(2):
        logits, cache = jax.jit(model.decode_step)(
            params, tok, cache, start + step)
        assert logits.shape == (B, 1, cfg.vocab)
        assert not bool(jnp.isnan(logits).any())
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The FULL config fields must match the assigned table exactly."""
    cfg = get_config(arch)
    expect = {
        "deepseek_v2_236b": (60, 5120, 128, 102400),
        "grok_1_314b": (64, 6144, 48, 131072),
        "stablelm_1_6b": (24, 2048, 32, 100352),
        "qwen2_72b": (80, 8192, 64, 152064),
        "qwen2_5_32b": (64, 5120, 40, 152064),
        "internlm2_1_8b": (24, 2048, 16, 92544),
        "whisper_tiny": (4, 384, 6, 51865),
        "hymba_1_5b": (32, 1600, 25, 32001),
        "falcon_mamba_7b": (64, 4096, 0, 65024),
        "qwen2_vl_72b": (80, 8192, 64, 152064),
    }[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.vocab) == expect


def test_cells_long500k_only_subquadratic():
    for arch in ARCH_IDS:
        has_long = "long_500k" in cells(arch)
        assert has_long == (get_config(arch).family in ("ssm", "hybrid"))


def test_param_counts_in_class():
    """Analytic parameter counts should land near the advertised sizes."""
    approx = {
        "deepseek_v2_236b": 236e9, "grok_1_314b": 314e9,
        "qwen2_72b": 72e9, "qwen2_5_32b": 32e9,
        "stablelm_1_6b": 1.6e9, "internlm2_1_8b": 1.8e9,
        "hymba_1_5b": 1.5e9, "falcon_mamba_7b": 7e9,
    }
    for arch, target in approx.items():
        n = get_config(arch).param_count()
        assert 0.5 * target < n < 1.7 * target, (arch, n, target)


def test_moe_gather_equals_einsum_dispatch():
    """The §Perf gather dispatch must be numerically identical to the
    one-hot einsum dispatch (same capacity/drop semantics)."""
    import dataclasses
    from repro.models import layers as Ly
    for arch in ("deepseek_v2_236b", "grok_1_314b"):
        cfg = get_reduced(arch)
        p = Ly.moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                              jnp.float32)
        ye, ae = Ly.moe_apply(p, dataclasses.replace(cfg, moe_impl="einsum"), x)
        yg, ag = Ly.moe_apply(p, dataclasses.replace(cfg, moe_impl="gather"), x)
        np.testing.assert_allclose(np.asarray(ye), np.asarray(yg),
                                   atol=3e-5, rtol=3e-5)
        assert float(jnp.abs(ae - ag)) < 1e-6
