"""The async serving layer (``repro.serve``) and the shared-cache
concurrency fixes it rides on: durable ``JobStore`` claims and crash
recovery, query payload round-trips, ``submit_async`` job handles
(poll / await / cancel / streamed events), overload degradation to
possibly-stale cached fronts, cooperative interrupt + checkpointed
resume (bit-identical final front, residual-only spend — including a
real SIGKILL of a worker process), the manifest lost-update regression
(lock → reload → merge → replace), archive peer-merge on save, plateau
streak semantics across reallocation top-ups, and run-partitioned
journal replay under overlapping submissions."""

import json
import os
import signal
import subprocess
import sys
import threading
import time
import warnings
from pathlib import Path

import numpy as np
import pytest

import jax

import repro.core as C
from repro import obs
from repro.api import Problem, Query, Session
from repro.core.workload import workload_features
from repro.explore.archive import (MANIFEST_NAME, ArchiveManifest,
                                   ParetoArchive)
from repro.explore.nsga import NSGAConfig
from repro.explore.service import (BudgetPolicy, ExplorationService,
                                   PlateauState, RunControl)
from repro.serve import (CANCELLED, DONE, PENDING, RUNNING,
                         CancelledError, Executor, JobHandle, JobStore,
                         graph_from_json, graph_to_json,
                         query_from_payload, query_to_payload, run_job)

TINY = dict(max_shape=(16, 16, 4, 4, 1, 2))
OBJ = ("latency_ns", "cost_usd")
SRC = str(Path(__file__).resolve().parents[1] / "src")


def _graph(k=64):
    return C.WorkloadGraph([C.matmul("mm", 512, 512, k)], [])


def _problem(k=64):
    return Problem(_graph(k), objectives=OBJ, ch_max=2, space_kwargs=TINY)


def _session(tmp_path, **policy_kw):
    policy_kw.setdefault("chunk_generations", 1)
    policy_kw.setdefault("adaptive", False)
    return Session(cache_dir=tmp_path,
                   nsga=NSGAConfig(pop=8, generations=2),
                   policy=BudgetPolicy(**policy_kw))


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return env


# ---------------------------------------------------------------------------
# JobStore: durable records, lock-arbitrated claims, crash recovery
# ---------------------------------------------------------------------------
def test_jobstore_lifecycle_and_claim_exclusivity(tmp_path):
    store = JobStore(tmp_path / "jobs")
    rec = store.create({"budget": 64}, "pkey", "ckey", seed=7)
    assert rec.state == PENDING and rec.seed == 7 and rec.attempts == 0
    got = store.get(rec.job_id)
    assert got.payload == {"budget": 64} and got.problem_key == "pkey"
    assert [r.job_id for r in store.pending()] == [rec.job_id]

    claimed = store.claim(rec.job_id)
    assert claimed.state == RUNNING and claimed.owner_pid == os.getpid()
    assert claimed.attempts == 1
    # a second claim of a RUNNING job loses
    assert store.claim(rec.job_id) is None
    assert store.pending() == []

    store.update(claimed, state=DONE, owner_pid=None,
                 n_evals_attempts=[64])
    final = store.get(rec.job_id)
    assert final.state == DONE and final.n_evals_attempts == [64]
    assert store.claim(rec.job_id) is None      # terminal stays terminal


def test_jobstore_recover_flips_dead_owners_only(tmp_path):
    store = JobStore(tmp_path / "jobs")
    dead = store.create({}, "p1", "c1", 0)
    live = store.create({}, "p2", "c2", 0)
    # a PID that is certainly dead: a child that already exited
    child = subprocess.Popen(["true"])
    child.wait()
    store.update(store.claim(dead.job_id), owner_pid=child.pid)
    store.claim(live.job_id)                    # owned by US (alive)
    recovered = store.recover()
    assert [r.job_id for r in recovered] == [dead.job_id]
    assert store.get(dead.job_id).state == PENDING
    assert store.get(dead.job_id).owner_pid is None
    assert store.get(live.job_id).state == RUNNING


def test_jobstore_tolerates_torn_record(tmp_path):
    store = JobStore(tmp_path / "jobs")
    ok = store.create({}, "p", "c", 0)
    (store.root / "job-deadbeef0000.json").write_text('{"torn":')
    with pytest.warns(UserWarning, match="unreadable job record"):
        recs = store.jobs()
    assert [r.job_id for r in recs] == [ok.job_id]


# ---------------------------------------------------------------------------
# payload round-trip: the job store must rebuild the exact Problem
# ---------------------------------------------------------------------------
def test_query_payload_roundtrips_problem_key():
    q = Query(_problem(), budget=96, engine="nsga", transfer=True)
    pay = json.loads(json.dumps(query_to_payload(q)))   # through JSON
    q2 = query_from_payload(pay)
    assert q2.problem.key() == q.problem.key()
    assert q2.problem == q.problem
    assert (q2.budget, q2.engine, q2.transfer) == (96, "nsga", True)
    # the graph round-trip alone is exact too
    g2 = graph_from_json(json.loads(json.dumps(graph_to_json(_graph()))))
    assert Problem(g2, OBJ, 2, TINY).key() == _problem().key()


def test_query_payload_rejects_non_durable_options():
    with pytest.raises(ValueError, match="do not survive"):
        query_to_payload(Query(_problem(), engine="nsga",
                               policy=BudgetPolicy()))
    with pytest.raises(ValueError, match="do not survive"):
        query_to_payload(Query(_problem(), engine="nsga",
                               seed_designs=[{"x": 1}]))


def test_executor_submit_rejects_opaque_keys_and_engines(tmp_path):
    sess = _session(tmp_path)
    ex = Executor(sess, store=tmp_path / "jobs")
    with pytest.raises(ValueError, match="integer seed"):
        ex.submit(Query(_problem(), engine="nsga"),
                  key=jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="nsga engine"):
        ex.submit(Query(_problem(), engine="bo_sa", weights=(1.0, 1.0)))
    ex.shutdown()


# ---------------------------------------------------------------------------
# plateau streak semantics (incl. the reallocation-reset regression)
# ---------------------------------------------------------------------------
def test_plateau_state_observe_reset_and_count():
    st = PlateauState()
    hv = np.array([1.0, 2.0])
    assert st.observe(hv, 0.01) == 0        # first look: nothing to judge
    assert st.observe(hv, 0.01) == 1        # flat -> streak grows
    assert st.observe(hv, 0.01) == 2
    assert st.observe(hv * 1.5, 0.01) == 0  # improvement resets
    # count=False records the hv as the next comparison base WITHOUT
    # judging (the empty-archive segment case)
    st2 = PlateauState()
    st2.observe(hv, 0.01)
    st2.observe(hv, 0.01)
    assert st2.observe(hv, 0.01, count=False) == 1  # streak untouched
    assert st2.observe(hv, 0.01) == 2       # judged against recorded hv
    st2.reset()
    assert st2.streak == 0 and st2.last_hv is None


def test_realloc_topup_gets_fresh_plateau_window():
    """The regression: a run plateaus (streak == patience), then a
    reallocation top-up extends it with FRESH budget.  Without the
    reset, the stale streak made the top-up's very first segment count
    as 'still plateaued' and the extension stopped instantly even while
    the front was improving."""
    from repro.explore.archive import ConvergenceTrace
    patience = 2
    st = PlateauState()
    flat = np.array([5.0])
    for _ in range(patience + 1):
        st.observe(flat, 0.01)
    assert st.streak >= patience            # plateaued: budget banked
    # the top-up's segments DO improve the archive
    topup = ConvergenceTrace(
        objectives=OBJ, pairs=((OBJ[0], OBJ[1]),),
        front_size=np.array([4, 5, 6]),
        hypervolume=np.array([[5.0], [5.5], [6.1]]),
        best=np.zeros(3), feasible_frac=np.ones(3),
        n_evals=np.array([8, 16, 24]),
        archive_hv=np.array([[5.0], [5.5], [6.1]]))
    st.reset()                              # what _reallocate now does
    for row in topup.archive_hv:
        streak = st.observe(row, 0.01)
        assert streak < patience, (
            "an improving top-up must never read as plateaued")


# ---------------------------------------------------------------------------
# journal: run-partitioned replay + live concurrent reads
# ---------------------------------------------------------------------------
def test_replay_partitions_overlapping_runs():
    recs = [  # two submissions of one problem, records interleaved
        dict(type="plan", key="k1", run="A", segments=[{}, {}], t=0.0),
        dict(type="segment", key="k1", run="A", n_evals=8, hv=[10.0],
             t=1.0),
        dict(type="segment", key="k1", run="B", n_evals=8, hv=[3.0],
             t=2.0),
        dict(type="segment", key="k1", run="A", n_evals=8, hv=[12.0],
             t=3.0),
        dict(type="result", key="k1", run="A", t=4.0),
        dict(type="segment", key="k1", run="B", n_evals=8, hv=[4.0],
             t=5.0),
        dict(type="result", key="k1", run="B", t=6.0),
    ]
    k = obs.replay(recs)["k1"]
    # each run's trajectory is its own — record order never splices
    # run B's segments into run A's hv path
    assert k["runs"]["A"]["hv_path"] == [10.0, 12.0]
    assert k["runs"]["B"]["hv_path"] == [3.0, 4.0]
    assert k["runs"]["A"]["segments"] == 2
    # aggregates: counters sum, trajectory comes from the latest run
    assert k["segments"] == 4 and k["n_evals"] == 32
    assert len(k["results"]) == 2 and k["planned_segments"] == 2
    assert k["final_hv"] == 4.0 and k["hv_path"] == [3.0, 4.0]


def test_replay_without_run_stamps_is_unchanged():
    recs = [
        dict(type="segment", key="k1", n_evals=8, hv=[1.0], t=1.0),
        dict(type="result", key="k1", t=2.0),
    ]
    k = obs.replay(recs)["k1"]
    assert k["segments"] == 1 and k["final_hv"] == 1.0
    assert list(k["runs"]) == [None]


def test_run_context_stamps_records_thread_locally():
    captured = []
    obs.add_sink(captured.append)
    try:
        with obs.run_context("r1"):
            assert obs.current_run() == "r1"
            obs.emit({"type": "x"})
            with obs.run_context("r2"):     # innermost wins
                obs.emit({"type": "y"})
            # a sibling thread without a context stays unstamped
            t = threading.Thread(target=lambda: obs.emit({"type": "z"}))
            t.start()
            t.join()
        obs.emit({"type": "w"})             # outside: unstamped
    finally:
        obs.remove_sink(captured.append)
    by_type = {r["type"]: r for r in captured}
    assert by_type["x"]["run"] == "r1"
    assert by_type["y"]["run"] == "r2"
    assert "run" not in by_type["z"] and "run" not in by_type["w"]


def test_journal_concurrent_writer_reader(tmp_path):
    """A reader polling a journal under active append sees only whole
    records and never warns about the writer's in-flight tail."""
    p = tmp_path / "live.jsonl"
    j = obs.Journal(p)
    N = 200
    def write():
        for i in range(N):
            j.write({"type": "seg", "i": i})
    t = threading.Thread(target=write)
    t.start()
    seen = 0
    deadline = time.monotonic() + 30
    while seen < N and time.monotonic() < deadline:
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            recs = list(obs.read_journal(p)) if p.exists() else []
        assert all(r["type"] == "seg" for r in recs)
        # a poll sees a prefix: complete records, in order
        assert [r["i"] for r in recs] == list(range(len(recs)))
        seen = len(recs)
    t.join()
    j.close()
    assert seen == N


# ---------------------------------------------------------------------------
# shared-cache writes: the lost-update regressions
# ---------------------------------------------------------------------------
def _group_for(svc, k=64):
    g = _graph(k)
    spec = C.SystemSpec.build(g, ch_max=2)
    space = C.DesignSpace(spec, **TINY)
    key = svc.problem_key(spec, space)
    arc = svc.archive_for(spec, space, key=key)
    return key, dict(arc=arc, spec=spec, space=space,
                     embedding=workload_features(spec.graph))


def _insert_row(arc, vals):
    designs = {k: v[:1] for k, v in arc.designs.items()}
    arc.insert(designs, np.asarray([vals], np.float64),
               count_evals=False)
    arc.n_evals += 8                        # an explicit 8-eval "run"


def test_manifest_lost_update_is_fixed(tmp_path):
    """The headline regression: service 1 snapshots the manifest, then
    service 2 commits an entry, then service 1 commits ITS entry from
    the stale snapshot.  The old reload-by-mtime + ``os.replace`` path
    made service 1's save silently drop service 2's records; the locked
    commit now merges the snapshot into a fresh read of the disk state
    before replacing."""
    s1 = ExplorationService(cache_dir=tmp_path)
    s2 = ExplorationService(cache_dir=tmp_path)
    m1 = s1.manifest                        # stale snapshot of record
    ck2, g2 = _group_for(s2, k=96)
    s2._update_manifest(ck2, g2)            # peer commits first
    ck1, g1 = _group_for(s1, k=64)
    s1._update_manifest(ck1, g1, m=m1)      # commit from the snapshot
    disk = ArchiveManifest.load(tmp_path / MANIFEST_NAME)
    assert ck1 in disk.entries, "slower writer lost its own entry"
    assert ck2 in disk.entries, \
        "lost update: the slower writer dropped the faster one's entry"
    # and the slower writer's cached view matches what it saved
    assert ck1 in s1.manifest.entries and ck2 in s1.manifest.entries


def test_archive_save_merges_peer_rows(tmp_path):
    """Two services refining ONE problem against one cache directory:
    the second save must union with what the first put on disk, not
    overwrite it (lock -> reload -> merge -> replace)."""
    s1 = ExplorationService(cache_dir=tmp_path)
    s2 = ExplorationService(cache_dir=tmp_path)
    key, g1 = _group_for(s1)
    _insert_row(g1["arc"], [1.0, 2.0, 1.0, 1.0])
    s1.save(key)
    time.sleep(0.01)                        # distinct mtimes
    _key2, g2 = _group_for(s2)              # loads s1's row from disk
    assert _key2 == key and len(g2["arc"]) == 1
    _insert_row(g2["arc"], [2.0, 1.0, 1.0, 1.0])    # nondominated peer
    _insert_row(g1["arc"], [0.5, 3.0, 1.0, 1.0])
    s1.save(key)                            # disk: rows {1, 3}
    time.sleep(0.01)
    s2.save(key)                            # must merge, not clobber
    disk = ParetoArchive.load(s1._path(key))
    rows = {tuple(r) for r in disk.objs[disk.valid]}
    assert (1.0, 2.0, 1.0, 1.0) in rows
    assert (2.0, 1.0, 1.0, 1.0) in rows
    assert (0.5, 3.0, 1.0, 1.0) in rows, \
        "lost update: the slower save dropped the faster one's rows"
    assert disk.n_evals == 16               # max of both ledgers, not sum


_CHILD = r"""
import sys, time
from pathlib import Path
import numpy as np
import repro.core as C
from repro.core.workload import workload_features
from repro.explore.service import ExplorationService

cache, go, k_own, row0 = sys.argv[1], sys.argv[2], int(sys.argv[3]), \
    float(sys.argv[4])
TINY = dict(max_shape=(16, 16, 4, 4, 1, 2))

def group(svc, k):
    g = C.WorkloadGraph([C.matmul("mm", 512, 512, k)], [])
    spec = C.SystemSpec.build(g, ch_max=2)
    space = C.DesignSpace(spec, **TINY)
    key = svc.problem_key(spec, space)
    arc = svc.archive_for(spec, space, key=key)
    return key, dict(arc=arc, spec=spec, space=space,
                     embedding=workload_features(spec.graph))

svc = ExplorationService(cache_dir=cache)
shared_key, shared = group(svc, 64)         # both children share this
own_key, own = group(svc, k_own)            # unique per child
designs = {k: v[:1] for k, v in shared["arc"].designs.items()}
shared["arc"].insert(designs, np.asarray([[row0, 1.0 / row0, 1.0, 1.0]]))
shared["arc"].n_evals += 8
Path(go + f".ready.{k_own}").touch()        # signal armed, then block
while not Path(go).exists():                # on the start barrier so
    time.sleep(0.005)                       # both processes race
svc.save(shared_key)                        # race the peer on purpose
svc._update_manifest(shared_key, shared)
svc._update_manifest(own_key, own)
print("OK", shared_key, own_key)
"""


@pytest.mark.slow
def test_two_processes_race_shared_cache_writes(tmp_path):
    """The satellite regression test that fails on the old code: two
    real processes save the same archive and commit manifest entries
    near-simultaneously (a go-file barrier lines them up).  Every row
    and every index entry must survive, whichever process writes last."""
    script = tmp_path / "child.py"
    script.write_text(_CHILD)
    cache, go = tmp_path / "cache", tmp_path / "go"
    procs = [subprocess.Popen(
        [sys.executable, str(script), str(cache), str(go), str(k), str(r)],
        env=_env(), stdout=subprocess.PIPE, stderr=subprocess.PIPE,
        text=True) for k, r in ((96, 2.0), (128, 4.0))]
    deadline = time.monotonic() + 240
    while not all((tmp_path / f"go.ready.{k}").exists()
                  for k in (96, 128)):
        assert time.monotonic() < deadline, "children never got ready"
        time.sleep(0.05)
    go.touch()
    outs = [p.communicate(timeout=300) for p in procs]
    assert all(p.returncode == 0 for p in procs), outs
    keys = [out.split()[1:3] for out, _ in outs]
    shared_key = keys[0][0]
    assert keys[1][0] == shared_key
    disk = ParetoArchive.load(cache / f"{shared_key}.npz")
    rows = {tuple(r) for r in disk.objs[disk.valid]}
    assert (2.0, 0.5, 1.0, 1.0) in rows and (4.0, 0.25, 1.0, 1.0) in rows
    m = ArchiveManifest.load(cache / MANIFEST_NAME)
    for ck in {shared_key, keys[0][1], keys[1][1]}:
        assert ck in m.entries, f"lost manifest entry {ck}"


# ---------------------------------------------------------------------------
# submit_async: handles, events, overload degradation, cancellation
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_submit_async_matches_sync_bit_for_bit(tmp_path):
    q = Query(_problem(), budget=64, engine="nsga")
    sync = _session(tmp_path / "sync").submit(q)            # PRNGKey(0)
    sess = _session(tmp_path / "async")
    h = sess.submit_async(q)                                # seed 0
    evs = list(h.events(timeout=300))
    r = h.result(timeout=300)
    assert h.done() and h.state() == DONE
    assert r.front_objs.tobytes() == sync.front_objs.tobytes()
    assert r.front_metrics.tobytes() == sync.front_metrics.tobytes()
    assert r.provenance.n_evals_run == 64
    # 64 evals / (pop 8 * chunk 1) = 8 streamed segments
    assert len(evs) == 8
    assert [e.segment for e in evs] == list(range(8))
    rec = h.record()
    assert rec.state == DONE and rec.attempts == 1
    assert rec.n_evals_attempts == [64]
    assert rec.problem_key == q.problem.key()
    sess.executor().shutdown()


@pytest.mark.slow
def test_overload_serves_stale_front_and_banks_refinement(tmp_path):
    sess = _session(tmp_path)
    q = Query(_problem(), budget=64, engine="nsga")
    warmed = sess.submit(q)                 # warm the archive first
    ex = Executor(sess, store=tmp_path / "jobs", max_workers=1,
                  max_pending=0)            # always overloaded
    h = ex.submit(q, deadline_s=0.0)
    # answered immediately from the cache, zero evaluations spent
    stale = h.poll()
    assert stale is not None and stale is h.stale
    pv = stale.provenance
    assert pv.stale and pv.from_cache and pv.n_evals_run == 0
    assert pv.n_evals_banked == 64
    assert stale.front_objs.tobytes() == warmed.front_objs.tobytes()
    # the refinement is banked, not dropped: the job is PENDING on disk
    assert not h.done() and h.state() == PENDING
    # ... and a later capacity window picks it up
    handles = ex.resume_pending()
    assert [x.job_id for x in handles] == [h.job_id]
    r = handles[0].result(timeout=300)
    assert handles[0].state() == DONE
    assert r.provenance.from_cache          # budget was already covered
    ex.shutdown()


@pytest.mark.slow
def test_stale_ttl_bounds_overload_serving(tmp_path):
    """``Executor(stale_ttl_s=...)``: under overload, a cached front
    younger than the TTL serves as the degradation answer; one older
    than the TTL is TOO stale — the query queues for fresh refinement
    instead of being answered with ancient data."""
    sess = _session(tmp_path)
    q = Query(_problem(), budget=64, engine="nsga")
    sess.submit(q)                          # warm the archive
    npz = sess.service._path(sess._cache_key(q.problem))
    assert npz.exists()
    # within the TTL: the cached front serves (historic degradation)
    ex = Executor(sess, store=tmp_path / "jobs", max_workers=1,
                  max_pending=0, stale_ttl_s=3600.0)
    h = ex.submit(q, deadline_s=0.0)
    assert h.stale is not None and h.stale.provenance.stale
    ex.shutdown()
    # age the archive past the TTL: nothing serves, the job queues
    old = time.time() - 7200.0
    os.utime(npz, (old, old))
    ex2 = Executor(sess, store=tmp_path / "jobs2", max_workers=1,
                   max_pending=0, stale_ttl_s=3600.0)
    h2 = ex2.submit(q, deadline_s=0.0)
    assert h2.stale is None
    r = h2.result(timeout=300)              # ran fresh instead
    assert h2.state() == DONE and not r.provenance.stale
    ex2.shutdown()


@pytest.mark.slow
def test_overload_cold_problem_queues_anyway(tmp_path):
    """Degradation needs something to serve: a cold problem (empty
    archive) is queued past the admission bound rather than answered
    with nothing."""
    sess = _session(tmp_path)
    ex = Executor(sess, store=tmp_path / "jobs", max_workers=1,
                  max_pending=0)
    h = ex.submit(Query(_problem(), budget=64, engine="nsga"),
                  deadline_s=0.0)
    assert h.stale is None
    r = h.result(timeout=300)
    assert h.state() == DONE and r.provenance.n_evals_run == 64
    ex.shutdown()


def test_cancel_pending_job_never_runs(tmp_path):
    store = JobStore(tmp_path / "jobs")
    # a banked job: durably recorded, not scheduled anywhere (what the
    # overload degradation path leaves behind)
    rec = store.create(query_to_payload(Query(_problem(), engine="nsga",
                                              budget=64)),
                       _problem().key(), "ck", 0)
    h = JobHandle(rec.job_id, store)
    assert h.cancel() is True
    assert h.state() == CANCELLED
    with pytest.raises(CancelledError):
        h.result(timeout=1)
    assert store.claim(rec.job_id) is None  # a worker can never win it
    assert h.cancel() is False              # already terminal


def test_cancelled_running_job_keeps_checkpoint_state(tmp_path):
    """run_job's cancel branch, driven deterministically: the handle's
    stop token is set before the engine starts, so the run interrupts at
    the first segment boundary and the store lands on CANCELLED."""
    sess = _session(tmp_path)
    store = JobStore(tmp_path / "jobs")
    q = Query(_problem(), budget=64, engine="nsga")
    rec = store.create(query_to_payload(q), q.problem.key(),
                       sess._cache_key(q.problem), 0)
    h = JobHandle(rec.job_id, store)
    h._cancelled = True
    h._control.stop()
    claimed = store.claim(rec.job_id)
    run_job(sess, store, claimed, handle=h)
    assert store.get(rec.job_id).state == CANCELLED
    with pytest.raises(CancelledError):
        h.result(timeout=1)


# ---------------------------------------------------------------------------
# crash-resume: cooperative interrupt and a real SIGKILL
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_interrupt_then_resume_is_bit_identical(tmp_path):
    """Kill a run at a segment boundary (cooperative stop), restart in a
    FRESH session: the checkpoint restores the last completed segment,
    only the residual budget is spent, and the final front is
    bit-identical to an uninterrupted run."""
    q = Query(_problem(), budget=64, engine="nsga")
    key = jax.random.PRNGKey(3)
    r0 = _session(tmp_path / "base").submit(q, key=key)

    sA = _session(tmp_path / "crash")
    ctl = RunControl()
    seen = []
    def stop_after_two(ev):
        seen.append(ev)
        if len(seen) == 2:
            ctl.stop()
    r1 = sA.submit(q, key=key, resume=True, control=ctl,
                   on_segment=stop_after_two)
    assert r1.provenance.interrupted and r1.provenance.n_evals_run == 16
    ck = sA._cache_key(q.problem)
    assert (tmp_path / "crash" / f"{ck}.ckpt.npz").exists()

    sB = _session(tmp_path / "crash")       # a new process, effectively
    r2 = sB.submit(q, key=key, resume=True)
    assert not r2.provenance.interrupted
    # residual-only spend: the two attempts sum to the uninterrupted run
    assert r1.provenance.n_evals_run + r2.provenance.n_evals_run \
        == r0.provenance.n_evals_run == 64
    assert r2.front_objs.tobytes() == r0.front_objs.tobytes()
    assert r2.front_metrics.tobytes() == r0.front_metrics.tobytes()
    # the checkpoint is consumed by normal completion
    assert not (tmp_path / "crash" / f"{ck}.ckpt.npz").exists()


@pytest.mark.slow
def test_sigkill_worker_then_restart_resumes(tmp_path):
    """The e2e crash drill: a real worker process is SIGKILLed
    mid-segment; a restarted worker recovers the job from the store,
    restores the checkpoint, spends only the residual budget, and lands
    on the front an uninterrupted run produces."""
    q = Query(_problem(), budget=64, engine="nsga")
    seed = 5
    r0 = _session(tmp_path / "base").submit(
        q, key=jax.random.PRNGKey(seed))    # uninterrupted baseline

    cache, store_dir = tmp_path / "cache", tmp_path / "store"
    sess = _session(cache)                  # same config as the workers
    ck = sess._cache_key(q.problem)
    store = JobStore(store_dir)
    rec = store.create(query_to_payload(q), q.problem.key(), ck, seed)

    worker_cmd = [sys.executable, "-m", "repro.serve.worker",
                  "--store", str(store_dir), "--cache", str(cache),
                  "--once", "--pop", "8", "--chunk-generations", "1",
                  "--no-adaptive"]
    w1 = subprocess.Popen(worker_cmd + ["--segment-delay", "1.0"],
                          env=_env(), stdout=subprocess.PIPE,
                          stderr=subprocess.PIPE, text=True)
    try:
        ckpt = cache / f"{ck}.ckpt.npz"
        deadline = time.monotonic() + 240
        while not ckpt.exists():            # >= 1 segment checkpointed
            assert w1.poll() is None, w1.communicate()
            assert time.monotonic() < deadline, "no checkpoint appeared"
            time.sleep(0.05)
        time.sleep(0.3)                     # well inside the delay window
        w1.send_signal(signal.SIGKILL)
        w1.wait(timeout=30)
    finally:
        if w1.poll() is None:
            w1.kill()
    after_kill = store.get(rec.job_id)
    assert after_kill.state == RUNNING      # the crash left it claimed
    assert ckpt.exists()

    w2 = subprocess.run(worker_cmd, env=_env(), capture_output=True,
                        text=True, timeout=400)
    assert w2.returncode == 0, w2.stderr
    lines = [json.loads(l) for l in w2.stdout.splitlines() if l]
    states = {l.get("state") for l in lines}
    assert "RECOVERED" in states            # dead owner detected
    done = [l for l in lines if l.get("state") == DONE]
    assert len(done) == 1 and done[0]["attempts"] == 2
    # residual-only spend: the restored attempt ran strictly less than
    # the whole budget
    assert 0 < done[0]["n_evals_attempts"][-1] < 64
    # bit-identical final front vs the uninterrupted baseline archive
    base_ck = ck
    base = ParetoArchive.load(tmp_path / "base" / f"{base_ck}.npz")
    resumed = ParetoArchive.load(cache / f"{ck}.npz")
    assert resumed.objs[resumed.valid].tobytes() \
        == base.objs[base.valid].tobytes()
    assert int(resumed.n_evals) == 64       # nothing double-counted
    assert not ckpt.exists()                # consumed on completion
