"""Per-kernel correctness: Pallas (interpret mode) and fast jnp paths vs the
pure-jnp oracles, swept over shapes/dtypes/mask kinds, plus hypothesis
property tests and gradient checks for the flash-attention custom VJP."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ops import (_fa_diff,
                                               flash_attention_blocked)
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.gp_cov.gp_cov import matern52_pallas
from repro.kernels.gp_cov.ref import matern52_ref
from repro.kernels.mamba_scan.mamba_scan import selective_scan_pallas
from repro.kernels.mamba_scan.ops import selective_scan_assoc
from repro.kernels.mamba_scan.ref import selective_scan_ref


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
FA_SHAPES = [
    # (B, Sq, Sk, H, KV, D, mask, window, kv_valid)
    (1, 32, 32, 4, 4, 16, "causal", 0, None),       # MHA
    (2, 64, 64, 8, 2, 32, "causal", 0, None),       # GQA
    (1, 64, 64, 4, 1, 64, "window", 16, None),      # MQA sliding window
    (2, 32, 32, 4, 2, 16, "none", 0, None),         # encoder
    (2, 8, 64, 4, 2, 16, "causal", 0, 40),          # decode-ish, cache mask
    (1, 16, 48, 2, 2, 8, "none", 0, 33),            # unaligned valid len
]


@pytest.mark.parametrize("shape", FA_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_pallas_matches_ref(shape, dtype):
    B, Sq, Sk, H, KV, D, mk, w, kvl = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), dtype)
    k = jax.random.normal(ks[1], (B, Sk, KV, D), dtype)
    v = jax.random.normal(ks[2], (B, Sk, KV, D), dtype)
    ref = attention_ref(q, k, v, mk, w, kvl)
    out = flash_attention_pallas(q, k, v, mk, w, kvl, block_q=8, block_k=16,
                                 interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("shape", FA_SHAPES)
def test_flash_blocked_matches_ref(shape):
    B, Sq, Sk, H, KV, D, mk, w, kvl = shape
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D))
    k = jax.random.normal(ks[1], (B, Sk, KV, D))
    v = jax.random.normal(ks[2], (B, Sk, KV, D))
    ref = attention_ref(q, k, v, mk, w, kvl)
    out = flash_attention_blocked(q, k, v, mk, w, kvl, block_k=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_blocked_traced_valid_len():
    """decode path: kv_valid_len may be a traced scalar."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (2, 1, 4, 16))
    k = jax.random.normal(ks[1], (2, 64, 2, 16))
    v = jax.random.normal(ks[2], (2, 64, 2, 16))
    f = jax.jit(lambda n: flash_attention_blocked(q, k, v, "causal", 0, n))
    for n in (3, 17, 64):
        ref = attention_ref(q, k, v, "causal", 0, n)
        np.testing.assert_allclose(np.asarray(f(n)), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)


@given(seed=st.integers(0, 1000), sq=st.sampled_from([8, 24, 40]),
       sk=st.sampled_from([16, 48]))
@settings(max_examples=10, deadline=None)
def test_flash_vjp_matches_autodiff_of_ref(seed, sq, sk):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, sq, 4, 16))
    k = jax.random.normal(ks[1], (1, sk, 2, 16))
    v = jax.random.normal(ks[2], (1, sk, 2, 16))
    f1 = lambda q, k, v: jnp.sum(jnp.sin(
        _fa_diff(q, k, v, "causal", 0, None, 16)))
    f2 = lambda q, k, v: jnp.sum(jnp.sin(attention_ref(q, k, v, "causal")))
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# mamba selective scan
# ---------------------------------------------------------------------------
MS_SHAPES = [(1, 16, 8, 4, 8), (2, 32, 16, 8, 8), (1, 64, 32, 16, 16)]


@pytest.mark.parametrize("B,S,Di,Ds,chunk", MS_SHAPES)
@pytest.mark.parametrize("with_h0", [False, True])
def test_mamba_pallas_matches_ref(B, S, Di, Ds, chunk, with_h0):
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    u = jax.random.normal(ks[0], (B, S, Di))
    dl = jax.nn.softplus(jax.random.normal(ks[1], (B, S, Di)))
    A = -jnp.exp(jax.random.normal(ks[2], (Di, Ds)) * 0.3)
    Bc = jax.random.normal(ks[3], (B, S, Ds))
    Cc = jax.random.normal(ks[4], (B, S, Ds))
    h0 = jax.random.normal(ks[5], (B, Di, Ds)) if with_h0 else None
    yr, hr = selective_scan_ref(u, dl, A, Bc, Cc, h0)
    yp, hp = selective_scan_pallas(u, dl, A, Bc, Cc, h0, chunk=chunk,
                                   interpret=True)
    np.testing.assert_allclose(np.asarray(yp), np.asarray(yr),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(hp), np.asarray(hr),
                               atol=1e-4, rtol=1e-4)


@given(seed=st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_mamba_assoc_matches_ref(seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    B, S, Di, Ds = 2, 24, 8, 4
    u = jax.random.normal(ks[0], (B, S, Di))
    dl = jax.nn.softplus(jax.random.normal(ks[1], (B, S, Di)))
    A = -jnp.exp(jax.random.normal(ks[2], (Di, Ds)) * 0.3)
    Bc = jax.random.normal(ks[3], (B, S, Ds))
    Cc = jax.random.normal(ks[4], (B, S, Ds))
    h0 = jax.random.normal(ks[5], (B, Di, Ds))
    yr, hr = selective_scan_ref(u, dl, A, Bc, Cc, h0)
    ya, ha = selective_scan_assoc(u, dl, A, Bc, Cc, h0)
    np.testing.assert_allclose(np.asarray(ya), np.asarray(yr),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(ha), np.asarray(hr),
                               atol=2e-4, rtol=2e-4)


def test_mamba_chunked_equals_two_calls():
    """state threading: scanning [0:S] equals scanning [0:S/2] then
    [S/2:S] with the carried state — the decode-step invariant."""
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    B, S, Di, Ds = 1, 32, 8, 4
    u = jax.random.normal(ks[0], (B, S, Di))
    dl = jax.nn.softplus(jax.random.normal(ks[1], (B, S, Di)))
    A = -jnp.exp(jax.random.normal(ks[2], (Di, Ds)) * 0.3)
    Bc = jax.random.normal(ks[3], (B, S, Ds))
    Cc = jax.random.normal(ks[4], (B, S, Ds))
    y_full, h_full = selective_scan_ref(u, dl, A, Bc, Cc)
    h = S // 2
    y1, h1 = selective_scan_assoc(u[:, :h], dl[:, :h], A, Bc[:, :h],
                                  Cc[:, :h])
    y2, h2 = selective_scan_assoc(u[:, h:], dl[:, h:], A, Bc[:, h:],
                                  Cc[:, h:], h0=h1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# GP covariance
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,m,d,blk", [(16, 16, 4, 8), (32, 24, 7, 8),
                                       (64, 64, 12, 32)])
@pytest.mark.parametrize("ls", [0.1, 0.5, 2.0])
def test_gp_cov_pallas_matches_ref(n, m, d, blk, ls):
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    X1 = jax.random.normal(ks[0], (n, d))
    X2 = jax.random.normal(ks[1], (m, d))
    ref = matern52_ref(X1, X2, ls)
    out = matern52_pallas(X1, X2, ls, block=blk, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_gp_cov_psd_and_unit_diag():
    X = jax.random.normal(jax.random.PRNGKey(1), (24, 5))
    K = matern52_pallas(X, X, 0.7, block=8, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.diag(K)), 1.0, atol=1e-5)
    evs = np.linalg.eigvalsh(np.asarray(K) + 1e-6 * np.eye(24))
    assert evs.min() > 0


def test_gp_cov_single_compile_across_lengthscale_sweep():
    """lengthscale is a runtime operand, not a compile-time static: a
    hyperparameter sweep under jit must hit ONE compiled kernel, not one
    per value."""
    ks = jax.random.split(jax.random.PRNGKey(4), 2)
    X1 = jax.random.normal(ks[0], (16, 6))
    X2 = jax.random.normal(ks[1], (16, 6))
    f = jax.jit(lambda ls: matern52_pallas(X1, X2, ls, block=8,
                                           interpret=True))
    for ls in (0.1, 0.3, 0.9, 2.7):
        out = f(jnp.float32(ls))
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(matern52_ref(X1, X2, ls)),
                                   atol=1e-5, rtol=1e-5)
    assert f._cache_size() == 1


# ---------------------------------------------------------------------------
# Pareto dominance counts
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,k,blk", [(128, 2, 128), (256, 4, 128),
                                     (64, 3, 64), (200, 4, 64)])
def test_pareto_rank_pallas_matches_ref(n, k, blk):
    from repro.kernels.pareto_rank.pareto_rank import dominance_counts_pallas
    from repro.kernels.pareto_rank.ref import dominance_counts_ref
    ks = jax.random.split(jax.random.PRNGKey(n + k), 2)
    objs = jax.random.normal(ks[0], (n, k))
    # duplicate a block of rows: exact ties exercise the strict-< leg
    objs = objs.at[n // 2:n // 2 + 8].set(objs[:8])
    valid = jax.random.bernoulli(ks[1], 0.8, (n,))
    pn = (-n) % blk
    objs_p = jnp.pad(objs, ((0, pn), (0, 0)))
    valid_p = jnp.pad(valid, (0, pn))
    out = dominance_counts_pallas(objs_p, valid_p, block=blk,
                                  interpret=True)[:n]
    ref = dominance_counts_ref(objs, valid)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_pareto_rank_ops_pads_ragged_pools(monkeypatch):
    """The dispatcher pads a non-block-multiple pool to the tile grid;
    padded rows are invalid dominators and their counts are sliced off —
    identical to the reference on the live rows."""
    from repro.kernels.pareto_rank import ops
    from repro.kernels.pareto_rank.ref import dominance_counts_ref
    ks = jax.random.split(jax.random.PRNGKey(7), 2)
    objs = jax.random.normal(ks[0], (190, 3))
    valid = jax.random.bernoulli(ks[1], 0.9, (190,))
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    out = ops.dominance_counts(objs, valid, block=64)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(dominance_counts_ref(objs,
                                                                  valid)))


def test_pareto_rank_all_invalid_is_zero():
    from repro.kernels.pareto_rank.pareto_rank import dominance_counts_pallas
    objs = jax.random.normal(jax.random.PRNGKey(3), (64, 2))
    valid = jnp.zeros((64,), bool)
    out = dominance_counts_pallas(objs, valid, block=64, interpret=True)
    assert int(jnp.sum(out)) == 0
