"""Tests for surrogate-gated evaluation (``repro.explore.surrogate`` +
the service/api wiring): dataset export layout, degenerate-fit guards,
the off/cold bit-identity contract, realized eval savings, and the
disagreement fallback."""

import numpy as np
import pytest

import jax

import repro.core as C
from repro.explore.archive import (ArchiveManifest, design_encoding_dim,
                                   flatten_design)
from repro.explore.service import (BudgetPolicy, ExplorationService,
                                   ExploreQuery)
from repro.explore.surrogate import (NONLINEAR_TRUST_MIN, NonlinearTrustModel,
                                     SurrogateConfig, fit_nonlinear_trust,
                                     fit_surrogate, harvest_rows)

TINY_SPACE_KW = dict(max_shape=(16, 16, 4, 4, 1, 2))
OBJ = ("latency_ns", "cost_usd")
KEY = jax.random.PRNGKey(0)


def _graph():
    return C.presets.bert_mms()["att2"]


def _svc(tmp_path, name="c"):
    return ExplorationService(cache_dir=tmp_path / name, capacity=128,
                              policy=BudgetPolicy(adaptive=False))


def _query(budget=64, surrogate=None):
    return ExploreQuery(_graph(), OBJ, budget=budget, ch_max=2,
                        space_kwargs=TINY_SPACE_KW, surrogate=surrogate)


# ---------------------------------------------------------------------------
# dataset export: flatten_design <-> export_rows layout
# ---------------------------------------------------------------------------
def test_export_rows_matches_flatten_design_layout(tmp_path):
    svc = _svc(tmp_path)
    svc.run_queries([_query(budget=32)], key=KEY)
    arc = next(iter(svc._archives.values()))
    X, Y = arc.export_rows()
    template = {k: v[0] for k, v in arc.designs.items()}
    assert X.shape == (len(np.flatnonzero(arc.valid)),
                       design_encoding_dim(template))
    assert Y.shape == (X.shape[0], 4)
    assert np.all(np.isfinite(X)) and np.all(np.isfinite(Y))
    # row i is exactly flatten_design of valid entry i — the gated scan
    # encodes candidates with the same helper, so the layouts must agree
    valid = np.flatnonzero(arc.valid)
    for row, i in zip(X[:4], valid[:4]):
        d = {k: v[i] for k, v in arc.designs.items()}
        np.testing.assert_allclose(row, np.asarray(flatten_design(d)),
                                   rtol=1e-6)


def test_export_rows_empty_archive(tmp_path):
    svc = _svc(tmp_path)
    g = _graph()
    spec = C.SystemSpec.build(g, ch_max=2)
    arc = svc.archive_for(spec, C.DesignSpace(spec, **TINY_SPACE_KW))
    X, Y = arc.export_rows()
    assert X.shape[0] == 0 and Y.shape == (0, 4)
    assert X.shape[1] == design_encoding_dim(
        {k: v[0] for k, v in arc.designs.items()})


# ---------------------------------------------------------------------------
# fitting degeneracies
# ---------------------------------------------------------------------------
def test_fit_surrogate_below_min_rows_returns_none():
    rng = np.random.default_rng(0)
    X = rng.random((10, 6)).astype(np.float32)
    Y = rng.random((10, 4)) + 0.5
    assert fit_surrogate(X, Y, SurrogateConfig(min_rows=64)) is None


def test_fit_surrogate_constant_metric_zero_variance():
    """A constant metric column (zero variance) must fit without NaN and
    predict (approximately) the constant back."""
    rng = np.random.default_rng(1)
    X = rng.random((48, 6)).astype(np.float32)
    Y = np.column_stack([np.full(48, 2.0),            # constant column
                         1.0 + rng.random((48, 3))])
    cfg = SurrogateConfig(min_rows=16, epochs=300)
    sur = fit_surrogate(X, Y, cfg)
    assert sur is not None
    mean, std = sur.predict(X[:8])
    assert np.all(np.isfinite(mean)) and np.all(np.isfinite(std))
    # zero-variance column: y_std guard pins the denormalized prediction
    # near the constant's log and the ensemble spread near zero (a
    # shared-trunk MLP never nails it exactly — loose tolerance)
    np.testing.assert_allclose(mean[:, 0], np.log(2.0), atol=0.35)
    assert np.all(std[:, 0] < 0.35)
    assert np.all(np.isfinite(sur.disagreement(X[:8])))


def test_fit_surrogate_drops_nonfinite_rows():
    rng = np.random.default_rng(2)
    X = rng.random((40, 5)).astype(np.float32)
    Y = 1.0 + rng.random((40, 4))
    X[3, 0] = np.nan
    Y[7, 2] = np.inf
    sur = fit_surrogate(X, Y, SurrogateConfig(min_rows=16, epochs=50))
    assert sur is not None and sur.n_rows == 38


def test_surrogate_config_n_exact_bounds():
    cfg = SurrogateConfig(exact_frac=0.5)
    assert cfg.n_exact(16) == 8
    assert cfg.n_exact(1) == 1
    assert SurrogateConfig(exact_frac=0.0).n_exact(16) == 1
    assert SurrogateConfig(exact_frac=1.0).n_exact(16) == 16


def test_harvest_rows_skips_mismatched_layouts():
    rows = np.random.default_rng(3).random((6, 10)).astype(np.float32)
    objs = 1.0 + np.random.default_rng(4).random((6, 4))

    class FakeArc:
        def export_rows(self):
            return rows, objs

    index = [("good", np.ones(3)), ("bad_emb", np.ones(5)),
             ("broken", np.ones(3))]
    X, Y = harvest_rows(index,
                        lambda k: None if k == "broken" else FakeArc(),
                        design_dim=10, embed_dim=3)
    assert X.shape == (6, 13) and Y.shape == (6, 4)
    np.testing.assert_allclose(X[:, :10], rows)
    np.testing.assert_allclose(X[:, 10:], 1.0)


# ---------------------------------------------------------------------------
# the off/cold bit-identity contract
# ---------------------------------------------------------------------------
def test_query_surrogate_validation():
    q = _query(surrogate=True)
    assert q.surrogate == {}                # True normalizes to defaults
    with pytest.raises(ValueError, match="surrogate"):
        _query(surrogate="yes")


def test_cold_cache_runs_exact_bit_identical(tmp_path):
    """surrogate requested on an EMPTY cache: nothing to fit, so the run
    must be byte-for-byte the surrogate=None run."""
    ra, = _svc(tmp_path, "a").run_queries([_query(surrogate=True)], key=KEY)
    rb, = _svc(tmp_path, "b").run_queries([_query()], key=KEY)
    assert not ra.surrogate_used and ra.surrogate_hits == 0
    assert ra.n_evals_run == rb.n_evals_run
    np.testing.assert_array_equal(ra.front_objs, rb.front_objs)
    np.testing.assert_array_equal(ra.front_metrics, rb.front_metrics)


# ---------------------------------------------------------------------------
# gated refinement through the service
# ---------------------------------------------------------------------------
def test_gated_run_spends_fewer_exact_evals(tmp_path):
    svc = _svc(tmp_path)
    svc.run_queries([_query(budget=64)], key=KEY)     # training rows
    r, = svc.run_queries([_query(budget=256,
                                 surrogate={"min_rows": 8, "epochs": 60})],
                         key=jax.random.PRNGKey(7))
    assert r.surrogate_used
    assert r.surrogate_fallbacks == 0
    assert r.surrogate_hits > 0
    # every generation's skipped candidates are exactly the gate's
    # non-exact slots: spent + skipped reconstructs the exact schedule
    from repro.explore import quantize
    sched = quantize.schedule(256, svc.nsga.pop,
                              svc.policy.chunk_generations)
    total = sched.pop * sched.chunk * sched.n_seg
    assert r.n_evals_run + r.surrogate_hits == total
    assert r.n_evals_run < total
    assert len(r.front_objs) > 0


def test_disagreement_fallback_abandons_surrogate(tmp_path):
    """fallback_tau below any achievable disagreement: the first gated
    segment trips the service-level fallback and the rest of the run is
    exact."""
    svc = _svc(tmp_path)
    svc.run_queries([_query(budget=64)], key=KEY)
    r, = svc.run_queries(
        [_query(budget=256, surrogate={"min_rows": 8, "epochs": 60,
                                       "fallback_tau": -1.0})],
        key=jax.random.PRNGKey(7))
    assert r.surrogate_used
    assert r.surrogate_fallbacks == 1
    # only the first segment was gated — later segments spent exact
    gated_all, = svc.run_queries(
        [_query(budget=257, surrogate={"min_rows": 8, "epochs": 60})],
        key=jax.random.PRNGKey(8))      # distinct budget => fresh refine
    assert r.surrogate_hits <= gated_all.surrogate_hits


# ---------------------------------------------------------------------------
# the non-linear trust head
# ---------------------------------------------------------------------------
def test_fit_nonlinear_trust_contract():
    rng = np.random.default_rng(5)
    records = ([dict(delta=rng.random(4) * 0.1, lift=0.9)
                for _ in range(20)]
               + [dict(delta=2.0 + rng.random(4), lift=0.05)
                  for _ in range(20)])
    tm = fit_nonlinear_trust(records, epochs=150)
    assert isinstance(tm, NonlinearTrustModel)
    near = tm.predict(np.zeros(4))
    far = tm.predict(np.full(4, 2.5))
    assert near >= 0.0 and far >= 0.0         # clamped at zero
    assert near > far                         # learned the structure
    assert tm.predict(np.zeros(9)) == 0.0     # dim mismatch => neutral


def test_fit_nonlinear_trust_below_min_returns_none():
    records = [dict(delta=np.ones(3), lift=0.5) for _ in range(4)]
    assert fit_nonlinear_trust(records) is None


def test_manifest_trust_model_switches_to_nonlinear():
    rng = np.random.default_rng(6)
    m = ArchiveManifest()
    for i in range(NONLINEAR_TRUST_MIN):
        lift = 0.9 if i % 2 == 0 else 0.1
        delta = (rng.random(4) * 0.1 if i % 2 == 0
                 else 2.0 + rng.random(4))
        m.record_transfer(f"s{i}", "d", delta, lift)
    tm = m.trust_model(dim=4)
    assert isinstance(tm, NonlinearTrustModel)
    assert tm.predict(np.zeros(4)) >= 0.0
