"""Tests for the Monad optimization engine: GP posterior sanity, PI
acquisition, SA monotonicity (best-ever never worsens), field restriction
(ablation-ladder correctness), and baseline iso-PE construction."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as C
from repro.core.optimizer import (OBJ_EDP, SAConfig, gp_posterior, make_sa,
                                  matern52, prob_improvement)


def test_gp_interpolates_training_points():
    X = jnp.asarray(np.random.default_rng(0).random((12, 3)), jnp.float32)
    y = jnp.sin(X.sum(axis=1) * 3.0)
    mu, sg = gp_posterior(X, y, X, lengthscale=0.5, noise=1e-6)
    np.testing.assert_allclose(np.asarray(mu), np.asarray(y), atol=1e-2)
    assert float(jnp.max(sg)) < 0.1


def test_gp_uncertainty_grows_off_data():
    X = jnp.zeros((4, 2), jnp.float32)
    y = jnp.zeros((4,), jnp.float32) + jnp.arange(4) * 0.01
    far = jnp.ones((1, 2), jnp.float32) * 5.0
    _, sg_far = gp_posterior(X, y, far, lengthscale=0.3)
    _, sg_near = gp_posterior(X, y, X[:1], lengthscale=0.3)
    assert float(sg_far[0]) > float(sg_near[0])


def test_pi_prefers_low_mean_high_sigma():
    mu = jnp.asarray([0.0, -1.0, 0.0])
    sg = jnp.asarray([0.1, 0.1, 2.0])
    pi = prob_improvement(mu, sg, best=0.0)
    assert int(jnp.argmax(pi)) == 1
    assert float(pi[2]) > float(pi[0])


def test_matern_kernel_properties():
    X = jnp.asarray(np.random.default_rng(1).random((8, 4)), jnp.float32)
    K = matern52(X, X, 0.7)
    np.testing.assert_allclose(np.asarray(jnp.diag(K)), 1.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(K), np.asarray(K.T), atol=1e-6)


def test_sa_improves_and_respects_fields():
    g = C.presets.bert_mms()["att2"]
    spec = C.SystemSpec.build(g, ch_max=36)
    bl = C.make_baseline("simba", spec, jax.random.PRNGKey(0))
    sa = make_sa(spec, bl.space, bl.sa_fields, SAConfig(steps=120, chains=2))
    w = jnp.asarray(OBJ_EDP, jnp.float32)
    d0 = bl.init
    db, ob = sa(jax.random.PRNGKey(1), d0, w)
    # frozen fields unchanged (simba may not move shape/spatial/packaging)
    for f in ("shape", "spatial", "packaging", "family"):
        np.testing.assert_array_equal(np.asarray(db[f]), np.asarray(d0[f]))
    # objective never worse than the init's own evaluation
    m0 = C.evaluate_system(spec, d0)
    from repro.core.optimizer import objective_from_metrics
    o0 = float(objective_from_metrics(bl.space, d0, m0, w))
    assert float(ob) <= o0 + 1e-4


def test_baselines_iso_pe_budget():
    g = C.presets.resnet_convs()["res3"]
    spec = C.SystemSpec.build(g, ch_max=36)
    for name in ("simba", "nn-baton"):
        bl = C.make_baseline(name, spec, jax.random.PRNGKey(0),
                             pe_budget=4096)
        sh = np.asarray(bl.init["shape"])
        pes = int(np.prod(sh, axis=1).sum())
        assert pes <= 4096 * 1.5, (name, pes)
        assert bl.space.fixed_packaging >= 0      # integration frozen


def test_feasibility_penalty_binds():
    g = C.presets.bert_mms()["att2"]
    spec = C.SystemSpec.build(g, ch_max=36)
    space = C.DesignSpace(spec, max_total_pes=256)
    d = C.random_design(jax.random.PRNGKey(0), space)
    d["shape"] = jnp.asarray([[16, 16, 4, 4, 6, 6]], jnp.int32)  # huge
    from repro.core.encoding import feasibility_penalty
    pen = float(feasibility_penalty(space, d, {}))
    assert pen > 1.0


def test_pareto_front_basic():
    from repro.core.optimizer import pareto_front
    idx = pareto_front([[1, 2], [2, 1], [2, 2], [0.5, 3]])
    assert sorted(idx) == [0, 1, 3]
    assert pareto_front([[1, 1]]) == [0]
