"""Property-based tests (hypothesis) for the portable design IR: migration
round-trips exactly through superset spec spaces, and migrated + repaired
designs are always feasible under the destination ``DesignSpace`` bounds —
for arbitrary source designs and arbitrary (source, destination) pairs
drawn from the model-derived workload library."""

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax  # noqa: E402

import repro.core as C  # noqa: E402
from repro.core.encoding import migrate, repair, space_digest  # noqa: E402

from test_transfer import assert_design_feasible  # noqa: E402

seeds = st.integers(0, 2**31 - 1)
dims = st.integers(8, 512)

# a small, structurally diverse graph pool (library families + a multi-head
# block with duplicate workloads) built once — graph construction is cheap
# but hypothesis draws hundreds of examples
_LIB = C.presets.workload_library()
_POOL = [
    _LIB["attn_qwen2_72b"], _LIB["attn_qwen2_5_32b"], _LIB["mlp_qwen2_72b"],
    _LIB["conv_whisper"], _LIB["scan_falcon_mamba"], _LIB["hybrid_hymba"],
    C.presets.transformer_block(),
    C.WorkloadGraph([C.matmul("mm", 256, 256, 64)], []),
]
_SPACES = {}


def _space(gi, ch_max):
    if (gi, ch_max) not in _SPACES:
        spec = C.SystemSpec.build(_POOL[gi], ch_max=ch_max)
        _SPACES[gi, ch_max] = C.DesignSpace(spec)
    return _SPACES[gi, ch_max]


def _repaired(space, seed):
    return repair(jax.tree.map(
        np.asarray, C.random_design(jax.random.PRNGKey(seed), space)), space)


@settings(max_examples=20, deadline=None)
@given(seed=seeds, m=dims, n=dims, k=dims, extra=st.integers(0, 2),
       ch_add=st.integers(0, 2))
def test_migrate_roundtrips_through_larger_space(seed, m, n, k, extra,
                                                 ch_add):
    """src -> superset (more workloads, more chiplet slots) -> src is the
    identity on repaired designs."""
    gA = C.WorkloadGraph([C.matmul("mm", m, n, k)], [])
    wls = list(gA.workloads) + [
        C.matmul(f"x{i}", 64 + 32 * i, 64, 64) for i in range(extra)]
    gB = C.WorkloadGraph(wls, [])
    specA = C.SystemSpec.build(gA, ch_max=2)
    specB = C.SystemSpec.build(gB, ch_max=2 + ch_add)
    spA, spB = C.DesignSpace(specA), C.DesignSpace(specB)
    dA = _repaired(spA, seed)
    dB = migrate(dA, spA, spB)
    assert_design_feasible(dB, spB)
    back = migrate(dB, spB, spA)
    for key in dA:
        np.testing.assert_array_equal(back[key], dA[key])


@settings(max_examples=20, deadline=None)
@given(seed=seeds, src=st.integers(0, len(_POOL) - 1),
       dst=st.integers(0, len(_POOL) - 1),
       ch_src=st.integers(1, 3), ch_dst=st.integers(1, 3))
def test_migrated_designs_always_feasible(seed, src, dst, ch_src, ch_dst):
    """ANY source design migrated into ANY destination space from the
    library lands inside the destination bounds with zero feasibility
    penalty — signature matches or not."""
    src_space = _space(src, ch_src)
    dst_space = _space(dst, ch_dst)
    d = _repaired(src_space, seed)
    out = migrate(d, src_space, dst_space)
    assert_design_feasible(out, dst_space)


@settings(max_examples=20, deadline=None)
@given(seed=seeds, gi=st.integers(0, len(_POOL) - 1))
def test_repair_is_idempotent_and_digest_equivalent(seed, gi):
    """repair(repair(d)) == repair(d), and repairing through the
    JSON-portable digest equals repairing through the DesignSpace."""
    space = _space(gi, 2)
    raw = jax.tree.map(
        np.asarray, C.random_design(jax.random.PRNGKey(seed), space))
    d1 = repair(raw, space)
    d2 = repair(d1, space)
    d3 = repair(raw, space_digest(space).to_json_dict())
    for key in d1:
        np.testing.assert_array_equal(d1[key], d2[key])
        np.testing.assert_array_equal(d1[key], d3[key])
