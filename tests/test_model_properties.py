"""Property-based tests on model-layer invariants (hypothesis)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import get_reduced
from repro.models import layers as Ly
from repro.models.config import ModelConfig


def _cfg(**kw):
    base = dict(name="t", family="dense", n_layers=2, d_model=32,
                n_heads=4, n_kv_heads=2, head_dim=8, d_ff=64, vocab=128)
    base.update(kw)
    return ModelConfig(**base)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
@given(shift=st.integers(1, 64), seed=st.integers(0, 100))
@settings(max_examples=15, deadline=None)
def test_rope_relative_position_invariance(shift, seed):
    """q.k after RoPE depends only on relative positions: shifting both
    queries' and keys' absolute positions by the same amount must not
    change the attention scores."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    q = jax.random.normal(ks[0], (1, 6, 2, 16))
    k = jax.random.normal(ks[1], (1, 6, 2, 16))
    p0 = jnp.arange(6)[None, :]
    s0 = jnp.einsum("bqhd,bkhd->bhqk",
                    Ly.apply_rope(q, p0), Ly.apply_rope(k, p0))
    p1 = p0 + shift
    s1 = jnp.einsum("bqhd,bkhd->bhqk",
                    Ly.apply_rope(q, p1), Ly.apply_rope(k, p1))
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1),
                               atol=2e-4, rtol=2e-4)


def test_rope_preserves_norm():
    q = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 16))
    p = jnp.arange(8)[None, :].repeat(2, 0)
    r = Ly.apply_rope(q, p)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(q), axis=-1),
        np.linalg.norm(np.asarray(r), axis=-1), atol=1e-4, rtol=1e-4)


def test_mrope_sections_match_plain_rope_for_equal_streams():
    """M-RoPE with identical t/h/w position streams == plain RoPE."""
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 2, 16))
    p = jnp.arange(8)[None, :]
    p3 = jnp.broadcast_to(p[..., None], (1, 8, 3))
    a = Ly.apply_rope(q, p, sections=())
    b = Ly.apply_rope(q, p3, sections=(4, 2, 2))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
@given(scale=st.floats(0.1, 100.0), seed=st.integers(0, 50))
@settings(max_examples=15, deadline=None)
def test_rmsnorm_scale_invariance(scale, seed):
    p = Ly.rmsnorm_init(16)
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 3, 16))
    a = Ly.rmsnorm(p, x)
    b = Ly.rmsnorm(p, x * scale)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=2e-3, rtol=2e-3)


def test_rmsnorm_unit_rms():
    p = Ly.rmsnorm_init(64)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64)) * 7.0
    y = np.asarray(Ly.rmsnorm(p, x), np.float64)
    rms = np.sqrt((y ** 2).mean(-1))
    np.testing.assert_allclose(rms, 1.0, atol=1e-2)


# ---------------------------------------------------------------------------
# attention causality
# ---------------------------------------------------------------------------
@given(seed=st.integers(0, 50), t=st.integers(1, 6))
@settings(max_examples=10, deadline=None)
def test_attention_causality(seed, t):
    """Perturbing future tokens must not change past outputs."""
    cfg = _cfg()
    p = Ly.attention_init(jax.random.PRNGKey(0), cfg)
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    x = jax.random.normal(ks[0], (1, 8, cfg.d_model))
    pos = jnp.arange(8)[None, :]
    y0, _ = Ly.attention_apply(p, cfg, x, pos, mask_kind="causal")
    x2 = x.at[:, t:].add(jax.random.normal(ks[1], (1, 8 - t, cfg.d_model)))
    y1, _ = Ly.attention_apply(p, cfg, x2, pos, mask_kind="causal")
    np.testing.assert_allclose(np.asarray(y0[:, :t]), np.asarray(y1[:, :t]),
                               atol=1e-4, rtol=1e-4)


def test_sliding_window_attention_locality():
    """With window w, token i must not see tokens < i - w + 1."""
    cfg = _cfg()
    p = Ly.attention_init(jax.random.PRNGKey(0), cfg)
    S, w = 16, 4
    x = jax.random.normal(jax.random.PRNGKey(1), (1, S, cfg.d_model))
    pos = jnp.arange(S)[None, :]
    y0, _ = Ly.attention_apply(p, cfg, x, pos, mask_kind="window", window=w)
    # perturb token 0: outputs at positions >= w must be unchanged
    x2 = x.at[:, 0].add(100.0)
    y1, _ = Ly.attention_apply(p, cfg, x2, pos, mask_kind="window", window=w)
    np.testing.assert_allclose(np.asarray(y0[:, w:]), np.asarray(y1[:, w:]),
                               atol=1e-4, rtol=1e-4)
    assert float(jnp.abs(y0[:, 0] - y1[:, 0]).max()) > 1e-3


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------
@given(seed=st.integers(0, 30))
@settings(max_examples=8, deadline=None)
def test_moe_infinite_capacity_equals_dense_mixture(seed):
    """With capacity >= T*K/E the dispatch drops nothing: the MoE output
    must equal the explicit gate-weighted mixture of expert MLPs."""
    cfg = _cfg(family="moe", n_experts=4, top_k=2, expert_ff=32,
               capacity_factor=16.0)
    p = Ly.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, 8, cfg.d_model))
    out, _ = Ly.moe_apply(p, cfg, x)

    xt = x.reshape(-1, cfg.d_model)
    logits = xt @ p["router"]["w"]
    probs = jax.nn.softmax(logits, -1)
    gv, gi = jax.lax.top_k(probs, 2)
    gv = gv / gv.sum(-1, keepdims=True)
    ref = jnp.zeros_like(xt)
    for e in range(4):
        h = jax.nn.silu(xt @ p["wg"][e]) * (xt @ p["wu"][e])
        ye = h @ p["wd"][e]
        wsel = jnp.where(gi == e, gv, 0.0).sum(-1)[:, None]
        ref = ref + wsel * ye
    np.testing.assert_allclose(np.asarray(out.reshape(-1, cfg.d_model)),
                               np.asarray(ref), atol=2e-4, rtol=2e-4)


def test_moe_capacity_drops_monotone():
    """Lower capacity factor must never increase the routed mass."""
    cfg0 = _cfg(family="moe", n_experts=4, top_k=2, expert_ff=32)
    p = Ly.moe_init(jax.random.PRNGKey(0), cfg0)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg0.d_model))
    norms = []
    for cf in (0.25, 1.0, 8.0):
        out, _ = Ly.moe_apply(
            p, dataclasses.replace(cfg0, capacity_factor=cf), x)
        norms.append(float(jnp.sum(jnp.abs(out))))
    assert norms[0] <= norms[1] <= norms[2]


# ---------------------------------------------------------------------------
# MLA cache equivalence
# ---------------------------------------------------------------------------
def test_mla_cache_decode_matches_full_forward():
    """Prefill+decode through the compressed-latent cache must match the
    full-sequence MLA forward at the decoded position."""
    cfg = get_reduced("deepseek_v2_236b")
    p = Ly.mla_init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 10
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    full, _ = Ly.mla_apply(p, cfg, x, pos)

    cache = jnp.zeros((B, S, cfg.kv_lora_rank + cfg.qk_rope_dim))
    _, cache = Ly.mla_apply(p, cfg, x[:, :S - 1], pos[:, :S - 1],
                            kv_cache=cache, cache_index=0)
    last, _ = Ly.mla_apply(p, cfg, x[:, S - 1:], pos[:, S - 1:],
                           kv_cache=cache, cache_index=S - 1)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(full[:, -1]),
                               atol=3e-4, rtol=3e-4)
