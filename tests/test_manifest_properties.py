"""Property-based tests (hypothesis) for the manifest growth policy:
LRU eviction never removes the entry being written (or the neighbors
just queried into existence), dedup merging is idempotent and
commutative, and ``nearest()`` is invariant under entry-insertion order.
Like the other property suites, the whole module self-skips when
hypothesis is absent."""

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.explore.archive import (ArchiveManifest,  # noqa: E402
                                   ManifestPolicy)

DIM = 4

# embeddings on a small integer grid: controllable distances, and grid
# points at L2 distance >= 1 from each other never alias under a dedup
# radius < 1
coords = st.lists(st.integers(0, 6), min_size=DIM, max_size=DIM)
entry_lists = st.lists(
    st.tuples(coords, st.integers(0, 64)),     # (embedding, n_evals)
    min_size=1, max_size=12)


def _manifest(policy, items, key=lambda i: f"k{i}"):
    m = ArchiveManifest(policy=policy)
    for i, (emb, n_evals) in enumerate(items):
        m.update(key(i), np.asarray(emb, np.float64), (1, 2, 1),
                 n_evals, n_evals, ("latency_ns",), digest={"i": i})
    return m


@settings(max_examples=50, deadline=None)
@given(items=entry_lists, max_entries=st.integers(1, 6))
def test_eviction_never_removes_the_entry_being_written(items, max_entries):
    """After EVERY update the just-written key is present and the bound
    holds — however small the bound and however many writes preceded."""
    m = ArchiveManifest(policy=ManifestPolicy(max_entries=max_entries))
    for i, (emb, n_evals) in enumerate(items):
        k = f"k{i}"
        m.update(k, np.asarray(emb, np.float64), (1, 2, 1),
                 n_evals, n_evals, (), digest={})
        assert k in m.entries
        assert len(m.entries) <= max_entries


@settings(max_examples=50, deadline=None)
@given(items=entry_lists, max_entries=st.integers(1, 6),
       qi=st.integers(0, 11))
def test_eviction_never_removes_the_entry_being_queried(items, max_entries,
                                                        qi):
    """``nearest`` is read-only (no entry disappears because of a query),
    and an explicit ``enforce(protect=...)`` spares the queried key."""
    m = _manifest(ManifestPolicy(max_entries=len(items) + 1), items)
    qk = f"k{qi % len(items)}"
    before = set(m.entries)
    m.nearest(m.entries[qk]["embedding"], k=3)
    assert set(m.entries) == before            # queries evict nothing
    m.policy = ManifestPolicy(max_entries=max_entries)
    m.enforce(protect=(qk,))
    assert qk in m.entries
    assert len(m.entries) <= max(max_entries, 1)


@settings(max_examples=50, deadline=None)
@given(items=entry_lists, radius=st.floats(0.0, 2.0))
def test_dedup_is_idempotent(items, radius):
    m = _manifest(ManifestPolicy(max_entries=64, dedup_radius=radius),
                  items)
    once = {k: dict(e, embedding=e["embedding"].copy())
            for k, e in m.entries.items()}
    m.dedup()
    for k in m.entries:
        assert k in once
        for f in ("n_evals", "budget_covered", "searched", "last_used"):
            assert m.entries[k][f] == once[k][f]
        np.testing.assert_array_equal(m.entries[k]["embedding"],
                                      once[k]["embedding"])


@settings(max_examples=50, deadline=None)
@given(a=coords, b=coords, na=st.integers(0, 64), nb=st.integers(0, 64),
       radius=st.floats(0.1, 3.0))
def test_dedup_merge_is_commutative(a, b, na, nb, radius):
    """Merging {A, B} gives the same surviving key and counters whichever
    insertion order built the manifest.  (Constructed with dedup off so
    the write-protection of ``update`` doesn't pre-merge asymmetrically;
    the merge under test is the explicit ``dedup()``.)"""
    pol0 = ManifestPolicy(max_entries=64, dedup_radius=0.0)
    pol = ManifestPolicy(max_entries=64, dedup_radius=radius)
    m1 = _manifest(pol0, [(a, na), (b, nb)])                 # kA=k0, kB=k1
    m2 = _manifest(pol0, [(b, nb), (a, na)], key=lambda i: f"k{1 - i}")
    for m in (m1, m2):
        m.policy = pol
        m.dedup()
    assert set(m1.entries) == set(m2.entries)
    for k in m1.entries:
        assert m1.entries[k]["n_evals"] == m2.entries[k]["n_evals"]
        assert m1.entries[k]["budget_covered"] \
            == m2.entries[k]["budget_covered"]
        np.testing.assert_array_equal(m1.entries[k]["embedding"],
                                      m2.entries[k]["embedding"])


@settings(max_examples=50, deadline=None)
@given(items=entry_lists, q=coords, k=st.integers(1, 8),
       seed=st.integers(0, 2**31 - 1))
def test_nearest_is_invariant_under_entry_reordering(items, q, k, seed):
    pol = ManifestPolicy(max_entries=64)
    m1 = _manifest(pol, items)
    order = np.random.default_rng(seed).permutation(len(items))
    m2 = ArchiveManifest(policy=pol)
    for i in order:
        emb, n_evals = items[i]
        m2.update(f"k{i}", np.asarray(emb, np.float64), (1, 2, 1),
                  n_evals, n_evals, ("latency_ns",), digest={"i": int(i)})
    got1 = m1.nearest(np.asarray(q, np.float64), k=k)
    got2 = m2.nearest(np.asarray(q, np.float64), k=k)
    assert [kk for kk, _ in got1] == [kk for kk, _ in got2]
    np.testing.assert_allclose([d for _, d in got1], [d for _, d in got2])
