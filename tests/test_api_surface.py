"""Public-API surface snapshot: ``repro.api.__all__`` and the field
names of the declarative types are contract — any drift must be a
conscious decision, made visible by updating
``tests/data/api_surface.json`` in the same change.  Runs in tier-1."""

import dataclasses
import json
from pathlib import Path

import repro.api as api

SNAPSHOT = json.loads(
    (Path(__file__).parent / "data" / "api_surface.json").read_text())


def _fields(cls):
    return [f.name for f in dataclasses.fields(cls)]


def test_api_all_matches_snapshot():
    assert sorted(api.__all__) == sorted(SNAPSHOT["all"])
    # everything advertised is importable
    for name in api.__all__:
        assert hasattr(api, name), f"repro.api.{name} missing"
    # the explore package re-exports the core types too
    import repro.explore as ex
    for name in ("Problem", "Query", "Plan", "Result", "Session"):
        assert getattr(ex, name) is getattr(api, name)


def test_engine_names_match_snapshot():
    assert list(api.ENGINES) == SNAPSHOT["engines"]


def test_declarative_type_fields_match_snapshot():
    for name, expect in SNAPSHOT["fields"].items():
        cls = getattr(api, name)
        got = _fields(cls)
        assert got == expect, (
            f"{name} fields drifted: {got} != snapshot {expect} — if "
            f"intentional, update tests/data/api_surface.json")


def test_problem_surface_is_stable():
    # Problem is slotted, not a dataclass: its public attribute contract
    assert api.Problem.__slots__ == (
        "graph", "objectives", "ch_max", "space_kwargs", "spec", "space",
        "_key")


def test_obs_surface_matches_snapshot():
    import repro.obs as obs
    assert sorted(obs.__all__) == sorted(SNAPSHOT["obs_all"])
    for name in obs.__all__:
        assert hasattr(obs, name), f"repro.obs.{name} missing"


def test_session_takes_journal_kwarg():
    import inspect
    params = inspect.signature(api.Session.__init__).parameters
    assert "journal" in params
    assert params["journal"].default is None
