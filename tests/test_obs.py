"""The flight recorder (``repro.obs``): metrics registry semantics, span
nesting and the zero-cost disabled path, crash-safe journal writes and
truncated-tail reads, the event-stream invariants of instrumented
``Session.submit`` runs (monotone per-phase segment indices, strictly
increasing ``seq``, reallocation top-ups), bit-identical fronts with
observability on or off, journal replay against the in-memory ``Result``,
and the plan-vs-actual report."""

import json
import threading
import warnings

import numpy as np
import pytest

import repro.core as C
from repro import obs
from repro.api import Problem, Query, Session
from repro.core.optimizer import SAConfig
from repro.explore.nsga import NSGAConfig
from repro.explore.service import BudgetPolicy, SegmentEvent
from repro.obs.report import render

TINY = dict(max_shape=(16, 16, 4, 4, 1, 2))
OBJ = ("latency_ns", "cost_usd")


@pytest.fixture(autouse=True)
def _obs_restored():
    """Module-level obs state (enable flag, sinks) must never leak
    between tests — the registry is process-wide by design, so tests
    assert on deltas, not absolutes."""
    yield
    obs.enable()
    for s in list(obs.trace._SINKS):
        obs.remove_sink(s)


def _graph(k=64):
    return C.WorkloadGraph([C.matmul("mm", 512, 512, k)], [])


def _session(tmp_path, journal=False, **policy_kw):
    policy = BudgetPolicy(**policy_kw) if policy_kw else BudgetPolicy()
    return Session(cache_dir=tmp_path / "cache", journal=journal,
                   nsga=NSGAConfig(pop=8, generations=2), policy=policy)


def _problem(k=64):
    return Problem(_graph(k), objectives=OBJ, ch_max=2, space_kwargs=TINY)


def _counter(name):
    return obs.REGISTRY.counter(name).value


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------
def test_counter_gauge_histogram_basics():
    r = obs.MetricsRegistry()
    r.counter("c").inc().inc(4)
    assert r.counter("c").value == 5
    r.gauge("g").set(2.5)
    assert r.gauge("g").value == 2.5
    h = r.histogram("h")
    for v in range(100):
        h.observe(float(v))
    # exact order statistics while within reservoir capacity
    assert h.quantile(0.5) == 50.0
    assert h.quantiles() == {"p50": 50.0, "p90": 90.0, "p99": 99.0}
    assert h.mean == pytest.approx(49.5)
    assert (h.vmin, h.vmax, h.count) == (0.0, 99.0, 100)
    snap = r.snapshot()
    assert snap["c"] == {"kind": "counter", "value": 5}
    assert snap["h"]["p99"] == 99.0 and snap["h"]["count"] == 100
    json.dumps(snap)                    # snapshot is JSON-clean
    r.reset()
    assert r.snapshot() == {}


def test_histogram_reservoir_stays_bounded():
    r = obs.MetricsRegistry()
    h = r.histogram("h", capacity=32)
    for v in range(1000):
        h.observe(float(v))
    assert len(h._res) == 32 and h.count == 1000
    q = h.quantile(0.5)                 # estimate from a uniform sample
    assert 0.0 <= q <= 999.0


def test_metric_name_bound_to_kind():
    r = obs.MetricsRegistry()
    r.counter("x")
    with pytest.raises(TypeError, match="is a Counter"):
        r.histogram("x")


def test_registry_thread_safety():
    r = obs.MetricsRegistry()

    def work():
        for _ in range(500):
            r.counter("n").inc()
            r.histogram("h").observe(1.0)

    ts = [threading.Thread(target=work) for _ in range(4)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    assert r.counter("n").value == 2000
    assert r.histogram("h").count == 2000


# ---------------------------------------------------------------------------
# tracing core
# ---------------------------------------------------------------------------
def test_spans_nest_and_emit_records():
    recs = []
    with obs.sink_attached(recs.append):
        with obs.span("outer", k=1):
            with obs.span("inner"):
                pass
    inner, outer = recs
    assert inner["name"] == "inner" and inner["parent"] == "outer" \
        and inner["depth"] == 1
    assert outer["name"] == "outer" and outer["parent"] is None \
        and outer["attrs"] == {"k": 1}
    assert 0.0 <= inner["elapsed_s"] <= outer["elapsed_s"]
    # every close also feeds the span.<name> histogram
    assert obs.REGISTRY.histogram("span.inner").count >= 1


def test_disabled_is_a_shared_noop():
    obs.disable()
    try:
        assert obs.span("x") is obs.span("y") is obs.NOOP_SPAN
        assert not obs.active()
        before = obs.REGISTRY.counter("test.off").value
        obs.inc("test.off")             # gated: no count while disabled
        assert obs.REGISTRY.counter("test.off").value == before
        recs = []
        with obs.sink_attached(recs.append):
            obs.emit({"type": "x"})
        assert recs == []
    finally:
        obs.enable()


def test_failing_sink_is_dropped_not_fatal():
    def bad(rec):
        raise OSError("disk full")
    before = _counter("obs.sink_errors")
    with obs.sink_attached(bad):
        obs.emit({"type": "x"})         # drops the sink, counts the loss
        obs.emit({"type": "y"})         # no sink left: no second error
    assert _counter("obs.sink_errors") == before + 1


def test_sink_attached_is_reentrant():
    recs = []
    with obs.sink_attached(recs.append):
        with obs.sink_attached(recs.append):    # no double-attach
            obs.emit({"type": "x"})
        obs.emit({"type": "y"})         # still attached after inner exit
    assert [r["type"] for r in recs] == ["x", "y"]
    with obs.sink_attached(None):       # None is a no-op, not an error
        obs.emit({"type": "z"})
    assert len(recs) == 2


def test_sink_attached_refcounts_across_overlapping_scopes():
    # Two submissions sharing one fleet journal can overlap on different
    # threads; the first to finish must not detach the sink under the
    # one still running (this lost a cold run's records in bench_explore).
    recs = []
    a = obs.sink_attached(recs.append)
    b = obs.sink_attached(recs.append)
    a.__enter__()
    b.__enter__()
    a.__exit__(None, None, None)
    obs.emit({"type": "late"})          # b still holds a reference
    b.__exit__(None, None, None)
    obs.emit({"type": "gone"})          # last exit detached the sink
    assert [r["type"] for r in recs] == ["late"]


# ---------------------------------------------------------------------------
# journal: atomic lines, crash tolerance, replay
# ---------------------------------------------------------------------------
def test_journal_roundtrip_and_numpy_serialization(tmp_path):
    p = tmp_path / "j.jsonl"
    with obs.Journal(p) as j:
        j.write(dict(type="a", v=np.float32(1.5), arr=np.arange(3),
                     tup=(1, 2)))
        j.write(dict(type="b", n=np.int64(7)))
    recs = list(obs.read_journal(p))
    assert [r["type"] for r in recs] == ["a", "b"]
    assert recs[0]["arr"] == [0, 1, 2] and recs[0]["tup"] == [1, 2]
    assert recs[1]["n"] == 7
    assert all("t" in r for r in recs)


def test_journal_opens_lazily(tmp_path):
    j = obs.Journal(tmp_path / "lazy.jsonl")
    assert not j.path.exists()          # configuring costs nothing
    j.write({"type": "x"})
    assert j.path.exists()
    j.close()


def test_read_journal_tolerates_truncated_tail(tmp_path):
    p = tmp_path / "j.jsonl"
    with obs.Journal(p) as j:
        j.write({"type": "a"})
        j.write({"type": "b"})
    with open(p, "a") as f:
        f.write('{"type":"c","half')    # the line a crash leaves behind
    # an unterminated final line is the normal in-flight state of a LIVE
    # journal (or a crash tail) — skipped silently, so a reader polling
    # a journal under active append doesn't warn on every poll
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        recs = list(obs.read_journal(p))
    assert [r["type"] for r in recs] == ["a", "b"]
    # a COMPLETE final record the writer just hasn't newline-terminated
    # is salvaged, not dropped
    p2 = tmp_path / "j2.jsonl"
    with obs.Journal(p2) as j:
        j.write({"type": "a"})
    with open(p2, "a") as f:
        f.write('{"type":"c"}')         # complete JSON, no trailing newline
    assert [r["type"] for r in obs.read_journal(p2)] == ["a", "c"]


def test_read_journal_warns_on_midfile_garbage(tmp_path):
    p = tmp_path / "j.jsonl"
    p.write_text('{"type":"a"}\nnot json at all\n{"type":"b"}\n')
    with pytest.warns(UserWarning, match="unparseable"):
        recs = list(obs.read_journal(p))
    assert [r["type"] for r in recs] == ["a", "b"]


def test_read_journal_directory(tmp_path):
    for name in ("b.jsonl", "a.jsonl"):
        with obs.Journal(tmp_path / name) as j:
            j.write({"type": name})
    assert [r["type"] for r in obs.read_journal(tmp_path)] \
        == ["a.jsonl", "b.jsonl"]       # name order


def test_replay_folds_segments_and_results():
    recs = [
        dict(type="plan", key="k1", segments=[{}, {}]),
        dict(type="segment", key="k1", phase="refine", n_evals=64,
             elapsed_s=0.5, hv=[10.0]),
        dict(type="segment", key="k1", phase="realloc", n_evals=32,
             elapsed_s=0.25, hv=[12.0]),
        dict(type="result", key="k1", n_evals=96),
        dict(type="span", name="x"),    # keyless records are skipped
    ]
    r = obs.replay(recs)["k1"]
    assert r["segments"] == 2 and r["planned_segments"] == 2
    assert r["segments_by_phase"] == {"refine": 1, "realloc": 1}
    assert r["n_evals"] == 96 and r["final_hv"] == 12.0
    assert r["hv_path"] == [10.0, 12.0] and len(r["results"]) == 1


# ---------------------------------------------------------------------------
# instrumented runs: event-stream invariants
# ---------------------------------------------------------------------------
def test_segment_events_carry_timing_and_monotone_seq(tmp_path):
    s = _session(tmp_path, chunk_generations=2, adaptive=False)
    events = []
    r = s.submit(Query(_problem(), budget=32), on_segment=events.append)
    assert [e.segment for e in events] == [0, 1]
    assert [e.seq for e in events] == [0, 1]
    assert all(e.elapsed_s > 0.0 for e in events)
    # the streamed slices still reassemble into the run's full trace
    whole = events[0].trace.extend(events[1].trace)
    np.testing.assert_array_equal(whole.n_evals, r.trace.n_evals)
    np.testing.assert_allclose(whole.archive_hv, r.trace.archive_hv)


def test_realloc_events_restart_segment_but_not_seq(tmp_path):
    s = _session(tmp_path)
    # submission 1 banks ledger credit via an aggressive plateau policy
    bank = BudgetPolicy(chunk_generations=1, plateau_rel=10.0, patience=1,
                        reallocate=False)
    r1 = s.submit(Query(_problem(64), budget=128, policy=bank))
    assert r1.provenance.plateaued and r1.provenance.n_evals_banked > 0
    # submission 2 (cold problem, plateau impossible) exhausts its own
    # budget and receives a reallocation top-up from the banked credit
    spend = BudgetPolicy(chunk_generations=1, plateau_rel=0.0)
    events = []
    r2 = s.submit(Query(_problem(96), budget=16, policy=spend),
                  on_segment=events.append)
    assert r2.provenance.n_evals_realloc > 0
    phases = [e.phase for e in events]
    assert "refine" in phases and "realloc" in phases
    for phase in ("refine", "realloc"):
        idx = [e.segment for e in events if e.phase == phase]
        assert idx == list(range(len(idx)))     # 0,1,... per phase
    assert [e.seq for e in events] == list(range(len(events)))
    assert all(e.cache_key == r2.provenance.cache_key for e in events)


def test_callback_failure_names_phase_and_segment(tmp_path):
    s = _session(tmp_path, chunk_generations=2, adaptive=False)
    jp = tmp_path / "j.jsonl"
    s._journal = obs.resolve_journal(jp)

    def boom(e):
        raise RuntimeError("dashboard down")

    before = _counter("obs.on_segment_errors")
    with pytest.warns(UserWarning,
                      match=r"on_segment callback failed .*"
                            r"\(phase=refine, segment=0\)"):
        s.submit(Query(_problem(), budget=32), on_segment=boom)
    assert _counter("obs.on_segment_errors") == before + 2
    errs = [r for r in obs.read_journal(jp)
            if r["type"] == "callback_error"]
    assert len(errs) == 2 and errs[0]["phase"] == "refine"
    assert [e["segment"] for e in errs] == [0, 1]


def test_scalarized_completion_event_and_journal(tmp_path):
    jp = tmp_path / "j.jsonl"
    s = _session(tmp_path, journal=jp)
    spec = C.SystemSpec.build(_graph(), ch_max=2)
    space = C.DesignSpace(spec, **TINY)
    events = []
    s.submit(Query(Problem.from_spec(spec, space), engine="bo_sa",
                   weights=(1.0, 1.0, 0.0, 0.0),
                   engine_opts=dict(bo_fields=(), n_init=2,
                                    sa=SAConfig(steps=10, chains=2))),
             on_segment=events.append)
    assert len(events) == 1 and isinstance(events[0], SegmentEvent)
    assert events[0].phase == "bo_sa" and events[0].elapsed_s > 0.0
    recs = list(obs.read_journal(jp))
    segs = [r for r in recs if r["type"] == "segment"]
    assert len(segs) == 1 and segs[0]["phase"] == "bo_sa"
    plans = [r for r in recs if r["type"] == "plan"]
    assert plans and plans[0]["engine"] == "bo_sa"
    assert any(r["type"] == "result" and r["engine"] == "bo_sa"
               for r in recs)


# ---------------------------------------------------------------------------
# observability is free: identical results on or off
# ---------------------------------------------------------------------------
def test_fronts_bit_identical_with_obs_on_and_off(tmp_path):
    q = Query(_problem(), budget=32)
    events = []
    jp = tmp_path / "j.jsonl"
    s_on = _session(tmp_path / "on", journal=jp, chunk_generations=2,
                    adaptive=False)
    r_on = s_on.submit(q, on_segment=events.append)
    obs.disable()
    try:
        s_off = _session(tmp_path / "off", chunk_generations=2,
                         adaptive=False)
        r_off = s_off.submit(q)
    finally:
        obs.enable()
    # numeric state is untouched by instrumentation: bit-identical fronts
    assert r_on.front_metrics.tobytes() == r_off.front_metrics.tobytes()
    assert r_on.front_objs.tobytes() == r_off.front_objs.tobytes()
    np.testing.assert_array_equal(r_on.trace.archive_hv,
                                  r_off.trace.archive_hv)
    # ... and the disabled arm journaled nothing
    assert len(events) == 2 and jp.exists()


# ---------------------------------------------------------------------------
# journal replay + report against the in-memory result
# ---------------------------------------------------------------------------
def test_journal_replays_to_in_memory_result(tmp_path):
    jp = tmp_path / "j.jsonl"
    s = _session(tmp_path, journal=jp, chunk_generations=2, adaptive=False)
    r = s.submit(Query(_problem(), budget=32))
    ck = r.provenance.cache_key
    recs = list(obs.read_journal(jp))
    rp = obs.replay(recs)[ck]
    assert rp["segments"] == r.trace.archive_hv.shape[0]
    assert rp["n_evals"] == r.provenance.n_evals_run
    assert rp["final_hv"] == pytest.approx(
        float(r.trace.archive_hv[-1, 0]))
    assert rp["planned_segments"] == rp["segments"]
    report = render(recs)
    assert f"problem {ck}" in report
    assert "== fleet summary ==" in report
    assert "queries=1" in report
    # every planned segment shows an actual observation: the actual_s
    # column (token 5: phase seg pop gens plan_evals actual_s ...) is a
    # float, not the '-' an unobserved planned segment renders
    seg_rows = [ln for ln in report.splitlines()
                if ln.startswith("  refine")]
    assert len(seg_rows) == rp["segments"]
    assert all(float(row.split()[5]) > 0.0 for row in seg_rows)


def test_warm_hit_journals_plan_and_result_only(tmp_path):
    jp = tmp_path / "j.jsonl"
    s = _session(tmp_path, journal=jp)
    q = Query(_problem(), budget=16)
    hit0, miss0 = _counter("explore.cache.hit"), \
        _counter("explore.cache.miss")
    s.submit(q)
    r = s.submit(q)                     # identical query: warm serve
    assert r.provenance.from_cache
    assert _counter("explore.cache.hit") == hit0 + 1
    assert _counter("explore.cache.miss") == miss0 + 1
    rp = obs.replay(obs.read_journal(jp))[r.provenance.cache_key]
    assert len(rp["results"]) == 2
    assert rp["results"][1]["from_cache"] is True
    assert rp["plans"][-1]["cache_hit"] is True
    assert not rp["plans"][-1]["segments"]


# ---------------------------------------------------------------------------
# journal wiring: Session(journal=...), $REPRO_JOURNAL_DIR
# ---------------------------------------------------------------------------
def test_env_var_enables_default_journal(tmp_path, monkeypatch):
    monkeypatch.setenv(obs.JOURNAL_ENV, str(tmp_path / "fleet"))
    s = _session(tmp_path, journal=None)
    s.submit(Query(_problem(), budget=16))
    files = list((tmp_path / "fleet").glob("run-*.jsonl"))
    assert len(files) == 1
    assert any(r["type"] == "result" for r in obs.read_journal(files[0]))
    # journal=False opts out even with the env var set
    s2 = _session(tmp_path / "b", journal=False)
    s2.submit(Query(_problem(96), budget=16))
    recs = list(obs.read_journal(files[0]))
    assert all(r.get("key") != s2._cache_key(_problem(96))
               for r in recs if r["type"] == "result")


def test_report_cli_renders_journal(tmp_path, capsys):
    jp = tmp_path / "j.jsonl"
    s = _session(tmp_path, journal=jp, chunk_generations=2, adaptive=False)
    s.submit(Query(_problem(), budget=32))
    from repro.obs.report import main
    assert main([str(jp)]) == 0
    out = capsys.readouterr().out
    assert "== plan vs actual ==" in out and "refine" in out
