"""Device-saturating search: the island-sharded NSGA scan (1-device mesh
bit-identity, multi-island subprocess execution), cross-problem
megabatching (fused fronts identical to sequential runs), the shared
pow2 quantization lattice, and the tiled dominance-count kernel routing
(`repro.kernels.pareto_rank`) that NSGA selection and archive insertion
funnel through.  Runs in tier-1 — the kernel tests here use interpret
mode, no TPU required."""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.core as C
from repro.api import Problem, Query, Session
from repro.explore import archive as archive_mod
from repro.explore import quantize
from repro.explore.nsga import (ISLAND_AXIS, NSGAConfig, make_nsga,
                                make_nsga_fused)
from repro.explore.service import BudgetPolicy, ExplorationService
from repro.core.encoding import random_design

TINY_SPACE_KW = dict(max_shape=(16, 16, 4, 4, 1, 2))
OBJ = ("latency_ns", "cost_usd")
SRC = str(Path(__file__).resolve().parents[1] / "src")


def _tiny(graph_name="att2", ch_max=2):
    g = C.presets.bert_mms()[graph_name]
    spec = C.SystemSpec.build(g, ch_max=ch_max)
    return g, spec, C.DesignSpace(spec, **TINY_SPACE_KW)


def _pop0(space, pop, key):
    return jax.vmap(lambda k: random_design(k, space))(
        jax.random.split(key, pop))


def _mesh1():
    return jax.sharding.Mesh(np.array(jax.devices()[:1]), (ISLAND_AXIS,))


# ---------------------------------------------------------------------------
# pow2 quantization lattice (repro.explore.quantize)
# ---------------------------------------------------------------------------
def test_pow2_helpers():
    assert [quantize.pow2_ceil(n) for n in (1, 2, 3, 8, 9, 1000)] == \
        [1, 2, 4, 8, 16, 1024]
    assert [quantize.pow2_floor(n) for n in (1, 2, 3, 8, 9, 1000)] == \
        [1, 2, 2, 8, 8, 512]


def test_effective_pop_floor_and_ceiling():
    assert quantize.effective_pop(2048, 64) == 64       # ceiling binds
    assert quantize.effective_pop(24, 64) == 32         # pow2 ceil
    assert quantize.effective_pop(24, 64, quantize_down=True) == 16
    assert quantize.effective_pop(3, 64) == quantize.MIN_POP
    assert quantize.effective_pop(3, 64, True) == quantize.MIN_POP


@pytest.mark.parametrize("budget", [8, 24, 64, 100, 2048])
def test_schedule_invariants(budget):
    for down in (False, True):
        s = quantize.schedule(budget, 64, 4, quantize_down=down)
        # everything on the pow2 lattice, and segments tile generations
        for v in (s.pop, s.generations, s.chunk):
            assert v & (v - 1) == 0
        assert s.n_seg * s.chunk == s.generations
        assert s.chunk <= s.generations
    # ceil covers the budget; floor never exceeds it (>= MIN_POP budgets)
    up = quantize.schedule(budget, 64, 4)
    assert up.evals >= budget
    if budget >= quantize.MIN_POP:
        dn = quantize.schedule(budget, 64, 4, quantize_down=True)
        assert dn.evals <= budget


def test_bucket_lanes():
    assert [quantize.bucket_lanes(n) for n in (1, 2, 3, 5, 8)] == \
        [1, 2, 4, 8, 8]
    assert quantize.bucket_lanes(9, max_lanes=8) == 8


# ---------------------------------------------------------------------------
# island-sharded NSGA: a 1-device mesh is bit-identical to the plain scan
# ---------------------------------------------------------------------------
def test_island_one_device_mesh_bit_identical():
    _, spec, space = _tiny()
    cfg = NSGAConfig(pop=8, generations=4)
    key = jax.random.PRNGKey(0)
    pop0 = _pop0(space, cfg.pop, jax.random.PRNGKey(1))
    plain = make_nsga(spec, space, OBJ, cfg)(key, pop0)
    isl = make_nsga(spec, space, OBJ, cfg, mesh=_mesh1())(key, pop0)
    for a, b in zip(jax.tree.leaves(plain), jax.tree.leaves(isl)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_island_mesh_validation():
    _, spec, space = _tiny()
    with pytest.raises(ValueError, match=ISLAND_AXIS):
        make_nsga(spec, space, OBJ, NSGAConfig(pop=8, generations=2),
                  mesh=jax.sharding.Mesh(
                      np.array(jax.devices()[:1]), ("wrong",)))


class _FakeMesh:
    """Stands in for a 4-device mesh on this 1-device host: ``_mesh_for``
    only reads ``mesh.shape``."""
    shape = {ISLAND_AXIS: 4}


def test_service_mesh_for_degrades_unshardable_pops(tmp_path):
    svc = ExplorationService(cache_dir=tmp_path, mesh=_mesh1())
    assert svc._mesh_for(8) is svc.mesh     # 1 island always fits
    svc.mesh = _FakeMesh()
    assert svc._mesh_for(8) is svc.mesh     # 4 islands of 2
    assert svc._mesh_for(9) is None         # not divisible
    assert svc._mesh_for(4) is None         # islands of 1 degenerate
    svc.mesh = None
    assert svc._mesh_for(8) is None


@pytest.mark.slow
def test_multi_island_subprocess_migrates():
    """4 forced host devices: the sharded scan runs, migrates, and
    produces global telemetry with the unsharded shapes."""
    prog = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        import repro.core as C
        from repro.explore.nsga import ISLAND_AXIS, NSGAConfig, make_nsga
        from repro.core.encoding import random_design
        g = C.presets.bert_mms()["att2"]
        spec = C.SystemSpec.build(g, ch_max=2)
        space = C.DesignSpace(spec, max_shape=(16, 16, 4, 4, 1, 2))
        assert len(jax.devices()) == 4
        mesh = jax.sharding.Mesh(np.array(jax.devices()), (ISLAND_AXIS,))
        cfg = NSGAConfig(pop=16, generations=4, migration_interval=2)
        pop0 = jax.vmap(lambda k: random_design(k, space))(
            jax.random.split(jax.random.PRNGKey(1), cfg.pop))
        out = make_nsga(spec, space, ("latency_ns", "cost_usd"), cfg,
                        mesh=mesh)(jax.random.PRNGKey(0), pop0)
        pop, raw, sel, ev_d, ev_r, ev_f, tr = out
        assert raw.shape == (cfg.pop, 4) and sel.shape[0] == cfg.pop
        assert ev_r.shape == (cfg.generations, cfg.pop, 4)
        assert tr["front_size"].shape == (cfg.generations,)
        assert bool(jnp.all(jnp.isfinite(raw)))
        print("ISLANDS-OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=4")
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", prog], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "ISLANDS-OK" in r.stdout


# ---------------------------------------------------------------------------
# fused multi-problem runner: lane i == unbatched run i
# ---------------------------------------------------------------------------
def test_fused_lanes_match_unbatched_runs():
    """Each lane of ``make_nsga_fused`` evolves the same designs as its
    unbatched ``make_nsga`` twin (bit-identical design pytrees; raw
    metrics agree to f32 batched-reduction tolerance)."""
    cfg = NSGAConfig(pop=8, generations=2)
    probs = [_tiny(n) for n in ("att1", "att2", "att3")]
    _, spec0, space0 = probs[0]
    keys = [jax.random.PRNGKey(i) for i in range(3)]
    pops = [_pop0(p[2], cfg.pop, jax.random.fold_in(k, 9))
            for p, k in zip(probs, keys)]
    run_f = make_nsga_fused(spec0, space0, OBJ, cfg, lanes=3)
    fused = run_f(keys, jax.tree.map(lambda *xs: jnp.stack(xs), *pops),
                  [p[1].arrays for p in probs])
    for j, ((_, spec, space), key, pop0) in enumerate(
            zip(probs, keys, pops)):
        single = make_nsga(spec0, space0, OBJ, cfg)(
            key, pop0, arrays=spec.arrays)
        s_pop, s_raw = single[0], single[1]
        f_pop = jax.tree.map(lambda x: x[j], fused[0])
        for a, b in zip(jax.tree.leaves(s_pop), jax.tree.leaves(f_pop)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_allclose(np.asarray(fused[1][j]),
                                   np.asarray(s_raw), rtol=1e-6)


# ---------------------------------------------------------------------------
# cross-problem megabatching through the service: fronts identical
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_megabatch_fronts_match_sequential(tmp_path):
    """Three distinct problems with one padded shape: the fused
    megabatch answers with the same fronts as three sequential
    refinements — design pytrees bit-identical, metrics to f32
    batched-reduction tolerance."""
    def _queries():
        return [Query(Problem(C.presets.bert_mms()[n], objectives=OBJ,
                              ch_max=2, space_kwargs=TINY_SPACE_KW),
                      budget=32, engine="nsga")
                for n in ("att1", "att2", "att3")]

    def _run(sub, megabatch):
        s = Session(cache_dir=tmp_path / sub,
                    nsga=NSGAConfig(pop=8, generations=2),
                    policy=BudgetPolicy(adaptive=False,
                                        chunk_generations=1,
                                        megabatch=megabatch))
        return s.submit(_queries(), key=jax.random.PRNGKey(5))

    fused = _run("fused", True)
    seq = _run("seq", False)
    for rf, rs in zip(fused, seq):
        np.testing.assert_allclose(rf.front_metrics, rs.front_metrics,
                                   rtol=1e-6)
        assert len(rf.front_designs) == len(rs.front_designs)
        for df, ds in zip(rf.front_designs, rs.front_designs):
            assert sorted(df) == sorted(ds)
            for k in df:
                np.testing.assert_array_equal(df[k], ds[k])
        assert rf.provenance.n_evals_run == rs.provenance.n_evals_run


@pytest.mark.slow
def test_megabatch_query_optout_stays_sequential(tmp_path):
    """A ``Query(megabatch=False)`` group never fuses — and the batch
    still answers every query correctly."""
    probs = [Problem(C.presets.bert_mms()[n], objectives=OBJ, ch_max=2,
                     space_kwargs=TINY_SPACE_KW)
             for n in ("att1", "att2")]
    s = Session(cache_dir=tmp_path, nsga=NSGAConfig(pop=8, generations=2),
                policy=BudgetPolicy(adaptive=False, chunk_generations=1))
    qs = [Query(probs[0], budget=16, engine="nsga", megabatch=False),
          Query(probs[1], budget=16, engine="nsga")]
    out = s.submit(qs, key=jax.random.PRNGKey(3))
    for r in out:
        assert r.provenance.n_evals_run == 16
        assert len(r.front_objs) >= 1


# ---------------------------------------------------------------------------
# dominance-count kernel routing (interpret mode — no TPU needed)
# ---------------------------------------------------------------------------
def test_dominance_counts_kernel_parity(monkeypatch):
    """Above the size threshold ``archive.dominance_counts`` routes
    through the tiled pareto_rank kernel; in interpret mode its counts
    equal the fused-jnp small-pool path exactly."""
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    ks = jax.random.split(jax.random.PRNGKey(11), 2)
    objs = jax.random.normal(ks[0], (160, 3))
    objs = objs.at[80:88].set(objs[:8])     # exact ties
    valid = jax.random.bernoulli(ks[1], 0.7, (160,))
    le = jnp.all(objs[:, None, :] <= objs[None, :, :], axis=-1)
    lt = jnp.any(objs[:, None, :] < objs[None, :, :], axis=-1)
    want = jnp.sum(le & lt & valid[:, None], axis=0)
    monkeypatch.setattr(archive_mod, "_PARETO_RANK_MIN_N", 16)
    got = archive_mod.dominance_counts(objs, valid)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_dominance_counts_threshold_routes_small_pools(monkeypatch):
    """Below the threshold the fused-jnp path answers — the kernel module
    is never imported (cheap small-pool inserts stay cheap)."""
    import builtins
    monkeypatch.setattr(archive_mod, "_PARETO_RANK_MIN_N", 1 << 30)
    real_import = builtins.__import__

    def guard(name, *a, **kw):
        assert "pareto_rank" not in name
        return real_import(name, *a, **kw)

    monkeypatch.setattr(builtins, "__import__", guard)
    objs = jax.random.normal(jax.random.PRNGKey(2), (32, 2))
    out = archive_mod.dominance_counts(objs, jnp.ones((32,), bool))
    assert out.shape == (32,)
