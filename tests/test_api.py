"""The declarative front door (``repro.api``): Problem identity/hashing,
Query validation and engine resolution, pre-evaluation Plans (segment
schedule, cache verdict, predicted transfer neighbors), Session.submit's
unified Result/Provenance across engines, scan-segment streaming, the
``REPRO_CACHE_DIR`` override, and the opt-in archive-file GC."""

from pathlib import Path

import numpy as np
import pytest

import jax

import repro.core as C
from repro.api import (ENGINES, NeighborPlan, Plan, Problem, Provenance,
                       Query, Result, SegmentEvent, SegmentPlan, Session)
from repro.core.optimizer import SAConfig
from repro.explore.archive import (MANIFEST_NAME, ArchiveManifest,
                                   ManifestPolicy, ParetoArchive,
                                   pareto_front)
from repro.explore.nsga import NSGAConfig
from repro.explore.service import (BudgetPolicy, ExplorationService,
                                   resolve_cache_dir)

TINY = dict(max_shape=(16, 16, 4, 4, 1, 2))
OBJ = ("latency_ns", "cost_usd")


def _graph(k=64):
    return C.WorkloadGraph([C.matmul("mm", 512, 512, k)], [])


def _session(tmp_path, **policy_kw):
    policy = BudgetPolicy(**policy_kw) if policy_kw else BudgetPolicy()
    return Session(cache_dir=tmp_path,
                   nsga=NSGAConfig(pop=8, generations=2), policy=policy)


def _problem(k=64):
    return Problem(_graph(k), objectives=OBJ, ch_max=2, space_kwargs=TINY)


# ---------------------------------------------------------------------------
# Problem: canonical, hashable
# ---------------------------------------------------------------------------
def test_problem_is_canonical_and_hashable():
    a, b = _problem(), _problem()          # equal content, new objects
    assert a == b and hash(a) == hash(b)
    assert len({a, b}) == 1                # usable as a dict/cache key
    # any content change breaks identity: workload, bounds, objectives
    assert _problem(96) != a
    assert Problem(_graph(), OBJ, 2, dict(TINY, max_logB=2)) != a
    assert Problem(_graph(), ("latency_ns",), 2, TINY) != a
    assert a.key() == b.key() != _problem(96).key()


def test_problem_from_spec_matches_graph_built():
    spec = C.SystemSpec.build(_graph(), ch_max=2)
    space = C.DesignSpace(spec, **TINY)
    assert Problem.from_spec(spec, space, objectives=OBJ) == _problem()
    # the reconstructed constraint set is complete
    p = Problem.from_spec(spec, space, objectives=OBJ)
    assert p.space_kwargs["max_shape"] == tuple(TINY["max_shape"])
    assert p.space_kwargs["max_total_pes"] == 0


def test_problem_rejects_bad_objectives():
    with pytest.raises(ValueError):
        Problem(_graph(), objectives=("latency_ns", "nope"))
    with pytest.raises(ValueError):
        Problem(_graph(), objectives=())


# ---------------------------------------------------------------------------
# Query: validation + engine resolution
# ---------------------------------------------------------------------------
def test_query_engine_validation_and_auto_resolution():
    p = _problem()
    with pytest.raises(ValueError):
        Query(p, engine="genetic")
    assert Query(p).resolved_engine() == "nsga"
    assert Query(p, weights=(1, 1, 0, 0)).resolved_engine() == "bo_sa"
    assert Query(p, engine="two_stage").resolved_engine() == "two_stage"
    for e in ENGINES:
        Query(p, engine=e)                 # every advertised engine is valid


def test_nsga_query_rejects_scalarized_options(tmp_path):
    s = _session(tmp_path)
    with pytest.raises(ValueError):
        s.submit(Query(_problem(), engine="nsga", weights=(1, 1, 0, 0)))
    with pytest.raises(ValueError):
        s.submit(Query(_problem(), engine="nsga",
                       engine_opts=dict(n_init=2)))


def test_scalarized_query_rejects_nsga_options(tmp_path):
    """Validation is symmetric: a transfer or policy request on a
    scalarized engine errors instead of being silently dropped."""
    s = _session(tmp_path)
    with pytest.raises(ValueError, match="transfer"):
        s.submit(Query(_problem(), engine="bo_sa", transfer=True))
    with pytest.raises(ValueError, match="BudgetPolicy"):
        s.plan(Query(_problem(), engine="two_stage",
                     policy=BudgetPolicy(patience=1)))
    # ... and a bad query anywhere in a batch fails BEFORE any engine runs
    with pytest.raises(ValueError):
        s.submit([Query(_problem(), budget=16),
                  Query(_problem(96), engine="bo_sa", transfer=True)])
    assert not s.service._archives       # nothing ran


def test_scalarized_session_never_touches_cache_dir(monkeypatch, tmp_path):
    """The service (and its cache directory) is constructed lazily: a
    purely scalarized session — the optimize/two_stage shim path — works
    even where no cache directory could be created."""
    clash = tmp_path / "occupied"
    clash.write_text("not a dir")
    monkeypatch.delenv("REPRO_EXPLORE_CACHE", raising=False)
    monkeypatch.setenv("REPRO_CACHE_DIR", str(clash))
    s = Session()                        # no error: nothing touched yet
    spec = C.SystemSpec.build(_graph(), ch_max=2)
    space = C.DesignSpace(spec, **TINY)
    r = s.submit(Query(Problem.from_spec(spec, space), engine="bo_sa",
                       weights=(1.0, 0.0, 0.0, 0.0),
                       engine_opts=dict(bo_fields=(), n_init=1,
                                        sa=SAConfig(steps=5, chains=2))))
    assert np.isfinite(r.best_objective)
    with pytest.raises(ValueError):      # the nsga path still validates
        s.submit(Query(_problem(), budget=16))


# ---------------------------------------------------------------------------
# Plan: segment schedule, cache verdict, policy override
# ---------------------------------------------------------------------------
def test_plan_cold_schedule_then_warm_verdict(tmp_path):
    s = _session(tmp_path, chunk_generations=2, adaptive=False)
    q = Query(_problem(), budget=32)
    plan = s.plan(q)
    assert plan.engine == "nsga" and not plan.cache_hit
    # budget 32 at pop 8 => 4 generations in 2 chunks of 2
    assert plan.segments == (SegmentPlan(0, 8, 2, 16),
                             SegmentPlan(1, 8, 2, 16))
    assert plan.n_evals_planned == 32
    assert plan.neighbors == () and plan.seed_cap == 0
    r = s.submit(q)
    assert not r.provenance.from_cache
    assert r.provenance.cache_key == plan.cache_key
    assert r.provenance.n_evals_run == plan.n_evals_planned
    # planning spends nothing: the warm verdict now flips, segments empty
    plan2 = s.plan(q)
    assert plan2.cache_hit and plan2.segments == ()
    r2 = s.submit(q)
    assert r2.provenance.from_cache and r2.provenance.n_evals_run == 0


def test_plan_honors_query_policy_override(tmp_path):
    s = _session(tmp_path, chunk_generations=2)
    q = Query(_problem(), budget=32,
              policy=BudgetPolicy(chunk_generations=1))
    assert len(s.plan(q).segments) == 4    # chunk 1 => one segment per gen
    with pytest.raises(ValueError):        # conflicting overrides
        s.submit([Query(_problem(), policy=BudgetPolicy(patience=1)),
                  Query(_problem(96), policy=BudgetPolicy(patience=3))])


def test_plan_predicts_transfer_and_provenance_matches(tmp_path):
    """The acceptance gate: on a transfer-eligible cold query the plan
    reports engine, segment schedule and >= 1 predicted neighbor with a
    quota; executing it yields provenance matching the prediction."""
    s = _session(tmp_path, adaptive=False)
    s.submit(Query(_problem(64), budget=16))        # the future neighbor
    q = Query(_problem(96), budget=16, transfer=True)
    plan = s.plan(q)
    assert plan.engine == "nsga" and not plan.cache_hit
    assert len(plan.segments) >= 1
    assert len(plan.neighbors) >= 1
    assert all(isinstance(n, NeighborPlan) and n.quota >= 1
               and n.distance >= 0.0 for n in plan.neighbors)
    assert plan.seed_cap >= 1
    r = s.submit(q)
    pv = r.provenance
    assert pv.engine == plan.engine and pv.cache_key == plan.cache_key
    assert pv.from_cache == plan.cache_hit is False
    # every seeding source was a predicted neighbor, within the cap
    assert len(pv.transferred_from) >= 1
    assert set(pv.transferred_from) <= {n.key for n in plan.neighbors}
    assert 1 <= pv.n_transfer_seeds <= plan.seed_cap
    # the run executed the planned schedule (no plateau: adaptive off)
    assert r.trace.archive_hv.shape[0] == len(plan.segments)
    assert pv.n_evals_run == plan.n_evals_planned


# ---------------------------------------------------------------------------
# Session.submit: unified results, streaming, mixed engines
# ---------------------------------------------------------------------------
def test_streaming_segments_reassemble_into_trace(tmp_path):
    s = _session(tmp_path, chunk_generations=2, adaptive=False)
    events = []
    r = s.submit(Query(_problem(), budget=32), on_segment=events.append)
    assert [e.segment for e in events] == [0, 1]
    assert all(isinstance(e, SegmentEvent) and e.phase == "refine"
               and e.cache_key == r.provenance.cache_key for e in events)
    # the streamed slices ARE the run: extending them recovers the trace
    whole = events[0].trace.extend(events[1].trace)
    assert whole.generations == r.trace.generations
    np.testing.assert_array_equal(whole.n_evals, r.trace.n_evals)
    np.testing.assert_allclose(whole.hypervolume, r.trace.hypervolume)
    # a throwing callback warns but never fails the query
    def boom(e):
        raise RuntimeError("dashboard down")
    with pytest.warns(UserWarning, match="on_segment callback failed"):
        r2 = s.submit(Query(_problem(96), budget=16), on_segment=boom)
    assert not r2.provenance.from_cache


def test_mixed_engine_batch(tmp_path):
    s = _session(tmp_path)
    spec = C.SystemSpec.build(_graph(), ch_max=2)
    space = C.DesignSpace(spec, **TINY)
    qs = [Query(_problem(), budget=16),
          Query(Problem.from_spec(spec, space), engine="bo_sa",
                weights=(1.0, 1.0, 0.0, 0.0),
                engine_opts=dict(bo_fields=(), n_init=2,
                                 sa=SAConfig(steps=10, chains=2)))]
    ra, rb = s.submit(qs)
    assert ra.provenance.engine == "nsga" and ra.best_design is None
    assert rb.provenance.engine == "bo_sa"
    assert rb.best_design is not None and np.isfinite(rb.best_objective)
    # one unified Result shape either way
    for r in (ra, rb):
        assert r.front_objs.shape[1] == 2
        assert len(r.front_designs) == len(r.front_objs)
        assert isinstance(r.provenance, Provenance)


def test_scalarized_result_with_archive_serves_front(tmp_path):
    s = _session(tmp_path)
    spec = C.SystemSpec.build(_graph(), ch_max=2)
    space = C.DesignSpace(spec, **TINY)
    arc = ParetoArchive(
        16, jax.tree.map(np.asarray,
                         C.random_design(jax.random.PRNGKey(0), space)),
        n_obj=4, obj_keys=C.METRIC_KEYS)
    events = []
    r = s.submit(Query(Problem.from_spec(spec, space, objectives=OBJ),
                       engine="bo_sa", weights=(1.0, 0.0, 1.0, 0.0),
                       archive=arc,
                       engine_opts=dict(bo_fields=(), n_init=3,
                                        sa=SAConfig(steps=10, chains=2))),
                 on_segment=events.append)
    # scalarized engines stream one completion event
    assert len(events) == 1 and events[0].phase == "bo_sa"
    assert len(r.front_objs) >= 1
    assert len(pareto_front(r.front_objs)) == len(r.front_objs)
    assert r.provenance.n_evals_run == 3 * 10 * 2
    assert arc.n_evals == 3                # the archive recorded the run


def test_module_level_default_session(tmp_path, monkeypatch):
    """The process-wide conveniences: ``session()`` is a singleton (kwargs
    only on first construction), ``plan``/``submit`` delegate to it."""
    import repro.explore.api as api_mod
    monkeypatch.setattr(api_mod, "_DEFAULT_SESSION", None)
    s = api_mod.session(cache_dir=tmp_path,
                        nsga=NSGAConfig(pop=8, generations=2))
    assert api_mod.session() is s
    with pytest.raises(RuntimeError):
        api_mod.session(cache_dir=tmp_path / "other")
    q = Query(_problem(), budget=16)
    assert not api_mod.plan(q).cache_hit
    r = api_mod.submit(q)
    assert r.provenance.n_evals_run >= 16
    assert api_mod.plan(q).cache_hit


# ---------------------------------------------------------------------------
# REPRO_CACHE_DIR override + construction-time validation
# ---------------------------------------------------------------------------
def test_repro_cache_dir_env_override(tmp_path, monkeypatch):
    monkeypatch.delenv("REPRO_EXPLORE_CACHE", raising=False)
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path / "fleet"))
    svc = ExplorationService()
    assert svc.cache_dir == tmp_path / "fleet"
    assert svc.cache_dir.is_dir()          # created at construction
    # the historic env var outranks the fleet-wide one ...
    monkeypatch.setenv("REPRO_EXPLORE_CACHE", str(tmp_path / "legacy"))
    assert ExplorationService().cache_dir == tmp_path / "legacy"
    # ... and the explicit argument outranks both
    assert ExplorationService(cache_dir=tmp_path / "arg").cache_dir \
        == tmp_path / "arg"


def test_cache_dir_validated_at_construction(tmp_path, monkeypatch):
    clash = tmp_path / "not_a_dir"
    clash.write_text("occupied")
    monkeypatch.delenv("REPRO_EXPLORE_CACHE", raising=False)
    monkeypatch.setenv("REPRO_CACHE_DIR", str(clash))
    with pytest.raises(ValueError, match="unusable"):
        ExplorationService()
    with pytest.raises(ValueError, match="unusable"):
        resolve_cache_dir(clash)


# ---------------------------------------------------------------------------
# opt-in archive-file GC (ManifestPolicy.reap_evicted_after)
# ---------------------------------------------------------------------------
def _manifest_with_files(tmp_path, policy, keys):
    Path(tmp_path).mkdir(parents=True, exist_ok=True)
    m = ArchiveManifest(tmp_path / MANIFEST_NAME, policy=policy)
    for i, k in enumerate(keys):
        (tmp_path / f"{k}.npz").write_bytes(b"stub")
        m.update(k, embedding=np.ones(3) * i, dims=(1, 2, 1),
                 n_evals=8, budget_covered=8, searched=OBJ, digest={})
    return m


def test_manifest_gc_reaps_stale_evictions_only(tmp_path):
    pol = ManifestPolicy(max_entries=1, reap_evicted_after=2)
    m = _manifest_with_files(tmp_path, pol, ["aaa", "bbb"])
    # aaa was evicted when bbb arrived; not yet stale
    assert "aaa" in m.evicted and m.reap_evicted() == ()
    assert (tmp_path / "aaa.npz").exists()
    m.touch("bbb")                          # tick the clock past the bound
    m.touch("bbb")
    assert m.reap_evicted() == ("aaa",)
    assert not (tmp_path / "aaa.npz").exists()
    assert (tmp_path / "bbb.npz").exists()  # indexed entries never reaped
    assert m.evicted == {}                  # record consumed


def test_manifest_gc_is_opt_in_and_reindex_cancels(tmp_path):
    m = _manifest_with_files(tmp_path, ManifestPolicy(max_entries=1),
                             ["aaa", "bbb"])
    for _ in range(5):
        m.touch("bbb")
    assert m.reap_evicted() == ()           # default policy: never
    assert (tmp_path / "aaa.npz").exists()
    # re-indexing an evicted key cancels its pending reap
    pol = ManifestPolicy(max_entries=2, reap_evicted_after=1)
    m2 = _manifest_with_files(tmp_path / "b", pol, ["aaa"])
    m2.evicted["ccc"] = 0
    (tmp_path / "b" / "ccc.npz").write_bytes(b"stub")
    m2.update("ccc", embedding=np.zeros(3), dims=(1, 2, 1), n_evals=1,
              budget_covered=1, searched=OBJ, digest={})
    for _ in range(3):
        m2.touch("ccc")
    assert m2.reap_evicted() == ()
    assert (tmp_path / "b" / "ccc.npz").exists()


def test_manifest_gc_eviction_records_roundtrip(tmp_path):
    pol = ManifestPolicy(max_entries=1, reap_evicted_after=10)
    m = _manifest_with_files(tmp_path, pol, ["aaa", "bbb"])
    m.save()
    back = ArchiveManifest.load(tmp_path / MANIFEST_NAME, policy=pol)
    assert back.evicted == m.evicted and "aaa" in back.evicted


def test_service_gc_end_to_end(tmp_path):
    """A fleet cache under disk pressure: with the opt-in policy, the
    archive file of a long-evicted entry disappears after enough ticks;
    fresher evictions keep their files."""
    svc = ExplorationService(
        cache_dir=tmp_path, nsga=NSGAConfig(pop=8, generations=2),
        policy=BudgetPolicy(adaptive=False, reallocate=False),
        manifest_policy=ManifestPolicy(max_entries=1,
                                       reap_evicted_after=1))
    session = Session(service=svc)
    keys = []
    for k in (64, 96, 128):
        keys.append(session.submit(
            Query(_problem(k), budget=16)).provenance.cache_key)
    a, b, c = keys
    assert not svc._path(a).exists()       # evicted first, stale => reaped
    assert svc._path(b).exists()           # evicted too recently
    assert svc._path(c).exists()           # still indexed
    assert list(svc.manifest.entries) == [c]
