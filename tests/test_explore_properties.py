"""Property-based tests (hypothesis) for the ``repro.explore.archive``
dominance/hypervolume/crowding primitives — the optimizer-layer invariants
every engine (NSGA-II fronts, Pareto archives, scalarized BO x SA) relies
on.  Each property is a plain ``_check_*`` function driven by a seeded RNG
so failures reproduce exactly from the printed seed."""

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax.numpy as jnp  # noqa: E402

from repro.explore.archive import (crowding_distance, dominance_counts,  # noqa: E402
                                   dominates, hypervolume_2d,
                                   hypervolume_2d_jit, pareto_front)

seeds = st.integers(0, 2**31 - 1)
sizes = st.integers(1, 24)
dims = st.integers(1, 4)


def _cloud(seed, n, k, ties=True):
    rng = np.random.default_rng(seed)
    pts = rng.random((n, k))
    if ties:    # quantize so exact ties/duplicates actually occur
        pts = np.round(pts * 4) / 4
    return pts


# ---------------------------------------------------------------------------
# dominance relation: antisymmetric, transitive, consistent across impls
# ---------------------------------------------------------------------------
def _dom_matrix(pts):
    le = np.all(pts[:, None, :] <= pts[None, :, :], axis=-1)
    lt = np.any(pts[:, None, :] < pts[None, :, :], axis=-1)
    return le & lt                                 # D[i, j]: i dominates j


@given(seed=seeds, n=sizes, k=dims)
@settings(max_examples=40, deadline=None)
def test_dominance_antisymmetric(seed, n, k):
    pts = _cloud(seed, n, k)
    D = _dom_matrix(pts)
    assert not np.any(D & D.T), "a dominates b AND b dominates a"
    # the jnp scalar predicate agrees with the matrix on every pair
    for i in range(min(n, 6)):
        for j in range(min(n, 6)):
            assert bool(dominates(jnp.asarray(pts[i]),
                                  jnp.asarray(pts[j]))) == bool(D[i, j])


@given(seed=seeds, n=sizes, k=dims)
@settings(max_examples=40, deadline=None)
def test_dominance_transitive(seed, n, k):
    pts = _cloud(seed, n, k)
    D = _dom_matrix(pts)
    # D[i,j] & D[j,l] => D[i,l]: the boolean product may not escape D
    chain = (D.astype(int) @ D.astype(int)) > 0
    assert not np.any(chain & ~D)


@given(seed=seeds, n=sizes, k=dims)
@settings(max_examples=40, deadline=None)
def test_pareto_front_consistent_with_dominance_counts(seed, n, k):
    pts = _cloud(seed, n, k)
    nd = np.asarray(dominance_counts(jnp.asarray(pts, jnp.float32),
                                     jnp.ones(n, bool)))
    assert sorted(pareto_front(pts)) == list(np.flatnonzero(nd == 0))
    # every point outside the front is dominated by some front point
    front = set(pareto_front(pts))
    D = _dom_matrix(pts)
    for j in range(n):
        if j not in front:
            assert any(D[i, j] for i in front)


# ---------------------------------------------------------------------------
# hypervolume: monotone under insertion, invariant to dominated points
# ---------------------------------------------------------------------------
@given(seed=seeds, n=sizes)
@settings(max_examples=40, deadline=None)
def test_hypervolume_monotone_under_insertion(seed, n):
    pts = _cloud(seed, n, 2)
    ref = (1.25, 1.25)
    hv = hypervolume_2d(pts[:-1], ref) if n > 1 else 0.0
    assert hypervolume_2d(pts, ref) >= hv - 1e-12
    # and bounded by the whole dominated box
    assert hypervolume_2d(pts, ref) <= ref[0] * ref[1] + 1e-12


@given(seed=seeds, n=sizes)
@settings(max_examples=40, deadline=None)
def test_hypervolume_invariant_to_dominated_points(seed, n):
    rng = np.random.default_rng(seed)
    pts = _cloud(seed, n, 2)
    ref = (1.5, 1.5)
    hv = hypervolume_2d(pts, ref)
    # append points dominated by existing ones: hv must not move
    base = pts[rng.integers(0, n, size=5)]
    dominated = base + rng.uniform(1e-3, 0.5, size=base.shape)
    assert hypervolume_2d(np.vstack([pts, dominated]), ref) \
        == pytest.approx(hv, rel=1e-12, abs=1e-12)
    # keeping only the Pareto front changes nothing either
    front = pts[pareto_front(pts)]
    assert hypervolume_2d(front, ref) == pytest.approx(hv, rel=1e-12,
                                                       abs=1e-12)


@given(seed=seeds, n=sizes)
@settings(max_examples=40, deadline=None)
def test_hypervolume_jit_matches_host(seed, n):
    pts = _cloud(seed, n, 2, ties=False)
    ref = (1.25, 1.1)
    assert float(hypervolume_2d_jit(pts, ref)) \
        == pytest.approx(hypervolume_2d(pts, ref), rel=1e-5, abs=1e-6)


# ---------------------------------------------------------------------------
# crowding distance: boundary points always carry +inf, invalid rows 0
# ---------------------------------------------------------------------------
@given(seed=seeds, n=st.integers(3, 24), k=dims)
@settings(max_examples=40, deadline=None)
def test_crowding_distance_boundary_handling(seed, n, k):
    rng = np.random.default_rng(seed)
    pts = rng.random((n, k))                       # distinct w.h.p.
    valid = rng.random(n) < 0.7
    valid[rng.integers(0, n)] = True               # at least one valid row
    crowd = np.asarray(crowding_distance(jnp.asarray(pts, jnp.float32),
                                         jnp.asarray(valid)))
    assert np.all(crowd[~valid] == 0.0)
    assert np.all(crowd[valid] >= 0.0)
    vidx = np.flatnonzero(valid)
    if len(vidx) >= 2:
        for c in range(k):
            col = pts[vidx, c]
            assert np.isinf(crowd[vidx[np.argmin(col)]])
            assert np.isinf(crowd[vidx[np.argmax(col)]])
    else:
        assert np.isinf(crowd[vidx[0]])            # lone point is boundary
