"""Tests for the launch-side analysis stack: the loop-aware HLO analyzer
(trip-count multiplication, wire-byte pricing), the roofline math, the
input specs, and the autosharding advisor's feasibility logic."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.autosharding.advisor import ShardPlan, exhaustive_best, predict
from repro.configs import ARCH_IDS, cells, get_config
from repro.launch import hlo_analysis as H
from repro.launch.specs import batch_specs, input_specs
from repro.models.config import SHAPES


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_analyzer_counts_scan_trips():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    c = _compile(f, x, x)
    an = H.ModuleAnalysis(c.as_text()).totals()
    assert an["flops"] == pytest.approx(2 * 256 ** 3 * 10, rel=1e-6)


def test_analyzer_counts_nested_scan_trips():
    def g(x, w):
        def outer(c, _):
            def inner(c2, _):
                return jnp.tanh(c2 @ w), None
            c2, _ = jax.lax.scan(inner, c, None, length=5)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = _compile(g, x, x)
    an = H.ModuleAnalysis(c.as_text()).totals()
    assert an["flops"] == pytest.approx(2 * 128 ** 3 * 20, rel=1e-6)


def test_analyzer_vs_xla_on_loop_free():
    """Without loops the analyzer must agree with XLA's own count."""
    def f(a, b):
        return (a @ b) @ b
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = _compile(f, x, x)
    an = H.ModuleAnalysis(c.as_text()).totals()
    xf, _ = H.cost_analysis_terms(c)
    assert an["flops"] == pytest.approx(xf, rel=1e-6)


def test_collective_wire_factors():
    txt = """
ENTRY %main (x: f32[16]) -> f32[16] {
  %ar = f32[1024]{0} all-reduce(%x), replica_groups={{0,1,2,3}}
  %ag = f32[4096]{0} all-gather(%x), replica_groups={{0,1,2,3}}
  ROOT %cp = f32[1024]{0} collective-permute(%x), source_target_pairs={{0,1}}
}
"""
    s = H.collective_stats(txt)
    assert s["wire_bytes"]["all-reduce"] == pytest.approx(
        2 * 4096 * 3 / 4)                      # 2 * size * (n-1)/n
    assert s["wire_bytes"]["all-gather"] == pytest.approx(
        4 * 4096 * 3 / 4)                      # out * (n-1)/n
    assert s["wire_bytes"]["collective-permute"] == pytest.approx(4096)


def test_roofline_terms_and_bottleneck():
    r = H.roofline(flops_per_device=197e12, bytes_per_device=819e9 / 2,
                   wire_bytes_per_device=0.0, n_chips=256,
                   model_flops=197e12 * 256)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(0.5)
    assert r.bottleneck == "compute"
    assert r.roofline_frac == pytest.approx(1.0)
    assert r.useful_ratio == pytest.approx(1.0)


def test_roofline_decode_bandwidth_floor():
    r = H.roofline(1e9, 819e9, 0.0, 256, model_flops=1e9 * 256,
                   model_min_bytes=819e9 * 256)
    # ideal = compulsory bytes at full bandwidth = 1s; step = memory 1s
    assert r.roofline_frac == pytest.approx(1.0, rel=1e-3)


# ---------------------------------------------------------------------------
# input specs
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["qwen2_72b", "deepseek_v2_236b",
                                  "falcon_mamba_7b", "whisper_tiny",
                                  "qwen2_vl_72b", "hymba_1_5b"])
def test_input_specs_shapes(arch):
    sp = input_specs(arch, "train_4k")
    assert sp["batch"]["tokens"].shape == (256, 4096)
    assert all(isinstance(l, jax.ShapeDtypeStruct)
               for l in jax.tree_util.tree_leaves(sp["params"]))
    if SHAPES["decode_32k"].name in [c for c in cells(arch)]:
        sd = input_specs(arch, "decode_32k")
        assert sd["tokens"].shape == (128, 1)
        # serving weights are bf16
        mats = [l for l in jax.tree_util.tree_leaves(sd["params"])
                if l.ndim >= 2]
        assert all(m.dtype == jnp.bfloat16 for m in mats)


def test_cells_cover_40_grid():
    total = sum(len(cells(a)) for a in ARCH_IDS)
    skipped = sum(1 for a in ARCH_IDS if "long_500k" not in cells(a))
    assert total + skipped == 40          # 10 archs x 4 shapes


# ---------------------------------------------------------------------------
# advisor
# ---------------------------------------------------------------------------
def test_advisor_rejects_infeasible_hbm():
    cfg = get_config("grok_1_314b")          # 314B params
    sc = SHAPES["train_4k"]
    tiny = ShardPlan(data=1, model=4, microbatch=1, remat="none")
    s = predict(cfg, sc, tiny)
    assert not s.feasible                    # 314B on 4 chips cannot fit


def test_advisor_best_is_feasible_and_balanced():
    cfg = get_config("qwen2_72b")
    plan, score, scored = exhaustive_best(cfg, SHAPES["train_4k"],
                                          chips=256)
    assert score.feasible
    assert score.hbm_gb < 16.0
    # feasible plans must be a strict subset
    assert 0 < sum(1 for _, s in scored if s.feasible) < len(scored)


def test_advisor_decode_prefers_sequence_kv_for_gqa8():
    cfg = get_config("qwen2_72b")            # kv=8
    plan, score, _ = exhaustive_best(cfg, SHAPES["decode_32k"], chips=256)
    if plan.model > 8:
        assert plan.decode_kv == "sequence"
