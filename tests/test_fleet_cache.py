"""End-to-end fleet-cache integration: one service cold-explores several
``workload_library`` graphs, then refines some of them *warm* with
transfer — exercising the manifest growth policy (size bound, LRU
eviction order), the trust table's save/load round-trip, and the
transfer/ledger accounting, all against a real on-disk cache directory.

Budgets are tiny (pop 8, two generations per exploration) and the graphs
are picked so the vmapped evaluator compiles only twice (the three
attention blocks share padded dims, the MLP stack is the second group).
"""

import numpy as np
import pytest

import repro.core as C
from repro.explore.archive import MANIFEST_NAME, ArchiveManifest, ManifestPolicy
from repro.explore.nsga import NSGAConfig
from repro.explore.service import BudgetPolicy, ExplorationService

# this module deliberately exercises the legacy explore entry points
# (now deprecation shims over repro.api) — expected warnings only
pytestmark = pytest.mark.filterwarnings("ignore:legacy entry point")

SPACE_KW = dict(max_shape=(16, 16, 4, 4, 1, 2))
OBJ = ("latency_ns", "cost_usd")
COLD = ("attn_qwen2_72b", "attn_qwen2_5_32b", "attn_internlm2",
        "mlp_qwen2_72b")
WARM = ("attn_qwen2_72b", "attn_internlm2")
MAX_ENTRIES = 3


@pytest.fixture(scope="module")
def fleet(tmp_path_factory):
    """One shared fleet run: 4 cold explorations, then 2 warm transfer
    refinements, against a bounded manifest."""
    cache = tmp_path_factory.mktemp("fleet_cache")
    lib = C.presets.workload_library()
    svc = ExplorationService(
        cache_dir=cache, nsga=NSGAConfig(pop=8, generations=2),
        policy=BudgetPolicy(adaptive=False, reallocate=False),
        manifest_policy=ManifestPolicy(max_entries=MAX_ENTRIES))
    cold = {}
    for name in COLD:
        cold[name] = svc.explore(lib[name], OBJ, budget=16, ch_max=2,
                                 space_kwargs=SPACE_KW)
    warm = {}
    for name in WARM:
        warm[name] = svc.explore(lib[name], OBJ, budget=48, ch_max=2,
                                 space_kwargs=SPACE_KW, transfer=True)
    return dict(cache=cache, svc=svc, cold=cold, warm=warm)


def test_every_query_ran_and_archives_persisted(fleet):
    svc = fleet["svc"]
    for name, r in fleet["cold"].items():
        assert not r.from_cache and r.n_evals_run >= 16
        assert len(r.front_objs) >= 1
        assert svc._path(r.cache_key).exists()
    for name, r in fleet["warm"].items():
        # a bigger budget on a half-explored problem resumes, never
        # re-serves the stale front
        assert not r.from_cache and r.n_evals_run >= 32
        assert r.cache_key == fleet["cold"][name].cache_key


def test_manifest_stays_within_bound_with_no_query_errors(fleet):
    svc = fleet["svc"]
    assert len(svc.manifest) <= MAX_ENTRIES
    # every surviving entry still answers nearest() queries (no dangling
    # embeddings / digests after evictions)
    any_key = next(iter(svc.manifest.entries))
    emb = svc.manifest.entries[any_key]["embedding"]
    got = svc.manifest.nearest(emb, k=10)
    assert 1 <= len(got) <= MAX_ENTRIES
    for nk, _ in got:
        assert svc.manifest.entries[nk]["digest"] is not None


def test_eviction_order_is_lru(fleet):
    """The manifest holds the MOST recently used problems: the warm
    refinements (and the neighbors they seeded from) outrank the colder
    entries, and whatever was evicted has strictly older ticks."""
    svc = fleet["svc"]
    live = {k: e.get("last_used", 0)
            for k, e in svc.manifest.entries.items()}
    # the final warm refinement is the freshest write — it must survive
    last_warm = fleet["warm"][WARM[-1]].cache_key
    assert last_warm in live
    # evicted keys (cold-explored but gone from the index) all have their
    # archive npz intact — eviction bounds the INDEX, not the cache
    evicted = [r.cache_key for r in fleet["cold"].values()
               if r.cache_key not in live]
    assert len(evicted) >= 1
    for ck in evicted:
        assert svc._path(ck).exists()


def test_trust_table_roundtrips_through_save_load(fleet):
    svc = fleet["svc"]
    trust = svc.manifest.trust
    assert len(trust) >= 1                 # the warm refinements recorded
    for r in trust:
        assert 0.0 <= r["lift"] <= 1.0
        assert np.all(np.isfinite(r["delta"]))
    back = ArchiveManifest.load(fleet["cache"] / MANIFEST_NAME)
    assert len(back.trust) == len(trust)
    for a, b in zip(trust, back.trust):
        assert (a["src"], a["dst"]) == (b["src"], b["dst"])
        assert a["lift"] == pytest.approx(b["lift"])
        np.testing.assert_allclose(a["delta"], b["delta"])
    # LRU ticks survive too (a fresh service must not reset the clock)
    assert back.clock == svc.manifest.clock >= len(COLD)


def test_transfer_accounting_consistent_with_ledger(fleet):
    svc = fleet["svc"]
    for r in fleet["cold"].values():       # transfer=False: no seeding
        assert r.transferred_from == () and r.n_transfer_seeds == 0
    for name, r in fleet["warm"].items():
        # a credited neighbor implies injected seeds and vice versa (the
        # balanced_init fallback never fires on a resumed archive)
        assert (len(r.transferred_from) >= 1) == (r.n_transfer_seeds >= 1)
        assert r.cache_key not in r.transferred_from
        # every credited neighbor has a trust record for this refinement
        for nk in r.transferred_from:
            assert any(t["src"] == nk and t["dst"] == r.cache_key
                       for t in svc.manifest.trust)
    # adaptive off: nothing plateaued, nothing banked, ledger empty
    for r in list(fleet["cold"].values()) + list(fleet["warm"].values()):
        assert not r.plateaued and r.n_evals_banked == 0
        assert r.n_evals_realloc == 0
    assert svc.ledger == {}
