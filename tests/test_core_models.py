"""Model-level tests: dataflow analysis invariants, network routing and
contention, cost model (Fig.-3 qualitative behavior), pipeline model
(Fig.-5 example), and property tests over random design points."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

import repro.core as C
from repro.core import network
from repro.core.dataflow import analyze_chiplet
from repro.core.perf_model import StageGraph, Stage, build_stage_graph
from repro.core.simulator import SystolicConfig, simulate_matmul
from repro.core.workload import matmul, conv2d, WorkloadGraph


def _design_mm(shape, spatial, order=None, t1=(64, 64, 64), t2=(256, 256, 512)):
    order = order if order is not None else [0, 1, 2, 3, 4, 5, 6, 7]
    return (jnp.asarray(shape, jnp.int32), jnp.asarray(spatial, jnp.int32),
            jnp.asarray([order] * 3, jnp.int32),
            jnp.asarray([list(t1) + [1] * 5, list(t2) + [1] * 5], jnp.int32))


def test_dataflow_mac_conservation():
    wl = matmul("mm", 256, 256, 256).to_arrays()
    sh, sp, od, ti = _design_mm([8, 8, 2, 2, 2, 2], [0, 1, 0, 1, 0, 1])
    an = analyze_chiplet(wl, sh, sp, od, ti)
    assert float(an["total_macs"]) == 256 ** 3
    assert float(an["mac_count"]) == pytest.approx(256 ** 3, rel=1e-6)
    assert 0 < float(an["utilization"]) <= 1.0


def test_dataflow_min_traffic_bound():
    """External traffic must be at least the compulsory (cold) volume of each
    tensor's per-chiplet share."""
    w = matmul("mm", 256, 256, 256)
    wl = w.to_arrays()
    sh, sp, od, ti = _design_mm([8, 8, 2, 2, 1, 1], [0, 1, 0, 1, 0, 1])
    an = analyze_chiplet(wl, sh, sp, od, ti)
    cold = (w.tensor_size("A") + w.tensor_size("B") + w.tensor_size("C")) * 2
    assert float(an["ext_bytes"]) >= cold * 0.99


def test_dataflow_order_changes_traffic():
    """Output-inner vs reduction-inner loop orders must differ in external
    traffic (reuse is order-dependent) — the core of dataflow exploration."""
    wl = matmul("mm", 512, 512, 512).to_arrays()
    sh = [16, 16, 2, 2, 1, 1]
    sp = [0, 1, 0, 1, 0, 1]
    _, _, od_k_inner, ti = _design_mm(sh, sp, [0, 1, 2, 3, 4, 5, 6, 7],
                                      t2=(64, 64, 64))
    _, _, od_k_outer, _ = _design_mm(sh, sp, [2, 0, 1, 3, 4, 5, 6, 7],
                                     t2=(64, 64, 64))
    a1 = analyze_chiplet(wl, *_design_mm(sh, sp, [0, 1, 2, 3, 4, 5, 6, 7],
                                         t2=(64, 64, 64))[0:4])
    a2 = analyze_chiplet(wl, *_design_mm(sh, sp, [2, 0, 1, 3, 4, 5, 6, 7],
                                         t2=(64, 64, 64))[0:4])
    assert float(a1["ext_bytes"]) != float(a2["ext_bytes"])


def test_dataflow_bigger_tile_less_refill():
    wl = matmul("mm", 512, 512, 512).to_arrays()
    sh, sp = [16, 16, 2, 2, 1, 1], [0, 1, 0, 1, 0, 1]
    small = analyze_chiplet(wl, *_design_mm(sh, sp, t2=(64, 64, 64)))
    big = analyze_chiplet(wl, *_design_mm(sh, sp, t2=(256, 256, 512)))
    assert float(big["ext_bytes"]) <= float(small["ext_bytes"])
    assert float(big["chip_buf_bytes"]) > float(small["chip_buf_bytes"])


# ---------------------------------------------------------------------------
# network
# ---------------------------------------------------------------------------
def test_routing_tables_reach_destination():
    nh_all = network.next_hop_tables()
    for fam in range(network.N_FAMILIES):
        for n in (2, 5, 9, 16, 36):
            nh = nh_all[network.topo_code(fam, n)]
            for s in range(n):
                for d in list(range(n)) + [n]:       # incl. DRAM node
                    cur, hops = s, 0
                    while cur != d and hops < network.MAX_HOPS:
                        cur = int(nh[cur, d])
                        hops += 1
                    assert cur == d, (fam, n, s, d)


def test_mesh_xy_hop_count():
    nh = network.next_hop_tables()[network.topo_code(network.FAM_MESH, 9)]
    # 3x3 mesh: node 0 -> node 8 = 2 + 2 hops
    links, hops = network.route_links(
        jnp.asarray(nh), jnp.asarray([0]), jnp.asarray([8]))
    assert int(hops[0]) == 4


def test_contention_throttles_proportionally():
    """Paper Fig. 5b: two flows sharing a link each get bandwidth pro-rata."""
    nh = jnp.asarray(network.next_hop_tables()[
        network.topo_code(network.FAM_CHAIN, 3)])
    src = jnp.asarray([0, 1])
    dst = jnp.asarray([2, 2])
    bwr = jnp.asarray([32.0, 32.0])
    vol = jnp.asarray([3.2e4, 3.2e4])
    out = network.evaluate_network(nh, src, dst, bwr, vol,
                                   jnp.asarray([True, True]),
                                   32.0, 128.0, 20.0, 3)
    # link 1->2 carries both flows: each gets 16 GB/s; flow0 has 2 hops
    assert float(out["delay_ns"][0]) == pytest.approx(2 * 20 + 3.2e4 / 16.0,
                                                      rel=1e-3)
    assert float(out["delay_ns"][1]) == pytest.approx(1 * 20 + 3.2e4 / 16.0,
                                                      rel=1e-3)


def test_no_contention_full_bandwidth():
    nh = jnp.asarray(network.next_hop_tables()[
        network.topo_code(network.FAM_CHAIN, 3)])
    out = network.evaluate_network(
        nh, jnp.asarray([0]), jnp.asarray([1]), jnp.asarray([16.0]),
        jnp.asarray([1.6e4]), jnp.asarray([True]), 32.0, 128.0, 20.0, 3)
    assert float(out["delay_ns"][0]) == pytest.approx(20 + 1.6e4 / 16.0,
                                                      rel=1e-3)


# ---------------------------------------------------------------------------
# cost model (Fig. 3 qualitative)
# ---------------------------------------------------------------------------
def test_yield_decreases_with_area():
    y1 = float(C.die_yield(100.0, 0.0009, 4.0))
    y2 = float(C.die_yield(600.0, 0.0009, 4.0))
    assert 0 < y2 < y1 < 1


def test_fig3_large_die_chipletization_wins():
    """TPU-class (331mm^2) dies: 3 chiplets on organic substrate must be
    cheaper than the 3x-area monolithic die (paper Fig. 3)."""
    mono = float(C.monolithic_cost(3 * 331.0))
    chl = float(C.package_cost(jnp.asarray([331.0] * 3), C.PKG_ORGANIC))
    assert chl < mono


def test_fig3_small_die_chipletization_no_win():
    """Gemmini-class (1.1mm^2) dies: negligible die-cost reduction, bonding
    overhead dominates -> chipletization does NOT pay off (paper Fig. 3)."""
    mono = float(C.monolithic_cost(3 * 1.1))
    chl = float(C.package_cost(jnp.asarray([1.1] * 3), C.PKG_ORGANIC))
    assert chl > mono


def test_fig3_interposer_costs_more():
    areas = jnp.asarray([331.0] * 3)
    organic = float(C.package_cost(areas, C.PKG_ORGANIC))
    passive = float(C.package_cost(areas, C.PKG_PASSIVE))
    active = float(C.package_cost(areas, C.PKG_ACTIVE))
    assert organic < passive < active


# ---------------------------------------------------------------------------
# pipeline model (paper Fig. 5a example)
# ---------------------------------------------------------------------------
def test_fig5_stage_graph():
    """v0, v1 in parallel; e01: v0->v2 ; e12: v1->v2."""
    sg = build_stage_graph(
        compute_delays={0: 10.0, 1: 8.0, 2: 6.0},
        binding={0: 0, 1: 1, 2: 2},
        deps=[(0, 2, 3.0), (1, 2, 5.0)])
    # longest path: v1(8) + e(5) + v2(6) = 19
    assert sg.latency() == pytest.approx(19.0)
    assert sg.throughput() == pytest.approx(1 / 10.0)
    assert sg.total_time(ticks=4) == pytest.approx(19.0 + 3 * 10.0)


def test_shared_chiplet_merges_stages():
    sg = build_stage_graph(
        compute_delays={0: 10.0, 1: 8.0, 2: 6.0},
        binding={0: 0, 1: 0, 2: 1},                 # wl 0,1 share chiplet 0
        deps=[(0, 2, 3.0), (1, 2, 3.0)])
    # merged stage = 18, then transfer 3, then 6
    assert sg.latency() == pytest.approx(27.0)


# ---------------------------------------------------------------------------
# full-evaluator properties
# ---------------------------------------------------------------------------
@given(seed=st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_random_designs_yield_finite_positive_metrics(seed):
    g = WorkloadGraph([matmul("mm", 128, 128, 128)], [])
    spec = C.SystemSpec.build(g, ch_max=36)
    space = C.DesignSpace(spec)
    d = C.random_design(jax.random.PRNGKey(seed), space)
    m = C.evaluate_system(spec, d)
    for k in ("latency_ns", "energy_pj", "cost_usd", "area_mm2", "edp"):
        v = float(m[k])
        assert np.isfinite(v) and v > 0, (k, v)
    assert 0 <= float(m["utilization"]) <= 1.0 + 1e-6


def test_analytical_vs_systolic_simulator():
    """Sec. V-A: analytical latency within ~10% of the cycle-approximate
    systolic simulation for compute-bound matmuls on an 8x8 array."""
    errs = []
    for (M, N, K) in [(128, 128, 128), (256, 256, 256), (512, 512, 128)]:
        sim = simulate_matmul(M, N, K, SystolicConfig(8, 8))
        wl = matmul("mm", M, N, K).to_arrays()
        sh = jnp.asarray([8, 8, 1, 1, 1, 1], jnp.int32)
        sp = jnp.asarray([0, 1, 0, 1, 0, 1], jnp.int32)
        od = jnp.asarray([[0, 1, 2, 3, 4, 5, 6, 7]] * 3, jnp.int32)
        ti = jnp.asarray([[8, 8, K] + [1] * 5, [M, N, K] + [1] * 5], jnp.int32)
        an = analyze_chiplet(wl, sh, sp, od, ti, ext_bw_gbps=128.0)
        err = abs(float(an["delay_ns"]) - sim["latency_ns"]) / sim["latency_ns"]
        errs.append(err)
    assert np.mean(errs) < 0.12, errs
