"""Cross-workload transfer: workload identity + feature embeddings, the
portable design IR (``to_portable``/``migrate``/``repair``), the cross-spec
archive manifest (nearest-neighbor index, crash-safe persistence), the
service's ``transfer=True`` warm-start path, and transferred seed
populations in the scalarized optimizer.  Hypothesis-driven migration
properties live in ``test_migration_properties.py``."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.core as C
from repro.core.encoding import (PortableDesign, SpaceDigest, from_portable,
                                 migrate, portable_signature, repair,
                                 space_digest, to_portable,
                                 feasibility_penalty)
from repro.core.network import N_FAMILIES
from repro.core.workload import (MAX_LOOPS, WL_EMBED_DIM, WL_FEATURE_DIM,
                                 embedding_delta, graph_feature_rows,
                                 workload_features, workload_signature)
from repro.explore.archive import (MANIFEST_NAME, ArchiveManifest,
                                   ManifestPolicy, ParetoArchive, TrustModel,
                                   atomic_savez, fit_trust_model)
from repro.explore.nsga import NSGAConfig
from repro.explore.service import BudgetPolicy, ExplorationService

# this module deliberately exercises the legacy explore/optimize entry
# points (now deprecation shims over repro.api) — expected warnings only
pytestmark = pytest.mark.filterwarnings("ignore:legacy entry point")

TINY_SPACE_KW = dict(max_shape=(16, 16, 4, 4, 1, 2))


def _tiny_graph(k=64):
    return C.WorkloadGraph([C.matmul("mm", 512, 512, k)], [])


def _space(graph, ch_max=2, **kw):
    spec = C.SystemSpec.build(graph, ch_max=ch_max)
    return spec, C.DesignSpace(spec, **(kw or TINY_SPACE_KW))


def _repaired_design(space, seed=0):
    return repair(jax.tree.map(
        np.asarray, C.random_design(jax.random.PRNGKey(seed), space)), space)


def assert_design_valid(d, space):
    """Every field inside its legal range for ``space`` AND zero
    feasibility penalty (chiplet-count / PE-budget constraints met)."""
    dg = space_digest(space) if not isinstance(space, SpaceDigest) else space
    W, CH, L = dg.W, dg.CH, MAX_LOOPS
    mx = np.asarray(dg.max_shape)
    nl = np.maximum(np.asarray(dg.n_loops), 1)
    sh = np.asarray(d["shape"])
    assert sh.shape == (W, 6) and sh.min() >= 1 and np.all(sh <= mx[None, :])
    sp = np.asarray(d["spatial"])
    assert np.all(sp >= 0) and np.all(sp < nl[:, None])
    for row in np.asarray(d["order"]).reshape(W * 3, L):
        assert sorted(row.tolist()) == list(range(L))
    tl = np.asarray(d["tiling"])
    assert tl.min() >= 1 and np.all(tl <= np.asarray(dg.bounds)[:, None, :])
    pipe = np.asarray(d["pipe"])
    assert np.all((pipe == L) | ((pipe >= 0) & (pipe < nl)))
    assert 0 <= int(np.asarray(d["logB"])) <= dg.max_logB
    assert 0 <= int(np.asarray(d["packaging"])) <= 2
    assert 0 <= int(np.asarray(d["family"])) < N_FAMILIES
    assert sorted(np.asarray(d["placement"]).tolist()) == list(range(W * CH))


def assert_design_feasible(d, space):
    assert_design_valid(d, space)
    pen = float(feasibility_penalty(
        space, {k: jnp.asarray(v) for k, v in d.items()}, {}))
    assert pen == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# workload identity + feature embeddings
# ---------------------------------------------------------------------------
def test_workload_signature_is_structural():
    a = C.matmul("first", 64, 64, 64)
    b = C.matmul("second", 64, 64, 64)       # same structure, other name
    c = C.matmul("first", 64, 64, 128)       # other bounds
    assert workload_signature(a) == workload_signature(b)
    assert workload_signature(a) != workload_signature(c)
    assert workload_signature(a) != workload_signature(
        C.conv2d("x", 1, 64, 64, 8, 8, 3, 3))


def test_feature_rows_and_embedding_dims():
    g = C.presets.transformer_block()
    rows = graph_feature_rows(g)
    assert rows.shape == (g.n, WL_FEATURE_DIM)
    emb = workload_features(g)
    assert emb.shape == (WL_EMBED_DIM,)
    assert np.all(np.isfinite(emb))
    # a single-workload graph lands in the SAME vector space
    assert workload_features(_tiny_graph()).shape == (WL_EMBED_DIM,)


def test_embedding_similarity_ranks_library_families():
    lib = C.presets.workload_library()
    emb = {k: workload_features(g) for k, g in lib.items()}
    d = lambda a, b: float(np.linalg.norm(emb[a] - emb[b]))
    # same-family graphs are closer than structurally alien ones
    assert d("attn_qwen2_72b", "attn_qwen2_5_32b") \
        < d("attn_qwen2_72b", "conv_whisper")
    assert d("mlp_qwen2_72b", "mlp_deepseek_v2") \
        < d("mlp_qwen2_72b", "conv_whisper")


def test_workload_library_is_diverse_and_buildable():
    lib = C.presets.workload_library()
    assert len(lib) >= 8
    sigs = set()
    for name, g in lib.items():
        spec = C.SystemSpec.build(g, ch_max=2)   # validates padding limits
        assert spec.W == g.n and g.depth() >= 2
        g.topo_order()                           # acyclic
        sigs.add(tuple(workload_signature(w) for w in g.workloads))
    assert len(sigs) == len(lib)                 # no duplicate graphs


# ---------------------------------------------------------------------------
# portable design IR
# ---------------------------------------------------------------------------
def test_space_digest_json_roundtrip():
    _, space = _space(C.presets.transformer_block())
    dg = space_digest(space)
    back = SpaceDigest.from_dict(dg.to_json_dict())
    assert back.signatures == dg.signatures
    assert back.W == dg.W and back.CH == dg.CH
    np.testing.assert_allclose(back.features, dg.features)
    np.testing.assert_array_equal(back.bounds, dg.bounds)
    assert back.max_shape == dg.max_shape
    # the dict form is accepted anywhere a space is (duck-typed digest)
    d = _repaired_design(space, seed=1)
    via_dict = migrate(d, dg.to_json_dict(), dg.to_json_dict())
    for k in d:
        np.testing.assert_array_equal(via_dict[k], d[k])


def test_repair_fixes_arbitrary_garbage():
    _, space = _space(_tiny_graph())
    W, CH, L = space.W, space.CH, MAX_LOOPS
    garbage = dict(
        shape=np.full((W, 6), 99, np.int64),
        spatial=np.full((W, 6), -3, np.int64),
        order=np.zeros((W, 3, L), np.int64),          # not a permutation
        tiling=np.full((W, 2, L), 10**9, np.int64),
        pipe=np.full((W,), 5, np.int64),              # >= n_loops (3)
        logB=np.asarray(99),
        packaging=np.asarray(-7),
        family=np.asarray(99),
        placement=np.zeros((W * CH,), np.int64))      # duplicate entries
    fixed = repair(garbage, space)
    assert_design_feasible(fixed, space)
    # idempotent
    again = repair(fixed, space)
    for k in fixed:
        np.testing.assert_array_equal(fixed[k], again[k])


def test_repair_respects_fixed_fields_and_pe_budget():
    spec, _ = _space(_tiny_graph())
    space = C.DesignSpace(spec, max_shape=(16, 16, 4, 4, 2, 2),
                          fixed_packaging=2, fixed_family=1,
                          max_total_pes=512, allow_pipeline=False)
    d = repair(jax.tree.map(
        np.asarray, C.random_design(jax.random.PRNGKey(9), space)), space)
    assert int(d["packaging"]) == 2 and int(d["family"]) == 1
    assert int(d["logB"]) == 0 and np.all(d["pipe"] == MAX_LOOPS)
    assert int(np.prod(d["shape"], axis=1).sum()) <= 512
    assert_design_feasible(d, space)


def test_migrate_roundtrip_through_superset_space():
    gA = C.presets.transformer_block()
    wls = list(gA.workloads) + [C.matmul("extra", 128, 128, 128)]
    gB = C.WorkloadGraph(wls, list(gA.edges))
    _, spA = _space(gA, ch_max=2, max_shape=(16, 16, 4, 4, 6, 6))
    _, spB = _space(gB, ch_max=4, max_shape=(16, 16, 4, 4, 6, 6))
    dA = _repaired_design(spA, seed=3)
    dB = migrate(dA, spA, spB)
    assert_design_feasible(dB, spB)
    back = migrate(dB, spB, spA)
    for k in dA:
        np.testing.assert_array_equal(back[k], dA[k])


def test_migrate_across_structurally_different_graphs():
    lib = C.presets.workload_library()
    _, src_space = _space(lib["attn_qwen2_72b"], ch_max=2)
    d = _repaired_design(src_space, seed=4)
    for name in ("attn_qwen2_5_32b", "conv_whisper", "scan_falcon_mamba"):
        _, dst_space = _space(lib[name], ch_max=3)
        out = migrate(d, src_space, dst_space)
        assert_design_feasible(out, dst_space)


def test_portable_design_record_structure():
    _, space = _space(C.presets.transformer_block())
    d = _repaired_design(space, seed=5)
    pd = to_portable(d, space)
    assert isinstance(pd, PortableDesign) and len(pd.records) == space.W
    sigs = [workload_signature(w) for w in space.spec.graph.workloads]
    assert [r["signature"] for r in pd.records] == sigs
    # duplicate workloads (the two identical heads) share a signature yet
    # keep their own records — first-unused matching maps them back 1:1
    assert sigs[0] == sigs[1]
    back = from_portable(pd, space)
    for k in d:
        np.testing.assert_array_equal(back[k], d[k])
    with pytest.raises(ValueError):
        from_portable(PortableDesign([], 0, 0, 0), space)


# ---------------------------------------------------------------------------
# cross-spec manifest + crash-safe persistence
# ---------------------------------------------------------------------------
def _entry(dim=4, seed=0, n_evals=8):
    rng = np.random.default_rng(seed)
    return dict(embedding=rng.random(dim), dims=(1, 2, 1),
                n_evals=n_evals, budget_covered=n_evals,
                searched=("latency_ns",), digest={"W": 1})


def test_manifest_roundtrip_and_nearest(tmp_path):
    m = ArchiveManifest(tmp_path / MANIFEST_NAME)
    for i in range(4):
        e = _entry(seed=i)
        m.update(f"k{i}", e["embedding"], e["dims"], e["n_evals"],
                 e["budget_covered"], e["searched"], digest={"seed": i})
    m.update("empty", np.zeros(4), (1, 1, 1), 0, 0, ())   # never searched
    m.save()
    back = ArchiveManifest.load(tmp_path / MANIFEST_NAME)
    assert len(back) == 5
    np.testing.assert_allclose(back.entries["k2"]["embedding"],
                               m.entries["k2"]["embedding"])
    assert back.entries["k3"]["digest"] == {"seed": 3}
    assert back.entries["k1"]["searched"] == ("latency_ns",)

    q = m.entries["k0"]["embedding"]
    got = back.nearest(q, k=10)
    # own entry first (distance 0), never the empty or excluded ones
    assert got[0] == ("k0", 0.0)
    assert [k for k, _ in got] == sorted(
        (k for k in back.entries if k != "empty"),
        key=lambda k: np.linalg.norm(back.entries[k]["embedding"] - q))
    assert all(k != "empty" for k, _ in got)
    got_ex = back.nearest(q, k=10, exclude=("k0",))
    assert all(k != "k0" for k, _ in got_ex) and len(got_ex) == 3
    # dimension-mismatched entries are skipped, not fatal
    back.update("odd", np.zeros(7), (1, 1, 1), 5, 5, ())
    assert all(k != "odd" for k, _ in back.nearest(q, k=10))


def test_manifest_corrupt_or_truncated_file_is_ignored(tmp_path):
    p = tmp_path / MANIFEST_NAME
    m = ArchiveManifest(p)
    m.update("k", np.ones(3), (1, 1, 1), 4, 4, ())
    m.save()
    # truncate: keep only the first few bytes of a valid npz
    p.write_bytes(p.read_bytes()[:20])
    with pytest.warns(UserWarning, match="unreadable explore manifest"):
        back = ArchiveManifest.load(p)
    assert len(back) == 0
    p.write_bytes(b"this is not an npz at all")
    with pytest.warns(UserWarning):
        assert len(ArchiveManifest.load(p)) == 0
    # absent file: silently empty
    assert len(ArchiveManifest.load(tmp_path / "nope.npz")) == 0


def test_atomic_savez_no_tmp_residue_and_archive_load(tmp_path):
    p = atomic_savez(tmp_path / "a.npz", x=np.arange(4))
    with np.load(p) as z:
        np.testing.assert_array_equal(z["x"], np.arange(4))
    assert [f.name for f in tmp_path.iterdir()] == ["a.npz"]
    # ParetoArchive.save goes through the same path
    arc = ParetoArchive(8, {"tag": np.zeros((), np.int32)}, n_obj=2)
    arc.insert({"tag": np.zeros(1, np.int32)}, np.array([[1.0, 2.0]]))
    arc.save(tmp_path / "arc.npz")
    assert sorted(f.name for f in tmp_path.iterdir()) == ["a.npz", "arc.npz"]
    assert len(ParetoArchive.load(tmp_path / "arc.npz")) == 1


def test_truncated_archive_npz_is_not_fatal_to_the_service(tmp_path):
    g = _tiny_graph()
    svc = ExplorationService(cache_dir=tmp_path,
                             nsga=NSGAConfig(pop=8, generations=2))
    r = svc.explore(g, ("latency_ns", "cost_usd"), budget=16, ch_max=2,
                    space_kwargs=TINY_SPACE_KW)
    path = svc._path(r.cache_key)
    path.write_bytes(path.read_bytes()[:30])      # simulated torn write
    fresh = ExplorationService(cache_dir=tmp_path,
                               nsga=NSGAConfig(pop=8, generations=2))
    with pytest.warns(UserWarning, match="unreadable explore cache"):
        r2 = fresh.explore(g, ("latency_ns", "cost_usd"), budget=16,
                           ch_max=2, space_kwargs=TINY_SPACE_KW)
    assert not r2.from_cache and len(r2.front_objs) >= 1


# ---------------------------------------------------------------------------
# manifest growth policy: LRU eviction, dedup, trust table
# ---------------------------------------------------------------------------
def test_manifest_lru_eviction_order(tmp_path):
    m = ArchiveManifest(tmp_path / MANIFEST_NAME,
                        policy=ManifestPolicy(max_entries=3))
    for i in range(3):
        e = _entry(seed=i)
        m.update(f"k{i}", e["embedding"], e["dims"], e["n_evals"],
                 e["budget_covered"], e["searched"], digest={})
    m.touch("k0")                          # k0 becomes most recently used
    e = _entry(seed=9)
    m.update("k3", e["embedding"], e["dims"], e["n_evals"],
             e["budget_covered"], e["searched"], digest={})
    # k1 was the least recently used — k0 was touched, k3 just written
    assert set(m.entries) == {"k0", "k2", "k3"}
    # the bound holds through further writes, oldest-first
    e = _entry(seed=10)
    m.update("k4", e["embedding"], e["dims"], e["n_evals"],
             e["budget_covered"], e["searched"], digest={})
    assert set(m.entries) == {"k0", "k3", "k4"}


def test_manifest_dedup_merges_near_identical_entries():
    m = ArchiveManifest(policy=ManifestPolicy(max_entries=8,
                                              dedup_radius=0.5))
    base = np.ones(4)
    m.update("a", base, (1, 2, 1), 32, 32, ("latency_ns",), digest={})
    # within the radius: merged.  The entry being WRITTEN survives (it is
    # protected), absorbing the max of the counters and the searched union
    m.update("b", base + 0.1, (1, 2, 1), 8, 8, ("cost_usd",), digest={})
    assert set(m.entries) == {"b"}
    ent = m.entries["b"]
    assert ent["n_evals"] == 32 and ent["budget_covered"] == 32
    assert set(ent["searched"]) == {"cost_usd", "latency_ns"}
    np.testing.assert_array_equal(ent["embedding"], base + 0.1)
    # outside the radius: both live
    m.update("c", base + 10.0, (1, 2, 1), 4, 4, (), digest={})
    assert set(m.entries) == {"b", "c"}
    # an UNPROTECTED merge (explicit dedup) keeps the better-explored twin
    m.entries["e"] = dict(embedding=base.copy(), dims=(1, 2, 1),
                          n_evals=4, budget_covered=4, searched=(),
                          digest={}, last_used=99)
    m.dedup()
    assert "e" not in m.entries and "b" in m.entries
    assert m.entries["b"]["last_used"] == 99  # freshness absorbed too


def test_manifest_v2_roundtrip_preserves_lru_and_trust(tmp_path):
    p = tmp_path / MANIFEST_NAME
    m = ArchiveManifest(p, policy=ManifestPolicy(max_entries=8))
    for i in range(3):
        e = _entry(seed=i)
        m.update(f"k{i}", e["embedding"], e["dims"], e["n_evals"],
                 e["budget_covered"], e["searched"], digest={"i": i})
    m.touch("k0")
    m.record_transfer("k1", "k0", np.arange(4, dtype=float), 0.75)
    m.save()
    back = ArchiveManifest.load(p)
    assert back.clock == m.clock
    for k in m.entries:
        assert back.entries[k]["last_used"] == m.entries[k]["last_used"]
    assert len(back.trust) == 1
    r = back.trust[0]
    assert (r["src"], r["dst"], r["lift"]) == ("k1", "k0", 0.75)
    np.testing.assert_allclose(r["delta"], np.arange(4, dtype=float))
    # LRU state survives: the next eviction decision matches in-memory
    back.policy = ManifestPolicy(max_entries=2)
    back.enforce()
    assert "k0" in back.entries              # touched last => survives


def test_manifest_save_tolerates_mixed_embedding_dims(tmp_path):
    """An embedding-layout upgrade must not wedge persistence: entries
    written under different feature dimensions save and load side by
    side (nearest() already skips the mismatched ones per query)."""
    p = tmp_path / MANIFEST_NAME
    m = ArchiveManifest(p)
    m.update("old", np.ones(4), (1, 2, 1), 8, 8, (), digest={})
    m.update("new", np.ones(9), (1, 2, 1), 8, 8, (), digest={})
    m.save()
    back = ArchiveManifest.load(p)
    assert back.entries["old"]["embedding"].shape == (4,)
    assert back.entries["new"]["embedding"].shape == (9,)
    assert [k for k, _ in back.nearest(np.ones(9), k=5)] == ["new"]


def test_manifest_trust_records_are_bounded():
    m = ArchiveManifest(policy=ManifestPolicy(max_trust_records=5))
    for i in range(12):
        m.record_transfer(f"s{i}", "d", np.zeros(3), 0.5)
    assert len(m.trust) == 5
    assert m.trust[0]["src"] == "s7"         # oldest rolled off


def test_trust_model_fit_predict_and_reweighting():
    rng = np.random.default_rng(0)
    m = ArchiveManifest(policy=ManifestPolicy())
    # near sources helped (lift ~1), far sources didn't (lift ~0)
    for i in range(8):
        m.record_transfer(f"near{i}", "d", rng.random(4) * 0.1, 0.9)
        m.record_transfer(f"far{i}", "d", 2.0 + rng.random(4), 0.1)
    tm = m.trust_model(dim=4)
    assert isinstance(tm, TrustModel)
    assert tm.predict(np.zeros(4)) > tm.predict(np.full(4, 2.5))
    # dimension-mismatched deltas predict neutral, never raise
    assert tm.predict(np.zeros(7)) == 0.0
    # too few records => no model
    assert fit_trust_model(m.trust[:2]) is None
    # trust-weighted nearest can ONLY pull trusted entries closer: the
    # reweighted distance is <= the raw distance
    m.update("e1", np.zeros(4), (1, 2, 1), 8, 8, (), digest={})
    m.update("e2", np.full(4, 3.0), (1, 2, 1), 8, 8, (), digest={})
    q = np.full(4, 0.05)
    raw = dict(m.nearest(q, k=2))
    wtd = dict(m.nearest(q, k=2, trust=tm))
    assert set(raw) == set(wtd) == {"e1", "e2"}
    for k in raw:
        assert wtd[k] <= raw[k] + 1e-12


def test_trust_model_predict_clamps_negative_lift():
    """Regression: an adversarial weight vector (large negative slopes)
    used to drive predicted lift below -1, and nearest()'s
    dist / (1 + lift) reweighting would flip or explode the ranking.
    predict() now clamps at 0 as its docstring always promised."""
    tm = TrustModel(weights=np.array([0.5, -10.0, -10.0, -10.0, -10.0]))
    assert tm.predict(np.full(4, 5.0)) == 0.0
    assert tm.predict(np.zeros(4)) == 0.5
    # reweighted distance stays finite, positive and monotone even for
    # deltas far outside the fitted range
    m = ArchiveManifest(policy=ManifestPolicy())
    m.update("a", np.zeros(4), (1, 2, 1), 8, 8, (), digest={})
    m.update("b", np.full(4, 8.0), (1, 2, 1), 8, 8, (), digest={})
    out = dict(m.nearest(np.full(4, 7.0), k=2, trust=tm))
    assert all(np.isfinite(v) and v >= 0.0 for v in out.values())
    assert out["b"] < out["a"]


def test_fit_trust_model_uses_modal_dim():
    """Regression: dim used to default to the LAST record's delta size,
    so one drifted-layout straggler filtered out the whole majority-dim
    history.  The modal dim wins now; the straggler is skipped (and
    counted on explore.trust.skipped_records)."""
    rng = np.random.default_rng(1)
    records = [{"src": f"s{i}", "dst": "d",
                "delta": rng.random(4), "lift": 0.5}
               for i in range(6)]
    records.append({"src": "drift", "dst": "d",
                    "delta": rng.random(9), "lift": 0.5})
    tm = fit_trust_model(records)
    assert isinstance(tm, TrustModel)
    assert tm.weights.shape == (5,)              # fitted on the 4-dim majority
    # a 2-vs-2 count tie breaks toward the freshest layout (9-dim, last)
    tied = records[:2] + [{"src": "n1", "dst": "d",
                           "delta": rng.random(9), "lift": 0.4},
                          {"src": "n2", "dst": "d",
                           "delta": rng.random(9), "lift": 0.6}]
    tm2 = fit_trust_model(tied, min_records=2)
    assert tm2 is not None and tm2.weights.shape == (10,)


def test_embedding_delta_symmetric_and_zero_on_match():
    lib = C.presets.workload_library()
    a = workload_features(lib["attn_qwen2_72b"])
    b = workload_features(lib["conv_whisper"])
    np.testing.assert_allclose(embedding_delta(a, b), embedding_delta(b, a))
    assert np.all(embedding_delta(a, a) == 0.0)
    assert np.all(embedding_delta(a, b) >= 0.0)
    assert embedding_delta(a, b).shape == (WL_EMBED_DIM,)


def test_portable_signature_identity_and_sensitivity():
    _, space = _space(C.presets.transformer_block())
    d = _repaired_design(space, seed=7)
    sig = portable_signature(d, space)
    # migration through the same space is the identity => same signature
    assert portable_signature(migrate(d, space, space), space) == sig
    # any field change changes the signature
    d2 = {k: np.array(v) for k, v in d.items()}
    d2["shape"][0, 0] = 2 if int(d2["shape"][0, 0]) == 1 \
        else int(d2["shape"][0, 0]) - 1
    assert portable_signature(d2, space) != sig
    d3 = {k: np.array(v) for k, v in d.items()}
    d3["packaging"] = np.asarray((int(d3["packaging"]) + 1) % 3)
    assert portable_signature(d3, space) != sig


# ---------------------------------------------------------------------------
# the service's transfer warm-start path
# ---------------------------------------------------------------------------
def test_transfer_seeds_cold_query_from_neighbor_archive(tmp_path):
    mk = lambda: ExplorationService(cache_dir=tmp_path,
                                    nsga=NSGAConfig(pop=8, generations=2))
    svc = mk()
    r1 = svc.explore(_tiny_graph(64), ("latency_ns", "cost_usd"), budget=16,
                     ch_max=2, space_kwargs=TINY_SPACE_KW)
    assert not r1.from_cache
    assert r1.cache_key in svc.manifest.entries          # indexed on save
    ent = svc.manifest.entries[r1.cache_key]
    assert ent["n_evals"] == r1.n_evals_run
    assert ent["digest"] is not None

    # never-seen graph, transfer on: seeded from the neighbor's front
    r2 = svc.explore(_tiny_graph(96), ("latency_ns", "cost_usd"), budget=16,
                     ch_max=2, space_kwargs=TINY_SPACE_KW, transfer=True)
    assert not r2.from_cache
    assert r2.transferred_from == (r1.cache_key,)
    assert r2.n_transfer_seeds >= 1
    assert len(r2.front_objs) >= 1

    # the manifest survives the disk round-trip: a NEW service transfers too
    r3 = mk().explore(_tiny_graph(128), ("latency_ns", "cost_usd"),
                      budget=16, ch_max=2, space_kwargs=TINY_SPACE_KW,
                      transfer=True)
    assert len(r3.transferred_from) >= 1

    # transfer=False never seeds
    r4 = svc.explore(_tiny_graph(160), ("latency_ns", "cost_usd"),
                     budget=16, ch_max=2, space_kwargs=TINY_SPACE_KW)
    assert r4.transferred_from == () and r4.n_transfer_seeds == 0


def test_transfer_falls_back_to_balanced_init(tmp_path):
    svc = ExplorationService(cache_dir=tmp_path,
                             nsga=NSGAConfig(pop=8, generations=2))
    r = svc.explore(_tiny_graph(), ("latency_ns", "cost_usd"), budget=16,
                    ch_max=2, space_kwargs=TINY_SPACE_KW, transfer=True)
    assert not r.from_cache
    assert r.transferred_from == ()
    assert r.n_transfer_seeds == 1            # the balanced_init seed
    assert len(r.front_objs) >= 1


def test_transfer_warm_hit_short_circuits(tmp_path):
    """A budget-covered archive is still served straight from cache —
    transfer only changes COLD starts."""
    svc = ExplorationService(cache_dir=tmp_path,
                             nsga=NSGAConfig(pop=8, generations=2))
    g = _tiny_graph()
    svc.explore(g, ("latency_ns", "cost_usd"), budget=16, ch_max=2,
                space_kwargs=TINY_SPACE_KW)
    r = svc.explore(g, ("latency_ns", "cost_usd"), budget=16, ch_max=2,
                    space_kwargs=TINY_SPACE_KW, transfer=True)
    assert r.from_cache and r.n_evals_run == 0
    assert r.transferred_from == () and r.n_transfer_seeds == 0


# ---------------------------------------------------------------------------
# transfer v2: warm-archive seeding, seed dedup, stale-manifest reload
# ---------------------------------------------------------------------------
def test_warm_archive_refinement_takes_transfer_seeds(tmp_path):
    """A budget-increase refinement of a half-explored problem is seeded
    from neighbors its archive has never seen — not just cold starts —
    and the outcome lands in the trust table."""
    svc = ExplorationService(cache_dir=tmp_path,
                             nsga=NSGAConfig(pop=8, generations=2))
    neighbor = svc.explore(_tiny_graph(64), ("latency_ns", "cost_usd"),
                           budget=32, ch_max=2, space_kwargs=TINY_SPACE_KW)
    half = svc.explore(_tiny_graph(96), ("latency_ns", "cost_usd"),
                       budget=16, ch_max=2, space_kwargs=TINY_SPACE_KW)
    assert not half.from_cache
    r = svc.explore(_tiny_graph(96), ("latency_ns", "cost_usd"), budget=48,
                    ch_max=2, space_kwargs=TINY_SPACE_KW, transfer=True)
    assert not r.from_cache                  # resumed, not served stale
    assert r.transferred_from == (neighbor.cache_key,)
    assert 1 <= r.n_transfer_seeds <= svc.nsga.pop // 2
    assert any(t["src"] == neighbor.cache_key
               and t["dst"] == r.cache_key
               and 0.0 <= t["lift"] <= 1.0 for t in svc.manifest.trust)
    assert half.cache_key == r.cache_key


def test_warm_refinement_with_own_front_injects_nothing(tmp_path):
    """Regression: offered its OWN archive front as neighbor seeds, a
    resumed problem must inject zero duplicates — and the refinement must
    behave exactly as if transfer was never requested (same PRNG path,
    identical resumed front)."""
    import shutil
    g = _tiny_graph(64)
    dirs = {}
    for tag in ("twin", "plain"):
        dirs[tag] = tmp_path / tag
    svc0 = ExplorationService(cache_dir=dirs["twin"],
                              nsga=NSGAConfig(pop=8, generations=2))
    r0 = svc0.explore(g, ("latency_ns", "cost_usd"), budget=16, ch_max=2,
                      space_kwargs=TINY_SPACE_KW,
                      key=jax.random.PRNGKey(3))
    # forge a same-content twin entry: the problem's own archive under a
    # different key, same digest, same embedding => every migrated seed
    # is a duplicate of the resumed front
    ck = r0.cache_key
    ent = svc0.manifest.entries[ck]
    shutil.copy(svc0._path(ck), dirs["twin"] / "feedbeefdeadbeef0000.npz")
    svc0.manifest.update("feedbeefdeadbeef0000", ent["embedding"],
                         (2, 2, 1), ent["n_evals"], ent["budget_covered"],
                         ent["searched"], digest=ent["digest"])
    svc0.manifest.save()
    shutil.copytree(dirs["twin"], dirs["plain"])

    mk = lambda d: ExplorationService(cache_dir=d,
                                      nsga=NSGAConfig(pop=8, generations=2))
    rt = mk(dirs["twin"]).explore(
        g, ("latency_ns", "cost_usd"), budget=48, ch_max=2,
        space_kwargs=TINY_SPACE_KW, transfer=True,
        key=jax.random.PRNGKey(5))
    rp = mk(dirs["plain"]).explore(
        g, ("latency_ns", "cost_usd"), budget=48, ch_max=2,
        space_kwargs=TINY_SPACE_KW, transfer=False,
        key=jax.random.PRNGKey(5))
    # zero duplicate seeds injected, no neighbor credited, no balanced
    # fallback on a resumed archive ...
    assert rt.n_transfer_seeds == 0 and rt.transferred_from == ()
    # ... and the resumed front (hence its hypervolume) is bit-identical
    # to the transfer-free refinement
    np.testing.assert_array_equal(rt.front_objs, rp.front_objs)
    np.testing.assert_array_equal(rt.trace.archive_hv, rp.trace.archive_hv)


def test_second_service_sees_fresh_manifest_before_acting(tmp_path):
    """Regression (stale manifest): service B loads the manifest, then
    service A indexes new problems; B's next manifest access must see
    A's writes (mtime-checked reload), so B's eviction decisions and
    transfer lookups never act on a stale index."""
    pol = ManifestPolicy(max_entries=8)
    a = ExplorationService(cache_dir=tmp_path, manifest_policy=pol,
                           nsga=NSGAConfig(pop=8, generations=2))
    b = ExplorationService(cache_dir=tmp_path, manifest_policy=pol,
                           nsga=NSGAConfig(pop=8, generations=2))
    assert len(b.manifest) == 0              # B loaded the (empty) index
    ra = a.explore(_tiny_graph(64), ("latency_ns", "cost_usd"), budget=16,
                   ch_max=2, space_kwargs=TINY_SPACE_KW)
    # B sees A's write without any B-side query in between
    assert ra.cache_key in b.manifest.entries
    # ... and B's transfer query finds A's archive as a neighbor
    rb = b.explore(_tiny_graph(96), ("latency_ns", "cost_usd"), budget=16,
                   ch_max=2, space_kwargs=TINY_SPACE_KW, transfer=True)
    assert rb.transferred_from == (ra.cache_key,)
    # the same-object fast path still holds while nothing changed on disk
    assert b.manifest is b.manifest


# ---------------------------------------------------------------------------
# transferred seed populations in the scalarized engines
# ---------------------------------------------------------------------------
def test_optimize_accepts_transferred_seed_population(tmp_path):
    src_spec, src_space = _space(_tiny_graph(64))
    dst_spec, dst_space = _space(_tiny_graph(96))
    seeds = [migrate(_repaired_design(src_space, seed=s), src_space,
                     dst_space) for s in range(2)]
    r = C.optimize(dst_spec, dst_space, jax.random.PRNGKey(0), bo_fields=(),
                   n_init=2, sa=C.SAConfig(steps=10, chains=2),
                   seed_designs=seeds)
    assert np.isfinite(r.objective)
    assert len(r.history) == 2
