"""Substrate tests: data pipeline determinism, AdamW, gradient compression,
checkpoint save/restore (incl. corruption + crash recovery), the
fault-tolerant driver, the straggler monitor, and pipeline parallelism
(subprocess with 8 virtual devices)."""

import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.compress import (compress_grads, decompress_grads,
                                  init_error_state)
from repro.runtime.driver import FaultTolerantTrainer, TransientError
from repro.runtime.straggler import StragglerMonitor


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_data_deterministic_and_resumable():
    cfg = DataConfig(vocab=97, seq_len=32, global_batch=8)
    a = SyntheticLM(cfg)
    b = SyntheticLM(cfg)
    for s in (0, 5, 1000):
        np.testing.assert_array_equal(a.batch_at(s)["tokens"],
                                      b.batch_at(s)["tokens"])
    assert not np.array_equal(a.batch_at(1)["tokens"],
                              a.batch_at(2)["tokens"])


def test_data_host_sharding_disjoint():
    full = SyntheticLM(DataConfig(vocab=97, seq_len=16, global_batch=8))
    h0 = SyntheticLM(DataConfig(vocab=97, seq_len=16, global_batch=8,
                                host_id=0, n_hosts=2))
    h1 = SyntheticLM(DataConfig(vocab=97, seq_len=16, global_batch=8,
                                host_id=1, n_hosts=2))
    assert h0.host_batch == 4 and h1.host_batch == 4
    assert not np.array_equal(h0.batch_at(0)["tokens"],
                              h1.batch_at(0)["tokens"])


def test_data_labels_are_shifted_tokens():
    d = SyntheticLM(DataConfig(vocab=97, seq_len=16, global_batch=2))
    b = d.batch_at(0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert b["loss_mask"][:, -1].sum() == 0


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
def test_adamw_converges_on_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      schedule="constant", moment_dtype="float32")
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(cfg, params)
    for _ in range(200):
        g = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(cfg, g, opt, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_bf16_moments_still_converge():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      schedule="constant", moment_dtype="bfloat16")
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(cfg, params)
    assert opt["mu"]["w"].dtype == jnp.bfloat16
    for _ in range(300):
        g = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(cfg, g, opt, params)
    assert float(jnp.abs(params["w"]).max()) < 5e-2


def test_grad_clip_limits_update_norm():
    cfg = AdamWConfig(lr=1.0, clip_norm=1e-3, weight_decay=0.0,
                      warmup_steps=0, schedule="constant")
    params = {"w": jnp.ones(4)}
    opt = adamw_init(cfg, params)
    _, _, m = adamw_update(cfg, {"w": jnp.full(4, 1e6)}, opt, params)
    assert float(m["grad_norm"]) > 1e5     # raw norm reported


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------
def test_int8_compression_with_error_feedback_converges():
    """SGD on a quadratic with int8-compressed grads + error feedback must
    still converge (the error-feedback convergence guarantee)."""
    w = jnp.asarray([4.0, -2.0, 1.0])
    err = init_error_state({"w": w})
    lr = 0.05
    for _ in range(300):
        g = {"w": 2 * w}
        q, s, err = compress_grads(g, err)
        deq = decompress_grads(q, s)
        w = w - lr * deq["w"]
    assert float(jnp.abs(w).max()) < 1e-2


def test_int8_quantization_bounded_error():
    x = jnp.linspace(-3, 3, 101)
    from repro.optim.compress import quantize_int8, dequantize_int8
    q, s = quantize_int8(x)
    assert q.dtype == jnp.int8
    assert float(jnp.abs(dequantize_int8(q, s) - x).max()) <= float(s) / 2 + 1e-6


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------
def _tiny_state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (4, 3)),
                       "b": jnp.zeros(3)},
            "opt": {"step": jnp.asarray(7, jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    cm = CheckpointManager(tmp_path)
    st = _tiny_state()
    cm.save(10, st, blocking=True)
    assert cm.latest_step() == 10
    out = cm.restore(10, jax.eval_shape(lambda: st))
    np.testing.assert_allclose(out["params"]["w"], st["params"]["w"])
    assert int(out["opt"]["step"]) == 7


def test_checkpoint_async_and_gc(tmp_path):
    cm = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        cm.save(s, _tiny_state(s))
    cm.wait()
    assert cm.latest_step() == 4
    steps = sorted(int(p.name.split("_")[1])
                   for p in Path(tmp_path).glob("step_*"))
    assert len(steps) <= 2


def test_checkpoint_skips_torn_save(tmp_path):
    cm = CheckpointManager(tmp_path)
    cm.save(5, _tiny_state(), blocking=True)
    torn = Path(tmp_path) / "step_000000009"
    torn.mkdir()
    (torn / "meta.json").write_text("{}")      # no COMMIT marker
    assert cm.latest_step() == 5


def test_checkpoint_detects_corruption(tmp_path):
    cm = CheckpointManager(tmp_path)
    st = _tiny_state()
    cm.save(3, st, blocking=True)
    d = Path(tmp_path) / "step_000000003"
    flat = dict(np.load(d / "shard_00000.npz"))
    flat["params/w"] = flat["params/w"] + 1.0
    np.savez(d / "shard_00000.npz", **flat)
    with pytest.raises(IOError):
        cm.restore(3, jax.eval_shape(lambda: st))


# ---------------------------------------------------------------------------
# fault-tolerant driver
# ---------------------------------------------------------------------------
def _toy_problem():
    def loss(params, batch):
        pred = batch["x"] @ params["w"]
        return jnp.mean((pred - batch["y"]) ** 2)

    def train_step(state, batch):
        l, g = jax.value_and_grad(loss)(state["params"], batch)
        params = jax.tree.map(lambda p, gg: p - 0.1 * gg,
                              state["params"], g)
        return {"params": params}, {"loss": l}

    rng = np.random.default_rng(0)
    w_true = rng.normal(size=(3, 1))

    def batch_at(step):
        r = np.random.default_rng(step)
        x = r.normal(size=(16, 3)).astype(np.float32)
        return {"x": jnp.asarray(x),
                "y": jnp.asarray(x @ w_true, jnp.float32)}

    state = {"params": {"w": jnp.zeros((3, 1))}}
    return train_step, batch_at, state


def test_driver_trains_and_checkpoints(tmp_path):
    step_fn, batch_at, state = _toy_problem()
    tr = FaultTolerantTrainer(step_fn, CheckpointManager(tmp_path),
                              ckpt_every=10)
    rep, state = tr.run(state, batch_at, num_steps=40)
    assert rep.losses[-1] < rep.losses[0] * 0.2
    assert tr.ckpt.latest_step() is not None


def test_driver_recovers_from_transient_faults(tmp_path):
    step_fn, batch_at, state = _toy_problem()
    boom = {25}

    def fault(step):
        if step in boom:
            boom.clear()
            raise TransientError("injected")

    tr = FaultTolerantTrainer(step_fn, CheckpointManager(tmp_path),
                              ckpt_every=10, fault_hook=fault)
    rep, state = tr.run(state, batch_at, num_steps=40)
    assert rep.restarts == 1
    assert rep.end_step == 40


def test_driver_resumes_across_process_restart(tmp_path):
    """Simulated crash: run 20 steps, drop everything, build a fresh driver
    from the same directory — it must resume from the checkpoint."""
    step_fn, batch_at, state = _toy_problem()
    tr1 = FaultTolerantTrainer(step_fn, CheckpointManager(tmp_path),
                               ckpt_every=5)
    rep1, _ = tr1.run(state, batch_at, num_steps=20)

    step_fn2, batch_at2, fresh = _toy_problem()
    tr2 = FaultTolerantTrainer(step_fn2, CheckpointManager(tmp_path),
                               ckpt_every=5)
    rep2, final = tr2.run(fresh, batch_at2, num_steps=10)
    assert rep2.start_step == 20            # resumed, not restarted
    assert rep2.losses[0] < rep1.losses[0]  # picked up trained weights


# ---------------------------------------------------------------------------
# straggler monitor
# ---------------------------------------------------------------------------
def test_straggler_flags_outliers_only():
    m = StragglerMonitor()
    flags = [m.observe(i, 0.1 + 0.001 * (i % 3)) for i in range(30)]
    assert not any(flags)
    assert m.observe(30, 1.5)               # 15x the mean
    assert not m.observe(31, 0.1)


# ---------------------------------------------------------------------------
# pipeline parallelism (needs multiple devices -> subprocess)
# ---------------------------------------------------------------------------
PIPE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.parallel.pipeline import pipeline_forward, split_stages

mesh = jax.make_mesh((4,), ("stage",))
L, D, MB, M = 8, 16, 4, 8
key = jax.random.PRNGKey(0)
params = {"w": jax.random.normal(key, (L, D, D)) * (1.0 / np.sqrt(D))}

def layer(p_l, x):
    return jnp.tanh(x @ p_l)

def stage_fn(p_stage, x):            # apply this stage's layer group
    def body(x, w):
        return layer(w, x), None
    y, _ = jax.lax.scan(body, x, p_stage["w"])
    return y

x = jax.random.normal(jax.random.PRNGKey(1), (M, MB, D))

# sequential reference
def seq(params, xs):
    def body(x, w):
        return layer(w, x), None
    out = []
    for i in range(M):
        y, _ = jax.lax.scan(body, xs[i], params["w"])
        out.append(y)
    return jnp.stack(out)

ref = seq(params, x)
staged = split_stages(params, L, 4)
with mesh:
    out = pipeline_forward(stage_fn, mesh, "stage", staged, x)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5,
                           rtol=1e-5)

# differentiability: grads must match the sequential program
def loss_pipe(p):
    with mesh:
        return jnp.sum(pipeline_forward(stage_fn, mesh, "stage",
                                        split_stages(p, L, 4), x) ** 2)
def loss_seq(p):
    return jnp.sum(seq(p, x) ** 2)
g1 = jax.grad(loss_pipe)(params)["w"]
g2 = jax.grad(loss_seq)(params)["w"]
np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4,
                           rtol=1e-4)
print("PIPELINE_OK")
"""


def test_pipeline_parallel_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    r = subprocess.run([sys.executable, "-c", PIPE_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=500)
    assert "PIPELINE_OK" in r.stdout, r.stdout + r.stderr
