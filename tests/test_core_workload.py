"""Unit tests for the workload IR and the Map/Bind/Reduce mapping formalism,
including element-level validation of the Omega transfer-volume closed form.
"""

import numpy as np
import pytest
pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.workload import (Edge, WorkloadGraph, contraction, conv2d,
                                 matmul, mttkrp)
from repro.core import mapping


def test_matmul_ir():
    w = matmul("mm", 4, 5, 6)
    assert w.macs == 4 * 5 * 6
    assert w.flops == 2 * w.macs
    assert w.tensor_size("A") == 24
    assert w.tensor_size("B") == 30
    assert w.tensor_size("C") == 20
    arr = w.to_arrays()
    assert arr["bounds"][:3].tolist() == [4, 5, 6]
    assert arr["loopmask"].sum() == 3
    assert arr["is_out"].tolist()[:3] == [False, False, True]


def test_conv_footprint_sliding_window():
    w = conv2d("cv", N=1, K=2, C=3, P=4, Q=5, R=3, S=3)
    # input footprint: N * C * (P+R-1) * (Q+S-1)
    assert w.tensor_size("I") == 1 * 3 * (4 + 3 - 1) * (5 + 3 - 1)
    assert w.tensor_size("W") == 2 * 3 * 3 * 3
    assert w.tensor_size("O") == 1 * 2 * 4 * 5


def test_mttkrp_three_inputs():
    w = mttkrp("mk", 4, 5, 6, 7)
    assert w.macs == 4 * 5 * 6 * 7
    assert w.flops_per_instance == 3


def test_graph_external_and_final():
    g = WorkloadGraph(
        [matmul("a", 4, 4, 4), matmul("b", 4, 4, 4)],
        [Edge(0, 1, "C", "A")])
    ext = g.external_inputs()
    assert (0, "A") in ext and (0, "B") in ext and (1, "B") in ext
    assert (1, "A") not in ext
    assert g.final_outputs() == [(1, "C")]
    assert g.topo_order() == [0, 1]


def test_graph_cycle_detection():
    with pytest.raises(ValueError):
        WorkloadGraph(
            [matmul("a", 2, 2, 2), matmul("b", 2, 2, 2)],
            [Edge(0, 1, "C", "A"), Edge(1, 0, "C", "A")]).topo_order()


# ---------------------------------------------------------------------------
# Map / Bind / Reduce + Omega (element-level oracle for the fast evaluator)
# ---------------------------------------------------------------------------
def test_map_instances_modulo():
    w = matmul("mm", 4, 4, 2)
    cl = mapping.Cluster({"pe": (2, 2)})
    coords = mapping.map_instances(w, cl, {"pe": ("i", "j")})
    inst = mapping.enumerate_instances(w)
    assert np.all(coords[:, 0] == inst[:, 0] % 2)
    assert np.all(coords[:, 1] == inst[:, 1] % 2)


def test_reduce_gathers_by_core():
    w = matmul("mm", 4, 4, 1)
    cl = mapping.Cluster({"core": (2, 2)})
    coords = mapping.map_instances(w, cl, {"core": ("i", "j")})
    groups = mapping.reduce_graph(coords)
    assert len(groups) == 4
    assert sum(len(v) for v in groups.values()) == w.macs


@given(m=st.integers(2, 6), n=st.integers(2, 6), k=st.integers(2, 6),
       n2=st.integers(2, 6))
@settings(max_examples=20, deadline=None)
def test_omega_matches_tensor_size(m, n, k, n2):
    """|Omega| (element-level last-writer -> first-reader pairs) equals the
    producer tensor size used by the fast evaluator as transfer volume."""
    a = matmul("a", m, n, k)
    b = matmul("b", m, n2, n)          # consumes a's C as its A (m x n)
    pairs = mapping.omega(a, b, "C", "A")
    assert len(pairs) == a.tensor_size("C")
    g = WorkloadGraph([a, b], [Edge(0, 1, "C", "A")])
    assert g.transfer_elems(g.edges[0]) == len(pairs)


def test_omega_orders_last_writer_first_reader():
    a = matmul("a", 2, 2, 3)
    b = matmul("b", 2, 2, 2)
    pairs = mapping.omega(a, b, "C", "A")
    inst_a = mapping.enumerate_instances(a)
    inst_b = mapping.enumerate_instances(b)
    for wi, ri in pairs:
        # writer is the LAST k-instance (k = bound-1)
        assert inst_a[wi][2] == 2
        # reader is the FIRST instance touching that element
        el = tuple(inst_a[wi][:2])
        earlier = [j for j in range(ri)
                   if (inst_b[j][0], inst_b[j][2]) == el]
        assert not earlier


def test_bind_sequence():
    bmap = mapping.bind([(0, 0), (0, 1)], [2, 3])
    assert bmap[(0, 0)] == 2 and bmap[(0, 1)] == 3
