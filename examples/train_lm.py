"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps
on synthetic data with the production stack — AdamW, grouped-remat scan,
fault-tolerant driver, async sharded checkpoints (and resume).

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--arch stablelm-1.6b]

By default builds a ~100M reduced-depth qwen2-class model so a few hundred
steps run on this CPU container; pass --full-arch to train any registry
config if you have the hardware.
"""

import argparse
import dataclasses

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.train import make_train_state, make_train_step
from repro.models.config import ModelConfig
from repro.models.model import build_model
from repro.optim.adamw import AdamWConfig
from repro.runtime.driver import FaultTolerantTrainer


def model_100m() -> ModelConfig:
    return ModelConfig(
        name="lm-100m", family="dense", n_layers=8, d_model=768,
        n_heads=12, n_kv_heads=4, head_dim=64, d_ff=3072, vocab=32000,
        remat="none")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="artifacts/train_lm_ckpt")
    args = ap.parse_args()

    cfg = model_100m()
    print(f"model: {cfg.name}, {cfg.param_count()/1e6:.0f}M params")
    model = build_model(cfg)
    opt_cfg = AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
    state = make_train_state(model, jax.random.PRNGKey(0), opt_cfg)
    step = make_train_step(model, opt_cfg)

    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.batch))
    trainer = FaultTolerantTrainer(step, CheckpointManager(args.ckpt_dir),
                                   ckpt_every=100)
    report, state = trainer.run(
        state, lambda s: {k: jax.numpy.asarray(v)
                          for k, v in data.batch_at(s).items()},
        num_steps=args.steps)
    print(f"\ntrained steps {report.start_step}..{report.end_step}: "
          f"loss {report.losses[0]:.3f} -> {report.losses[-1]:.3f} "
          f"({report.wall_s:.0f}s, restarts={report.restarts}, "
          f"stragglers={len(report.straggler_steps)})")
    assert report.losses[-1] < report.losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
