"""Level-B example: the Monad engine advising the distribution layout for a
(architecture x input shape) cell on the production mesh.

    PYTHONPATH=src python examples/autoshard.py --arch qwen2-72b --shape train_4k
"""

import argparse

from repro.autosharding.advisor import bo_search, exhaustive_best
from repro.configs import ALIASES, get_config
from repro.models.config import SHAPES


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-72b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--chips", type=int, default=256)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    sc = SHAPES[args.shape]
    plan, score, scored = exhaustive_best(cfg, sc, chips=args.chips)
    print(f"cell: {cfg.name} x {sc.name} on {args.chips} chips "
          f"({sum(1 for _, s in scored if s.feasible)}/{len(scored)} "
          f"feasible layouts)")
    print(f"best layout: dp={plan.data} tp={plan.model} "
          f"pp={plan.pipeline_stages} microbatch={plan.microbatch} "
          f"remat={plan.remat} fsdp={plan.fsdp} decode_kv={plan.decode_kv}")
    print(f"predicted step: {score.step_s*1e3:.1f} ms  "
          f"(compute {score.compute_s*1e3:.1f} / memory "
          f"{score.memory_s*1e3:.1f} / collective "
          f"{score.collective_s*1e3:.1f}; HBM {score.hbm_gb:.1f} GB/chip)")

    bp, bs, n, _ = bo_search(cfg, sc, chips=args.chips, budget=24)
    print(f"BO (paper Sec. IV-C engine): reaches "
          f"{bs.step_s/score.step_s:.2f}x the optimum in {n} evaluations "
          f"of {len(scored)} layouts")


if __name__ == "__main__":
    main()
