"""Quickstart: co-design a chiplet-based accelerator for a Transformer
block with Monad (paper Fig. 4 workload, EDP objective) through the
declarative ``repro.api`` front door, then print the chosen design and
its PPA + cost breakdown.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

import repro.core as C
from repro.api import Problem, Query, Session
from repro.core.constants import PACKAGING_NAMES
from repro.core.optimizer import SAConfig


def main():
    # 1. the workload graph: 2 attention heads = 5 matmuls (paper Fig. 4a)
    graph = C.presets.transformer_block(seq=512, d=512, heads=2)
    print("workload graph:")
    for i, w in enumerate(graph.workloads):
        print(f"  [{i}] {w.name}: {dict(w.loops)} ({w.macs/1e6:.0f} MMACs)")
    for e in graph.edges:
        print(f"  edge {e.src} -> {e.dst} ({e.tensor_src}->{e.tensor_dst}, "
              f"{graph.transfer_elems(e)} elems)")

    # 2. one declarative query: the scalarized BO x SA engine under the
    # EDP weighting (the nested engine of paper Fig. 6b)
    problem = Problem(graph, objectives=("latency_ns", "energy_pj"),
                      ch_max=6, space_kwargs=dict(max_total_pes=4096))
    query = Query(problem, engine="bo_sa", weights=C.OBJ_EDP,
                  engine_opts=dict(n_init=4, n_iter=8,
                                   sa=SAConfig(steps=250, chains=4)))
    session = Session()
    print(f"\nplan: {session.plan(query)}")
    res = session.submit(query)

    # 3. inspect the winner (one unified Result whatever engine ran)
    d, m = res.best_design, res.best_metrics
    print("\nchosen design:")
    shape = np.asarray(d["shape"])
    for i, w in enumerate(graph.workloads):
        print(f"  {w.name}: PEs {shape[i,0]}x{shape[i,1]}, cores "
              f"{shape[i,2]}x{shape[i,3]}, chiplets {shape[i,4]}x{shape[i,5]}")
    print(f"  packaging: {PACKAGING_NAMES[int(np.asarray(d['packaging']))]}"
          f", network family: {int(np.asarray(d['family']))}"
          f", pipeline ticks: {2**int(np.asarray(d['logB']))}")
    print("\nmetrics:")
    for k in ("latency_ns", "energy_pj", "edp", "cost_usd", "area_mm2",
              "utilization"):
        print(f"  {k:14s} {float(m[k]):.4g}")
    t = res.trace
    print(f"  search objective improved {t.best[0] - t.best[-1]:.2f} nats "
          f"over {t.generations} rounds "
          f"({res.provenance.n_evals_run} evaluations)")


if __name__ == "__main__":
    main()
