"""Quickstart: explore a chiplet-based accelerator for a Transformer block
with Monad (paper Fig. 4 workload, EDP objective), then print the chosen
design and its PPA + cost breakdown.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

import repro.core as C
from repro.core.constants import PACKAGING_NAMES
from repro.core.optimizer import SAConfig, optimize


def main():
    # 1. the workload graph: 2 attention heads = 5 matmuls (paper Fig. 4a)
    graph = C.presets.transformer_block(seq=512, d=512, heads=2)
    print("workload graph:")
    for i, w in enumerate(graph.workloads):
        print(f"  [{i}] {w.name}: {dict(w.loops)} ({w.macs/1e6:.0f} MMACs)")
    for e in graph.edges:
        print(f"  edge {e.src} -> {e.dst} ({e.tensor_src}->{e.tensor_dst}, "
              f"{graph.transfer_elems(e)} elems)")

    # 2. co-optimize architecture + integration (nested BO x SA engine)
    spec = C.SystemSpec.build(graph, ch_max=6)
    space = C.DesignSpace(spec, max_total_pes=4096)
    res = optimize(spec, space, jax.random.PRNGKey(0), weights=C.OBJ_EDP,
                   n_init=4, n_iter=8, sa=SAConfig(steps=250, chains=4))

    # 3. inspect the winner
    d, m = res.design, res.metrics
    print("\nchosen design:")
    shape = np.asarray(d["shape"])
    for i, w in enumerate(graph.workloads):
        print(f"  {w.name}: PEs {shape[i,0]}x{shape[i,1]}, cores "
              f"{shape[i,2]}x{shape[i,3]}, chiplets {shape[i,4]}x{shape[i,5]}")
    print(f"  packaging: {PACKAGING_NAMES[int(np.asarray(d['packaging']))]}"
          f", network family: {int(np.asarray(d['family']))}"
          f", pipeline ticks: {2**int(np.asarray(d['logB']))}")
    print("\nmetrics:")
    for k in ("latency_ns", "energy_pj", "edp", "cost_usd", "area_mm2",
              "utilization"):
        print(f"  {k:14s} {float(m[k]):.4g}")
    print(f"  search objective improved "
          f"{res.history[0][1] - res.history[-1][1]:.2f} nats over "
          f"{len(res.history)} rounds")


if __name__ == "__main__":
    main()
