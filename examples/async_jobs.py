"""Async serving example: submit exploration jobs through the durable job
layer, watch segment events stream in, survive overload via stale fronts,
and resume an interrupted job from its checkpoint.

    PYTHONPATH=src python examples/async_jobs.py [--budget 64]
"""

import argparse
import tempfile
from pathlib import Path

import repro.core as C
from repro.api import Problem, Query, Session
from repro.explore.nsga import NSGAConfig
from repro.explore.service import BudgetPolicy
from repro.serve import DONE, Executor


def _problem(k):
    graph = C.WorkloadGraph([C.matmul("mm", 512, 512, k)], [])
    return Problem(graph, objectives=("latency_ns", "cost_usd"), ch_max=2,
                   space_kwargs=dict(max_shape=(16, 16, 4, 4, 1, 2)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=int, default=64)
    args = ap.parse_args()

    root = Path(tempfile.mkdtemp(prefix="repro-serve-"))
    sess = Session(cache_dir=root / "cache",
                   nsga=NSGAConfig(pop=8, generations=2),
                   policy=BudgetPolicy(chunk_generations=1, adaptive=False))
    ex = Executor(sess, store=root / "jobs", max_workers=2, max_pending=4)

    # --- async submit: a JobHandle streams segment events ------------------
    h = ex.submit(Query(_problem(64), budget=args.budget), key=0)
    print(f"submitted job {h.job_id}")
    for ev in h.events(timeout=600):
        print(f"  segment {ev.segment}: evals={int(ev.trace.n_evals[-1])}, "
              f"front={int(ev.trace.front_size[-1])}, "
              f"hv={float(ev.trace.hypervolume[-1][0]):.3g}")
    r = h.result(timeout=600)
    print(f"job {h.job_id} -> {h.state()}: {r.front_objs.shape[0]}-point "
          f"front, {r.provenance.n_evals_run} evals\n")

    # --- overload: zero slots degrades warm queries to a stale front -------
    busy = Executor(sess, store=root / "jobs-busy", max_workers=1,
                    max_pending=0)
    hs = busy.submit(Query(_problem(64), budget=args.budget), key=1,
                     deadline_s=0.0)
    stale = hs.stale
    print(f"overloaded executor answered instantly from cache: "
          f"{stale.front_objs.shape[0]}-point front "
          f"(stale={stale.provenance.stale}, "
          f"banked={stale.provenance.n_evals_banked} evals)")
    # capacity returns: the banked refinement drains from the journal
    for hb in busy.resume_pending():
        hb.result(timeout=600)
        print(f"banked job {hb.job_id} drained -> {hb.state()}")
        assert hb.state() == DONE
    busy.shutdown()
    ex.shutdown()

    # --- crash-resume: a killed run restarts at the last segment -----------
    # (here simulated with a cooperative stop after the first event; a
    # SIGKILL'd worker process resumes the same way via `repro.serve.worker`)
    crash = Session(cache_dir=root / "cache2",
                    nsga=NSGAConfig(pop=8, generations=2),
                    policy=BudgetPolicy(chunk_generations=1, adaptive=False))
    ex2 = Executor(crash, store=root / "jobs2", max_workers=1)
    h2 = ex2.submit(Query(_problem(96), budget=args.budget), key=2)
    next(h2.events(timeout=600))            # wait for one segment...
    h2.cancel()                             # ...then interrupt the run
    ex2.shutdown()
    print(f"\ninterrupted job {h2.job_id} -> {h2.state()} "
          "(checkpoint kept on disk)")

    resumed = Session(cache_dir=root / "cache2",
                      nsga=NSGAConfig(pop=8, generations=2),
                      policy=BudgetPolicy(chunk_generations=1,
                                          adaptive=False))
    import jax
    r2 = resumed.submit(Query(_problem(96), budget=args.budget),
                        key=jax.random.PRNGKey(2), resume=True)
    print(f"resumed in a fresh session: spent only "
          f"{r2.provenance.n_evals_run}/{args.budget} residual evals, "
          f"{r2.front_objs.shape[0]}-point front")


if __name__ == "__main__":
    main()
