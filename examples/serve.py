"""Serving example: batched prefill + decode with a KV cache on a reduced
config (any of the 10 registry architectures).

    PYTHONPATH=src python examples/serve.py --arch hymba-1.5b --tokens 16
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.models.model import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = args.batch, args.prompt
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    batch = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab)}
    if cfg.family == "encdec":
        batch["audio_embeds"] = jax.random.normal(
            ks[1], (B, cfg.enc_positions, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            ks[1], (B, 4, cfg.d_model), jnp.float32)
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, :, None], (B, S, 3))

    max_seq = S + cfg.meta_tokens + args.tokens + 1
    cache = model.init_cache(B, max_seq)
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    logits, cache = prefill(params, batch, cache)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out = [tok]
    base = S + cfg.meta_tokens
    for i in range(args.tokens - 1):
        logits, cache = decode(params, tok, cache, base + i)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out.append(tok)
    toks = jnp.concatenate(out, axis=1)
    dt = time.time() - t0
    print(f"arch={cfg.name} batch={B} prompt={S} generated={args.tokens}")
    print(f"first sequence: {toks[0].tolist()}")
    print(f"wall {dt:.1f}s ({B*args.tokens/dt:.1f} tok/s incl. compile)")
    assert not bool(jnp.isnan(logits).any())


if __name__ == "__main__":
    main()
