"""Paper Fig. 10 case study as a runnable script: cost-aware exploration of
a chiplet accelerator for the tensor-train contraction chain.

    PYTHONPATH=src python examples/chiplet_tt.py
"""

import jax
import numpy as np

import repro.core as C
from repro.core.constants import PACKAGING_NAMES
from repro.core.cost import monolithic_cost
from repro.core.optimizer import SAConfig, optimize


def main():
    graph = C.presets.tt_chain(s=32, r=32)
    print("TT contraction chain:")
    for i, w in enumerate(graph.workloads):
        print(f"  [{i}] {w.name}: {w.macs/1e9:.2f} GMACs")

    spec = C.SystemSpec.build(graph, ch_max=4)
    space = C.DesignSpace(spec, max_total_pes=8192)
    res = optimize(spec, space, jax.random.PRNGKey(0),
                   weights=C.OBJ_COST_EDP, n_init=4, n_iter=8,
                   sa=SAConfig(steps=250, chains=4))
    d, m = res.design, res.metrics
    shape = np.asarray(d["shape"])
    chips = shape[:, 4] * shape[:, 5]
    print("\ncost-aware design:")
    for i, w in enumerate(graph.workloads):
        print(f"  {w.name}: {int(chips[i])} chiplet(s), "
              f"{int(shape[i,0]*shape[i,1]*shape[i,2]*shape[i,3])} PEs each")
    mono = float(monolithic_cost(float(m['area_mm2'])))
    print(f"  packaging {PACKAGING_NAMES[int(np.asarray(d['packaging']))]}"
          f" | cost ${float(m['cost_usd']):.0f} vs monolithic ${mono:.0f}"
          f" ({(1-float(m['cost_usd'])/mono)*100:.0f}% cut; paper: 28%)")
    print(f"  latency {float(m['latency_ns'])/1e3:.1f} us | "
          f"energy {float(m['energy_pj'])/1e6:.2f} uJ")


if __name__ == "__main__":
    main()
