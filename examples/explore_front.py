"""Explore the latency-cost tradeoff front of the paper's Transformer
block (Fig. 9) through the `repro.explore` service, then print the front
classified by packaging technology.

The first run is cold: an NSGA-II population evolves under the shared
evaluation model and every evaluated design lands in the on-disk Pareto
archive (artifacts/explore_cache/<hash>.npz).  Run the script again and
the identical query is answered from the archive in milliseconds.

    PYTHONPATH=src python examples/explore_front.py
"""

import numpy as np

import repro.core as C
from repro.core.constants import PACKAGING_NAMES
from repro.explore import hypervolume_2d
from repro.explore.service import ExplorationService


def main():
    graph = C.presets.transformer_block()
    svc = ExplorationService()
    res = svc.explore(graph, objectives=("latency_ns", "cost_usd"),
                      budget=1024, ch_max=4,
                      space_kwargs=dict(max_shape=(32, 32, 4, 4, 2, 2)))

    src = "archive cache (warm)" if res.from_cache else \
        f"cold search ({res.n_evals_run} evaluations)"
    print(f"query answered from {src} in {res.elapsed_s:.2f}s "
          f"[archive {res.cache_key}]")

    if res.trace is not None:       # cold runs carry per-generation telemetry
        t = res.trace
        print(f"\nconvergence ({t.generations} generations, "
              f"plateaued={res.plateaued}, banked={res.n_evals_banked} "
              f"of the budget):")
        print(f"  {'gen':>5s} {'evals':>7s} {'front':>6s} "
              f"{'log-hv':>10s} {'best':>9s} {'feas':>5s}")
        step = max(1, t.generations // 8)
        for i in list(range(0, t.generations, step))[-8:]:
            print(f"  {i:5d} {t.n_evals[i]:7d} {t.front_size[i]:6d} "
                  f"{t.hypervolume[i, 0]:10.2f} {t.best[i]:9.3f} "
                  f"{t.feasible_frac[i]:5.2f}")

    print(f"\nlatency-cost Pareto front ({len(res.front_objs)} points):")
    print(f"  {'latency':>12s} {'cost':>10s} {'energy':>12s} {'packaging'}")
    order = np.argsort(res.front_objs[:, 0])
    for i in order:
        lat, cost = res.front_objs[i]
        energy = res.front_metrics[i][1]
        pkg = PACKAGING_NAMES[int(res.front_designs[i]["packaging"])]
        print(f"  {lat:10.0f}ns {cost:9.1f}$ {energy:10.3g}pJ  {pkg}")

    ref = res.front_objs.max(axis=0) * 1.1
    print(f"\nfront hypervolume (ref={ref.round(1)}): "
          f"{hypervolume_2d(res.front_objs, ref):.4g}")
    print("re-run this script: the same query now hits the archive.")


if __name__ == "__main__":
    main()
