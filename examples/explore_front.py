"""Explore the latency-cost tradeoff front of the paper's Transformer
block (Fig. 9) through the declarative ``repro.api`` front door, then
print the front classified by packaging technology.

``Session.plan(query)`` shows what WILL happen before any evaluation is
spent: the engine chosen, the quantized scan-segment schedule, the
cache-hit verdict (and, for ``transfer=True`` queries against a warm
cache directory, the predicted neighbor seeds with their trust-weighted
quotas).  ``Session.submit`` then executes the plan, streaming one
``SegmentEvent`` per scan segment — the dashboard hook — and returns a
unified ``Result`` whose ``provenance`` records the cache / transfer /
reallocation accounting.  Run the script twice: the second run's plan
says ``cache_hit=True`` and the query is answered in milliseconds.

    PYTHONPATH=src python examples/explore_front.py
"""

import numpy as np

import repro.core as C
from repro.api import Problem, Query, Session
from repro.core.constants import PACKAGING_NAMES
from repro.explore import hypervolume_2d


def main():
    graph = C.presets.transformer_block()
    session = Session()
    query = Query(
        Problem(graph, objectives=("latency_ns", "cost_usd"), ch_max=4,
                space_kwargs=dict(max_shape=(32, 32, 4, 4, 2, 2))),
        budget=1024)

    plan = session.plan(query)
    print(f"plan: engine={plan.engine} cache_hit={plan.cache_hit} "
          f"segments={len(plan.segments)} "
          f"[archive {plan.cache_key}]")

    res = session.submit(
        query,
        on_segment=lambda e: print(
            f"  segment {e.segment}: {e.trace.generations} generations, "
            f"front {int(e.trace.front_size[-1])}, "
            f"log-hv {e.trace.hypervolume[-1, 0]:.2f}"))

    pv = res.provenance
    src = "archive cache (warm)" if pv.from_cache else \
        f"cold search ({pv.n_evals_run} evaluations)"
    print(f"query answered from {src} in {pv.elapsed_s:.2f}s")

    if res.trace is not None:       # cold runs carry per-generation telemetry
        t = res.trace
        print(f"\nconvergence ({t.generations} generations, "
              f"plateaued={pv.plateaued}, banked={pv.n_evals_banked} "
              f"of the budget):")
        print(f"  {'gen':>5s} {'evals':>7s} {'front':>6s} "
              f"{'log-hv':>10s} {'best':>9s} {'feas':>5s}")
        step = max(1, t.generations // 8)
        for i in list(range(0, t.generations, step))[-8:]:
            print(f"  {i:5d} {t.n_evals[i]:7d} {t.front_size[i]:6d} "
                  f"{t.hypervolume[i, 0]:10.2f} {t.best[i]:9.3f} "
                  f"{t.feasible_frac[i]:5.2f}")

    print(f"\nlatency-cost Pareto front ({len(res.front_objs)} points):")
    print(f"  {'latency':>12s} {'cost':>10s} {'energy':>12s} {'packaging'}")
    order = np.argsort(res.front_objs[:, 0])
    for i in order:
        lat, cost = res.front_objs[i]
        energy = res.front_metrics[i][1]
        pkg = PACKAGING_NAMES[int(res.front_designs[i]["packaging"])]
        print(f"  {lat:10.0f}ns {cost:9.1f}$ {energy:10.3g}pJ  {pkg}")

    ref = res.front_objs.max(axis=0) * 1.1
    print(f"\nfront hypervolume (ref={ref.round(1)}): "
          f"{hypervolume_2d(res.front_objs, ref):.4g}")
    print("re-run this script: the same query now plans as a cache hit.")


if __name__ == "__main__":
    main()
