"""Monad-as-autosharder (Level B, DESIGN.md Sec. 2).

The paper co-designs *architecture* (per-workload resources + dataflow)
with *integration* (network + packaging) through an analytical model and a
BO engine.  At pod scale the same objects are: the parallelism layout
(mesh factorization, FSDP/TP/EP/PP assignment, microbatching, remat,
decode-cache layout) co-designed against the ICI fabric.  This module:

* defines the layout design space (``ShardPlan``),
* scores a plan with a Monad-style three-term analytical model (compute /
  HBM / ICI — the same non-uniformity decomposition as Sec. III-C, with
  the GPipe bubble playing the role of the paper's pipeline-stall term),
* searches it with the SAME GP+PI Bayesian machinery as the chiplet DSE
  (``repro.core.optimizer``), exhaustive enumeration being the ground
  truth the BO run is benchmarked against,
* and is validated against the compiled dry-run artifacts
  (benchmarks/bench_autoshard.py).
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.constants import DEFAULT_TPU, TPUTarget
from repro.models.config import ModelConfig, ShapeConfig

REMAT_MULT = {"none": 1.0, "dots": 1.18, "full": 4.0 / 3.0}


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    data: int                    # mesh data-axis extent (x pods implicitly)
    model: int                   # mesh model-axis extent (TP)
    microbatch: int = 1
    remat: str = "full"
    fsdp: bool = True            # ZeRO-3 weight sharding over data
    decode_kv: str = "sequence"  # sequence | heads
    pipeline_stages: int = 1     # PP over layer groups (GPipe)
    seq_shard: bool = False

    def chips(self, pods: int = 1) -> int:
        return pods * self.data * self.model * self.pipeline_stages


@dataclasses.dataclass
class PlanScore:
    compute_s: float
    memory_s: float
    collective_s: float
    bubble_frac: float
    hbm_gb: float
    feasible: bool
    step_s: float

    def to_dict(self):
        return dataclasses.asdict(self)


def predict(cfg: ModelConfig, sc: ShapeConfig, plan: ShardPlan,
            pods: int = 1, tpu: TPUTarget = DEFAULT_TPU) -> PlanScore:
    """Analytical three-term score of a layout (Monad Sec. III-C at pod
    scale).  Deliberately simple closed forms — the point is correct
    *ranking*, validated against dry-run artifacts."""
    N = cfg.active_param_count()
    P_all = cfg.param_count()
    chips = plan.chips(pods)
    dp = pods * plan.data
    tp = plan.model
    pp = plan.pipeline_stages
    L = max(cfg.n_layers, 1)
    d = cfg.d_model
    B, S = sc.global_batch, sc.seq_len
    bpe = 2.0
    peak = tpu.peak_bf16_tflops * 1e12
    hbm = tpu.hbm_gbps * 1e9
    ici = tpu.ici_links_per_chip * tpu.ici_link_gbps * 1e9

    if sc.kind == "train":
        tokens = B * S
        flops = 6.0 * N * tokens
        # attention quadratic term (full-attention archs)
        if cfg.n_heads and not cfg.subquadratic:
            flops += 3.0 * 4.0 * B * S * S * cfg.n_heads * cfg.head_dim * L
        flops *= REMAT_MULT[plan.remat]
        passes = 2.0 + (1.0 if plan.remat != "none" else 0.0)
        m = plan.microbatch
        # HBM: weights stream per microbatch per pass + activation dots I/O
        w_local = P_all * bpe / (tp * pp) / (dp if not plan.fsdp else 1.0)
        w_traffic = (P_all * bpe / (tp * pp)) * m * passes
        act = tokens / dp / m * d * bpe
        act_traffic = act * L / pp * 14.0 * passes * m
        mem_bytes = w_traffic + act_traffic + 3 * P_all * 4.0 / chips
        # ICI: FSDP gathers + grad reduce-scatter + TP all-reduces (+EP a2a)
        wire = 0.0
        if plan.fsdp and dp > 1:
            wire += (P_all * bpe / (tp * pp)) * (dp - 1) / dp * m * passes
            wire += 2.0 * (P_all * 4.0 / (tp * pp)) * (dp - 1) / dp
        elif dp > 1:
            wire += 2.0 * (P_all * 4.0 / (tp * pp)) * (dp - 1) / dp
        if tp > 1:
            wire += 2.0 * 2.0 * act * m * L / pp * (tp - 1) / tp * passes
        if cfg.n_experts:
            a2a = tokens / dp * cfg.top_k * d * bpe
            wire += 2.0 * a2a * L / pp * (tp - 1) / tp * passes / tp
        if pp > 1:
            wire += act * m * (pp - 1) / pp * passes
        bubble = (pp - 1) / (m + pp - 1) if pp > 1 else 0.0
        # params f32 + bf16 moments + f32 grads = 12 B/param, ZeRO-sharded;
        # + sqrt(L) saved layer boundaries (grouped remat) per microbatch
        hbm_need = (P_all * 12.0 / chips + math.sqrt(L) * act * 2.0)
    else:
        tokens = B * S if sc.kind == "prefill" else B
        flops = 2.0 * N * tokens
        if cfg.n_heads and not cfg.subquadratic:
            ctx = S
            flops += 4.0 * B * (S * S if sc.kind == "prefill" else ctx) \
                * cfg.n_heads * cfg.head_dim * L
        cache = _cache_bytes(cfg, sc)
        # weights + cache stream once per step, sharded across all chips
        mem_bytes = (2.0 * N + cache) / chips
        wire = 0.0
        act = tokens / max(dp, 1) * d * bpe
        if tp > 1:
            wire += 2.0 * 2.0 * act * L * (tp - 1) / tp
        if sc.kind == "decode" and plan.decode_kv == "sequence" and tp > 1:
            # flash-decoding partial-softmax combine per layer
            wire += 2.0 * B / max(dp, 1) * cfg.n_heads * (cfg.head_dim + 2) \
                * 4.0 * L * (tp - 1) / tp
        bubble = 0.0
        m = 1
        hbm_need = 2.0 * P_all / chips + cache / chips

    # mem_bytes and wire are PER-DEVICE totals by construction above
    compute_s = flops / chips / peak / max(1.0 - bubble, 1e-3)
    memory_s = mem_bytes / hbm if sc.kind == "train" else mem_bytes / hbm
    collective_s = wire / ici
    feas_kv = not (plan.decode_kv == "heads" and cfg.n_kv_heads
                   and tp > 1 and cfg.n_kv_heads % tp != 0)
    if sc.kind == "train":
        ok_batch = B % (dp * plan.microbatch) == 0
    else:
        ok_batch = (B % dp == 0) if B >= dp else (dp == 1)
    feasible = (hbm_need <= tpu.hbm_bytes * 0.9) and feas_kv and ok_batch \
        and cfg.n_layers % plan.pipeline_stages == 0
    step = max(compute_s, memory_s, collective_s)
    return PlanScore(compute_s=compute_s, memory_s=memory_s,
                     collective_s=collective_s, bubble_frac=bubble,
                     hbm_gb=hbm_need / 1e9, feasible=feasible, step_s=step)


def _cache_bytes(cfg: ModelConfig, sc: ShapeConfig) -> float:
    B, S = sc.global_batch, sc.seq_len
    L = cfg.n_layers
    if cfg.family == "ssm":
        return B * L * (cfg.d_inner * cfg.ssm_state * 4.0
                        + cfg.d_inner * (cfg.ssm_conv - 1) * 2.0)
    if cfg.family == "hybrid":
        W = min(cfg.window or S, S)
        return B * L * (2.0 * W * cfg.n_kv_heads * cfg.head_dim * 2.0
                        + cfg.d_inner * cfg.ssm_state * 4.0)
    if cfg.use_mla:
        return B * L * S * (cfg.kv_lora_rank + cfg.qk_rope_dim) * 2.0
    return 2.0 * B * L * S * cfg.n_kv_heads * cfg.head_dim * 2.0


# ---------------------------------------------------------------------------
# search: exhaustive ground truth + the paper's GP/PI Bayesian engine
# ---------------------------------------------------------------------------
def plan_space(chips: int = 256, train: bool = True) -> List[ShardPlan]:
    plans = []
    factorizations = [(d, chips // d) for d in (1, 2, 4, 8, 16, 32, 64, 128,
                                                256) if d <= chips]
    for data, rest in factorizations:
        for pp in (1, 2, 4, 8):
            if rest % pp:
                continue
            model = rest // pp
            if model < 1 or model > 256:
                continue
            for mb in ((1, 2, 4, 8, 16, 32) if train else (1,)):
                for remat in (("none", "dots", "full") if train
                              else ("none",)):
                    for fsdp in ((True, False) if train else (False,)):
                        for dk in (("sequence", "heads")
                                   if not train else ("sequence",)):
                            plans.append(ShardPlan(
                                data=data, model=model, microbatch=mb,
                                remat=remat, fsdp=fsdp, decode_kv=dk,
                                pipeline_stages=pp))
    return plans


def exhaustive_best(cfg: ModelConfig, sc: ShapeConfig, chips: int = 256,
                    pods: int = 1) -> Tuple[ShardPlan, PlanScore, List]:
    best, best_s, scored = None, None, []
    for p in plan_space(chips // pods, train=(sc.kind == "train")):
        s = predict(cfg, sc, p, pods=pods)
        scored.append((p, s))
        if not s.feasible:
            continue
        if best_s is None or s.step_s < best_s.step_s:
            best, best_s = p, s
    return best, best_s, scored


def _encode(plan: ShardPlan, chips: int) -> np.ndarray:
    return np.array([
        math.log2(max(plan.data, 1)) / math.log2(chips),
        math.log2(max(plan.microbatch, 1)) / 5.0,
        {"none": 0.0, "dots": 0.5, "full": 1.0}[plan.remat],
        1.0 if plan.fsdp else 0.0,
        1.0 if plan.decode_kv == "heads" else 0.0,
        math.log2(max(plan.pipeline_stages, 1)) / 3.0,
    ])


def bo_search(cfg: ModelConfig, sc: ShapeConfig, chips: int = 256,
              pods: int = 1, budget: int = 32, seed: int = 0):
    """GP + probability-of-improvement over the plan space (the paper's
    engine, Sec. IV-C, reused verbatim from repro.core.optimizer).
    Returns (best plan, best score, #evaluations, trace)."""
    import jax.numpy as jnp
    from repro.core.optimizer import gp_posterior, prob_improvement

    rng = np.random.default_rng(seed)
    space = plan_space(chips // pods, train=(sc.kind == "train"))
    Z = np.stack([_encode(p, chips) for p in space])

    def ev(p):
        s = predict(cfg, sc, p, pods=pods)
        return (s.step_s if s.feasible else s.step_s * 100.0), s

    idx = list(rng.choice(len(space), size=min(8, len(space)),
                          replace=False))
    X = [Z[i] for i in idx]
    Y = []
    trace = []
    for i in idx:
        y, _ = ev(space[i])
        Y.append(math.log(y))
        trace.append((len(trace), min(Y)))
    seen = set(idx)
    for it in range(budget - len(idx)):
        mu, sg = gp_posterior(jnp.asarray(np.stack(X), jnp.float32),
                              jnp.asarray(np.asarray(Y), jnp.float32),
                              jnp.asarray(Z, jnp.float32))
        pi = np.array(prob_improvement(mu, sg, min(Y)))
        pi[list(seen)] = -1.0
        j = int(np.argmax(pi))
        seen.add(j)
        y, _ = ev(space[j])
        X.append(Z[j])
        Y.append(math.log(y))
        trace.append((len(trace), min(Y)))
    ib = int(np.argmin(Y))
    best_plan = None
    for j in seen:
        if np.allclose(Z[j], X[ib]):
            best_plan = space[j]
            break
    score = predict(cfg, sc, best_plan, pods=pods)
    return best_plan, score, len(Y), trace


def advise(cfg: ModelConfig, sc: ShapeConfig, chips: int = 256,
           pods: int = 1) -> Dict:
    plan, score, scored = exhaustive_best(cfg, sc, chips, pods)
    return {"plan": dataclasses.asdict(plan) if plan else None,
            "score": score.to_dict() if score else None,
            "n_feasible": sum(1 for _, s in scored if s.feasible),
            "n_total": len(scored)}
