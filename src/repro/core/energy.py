"""Accelergy-style energy model (paper Sec. IV-A: "energy or area is
estimated by adding overheads on MACs, memories, and networks").

All inputs are the access counts produced by ``dataflow.analyze_chiplet`` and
the network byte-hop totals from ``network.evaluate_network``; constants are
documented in ``constants.TechConstants``.  Output unit: pJ.
"""

from __future__ import annotations

import jax.numpy as jnp

from .constants import TechConstants, DEFAULT_TECH

F = jnp.float32


def chiplet_energy_pj(an: dict, tech: TechConstants = DEFAULT_TECH):
    """Energy for one workload executing on its chiplet cluster.

    ``an`` is the analyze_chiplet dict; per-chiplet byte counts are scaled by
    the cluster size here.  DRAM and D2D energies are added at system level
    from the communication-graph traffic (avoids double counting).
    """
    nchip = an["n_chiplets"]
    e_mac = an["mac_count"] * F(tech.e_mac_pj)
    e_reg = an["reg_acc_bytes"] * nchip * 8.0 * F(tech.e_reg_pj_bit)
    e_core = an["core_acc_bytes"] * nchip * 8.0 * F(tech.e_core_sram_pj_bit)
    # chiplet buffer: read by core refills + written by external fills
    chip_bits = (an["chipbuf_acc_bytes"] + an["ext_bytes"]) * nchip * 8.0
    e_chip = chip_bits * F(tech.e_chip_sram_pj_bit)
    return e_mac + e_reg + e_core + e_chip


def system_network_energy_pj(net: dict, packaging: int,
                             tech: TechConstants = DEFAULT_TECH):
    """D2D link + router + DRAM energy from network traffic totals."""
    e_d2d_tab = jnp.asarray(tech.e_d2d_pj_bit, F)
    e_d2d = net["d2d_byte_hops"] * 8.0 * e_d2d_tab[packaging]
    e_rt = net["router_byte_hops"] * 8.0 * F(tech.e_router_pj_bit)
    e_dram = net["dram_bytes"] * 8.0 * F(tech.e_dram_pj_bit)
    return e_d2d + e_rt + e_dram


def chiplet_area_mm2(an: dict, io_bw_gbps, packaging: int,
                     tech: TechConstants = DEFAULT_TECH):
    """Area of ONE chiplet: cores (PEs + core buffer) + chiplet buffer +
    router + I/O bump area reservation  bw / D_bw * N_link  (paper Sec. IV-B).
    """
    bw_density = jnp.asarray(tech.bw_density, F)[packaging]
    n_link = jnp.asarray(tech.n_link_io, F)[packaging]
    core = (an["n_pes"] * F(tech.a_pe)
            + an["core_buf_bytes"] / F(2**20) * F(tech.a_sram_per_mb)
            + F(tech.a_core_overhead))
    chip = (an["n_cores"] * core
            + an["chip_buf_bytes"] / F(2**20) * F(tech.a_sram_per_mb)
            + F(tech.a_router) + F(tech.a_chiplet_overhead))
    # 4 in-package links per chiplet node (mesh degree); N_link scales how
    # many of them cross bumps for the chosen packaging.
    io = io_bw_gbps / jnp.maximum(bw_density, 1e-6) * 4.0 * n_link
    return chip + io
