"""Workload suites used by the paper's experiments.

* res[2-5]  — the ``res{2,3,4,5}b_branch2b`` 3x3 convolutions of ResNet-50
  (paper Fig. 7, batch 1).
* att[1-4]  — four matrix-multiply shapes from BERT-large (seq 512, hidden
  1024, heads 16, FFN 4096): QKV projection, QK^T scores, scores x V, FFN.
* transformer_block — Fig. 4a: 2 heads = 5 matmuls with the 0->2, 1->3,
  2->4, 3->4 dependency structure, pipelineable across chiplets.
* tt_chain  — Fig. 10: tensor-train contraction chain C23 -> C33 -> C43 -> C52.
"""

from __future__ import annotations

from typing import Dict

from .workload import (Edge, Workload, WorkloadGraph, contraction, conv2d,
                       matmul, mttkrp)


def resnet_convs() -> Dict[str, WorkloadGraph]:
    """res{2-5}b_branch2b: 3x3 stride-1 convs at each ResNet-50 stage."""
    shapes = {
        "res2": dict(N=1, K=64, C=64, P=56, Q=56, R=3, S=3),
        "res3": dict(N=1, K=128, C=128, P=28, Q=28, R=3, S=3),
        "res4": dict(N=1, K=256, C=256, P=14, Q=14, R=3, S=3),
        "res5": dict(N=1, K=512, C=512, P=7, Q=7, R=3, S=3),
    }
    return {k: WorkloadGraph([conv2d(k, **v)], []) for k, v in shapes.items()}


def bert_mms() -> Dict[str, WorkloadGraph]:
    """Four matmul shapes from BERT-large."""
    shapes = {
        "att1": (512, 1024, 1024),   # QKV projection
        "att2": (512, 512, 64),      # per-head Q K^T
        "att3": (512, 64, 512),      # per-head scores x V
        "att4": (512, 4096, 1024),   # FFN up-projection
    }
    return {k: WorkloadGraph([matmul(k, *v)], []) for k, v in shapes.items()}


def fig7_suite() -> Dict[str, WorkloadGraph]:
    out = dict(resnet_convs())
    out.update(bert_mms())
    return out


def transformer_block(seq: int = 512, d: int = 512,
                      heads: int = 2) -> WorkloadGraph:
    """Paper Fig. 4a: 2 heads / 5 matmuls with cross-head concat into MM4."""
    dh = d // heads
    wls = [
        matmul("mm0_qk_h0", seq, seq, dh),
        matmul("mm1_qk_h1", seq, seq, dh),
        matmul("mm2_av_h0", seq, dh, seq),
        matmul("mm3_av_h1", seq, dh, seq),
        matmul("mm4_out", seq, d, d),
    ]
    edges = [
        Edge(0, 2, "C", "A"),
        Edge(1, 3, "C", "A"),
        Edge(2, 4, "C", "A"),
        Edge(3, 4, "C", "A"),
    ]
    return WorkloadGraph(wls, edges)


def tt_chain(s: int = 32, r: int = 32) -> WorkloadGraph:
    """Fig. 10: TT reconstruction by sequential contraction.  The result
    tensor grows: C23 (O(n^4)) -> C33 (O(n^5)) -> C43/C52 (O(n^6))."""
    c23 = contraction("c23", {"s1": s}, {"s2": s, "a2": r}, {"a1": r})
    c33 = contraction("c33", {"m": s * s}, {"s3": s, "a3": r}, {"a2": r})
    c43 = contraction("c43", {"m": s * s * s}, {"s4": s, "a4": r}, {"a3": r})
    c52 = contraction("c52", {"m": s * s * s * s}, {"s5": s}, {"a4": r})
    edges = [
        Edge(0, 1, "O", "A"),
        Edge(1, 2, "O", "A"),
        Edge(2, 3, "O", "A"),
    ]
    return WorkloadGraph([c23, c33, c43, c52], edges)


def validation_suite() -> Dict[str, WorkloadGraph]:
    """Small matmuls for the Sec. V-A model-vs-simulator validation (the
    paper uses a four-chip transformer with 8x8 PE arrays per chip)."""
    out = {}
    for m, n, k in [(64, 64, 64), (128, 128, 128), (128, 512, 256),
                    (256, 256, 256), (512, 512, 128)]:
        out[f"mm{m}x{n}x{k}"] = WorkloadGraph([matmul("mm", m, n, k)], [])
    return out


def mttkrp_example(i: int = 256, j: int = 64, k: int = 128,
                   l: int = 128) -> WorkloadGraph:
    return WorkloadGraph([mttkrp("mttkrp", i, j, k, l)], [])
