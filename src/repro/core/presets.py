"""Workload suites used by the paper's experiments.

* res[2-5]  — the ``res{2,3,4,5}b_branch2b`` 3x3 convolutions of ResNet-50
  (paper Fig. 7, batch 1).
* att[1-4]  — four matrix-multiply shapes from BERT-large (seq 512, hidden
  1024, heads 16, FFN 4096): QKV projection, QK^T scores, scores x V, FFN.
* transformer_block — Fig. 4a: 2 heads = 5 matmuls with the 0->2, 1->3,
  2->4, 3->4 dependency structure, pipelineable across chiplets.
* tt_chain  — Fig. 10: tensor-train contraction chain C23 -> C33 -> C43 -> C52.
* workload_library — ~8 workload graphs *derived from the registered
  ``repro.configs`` architectures* (attention blocks, MLP stacks, conv
  chains, scan-style contraction chains): the scenario-diverse library the
  cross-spec transfer subsystem is exercised on.
"""

from __future__ import annotations

import os
from typing import Dict

from .constants import DEFAULT_TECH, TechConstants
from .workload import (Edge, Workload, WorkloadGraph, contraction, conv2d,
                       matmul, mttkrp)


def resnet_convs() -> Dict[str, WorkloadGraph]:
    """res{2-5}b_branch2b: 3x3 stride-1 convs at each ResNet-50 stage."""
    shapes = {
        "res2": dict(N=1, K=64, C=64, P=56, Q=56, R=3, S=3),
        "res3": dict(N=1, K=128, C=128, P=28, Q=28, R=3, S=3),
        "res4": dict(N=1, K=256, C=256, P=14, Q=14, R=3, S=3),
        "res5": dict(N=1, K=512, C=512, P=7, Q=7, R=3, S=3),
    }
    return {k: WorkloadGraph([conv2d(k, **v)], []) for k, v in shapes.items()}


def bert_mms() -> Dict[str, WorkloadGraph]:
    """Four matmul shapes from BERT-large."""
    shapes = {
        "att1": (512, 1024, 1024),   # QKV projection
        "att2": (512, 512, 64),      # per-head Q K^T
        "att3": (512, 64, 512),      # per-head scores x V
        "att4": (512, 4096, 1024),   # FFN up-projection
    }
    return {k: WorkloadGraph([matmul(k, *v)], []) for k, v in shapes.items()}


def fig7_suite() -> Dict[str, WorkloadGraph]:
    out = dict(resnet_convs())
    out.update(bert_mms())
    return out


def transformer_block(seq: int = 512, d: int = 512,
                      heads: int = 2) -> WorkloadGraph:
    """Paper Fig. 4a: 2 heads / 5 matmuls with cross-head concat into MM4."""
    dh = d // heads
    wls = [
        matmul("mm0_qk_h0", seq, seq, dh),
        matmul("mm1_qk_h1", seq, seq, dh),
        matmul("mm2_av_h0", seq, dh, seq),
        matmul("mm3_av_h1", seq, dh, seq),
        matmul("mm4_out", seq, d, d),
    ]
    edges = [
        Edge(0, 2, "C", "A"),
        Edge(1, 3, "C", "A"),
        Edge(2, 4, "C", "A"),
        Edge(3, 4, "C", "A"),
    ]
    return WorkloadGraph(wls, edges)


def tt_chain(s: int = 32, r: int = 32) -> WorkloadGraph:
    """Fig. 10: TT reconstruction by sequential contraction.  The result
    tensor grows: C23 (O(n^4)) -> C33 (O(n^5)) -> C43/C52 (O(n^6))."""
    c23 = contraction("c23", {"s1": s}, {"s2": s, "a2": r}, {"a1": r})
    c33 = contraction("c33", {"m": s * s}, {"s3": s, "a3": r}, {"a2": r})
    c43 = contraction("c43", {"m": s * s * s}, {"s4": s, "a4": r}, {"a3": r})
    c52 = contraction("c52", {"m": s * s * s * s}, {"s5": s}, {"a4": r})
    edges = [
        Edge(0, 1, "O", "A"),
        Edge(1, 2, "O", "A"),
        Edge(2, 3, "O", "A"),
    ]
    return WorkloadGraph([c23, c33, c43, c52], edges)


def validation_suite() -> Dict[str, WorkloadGraph]:
    """Small matmuls for the Sec. V-A model-vs-simulator validation (the
    paper uses a four-chip transformer with 8x8 PE arrays per chip)."""
    out = {}
    for m, n, k in [(64, 64, 64), (128, 128, 128), (128, 512, 256),
                    (256, 256, 256), (512, 512, 128)]:
        out[f"mm{m}x{n}x{k}"] = WorkloadGraph([matmul("mm", m, n, k)], [])
    return out


def mttkrp_example(i: int = 256, j: int = 64, k: int = 128,
                   l: int = 128) -> WorkloadGraph:
    return WorkloadGraph([mttkrp("mttkrp", i, j, k, l)], [])


# ---------------------------------------------------------------------------
# model-derived workload library (cross-workload transfer scenarios)
# ---------------------------------------------------------------------------
def attention_block(cfg, seq: int = 256) -> WorkloadGraph:
    """One self-attention block of a registered architecture: QKV
    projection -> per-head QK^T -> scores x V -> output projection, chained
    producer->consumer (per-head matmuls at head_dim width)."""
    d, hd = cfg.d_model, cfg.head_dim
    qkv_cols = (cfg.n_heads + 2 * cfg.n_kv_heads) * hd
    wls = [
        matmul("qkv_proj", seq, qkv_cols, d),
        matmul("qk_scores", seq, seq, hd),
        matmul("av", seq, hd, seq),
        matmul("out_proj", seq, d, cfg.n_heads * hd),
    ]
    edges = [Edge(0, 1, "C", "A"), Edge(1, 2, "C", "A"), Edge(2, 3, "C", "A")]
    return WorkloadGraph(wls, edges)


def mlp_stack(cfg, seq: int = 256) -> WorkloadGraph:
    """Gated MLP of a registered architecture (MoE archs use the per-expert
    width): gate and up projections feeding the down projection."""
    d = cfg.d_model
    ff = cfg.expert_ff if cfg.n_experts > 0 else cfg.d_ff
    wls = [
        matmul("gate_proj", seq, ff, d),
        matmul("up_proj", seq, ff, d),
        matmul("down_proj", seq, d, ff),
    ]
    edges = [Edge(0, 2, "C", "A"), Edge(1, 2, "C", "B")]
    return WorkloadGraph(wls, edges)


def conv_frontend(cfg, frames: int = 1500, mel: int = 80) -> WorkloadGraph:
    """Whisper-style audio conv frontend: two stride-adjacent k=3 conv1d
    layers (encoded as 7-loop conv2d with a unit Q axis)."""
    d = cfg.d_model
    c1 = conv2d("conv1", N=1, K=d, C=mel, P=frames, Q=1, R=3, S=1)
    c2 = conv2d("conv2", N=1, K=d, C=d, P=frames // 2, Q=1, R=3, S=1)
    return WorkloadGraph([c1, c2], [Edge(0, 1, "O", "I")])


def scan_chain(cfg, seq: int = 512) -> WorkloadGraph:
    """Mamba-style selective-scan dataflow as a contraction chain:
    in-projection -> state contraction -> output projection (the tensor
    sizes flow (t, d_inner) -> (t, n_state) -> (t, d_model))."""
    d, di, n = cfg.d_model, cfg.d_inner, max(cfg.ssm_state, 1)
    c_in = contraction("in_proj", {"t": seq}, {"di": di}, {"d": d})
    c_h = contraction("state", {"t": seq}, {"n": n}, {"di": di})
    c_out = contraction("out_proj", {"t": seq}, {"dm": d}, {"n": n})
    return WorkloadGraph([c_in, c_h, c_out],
                         [Edge(0, 1, "O", "A"), Edge(1, 2, "O", "A")])


def hybrid_block(cfg, seq: int = 256) -> WorkloadGraph:
    """Hymba-style parallel heads: sliding-window attention (scores over a
    ``window`` span) beside an SSM state contraction, both feeding one
    output projection."""
    d, hd = cfg.d_model, cfg.head_dim
    w = cfg.window or seq
    di, n = cfg.d_inner, max(cfg.ssm_state, 1)
    wls = [
        matmul("win_scores", seq, min(w, seq), hd),
        matmul("win_av", seq, hd, min(w, seq)),
        contraction("ssm", {"t": seq}, {"n": n}, {"di": di}),
        matmul("out_proj", seq, d, d),
    ]
    edges = [Edge(0, 1, "C", "A"), Edge(1, 3, "C", "A"),
             Edge(2, 3, "O", "B")]
    return WorkloadGraph(wls, edges)


def workload_library() -> Dict[str, WorkloadGraph]:
    """Scenario-diverse workload graphs derived from the registered
    ``repro.configs`` architectures — attention blocks, MLP stacks, a conv
    chain, scan-style contraction chains.  Genuinely different graphs (not
    toy variants), so cross-spec transfer is exercised for real: similar
    pairs exist (the three attention blocks; the two MLPs) alongside
    structurally alien ones (conv vs. scan vs. attention)."""
    from repro.configs import get_config      # lazy: keep repro.core light
    qwen72 = get_config("qwen2_72b")
    qwen32 = get_config("qwen2_5_32b")
    intern = get_config("internlm2_1_8b")
    deepseek = get_config("deepseek_v2_236b")
    whisper = get_config("whisper_tiny")
    mamba = get_config("falcon_mamba_7b")
    hymba = get_config("hymba_1_5b")
    return {
        "attn_qwen2_72b": attention_block(qwen72),
        "attn_qwen2_5_32b": attention_block(qwen32),
        "attn_internlm2": attention_block(intern),
        "mlp_qwen2_72b": mlp_stack(qwen72),
        "mlp_deepseek_v2": mlp_stack(deepseek),
        "conv_whisper": conv_frontend(whisper),
        "scan_falcon_mamba": scan_chain(mamba),
        "hybrid_hymba": hybrid_block(hymba),
    }


# ---------------------------------------------------------------------------
# Technology presets — named TechConstants variants, including calibrated
# artifacts produced by ``repro.calib`` (see README "Calibration").
# ---------------------------------------------------------------------------
_TECH_PRESETS: Dict[str, TechConstants] = {"default": DEFAULT_TECH}


def register_tech(name: str, tech: TechConstants) -> None:
    """Register a named TechConstants preset for this process.  Re-registering
    the same name with different constants is an error (preset identity must
    stay stable within a process); re-registering identical constants is a
    no-op."""
    prev = _TECH_PRESETS.get(name)
    if prev is not None and prev != tech:
        raise ValueError(f"tech preset {name!r} already registered with "
                         "different constants")
    _TECH_PRESETS[name] = tech


def tech_preset_names() -> tuple:
    return tuple(sorted(_TECH_PRESETS))


def _load_tech_file(path: str) -> "tuple[str, TechConstants]":
    """Load a tech preset from a JSON file: either a bare tech dict or a
    ``repro.calib`` CalibratedTech artifact ({"name": ..., "tech": {...}})."""
    import json

    from .constants import tech_from_dict
    with open(path) as f:
        doc = json.load(f)
    if "tech" in doc and isinstance(doc["tech"], dict):
        name = doc.get("name") or os.path.splitext(os.path.basename(path))[0]
        return str(name), tech_from_dict(doc["tech"])
    name = os.path.splitext(os.path.basename(path))[0]
    return name, tech_from_dict(doc)


def tech_preset(name: str) -> TechConstants:
    """Resolve a tech preset by name.

    Resolution order: in-process registry (``register_tech``), then
    ``$REPRO_CALIB_DIR/<name>.json``, then ``name`` interpreted as a path to
    a JSON artifact.  File-resolved presets are cached in the registry so a
    name always maps to one set of constants per process.
    """
    if name in _TECH_PRESETS:
        return _TECH_PRESETS[name]
    cal_dir = os.environ.get("REPRO_CALIB_DIR", "")
    candidates = []
    if cal_dir:
        candidates.append(os.path.join(cal_dir, f"{name}.json"))
    if name.endswith(".json") or os.sep in name:
        candidates.append(name)
    for path in candidates:
        if os.path.exists(path):
            _, tech = _load_tech_file(path)
            register_tech(name, tech)
            return tech
    raise KeyError(
        f"unknown tech preset {name!r}; known: {tech_preset_names()} "
        "(set REPRO_CALIB_DIR or pass a JSON artifact path)")


def resolve_tech(tech) -> "tuple[str, TechConstants]":
    """Normalize any accepted tech designator to ``(name, TechConstants)``.

    Accepts ``None`` (default constants), a preset name or artifact path
    (str), a :class:`TechConstants`, or a ``repro.calib`` CalibratedTech
    (duck-typed: ``.name`` + ``.tech`` attributes).
    """
    if tech is None:
        return "default", DEFAULT_TECH
    if isinstance(tech, str):
        return tech, tech_preset(tech)
    if isinstance(tech, TechConstants):
        if tech == DEFAULT_TECH:
            return "default", tech
        for name, t in _TECH_PRESETS.items():
            if t == tech:
                return name, tech
        return "custom", tech
    name = getattr(tech, "name", None)
    inner = getattr(tech, "tech", None)
    if isinstance(inner, TechConstants) and name:
        register_tech(str(name), inner)
        return str(name), inner
    raise TypeError(f"cannot resolve tech designator of type {type(tech)!r}")


def tech_label(tech) -> str:
    """Human-readable tech identity ``name@digest12`` carried in provenance
    and job payloads; plain ``"default"`` for the uncalibrated constants."""
    from .constants import tech_key
    name, t = resolve_tech(tech)
    if t == DEFAULT_TECH:
        return "default"
    return f"{name}@{tech_key(t)[:12]}"
