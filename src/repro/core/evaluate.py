"""System-level evaluation of one chiplet-accelerator design point.

Composes the per-chiplet dataflow analysis, the contention-aware network
model, the energy/area models and the Eq.-1 cost model into the paper's
pipeline performance model (Sec. III-C):

    Lat = max_path sum D(stage),   Thr = 1 / max_stage D,
    T_total = Lat + (B - 1) / Thr          (B = pipeline ticks)

Everything below is pure jnp on fixed-shape arrays so that `jax.vmap`
evaluates whole populations of design points in one `jit` — the TPU-native
re-think of the paper's one-candidate-at-a-time DSE loop.

A ``SystemSpec`` (static, per workload graph) fixes the padded dims:
W workloads x CH chiplets-per-cluster x E edges.  A *design* is a pytree of
arrays (see ``encoding.py``):

    shape   (W, 6)  raw dims [x0,y0,x1,y1,x2,y2]
    spatial (W, 6)  loop ids
    order   (W, 3, L)
    tiling  (W, 2, L)
    pipe    (W,)    pipelined loop id (L => none)
    logB    ()      log2 pipeline ticks
    packaging ()    0..2
    family  ()      network family 0..3
    placement (W*CH,) global chiplet -> node id
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import network as netmod
from .constants import TechConstants, DEFAULT_TECH
from .cost import package_cost
from .dataflow import analyze_chiplet
from .energy import chiplet_energy_pj, chiplet_area_mm2, system_network_energy_pj
from .network import MAX_NODES, N_TOT, evaluate_network, next_hop_tables
from .workload import MAX_LOOPS, MAX_TENSORS, WorkloadGraph

F = jnp.float32
BIG = F(1e18)


@dataclasses.dataclass(frozen=True)
class SystemSpec:
    """Static (non-traced) description of a workload graph, padded."""
    W: int                       # max workloads
    CH: int                      # max chiplets per cluster
    E: int                       # max edges
    arrays: Dict[str, np.ndarray]
    graph: WorkloadGraph

    @staticmethod
    def build(graph: WorkloadGraph, ch_max: int = 8) -> "SystemSpec":
        W = len(graph.workloads)
        E = max(len(graph.edges), 1)
        wl = [w.to_arrays() for w in graph.workloads]
        arr = {k: np.stack([d[k] for d in wl]) for k in wl[0]}
        arr["wmask"] = np.ones(W, bool)

        tname_idx = [
            {t.name: i for i, t in enumerate(w.tensors)}
            for w in graph.workloads
        ]
        esrc = np.zeros(E, np.int32)
        edst = np.zeros(E, np.int32)
        edst_tensor = np.zeros(E, np.int32)
        emask = np.zeros(E, bool)
        for i, e in enumerate(graph.edges):
            esrc[i], edst[i] = e.src, e.dst
            edst_tensor[i] = tname_idx[e.dst][e.tensor_dst]
            emask[i] = True
        arr.update(esrc=esrc, edst=edst, edst_tensor=edst_tensor, emask=emask)

        ext_in = np.zeros((W, MAX_TENSORS), bool)
        for wi, tn in graph.external_inputs():
            ext_in[wi, tname_idx[wi][tn]] = True
        fin_out = np.zeros((W, MAX_TENSORS), bool)
        for wi, tn in graph.final_outputs():
            fin_out[wi, tname_idx[wi][tn]] = True
        arr.update(ext_in=ext_in, fin_out=fin_out)
        return SystemSpec(W=W, CH=ch_max, E=E, arrays=arr, graph=graph)


def _tick_bounds(bounds, loopmask, pipe_loop, B):
    """Divide the pipelined loop's bound by B (the per-tick sub-problem)."""
    l = jnp.arange(MAX_LOOPS)
    hit = (l == pipe_loop) & loopmask
    return jnp.where(hit, jnp.maximum((bounds + B - 1) // B, 1), bounds)


def evaluate_system(spec: SystemSpec, design: Dict,
                    tech: TechConstants = DEFAULT_TECH) -> Dict:
    """Full PPA + cost evaluation of one design point (jit/vmap-able)."""
    return evaluate_arrays(spec.arrays, design, (spec.W, spec.CH, spec.E),
                           tech)


def evaluate_arrays(arrays: Dict, design: Dict, dims: Tuple[int, int, int],
                    tech: TechConstants = DEFAULT_TECH) -> Dict:
    """Same as ``evaluate_system`` but over raw (traced) workload arrays, so
    one jit compilation is shared by every workload graph with equal padded
    dims (W, CH, E) — the whole Fig.-7 suite compiles once."""
    arr = {k: jnp.asarray(v) for k, v in arrays.items()}
    W, CH, E = dims
    L = MAX_LOOPS

    pkg = design["packaging"]
    cap = jnp.asarray(tech.link_bw_cap, F)[pkg]
    B = (2 ** design["logB"]).astype(F)

    # ---- per-workload chiplet analysis (per pipeline tick) -----------------
    def analyze_one(wi, ext_bw):
        wl = {k: arr[k][wi] for k in
              ("bounds", "loopmask", "A", "tmask", "dmask", "is_out")}
        wl = dict(wl)
        wl["bounds"] = _tick_bounds(wl["bounds"], wl["loopmask"],
                                    design["pipe"][wi],
                                    (2 ** design["logB"]).astype(jnp.int32))
        return analyze_chiplet(wl, design["shape"][wi], design["spatial"][wi],
                               design["order"][wi], design["tiling"][wi],
                               tech=tech, ext_bw_gbps=ext_bw)

    an0 = jax.vmap(lambda wi: analyze_one(wi, cap))(jnp.arange(W))
    d_stage0 = an0["delay_ns"]                                  # (W,)

    n_chips = an0["n_chiplets"].astype(jnp.int32)               # (W,)
    base = jnp.cumsum(n_chips) - n_chips                        # global chiplet base
    n_nodes = jnp.sum(n_chips)
    placement = design["placement"]                             # (W*CH,)

    # ---- communication graph (flows) ---------------------------------------
    # block A: DRAM->chiplet external-input streams  (W*CH flows)
    # block B: chiplet->DRAM final-output writebacks (W*CH flows)
    # block C: producer->consumer intermediate flows (E*CH flows)
    ch_ids = jnp.arange(CH)

    def wl_chip_node(wi, j):
        g = jnp.clip(base[wi] + j, 0, W * CH - 1)
        return placement[g]

    wgrid = jnp.repeat(jnp.arange(W), CH)                       # (W*CH,)
    jgrid = jnp.tile(ch_ids, W)
    chip_valid = jgrid < n_chips[wgrid]
    node_of = jax.vmap(wl_chip_node)(wgrid, jgrid)              # (W*CH,)

    ein = an0["ext_in_bytes_t"]                                 # (W, T) per chiplet
    eout = an0["ext_out_bytes_t"]
    dram_in_vol = jnp.sum(ein * arr["ext_in"], axis=1)[wgrid]   # (W*CH,)
    dram_out_vol = jnp.sum(eout * arr["fin_out"], axis=1)[wgrid]

    dram_node = n_nodes
    srcA = jnp.full((W * CH,), 0, jnp.int32) + dram_node
    dstA = node_of
    volA, mA = dram_in_vol, chip_valid & (dram_in_vol > 0)
    srcB, dstB = node_of, jnp.full((W * CH,), 0, jnp.int32) + dram_node
    volB, mB = dram_out_vol, chip_valid & (dram_out_vol > 0)

    egrid = jnp.repeat(jnp.arange(E), CH)                       # (E*CH,)
    jg = jnp.tile(ch_ids, E)
    w1, w2 = arr["esrc"][egrid], arr["edst"][egrid]
    mC = arr["emask"][egrid] & (jg < n_chips[w2])
    volC = ein[w2, arr["edst_tensor"][egrid]]                   # per consumer chiplet
    srcC = jax.vmap(wl_chip_node)(w1, jg % jnp.maximum(n_chips[w1], 1))
    dstC = jax.vmap(wl_chip_node)(w2, jg)

    src = jnp.concatenate([srcA, srcB, srcC]).astype(jnp.int32)
    dst = jnp.concatenate([dstA, dstB, dstC]).astype(jnp.int32)
    vol = jnp.concatenate([volA, volB, volC])
    fmask = jnp.concatenate([mA, mB, mC])
    fw_src = jnp.concatenate([wgrid, wgrid, w1])                # stage of src
    fw_dst = jnp.concatenate([wgrid, wgrid, w2])
    is_dram_f = jnp.concatenate([jnp.ones_like(mA), jnp.ones_like(mB),
                                 jnp.zeros_like(mC)])

    # bwr_{i,j} = |Omega| / min(D(v_i), D(v_j))  (DRAM side: consumer delay)
    d_src = jnp.where(is_dram_f > 0, BIG, d_stage0[fw_src])
    d_min = jnp.minimum(d_src, d_stage0[fw_dst])
    bwr = vol / jnp.maximum(d_min, 1.0)

    # ---- network: provision at hotspot, cap by packaging -------------------
    nh_all = jnp.asarray(next_hop_tables())
    tcode = design["family"] * (MAX_NODES + 1) + jnp.clip(n_nodes, 1, MAX_NODES)
    nh = nh_all[tcode]
    pre = evaluate_network(nh, src, dst, bwr, vol, fmask,
                           cap, tech.dram_bw, tech.router_delay_ns, n_nodes)
    link_bw = jnp.minimum(jnp.maximum(pre["hotspot"], 1.0), cap)
    net = evaluate_network(nh, src, dst, bwr, vol, fmask,
                           link_bw, tech.dram_bw, tech.router_delay_ns,
                           n_nodes)

    # ---- fixed-point pass: refine stage delays with achieved inbound bw ----
    # DRAM streaming overlaps compute INSIDE the stage (max(D_C, D_B, D_A),
    # Sec III-C); each workload's effective external bandwidth per chiplet is
    # what its block-A flows achieved under contention.
    ebw_f = jnp.where(fmask, vol / jnp.maximum(net["delay_ns"], 1.0), 0.0)
    ebw_A = ebw_f[: W * CH]
    inbound = jnp.zeros((W,), F).at[wgrid].add(jnp.where(mA, ebw_A, 0.0))
    per_chip_bw = inbound / jnp.maximum(an0["n_chiplets"], 1.0)
    per_chip_bw = jnp.where(per_chip_bw > 0, per_chip_bw, cap)
    an = jax.vmap(lambda wi, bw: analyze_one(wi, bw))(
        jnp.arange(W), jnp.minimum(per_chip_bw, cap))
    d_stage = an["delay_ns"]                                    # (W,)

    # ---- transfer-stage delays ---------------------------------------------
    # DRAM in/out contributes only the FIRST/LAST tile fill to the path (the
    # bulk is overlapped inside the compute stage); producer->consumer edges
    # are full pipeline transfer stages D(e) = max over the edge's flows.
    fdel = jnp.where(fmask, net["delay_ns"], 0.0)
    hop_lat = net["hops"] * F(tech.router_delay_ns)
    tiles_w = jnp.maximum(an["ext_tiles"], 1.0)                 # (W,)
    first_fill = hop_lat + (fdel - hop_lat) / tiles_w[
        jnp.concatenate([wgrid, wgrid, w1])]
    d_in = jnp.zeros((W,), F).at[wgrid].max(
        jnp.where(mA, first_fill[: W * CH], 0.0))
    d_out = jnp.zeros((W,), F).at[wgrid].max(
        jnp.where(mB, first_fill[W * CH: 2 * W * CH], 0.0))
    eflow = fdel[2 * W * CH:]
    d_edge = jnp.zeros((E,), F).at[egrid].max(jnp.where(mC, eflow, 0.0))

    # ---- DAG longest path (max-plus relaxation over edges) -----------------
    dist = d_in + d_stage                                       # (W,)
    def relax(dist, _):
        upd = dist[arr["esrc"]] + d_edge + d_stage[arr["edst"]]
        upd = jnp.where(arr["emask"], upd, -BIG)
        return dist.at[arr["edst"]].max(upd), None
    dist, _ = jax.lax.scan(relax, dist, None, length=W)
    lat_tick = jnp.max(dist + d_out)

    max_stage = jnp.maximum(
        jnp.max(d_stage),
        jnp.maximum(jnp.max(jnp.where(arr["emask"], d_edge, 0.0)),
                    jnp.maximum(jnp.max(d_in), jnp.max(d_out))))
    latency = lat_tick + (B - 1.0) * max_stage
    throughput = 1.0 / jnp.maximum(max_stage, 1e-9)

    # ---- energy -------------------------------------------------------------
    e_compute = jnp.sum(jax.vmap(
        lambda i: chiplet_energy_pj({k: v[i] for k, v in an.items()}, tech))(
            jnp.arange(W))) * B
    e_net = system_network_energy_pj(net, pkg, tech) * B
    energy = e_compute + e_net

    # ---- area / cost --------------------------------------------------------
    area_w = jax.vmap(
        lambda i: chiplet_area_mm2({k: v[i] for k, v in an.items()},
                                   link_bw, pkg, tech))(jnp.arange(W))  # (W,)
    die_areas = jnp.where(chip_valid, area_w[wgrid], 0.0)       # (W*CH,)
    cost = package_cost(die_areas, pkg, tech)
    area = jnp.sum(die_areas)

    # ---- calibration corrections -------------------------------------------
    # Per-metric multiplicative factors fitted by repro.calib; all default to
    # 1.0 (exact multiplicative identity), so the uncalibrated model returns
    # bit-identical numbers to a build without this block.
    cl, ce = F(tech.corr_latency), F(tech.corr_energy)
    ca, cc = F(tech.corr_area), F(tech.corr_cost)
    latency, lat_tick = latency * cl, lat_tick * cl
    throughput = throughput / cl
    d_stage, d_edge = d_stage * cl, d_edge * cl
    e_compute, e_net = e_compute * ce, e_net * ce
    energy = energy * ce
    cost, area = cost * cc, area * ca

    return dict(
        latency_ns=latency, lat_tick_ns=lat_tick, throughput_per_ns=throughput,
        energy_pj=energy, edp=energy * 1e-12 * latency * 1e-9,
        cost_usd=cost, area_mm2=area,
        utilization=jnp.sum(an["utilization"] * an["n_chiplets"])
        / jnp.maximum(jnp.sum(an["n_chiplets"]), 1.0),
        hotspot_gbps=pre["hotspot"], link_bw_gbps=link_bw,
        n_nodes=n_nodes, stage_delays_ns=d_stage, edge_delays_ns=d_edge,
        energy_compute_pj=e_compute, energy_network_pj=e_net,
        dram_bytes=net["dram_bytes"] * B,
        d2d_byte_hops=net["d2d_byte_hops"] * B,
    )


def make_batch_evaluator(spec: SystemSpec, tech: TechConstants = DEFAULT_TECH):
    """vmapped + jitted population evaluator: designs (stacked pytree) -> metrics."""
    def one(design):
        return evaluate_system(spec, design, tech)
    return jax.jit(jax.vmap(one))
