"""Monad's nested optimization engine (paper Sec. IV-C, Fig. 6b).

Outer loop: **Bayesian optimization** over the low-dimensional fields
(shape, spatial, packaging, network family) — Gaussian-process surrogate
(Matern-5/2) + *probability of improvement* acquisition, exactly the paper's
choices.  Each BO sample is *evaluated by running a simulated-annealing
engine* over the high-dimensional fields (order, tiling, pipe, placement)
with the low-dim fields frozen.

The SA inner loop is a single ``lax.scan`` jitted over vmapped chains — the
whole nested engine evaluates thousands of design points per second on one
host and scales to accelerators unchanged (the TPU-native re-think of the
paper's engine; see DESIGN.md Sec. 2).
"""

from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .encoding import (ALL_FIELDS, ARCH_FIELDS, BO_FIELDS, INTEG_FIELDS,
                       SA_FIELDS, DesignSpace, feasibility_penalty, mutate,
                       random_design, repair)
from .evaluate import SystemSpec, evaluate_system
from .network import N_FAMILIES

F = jnp.float32

# the objective axes every engine (scalarized BO x SA, NSGA-II fronts,
# Pareto archives) agrees on, in canonical order
METRIC_KEYS = ("latency_ns", "energy_pj", "cost_usd", "area_mm2")

# objective weights over log-metrics: (latency, energy, cost, area)
OBJ_EDP = (1.0, 1.0, 0.0, 0.0)
OBJ_LATENCY = (1.0, 0.0, 0.0, 0.0)
OBJ_ENERGY = (0.0, 1.0, 0.0, 0.0)
OBJ_COST_EDP = (1.0, 1.0, 1.0, 0.0)     # cost-effectiveness (Fig. 9/10)


def metric_stack(metrics: Dict) -> jnp.ndarray:
    """(4,) raw metric vector in ``METRIC_KEYS`` order (archive rows)."""
    return jnp.stack([jnp.asarray(metrics[k], F) for k in METRIC_KEYS])


def log_metric_stack(metrics: Dict) -> jnp.ndarray:
    """(4,) clipped log-metric vector — the shared evaluation path under
    both the scalarized engines here and ``repro.explore.nsga``."""
    return jnp.stack([jnp.log(jnp.maximum(metrics[k], 1e-3))
                      for k in METRIC_KEYS])


def penalty_log(space: DesignSpace, design: Dict, metrics: Dict):
    """log feasibility penalty (shared by scalarized + front explorers)."""
    return jnp.log(feasibility_penalty(space, design, metrics))


def objective_from_metrics(space: DesignSpace, design: Dict, metrics: Dict,
                           weights) -> jnp.ndarray:
    """sum_i w_i * log(metric_i) + log(feasibility penalty); minimize."""
    w = jnp.asarray(weights, F)
    return (jnp.sum(w * log_metric_stack(metrics))
            + 8.0 * penalty_log(space, design, metrics))


# ---------------------------------------------------------------------------
# simulated annealing (jit'd scan, vmapped chains)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SAConfig:
    steps: int = 400
    chains: int = 8
    t0: float = 1.0
    t1: float = 0.01


# compiled SA runners, keyed on everything that shapes the compiled code;
# all workload graphs with the same padded dims share one compilation.
_SA_CACHE: dict = {}

# compiled stacked-designs -> metrics evaluators, shared by every spec with
# equal padded dims (used by the archive-recording path below)
_BATCH_EVAL_CACHE: dict = {}


def _batch_metrics(spec: SystemSpec, tech):
    from .evaluate import evaluate_arrays
    dims = (spec.W, spec.CH, spec.E)
    key = (dims, tech)
    if key not in _BATCH_EVAL_CACHE:
        _BATCH_EVAL_CACHE[key] = jax.jit(
            lambda ds, arr: jax.vmap(
                lambda d: evaluate_arrays(arr, d, dims, tech))(ds))
    f = _BATCH_EVAL_CACHE[key]
    arr = {k: jnp.asarray(v) for k, v in spec.arrays.items()}
    return lambda ds: f(ds, arr)


def make_sa(spec: SystemSpec, space: DesignSpace,
            fields: Tuple[str, ...] = SA_FIELDS,
            sa: SAConfig = SAConfig(), tech=None):
    """Build a jitted SA runner: (key, init_design, weights) -> (best design,
    best objective).  ``fields`` = the mutable subset.

    The workload arrays are passed as *traced arguments* so the compiled SA
    is shared by every spec with the same padded dims — the jit cache is
    keyed on (dims, fields, chains, steps, objective shape) only.
    """
    from .constants import DEFAULT_TECH
    tech = tech or DEFAULT_TECH
    from .evaluate import evaluate_arrays
    dims = (spec.W, spec.CH, spec.E)

    cache_key = (dims, tuple(fields), sa, tech, space.max_shape,
                 space.max_logB, space.max_total_pes, space.fixed_packaging,
                 space.fixed_family, space.allow_pipeline)
    if cache_key in _SA_CACHE:
        jitted = _SA_CACHE[cache_key]

        def runner(key, d0, weights, arrays=None):
            arr = {k: jnp.asarray(v)
                   for k, v in (arrays or spec.arrays).items()}
            return jitted(key, d0, weights, arr)
        return runner

    def obj(design, weights, arr):
        m = evaluate_arrays(arr, design, dims, tech)
        return objective_from_metrics(space, design, m, weights)

    def chain(key, d0, weights, arr):
        o0 = obj(d0, weights, arr)
        nl = jnp.sum(arr["loopmask"], axis=1).astype(jnp.int32)

        def step(carry, xs):
            d_cur, o_cur, d_best, o_best = carry
            k, t = xs
            k1, k2 = jax.random.split(k)
            d_new = mutate(k1, d_cur, space, fields,
                           nl=nl, bounds=arr["bounds"])
            o_new = obj(d_new, weights, arr)
            accept = (o_new < o_cur) | (
                jax.random.uniform(k2) < jnp.exp((o_cur - o_new) / t))
            d_cur = jax.tree.map(
                lambda a, b: jnp.where(accept, b, a), d_cur, d_new)
            o_cur = jnp.where(accept, o_new, o_cur)
            better = o_new < o_best
            d_best = jax.tree.map(
                lambda a, b: jnp.where(better, b, a), d_best, d_new)
            o_best = jnp.where(better, o_new, o_best)
            return (d_cur, o_cur, d_best, o_best), None

        keys = jax.random.split(key, sa.steps)
        temps = jnp.exp(jnp.linspace(math.log(sa.t0), math.log(sa.t1),
                                     sa.steps)).astype(F)
        (_, _, d_best, o_best), _ = jax.lax.scan(
            step, (d0, o0, d0, o0), (keys, temps))
        return d_best, o_best

    def run(key, d0, weights, arr):
        keys = jax.random.split(key, sa.chains)
        d0s = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (sa.chains,) + x.shape), d0)
        db, ob = jax.vmap(chain, in_axes=(0, 0, None, None))(
            keys, d0s, weights, arr)
        i = jnp.argmin(ob)
        return jax.tree.map(lambda x: x[i], db), ob[i]

    jitted = jax.jit(run)
    _SA_CACHE[cache_key] = jitted

    def runner(key, d0, weights, arrays=None):
        arr = {k: jnp.asarray(v)
               for k, v in (arrays or spec.arrays).items()}
        return jitted(key, d0, weights, arr)

    return runner


# ---------------------------------------------------------------------------
# Gaussian process + probability of improvement (from scratch; the Matern
# covariance has a Pallas kernel in repro.kernels.gp_cov used on TPU)
# ---------------------------------------------------------------------------
def matern52(X1, X2, lengthscale):
    d2 = jnp.sum((X1[:, None, :] - X2[None, :, :]) ** 2, -1)
    r = jnp.sqrt(jnp.maximum(d2, 1e-12)) / lengthscale
    s5 = math.sqrt(5.0)
    return (1.0 + s5 * r + 5.0 * r * r / 3.0) * jnp.exp(-s5 * r)


def gp_posterior(X, y, Xq, lengthscale=0.3, noise=1e-4, cov_fn=None):
    """GP posterior mean/std at query points (standardized y)."""
    cov = cov_fn or matern52
    mu0, sd = jnp.mean(y), jnp.maximum(jnp.std(y), 1e-9)
    yn = (y - mu0) / sd
    K = cov(X, X, lengthscale) + noise * jnp.eye(X.shape[0])
    L = jnp.linalg.cholesky(K)
    a = jax.scipy.linalg.cho_solve((L, True), yn)
    Kq = cov(Xq, X, lengthscale)
    mu = Kq @ a
    v = jax.scipy.linalg.solve_triangular(L, Kq.T, lower=True)
    var = jnp.clip(1.0 - jnp.sum(v * v, axis=0), 1e-10, None)
    return mu * sd + mu0, jnp.sqrt(var) * sd


def prob_improvement(mu, sigma, best, xi=0.01):
    z = (best - xi - mu) / jnp.maximum(sigma, 1e-9)
    return jax.scipy.stats.norm.cdf(z)


# ---------------------------------------------------------------------------
# low-dim field <-> unit-cube vector codec for the BO surrogate
# ---------------------------------------------------------------------------
def _bo_dims(space: DesignSpace, fields) -> int:
    W = space.W
    n = 0
    for f in fields:
        if f == "shape":
            n += 6 * W
        elif f == "spatial":
            n += 6 * W
        elif f == "packaging":
            n += 1
        elif f == "family":
            n += 1
    return n


def encode_bo(space: DesignSpace, design: Dict, fields) -> np.ndarray:
    out = []
    mx = np.asarray(space.max_shape, np.float64)
    nl = np.maximum(space.n_loops.astype(np.float64), 1)
    for f in fields:
        if f == "shape":
            out.append((np.asarray(design["shape"]) - 1) / np.maximum(mx - 1, 1))
        elif f == "spatial":
            out.append(np.asarray(design["spatial"]) / nl[:, None])
        elif f == "packaging":
            out.append(np.asarray(design["packaging"]).reshape(1) / 2.0)
        elif f == "family":
            out.append(np.asarray(design["family"]).reshape(1)
                       / (N_FAMILIES - 1))
    return np.concatenate([np.ravel(o) for o in out]).astype(np.float64)


def decode_bo(space: DesignSpace, z: np.ndarray, base: Dict, fields) -> Dict:
    d = {k: np.asarray(v).copy() for k, v in base.items()}
    W = space.W
    mx = np.asarray(space.max_shape, np.float64)
    nl = np.maximum(space.n_loops.astype(np.float64), 1)
    i = 0
    for f in fields:
        if f == "shape":
            blk = z[i:i + 6 * W].reshape(W, 6)
            d["shape"] = np.clip(
                np.rint(blk * np.maximum(mx - 1, 1) + 1), 1, mx
            ).astype(np.int32)
            i += 6 * W
        elif f == "spatial":
            blk = z[i:i + 6 * W].reshape(W, 6)
            d["spatial"] = np.clip(np.rint(blk * nl[:, None]), 0,
                                   nl[:, None] - 1).astype(np.int32)
            i += 6 * W
        elif f == "packaging":
            if space.fixed_packaging < 0:
                d["packaging"] = np.int32(np.clip(np.rint(z[i] * 2), 0, 2))
            i += 1
        elif f == "family":
            if space.fixed_family < 0:
                d["family"] = np.int32(np.clip(
                    np.rint(z[i] * (N_FAMILIES - 1)), 0, N_FAMILIES - 1))
            i += 1
    return {k: jnp.asarray(v) for k, v in d.items()}


# ---------------------------------------------------------------------------
# the full nested engine
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class SearchResult:
    design: Dict
    objective: float
    metrics: Dict
    history: list                 # (iteration, best objective) trace
    trace: Optional["ConvergenceTrace"] = None   # the shared convergence
    #                               telemetry type (repro.explore.archive):
    #                               the scalarized loop's running-best
    #                               objective + cumulative SA evaluations,
    #                               comparable against ExploreResult.trace


def optimize(spec: SystemSpec, space: DesignSpace, key,
             weights=OBJ_EDP,
             bo_fields: Tuple[str, ...] = BO_FIELDS,
             sa_fields: Tuple[str, ...] = SA_FIELDS,
             n_init: int = 8, n_iter: int = 24,
             sa: SAConfig = SAConfig(), tech=None,
             init_design: Optional[Dict] = None,
             seed_designs: Optional[Sequence[Dict]] = None,
             archive=None) -> SearchResult:
    """DEPRECATED shim over the ``bo_sa`` engine backend — routes through
    ``repro.api.Session.submit`` (``Query(Problem.from_spec(spec, space),
    engine="bo_sa", ...)``) and returns the backend's ``SearchResult``
    unchanged.  See ``_optimize_impl`` for the engine itself."""
    warnings.warn(
        "legacy entry point repro.core.optimizer.optimize() is "
        "deprecated; use repro.api: Session(tech=...).submit(Query("
        "Problem.from_spec(spec, space), engine=\"bo_sa\", weights=..., "
        "engine_opts=dict(n_init=..., n_iter=..., sa=...)))",
        DeprecationWarning, stacklevel=2)
    from ..explore.api import Problem, Query, Session
    q = Query(Problem.from_spec(spec, space), engine="bo_sa",
              weights=tuple(float(w) for w in weights),
              seed_designs=seed_designs, archive=archive,
              engine_opts=dict(bo_fields=bo_fields, sa_fields=sa_fields,
                               n_init=n_init, n_iter=n_iter, sa=sa,
                               init_design=init_design))
    return Session(tech=tech).submit(q, key=key).raw


def _optimize_impl(spec: SystemSpec, space: DesignSpace, key,
                   weights=OBJ_EDP,
                   bo_fields: Tuple[str, ...] = BO_FIELDS,
                   sa_fields: Tuple[str, ...] = SA_FIELDS,
                   n_init: int = 8, n_iter: int = 24,
                   sa: SAConfig = SAConfig(), tech=None,
                   init_design: Optional[Dict] = None,
                   seed_designs: Optional[Sequence[Dict]] = None,
                   archive=None) -> SearchResult:
    """Nested BO(low-dim) x SA(high-dim) search (paper Fig. 6b).

    Setting ``bo_fields=()`` degenerates to pure SA over ``sa_fields`` —
    used by the Fig.-8 ablation ladder and the baseline mapping searches.

    ``seed_designs`` (e.g. a transferred population migrated out of a
    neighbor spec's archive via ``encoding.migrate``) replaces the leading
    random restarts of the init phase; each seed is ``repair``-ed into
    this space's feasible set first.  ``init_design`` keeps its historic
    slot-0 meaning and precedes any seeds.  At most ``n_init`` entries are
    consumed (one SA refinement each) — pass a larger ``n_init`` to spend
    budget on a bigger transferred population.

    ``archive`` (a ``repro.explore.archive.ParetoArchive``) optionally
    records every SA-refined design with its raw metric vector, so
    scalarized runs feed the same Pareto cache the exploration service
    serves fronts from.
    """
    from .constants import DEFAULT_TECH
    tech = tech or DEFAULT_TECH
    sa_run = make_sa(spec, space, sa_fields, sa, tech)
    rng = np.random.default_rng(np.asarray(
        jax.random.key_data(key) if hasattr(jax.random, "key_data")
        else key)[-1])

    X, Y, designs = [], [], []
    history = []
    inits = ([] if init_design is None else [init_design]) + [
        {k: jnp.asarray(v) for k, v in repair(d, space).items()}
        for d in (seed_designs or [])]
    metrics_fn = jax.jit(lambda d: evaluate_system(spec, d, tech))

    def eval_point(d0, i):
        kd = jax.random.PRNGKey(int(rng.integers(2 ** 31)))
        d_best, o_best = sa_run(kd, d0, jnp.asarray(weights, F))
        return d_best, float(o_best)

    n_bo = _bo_dims(space, bo_fields)
    total = n_init + (n_iter if n_bo > 0 else 0)
    for i in range(n_init):
        d0 = random_design(jax.random.PRNGKey(int(rng.integers(2 ** 31))),
                           space)
        if i < len(inits):
            d0 = inits[i]
        db, ob = eval_point(d0, i)
        designs.append(db)
        Y.append(ob)
        if n_bo > 0:
            X.append(encode_bo(space, db, bo_fields))
        history.append((i, float(np.min(Y))))

    if n_bo > 0:
        for i in range(n_iter):
            Xa = jnp.asarray(np.stack(X))
            Ya = jnp.asarray(np.asarray(Y, np.float64), F)
            # acquisition: PI over random candidates + perturbations of best
            cand = rng.random((384, n_bo))
            zb = X[int(np.argmin(Y))]
            pert = np.clip(zb[None, :] + rng.normal(0, 0.15, (128, n_bo)),
                           0, 1)
            Z = np.vstack([cand, pert])
            mu, sg = gp_posterior(Xa, Ya, jnp.asarray(Z, F))
            pi = prob_improvement(mu, sg, float(np.min(Y)))
            z = Z[int(jnp.argmax(pi))]
            d0 = decode_bo(space, z, designs[int(np.argmin(Y))], bo_fields)
            db, ob = eval_point(d0, n_init + i)
            designs.append(db)
            Y.append(ob)
            X.append(encode_bo(space, db, bo_fields))
            history.append((n_init + i, float(np.min(Y))))

    ib = int(np.argmin(Y))
    best = designs[ib]
    metrics = metrics_fn(best)
    if archive is not None and designs:
        # one batched (vmapped) evaluation + insert for every SA-refined
        # design of the run — no per-iteration device round-trips, one
        # compilation shared across runs with equal padded dims
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *designs)
        mb = _batch_metrics(spec, tech)(stacked)
        raw = jnp.stack([jnp.asarray(mb[k], F) for k in METRIC_KEYS],
                        axis=-1)
        feas = jax.vmap(
            lambda d, m: feasibility_penalty(space, d, m))(stacked, mb) \
            <= 1.0 + 1e-6
        archive.insert(stacked, raw, mask=feas)
    return SearchResult(design=best, objective=float(Y[ib]),
                        metrics={k: np.asarray(v) for k, v in metrics.items()},
                        history=history,
                        trace=ConvergenceTrace.from_history(
                            history, evals_per_step=sa.steps * sa.chains))


# ---------------------------------------------------------------------------
# the paper's two-stage flow (Sec. IV-A): the architecture stage keeps a
# Pareto set; the integration stage's design-selector picks from it.
# The dominance convention AND the convergence-telemetry type live in ONE
# place — repro.explore.archive — and are re-exported here for the engine
# and its tests.
# ---------------------------------------------------------------------------
from ..explore.archive import (ConvergenceTrace,  # noqa: E402  (canonical)
                               pareto_front)


def two_stage_optimize(spec: SystemSpec, space: DesignSpace, key,
                       n_candidates: int = 3,
                       sa: SAConfig = SAConfig(steps=250, chains=4),
                       tech=None, archive=None,
                       seed_designs: Optional[Sequence[Dict]] = None
                       ) -> SearchResult:
    """DEPRECATED shim over the ``two_stage`` engine backend — routes
    through ``repro.api.Session.submit`` (``Query(..., engine=
    "two_stage")``) and returns the backend's ``SearchResult`` unchanged.
    See ``_two_stage_impl`` for the engine itself."""
    warnings.warn(
        "legacy entry point repro.core.optimizer.two_stage_optimize() is "
        "deprecated; use repro.api: Session(tech=...).submit(Query("
        "Problem.from_spec(spec, space), engine=\"two_stage\", "
        "engine_opts=dict(n_candidates=..., sa=...)))",
        DeprecationWarning, stacklevel=2)
    from ..explore.api import Problem, Query, Session
    q = Query(Problem.from_spec(spec, space), engine="two_stage",
              seed_designs=seed_designs, archive=archive,
              engine_opts=dict(n_candidates=n_candidates, sa=sa))
    return Session(tech=tech).submit(q, key=key).raw


def _two_stage_impl(spec: SystemSpec, space: DesignSpace, key,
                    n_candidates: int = 3,
                    sa: SAConfig = SAConfig(steps=250, chains=4),
                    tech=None, archive=None,
                    seed_designs: Optional[Sequence[Dict]] = None
                    ) -> SearchResult:
    """Stage 1 (architecture): search arch fields under several objective
    scalarizations, keep the Pareto-optimal candidates over
    (latency, energy, area).  Stage 2 (integration): for each kept
    candidate, open the integration fields (packaging/network/placement)
    and optimize EDP; the best pair wins — the selector made explicit.

    Both stages run through the same evaluation/objective path as the
    ``repro.explore`` front explorer (``log_metric_stack`` + penalty), and
    an optional ``archive`` records every refined candidate.
    ``seed_designs`` (a transferred population) warm-starts every stage-1
    scalarization's init phase."""
    from .constants import DEFAULT_TECH
    tech = tech or DEFAULT_TECH
    keys = jax.random.split(key, 8)

    cands, objs = [], []
    weights_list = [OBJ_LATENCY, OBJ_ENERGY, OBJ_EDP,
                    (1.0, 1.0, 0.0, 1.0)][:max(n_candidates, 2)]
    for i, w in enumerate(weights_list):
        r = _optimize_impl(spec, space, keys[i], weights=w,
                           bo_fields=("shape", "spatial"),
                           sa_fields=("order", "tiling", "pipe"),
                           n_init=4, n_iter=6, sa=sa, tech=tech,
                           archive=archive, seed_designs=seed_designs)
        cands.append(r.design)
        m = r.metrics
        objs.append([float(m["latency_ns"]), float(m["energy_pj"]),
                     float(m["area_mm2"])])
    keep = pareto_front(objs)

    best = None
    for ki, ci in enumerate(keep):
        r = _optimize_impl(spec, space, keys[4 + (ki % 4)], weights=OBJ_EDP,
                           bo_fields=("packaging", "family"),
                           sa_fields=("placement",),
                           n_init=2, n_iter=4, sa=sa, tech=tech,
                           init_design=cands[ci], archive=archive)
        if best is None or r.objective < best.objective:
            best = r
    best.history.append(("pareto_kept", len(keep)))
    return best
