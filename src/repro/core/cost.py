"""Fabrication cost model — paper Eq. (1):

    C_total = sum_i ( C_die^i / y_die^i + C_bond ) + C_sub + C_int / y_int + C_proc

Die cost from wafer price / dies-per-wafer; yield from the negative-binomial
model  y = (1 + A * D0 / alpha)^(-alpha).  The substrate cost is proportional
to package area; the interposer is fabricated and yielded like a die (passive:
metal-only low defect density; active: standard CMOS).  Constants follow
public wafer-price/defect tables in the style of ICKnowledge [8] — see
``constants.py``.
"""

from __future__ import annotations

import jax.numpy as jnp

from .constants import (TechConstants, DEFAULT_TECH,
                        PKG_ORGANIC, PKG_PASSIVE, PKG_ACTIVE)

F = jnp.float32


def die_yield(area_mm2, d0_mm2, alpha):
    return (1.0 + area_mm2 * d0_mm2 / alpha) ** (-alpha)


def dies_per_wafer(area_mm2, tech: TechConstants = DEFAULT_TECH):
    """Classic dies-per-wafer approximation with scribe margin."""
    d = F(tech.wafer_diameter_mm)
    a = area_mm2 + tech.scribe_mm * jnp.sqrt(jnp.maximum(area_mm2, 1e-6))
    return jnp.maximum(
        jnp.pi * (d / 2.0) ** 2 / a - jnp.pi * d / jnp.sqrt(2.0 * a), 1.0)


def die_cost(area_mm2, tech: TechConstants = DEFAULT_TECH,
             wafer_cost=None, d0=None):
    wc = F(tech.wafer_cost if wafer_cost is None else wafer_cost)
    d0 = F(tech.defect_density_mm2 if d0 is None else d0)
    c = wc / dies_per_wafer(area_mm2, tech)
    y = die_yield(area_mm2, d0, F(tech.yield_alpha))
    return c / y


def package_cost(die_areas_mm2, packaging, tech: TechConstants = DEFAULT_TECH):
    """Eq. (1) for a package of dies under a packaging technology.

    die_areas_mm2: (N,) array (0 entries = unused slots).
    packaging: 0 organic / 1 passive interposer / 2 active interposer
               (may be a traced int).
    """
    areas = jnp.asarray(die_areas_mm2, F)
    used = areas > 0.0
    n_dies = jnp.sum(used.astype(F))
    dies = jnp.where(used, die_cost(jnp.maximum(areas, 1e-3), tech), 0.0)
    bond = jnp.asarray(tech.c_bond, F)[packaging] / F(tech.bond_yield)
    c_dies = jnp.sum(dies) + n_dies * bond

    pkg_area = jnp.sum(areas) * F(tech.interposer_margin)
    c_sub = pkg_area * F(tech.c_substrate_mm2)

    int_wafer = jnp.asarray(tech.int_wafer_cost, F)[packaging]
    int_d0 = jnp.asarray(tech.int_defect_mm2, F)[packaging]
    c_int_raw = int_wafer / dies_per_wafer(jnp.maximum(pkg_area, 1.0), tech)
    y_int = die_yield(pkg_area, int_d0, F(tech.yield_alpha))
    has_int = (jnp.asarray(packaging) != PKG_ORGANIC).astype(F)
    c_int = has_int * c_int_raw / jnp.maximum(y_int, 1e-3)

    return c_dies + c_sub + c_int + F(tech.c_process)


def monolithic_cost(total_area_mm2, tech: TechConstants = DEFAULT_TECH):
    """Baseline: one big die of the same total area + cheap substrate."""
    return (die_cost(total_area_mm2, tech)
            + total_area_mm2 * F(tech.interposer_margin)
            * F(tech.c_substrate_mm2) + F(tech.c_process))
