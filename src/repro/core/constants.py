"""Technology constants for the Monad models (energy / area / cost / network).

The paper sources these from Accelergy [34], ICKnowledge [8] and the UCIe
white paper [31]; none of those tools/tables ship offline, so every constant
here is a documented public-literature value.  Absolute outputs therefore
differ from the paper's; the *relative* experiments (Fig. 3/7/8/9/10) are what
the benchmarks reproduce.

Conventions
-----------
* energy:   pJ  (per event or per bit, as named)
* area:     mm^2
* cost:     USD
* bandwidth: GB/s  (= bytes/ns)
* time:     ns (1 GHz core clock -> 1 cycle = 1 ns)
"""

from __future__ import annotations

import dataclasses

# ---------------------------------------------------------------------------
# Packaging technology ids (paper Sec. IV-B: encoded as 0-2)
# ---------------------------------------------------------------------------
PKG_ORGANIC = 0          # organic substrate
PKG_PASSIVE = 1          # passive silicon interposer
PKG_ACTIVE = 2           # active silicon interposer
PACKAGINGS = (PKG_ORGANIC, PKG_PASSIVE, PKG_ACTIVE)
PACKAGING_NAMES = ("organic", "passive-interposer", "active-interposer")


@dataclasses.dataclass(frozen=True)
class TechConstants:
    # --- timing -----------------------------------------------------------
    clock_ghz: float = 1.0                # core clock; 1 cycle == 1 ns
    router_delay_ns: float = 20.0         # t_s: per-hop switch delay (head flit)

    # --- datatype ---------------------------------------------------------
    bytes_per_elem: int = 2               # fp16/bf16 operands

    # --- energy (pJ) ------------------------------------------------------
    # MAC @ 28nm, 16-bit (Horowitz ISSCC'14 scaled)
    e_mac_pj: float = 1.0
    # register-file access, per bit
    e_reg_pj_bit: float = 0.03
    # core (L1) SRAM buffer, per bit (64-256 KB class)
    e_core_sram_pj_bit: float = 0.30
    # chiplet (L2) SRAM buffer, per bit (MB class); paper cites 0.81 pJ/bit [28]
    e_chip_sram_pj_bit: float = 0.81
    # DRAM access per bit (LPDDR class)
    e_dram_pj_bit: float = 8.0
    # die-to-die link energy per bit, by packaging (UCIe white paper [31]:
    # ~0.5 pJ/bit standard (organic) package, 0.25 pJ/bit advanced package)
    e_d2d_pj_bit: tuple = (0.50, 0.25, 0.25)
    # on-package router traversal per bit per hop
    e_router_pj_bit: float = 0.10

    # --- area (mm^2) @ 28nm ----------------------------------------------
    a_pe: float = 0.0015                  # MAC + operand regs + pipeline
    a_sram_per_mb: float = 2.0            # 6T SRAM macro incl. periphery
    a_router: float = 0.25                # in-chiplet NoC router
    a_core_overhead: float = 0.05         # per-core control/misc
    a_chiplet_overhead: float = 1.0       # per-chiplet phy/ctrl floor

    # --- bandwidth --------------------------------------------------------
    # bandwidth density GB/s per mm^2 of die edge I/O area, by packaging.
    # UCIe [31]: advanced package ~6x the density of standard (paper Sec. II-B:
    # interposer has 6x interconnect density vs organic substrate).
    bw_density: tuple = (30.0, 180.0, 180.0)
    # feasible per-link bandwidth cap, by packaging (GB/s)
    link_bw_cap: tuple = (32.0, 256.0, 256.0)
    # per-link bump/lane count multiplier used for the I/O area reservation
    n_link_io: tuple = (1.0, 1.0, 0.5)    # active interposer: routers in the
                                          # interposer -> only 2 of the links
                                          # per chiplet cross bumps (Sec IV-B)
    dram_bw: float = 128.0                # boundary DRAM controller bandwidth
    core_buf_bw: float = 64.0             # core SRAM buffer bandwidth GB/s
    chip_buf_bw: float = 256.0            # chiplet SRAM buffer bandwidth GB/s
    chip_noc_bw: float = 128.0            # intra-chiplet core<->buffer NoC

    # --- fabrication cost (Eq. 1) ------------------------------------------
    wafer_diameter_mm: float = 300.0
    wafer_cost: float = 3500.0            # 28nm processed wafer, USD
    defect_density_mm2: float = 0.0009    # D0 = 0.09 /cm^2  (28nm mature)
    yield_alpha: float = 4.0              # negative-binomial clustering alpha
    scribe_mm: float = 0.2                # die separation margin
    # bonding cost per die: organic / passive / active (microbump attach)
    c_bond: tuple = (1.0, 2.0, 2.0)
    bond_yield: float = 0.99              # per-die bonding success
    # organic substrate cost per mm^2 of package area
    c_substrate_mm2: float = 0.01
    # interposer wafers: passive (metal-only, low defect density) vs active
    # (mature-node CMOS, e.g. 65nm class)
    int_wafer_cost: tuple = (0.0, 900.0, 1500.0)
    int_defect_mm2: tuple = (0.0, 0.0002, 0.0005)
    c_process: float = 5.0                # assembly/test per package
    interposer_margin: float = 1.15       # interposer area vs sum of die area


DEFAULT_TECH = TechConstants()


# ---------------------------------------------------------------------------
# TPU v5e-class target constants used by Level B (autosharding / roofline)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TPUTarget:
    peak_bf16_tflops: float = 197.0       # per chip
    hbm_gbps: float = 819.0               # per chip
    ici_link_gbps: float = 50.0           # per link per direction
    ici_links_per_chip: int = 4           # 2D torus: +/-x, +/-y
    hbm_bytes: float = 16e9               # capacity per chip
    vmem_bytes: float = 128 * 2**20       # on-chip vector memory


DEFAULT_TPU = TPUTarget()
