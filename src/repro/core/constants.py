"""Technology constants for the Monad models (energy / area / cost / network).

The paper sources these from Accelergy [34], ICKnowledge [8] and the UCIe
white paper [31]; none of those tools/tables ship offline, so every constant
here is a documented public-literature value.  Absolute outputs therefore
differ from the paper's; the *relative* experiments (Fig. 3/7/8/9/10) are what
the benchmarks reproduce.

Conventions
-----------
* energy:   pJ  (per event or per bit, as named)
* area:     mm^2
* cost:     USD
* bandwidth: GB/s  (= bytes/ns)
* time:     ns (1 GHz core clock -> 1 cycle = 1 ns)
"""

from __future__ import annotations

import dataclasses
import hashlib
import json

# ---------------------------------------------------------------------------
# Packaging technology ids (paper Sec. IV-B: encoded as 0-2)
# ---------------------------------------------------------------------------
PKG_ORGANIC = 0          # organic substrate
PKG_PASSIVE = 1          # passive silicon interposer
PKG_ACTIVE = 2           # active silicon interposer
PACKAGINGS = (PKG_ORGANIC, PKG_PASSIVE, PKG_ACTIVE)
PACKAGING_NAMES = ("organic", "passive-interposer", "active-interposer")


@dataclasses.dataclass(frozen=True)
class TechConstants:
    # --- timing -----------------------------------------------------------
    clock_ghz: float = 1.0                # core clock; 1 cycle == 1 ns
    router_delay_ns: float = 20.0         # t_s: per-hop switch delay (head flit)
    # fixed per-external-tile launch overhead (DMA descriptor setup, drain).
    # Real systolic arrays pay a constant cost each time a tile's operands
    # are (re)staged from DRAM; the pure pipeline model omits it.  Default 0
    # keeps the uncalibrated model bit-identical; calibration fits it.
    t_tile_overhead_ns: float = 0.0

    # --- datatype ---------------------------------------------------------
    bytes_per_elem: int = 2               # fp16/bf16 operands

    # --- energy (pJ) ------------------------------------------------------
    # MAC @ 28nm, 16-bit (Horowitz ISSCC'14 scaled)
    e_mac_pj: float = 1.0
    # register-file access, per bit
    e_reg_pj_bit: float = 0.03
    # core (L1) SRAM buffer, per bit (64-256 KB class)
    e_core_sram_pj_bit: float = 0.30
    # chiplet (L2) SRAM buffer, per bit (MB class); paper cites 0.81 pJ/bit [28]
    e_chip_sram_pj_bit: float = 0.81
    # DRAM access per bit (LPDDR class)
    e_dram_pj_bit: float = 8.0
    # die-to-die link energy per bit, by packaging (UCIe white paper [31]:
    # ~0.5 pJ/bit standard (organic) package, 0.25 pJ/bit advanced package)
    e_d2d_pj_bit: tuple = (0.50, 0.25, 0.25)
    # on-package router traversal per bit per hop
    e_router_pj_bit: float = 0.10

    # --- area (mm^2) @ 28nm ----------------------------------------------
    a_pe: float = 0.0015                  # MAC + operand regs + pipeline
    a_sram_per_mb: float = 2.0            # 6T SRAM macro incl. periphery
    a_router: float = 0.25                # in-chiplet NoC router
    a_core_overhead: float = 0.05         # per-core control/misc
    a_chiplet_overhead: float = 1.0       # per-chiplet phy/ctrl floor

    # --- bandwidth --------------------------------------------------------
    # bandwidth density GB/s per mm^2 of die edge I/O area, by packaging.
    # UCIe [31]: advanced package ~6x the density of standard (paper Sec. II-B:
    # interposer has 6x interconnect density vs organic substrate).
    bw_density: tuple = (30.0, 180.0, 180.0)
    # feasible per-link bandwidth cap, by packaging (GB/s)
    link_bw_cap: tuple = (32.0, 256.0, 256.0)
    # per-link bump/lane count multiplier used for the I/O area reservation
    n_link_io: tuple = (1.0, 1.0, 0.5)    # active interposer: routers in the
                                          # interposer -> only 2 of the links
                                          # per chiplet cross bumps (Sec IV-B)
    dram_bw: float = 128.0                # boundary DRAM controller bandwidth
    core_buf_bw: float = 64.0             # core SRAM buffer bandwidth GB/s
    chip_buf_bw: float = 256.0            # chiplet SRAM buffer bandwidth GB/s
    chip_noc_bw: float = 128.0            # intra-chiplet core<->buffer NoC

    # --- fabrication cost (Eq. 1) ------------------------------------------
    wafer_diameter_mm: float = 300.0
    wafer_cost: float = 3500.0            # 28nm processed wafer, USD
    defect_density_mm2: float = 0.0009    # D0 = 0.09 /cm^2  (28nm mature)
    yield_alpha: float = 4.0              # negative-binomial clustering alpha
    scribe_mm: float = 0.2                # die separation margin
    # bonding cost per die: organic / passive / active (microbump attach)
    c_bond: tuple = (1.0, 2.0, 2.0)
    bond_yield: float = 0.99              # per-die bonding success
    # organic substrate cost per mm^2 of package area
    c_substrate_mm2: float = 0.01
    # interposer wafers: passive (metal-only, low defect density) vs active
    # (mature-node CMOS, e.g. 65nm class)
    int_wafer_cost: tuple = (0.0, 900.0, 1500.0)
    int_defect_mm2: tuple = (0.0, 0.0002, 0.0005)
    c_process: float = 5.0                # assembly/test per package
    interposer_margin: float = 1.15       # interposer area vs sum of die area

    # --- calibration correction factors ------------------------------------
    # Per-metric multiplicative corrections applied at the very end of
    # evaluate_arrays.  1.0 is the exact multiplicative identity for every
    # finite float, so the default model stays bit-identical; repro.calib
    # fits them (in log-space) against measured ground truth.
    corr_latency: float = 1.0
    corr_energy: float = 1.0
    corr_area: float = 1.0
    corr_cost: float = 1.0


DEFAULT_TECH = TechConstants()


# ---------------------------------------------------------------------------
# Calibration support: stable identity + serialization + fittable whitelist
# ---------------------------------------------------------------------------

def tech_to_dict(tech: TechConstants) -> dict:
    """Serialize a TechConstants to a JSON-clean dict (tuples -> lists)."""
    out = {}
    for f in dataclasses.fields(tech):
        v = getattr(tech, f.name)
        out[f.name] = list(v) if isinstance(v, tuple) else v
    return out


def tech_from_dict(d: dict) -> TechConstants:
    """Inverse of :func:`tech_to_dict`.  Unknown keys are rejected loudly;
    missing keys fall back to the field default (forward compatibility for
    artifacts written before a field existed)."""
    names = {f.name for f in dataclasses.fields(TechConstants)}
    unknown = set(d) - names
    if unknown:
        raise KeyError(f"unknown TechConstants fields: {sorted(unknown)}")
    kwargs = {}
    for f in dataclasses.fields(TechConstants):
        if f.name not in d:
            continue
        v = d[f.name]
        if isinstance(f.default, tuple):
            v = tuple(v)
        elif isinstance(f.default, int) and not isinstance(f.default, bool):
            v = int(v) if float(v) == int(v) else float(v)
        else:
            v = float(v)
        kwargs[f.name] = v
    return TechConstants(**kwargs)


def tech_key(tech: TechConstants | None = None) -> str:
    """Stable content digest of a TechConstants.

    This — not ``repr()`` — is the canonical tech identity everywhere one is
    needed (archive/manifest cache keys, provenance, calibrated-preset
    artifacts).  Values are serialized with ``repr(float(...))`` which is
    exact for Python floats, so two structurally-equal instances always share
    a key and any field change (including a fitted correction factor) yields
    a new one.
    """
    tech = DEFAULT_TECH if tech is None else tech
    payload = json.dumps(tech_to_dict(tech), sort_keys=True, separators=(",", ":"),
                         default=repr)
    return hashlib.sha256(payload.encode()).hexdigest()


#: TechConstants fields the calibration fit is allowed to move.  Everything
#: here is a positive scalar (log-space reparameterization assumes > 0 after
#: flooring); integers, tuples and geometry-defining fields stay frozen.
FITTABLE_FIELDS = (
    # timing
    "router_delay_ns", "t_tile_overhead_ns",
    # energy
    "e_mac_pj", "e_reg_pj_bit", "e_core_sram_pj_bit", "e_chip_sram_pj_bit",
    "e_dram_pj_bit", "e_router_pj_bit",
    # area
    "a_pe", "a_sram_per_mb", "a_router", "a_core_overhead",
    "a_chiplet_overhead",
    # bandwidth
    "dram_bw", "core_buf_bw", "chip_buf_bw", "chip_noc_bw",
    # cost
    "wafer_cost", "defect_density_mm2", "c_substrate_mm2", "c_process",
    # per-metric corrections
    "corr_latency", "corr_energy", "corr_area", "corr_cost",
)

#: metric -> fields guaranteed to move that metric on the golden design used
#: by the differentiability regression test (tests/test_calib.py).  The
#: bandwidth fields are fittable but deliberately absent here: latency takes
#: the max over compute/memory passes, so a bandwidth's gradient is non-zero
#: only in the regime where that bandwidth binds (the test exercises one such
#: regime separately).
METRIC_FIELDS = {
    "latency_ns": ("router_delay_ns", "t_tile_overhead_ns", "corr_latency"),
    "energy_pj": ("e_mac_pj", "e_reg_pj_bit", "e_core_sram_pj_bit",
                  "e_chip_sram_pj_bit", "e_dram_pj_bit", "e_router_pj_bit",
                  "corr_energy"),
    "area_mm2": ("a_pe", "a_sram_per_mb", "a_router", "a_core_overhead",
                 "a_chiplet_overhead", "corr_area"),
    "cost_usd": ("wafer_cost", "defect_density_mm2", "c_substrate_mm2",
                 "c_process", "corr_cost"),
}


# ---------------------------------------------------------------------------
# TPU v5e-class target constants used by Level B (autosharding / roofline)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TPUTarget:
    peak_bf16_tflops: float = 197.0       # per chip
    hbm_gbps: float = 819.0               # per chip
    ici_link_gbps: float = 50.0           # per link per direction
    ici_links_per_chip: int = 4           # 2D torus: +/-x, +/-y
    hbm_bytes: float = 16e9               # capacity per chip
    vmem_bytes: float = 128 * 2**20       # on-chip vector memory


DEFAULT_TPU = TPUTarget()
