"""Cycle-approximate systolic-array simulator (ScaleSim [23] stand-in).

The paper validates its analytical model against ScaleSim on a four-chip
transformer (8x8 PE arrays) and reports <= 9.8% latency error (Sec. V-A).
ScaleSim is not available offline, so we implement the same class of
simulator: an output-stationary systolic array executed fold-by-fold with
explicit pipeline fill/drain skew and double-buffered operand streaming —
the standard ScaleSim timing equations — and validate our analytical model
against it in ``benchmarks/bench_validation.py``.
"""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class SystolicConfig:
    array_x: int = 8            # PE rows
    array_y: int = 8            # PE cols
    dram_bw_gbps: float = 128.0
    clock_ghz: float = 1.0
    bytes_per_elem: int = 2
    dma_setup_cycles: int = 16  # per-fold DMA/descriptor overhead


def simulate_matmul(M: int, N: int, K: int, cfg: SystolicConfig) -> dict:
    """Output-stationary systolic execution of C[M,N] = A[M,K] @ B[K,N].

    The array computes an (array_x x array_y) output tile per fold; a fold
    streams K partial sums through the array with (array_x + array_y - 2)
    fill/drain skew (ScaleSim OS timing: 2*rows + cols + K - 2 per fold).
    Cycle-level effects the analytical model deliberately abstracts — and
    which the Sec.-V-A validation therefore measures:
      * the FIRST fold's operand load is not overlapped (cold start),
      * each fold pays a DMA setup overhead,
      * edge folds run at their true (rows, cols), not the padded tile.
    """
    X, Y = cfg.array_x, cfg.array_y
    folds_m = math.ceil(M / X)
    folds_n = math.ceil(N / Y)
    bytes_per_cycle = cfg.dram_bw_gbps / cfg.clock_ghz     # bytes / cycle

    def stream_cycles(rows, cols):
        a = rows * K * cfg.bytes_per_elem
        b = K * cols * cfg.bytes_per_elem
        c = rows * cols * cfg.bytes_per_elem
        return (a + b + c) / bytes_per_cycle

    cycles = stream_cycles(min(X, M), min(Y, N))           # cold start
    for fm in range(folds_m):
        rows = min(X, M - fm * X)
        for fn in range(folds_n):
            cols = min(Y, N - fn * Y)
            compute = 2 * rows + cols + K - 2
            cycles += max(compute, stream_cycles(rows, cols)) \
                + cfg.dma_setup_cycles
    total_macs = M * N * K
    return dict(
        cycles=cycles,
        latency_ns=cycles / cfg.clock_ghz,
        utilization=total_macs / (cycles * X * Y),
        macs=total_macs,
    )


def simulate_pipeline(stages, transfers) -> float:
    """Reference pipelined execution of dependent matmul stages on distinct
    chips (paper Fig. 5a): event-driven longest-path over (stage delays,
    transfer delays) — used to validate the StageGraph model."""
    from .perf_model import StageGraph, Stage
    stage_objs = [Stage(f"v{i}", d) for i, d in enumerate(stages)]
    edges = []
    for (u, v, d) in transfers:
        stage_objs.append(Stage(f"e{u},{v}", d, kind="transfer"))
        t = len(stage_objs) - 1
        edges.append((u, t))
        edges.append((t, v))
    return StageGraph(stage_objs, edges).latency()
