"""In-package network model (paper Sec. III-A Def. 2 + Sec. III-C).

Communication graph: flows (src node, dst node, bandwidth-requirement bwr,
volume bytes).  The network is one of four deterministic-routing topology
families over up to ``MAX_NODES`` chiplet nodes plus one DRAM node:

    0 chain   — 1D line, dimension-order routing
    1 ring    — shortest direction, clockwise on tie
    2 mesh    — row-major 2D grid (rows = largest divisor <= sqrt(n)), XY routing
    3 star    — hub at node 0

DRAM (memory-controller) node = index ``n_nodes``; it attaches to column-0
nodes of a mesh and to node 0 otherwise (paper Fig. 1: boundary chiplets
connect to DRAM).

Flow control (paper Sec. III-C): links are provisioned uniformly at the
*hotspot* requirement, capped by the packaging's feasible per-link bandwidth;
if a link's total load exceeds its bandwidth, flows through it are throttled
in proportion to their requirements:

    ebw_c^f = bwr_f * min(1, bw_c / load_c),   ebw_f = min over links on path
    D(e)    = |f| * t_s + bytes / ebw_f

Routing is precomputed on host into next-hop tables (numpy); the contention
evaluation walks paths with ``lax.scan`` so it jits/vmaps with the rest of the
evaluator.
"""

from __future__ import annotations

import math
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

MAX_NODES = 36          # paper Sec. IV-C: placement field is up to 36 nodes
N_TOT = MAX_NODES + 1   # + DRAM node
MAX_HOPS = 40           # >= diameter of any supported topology (chain-36 + DRAM)
FAM_CHAIN, FAM_RING, FAM_MESH, FAM_STAR = 0, 1, 2, 3
N_FAMILIES = 4


def _mesh_dims(n: int):
    r = int(math.isqrt(n))
    while r > 1 and n % r != 0:
        r -= 1
    return r, n // r          # rows, cols


def _build_next_hop(family: int, n: int) -> np.ndarray:
    """Next-hop table NH[s, d] for n chiplet nodes + DRAM node (= index n).

    NH[s, d] = next node on the deterministic path s -> d; NH[d, d] = d.
    Unused node slots route to themselves.
    """
    # default NH[s, d] = d (arrived / unused slots terminate immediately)
    NH = np.tile(np.arange(N_TOT, dtype=np.int16)[None, :], (N_TOT, 1))
    dram = n

    def set_hop(s, d, nxt):
        NH[s, d] = nxt

    if family == FAM_MESH:
        rows, cols = _mesh_dims(n)
    for s in range(n):
        for d in range(n):
            if s == d:
                continue
            if family == FAM_CHAIN:
                nxt = s + 1 if d > s else s - 1
            elif family == FAM_RING:
                fwd = (d - s) % n
                bwd = (s - d) % n
                nxt = (s + 1) % n if fwd <= bwd else (s - 1) % n
            elif family == FAM_MESH:
                sr, sc = divmod(s, cols)
                dr, dc = divmod(d, cols)
                if sc != dc:                       # X first
                    nxt = sr * cols + (sc + (1 if dc > sc else -1))
                else:                              # then Y
                    nxt = (sr + (1 if dr > sr else -1)) * cols + sc
            else:                                  # star via hub 0
                nxt = d if s == 0 else 0
            set_hop(s, d, nxt)
    # DRAM attachments
    if family == FAM_MESH:
        rows, cols = _mesh_dims(n)
        for d in range(n):
            dr = d // cols
            set_hop(dram, d, dr * cols)            # enter at column 0, own row
        for s in range(n):
            sr, sc = divmod(s, cols)
            set_hop(s, dram, dram if sc == 0 else sr * cols + (sc - 1))
    else:
        # DRAM attaches to node 0 only: enter/leave the network via node 0.
        for d in range(n):
            NH[dram, d] = np.int16(0)
        for s in range(n):
            NH[s, dram] = np.int16(dram) if s == 0 else NH[s, 0]
    return NH


@lru_cache(maxsize=1)
def next_hop_tables() -> np.ndarray:
    """Stacked NH tables, indexed by topo_code = family * (MAX_NODES+1) + n."""
    out = np.zeros((N_FAMILIES * (MAX_NODES + 1), N_TOT, N_TOT), np.int16)
    for fam in range(N_FAMILIES):
        for n in range(1, MAX_NODES + 1):
            out[fam * (MAX_NODES + 1) + n] = _build_next_hop(fam, n)
    return out


def topo_code(family: int, n_nodes: int) -> int:
    return family * (MAX_NODES + 1) + n_nodes


# ---------------------------------------------------------------------------
# jnp contention evaluation
# ---------------------------------------------------------------------------
def route_links(nh, src, dst):
    """Walk paths for all flows.  nh: (N_TOT,N_TOT) int; src/dst: (F,) int.
    Returns (links, hops): links (MAX_HOPS, F, 2) int32 with (u,v) per hop
    (u==v once arrived => no link), hops (F,) float."""
    def step(cur, _):
        nxt = nh[cur, dst].astype(jnp.int32)
        return nxt, jnp.stack([cur, nxt], axis=-1)
    _, links = jax.lax.scan(step, src.astype(jnp.int32), None,
                            length=MAX_HOPS)
    hops = jnp.sum(links[:, :, 0] != links[:, :, 1], axis=0).astype(jnp.float32)
    return links, hops


def evaluate_network(nh, src, dst, bwr, vol_bytes, fmask,
                     link_bw, dram_bw, router_delay_ns, n_nodes):
    """Contention-aware per-flow delay (paper Sec. III-C last equation).

    nh:        (N_TOT, N_TOT) next-hop table (jnp int)
    src, dst:  (F,) node ids per flow (DRAM node = n_nodes)
    bwr:       (F,) bandwidth requirement GB/s
    vol_bytes: (F,) transfer volume
    fmask:     (F,) bool valid-flow mask
    link_bw:   provisioned chiplet-link bandwidth (GB/s, scalar)
    Returns dict(delay_ns (F,), hops (F,), hotspot_load, link_bits_hops).
    """
    Fd = jnp.float32
    links, hops = route_links(nh, src, dst)
    u = links[:, :, 0].astype(jnp.int32)        # (H, F)
    v = links[:, :, 1].astype(jnp.int32)
    active = (u != v) & fmask[None, :]
    lid = u * N_TOT + v                          # directed link id

    load = jnp.zeros((N_TOT * N_TOT,), Fd)
    load = load.at[lid.reshape(-1)].add(
        jnp.where(active, bwr[None, :], 0.0).reshape(-1))
    hotspot = jnp.max(load)

    # per-link capacity: DRAM-attached links run at dram_bw, others at link_bw
    is_dram_link = (u == n_nodes) | (v == n_nodes)
    cap = jnp.where(is_dram_link, Fd(dram_bw), Fd(link_bw))   # (H, F)
    link_load = load[lid]                                      # (H, F)
    ratio = jnp.where(active,
                      jnp.minimum(1.0, cap / jnp.maximum(link_load, 1e-9)),
                      1.0)
    min_ratio = jnp.min(ratio, axis=0)                         # (F,)
    ebw = jnp.maximum(bwr * min_ratio, 1e-9)
    delay = hops * Fd(router_delay_ns) + vol_bytes / ebw
    delay = jnp.where(fmask, delay, 0.0)

    # bits x hops on chiplet-to-chiplet links (for D2D energy); DRAM-link
    # traversals counted separately (DRAM access energy).
    d2d_hops = jnp.sum(jnp.where(active & ~is_dram_link, 1.0, 0.0), axis=0)
    dram_hops = jnp.sum(jnp.where(active & is_dram_link, 1.0, 0.0), axis=0)
    return dict(delay_ns=delay, hops=hops, hotspot=hotspot,
                d2d_byte_hops=jnp.sum(vol_bytes * d2d_hops * fmask),
                dram_bytes=jnp.sum(vol_bytes * jnp.minimum(dram_hops, 1.0)
                                   * fmask),
                router_byte_hops=jnp.sum(vol_bytes * hops * fmask))
