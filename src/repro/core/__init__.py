"""Monad core: cost-aware co-design for chiplet-based spatial accelerators.

The paper's primary contribution as a composable JAX library:

* workload IR + graphs .......... ``repro.core.workload``, ``presets``
* Map/Bind/Reduce formalism ...... ``repro.core.mapping``
* dataflow / reuse analysis ...... ``repro.core.dataflow``
* pipeline performance model ..... ``repro.core.perf_model``, ``evaluate``
* network contention model ....... ``repro.core.network``
* energy / area / cost models .... ``repro.core.energy``, ``cost``
* uniform encoding + BO x SA ..... ``repro.core.encoding``, ``optimizer``
* Simba / NN-Baton baselines ..... ``repro.core.baselines``
* validation simulator ........... ``repro.core.simulator``
"""

from .constants import (DEFAULT_TECH, DEFAULT_TPU, PACKAGING_NAMES,
                        PKG_ACTIVE, PKG_ORGANIC, PKG_PASSIVE, TechConstants,
                        TPUTarget)
from .workload import (Edge, TensorRef, Workload, WorkloadGraph, contraction,
                       conv2d, matmul, mttkrp, workload_features,
                       workload_signature)
from .evaluate import SystemSpec, evaluate_system, make_batch_evaluator
from .encoding import (ALL_FIELDS, ARCH_FIELDS, BO_FIELDS, INTEG_FIELDS,
                       SA_FIELDS, DesignSpace, PortableDesign, SpaceDigest,
                       balanced_init, from_portable, migrate, mutate,
                       random_design, repair, space_digest, to_portable)
from .optimizer import (METRIC_KEYS, OBJ_COST_EDP, OBJ_EDP, OBJ_ENERGY,
                        OBJ_LATENCY, SAConfig, SearchResult, make_sa,
                        optimize, pareto_front, two_stage_optimize)
from .baselines import Baseline, make_baseline
from .cost import die_cost, die_yield, dies_per_wafer, monolithic_cost, package_cost
from . import presets

__all__ = [k for k in dir() if not k.startswith("_")]
