"""Uniform encoding of the architecture + integration design space
(paper Sec. IV-B, Fig. 6a).

Architecture fields (per workload):
    shape   (W, 6)   geometry of PE / core / chiplet arrays (raw dims)
    spatial (W, 6)   spatially-parallelized loop per array dim per level
    order   (W, 3, L) loop permutation per level (execution order)
    tiling  (W, 2, L) tile sizes (core tile t1, chiplet tile t2)
    pipe    (W,)     pipelined loop id (== L means "not pipelined")
    logB    ()       log2 of pipeline tick count

Integration fields:
    packaging ()       0 organic / 1 passive / 2 active interposer
    family    ()       network topology family (chain/ring/mesh/star)
    placement (W*CH,)  global chiplet id -> network node id (a permutation
                       prefix; the paper's "placement" field, <= 36 nodes)

The BO engine owns the low-dimensional fields {shape, spatial, packaging,
family, logB}; the SA engine owns the high-dimensional {order, tiling,
placement, pipe} (paper Sec. IV-C).
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from .evaluate import SystemSpec
from .network import MAX_NODES, N_FAMILIES
from .workload import (MAX_LOOPS, graph_feature_rows, workload_signature)


@dataclasses.dataclass(frozen=True)
class DesignSpace:
    """Static bounds of the explorable space for one SystemSpec."""
    spec: SystemSpec
    max_shape: Tuple[int, ...] = (16, 16, 4, 4, 6, 6)   # per-level max dims
    max_logB: int = 6
    max_total_pes: int = 0          # 0 = unconstrained (Fig-7 fairness knob)
    fixed_packaging: int = -1       # >=0 pins the field (ablation studies)
    fixed_family: int = -1
    allow_pipeline: bool = True

    @property
    def W(self):
        return self.spec.W

    @property
    def CH(self):
        return self.spec.CH

    @property
    def n_loops(self) -> np.ndarray:
        return self.spec.arrays["loopmask"].sum(axis=1).astype(np.int32)

    @property
    def bounds(self) -> np.ndarray:
        return self.spec.arrays["bounds"]

    def max_nodes(self) -> int:
        return min(MAX_NODES, self.W * self.CH)


def _rand_perm_rows(key, W, levels, L):
    keys = jax.random.split(key, W * levels)
    perms = jnp.stack([jax.random.permutation(k, L) for k in keys])
    return perms.reshape(W, levels, L).astype(jnp.int32)


def random_design(key, space: DesignSpace, nl=None, bounds=None) -> Dict:
    """Uniform random design point (the paper's 'Random' baseline).

    ``nl``/``bounds`` may be passed as traced arrays (from the workload
    arrays) so the compiled sampler is workload-independent — same
    contract as ``mutate``."""
    W, CH, L = space.W, space.CH, MAX_LOOPS
    ks = jax.random.split(key, 10)
    mx = jnp.asarray(space.max_shape, jnp.int32)
    shape = jax.random.randint(ks[0], (W, 6), 1, mx + 1)
    nl = jnp.asarray(space.n_loops if nl is None else nl)
    spatial = jax.random.randint(ks[1], (W, 6), 0, jnp.maximum(nl, 1)[:, None])
    order = _rand_perm_rows(ks[2], W, 3, L)
    bounds = jnp.asarray(space.bounds if bounds is None else bounds)
    tmax = jnp.maximum(bounds, 1)
    u = jax.random.uniform(ks[3], (W, 2, L))
    tiling = jnp.maximum(
        1, (tmax[:, None, :].astype(jnp.float32) ** u)).astype(jnp.int32)
    pipe = jnp.where(
        jnp.asarray(space.allow_pipeline)
        & (jax.random.uniform(ks[4], (W,)) < 0.5),
        jax.random.randint(ks[5], (W,), 0, jnp.maximum(nl, 1)),
        jnp.full((W,), L, jnp.int32)).astype(jnp.int32)
    logB = jnp.where(space.allow_pipeline,
                     jax.random.randint(ks[6], (), 0, space.max_logB + 1), 0)
    packaging = (jnp.asarray(space.fixed_packaging, jnp.int32)
                 if space.fixed_packaging >= 0
                 else jax.random.randint(ks[7], (), 0, 3))
    family = (jnp.asarray(space.fixed_family, jnp.int32)
              if space.fixed_family >= 0
              else jax.random.randint(ks[8], (), 0, N_FAMILIES))
    placement = jax.random.permutation(ks[9], W * CH).astype(jnp.int32)
    return dict(shape=shape, spatial=spatial, order=order, tiling=tiling,
                pipe=pipe, logB=jnp.asarray(logB, jnp.int32),
                packaging=jnp.asarray(packaging, jnp.int32),
                family=jnp.asarray(family, jnp.int32), placement=placement)


def balanced_init(key, space: DesignSpace, total_pes: int = 4096) -> Dict:
    """Paper Sec. IV-B: assign PEs to each workload proportionally to its
    MAC count so pipeline stages are roughly balanced."""
    d = random_design(key, space)
    macs = np.array([w.macs for w in space.spec.graph.workloads], np.float64)
    share = macs / macs.sum()
    pes = np.maximum((share * total_pes).astype(np.int64), 64)
    side = np.clip(np.sqrt(pes / 4).astype(np.int32), 1,
                   np.asarray(space.max_shape)[:2].min())
    shape = np.array(d["shape"])
    shape[:, 0] = side
    shape[:, 1] = side
    shape[:, 2:4] = 2
    shape[:, 4:6] = 1
    d["shape"] = jnp.asarray(shape)
    return d


# ---------------------------------------------------------------------------
# SA neighborhood moves (jit-able; one random field mutation per call)
# ---------------------------------------------------------------------------
ARCH_FIELDS = ("shape", "spatial", "order", "tiling", "pipe")
INTEG_FIELDS = ("packaging", "family", "placement")
ALL_FIELDS = ARCH_FIELDS + INTEG_FIELDS
# high-dimensional fields owned by the SA engine (paper Sec. IV-C)
SA_FIELDS = ("order", "tiling", "pipe", "placement")
# low-dimensional fields owned by the Bayesian engine
BO_FIELDS = ("shape", "spatial", "packaging", "family")


def mutate(key, design: Dict, space: DesignSpace,
           fields: Tuple[str, ...] = ALL_FIELDS,
           nl=None, bounds=None) -> Dict:
    """One random neighbor move restricted to ``fields`` (static tuple).
    Field subsets drive the Fig.-8 ablation ladder (Res/Dfw/Arch/Net/Pkg/...)
    and the nested BO+SA engine (SA owns the high-dim fields).

    ``nl``/``bounds`` may be passed as traced arrays (from the workload
    arrays) so the compiled move kernel is workload-independent."""
    W, CH, L = space.W, space.CH, MAX_LOOPS
    ks = jax.random.split(key, 12)
    nl = jnp.maximum(jnp.asarray(space.n_loops if nl is None else nl), 1)
    bounds_arr = jnp.asarray(space.bounds if bounds is None else bounds)
    wsel = jax.random.randint(ks[0], (), 0, W)

    d = {k: v for k, v in design.items()}

    # --- architecture moves -------------------------------------------------
    def mv_shape(d):
        i = jax.random.randint(ks[2], (), 0, 6)
        delta = jax.random.choice(ks[3], jnp.asarray([-2, -1, 1, 2]))
        mx = jnp.asarray(space.max_shape, jnp.int32)
        s = d["shape"].at[wsel, i].add(delta)
        d["shape"] = jnp.clip(s, 1, mx[None, :])
        return d

    def mv_spatial(d):
        i = jax.random.randint(ks[2], (), 0, 6)
        v = jax.random.randint(ks[3], (), 0, nl[wsel])
        d["spatial"] = d["spatial"].at[wsel, i].set(v)
        return d

    def mv_order(d):
        lvl = jax.random.randint(ks[2], (), 0, 3)
        i = jax.random.randint(ks[3], (), 0, L)
        j = jax.random.randint(ks[4], (), 0, L)
        row = d["order"][wsel, lvl]
        a, b = row[i], row[j]
        row = row.at[i].set(b).at[j].set(a)
        d["order"] = d["order"].at[wsel, lvl].set(row)
        return d

    def mv_tiling(d):
        lvl = jax.random.randint(ks[2], (), 0, 2)
        i = jax.random.randint(ks[3], (), 0, nl[wsel])
        f = jax.random.choice(ks[4], jnp.asarray([0.25, 0.5, 2.0, 4.0]))
        bmax = bounds_arr[wsel, i]
        t = d["tiling"][wsel, lvl, i].astype(jnp.float32) * f
        t = jnp.clip(t.astype(jnp.int32), 1, bmax)
        d["tiling"] = d["tiling"].at[wsel, lvl, i].set(
            jnp.maximum(t, 1).astype(jnp.int32))
        return d

    def mv_pipe(d):
        on = jax.random.uniform(ks[2]) < (0.7 if space.allow_pipeline else 0.0)
        loop = jax.random.randint(ks[3], (), 0, nl[wsel])
        d["pipe"] = d["pipe"].at[wsel].set(
            jnp.where(on, loop, jnp.int32(L)).astype(jnp.int32))
        d["logB"] = jnp.where(
            on, jnp.clip(d["logB"]
                         + jax.random.randint(ks[4], (), -1, 2),
                         0, space.max_logB),
            d["logB"]).astype(jnp.int32)
        return d

    # --- integration moves ---------------------------------------------------
    def mv_packaging(d):
        if space.fixed_packaging >= 0:
            return d
        d["packaging"] = jax.random.randint(ks[2], (), 0, 3)
        return d

    def mv_family(d):
        if space.fixed_family >= 0:
            return d
        d["family"] = jax.random.randint(ks[2], (), 0, N_FAMILIES)
        return d

    def mv_placement(d):
        i = jax.random.randint(ks[2], (), 0, W * CH)
        j = jax.random.randint(ks[3], (), 0, W * CH)
        p = d["placement"]
        a, b = p[i], p[j]
        d["placement"] = p.at[i].set(b).at[j].set(a)
        return d

    all_moves = dict(shape=mv_shape, spatial=mv_spatial, order=mv_order,
                     tiling=mv_tiling, pipe=mv_pipe, packaging=mv_packaging,
                     family=mv_family, placement=mv_placement)
    moves = [all_moves[f] for f in fields]
    mid = jax.random.randint(ks[1], (), 0, len(moves))
    branches = [lambda op, m=m: m(dict(d)) for m in moves]
    return jax.lax.switch(mid, branches, 0)


def feasibility_penalty(space: DesignSpace, design: Dict, metrics: Dict):
    """Soft constraints: total chiplets <= placeable nodes; optional PE budget
    (Fig. 7 iso-PE comparisons).  Returned as a multiplicative penalty."""
    n_chips = jnp.sum(design["shape"][:, 4] * design["shape"][:, 5])
    over_nodes = jnp.maximum(
        n_chips - jnp.int32(space.max_nodes()), 0).astype(jnp.float32)
    pes = jnp.sum(design["shape"][:, 0] * design["shape"][:, 1]
                  * design["shape"][:, 2] * design["shape"][:, 3]
                  * design["shape"][:, 4] * design["shape"][:, 5])
    over_pes = jnp.where(
        space.max_total_pes > 0,
        jnp.maximum(pes - space.max_total_pes, 0).astype(jnp.float32), 0.0)
    return 1.0 + over_nodes + over_pes / 64.0


# ---------------------------------------------------------------------------
# portable (spec-independent) design IR — the cross-workload transfer
# substrate.  A raw design is a pytree of arrays padded to ONE SystemSpec's
# (W, CH, E); a PortableDesign re-keys those arrays by *workload identity*
# (``workload_signature``) so knowledge moves between spec spaces:
#
#     design_A --to_portable--> PortableDesign --from_portable--> design_B
#
# ``migrate`` composes the two; ``repair`` makes any design dict feasible
# under a destination DesignSpace (permutation fields re-ranked, bounds
# clipped, chiplet-count / PE-budget constraints enforced), so migrated
# seeds are always legal population members.
# ---------------------------------------------------------------------------
_PLACE_FAR = 1e15          # placement rank key for unmatched chiplet slots


@dataclasses.dataclass(frozen=True)
class SpaceDigest:
    """The facts about an exploration problem that migration needs — a
    pure-data view of (SystemSpec.graph, DesignSpace) that is JSON-portable,
    so the cross-spec archive manifest can persist it and a later process
    can migrate out of a cached archive *without* reconstructing the source
    ``WorkloadGraph``."""
    W: int
    CH: int
    signatures: Tuple[str, ...]        # per-workload identity hashes
    features: np.ndarray               # (W, WL_FEATURE_DIM) matching rows
    bounds: np.ndarray                 # (W, MAX_LOOPS) padded loop bounds
    n_loops: np.ndarray                # (W,)
    max_shape: Tuple[int, ...]
    max_logB: int
    max_total_pes: int
    fixed_packaging: int
    fixed_family: int
    allow_pipeline: bool

    def max_nodes(self) -> int:
        return min(MAX_NODES, self.W * self.CH)

    def to_json_dict(self) -> Dict:
        return dict(
            W=int(self.W), CH=int(self.CH),
            signatures=list(self.signatures),
            features=np.asarray(self.features, np.float64).tolist(),
            bounds=np.asarray(self.bounds, np.int64).tolist(),
            n_loops=np.asarray(self.n_loops, np.int64).tolist(),
            max_shape=[int(v) for v in self.max_shape],
            max_logB=int(self.max_logB),
            max_total_pes=int(self.max_total_pes),
            fixed_packaging=int(self.fixed_packaging),
            fixed_family=int(self.fixed_family),
            allow_pipeline=bool(self.allow_pipeline))

    @classmethod
    def from_dict(cls, d: Dict) -> "SpaceDigest":
        return cls(
            W=int(d["W"]), CH=int(d["CH"]),
            signatures=tuple(d["signatures"]),
            features=np.asarray(d["features"], np.float64),
            bounds=np.asarray(d["bounds"], np.int64),
            n_loops=np.asarray(d["n_loops"], np.int64),
            max_shape=tuple(int(v) for v in d["max_shape"]),
            max_logB=int(d["max_logB"]),
            max_total_pes=int(d["max_total_pes"]),
            fixed_packaging=int(d["fixed_packaging"]),
            fixed_family=int(d["fixed_family"]),
            allow_pipeline=bool(d["allow_pipeline"]))


def space_digest(space: DesignSpace) -> SpaceDigest:
    graph = space.spec.graph
    return SpaceDigest(
        W=space.W, CH=space.CH,
        signatures=tuple(workload_signature(w) for w in graph.workloads),
        features=graph_feature_rows(graph),
        bounds=np.asarray(space.bounds, np.int64),
        n_loops=np.asarray(space.n_loops, np.int64),
        max_shape=tuple(space.max_shape), max_logB=space.max_logB,
        max_total_pes=space.max_total_pes,
        fixed_packaging=space.fixed_packaging,
        fixed_family=space.fixed_family,
        allow_pipeline=space.allow_pipeline)


SpaceLike = Union[DesignSpace, SpaceDigest, Dict]


def _as_digest(x: SpaceLike) -> SpaceDigest:
    if isinstance(x, SpaceDigest):
        return x
    if isinstance(x, DesignSpace):
        return space_digest(x)
    if isinstance(x, dict):
        return SpaceDigest.from_dict(x)
    raise TypeError(f"cannot digest {type(x).__name__}")


@dataclasses.dataclass
class PortableDesign:
    """One design point in spec-independent form: per-workload records
    (each carrying the workload's identity signature + feature row and its
    architecture fields) plus the global integration fields.  ``place_key``
    is the workload's chiplet slots' positions in the source placement
    permutation — relative order, not absolute node ids — so placements
    survive re-ranking into any destination permutation length."""
    records: List[Dict]
    logB: int
    packaging: int
    family: int


def to_portable(design: Dict, src: SpaceLike) -> PortableDesign:
    dg = _as_digest(src)
    d = {k: np.asarray(v) for k, v in design.items()}
    records = []
    for wi in range(dg.W):
        g0 = wi * dg.CH
        records.append(dict(
            signature=dg.signatures[wi],
            features=np.asarray(dg.features[wi], np.float64),
            shape=d["shape"][wi].copy(),
            spatial=d["spatial"][wi].copy(),
            order=d["order"][wi].copy(),
            tiling=d["tiling"][wi].copy(),
            pipe=np.int32(d["pipe"][wi]),
            place_key=d["placement"][g0:g0 + dg.CH].astype(np.float64)))
    return PortableDesign(records=records, logB=int(d["logB"]),
                          packaging=int(d["packaging"]),
                          family=int(d["family"]))


def _match_records(records: Sequence[Dict], dg: SpaceDigest) -> List[int]:
    """One source record per destination workload: first-unused exact
    signature match, then any exact match, then nearest feature row
    (unused records preferred on ties).  Deterministic."""
    sigs = [r["signature"] for r in records]
    feats = np.stack([np.asarray(r["features"], np.float64)
                      for r in records])
    used: set = set()
    out: List[int] = []
    for wi in range(dg.W):
        cand = [k for k, s in enumerate(sigs) if s == dg.signatures[wi]]
        j = next((k for k in cand if k not in used),
                 cand[0] if cand else None)
        if j is None:
            f = np.asarray(dg.features[wi], np.float64)
            if feats.shape[1] == f.shape[0]:
                dist = np.linalg.norm(feats - f[None, :], axis=1)
            else:           # feature layout drifted across versions: any
                #             record is as good as any other
                dist = np.arange(len(records), dtype=np.float64)
            dist = dist + 1e-9 * np.asarray(
                [k in used for k in range(len(records))], np.float64)
            j = int(np.argmin(dist))
        used.add(j)
        out.append(j)
    return out


def from_portable(pd: PortableDesign, dst: SpaceLike) -> Dict:
    """Materialize a PortableDesign into a destination space's raw design
    dict.  Always ends in ``repair``, so the result is feasible whatever
    the source/destination mismatch."""
    dg = _as_digest(dst)
    if not pd.records:
        raise ValueError("cannot materialize an empty PortableDesign")
    W, CH, L = dg.W, dg.CH, MAX_LOOPS
    match = _match_records(pd.records, dg)
    shape = np.ones((W, 6), np.int32)
    spatial = np.zeros((W, 6), np.int32)
    order = np.zeros((W, 3, L), np.int32)
    tiling = np.ones((W, 2, L), np.int32)
    pipe = np.full((W,), L, np.int32)
    keys = np.empty((W * CH,), np.float64)
    for wi, j in enumerate(match):
        r = pd.records[j]
        shape[wi] = r["shape"]
        spatial[wi] = r["spatial"]
        order[wi] = r["order"]
        tiling[wi] = r["tiling"]
        pipe[wi] = r["pipe"]
        pk = np.asarray(r["place_key"], np.float64)
        for c in range(CH):
            g = wi * CH + c
            keys[g] = pk[c] if c < len(pk) else _PLACE_FAR + g
    design = dict(
        shape=shape, spatial=spatial, order=order, tiling=tiling, pipe=pipe,
        logB=np.asarray(pd.logB, np.int32),
        packaging=np.asarray(pd.packaging, np.int32),
        family=np.asarray(pd.family, np.int32),
        placement=keys)           # repair re-ranks into a permutation
    return repair(design, dg)


def migrate(design: Dict, src: SpaceLike, dst: SpaceLike) -> Dict:
    """Move one design between spec spaces: re-key its per-workload fields
    by workload identity, re-rank its placement, repair into feasibility.
    Migrating a repaired design through a superset space (same workloads,
    >= CH, >= bounds) and back is the identity."""
    return from_portable(to_portable(design, src), dst)


def portable_signature(design: Dict, space: SpaceLike) -> str:
    """Content hash of one design in its portable form: the per-workload
    records (each keyed by the workload's structural signature) plus the
    global integration fields.  Two repaired designs in the same space
    hash equal iff their portable forms are identical, so the transfer
    seeding path uses this to drop migrated seeds that duplicate points
    the destination archive already holds (migration is the identity on
    same-space repaired designs, so an archive's own front re-offered as
    seeds dedups to nothing)."""
    pd = to_portable(design, space)
    h = hashlib.sha256()
    h.update(repr((int(pd.logB), int(pd.packaging),
                   int(pd.family))).encode())
    for r in pd.records:
        h.update(r["signature"].encode())
        for k in ("shape", "spatial", "order", "tiling", "pipe"):
            h.update(np.asarray(r[k], np.int64).tobytes())
        h.update(np.asarray(r["place_key"], np.float64).tobytes())
    return h.hexdigest()[:16]


def _rank(values: np.ndarray) -> np.ndarray:
    """Stable rank — any real-valued key vector becomes a permutation of
    ``range(n)`` preserving relative order; a permutation maps to itself."""
    return np.argsort(np.argsort(values, kind="stable"), kind="stable")


def repair(design: Dict, space: SpaceLike) -> Dict:
    """Project a design dict onto the feasible set of a destination space:
    every field clipped into its legal range, ``order``/``placement``
    re-ranked into valid permutations, and the hard constraints
    (chiplet count <= placeable nodes, optional total-PE budget) enforced
    by halving the widest offending dims.  Idempotent; pure numpy."""
    dg = _as_digest(space)
    W, CH, L = dg.W, dg.CH, MAX_LOOPS
    d = {k: np.array(v) for k, v in design.items()}
    mx = np.asarray(dg.max_shape, np.int64)
    nl = np.maximum(np.asarray(dg.n_loops, np.int64), 1)
    bounds = np.maximum(np.asarray(dg.bounds, np.int64), 1)

    d["shape"] = np.clip(d["shape"].reshape(W, 6), 1,
                         mx[None, :]).astype(np.int32)
    d["spatial"] = np.clip(d["spatial"].reshape(W, 6), 0,
                           (nl - 1)[:, None]).astype(np.int32)
    o = d["order"].reshape(W * 3, L)
    d["order"] = np.stack([_rank(row) for row in o]).astype(
        np.int32).reshape(W, 3, L)
    d["tiling"] = np.clip(d["tiling"].reshape(W, 2, L), 1,
                          bounds[:, None, :]).astype(np.int32)
    pipe = d["pipe"].reshape(W).astype(np.int64)
    pipe = np.where((pipe < 0) | (pipe >= nl), L, pipe)
    if not dg.allow_pipeline:
        pipe = np.full((W,), L, np.int64)
    d["pipe"] = pipe.astype(np.int32)
    logB = int(np.clip(np.asarray(d["logB"]).reshape(()), 0, dg.max_logB))
    d["logB"] = np.asarray(logB if dg.allow_pipeline else 0, np.int32)
    pkg = int(np.clip(np.asarray(d["packaging"]).reshape(()), 0, 2))
    d["packaging"] = np.asarray(
        dg.fixed_packaging if dg.fixed_packaging >= 0 else pkg, np.int32)
    fam = int(np.clip(np.asarray(d["family"]).reshape(()), 0,
                      N_FAMILIES - 1))
    d["family"] = np.asarray(
        dg.fixed_family if dg.fixed_family >= 0 else fam, np.int32)
    d["placement"] = _rank(
        np.asarray(d["placement"], np.float64).reshape(W * CH)).astype(
            np.int32)

    # hard constraint 1: total chiplets <= placeable network nodes
    sh = d["shape"].astype(np.int64)
    while int((sh[:, 4] * sh[:, 5]).sum()) > dg.max_nodes():
        w = int(np.argmax(sh[:, 4] * sh[:, 5]))
        j = 4 + int(np.argmax(sh[w, 4:6]))
        if sh[w, j] <= 1:
            break
        sh[w, j] //= 2
    # hard constraint 2: optional total-PE budget
    if dg.max_total_pes > 0:
        while int(np.prod(sh, axis=1).sum()) > dg.max_total_pes:
            w = int(np.argmax(np.prod(sh, axis=1)))
            j = int(np.argmax(sh[w]))
            if sh[w, j] <= 1:
                break
            sh[w, j] //= 2
    d["shape"] = sh.astype(np.int32)
    return d
