"""Uniform encoding of the architecture + integration design space
(paper Sec. IV-B, Fig. 6a).

Architecture fields (per workload):
    shape   (W, 6)   geometry of PE / core / chiplet arrays (raw dims)
    spatial (W, 6)   spatially-parallelized loop per array dim per level
    order   (W, 3, L) loop permutation per level (execution order)
    tiling  (W, 2, L) tile sizes (core tile t1, chiplet tile t2)
    pipe    (W,)     pipelined loop id (== L means "not pipelined")
    logB    ()       log2 of pipeline tick count

Integration fields:
    packaging ()       0 organic / 1 passive / 2 active interposer
    family    ()       network topology family (chain/ring/mesh/star)
    placement (W*CH,)  global chiplet id -> network node id (a permutation
                       prefix; the paper's "placement" field, <= 36 nodes)

The BO engine owns the low-dimensional fields {shape, spatial, packaging,
family, logB}; the SA engine owns the high-dimensional {order, tiling,
placement, pipe} (paper Sec. IV-C).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .evaluate import SystemSpec
from .network import MAX_NODES, N_FAMILIES
from .workload import MAX_LOOPS


@dataclasses.dataclass(frozen=True)
class DesignSpace:
    """Static bounds of the explorable space for one SystemSpec."""
    spec: SystemSpec
    max_shape: Tuple[int, ...] = (16, 16, 4, 4, 6, 6)   # per-level max dims
    max_logB: int = 6
    max_total_pes: int = 0          # 0 = unconstrained (Fig-7 fairness knob)
    fixed_packaging: int = -1       # >=0 pins the field (ablation studies)
    fixed_family: int = -1
    allow_pipeline: bool = True

    @property
    def W(self):
        return self.spec.W

    @property
    def CH(self):
        return self.spec.CH

    @property
    def n_loops(self) -> np.ndarray:
        return self.spec.arrays["loopmask"].sum(axis=1).astype(np.int32)

    @property
    def bounds(self) -> np.ndarray:
        return self.spec.arrays["bounds"]

    def max_nodes(self) -> int:
        return min(MAX_NODES, self.W * self.CH)


def _rand_perm_rows(key, W, levels, L):
    keys = jax.random.split(key, W * levels)
    perms = jnp.stack([jax.random.permutation(k, L) for k in keys])
    return perms.reshape(W, levels, L).astype(jnp.int32)


def random_design(key, space: DesignSpace) -> Dict:
    """Uniform random design point (the paper's 'Random' baseline)."""
    W, CH, L = space.W, space.CH, MAX_LOOPS
    ks = jax.random.split(key, 10)
    mx = jnp.asarray(space.max_shape, jnp.int32)
    shape = jax.random.randint(ks[0], (W, 6), 1, mx + 1)
    nl = jnp.asarray(space.n_loops)
    spatial = jax.random.randint(ks[1], (W, 6), 0, jnp.maximum(nl, 1)[:, None])
    order = _rand_perm_rows(ks[2], W, 3, L)
    bounds = jnp.asarray(space.bounds)
    tmax = jnp.maximum(bounds, 1)
    u = jax.random.uniform(ks[3], (W, 2, L))
    tiling = jnp.maximum(
        1, (tmax[:, None, :].astype(jnp.float32) ** u)).astype(jnp.int32)
    pipe = jnp.where(
        jnp.asarray(space.allow_pipeline)
        & (jax.random.uniform(ks[4], (W,)) < 0.5),
        jax.random.randint(ks[5], (W,), 0, jnp.maximum(nl, 1)),
        jnp.full((W,), L, jnp.int32)).astype(jnp.int32)
    logB = jnp.where(space.allow_pipeline,
                     jax.random.randint(ks[6], (), 0, space.max_logB + 1), 0)
    packaging = (jnp.asarray(space.fixed_packaging, jnp.int32)
                 if space.fixed_packaging >= 0
                 else jax.random.randint(ks[7], (), 0, 3))
    family = (jnp.asarray(space.fixed_family, jnp.int32)
              if space.fixed_family >= 0
              else jax.random.randint(ks[8], (), 0, N_FAMILIES))
    placement = jax.random.permutation(ks[9], W * CH).astype(jnp.int32)
    return dict(shape=shape, spatial=spatial, order=order, tiling=tiling,
                pipe=pipe, logB=jnp.asarray(logB, jnp.int32),
                packaging=jnp.asarray(packaging, jnp.int32),
                family=jnp.asarray(family, jnp.int32), placement=placement)


def balanced_init(key, space: DesignSpace, total_pes: int = 4096) -> Dict:
    """Paper Sec. IV-B: assign PEs to each workload proportionally to its
    MAC count so pipeline stages are roughly balanced."""
    d = random_design(key, space)
    macs = np.array([w.macs for w in space.spec.graph.workloads], np.float64)
    share = macs / macs.sum()
    pes = np.maximum((share * total_pes).astype(np.int64), 64)
    side = np.clip(np.sqrt(pes / 4).astype(np.int32), 1,
                   np.asarray(space.max_shape)[:2].min())
    shape = np.array(d["shape"])
    shape[:, 0] = side
    shape[:, 1] = side
    shape[:, 2:4] = 2
    shape[:, 4:6] = 1
    d["shape"] = jnp.asarray(shape)
    return d


# ---------------------------------------------------------------------------
# SA neighborhood moves (jit-able; one random field mutation per call)
# ---------------------------------------------------------------------------
ARCH_FIELDS = ("shape", "spatial", "order", "tiling", "pipe")
INTEG_FIELDS = ("packaging", "family", "placement")
ALL_FIELDS = ARCH_FIELDS + INTEG_FIELDS
# high-dimensional fields owned by the SA engine (paper Sec. IV-C)
SA_FIELDS = ("order", "tiling", "pipe", "placement")
# low-dimensional fields owned by the Bayesian engine
BO_FIELDS = ("shape", "spatial", "packaging", "family")


def mutate(key, design: Dict, space: DesignSpace,
           fields: Tuple[str, ...] = ALL_FIELDS,
           nl=None, bounds=None) -> Dict:
    """One random neighbor move restricted to ``fields`` (static tuple).
    Field subsets drive the Fig.-8 ablation ladder (Res/Dfw/Arch/Net/Pkg/...)
    and the nested BO+SA engine (SA owns the high-dim fields).

    ``nl``/``bounds`` may be passed as traced arrays (from the workload
    arrays) so the compiled move kernel is workload-independent."""
    W, CH, L = space.W, space.CH, MAX_LOOPS
    ks = jax.random.split(key, 12)
    nl = jnp.maximum(jnp.asarray(space.n_loops if nl is None else nl), 1)
    bounds_arr = jnp.asarray(space.bounds if bounds is None else bounds)
    wsel = jax.random.randint(ks[0], (), 0, W)

    d = {k: v for k, v in design.items()}

    # --- architecture moves -------------------------------------------------
    def mv_shape(d):
        i = jax.random.randint(ks[2], (), 0, 6)
        delta = jax.random.choice(ks[3], jnp.asarray([-2, -1, 1, 2]))
        mx = jnp.asarray(space.max_shape, jnp.int32)
        s = d["shape"].at[wsel, i].add(delta)
        d["shape"] = jnp.clip(s, 1, mx[None, :])
        return d

    def mv_spatial(d):
        i = jax.random.randint(ks[2], (), 0, 6)
        v = jax.random.randint(ks[3], (), 0, nl[wsel])
        d["spatial"] = d["spatial"].at[wsel, i].set(v)
        return d

    def mv_order(d):
        lvl = jax.random.randint(ks[2], (), 0, 3)
        i = jax.random.randint(ks[3], (), 0, L)
        j = jax.random.randint(ks[4], (), 0, L)
        row = d["order"][wsel, lvl]
        a, b = row[i], row[j]
        row = row.at[i].set(b).at[j].set(a)
        d["order"] = d["order"].at[wsel, lvl].set(row)
        return d

    def mv_tiling(d):
        lvl = jax.random.randint(ks[2], (), 0, 2)
        i = jax.random.randint(ks[3], (), 0, nl[wsel])
        f = jax.random.choice(ks[4], jnp.asarray([0.25, 0.5, 2.0, 4.0]))
        bmax = bounds_arr[wsel, i]
        t = d["tiling"][wsel, lvl, i].astype(jnp.float32) * f
        t = jnp.clip(t.astype(jnp.int32), 1, bmax)
        d["tiling"] = d["tiling"].at[wsel, lvl, i].set(
            jnp.maximum(t, 1).astype(jnp.int32))
        return d

    def mv_pipe(d):
        on = jax.random.uniform(ks[2]) < (0.7 if space.allow_pipeline else 0.0)
        loop = jax.random.randint(ks[3], (), 0, nl[wsel])
        d["pipe"] = d["pipe"].at[wsel].set(
            jnp.where(on, loop, jnp.int32(L)).astype(jnp.int32))
        d["logB"] = jnp.where(
            on, jnp.clip(d["logB"]
                         + jax.random.randint(ks[4], (), -1, 2),
                         0, space.max_logB),
            d["logB"]).astype(jnp.int32)
        return d

    # --- integration moves ---------------------------------------------------
    def mv_packaging(d):
        if space.fixed_packaging >= 0:
            return d
        d["packaging"] = jax.random.randint(ks[2], (), 0, 3)
        return d

    def mv_family(d):
        if space.fixed_family >= 0:
            return d
        d["family"] = jax.random.randint(ks[2], (), 0, N_FAMILIES)
        return d

    def mv_placement(d):
        i = jax.random.randint(ks[2], (), 0, W * CH)
        j = jax.random.randint(ks[3], (), 0, W * CH)
        p = d["placement"]
        a, b = p[i], p[j]
        d["placement"] = p.at[i].set(b).at[j].set(a)
        return d

    all_moves = dict(shape=mv_shape, spatial=mv_spatial, order=mv_order,
                     tiling=mv_tiling, pipe=mv_pipe, packaging=mv_packaging,
                     family=mv_family, placement=mv_placement)
    moves = [all_moves[f] for f in fields]
    mid = jax.random.randint(ks[1], (), 0, len(moves))
    branches = [lambda op, m=m: m(dict(d)) for m in moves]
    return jax.lax.switch(mid, branches, 0)


def feasibility_penalty(space: DesignSpace, design: Dict, metrics: Dict):
    """Soft constraints: total chiplets <= placeable nodes; optional PE budget
    (Fig. 7 iso-PE comparisons).  Returned as a multiplicative penalty."""
    n_chips = jnp.sum(design["shape"][:, 4] * design["shape"][:, 5])
    over_nodes = jnp.maximum(
        n_chips - jnp.int32(space.max_nodes()), 0).astype(jnp.float32)
    pes = jnp.sum(design["shape"][:, 0] * design["shape"][:, 1]
                  * design["shape"][:, 2] * design["shape"][:, 3]
                  * design["shape"][:, 4] * design["shape"][:, 5])
    over_pes = jnp.where(
        space.max_total_pes > 0,
        jnp.maximum(pes - space.max_total_pes, 0).astype(jnp.float32), 0.0)
    return 1.0 + over_nodes + over_pes / 64.0
