"""The paper's mapping formalism (Sec. III-B): Map / Bind / Reduce.

This module is the *semantic reference* for the fast closed-form evaluator:
it enumerates loop instances explicitly (small bounds only), so tests can
check the closed-form transfer volumes / reuse counts used by
``dataflow.py`` and ``evaluate.py`` against element-level ground truth.

    Map(G, chi)     : loop instance -> cluster coordinate [p0, p1, p2]
    Bind(chi, C)    : cluster chiplet -> system chiplet (execution sequence)
    Reduce_r(G, G') : gather vertices under rule r (hierarchical graphs)
    Omega(G1, G2, F): {(max P_{G1,F[f]}, min P_{G2,F[f]}) for all f}
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, List, Sequence, Tuple

import numpy as np

from .workload import TensorRef, Workload


Coord = Tuple[int, ...]


@dataclasses.dataclass
class Cluster:
    """A domain of computing engines (paper Def. 3), one entry per level:
    e.g. dims = {"chiplet": (2, 2), "core": (2, 2), "pe": (4, 4)}."""
    dims: Dict[str, Tuple[int, int]]

    def size(self, level: str) -> int:
        x, y = self.dims[level]
        return x * y


def enumerate_instances(w: Workload) -> np.ndarray:
    """All loop instances of a workload as an (N, n_loops) int array, in
    lexicographic (declared-order) execution sequence."""
    bounds = [b for _, b in w.loops]
    grids = np.indices(bounds).reshape(len(bounds), -1).T
    return grids


def map_instances(w: Workload, cluster: Cluster,
                  spatial: Dict[str, Tuple[str, str]]) -> np.ndarray:
    """Map(G, chi): assign every loop instance a coordinate per level via
    modulo parallelization of the chosen spatial loops, e.g.
    ``S[i,j,k] -> PE[i % X, j % Y]`` (paper Sec. III-B example).

    Returns (N, n_levels * 2) coordinates, level order = cluster.dims order.
    """
    inst = enumerate_instances(w)
    names = list(w.loop_names)
    cols = []
    for level, (X, Y) in cluster.dims.items():
        lx, ly = spatial[level]
        cols.append(inst[:, names.index(lx)] % X)
        cols.append(inst[:, names.index(ly)] % Y)
    return np.stack(cols, axis=1)


def bind(cluster_chiplets: Sequence[Coord],
         system_chiplets: Sequence[int]) -> Dict[Coord, int]:
    """Bind(chi, C): cluster coordinate -> system chiplet id; binding order
    encodes the execution sequence on shared chiplets (paper Fig. 4d)."""
    assert len(cluster_chiplets) == len(system_chiplets)
    return dict(zip(cluster_chiplets, system_chiplets))


def reduce_graph(assignment: np.ndarray) -> Dict[Tuple, np.ndarray]:
    """Reduce_r(G, G'): gather instances by an assignment key (e.g. their
    core coordinate) into super-vertices.  Returns key -> instance indices."""
    out: Dict[Tuple, List[int]] = {}
    for i, key in enumerate(map(tuple, assignment)):
        out.setdefault(key, []).append(i)
    return {k: np.asarray(v) for k, v in out.items()}


def _element_of(t: TensorRef, names: List[str], inst: np.ndarray) -> Tuple:
    idx = []
    for grp in t.dims:
        idx.append(sum(int(inst[names.index(l)]) for l in grp))
    return tuple(idx)


def omega(producer: Workload, consumer: Workload,
          t_prod: str, t_cons: str) -> List[Tuple[int, int]]:
    """Data-dependence set Omega_{G1,G2} (paper Sec. III-B): for every element
    f of the shared tensor, connect the LAST producer instance writing f with
    the FIRST consumer instance reading f.  Returns instance-index pairs.

    Element-count |Omega| is what the fast evaluator uses as transfer volume.
    """
    tp = producer.tensor(t_prod)
    tc = consumer.tensor(t_cons)
    pn, cn = list(producer.loop_names), list(consumer.loop_names)

    last_write: Dict[Tuple, int] = {}
    for i, inst in enumerate(enumerate_instances(producer)):
        last_write[_element_of(tp, pn, inst)] = i

    first_read: Dict[Tuple, int] = {}
    for i, inst in enumerate(enumerate_instances(consumer)):
        f = _element_of(tc, cn, inst)
        if f not in first_read:
            first_read[f] = i

    pairs = []
    for f, wi in last_write.items():
        if f in first_read:
            pairs.append((wi, first_read[f]))
    return pairs
