"""Simba [25] and NN-Baton [28] realized inside the Monad framework
(paper Sec. V-B: "realizing their hardware configurations (the same number
of PEs and die-to-die interfaces) and mapping strategies in our framework.
The parameters are searched with our optimizer.").

Both baselines therefore share Monad's evaluator; what differs is the
*frozen* part of the encoding:

* Simba    — MCM on organic substrate, 2D-mesh package network, a fixed
  36-chiplet-class geometry, and a mapping that spatially divides the
  INPUT and OUTPUT CHANNELS (k, c) at every level.
* NN-Baton — organic substrate, RING network, fewer/larger chiplets, and a
  mapping that spatially divides the OUTPUT PLANE (p, q) across chiplets
  (i, j for matmuls).

The remaining fields (order, tiling, pipeline) are searched by the same SA
engine that Monad uses, so comparisons are iso-optimizer and iso-PE-budget.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .encoding import DesignSpace, random_design
from .evaluate import SystemSpec
from .network import FAM_MESH, FAM_RING
from .constants import PKG_ORGANIC
from .workload import MAX_LOOPS


@dataclasses.dataclass(frozen=True)
class Baseline:
    name: str
    space: DesignSpace
    init: Dict                       # frozen hardware + mapping strategy
    sa_fields: Tuple[str, ...]       # what its mapper may still tune
    bo_fields: Tuple[str, ...] = ()


def _spatial_for(graph, kind: str) -> np.ndarray:
    """Loop-id pairs per level [PE, core, chiplet] for a mapping strategy.

    kind='channels' (Simba): divide output/input CHANNELS at every level —
    conv (k, c), matmul (j, k).
    kind='plane' (NN-Baton): divide the OUTPUT PLANE across chiplets (conv
    (p, q), matmul (i, j)); inside a chiplet, channel parallelism feeds the
    PE arrays (the paper's description of its orchestration).
    """
    W = len(graph.workloads)
    out = np.zeros((W, 6), np.int32)
    for wi, w in enumerate(graph.workloads):
        names = list(w.loop_names)
        if "k" in names and "c" in names:            # conv
            chan = (names.index("k"), names.index("c"))
            plane = (names.index("p"), names.index("q"))
        elif "i" in names and "j" in names:          # matmul
            chan = (names.index("j"), names.index("k"))
            plane = (names.index("i"), names.index("j"))
        else:                                        # generic contraction
            chan = (1, 2 if len(names) > 2 else 0)
            plane = (0, 1)
        if kind == "channels":
            pe = core = chip = chan
        else:
            pe = core = chan
            chip = plane
        out[wi] = [pe[0], pe[1], core[0], core[1], chip[0], chip[1]]
    return out


def make_baseline(name: str, spec: SystemSpec, key,
                  pe_budget: int = 4096) -> Baseline:
    """Instantiate 'simba' / 'nn-baton' / 'monad' under an iso-PE budget."""
    graph = spec.graph
    W = spec.W
    L = MAX_LOOPS

    if name == "monad":
        space = DesignSpace(spec, max_total_pes=pe_budget)
        init = random_design(key, space)
        return Baseline(name, space, init,
                        sa_fields=("order", "tiling", "pipe", "placement"),
                        bo_fields=("shape", "spatial", "packaging", "family"))

    d = random_design(key, DesignSpace(spec))
    d = {k: np.asarray(v).copy() for k, v in d.items()}
    per_wl = max(pe_budget // max(W, 1), 64)

    if name == "simba":
        # 16 chiplets x 16 cores x 16 PEs class geometry (scaled to budget)
        chips = 4 if per_wl >= 1024 else 2
        d["shape"][:] = 0
        d["shape"][:, 0:2] = 4                      # 4x4 PEs / core
        d["shape"][:, 2:4] = 4                      # 4x4 cores
        side = max(int(np.sqrt(per_wl / 256)), 1)
        d["shape"][:, 4] = side
        d["shape"][:, 5] = max(per_wl // (256 * side), 1)
        d["spatial"] = _spatial_for(graph, "channels")
        d["packaging"] = np.int32(PKG_ORGANIC)
        d["family"] = np.int32(FAM_MESH)
    elif name == "nn-baton":
        # fewer, larger chiplets on a ring; output-plane partitioning
        d["shape"][:] = 0
        d["shape"][:, 0:2] = 8                      # 8x8 PEs / core
        d["shape"][:, 2:4] = 2                      # 2x2 cores
        nch = max(per_wl // 256, 1)
        d["shape"][:, 4] = 1
        d["shape"][:, 5] = min(nch, 6)
        d["spatial"] = _spatial_for(graph, "plane")
        d["packaging"] = np.int32(PKG_ORGANIC)
        d["family"] = np.int32(FAM_RING)
    else:
        raise ValueError(name)

    space = DesignSpace(spec, max_total_pes=pe_budget,
                        fixed_packaging=int(d["packaging"]),
                        fixed_family=int(d["family"]))
    init = {k: jnp.asarray(v) for k, v in d.items()}
    # baselines tune execution order, tiling, pipelining and placement with
    # the same SA engine; geometry/spatial/integration stay frozen.
    return Baseline(name, space, init,
                    sa_fields=("order", "tiling", "pipe", "placement"),
                    bo_fields=())
