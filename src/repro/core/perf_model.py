"""Readable reference implementation of the pipeline performance model
(paper Sec. III-C, Fig. 5).  The fast path lives in ``evaluate.py``; this
module exists so tests and the validation benchmark can express the paper's
examples directly:

    Lat = max_{p in P} sum_{v in p} D(v),   Thr = 1 / max_v D(v)

Stages are compute stages (workloads bound to chiplets; workloads sharing a
chiplet become one long sequential stage — paper Fig. 4d) and data-transfer
stages between them.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class Stage:
    name: str
    delay: float                       # ns
    kind: str = "compute"              # or "transfer"


@dataclasses.dataclass
class StageGraph:
    stages: List[Stage]
    edges: List[Tuple[int, int]]       # stage index -> stage index

    def latency(self) -> float:
        """Longest path over the stage DAG."""
        n = len(self.stages)
        indeg = [0] * n
        adj: List[List[int]] = [[] for _ in range(n)]
        for u, v in self.edges:
            adj[u].append(v)
            indeg[v] += 1
        dist = [s.delay for s in self.stages]
        order = [i for i in range(n) if indeg[i] == 0]
        head = 0
        while head < len(order):
            u = order[head]
            head += 1
            for v in adj[u]:
                dist[v] = max(dist[v], dist[u] + self.stages[v].delay)
                indeg[v] -= 1
                if indeg[v] == 0:
                    order.append(v)
        if len(order) != n:
            raise ValueError("stage graph has a cycle")
        return max(dist) if dist else 0.0

    def throughput(self) -> float:
        mx = max((s.delay for s in self.stages), default=0.0)
        return 1.0 / mx if mx > 0 else float("inf")

    def total_time(self, ticks: int = 1) -> float:
        """Latency of the first tick + (ticks-1) pipeline intervals."""
        return self.latency() + (ticks - 1) / self.throughput()


def build_stage_graph(compute_delays: Dict[int, float],
                      binding: Dict[int, int],
                      deps: Sequence[Tuple[int, int, float]]) -> StageGraph:
    """Compose stages from workload delays + chiplet binding + transfers.

    compute_delays: workload -> D(v);  binding: workload -> chiplet id
    (workloads bound to the same chiplet are concatenated, in key order,
    into one long stage);  deps: (producer wl, consumer wl, transfer delay).
    """
    by_chip: Dict[int, List[int]] = {}
    for wl in sorted(compute_delays):
        by_chip.setdefault(binding[wl], []).append(wl)

    stages: List[Stage] = []
    stage_of: Dict[int, int] = {}
    for chip, wls in sorted(by_chip.items()):
        idx = len(stages)
        stages.append(Stage(
            name=f"chip{chip}:" + "+".join(f"w{w}" for w in wls),
            delay=sum(compute_delays[w] for w in wls)))
        for w in wls:
            stage_of[w] = idx

    edges: List[Tuple[int, int]] = []
    for src, dst, tdelay in deps:
        su, sv = stage_of[src], stage_of[dst]
        if su == sv:
            continue                   # same chiplet: already serialized
        t = len(stages)
        stages.append(Stage(name=f"xfer w{src}->w{dst}", delay=tdelay,
                            kind="transfer"))
        edges.append((su, t))
        edges.append((t, sv))
    return StageGraph(stages, edges)
