"""Per-chiplet dataflow analysis (paper Sec. III-B/III-C, TENET-style).

Given one workload (padded arrays from ``Workload.to_arrays``) and one chiplet
design point, compute — entirely in jnp so the whole thing vmaps over design
populations — the quantities the performance/energy/cost models consume:

* temporal trip counts and spatial splits per hierarchy level,
* buffer footprints (core / chiplet) from the tile sizes,
* access counts at every level of the memory hierarchy with *order-dependent
  reuse* (innermost-irrelevant-suffix stationarity) and multicast discounts,
* compute cycles and utilization,
* the pipelined per-level delay  D = trips x max(D_C, D_B, D_A)  (Sec III-C).

Hierarchy and loop structure modeled per chiplet (paper Fig. 1):

    for n2-loops over t2-tiles          # chiplet buffer refilled from ext
      spatial over (X1 x Y1) cores
      for n1-loops over t1-tiles        # core buffer refilled from chiplet buf
        spatial over (X0 x Y0) PEs
        for p-loops over elements       # PE: 1 MAC/cycle, register reuse

Design-point encoding (all int32):
    shape   (6,)   [x0, y0, x1, y1, x2, y2]       raw array dims (>= 1)
    spatial (6,)   [sx0, sy0, sx1, sy1, sx2, sy2] loop ids per level
    order   (3,L)  loop id by position, 0 = outermost   (PE, core, chiplet)
    tiling  (2,L)  [t1; t2] raw tile sizes (clamped internally)
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .workload import MAX_LOOPS
from .constants import TechConstants, DEFAULT_TECH

F = jnp.float32


def _cdiv(a, b):
    return (a + b - 1) // b


def _split_of(spatial_x, spatial_y, X, Y, L=MAX_LOOPS):
    """Per-loop spatial split factor at one level."""
    l = jnp.arange(L)
    sx = jnp.where(l == spatial_x, X, 1)
    sy = jnp.where(l == spatial_y, Y, 1)
    return sx * sy                       # if sx==sy loop: X*Y on that loop


def _positions(order):
    """order: (L,) loop id by position -> pos[loop] = position (0=outermost)."""
    return jnp.argsort(order)


def _footprint(A, dmask, tile):
    """Tile footprint (elements) per tensor.  A: (T,D,L) int, tile: (L,)."""
    span = jnp.einsum("tdl,l->td", A.astype(F), tile.astype(F))
    nnz = jnp.sum(A != 0, axis=-1).astype(F)                  # (T,D)
    fd = jnp.where(dmask, span - jnp.maximum(nnz - 1.0, 0.0), 1.0)
    fd = jnp.maximum(fd, 1.0)
    return jnp.prod(fd, axis=-1)                              # (T,)


def _refills(rel, pos, trips, loopmask):
    """Order-aware refill count per tensor.

    rel: (T,L) bool — loop relevant to tensor; pos: (L,) position of loop;
    trips: (L,) trip counts at this level.  A tensor tile is reused across the
    innermost contiguous run of irrelevant loops; every loop at or outside the
    innermost *relevant* position multiplies refills.
    """
    posb = jnp.broadcast_to(pos, rel.shape)                   # (T,L)
    pstar = jnp.max(jnp.where(rel & loopmask, posb, -1), axis=-1)  # (T,)
    count = (posb <= pstar[:, None]) & loopmask
    return jnp.prod(jnp.where(count, trips.astype(F), 1.0), axis=-1)  # (T,)


def _distinct(rel, trips, loopmask):
    return jnp.prod(
        jnp.where(rel & loopmask, trips.astype(F), 1.0), axis=-1)


def _multicast(rel, spatial_x, spatial_y, X, Y):
    """Multicast fan-out for tensors *not* split by a spatial loop."""
    rx = rel[:, spatial_x] if rel.ndim == 2 else rel[spatial_x]
    ry = rel[:, spatial_y]
    mx = jnp.where(rx, 1, X)
    my = jnp.where(ry, 1, Y)
    same = spatial_x == spatial_y
    return jnp.where(same, mx, mx * my).astype(F)


def analyze_chiplet(wl: Dict, shape, spatial, order, tiling,
                    tech: TechConstants = DEFAULT_TECH,
                    ext_bw_gbps=None) -> Dict:
    """Analyze one workload mapped on one chiplet design (pure jnp).

    wl: dict from Workload.to_arrays() (bounds/loopmask/A/tmask/dmask/is_out).
    ext_bw_gbps: effective external (network/DRAM) bandwidth for this chiplet's
      streaming traffic; defaults to the DRAM bandwidth. The system evaluator
      re-invokes with contention-derived effective bandwidth (fixed point).
    Returns a dict of scalars (all jnp float32) — see bottom of function.
    """
    bounds = wl["bounds"].astype(jnp.int32)
    loopmask = wl["loopmask"]
    A, tmask, dmask, is_out = wl["A"], wl["tmask"], wl["dmask"], wl["is_out"]
    rel = jnp.any(A != 0, axis=1) & tmask[:, None]            # (T,L)

    x0, y0, x1, y1, x2, y2 = [jnp.maximum(shape[i], 1) for i in range(6)]
    n_pe, n_core, n_chip = x0 * y0, x1 * y1, x2 * y2

    ext_bw = tech.dram_bw if ext_bw_gbps is None else ext_bw_gbps
    bpe = F(tech.bytes_per_elem)

    # ---- per-loop tiling / trip structure ---------------------------------
    s2 = _split_of(spatial[4], spatial[5], x2, y2)            # cluster split
    N2 = _cdiv(bounds, s2)                                    # per-chiplet share
    t2 = jnp.clip(tiling[1], 1, N2)
    n2 = jnp.where(loopmask, _cdiv(N2, t2), 1)                # chiplet trips

    s1 = _split_of(spatial[2], spatial[3], x1, y1)
    share1 = _cdiv(t2, s1)                                    # per-core share
    t1 = jnp.clip(tiling[0], 1, share1)
    n1 = jnp.where(loopmask, _cdiv(share1, t1), 1)            # core trips

    s0 = _split_of(spatial[0], spatial[1], x0, y0)
    p = jnp.where(loopmask, _cdiv(t1, s0), 1)                 # per-PE iters

    pos0 = _positions(order[0])
    pos1 = _positions(order[1])
    pos2 = _positions(order[2])

    # ---- compute cycles ----------------------------------------------------
    pe_pass = jnp.prod(p.astype(F))                 # cycles per core-tile pass
    n1_tot = jnp.prod(n1.astype(F))
    n2_tot = jnp.prod(n2.astype(F))
    total_macs = jnp.prod(jnp.where(loopmask, bounds, 1).astype(F))
    macs_per_chip = total_macs / F(n_chip)          # useful work (pre-padding)

    # ---- footprints --------------------------------------------------------
    f1 = _footprint(A, dmask, t1) * tmask           # core-buffer tile elems
    f2 = _footprint(A, dmask, t2) * tmask           # chiplet-buffer tile elems
    core_buf_bytes = jnp.sum(f1) * bpe
    chip_buf_bytes = jnp.sum(f2) * bpe

    # ---- level-0: core buffer <-> PE registers ----------------------------
    # A PE-array spatial loop that a tensor does NOT depend on forwards the
    # same element across the array (systolic multicast), so the buffer only
    # feeds the distinct elements at the array edge: n_pe / m0 per tensor.
    r0 = _refills(rel, pos0, p, loopmask)                     # per PE per pass
    d0 = _distinct(rel, p, loopmask)
    rd0 = jnp.where(is_out, r0 + jnp.maximum(r0 - d0, 0.0), r0)
    m0 = _multicast(rel, spatial[0], spatial[1], x0, y0)      # (T,)
    core_acc_pass = jnp.sum(rd0 * tmask / m0 * F(n_pe)) * bpe  # bytes/core/pass
    core_acc_total = core_acc_pass * n1_tot * n2_tot * F(n_core)

    # ---- level-1: chiplet buffer <-> core buffers --------------------------
    r1 = _refills(rel, pos1, n1, loopmask)          # t1-tile refills per pass
    d1 = _distinct(rel, n1, loopmask)
    rw1 = jnp.where(is_out, 2.0 * r1 - d1, r1)      # outputs: write + psum rd
    m1 = _multicast(rel, spatial[2], spatial[3], x1, y1)      # (T,)
    # broadcast on the intra-chiplet NoC: a tile multicast to m1 cores
    # crosses the shared fabric once (bus/tree multicast model)
    chipbuf_acc_pass = jnp.sum(rw1 * f1 * tmask / m1) * bpe * F(n_core)
    noc_bytes_pass = chipbuf_acc_pass
    chipbuf_acc_total = chipbuf_acc_pass * n2_tot
    noc_bytes_total = noc_bytes_pass * n2_tot

    # ---- level-2: external (network / DRAM) <-> chiplet buffer -------------
    r2 = _refills(rel, pos2, n2, loopmask)
    d2 = _distinct(rel, n2, loopmask)
    rw2 = jnp.where(is_out, 2.0 * r2 - d2, r2)
    ext_bytes = jnp.sum(rw2 * f2 * tmask) * bpe               # per chiplet
    m2 = _multicast(rel, spatial[4], spatial[5], x2, y2)
    # external traffic split per tensor (inputs in, outputs out) for the
    # communication-graph construction:
    ext_in_t = jnp.where(is_out, 0.0, r2 * f2 * tmask) * bpe
    ext_out_t = jnp.where(is_out, rw2 * f2 * tmask, 0.0) * bpe

    # ---- pipelined delays (ns; paper Sec III-C max-composition) ------------
    # pe_pass + output-stationary systolic fill/drain skew (2X + Y - 2),
    # the ScaleSim timing model our Sec.-V-A validation compares against
    skew = (2 * x0 + y0 - 2).astype(F)
    d_pe = (pe_pass + skew) / tech.clock_ghz
    d_b0 = core_acc_pass / F(tech.core_buf_bw)
    core_pass_d = jnp.maximum(d_pe, d_b0)
    d_noc = noc_bytes_pass / F(tech.chip_noc_bw)
    d_b1 = chipbuf_acc_pass / F(tech.chip_buf_bw)
    chip_pass_d = jnp.maximum(n1_tot * core_pass_d, jnp.maximum(d_noc, d_b1))
    d_ext_pass = (ext_bytes / n2_tot) / jnp.maximum(F(ext_bw), 1e-6)
    # Each external tile also pays a fixed launch overhead (DMA descriptor
    # setup / drain); default 0.0 so x + 0.0 keeps the seed model
    # bit-identical, and repro.calib fits it against simulator ground truth.
    delay = n2_tot * (jnp.maximum(chip_pass_d, d_ext_pass)
                      + F(tech.t_tile_overhead_ns))           # per chiplet, ns

    util = macs_per_chip / jnp.maximum(
        F(n_pe) * F(n_core) * delay * tech.clock_ghz, 1e-9)

    return dict(
        delay_ns=delay,
        ext_tiles=n2_tot,
        compute_cycles=n2_tot * n1_tot * pe_pass,
        utilization=util,
        total_macs=total_macs,
        n_chiplets=F(n_chip), n_cores=F(n_core), n_pes=F(n_pe),
        core_buf_bytes=core_buf_bytes, chip_buf_bytes=chip_buf_bytes,
        core_acc_bytes=core_acc_total,            # per chiplet
        chipbuf_acc_bytes=chipbuf_acc_total,      # per chiplet
        noc_bytes=noc_bytes_total,                # per chiplet
        ext_bytes=ext_bytes,                      # per chiplet
        ext_in_bytes_t=ext_in_t, ext_out_bytes_t=ext_out_t,
        ext_multicast_t=m2,
        reg_acc_bytes=(jnp.sum(rd0 * tmask) * bpe
                       * F(n_pe) * n1_tot * n2_tot * F(n_core)),
        mac_count=macs_per_chip * F(n_chip),
    )


analyze_chiplet_jit = jax.jit(analyze_chiplet, static_argnames=("tech",))
