"""Tensor-workload IR (paper Sec. II-A / III-A).

A *workload* is a perfectly-nested loop program over tensors — an operation
expressible as  ``Out[f(idx)] (+)= Π_i In_i[g_i(idx)]``  (matmul, convolution,
MTTKRP, tensor-train contractions, ...).  Each tensor dimension indexes either
a single loop (``("k",)``) or a sliding-window sum of loops (``("p","r")`` for
``p+r`` in a convolution), which is all the reuse analysis needs:

* footprint of a dim-group under tile sizes t:  sum(t_l) - (len-1)
* a loop is *relevant* to a tensor iff it appears in any dim-group.

A ``WorkloadGraph`` is the paper's dependency graph G=(V,E): vertices are
workloads, edges carry the tensor that flows producer -> consumer (used for
the data-dependency set Omega and the communication graph).
"""

from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

MAX_LOOPS = 8          # padded loop-nest width for the vectorized evaluator
MAX_TENSORS = 4        # operands + output per workload
MAX_DIMS = 4           # dims per tensor


@dataclasses.dataclass(frozen=True)
class TensorRef:
    """One tensor access inside a workload."""
    name: str
    dims: Tuple[Tuple[str, ...], ...]     # dim-groups, e.g. (("i",), ("k",))
    is_output: bool = False

    def loops(self) -> Tuple[str, ...]:
        out: List[str] = []
        for grp in self.dims:
            for l in grp:
                if l not in out:
                    out.append(l)
        return tuple(out)


@dataclasses.dataclass(frozen=True)
class Workload:
    """A single tensor workload: loop bounds + tensor accesses."""
    name: str
    loops: Tuple[Tuple[str, int], ...]    # ordered (loop name, bound)
    tensors: Tuple[TensorRef, ...]
    flops_per_instance: int = 2           # one MAC

    # ------------------------------------------------------------------ api
    @property
    def loop_names(self) -> Tuple[str, ...]:
        return tuple(n for n, _ in self.loops)

    @property
    def bounds(self) -> Dict[str, int]:
        return dict(self.loops)

    @property
    def macs(self) -> int:
        return int(np.prod([b for _, b in self.loops], dtype=np.int64))

    @property
    def flops(self) -> int:
        return self.macs * self.flops_per_instance

    def output(self) -> TensorRef:
        for t in self.tensors:
            if t.is_output:
                return t
        raise ValueError(f"workload {self.name} has no output tensor")

    def tensor(self, name: str) -> TensorRef:
        for t in self.tensors:
            if t.name == name:
                return t
        raise KeyError(name)

    def tensor_size(self, name: str) -> int:
        """Number of elements of a tensor under the full loop bounds."""
        t = self.tensor(name)
        b = self.bounds
        size = 1
        for grp in t.dims:
            size *= sum(b[l] for l in grp) - (len(grp) - 1)
        return int(size)

    # ------------------------------------------------------- array encoding
    def to_arrays(self) -> Dict[str, np.ndarray]:
        """Pad to fixed shapes for the vmappable evaluator.

        Returns
        -------
        bounds:  (MAX_LOOPS,) int32, padded with 1
        loopmask:(MAX_LOOPS,) bool
        A:       (MAX_TENSORS, MAX_DIMS, MAX_LOOPS) int8 dim-group incidence
        tmask:   (MAX_TENSORS,) bool
        dmask:   (MAX_TENSORS, MAX_DIMS) bool
        is_out:  (MAX_TENSORS,) bool
        """
        ln = self.loop_names
        if len(ln) > MAX_LOOPS:
            raise ValueError(f"{self.name}: too many loops ({len(ln)})")
        if len(self.tensors) > MAX_TENSORS:
            raise ValueError(f"{self.name}: too many tensors")
        idx = {n: i for i, n in enumerate(ln)}
        bounds = np.ones(MAX_LOOPS, np.int32)
        for i, (_, b) in enumerate(self.loops):
            bounds[i] = b
        loopmask = np.zeros(MAX_LOOPS, bool)
        loopmask[: len(ln)] = True
        A = np.zeros((MAX_TENSORS, MAX_DIMS, MAX_LOOPS), np.int8)
        tmask = np.zeros(MAX_TENSORS, bool)
        dmask = np.zeros((MAX_TENSORS, MAX_DIMS), bool)
        is_out = np.zeros(MAX_TENSORS, bool)
        for ti, t in enumerate(self.tensors):
            tmask[ti] = True
            is_out[ti] = t.is_output
            if len(t.dims) > MAX_DIMS:
                raise ValueError(f"{self.name}.{t.name}: too many dims")
            for di, grp in enumerate(t.dims):
                dmask[ti, di] = True
                for l in grp:
                    A[ti, di, idx[l]] = 1
        return dict(bounds=bounds, loopmask=loopmask, A=A, tmask=tmask,
                    dmask=dmask, is_out=is_out)


# ---------------------------------------------------------------------------
# constructors for the workload kinds used in the paper
# ---------------------------------------------------------------------------
def matmul(name: str, M: int, N: int, K: int) -> Workload:
    """C[i,j] += A[i,k] * B[k,j]"""
    return Workload(
        name=name,
        loops=(("i", M), ("j", N), ("k", K)),
        tensors=(
            TensorRef("A", (("i",), ("k",))),
            TensorRef("B", (("k",), ("j",))),
            TensorRef("C", (("i",), ("j",)), is_output=True),
        ),
    )


def conv2d(name: str, N: int, K: int, C: int, P: int, Q: int,
           R: int, S: int) -> Workload:
    """O[n,k,p,q] += W[k,c,r,s] * I[n,c,p+r,q+s]   (stride 1, 7 loops)."""
    return Workload(
        name=name,
        loops=(("n", N), ("k", K), ("p", P), ("q", Q),
               ("c", C), ("r", R), ("s", S)),
        tensors=(
            TensorRef("I", (("n",), ("c",), ("p", "r"), ("q", "s"))),
            TensorRef("W", (("k",), ("c",), ("r",), ("s",))),
            TensorRef("O", (("n",), ("k",), ("p",), ("q",)), is_output=True),
        ),
    )


def mttkrp(name: str, I: int, J: int, K: int, L: int) -> Workload:
    """O[i,j] += T[i,k,l] * B[k,j] * C[l,j]"""
    return Workload(
        name=name,
        loops=(("i", I), ("j", J), ("k", K), ("l", L)),
        tensors=(
            TensorRef("T", (("i",), ("k",), ("l",))),
            TensorRef("B", (("k",), ("j",))),
            TensorRef("C", (("l",), ("j",))),
            TensorRef("O", (("i",), ("j",)), is_output=True),
        ),
        flops_per_instance=3,
    )


def contraction(name: str, free_a: Dict[str, int], free_b: Dict[str, int],
                contracted: Dict[str, int],
                a_name: str = "A", b_name: str = "B",
                out_name: str = "O") -> Workload:
    """Generalized tensor contraction  O[fa, fb] += A[fa, c] * B[c, fb]
    (the tensor-train building block, paper Fig. 10)."""
    loops = tuple(free_a.items()) + tuple(free_b.items()) \
        + tuple(contracted.items())
    return Workload(
        name=name,
        loops=loops,
        tensors=(
            TensorRef(a_name, tuple((l,) for l in list(free_a) + list(contracted))),
            TensorRef(b_name, tuple((l,) for l in list(contracted) + list(free_b))),
            TensorRef(out_name, tuple((l,) for l in list(free_a) + list(free_b)),
                      is_output=True),
        ),
    )


# ---------------------------------------------------------------------------
# workload graphs
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Edge:
    src: int                      # producer workload index
    dst: int                      # consumer workload index
    tensor_src: str               # tensor name in producer (its output)
    tensor_dst: str               # tensor name in consumer (an input)


@dataclasses.dataclass
class WorkloadGraph:
    """Dependency graph of tensor workloads (paper Def. 1)."""
    workloads: List[Workload]
    edges: List[Edge]

    def __post_init__(self):
        n = len(self.workloads)
        for e in self.edges:
            assert 0 <= e.src < n and 0 <= e.dst < n and e.src != e.dst
            self.workloads[e.src].tensor(e.tensor_src)
            self.workloads[e.dst].tensor(e.tensor_dst)

    @property
    def n(self) -> int:
        return len(self.workloads)

    def transfer_elems(self, e: Edge) -> int:
        """|Omega_{G1,G2}|: elements flowing producer->consumer = size of the
        produced tensor restricted to what the consumer reads (here: the full
        produced tensor; validated element-wise in mapping.py / tests)."""
        return self.workloads[e.src].tensor_size(e.tensor_src)

    def external_inputs(self) -> List[Tuple[int, str]]:
        """(workload, tensor) pairs that must be streamed from DRAM."""
        produced = {(e.dst, e.tensor_dst) for e in self.edges}
        out = []
        for wi, w in enumerate(self.workloads):
            for t in w.tensors:
                if not t.is_output and (wi, t.name) not in produced:
                    out.append((wi, t.name))
        return out

    def final_outputs(self) -> List[Tuple[int, str]]:
        """(workload, tensor) outputs that nobody consumes -> written to DRAM."""
        consumed = {(e.src, e.tensor_src) for e in self.edges}
        out = []
        for wi, w in enumerate(self.workloads):
            t = w.output()
            if (wi, t.name) not in consumed:
                out.append((wi, t.name))
        return out

    def topo_order(self) -> List[int]:
        indeg = [0] * self.n
        adj: List[List[int]] = [[] for _ in range(self.n)]
        for e in self.edges:
            adj[e.src].append(e.dst)
            indeg[e.dst] += 1
        stack = [i for i in range(self.n) if indeg[i] == 0]
        order: List[int] = []
        while stack:
            u = stack.pop()
            order.append(u)
            for v in adj[u]:
                indeg[v] -= 1
                if indeg[v] == 0:
                    stack.append(v)
        if len(order) != self.n:
            raise ValueError("workload graph has a cycle")
        return order

    def depth(self) -> int:
        """Longest producer->consumer chain length (nodes on the critical
        dependency path; 1 for an edgeless graph)."""
        dist = [1] * self.n
        for u in self.topo_order():
            for e in self.edges:
                if e.src == u:
                    dist[e.dst] = max(dist[e.dst], dist[u] + 1)
        return max(dist) if dist else 0


# ---------------------------------------------------------------------------
# workload identity + feature embeddings (the cross-spec transfer substrate)
# ---------------------------------------------------------------------------
# Per-workload feature row layout (all sizes log2-scaled so magnitudes are
# comparable across wildly different problem sizes):
#   [0:L)       loop bounds in declared order, padded with 1
#   [L:2L)      loop bounds sorted descending (permutation-invariant view)
#   [2L:2L+T)   tensor sizes sorted descending, padded with 1
#   then: n_loops, n_tensors, macs, output size, total footprint,
#         in-degree, out-degree
WL_FEATURE_DIM = 2 * MAX_LOOPS + MAX_TENSORS + 7
# graph summary: n workloads, n edges, DAG depth, external inputs, final
# outputs, total macs (log2), total producer->consumer elements (log2)
GRAPH_SUMMARY_DIM = 7
# workload_features(graph) = [mean rows | max rows | graph summary]
WL_EMBED_DIM = 2 * WL_FEATURE_DIM + GRAPH_SUMMARY_DIM


def workload_signature(w: Workload) -> str:
    """Content hash of one workload's *structure*: padded loop bounds and
    dim-group incidence, NOT its name.  Two workloads with equal signatures
    are the same tensor program, so per-workload design records transfer
    between them verbatim (``encoding.PortableDesign``)."""
    arr = w.to_arrays()
    h = hashlib.sha256()
    h.update(repr(int(w.flops_per_instance)).encode())
    for k in sorted(arr):
        a = np.asarray(arr[k])
        h.update(k.encode())
        h.update(str(a.dtype).encode())
        h.update(a.tobytes())
    return h.hexdigest()[:16]


def _log2(x) -> np.ndarray:
    return np.log2(np.maximum(np.asarray(x, np.float64), 1.0))


def workload_feature_row(w: Workload, in_deg: int = 0,
                         out_deg: int = 0) -> np.ndarray:
    """(WL_FEATURE_DIM,) numeric fingerprint of one workload — what
    nearest-record matching ranks on when no exact signature match exists."""
    bounds = np.ones(MAX_LOOPS, np.float64)
    for i, (_, b) in enumerate(w.loops):
        bounds[i] = b
    tsizes = np.ones(MAX_TENSORS, np.float64)
    for i, t in enumerate(w.tensors):
        tsizes[i] = w.tensor_size(t.name)
    return np.concatenate([
        _log2(bounds),
        np.sort(_log2(bounds))[::-1],
        np.sort(_log2(tsizes))[::-1],
        [float(len(w.loops)), float(len(w.tensors)),
         float(_log2(w.macs)), float(_log2(w.tensor_size(w.output().name))),
         float(_log2(tsizes.sum())), float(in_deg), float(out_deg)],
    ])


def graph_feature_rows(graph: WorkloadGraph) -> np.ndarray:
    """(n, WL_FEATURE_DIM) per-workload feature matrix with edge degrees."""
    indeg = np.zeros(graph.n, np.int64)
    outdeg = np.zeros(graph.n, np.int64)
    for e in graph.edges:
        outdeg[e.src] += 1
        indeg[e.dst] += 1
    return np.stack([workload_feature_row(w, int(indeg[i]), int(outdeg[i]))
                     for i, w in enumerate(graph.workloads)])


def workload_features(graph: WorkloadGraph) -> np.ndarray:
    """Fixed-dimension (WL_EMBED_DIM,) embedding of a whole workload graph:
    mean- and max-pooled per-workload rows plus a graph-structure summary.
    Graphs of any size land in ONE vector space, so the explore cache can
    rank cached problems by similarity (``ArchiveManifest.nearest``) and
    warm-start new graphs from their neighbors' fronts."""
    rows = graph_feature_rows(graph)
    transfer = sum(graph.transfer_elems(e) for e in graph.edges)
    summary = np.asarray([
        float(graph.n), float(len(graph.edges)), float(graph.depth()),
        float(len(graph.external_inputs())), float(len(graph.final_outputs())),
        float(_log2(sum(w.macs for w in graph.workloads))),
        float(_log2(transfer)),
    ])
    return np.concatenate([rows.mean(axis=0), rows.max(axis=0), summary])


def embedding_delta(a, b) -> np.ndarray:
    """Per-dimension absolute difference of two ``workload_features``
    embeddings — the feature vector the transfer trust calibration
    (``repro.explore.archive.fit_trust_model``) regresses observed
    hypervolume lift on.  Symmetric in (a, b) and all-zero iff the
    embeddings coincide."""
    return np.abs(np.asarray(a, np.float64).ravel()
                  - np.asarray(b, np.float64).ravel())
