"""Activation-sharding context.

GSPMD propagates weight shardings well, but for activations on odd-shaped
models (6 attention heads vs a 16-way model axis, batch vs fused scans) its
choices can be catastrophic — the whisper train cell replicated the full
batch into every attention residual before these constraints existed
(EXPERIMENTS.md §Perf, iteration 0).  The launcher installs this context
around tracing; the model code calls ``shard(x, (...logical dims...))`` at
the few layout-critical points.  With no context installed (unit tests,
plain CPU runs) every call is a no-op.

Logical dim names:
    batch — FSDP axes, applied iff the dim is divisible
    seq   — "data" iff ParallelConfig.seq_shard and batch didn't claim it
    heads/tp/ep — the tensor axis, iff divisible
    None  — unconstrained
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_tls = threading.local()


def _state():
    return getattr(_tls, "ctx", None)


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, pc) -> None:
    """pc: repro.models.config.ParallelConfig"""
    # activation BATCH sharding always uses the data axes; pc.fsdp_axes
    # only controls weight sharding
    fs = tuple(a for a in ("pod", "data") if a in mesh.shape)
    tp = pc.tensor_axis if pc.tensor_axis in mesh.shape else None
    prev = _state()
    _tls.ctx = dict(mesh=mesh, fs=fs or None, tp=tp,
                    seq_shard=bool(pc.seq_shard),
                    seq_tp=bool(getattr(pc, "seq_tp", False)))
    try:
        yield
    finally:
        _tls.ctx = prev


def _axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape.get(a, 1)
    return n


def shard(x, dims: Tuple[Optional[str], ...]):
    """Apply a with_sharding_constraint resolving logical dim names.
    No-op without an installed context."""
    ctx = _state()
    if ctx is None or x.ndim != len(dims):
        return x
    mesh, fs, tp = ctx["mesh"], ctx["fs"], ctx["tp"]
    used = set()
    spec = [None] * len(dims)
    # pass 1: tensor-axis claims (heads/tp/ep outrank seq_tp's model use)
    for i, (d, name) in enumerate(zip(x.shape, dims)):
        if name in ("heads", "tp", "ep"):
            if tp and tp not in used and d % mesh.shape.get(tp, 1) == 0:
                spec[i] = tp
                used.add(tp)
    # pass 2: batch / sequence / capacity dims
    for i, (d, name) in enumerate(zip(x.shape, dims)):
        if spec[i] is not None:
            continue
        if name == "batch":
            if fs and "batch" not in used and d % _axis_size(mesh, fs) == 0:
                spec[i] = fs
                used.add("batch")
        elif name == "seq":
            if (ctx["seq_tp"] and tp and tp not in used
                    and d % mesh.shape.get(tp, 1) == 0):
                # Megatron SP: residual stream seq-sharded over MODEL
                spec[i] = tp
                used.add(tp)
            elif (ctx["seq_shard"] and "batch" not in used
                    and "data" not in used
                    and "data" in mesh.shape and d % mesh.shape["data"] == 0):
                spec[i] = "data"
                used.add("data")
        elif name == "cap":
            # MoE capacity dim: spread over the data axis so dispatch
            # scatter traffic stays shard-local (EP x DP buffer layout)
            if ("data" not in used and "data" in mesh.shape
                    and d % mesh.shape["data"] == 0):
                spec[i] = "data"
                used.add("data")
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


def active() -> bool:
    return _state() is not None
