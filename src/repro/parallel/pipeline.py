"""Pipeline parallelism (PP): GPipe-style microbatch pipeline over a mesh
axis, built from ``shard_map`` + ``lax.ppermute``.

Stages own contiguous layer groups (stage s holds params[s]); microbatches
stream through: at tick t, stage s runs microbatch (t - s).  The schedule
costs the classic GPipe bubble (stages-1)/(ticks) — the autosharding
advisor accounts for it when scoring PP against FSDPxTP layouts.  The whole
loop is differentiable (grad flows back through the reversed ppermutes), so
``pipeline_forward`` drops into the standard train step; combine with remat
for 1F1B-class memory behavior.

Layout contract:
  * ``params``: pytree with leading STAGE axis, sharded P("stage", ...)
  * ``x_mb``:   (n_micro, mb, ...) microbatched inputs (replicated over the
    stage axis; only stage 0 consumes them)
  * returns (n_micro, mb, ...) outputs (only stage L-1's results are real;
    they are gathered back to all stages)
"""

from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_forward(stage_fn: Callable, mesh: Mesh, axis: str,
                     params, x_mb):
    """Run the pipeline.  stage_fn(stage_params, x) -> y applies ONE stage's
    layer group; stage_params has the stage axis already stripped."""
    stages = mesh.shape[axis]
    n_micro = x_mb.shape[0]
    ticks = n_micro + stages - 1

    pspec = jax.tree.map(lambda _: P(axis), params)
    others = tuple(a for a in mesh.axis_names if a != axis)

    @partial(shard_map, mesh=mesh, check_rep=False,
             in_specs=(pspec, P()), out_specs=P())
    def run(p_local, xs):
        p_local = jax.tree.map(lambda a: a[0], p_local)   # strip stage dim
        s = jax.lax.axis_index(axis)
        buf = jnp.zeros_like(xs[0])
        outs = jnp.zeros_like(xs)

        def tick(carry, t):
            buf, outs = carry
            mb_in = t - 0
            xin0 = jnp.where(mb_in < n_micro,
                             xs[jnp.clip(mb_in, 0, n_micro - 1)], 0.0)
            xin = jnp.where(s == 0, xin0, buf)
            y = stage_fn(p_local, xin)
            # forward the activation to the next stage
            nxt = jax.lax.ppermute(
                y, axis, [(i, i + 1) for i in range(stages - 1)])
            mb_out = t - (stages - 1)
            outs = jnp.where(
                (s == stages - 1) & (mb_out >= 0) & (mb_out < n_micro),
                outs.at[jnp.clip(mb_out, 0, n_micro - 1)].set(y), outs)
            return (nxt, outs), None

        (_, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(ticks))
        # broadcast the last stage's collected outputs to every stage
        # (only stage L-1 holds real data; psum is a masked broadcast)
        outs = jax.lax.psum(
            jnp.where(s == stages - 1, outs, 0.0), axis)
        return outs

    return run(params, x_mb)


def split_stages(params, n_layers: int, stages: int):
    """Reshape layer-stacked params (L, ...) -> (stages, L/stages, ...)."""
    assert n_layers % stages == 0
    g = n_layers // stages
    return jax.tree.map(
        lambda a: a.reshape((stages, g) + a.shape[1:]), params)
