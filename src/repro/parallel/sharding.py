"""Logical sharding rules: param/optimizer/activation/cache PartitionSpecs.

Layout philosophy (DESIGN.md Sec. 5):
* every large weight is 2D-sharded — the contraction-safe dim over the
  ``model`` (TP) axis, the other over the ``("pod","data")`` FSDP axes —
  so parameters AND optimizer state scale with the full chip count
  (ZeRO-3 x TP), and adding pods never changes the rules;
* a dim is only sharded if divisible by the mesh-axis extent (GQA kv=8
  against a 16-way model axis falls back to replication — the Monad
  advisor's "sequence-sharded decode" covers that case for KV caches);
* MoE experts shard over ``model`` when the expert count divides it
  (deepseek: 160/16); otherwise experts replicate and each expert is
  TP-sharded internally (grok: 8 experts, d_ff 32768/16) — exactly the
  resource-vs-communication tradeoff Level A reasons about.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig, ParallelConfig


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    s = 1
    for a in axes:
        if a in mesh.shape:
            s *= mesh.shape[a]
    return s


def _div(dim: int, mesh: Mesh, axes) -> bool:
    return dim % max(_axis_size(mesh, axes), 1) == 0


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """Resolved mesh axes for this run (missing axes are dropped)."""
    fsdp: Tuple[str, ...]
    tensor: str

    def fs(self, mesh: Mesh):
        return tuple(a for a in self.fsdp if a in mesh.shape) or None

    def tp(self, mesh: Mesh):
        return self.tensor if self.tensor in mesh.shape else None


def make_rules(pc: ParallelConfig) -> AxisRules:
    return AxisRules(fsdp=tuple(pc.fsdp_axes), tensor=pc.tensor_axis)


def param_spec(path: Tuple[str, ...], leaf, cfg: ModelConfig,
               mesh: Mesh, rules: AxisRules) -> P:
    """PartitionSpec for one parameter leaf, by its tree path."""
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    name = names[-1]
    parent = names[-2] if len(names) >= 2 else ""
    stacked = "blocks" in names or "encoder" in names or "decoder" in names
    pre = (None,) if stacked else ()
    fs, tp = rules.fs(mesh), rules.tp(mesh)
    shp = leaf.shape[1:] if stacked else leaf.shape

    def guard(spec_dims):
        out = []
        for dim, ax in zip(shp, spec_dims):
            out.append(ax if ax is not None and _div(dim, mesh, ax) else None)
        return P(*pre, *out)

    if name == "embed":
        return guard((tp, fs))
    if name in ("scale", "b", "conv_b", "D", "meta"):
        if name == "b" and parent in ("wq", "wk", "wv", "wg", "wu"):
            return guard((tp,))
        return P(*pre, *([None] * len(shp)))
    if parent in ("wq", "wk", "wv") or parent in ("wg", "wu"):
        return guard((fs, tp))
    if parent in ("wo", "wd") or parent == "out_proj":
        return guard((tp, fs))
    if parent == "lm_head":
        return guard((fs, tp))
    if parent == "router":
        return guard((fs, None))
    if name in ("wg", "wu") and len(shp) == 3:                 # MoE (E, d, f)
        if _div(shp[0], mesh, tp):
            return guard((tp, fs, None))                       # EP
        return guard((None, fs, tp))                           # expert-TP
    if name == "wd" and len(shp) == 3:                         # MoE (E, f, d)
        if _div(shp[0], mesh, tp):
            return guard((tp, None, fs))
        return guard((None, tp, fs))
    if parent == "in_proj":                                    # mamba (d, 2di)
        return guard((fs, tp))
    if name == "conv_w":
        return guard((None, tp))
    if parent == "x_proj":
        return guard((tp, None))
    if parent == "dt_proj":
        return guard((None, tp))
    if name == "A_log":
        return guard((tp, None))
    if parent in ("wkv_down",):                                # MLA down-proj
        return guard((fs, None))
    if parent in ("wk_up", "wv_up"):
        return guard((None, tp))
    # default: replicate
    return P(*pre, *([None] * len(shp)))


def param_shardings(params_shape, cfg: ModelConfig, mesh: Mesh,
                    rules: AxisRules):
    """NamedSharding tree matching a params (or ShapeDtypeStruct) tree."""
    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, param_spec(p, l, cfg, mesh, rules)),
        params_shape)


# ---------------------------------------------------------------------------
# batch / cache shardings
# ---------------------------------------------------------------------------
DATA_AXES = ("pod", "data")     # batch parallelism axes (always on; the
                                # fsdp_axes knob only controls WEIGHT sharding)


def _batch_axes(mesh: Mesh):
    return tuple(a for a in DATA_AXES if a in mesh.shape) or None


def batch_spec(cfg: ModelConfig, pc: ParallelConfig, mesh: Mesh,
               batch: int, seq: int) -> Dict[str, P]:
    fs = _batch_axes(mesh)
    bax = fs if batch % max(_axis_size(mesh, fs), 1) == 0 else None
    sax = "data" if (pc.seq_shard and bax is None
                     and seq % max(_axis_size(mesh, "data"), 1) == 0) else None
    specs = {"tokens": P(bax, sax), "labels": P(bax, sax),
             "loss_mask": P(bax, sax)}
    if cfg.family == "encdec":
        specs["audio_embeds"] = P(bax, None, None)
    if cfg.family == "vlm":
        specs["patch_embeds"] = P(bax, None, None)
        specs["positions"] = P(bax, sax, None)
    return specs


def cache_spec(cfg: ModelConfig, pc: ParallelConfig, mesh: Mesh,
               batch: int):
    """PartitionSpecs for the KV/SSM cache pytree (decode cells).

    decode_kv='sequence': shard the cache SEQ dim over the model axis —
    flash-decoding-style partial-softmax reduction, the layout the advisor
    picks whenever kv_heads doesn't divide the model axis (GQA kv=8 vs 16).
    decode_kv='heads': classic head-sharded cache."""
    rules = make_rules(pc)
    tp = rules.tp(mesh)
    fs = _batch_axes(mesh)
    bax = fs if batch % max(_axis_size(mesh, fs), 1) == 0 else None
    mode = pc.decode_kv
    if mode == "auto":
        kv_ok = cfg.n_kv_heads > 0 and _div(cfg.n_kv_heads, mesh, tp)
        mode = "heads" if kv_ok else "sequence"

    def kv(leaf_ndim_5: bool = True):
        if mode == "heads":
            return P(None, bax, None, tp, None)
        return P(None, bax, tp, None, None)

    if cfg.family == "ssm":
        return (P(None, bax, None, tp), P(None, bax, tp, None))
    if cfg.family == "hybrid":
        attn = (kv(), kv(), P(None, bax, None))
        ssm = (P(None, bax, None, tp), P(None, bax, tp, None))
        return (attn, ssm)
    if cfg.use_mla:
        # compressed latent cache (L, B, S, r+dr): shard seq over model
        return P(None, bax, tp, None)
    if cfg.family == "encdec":
        return {"self": (kv(), kv()), "enc": P(bax, None, None)}
    return (kv(), kv())


def like_tree(specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
