"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
ssm_state=16, parallel attention+mamba heads, sliding-window attention
(w=1024) + 128 meta tokens  [arXiv:2411.13676; hf]."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab=32001, ssm_state=16, ssm_conv=4, ssm_expand=2,
    window=1024, meta_tokens=128,
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="hymba-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab=256, window=32,
        meta_tokens=8, ssm_state=8)
