"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the exact assigned full-scale config;
``get_reduced(name)`` returns the same-family reduced config used by the CPU
smoke tests (the full configs are only ever lowered via ShapeDtypeStruct in
the dry-run).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.models.config import ModelConfig, ShapeConfig, SHAPES

ARCH_IDS = [
    "deepseek_v2_236b",
    "grok_1_314b",
    "stablelm_1_6b",
    "qwen2_72b",
    "qwen2_5_32b",
    "internlm2_1_8b",
    "whisper_tiny",
    "hymba_1_5b",
    "falcon_mamba_7b",
    "qwen2_vl_72b",
]

# canonical external ids (--arch flag) -> module names
ALIASES = {
    "deepseek-v2-236b": "deepseek_v2_236b",
    "grok-1-314b": "grok_1_314b",
    "stablelm-1.6b": "stablelm_1_6b",
    "qwen2-72b": "qwen2_72b",
    "qwen2.5-32b": "qwen2_5_32b",
    "internlm2-1.8b": "internlm2_1_8b",
    "whisper-tiny": "whisper_tiny",
    "hymba-1.5b": "hymba_1_5b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "qwen2-vl-72b": "qwen2_vl_72b",
}


def _module(name: str):
    mod = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_reduced(name: str) -> ModelConfig:
    return _module(name).reduced()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def cells(arch: str) -> List[str]:
    """The shape cells this arch runs (long_500k only for sub-quadratic
    archs; see DESIGN.md Sec. 4)."""
    cfg = get_config(arch)
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        out.append("long_500k")
    return out
