"""deepseek-v2-236b [moe]: 60L d_model=5120 128H (MLA kv_lora=512) vocab=102400,
MoE 160 routed top-6 + 2 shared, expert d_ff=1536  [arXiv:2405.04434; hf].

All 60 layers are MoE with the assigned expert width (we do not add
DeepSeek's first-k-dense exception; the config is kept exactly as assigned —
DESIGN.md Sec. 4)."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=1536, vocab=102400, head_dim=128,
    n_experts=160, top_k=6, n_shared_experts=2, expert_ff=1536,
    use_mla=True, kv_lora_rank=512, qk_rope_dim=64, qk_nope_dim=128,
    v_head_dim=128,
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="deepseek-v2-reduced", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, vocab=256,
        n_experts=8, top_k=2, n_shared_experts=1, expert_ff=32, d_ff=32,
        kv_lora_rank=16, qk_rope_dim=8, qk_nope_dim=16, v_head_dim=16)
