"""qwen2-vl-72b [vlm]: qwen2-72b backbone + M-RoPE (t/h/w rotary sections)
+ dynamic-resolution vision frontend as a STUB — input_specs() provides
patch embeddings and (t, h, w) position ids  [arXiv:2409.12191; hf]."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29568, vocab=152064, qkv_bias=True, rope_theta=1e6,
    mrope_sections=(16, 24, 24),
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="qwen2-vl-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=160, vocab=256,
        mrope_sections=(4, 2, 2))
