"""whisper-tiny [audio]: 4+4L d_model=384 6H d_ff=1536 vocab=51865,
enc-dec; the conv frontend is a STUB — input_specs() provides post-conv
frame embeddings (B, 1500, d)  [arXiv:2212.04356; unverified]."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="encdec",
    n_layers=4, enc_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab=51865, enc_positions=1500,
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="whisper-reduced", n_layers=2, enc_layers=2,
        d_model=64, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128,
        vocab=256, enc_positions=32)
