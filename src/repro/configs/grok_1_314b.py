"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2  [hf:xai-org/grok-1; unverified]."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=32768, vocab=131072,
    n_experts=8, top_k=2, expert_ff=32768,
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="grok-1-reduced", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab=256,
        n_experts=4, top_k=2, expert_ff=128)
