"""falcon-mamba-7b [ssm]: 64L d_model=4096, attention-free mamba1
(d_inner=8192, d_state=16, d_conv=4), vocab=65024
[arXiv:2410.05355; unverified]."""
import dataclasses
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=65024, ssm_state=16, ssm_conv=4, ssm_expand=2,
)

def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, name="falcon-mamba-reduced", n_layers=2, d_model=64,
        vocab=256, ssm_state=8)
