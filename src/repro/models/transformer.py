"""Block and model assembly for all 10 architectures.

Layer stacking uses ``jax.lax.scan`` over axis-0-stacked per-layer params, so
the lowered HLO is depth-independent (critical for the 512-device dry-run
compiles) and the remat policy applies per scanned layer.

Block kinds (selected by ModelConfig.family):
    dense   — GQA attention + SwiGLU MLP               (qwen2/stablelm/internlm2)
    moe     — GQA (grok) or MLA (deepseek) + MoE FFN
    ssm     — Mamba-1 mixer only                        (falcon-mamba)
    hybrid  — parallel attention/SSM heads + MLP        (hymba)
    encdec  — Whisper encoder/decoder stacks
    vlm     — dense + M-RoPE positions + patch-embed splice (qwen2-vl)
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel import ctx
from . import layers as Ly
from .config import ModelConfig

Params = Dict


# ---------------------------------------------------------------------------
# per-layer init / apply
# ---------------------------------------------------------------------------
def block_init(key, cfg: ModelConfig, kind: Optional[str] = None) -> Params:
    kind = kind or cfg.family
    ks = jax.random.split(key, 8)
    p: Params = {"ln1": Ly.rmsnorm_init(cfg.d_model)}
    if kind in ("dense", "vlm"):
        p["attn"] = Ly.attention_init(ks[0], cfg)
        p["ln2"] = Ly.rmsnorm_init(cfg.d_model)
        p["mlp"] = Ly.mlp_init(ks[1], cfg)
    elif kind == "moe":
        p["attn"] = (Ly.mla_init(ks[0], cfg) if cfg.use_mla
                     else Ly.attention_init(ks[0], cfg))
        p["ln2"] = Ly.rmsnorm_init(cfg.d_model)
        p["moe"] = Ly.moe_init(ks[1], cfg)
    elif kind == "ssm":
        p["mamba"] = Ly.mamba_init(ks[0], cfg)
    elif kind == "hybrid":
        p["attn"] = Ly.attention_init(ks[0], cfg)
        p["mamba"] = Ly.mamba_init(ks[1], cfg)
        p["attn_norm"] = Ly.rmsnorm_init(cfg.d_model)
        p["ssm_norm"] = Ly.rmsnorm_init(cfg.d_model)
        p["ln2"] = Ly.rmsnorm_init(cfg.d_model)
        p["mlp"] = Ly.mlp_init(ks[2], cfg)
    elif kind == "enc":
        enc_cfg = cfg
        p["attn"] = Ly.attention_init(ks[0], enc_cfg)
        p["ln2"] = Ly.rmsnorm_init(cfg.d_model)
        p["mlp"] = Ly.mlp_init(ks[1], cfg)
    elif kind == "dec":
        p["attn"] = Ly.attention_init(ks[0], cfg)
        p["ln_x"] = Ly.rmsnorm_init(cfg.d_model)
        p["xattn"] = Ly.attention_init(ks[1], cfg, cross=True)
        p["ln2"] = Ly.rmsnorm_init(cfg.d_model)
        p["mlp"] = Ly.mlp_init(ks[2], cfg)
    else:
        raise ValueError(kind)
    return p


def block_apply(p: Params, cfg: ModelConfig, kind: str, x, positions,
                kv_cache=None, cache_index=None, enc_out=None,
                window_override: Optional[int] = None):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache = None
    h = Ly.rmsnorm(p["ln1"], x)

    if kind == "ssm":
        y, new_cache = Ly.mamba_apply(p["mamba"], cfg, h, state=kv_cache)
        return x + y, new_cache, aux

    if kind == "hybrid":
        win = cfg.window if window_override is None else window_override
        a_cache = None if kv_cache is None else kv_cache[0]
        m_state = None if kv_cache is None else kv_cache[1]
        if cache_index is not None:          # decode: O(window) rolling cache
            attn_out, a_new = Ly.attention_decode_rolling(
                p["attn"], cfg, h, cache_index, a_cache, win)
        else:
            attn_out, a_new = Ly.attention_apply(
                p["attn"], cfg, h, positions, mask_kind="window", window=win)
        ssm_out, m_new = Ly.mamba_apply(p["mamba"], cfg, h, state=m_state)
        # Hymba: fuse the two heads' outputs after per-branch normalization
        y = 0.5 * Ly.rmsnorm(p["attn_norm"], attn_out) \
            + 0.5 * Ly.rmsnorm(p["ssm_norm"], ssm_out)
        x = x + y
        h2 = Ly.rmsnorm(p["ln2"], x)
        x = x + Ly.mlp_apply(p["mlp"], h2)
        return x, (a_new, m_new), aux

    if kind == "moe" and cfg.use_mla:
        y, new_cache = Ly.mla_apply(p["attn"], cfg, h, positions,
                                    kv_cache=kv_cache,
                                    cache_index=cache_index)
    elif kind == "enc":
        y, _ = Ly.attention_apply(p["attn"], cfg, h, positions,
                                  mask_kind="none")
    else:
        y, new_cache = Ly.attention_apply(
            p["attn"], cfg, h, positions, mask_kind="causal",
            kv_cache=kv_cache, cache_index=cache_index)
    x = x + y

    if kind == "dec":
        hx = Ly.rmsnorm(p["ln_x"], x)
        enc_pos = jnp.broadcast_to(
            jnp.arange(enc_out.shape[1])[None], enc_out.shape[:2])
        y, _ = Ly.attention_apply(p["xattn"], cfg, hx, positions,
                                  kv_x=enc_out, kv_positions=enc_pos,
                                  mask_kind="none")
        x = x + y

    h2 = Ly.rmsnorm(p["ln2"], x)
    if kind == "moe":
        y, aux = Ly.moe_apply(p["moe"], cfg, h2)
    else:
        y = Ly.mlp_apply(p["mlp"], h2)
    return x + y, new_cache, aux


# ---------------------------------------------------------------------------
# stacked layers (scan) + remat
# ---------------------------------------------------------------------------
def stack_init(key, cfg: ModelConfig, n_layers: int, kind: str) -> Params:
    keys = jax.random.split(key, n_layers)
    return jax.vmap(lambda k: block_init(k, cfg, kind))(keys)


def _remat_policy(cfg: ModelConfig):
    if cfg.remat == "none":
        return None
    if cfg.remat == "full":
        return jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims


def _remat_group(L: int) -> int:
    """Largest divisor of L <= ceil(sqrt(L)): sqrt-checkpointing group size
    (saves L/G layer boundaries instead of L)."""
    import math as _m
    g = max(int(_m.ceil(_m.sqrt(L))), 1)
    while g > 1 and L % g != 0:
        g -= 1
    return g


def stack_apply(params: Params, cfg: ModelConfig, kind: str, x, positions,
                caches=None, cache_index=None, enc_out=None,
                window_override=None, collect_caches: bool = False):
    """scan over layers; caches is a pytree with leading layer axis.
    collect_caches=True forces the flat path that stacks per-layer new
    caches (hybrid prefill builds its rolling cache from them).

    Training path (caches=None, remat on): layers scan in sqrt(L) GROUPS
    with the whole group rematerialized — the backward keeps only L/G layer
    boundaries live instead of L (at 80 layers x 128 MB boundaries that is
    the difference between 10 GB and 1.3 GB per device), and per-layer K/V
    are never stacked."""
    def body(x, xs):
        p_l, c_l = xs
        x = ctx.shard(x, ("batch", "seq", None))
        y, c_new, aux = block_apply(p_l, cfg, kind, x, positions,
                                    kv_cache=c_l, cache_index=cache_index,
                                    enc_out=enc_out,
                                    window_override=window_override)
        return y, (c_new, aux)

    if caches is None and cfg.remat != "none" and not collect_caches:
        L = jax.tree_util.tree_leaves(params)[0].shape[0]
        G = cfg.remat_group if (cfg.remat_group and
                                L % cfg.remat_group == 0) \
            else _remat_group(L)

        def group_body(x, gparams):
            def inner(x, p_l):
                x = ctx.shard(x, ("batch", "seq", None))
                y, _, aux = block_apply(p_l, cfg, kind, x, positions,
                                        enc_out=enc_out,
                                        window_override=window_override)
                return y, aux
            return jax.lax.scan(inner, x, gparams)

        gb = jax.checkpoint(group_body, policy=_remat_policy(cfg),
                            prevent_cse=False)
        params_g = jax.tree.map(
            lambda a: a.reshape((L // G, G) + a.shape[1:]), params)
        x, auxs = jax.lax.scan(gb, x, params_g)
        return x, None, jnp.sum(auxs)

    x, (new_caches, auxs) = jax.lax.scan(body, x, (params, caches))
    return x, new_caches, jnp.sum(auxs)
