"""Model / run configuration for the 10 assigned architectures.

One ``ModelConfig`` instance per architecture lives in ``repro/configs/``;
the builders in ``repro.models`` consume only this dataclass, so every
architecture is a pure config choice (``--arch <id>``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 1e4

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    expert_ff: int = 0             # per-expert hidden (MoE d_ff)
    capacity_factor: float = 1.25
    moe_impl: str = "einsum"       # einsum (one-hot dispatch) | gather
                                   # (scatter/gather dispatch — no O(T*E*cap)
                                   # dispatch FLOPs; §Perf hillclimb)
    moe_groups: int = 1            # group-local dispatch: capacity is per
                                   # token group, dispatch FLOPs drop by G
                                   # (MaxText-style num_groups; §Perf)

    # --- MLA (DeepSeek-V2) ---------------------------------------------------
    use_mla: bool = False
    kv_lora_rank: int = 0          # compressed KV dim
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128

    # --- SSM (Mamba-1) --------------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2

    # --- hybrid (Hymba) --------------------------------------------------------
    window: int = 0                # sliding-window size (0 = full attention)
    meta_tokens: int = 0

    # --- encoder-decoder (Whisper) ---------------------------------------------
    enc_layers: int = 0
    enc_positions: int = 1500      # post-conv audio frames

    # --- VLM (Qwen2-VL) -----------------------------------------------------------
    mrope_sections: Tuple[int, ...] = ()   # (t, h, w) rotary sections

    # --- numerics / training -----------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: str = "dots"            # none | dots | full
    remat_group: int = 0           # layers per checkpoint group (0 = sqrt(L))
    logical_rules: str = "default"

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ---------------------------------------------------------------- info
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a 256 multiple so the embedding/lm_head can
        shard over the model axis (padding masked at the logits; an
        implementation detail — param_count() uses the true vocab)."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Can this arch run the 524k-token cell? (DESIGN.md Sec. 4)"""
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def param_count(self) -> int:
        """Total parameters (embedding + blocks + head), analytic."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        per = 0
        if self.family != "ssm":
            hd = self.head_dim
            if self.use_mla:
                q = d * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
                kv = (d * (self.kv_lora_rank + self.qk_rope_dim)
                      + self.kv_lora_rank * self.n_heads
                      * (self.qk_nope_dim + self.v_head_dim))
                o = self.n_heads * self.v_head_dim * d
                per += q + kv + o
            else:
                per += d * (self.n_heads + 2 * self.n_kv_heads) * hd
                per += self.n_heads * hd * d
        if self.family in ("ssm", "hybrid"):
            di, ds = self.d_inner, self.ssm_state
            per += d * 2 * di + di * self.ssm_conv + di * (2 * ds + 1) \
                + di * ds + di + di * d
        if self.n_experts > 0:
            per += d * self.n_experts          # router
            per += 3 * d * self.expert_ff * (self.n_experts
                                             + self.n_shared_experts)
        elif self.family != "ssm":
            per += 3 * d * self.d_ff
        per += 2 * d                            # norms
        total = emb + L * per
        if self.enc_layers:
            enc_per = d * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim \
                + self.n_heads * self.head_dim * d + 3 * d * self.d_ff + 2 * d
            # decoder cross-attention
            total += self.enc_layers * enc_per + L * enc_per // 2
        return int(total)

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE top-k instead of all experts)."""
        if self.n_experts == 0:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        inactive = 3 * d * self.expert_ff * (self.n_experts - self.top_k)
        return int(self.param_count() - L * inactive)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (the assigned shape grid)."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """Distribution layout for a (config, shape, mesh) cell.  The defaults
    are what the Monad-based autosharding advisor picks (see
    repro.autosharding); every knob here is a searchable field there."""
    fsdp_axes: Tuple[str, ...] = ("pod", "data")   # weight/optimizer sharding
    tensor_axis: str = "model"
    expert_sharding: str = "auto"   # auto | expert | tensor (grok: tensor)
    decode_kv: str = "auto"         # auto | heads | sequence
    seq_shard: bool = False         # SP: shard activations along seq (long ctx)
    seq_tp: bool = False            # Megatron-style sequence parallelism:
                                    # residual stream seq-sharded over the
                                    # MODEL axis (TP all-reduces become
                                    # reduce-scatter/all-gather pairs and
                                    # layer boundaries shrink by TP)
    pipeline_stages: int = 1        # PP (>1 uses parallel.pipeline)
    microbatch: int = 1
    remat: str = "dots"
