"""Pure-JAX layer library for the 10 assigned architectures.

Functional style: each module is an ``<name>_init(key, cfg) -> params`` +
``<name>_apply(params, ...) -> out`` pair over plain dict pytrees (no flax
offline).  Everything is written to lower cleanly under pjit with the
logical sharding rules in ``repro.parallel.sharding``:

* weights are 2D-shardable (row dim -> fsdp axes, col dim -> tensor axis),
* attention uses the flash-attention op (Pallas kernel on TPU, fused jnp
  reference elsewhere) with causal / sliding-window / cross variants,
* MoE uses capacity-factor dispatch/combine einsums (static shapes; the
  expert axis is shardable for EP, XLA inserts the all-to-alls),
* Mamba-1 uses a chunked selective scan (Pallas kernel on TPU).
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from repro.parallel import ctx

Params = Dict


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(key, d_in: int, d_out: int, bias: bool = False,
               scale: Optional[float] = None, dtype=jnp.float32) -> Params:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out), dtype) * scale)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Params, x, dtype=None):
    w = p["w"].astype(dtype or x.dtype)
    y = x @ w
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rmsnorm_init(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p: Params, x, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * p["scale"]).astype(dt)


# ---------------------------------------------------------------------------
# rotary embeddings (RoPE + 3-section M-RoPE for Qwen2-VL)
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 1e4,
               sections: Tuple[int, ...] = ()):
    """x: (B, S, H, D); positions: (B, S) or (B, S, 3) for M-RoPE.

    M-RoPE (Qwen2-VL): the rotary dims are split into (t, h, w) sections,
    each rotated by its own position stream."""
    B, S, H, D = x.shape
    inv = rope_freqs(D, theta)                        # (D/2,)
    if sections:
        assert positions.ndim == 3 and sum(sections) == D // 2
        secs = []
        start = 0
        for si, sec in enumerate(sections):
            secs.append(positions[..., si:si + 1]
                        * jnp.ones((sec,), jnp.float32))
            start += sec
        pos = jnp.concatenate(secs, axis=-1)          # (B, S, D/2)
        ang = pos * inv[None, None, :]
    else:
        ang = positions.astype(jnp.float32)[..., None] * inv[None, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA; causal / sliding-window / cross) via the flash-attn op
# ---------------------------------------------------------------------------
def attention_init(key, cfg: ModelConfig, cross: bool = False) -> Params:
    ks = jax.random.split(key, 4)
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "wq": dense_init(ks[0], d, H * hd, bias=cfg.qkv_bias),
        "wk": dense_init(ks[1], d, KV * hd, bias=cfg.qkv_bias),
        "wv": dense_init(ks[2], d, KV * hd, bias=cfg.qkv_bias),
        "wo": dense_init(ks[3], H * hd, d),
    }


def _split_heads(x, n, hd):
    B, S, _ = x.shape
    return x.reshape(B, S, n, hd)


def attention_apply(p: Params, cfg: ModelConfig, x, positions,
                    kv_x=None, kv_positions=None, mask_kind: str = "causal",
                    window: int = 0, kv_cache=None, cache_index=None,
                    use_rope: bool = True):
    """Returns (out, new_kv_cache).  kv_cache = (k, v) with shape
    (B, S_cache, KV, hd); cache_index = current fill position (decode)."""
    from repro.kernels.flash_attention import ops as fa
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    src = x if kv_x is None else kv_x
    q = ctx.shard(_split_heads(dense(p["wq"], x), H, hd),
                  ("batch", "seq", "heads", None))
    k = ctx.shard(_split_heads(dense(p["wk"], src), KV, hd),
                  ("batch", "seq", "heads", None))
    v = ctx.shard(_split_heads(dense(p["wv"], src), KV, hd),
                  ("batch", "seq", "heads", None))
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        kp = positions if kv_positions is None else kv_positions
        k = apply_rope(k, kp, cfg.rope_theta, cfg.mrope_sections)

    if kv_cache is not None:
        ck, cv = kv_cache
        if cache_index is not None:                       # decode: append
            # replicate the (tiny) new-token tensors over the model axis:
            # the cache keeps its seq-sharded layout and attention reduces
            # via DISTRIBUTED partial softmax (flash-decoding) instead of
            # GSPMD all-gathering the cache to match q's head sharding
            q = ctx.shard(q, ("batch", None, None, None))
            k = ctx.shard(k, ("batch", None, None, None))
            v = ctx.shard(v, ("batch", None, None, None))
            ck = jax.lax.dynamic_update_slice_in_dim(
                ck, k.astype(ck.dtype), cache_index, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cv, v.astype(cv.dtype), cache_index, axis=1)
        k, v = ck, cv
        new_cache = (ck, cv)
    else:
        new_cache = (k, v)        # prefill: caller may build a cache from it

    kv_len = (cache_index + x.shape[1] if cache_index is not None else None)
    out = fa.flash_attention(q, k, v, mask_kind=mask_kind, window=window,
                             kv_valid_len=kv_len)
    out = ctx.shard(out, ("batch", None, None, None)
                    if cache_index is not None
                    else ("batch", "seq", "heads", None))
    B, S = x.shape[:2]
    return dense(p["wo"], out.reshape(B, S, H * hd)), new_cache


def attention_decode_rolling(p: Params, cfg: ModelConfig, x, position,
                             cache, window: int):
    """Single-token decode against an O(window) ROLLING KV cache (Hymba
    sliding-window heads; what makes hymba's long_500k cell O(1) in seq).

    cache = (k (B, W, KV, hd), v (B, W, KV, hd), kpos (B, W) int32, -1 =
    empty).  Keys are stored rope'd at their absolute positions.  Returns
    (out, new_cache)."""
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    B = x.shape[0]
    pos2d = jnp.broadcast_to(jnp.asarray(position)[None, None], (B, 1))
    q = _split_heads(dense(p["wq"], x), H, hd)
    k = _split_heads(dense(p["wk"], x), KV, hd)
    v = _split_heads(dense(p["wv"], x), KV, hd)
    q = apply_rope(q, pos2d, cfg.rope_theta)
    k = apply_rope(k, pos2d, cfg.rope_theta)

    ck, cv, kpos = cache
    ck = jnp.concatenate([ck[:, 1:], k.astype(ck.dtype)], axis=1)
    cv = jnp.concatenate([cv[:, 1:], v.astype(cv.dtype)], axis=1)
    kpos = jnp.concatenate(
        [kpos[:, 1:], pos2d.astype(kpos.dtype)], axis=1)

    rep = H // KV
    kf = jnp.repeat(ck, rep, axis=2).astype(jnp.float32)
    vf = jnp.repeat(cv, rep, axis=2).astype(jnp.float32)
    qf = q.astype(jnp.float32) / jnp.sqrt(jnp.float32(hd))
    s = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
    valid = ((kpos >= 0) & (kpos <= position)
             & (position - kpos < window))                # (B, W)
    s = jnp.where(valid[:, None, None, :], s, jnp.finfo(jnp.float32).min)
    pr = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", pr, vf).astype(x.dtype)
    out = dense(p["wo"], out.reshape(B, 1, H * hd))
    return out, (ck, cv, kpos)


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek-V2)
# ---------------------------------------------------------------------------
def mla_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 6)
    d, H = cfg.d_model, cfg.n_heads
    r, dr, dn, dv = (cfg.kv_lora_rank, cfg.qk_rope_dim, cfg.qk_nope_dim,
                     cfg.v_head_dim)
    return {
        # queries: full-rank projection to per-head (nope + rope) dims
        "wq": dense_init(ks[0], d, H * (dn + dr)),
        # KV: compress to latent r (+ shared rope key), then up-project
        "wkv_down": dense_init(ks[1], d, r + dr),
        "kv_norm": rmsnorm_init(r),
        "wk_up": dense_init(ks[2], r, H * dn),
        "wv_up": dense_init(ks[3], r, H * dv),
        "wo": dense_init(ks[4], H * dv, d),
    }


def mla_apply(p: Params, cfg: ModelConfig, x, positions,
              kv_cache=None, cache_index=None):
    """MLA with the *compressed* latent as the KV cache — the paper-faithful
    memory saving: cache is (B, S, r + dr) instead of (B, S, 2*H*hd).

    kv_cache: (B, S_cache, r + dr); returns (out, new_cache)."""
    from repro.kernels.flash_attention import ops as fa
    H = cfg.n_heads
    r, dr, dn, dv = (cfg.kv_lora_rank, cfg.qk_rope_dim, cfg.qk_nope_dim,
                     cfg.v_head_dim)
    B, S, _ = x.shape

    q = ctx.shard(dense(p["wq"], x).reshape(B, S, H, dn + dr),
                  ("batch", "seq", "heads", None))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    latent = dense(p["wkv_down"], x)                     # (B, S, r + dr)
    if kv_cache is not None and cache_index is not None:
        latent = jax.lax.dynamic_update_slice_in_dim(
            kv_cache, latent.astype(kv_cache.dtype), cache_index, axis=1)
    new_cache = latent if kv_cache is not None else None
    c_kv, k_rope_flat = latent[..., :r], latent[..., r:]
    c_kv = rmsnorm(p["kv_norm"], c_kv)
    Sk = c_kv.shape[1]
    # absolute positions of cached entries for the shared rope key
    kpos = jnp.broadcast_to(jnp.arange(Sk)[None, :], (B, Sk))
    k_rope = apply_rope(k_rope_flat[:, :, None, :], kpos, cfg.rope_theta)

    k_nope = ctx.shard(dense(p["wk_up"], c_kv).reshape(B, Sk, H, dn),
                       ("batch", "seq", "heads", None))
    v = ctx.shard(dense(p["wv_up"], c_kv).reshape(B, Sk, H, dv),
                  ("batch", "seq", "heads", None))
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope, (B, Sk, H, dr))], -1)
    qf = jnp.concatenate([q_nope, q_rope], -1)

    kv_len = (cache_index + S if cache_index is not None else None)
    out = fa.flash_attention(qf, k, v, mask_kind="causal",
                             kv_valid_len=kv_len)
    return dense(p["wo"], out.reshape(B, S, H * dv)), new_cache


# ---------------------------------------------------------------------------
# MLPs: dense SwiGLU + capacity-factor MoE
# ---------------------------------------------------------------------------
def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    ks = jax.random.split(key, 3)
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {"wg": dense_init(ks[0], d, f), "wu": dense_init(ks[1], d, f),
            "wd": dense_init(ks[2], f, d)}


def mlp_apply(p: Params, x):
    h = jax.nn.silu(dense(p["wg"], x)) * dense(p["wu"], x)
    if h.ndim == 3:
        h = ctx.shard(h, ("batch", "seq", "tp"))
    return dense(p["wd"], h)


def moe_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 5)
    d, E, f = cfg.d_model, cfg.n_experts, cfg.expert_ff
    s = 1.0 / math.sqrt(d)
    p = {
        "router": dense_init(ks[0], d, E, scale=0.02),
        "wg": jax.random.normal(ks[1], (E, d, f)) * s,
        "wu": jax.random.normal(ks[2], (E, d, f)) * s,
        "wd": jax.random.normal(ks[3], (E, f, d)) * (1.0 / math.sqrt(f)),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[4], cfg,
                               d_ff=cfg.expert_ff * cfg.n_shared_experts)
    return p


def _moe_route(p: Params, cfg: ModelConfig, xt):
    """Shared router math: returns (gate_vals (T,K), gate_idx, pos (T,K),
    in_cap (T,K), cap, aux)."""
    E, K = cfg.n_experts, cfg.top_k
    T = xt.shape[0]
    logits = dense(p["router"], xt).astype(jnp.float32)     # (T, E)
    probs = jax.nn.softmax(logits, -1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)            # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)
    cap = max(int(cfg.capacity_factor * T * K / E), 1)
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)    # (T, K, E)
    flat = onehot.reshape(T * K, E)
    pos_e = jnp.cumsum(flat, axis=0) * flat - 1              # (T*K, E)
    pos = jnp.max(pos_e.reshape(T, K, E), axis=-1)           # (T, K)
    in_cap = (pos >= 0) & (pos < cap)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jnp.sum(onehot, axis=1).astype(jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)
    return gate_vals, gate_idx, pos, in_cap, cap, onehot, aux


def _moe_experts(p: Params, cfg: ModelConfig, xe, dtype):
    """Batched expert FFN over (E, cap, d) buffers."""
    h = jnp.einsum("ecd,edf->ecf", xe, p["wg"].astype(dtype))
    u = jnp.einsum("ecd,edf->ecf", xe, p["wu"].astype(dtype))
    h = ctx.shard(h, ("ep", None, "tp"))
    u = ctx.shard(u, ("ep", None, "tp"))
    ye = ctx.shard(jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u,
                              p["wd"].astype(dtype)),
                   ("ep", None, None))                       # (E, cap, d)
    return ye


def moe_apply(p: Params, cfg: ModelConfig, x):
    """Capacity-factor top-k MoE.  Static shapes (dry-run friendly): tokens
    beyond an expert's capacity are dropped (residual passes through); the
    expert (E) axis is shardable — under EP dispatch lowers to all-to-alls.

    Two dispatch implementations (cfg.moe_impl):
      * 'einsum' — classic one-hot dispatch/combine matmuls.  Simple, but
        the dispatch tensor costs O(T*E*cap) FLOPs, which DWARFS the expert
        FLOPs at deepseek scale (160 experts) — see EXPERIMENTS.md §Perf.
      * 'gather' — scatter tokens into the (E*cap, d) buffer and gather
        results back by index: zero dispatch FLOPs, same numerics.
    Returns (out, aux_loss)."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, d)
    gate_vals, gate_idx, pos, in_cap, cap, onehot, aux = \
        _moe_route(p, cfg, xt)

    if cfg.moe_impl == "gather":
        # scatter/gather dispatch: buffer row = expert * cap + position
        buf_idx = jnp.where(in_cap, gate_idx * cap + pos, E * cap)  # (T,K)
        xe = jnp.zeros((E * cap + 1, d), x.dtype)
        tok_idx = jnp.broadcast_to(jnp.arange(T)[:, None], (T, K))
        xe = xe.at[buf_idx.reshape(-1)].add(
            xt[tok_idx.reshape(-1)], mode="drop")
        xe = ctx.shard(xe[:E * cap].reshape(E, cap, d), ("ep", None, None))
        ye = _moe_experts(p, cfg, xe, x.dtype)
        flat = jnp.concatenate(
            [ye.reshape(E * cap, d), jnp.zeros((1, d), ye.dtype)])
        picked = flat[buf_idx.reshape(-1)].reshape(T, K, d)
        out = jnp.sum(picked * (gate_vals
                                * in_cap.astype(jnp.float32)
                                )[..., None].astype(x.dtype), axis=1)
    else:
        # group-local dispatch (cfg.moe_groups = G): tokens compete for
        # capacity only within their group, so the dispatch one-hots are
        # (G, Tg, E, cap/G) and dispatch FLOPs drop by G while the expert
        # batch keeps the same total capacity (MaxText num_groups).
        G = max(cfg.moe_groups, 1)
        Tg, capg = T // G, max(cap // G, 1)
        pos_c = jnp.clip(pos, 0, cap - 1)
        if G > 1:
            # recompute positions group-locally
            oh_g = onehot.reshape(G, Tg * K, E)
            pos_g = jnp.cumsum(oh_g, axis=1) * oh_g - 1      # (G, Tg*K, E)
            pos = jnp.max(pos_g.reshape(G, Tg, K, E), axis=-1)
            in_cap_g = (pos >= 0) & (pos < capg)
            pos_c = jnp.clip(pos, 0, capg - 1)
            ohg = onehot.reshape(G, Tg, K, E).astype(x.dtype)
            disp = jnp.einsum(
                "gtke,gtkc->gtec", ohg,
                jax.nn.one_hot(pos_c, capg, dtype=x.dtype)
                * in_cap_g[..., None].astype(x.dtype))       # (G,Tg,E,capg)
            comb = disp * jnp.einsum(
                "gtk,gtke->gte",
                gate_vals.reshape(G, Tg, K)
                * in_cap_g.astype(jnp.float32),
                ohg.astype(jnp.float32)).astype(x.dtype)[..., None]
            xg = xt.reshape(G, Tg, d)
            xe = jnp.einsum("gtd,gtec->egcd", xg, disp)      # (E,G,capg,d)
            xe = ctx.shard(xe.reshape(E, G * capg, d), ("ep", None, None))
            ye = _moe_experts(p, cfg, xe, x.dtype)
            ye = ye.reshape(E, G, capg, d)
            out = jnp.einsum("egcd,gtec->gtd", ye, comb).reshape(T, d)
        else:
            disp = jnp.einsum(
                "tke,tkc->tec", onehot.astype(x.dtype),
                jax.nn.one_hot(pos_c, cap, dtype=x.dtype)
                * in_cap[..., None].astype(x.dtype))         # (T, E, cap)
            comb = disp * jnp.einsum(
                "tk,tke->te", gate_vals * in_cap.astype(jnp.float32),
                onehot.astype(jnp.float32)).astype(x.dtype)[:, :, None]
            xe = ctx.shard(jnp.einsum("td,tec->ecd", xt, disp),
                           ("ep", None, None))               # (E, cap, d)
            ye = _moe_experts(p, cfg, xe, x.dtype)
            out = jnp.einsum("ecd,tec->td", ye, comb)

    if cfg.n_shared_experts:
        out = out + mlp_apply(p["shared"], xt)
    return out.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# Mamba-1 block (Falcon-Mamba / Hymba SSM heads)
# ---------------------------------------------------------------------------
def mamba_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 6)
    d, di, ds, dc = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    dt_rank = max(d // 16, 1)
    return {
        "in_proj": dense_init(ks[0], d, 2 * di),
        "conv_w": jax.random.normal(ks[1], (dc, di)) * 0.2,
        "conv_b": jnp.zeros((di,)),
        "x_proj": dense_init(ks[2], di, dt_rank + 2 * ds),
        "dt_proj": dense_init(ks[3], dt_rank, di, bias=True),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))),
        "D": jnp.ones((di,)),
        "out_proj": dense_init(ks[4], di, d),
    }


def mamba_apply(p: Params, cfg: ModelConfig, x, state=None):
    """Mamba-1: in-proj -> causal conv1d -> selective SSM scan -> gate.

    state: None (full-sequence scan) or (conv_state (B, dc-1, di),
    ssm_state (B, di, ds)) for single-step decode.
    Returns (y, new_state)."""
    from repro.kernels.mamba_scan import ops as ms
    B, S, d = x.shape
    di, ds, dc = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    dt_rank = max(d // 16, 1)

    xz = dense(p["in_proj"], x)
    xs, z = jnp.split(xz, 2, axis=-1)                        # (B, S, di)
    xs = ctx.shard(xs, ("batch", "seq", "tp"))
    z = ctx.shard(z, ("batch", "seq", "tp"))

    if state is None:
        pad = jnp.zeros((B, dc - 1, di), xs.dtype)
        new_conv = jnp.concatenate([pad, xs], 1)[:, -(dc - 1):, :] \
            if dc > 1 else jnp.zeros((B, 0, di), xs.dtype)
        xc = jnp.concatenate([pad, xs], axis=1)
        conv = sum(xc[:, i:i + S, :] * p["conv_w"][i].astype(xs.dtype)
                   for i in range(dc))
    else:
        conv_state, ssm_state = state
        xc = jnp.concatenate([conv_state.astype(xs.dtype), xs], axis=1)
        new_conv = xc[:, -(dc - 1):, :] if dc > 1 \
            else jnp.zeros((B, 0, di), xs.dtype)
        conv = sum(xc[:, i:i + S, :] * p["conv_w"][i].astype(xs.dtype)
                   for i in range(dc))
    u = jax.nn.silu(conv + p["conv_b"].astype(xs.dtype))

    proj = dense(p["x_proj"], u)
    dt, Bc, Cc = jnp.split(proj, [dt_rank, dt_rank + ds], axis=-1)
    delta = jax.nn.softplus(dense(p["dt_proj"], dt)).astype(jnp.float32)
    A = -jnp.exp(p["A_log"])                                 # (di, ds)

    h0 = (None if state is None else state[1])
    y, hT = ms.selective_scan(u.astype(jnp.float32), delta, A,
                              Bc.astype(jnp.float32), Cc.astype(jnp.float32),
                              h0=h0)
    y = (y + u.astype(jnp.float32) * p["D"]).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = dense(p["out_proj"], y)
    return out, (new_conv, hT)
