"""Model assembly: ``build_model(cfg)`` -> init / forward / loss / prefill /
decode_step for every architecture family.

The returned functions are pure (params and caches are explicit pytrees) so
they compose directly with pjit sharding, the AdamW optimizer, checkpointing
and the dry-run launcher.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel import ctx
from . import layers as Ly
from . import transformer as Tr
from .config import ModelConfig

Params = Dict


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable
    forward: Callable                 # (params, batch) -> (logits, aux)
    loss: Callable                    # (params, batch) -> (loss, metrics)
    init_cache: Callable              # (batch, max_seq) -> cache
    prefill: Callable                 # (params, batch, cache) -> (logits, cache)
    decode_step: Callable             # (params, tokens, cache, index) -> ...


def _embed_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 3)
    p = {"embed": jax.random.normal(ks[0], (cfg.padded_vocab, cfg.d_model),
                                    jnp.float32) * 0.02,
         "final_norm": Ly.rmsnorm_init(cfg.d_model)}
    if not cfg.tie_embeddings:
        p["lm_head"] = Ly.dense_init(ks[1], cfg.d_model, cfg.padded_vocab)
    return p


def _logits(p: Params, cfg: ModelConfig, x):
    h = Ly.rmsnorm(p["final_norm"], x)
    if cfg.tie_embeddings:
        out = h @ p["embed"].T.astype(h.dtype)
    else:
        out = Ly.dense(p["lm_head"], h)
    if cfg.padded_vocab != cfg.vocab:
        # mask the padding ids (keeps the vocab-sharded layout intact)
        ids = jnp.arange(cfg.padded_vocab)
        out = jnp.where(ids >= cfg.vocab, jnp.asarray(-1e9, out.dtype), out)
    return out


def _positions(batch, B, S, cfg: ModelConfig):
    if "positions" in batch:
        return batch["positions"]
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    if cfg.mrope_sections:
        pos = jnp.broadcast_to(pos[..., None], (B, S, 3))
    return pos


def _xent(logits, labels, mask=None):
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# decoder-only families (dense / moe / ssm / hybrid / vlm)
# ---------------------------------------------------------------------------
def _build_decoder(cfg: ModelConfig) -> Model:
    kind = cfg.family if cfg.family != "vlm" else "dense"
    dt = jnp.dtype(cfg.dtype)

    def init(key) -> Params:
        k1, k2, k3 = jax.random.split(key, 3)
        p = _embed_init(k1, cfg)
        p["blocks"] = Tr.stack_init(k2, cfg, cfg.n_layers, kind)
        if cfg.meta_tokens:
            p["meta"] = jax.random.normal(
                k3, (cfg.meta_tokens, cfg.d_model), jnp.float32) * 0.02
        return p

    def embed_inputs(p, batch):
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = jnp.take(p["embed"], tokens, axis=0).astype(dt)
        if cfg.family == "vlm" and "patch_embeds" in batch:
            # vision stub: patch embeddings from the frontend replace the
            # leading positions (M-RoPE position ids come with the batch)
            pe = batch["patch_embeds"].astype(dt)
            x = jax.lax.dynamic_update_slice(x, pe, (0, 0, 0))
        if cfg.meta_tokens:
            meta = jnp.broadcast_to(p["meta"].astype(dt)[None],
                                    (B, cfg.meta_tokens, cfg.d_model))
            x = jnp.concatenate([meta, x], axis=1)
        return ctx.shard(x, ("batch", "seq", None))

    def forward(p, batch):
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = embed_inputs(p, batch)
        pos = _positions(batch, B, x.shape[1], cfg)
        x, _, aux = Tr.stack_apply(p["blocks"], cfg, kind, x, pos)
        if cfg.meta_tokens:
            x = x[:, cfg.meta_tokens:]
        return _logits(p, cfg, x), aux

    def loss(p, batch):
        logits, aux = forward(p, batch)
        labels = batch.get("labels")
        if labels is None:
            labels = jnp.pad(batch["tokens"][:, 1:], ((0, 0), (0, 1)))
        l = _xent(logits, labels, batch.get("loss_mask"))
        total = l + 0.01 * aux
        return total, {"xent": l, "aux": aux}

    # ---- caches -------------------------------------------------------------
    def init_cache(batch_size: int, max_seq: int):
        L, B = cfg.n_layers, batch_size
        KV, hd = cfg.n_kv_heads, cfg.head_dim
        if cfg.family == "ssm":
            return (jnp.zeros((L, B, cfg.ssm_conv - 1, cfg.d_inner), dt),
                    jnp.zeros((L, B, cfg.d_inner, cfg.ssm_state),
                              jnp.float32))
        if cfg.family == "hybrid":
            W = min(cfg.window or max_seq, max_seq) + cfg.meta_tokens
            attn = (jnp.zeros((L, B, W, KV, hd), dt),
                    jnp.zeros((L, B, W, KV, hd), dt),
                    jnp.full((L, B, W), -1, jnp.int32))
            ssm = (jnp.zeros((L, B, cfg.ssm_conv - 1, cfg.d_inner), dt),
                   jnp.zeros((L, B, cfg.d_inner, cfg.ssm_state),
                             jnp.float32))
            return (attn, ssm)
        if cfg.use_mla:
            return jnp.zeros(
                (L, B, max_seq, cfg.kv_lora_rank + cfg.qk_rope_dim), dt)
        return (jnp.zeros((L, B, max_seq, KV, hd), dt),
                jnp.zeros((L, B, max_seq, KV, hd), dt))

    def prefill(p, batch, cache):
        """Process the prompt, fill the cache, return last-token logits."""
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = embed_inputs(p, batch)
        St = x.shape[1]
        pos = _positions(batch, B, St, cfg)
        if cfg.family in ("ssm",):
            x, new_cache, _ = Tr.stack_apply(p["blocks"], cfg, kind, x, pos,
                                             caches=cache)
        elif cfg.family == "hybrid":
            x, raw, _ = Tr.stack_apply(p["blocks"], cfg, kind, x, pos,
                                       collect_caches=True)
            (k_full, v_full), m_state = raw[0], raw[1]
            W = cache[0][0].shape[2]
            ck, cv, kpos = cache[0]
            take = min(W, St)
            ck = ck.at[:, :, -take:].set(k_full[:, :, St - take:].astype(dt))
            cv = cv.at[:, :, -take:].set(v_full[:, :, St - take:].astype(dt))
            kpos = kpos.at[:, :, -take:].set(
                jnp.broadcast_to(jnp.arange(St - take, St)[None, None],
                                 (cfg.n_layers, B, take)))
            new_cache = ((ck, cv, kpos), m_state)
        else:
            x, new_cache, _ = Tr.stack_apply(p["blocks"], cfg, kind, x, pos,
                                             caches=cache, cache_index=0)
        if cfg.meta_tokens:
            x = x[:, cfg.meta_tokens:]
        return _logits(p, cfg, x[:, -1:]), new_cache

    def decode_step(p, tokens, cache, index):
        """One decode step.  tokens: (B, 1); index: current absolute position
        (traced scalar ok on the blocked-attention path)."""
        B = tokens.shape[0]
        x = jnp.take(p["embed"], tokens, axis=0).astype(dt)
        pos = jnp.broadcast_to(jnp.asarray(index)[None, None], (B, 1))
        if cfg.mrope_sections:
            pos = jnp.broadcast_to(pos[..., None], (B, 1, 3))
        if cfg.family == "ssm":
            x, new_cache, _ = Tr.stack_apply(p["blocks"], cfg, kind, x, pos,
                                             caches=cache)
        else:
            x, new_cache, _ = Tr.stack_apply(p["blocks"], cfg, kind, x, pos,
                                             caches=cache, cache_index=index)
        return _logits(p, cfg, x), new_cache

    return Model(cfg, init, forward, loss, init_cache, prefill, decode_step)


# ---------------------------------------------------------------------------
# encoder-decoder (whisper)
# ---------------------------------------------------------------------------
def _build_encdec(cfg: ModelConfig) -> Model:
    dt = jnp.dtype(cfg.dtype)

    def init(key) -> Params:
        k1, k2, k3 = jax.random.split(key, 3)
        p = _embed_init(k1, cfg)
        p["encoder"] = Tr.stack_init(k2, cfg, cfg.enc_layers, "enc")
        p["decoder"] = Tr.stack_init(k3, cfg, cfg.n_layers, "dec")
        return p

    def encode(p, batch):
        """audio_embeds: (B, frames, d) — the conv frontend is a STUB; the
        input spec provides post-conv frame embeddings (DESIGN.md Sec. 4)."""
        x = batch["audio_embeds"].astype(dt)
        B, S, _ = x.shape
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x, _, _ = Tr.stack_apply(p["encoder"], cfg, "enc", x, pos)
        return x

    def forward(p, batch):
        enc = encode(p, batch)
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = jnp.take(p["embed"], tokens, axis=0).astype(dt)
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x, _, aux = Tr.stack_apply(p["decoder"], cfg, "dec", x, pos,
                                   enc_out=enc)
        return _logits(p, cfg, x), aux

    def loss(p, batch):
        logits, aux = forward(p, batch)
        labels = batch.get("labels")
        if labels is None:
            labels = jnp.pad(batch["tokens"][:, 1:], ((0, 0), (0, 1)))
        l = _xent(logits, labels, batch.get("loss_mask"))
        return l, {"xent": l, "aux": aux}

    def init_cache(batch_size: int, max_seq: int):
        L, B = cfg.n_layers, batch_size
        KV, hd = cfg.n_kv_heads, cfg.head_dim
        self_kv = (jnp.zeros((L, B, max_seq, KV, hd), dt),
                   jnp.zeros((L, B, max_seq, KV, hd), dt))
        enc = jnp.zeros((B, cfg.enc_positions, cfg.d_model), dt)
        return {"self": self_kv, "enc": enc}

    def prefill(p, batch, cache):
        enc = encode(p, batch)
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = jnp.take(p["embed"], tokens, axis=0).astype(dt)
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x, self_kv, _ = Tr.stack_apply(p["decoder"], cfg, "dec", x, pos,
                                       caches=cache["self"], cache_index=0,
                                       enc_out=enc)
        return _logits(p, cfg, x[:, -1:]), {"self": self_kv, "enc": enc}

    def decode_step(p, tokens, cache, index):
        B = tokens.shape[0]
        x = jnp.take(p["embed"], tokens, axis=0).astype(dt)
        pos = jnp.broadcast_to(jnp.asarray(index)[None, None], (B, 1))
        x, self_kv, _ = Tr.stack_apply(p["decoder"], cfg, "dec", x, pos,
                                       caches=cache["self"],
                                       cache_index=index,
                                       enc_out=cache["enc"])
        return _logits(p, cfg, x), {"self": self_kv, "enc": cache["enc"]}

    return Model(cfg, init, forward, loss, init_cache, prefill, decode_step)


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family == "encdec":
        return _build_encdec(cfg)
    return _build_decoder(cfg)
