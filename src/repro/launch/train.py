"""Train-step assembly + the (single-host) training loop driver.

``make_train_step`` composes model.loss with AdamW into the pjit-able step
used both by the dry-run (lowered against ShapeDtypeStructs on the
production mesh) and by the real CPU training loop in the examples
(reduced configs, host mesh).
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, ParallelConfig
from repro.models.model import Model, build_model
from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               global_norm)


def make_train_state(model: Model, key, opt_cfg: AdamWConfig) -> Dict:
    params = model.init(key)
    return {"params": params, "opt": adamw_init(opt_cfg, params)}


def train_state_specs(model: Model, opt_cfg: AdamWConfig):
    return jax.eval_shape(
        lambda: make_train_state(model, jax.random.PRNGKey(0), opt_cfg))


def make_train_step(model: Model, opt_cfg: AdamWConfig,
                    microbatches: int = 1) -> Callable:
    """(state, batch) -> (state, metrics).  Pure; pjit-ready.

    microbatches > 1 runs gradient accumulation: the global batch is split
    on its leading dim and scanned, so live activation memory scales with
    the microbatch while the gradient all-reduce still happens once per
    step (the per-microbatch grads accumulate in the sharded f32 buffer)."""

    def grad_fn(params, batch):
        return jax.value_and_grad(model.loss, has_aux=True)(params, batch)

    def step(state, batch):
        if microbatches <= 1:
            (loss, parts), grads = grad_fn(state["params"], batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                return x.reshape((microbatches, b // microbatches)
                                 + x.shape[1:])
            mb = jax.tree.map(split, batch)
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])

            def acc(carry, mbatch):
                g, l, a = carry
                (loss, parts), grads = grad_fn(state["params"], mbatch)
                g = jax.tree.map(
                    lambda x, y: x + y.astype(jnp.float32), g, grads)
                return (g, l + loss, a + parts["aux"]), None

            (grads, loss, aux), _ = jax.lax.scan(
                acc, (g0, jnp.zeros(()), jnp.zeros(())), mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            parts = {"xent": loss, "aux": aux / microbatches}
        params, opt, om = adamw_update(opt_cfg, grads, state["opt"],
                                       state["params"])
        metrics = {"loss": loss, **parts, **om}
        return {"params": params, "opt": opt}, metrics

    return step


def make_eval_step(model: Model) -> Callable:
    def step(params, batch):
        loss, parts = model.loss(params, batch)
        return {"loss": loss, **parts}
    return step


@dataclasses.dataclass
class LoopResult:
    losses: list
    steps: int
    wall_s: float


def train_loop(model: Model, state, batches, train_step,
               log_every: int = 20,
               on_step: Optional[Callable] = None) -> LoopResult:
    """Simple driver (the fault-tolerant production driver wraps this in
    repro.runtime.driver)."""
    losses = []
    t0 = time.time()
    stepped = jax.jit(train_step, donate_argnums=(0,))
    for i, batch in enumerate(batches):
        state, metrics = stepped(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if on_step is not None:
            on_step(i, state, metrics)
        if log_every and i % log_every == 0:
            print(f"step {i:5d}  loss {loss:.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}")
    return LoopResult(losses=losses, steps=len(losses),
                      wall_s=time.time() - t0), state
