import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Everything below is ordinary.

"""Multi-pod dry-run launcher.

For every (architecture x input-shape x mesh) cell: build the production
mesh, shard the step function with the advisor's ParallelConfig, then
``.lower().compile()`` against ShapeDtypeStructs — no real allocation — and
record memory analysis, cost analysis, and the parsed collective schedule
into ``artifacts/dryrun/<arch>__<shape>__<mesh>.json`` for the roofline
table (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --skip-existing
  python -m repro.launch.dryrun --arch ... --set decode_kv=heads remat=full
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, ALIASES, cells, get_config
from repro.launch import hlo_analysis as H
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.launch.train import make_train_step, train_state_specs
from repro.models.config import SHAPES, ParallelConfig
from repro.models.model import build_model
from repro.optim.adamw import AdamWConfig
from repro.parallel import sharding as Sh
from repro.parallel.ctx import activation_sharding

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def default_parallel(arch: str, shape_name: str,
                     overrides=None):
    """The advisor-chosen layout per cell, POST-hillclimb (EXPERIMENTS.md
    §Perf records the iteration path from the v0 baselines to these).
    Returns (ParallelConfig, model-config overrides)."""
    cfg0 = get_config(ALIASES.get(arch, arch))
    moe = cfg0.n_experts > 0
    kw = dict(fsdp_axes=("pod", "data"), tensor_axis="model",
              decode_kv="auto", remat="dots")
    cfgk = {}
    kind = SHAPES[shape_name].kind
    if kind == "train":
        # full remat in groups of 4 + gradient accumulation (fits HBM);
        # Megatron-style sequence parallelism for the non-recurrent,
        # non-MoE families (it reshards MoE dispatch/SSM convs badly)
        kw["remat"] = "full"
        kw["microbatch"] = 16 if moe else 8
        cfgk["remat_group"] = 2 if moe else 4
        if cfg0.family in ("dense", "vlm", "encdec"):
            kw["seq_tp"] = True
    if moe and kind in ("train", "prefill"):
        # group-local dispatch: one-hot dispatch FLOPs drop ~G-fold
        cfgk.update(moe_groups=64, capacity_factor=1.0)
    if shape_name == "long_500k":
        kw["seq_shard"] = True
    if overrides:
        kw.update(overrides)
    return ParallelConfig(**kw), cfgk


def model_flops_for(cfg, sc) -> float:
    """MODEL_FLOPS = 6 N D (train) / 2 N D (prefill) / 2 N B (decode),
    N = active params (MoE counts top-k only)."""
    n = cfg.active_param_count()
    if sc.kind == "train":
        return 6.0 * n * sc.global_batch * sc.seq_len
    if sc.kind == "prefill":
        return 2.0 * n * sc.global_batch * sc.seq_len
    return 2.0 * n * sc.global_batch


def model_min_bytes_for(cfg, sc, specs) -> float:
    """Compulsory per-step HBM stream: decode must read the active weights
    (bf16) and the whole KV/SSM cache once per token step."""
    if sc.kind != "decode":
        return 0.0
    total = 2.0 * cfg.active_param_count()
    for leaf in jax.tree_util.tree_leaves(specs.get("cache", {})):
        total += float(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
    return total


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               overrides=None):
    """Build + lower + compile one cell; returns the artifact dict."""
    cfg = get_config(arch)
    sc = SHAPES[shape_name]
    if sc.name == "long_500k" and not cfg.subquadratic:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped (full attention)"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    overrides = dict(overrides or {})
    cfg_over = {k: overrides.pop(k) for k in list(overrides)
                if k in ("moe_impl", "capacity_factor", "moe_groups",
                         "remat_group")}
    pc, cfg_defaults = default_parallel(arch, shape_name, overrides or None)
    cfg_defaults.update(cfg_over)
    cfg = dataclasses.replace(cfg, remat=pc.remat, **cfg_defaults)
    model = build_model(cfg)
    rules = Sh.make_rules(pc)
    specs = input_specs(arch, shape_name)

    t0 = time.time()
    with mesh, activation_sharding(mesh, pc):
        if sc.kind == "train":
            opt_cfg = AdamWConfig()
            step = make_train_step(model, opt_cfg,
                                   microbatches=pc.microbatch)
            state_sds = train_state_specs(model, opt_cfg)
            p_sh = Sh.param_shardings(state_sds["params"], cfg, mesh, rules)
            state_sh = {"params": p_sh,
                        "opt": {"mu": p_sh, "nu": p_sh,
                                "step": NamedSharding(mesh, P())}}
            b_spec = Sh.batch_spec(cfg, pc, mesh, sc.global_batch,
                                   sc.seq_len)
            b_sh = {k: NamedSharding(mesh, b_spec.get(k, P()))
                    for k in specs["batch"]}
            jitted = jax.jit(step, in_shardings=(state_sh, b_sh),
                             out_shardings=(state_sh, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_sds, specs["batch"])
        elif sc.kind == "prefill":
            p_sh = Sh.param_shardings(specs["params"], cfg, mesh, rules)
            b_spec = Sh.batch_spec(cfg, pc, mesh, sc.global_batch,
                                   sc.seq_len)
            b_sh = {k: NamedSharding(mesh, b_spec.get(k, P()))
                    for k in specs["batch"]}
            c_sh = Sh.like_tree(
                Sh.cache_spec(cfg, pc, mesh, sc.global_batch), mesh)
            jitted = jax.jit(model.prefill,
                             in_shardings=(p_sh, b_sh, c_sh),
                             out_shardings=(None, c_sh),
                             donate_argnums=(2,))
            lowered = jitted.lower(specs["params"], specs["batch"],
                                   specs["cache"])
        else:
            p_sh = Sh.param_shardings(specs["params"], cfg, mesh, rules)
            c_sh = Sh.like_tree(
                Sh.cache_spec(cfg, pc, mesh, sc.global_batch), mesh)
            t_sh = NamedSharding(mesh, P())
            jitted = jax.jit(model.decode_step,
                             in_shardings=(p_sh, t_sh, c_sh, t_sh),
                             out_shardings=(None, c_sh),
                             donate_argnums=(2,))
            lowered = jitted.lower(specs["params"], specs["tokens"],
                                   specs["cache"], specs["index"])
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    # loop-aware HLO walk (XLA's cost_analysis counts while bodies once)
    ma = H.ModuleAnalysis(compiled.as_text()).totals()
    flops, byts = ma["flops"], ma["bytes"]
    xla_flops, xla_bytes = H.cost_analysis_terms(compiled)
    mem = H.memory_stats(compiled)
    coll = {"wire_bytes": ma["wire_bytes"], "counts": ma["counts"],
            "total_wire_bytes": ma["total_wire_bytes"]}
    mf = model_flops_for(cfg, sc)
    mb = model_min_bytes_for(cfg, sc, specs)
    rl = H.roofline(flops, byts, coll["total_wire_bytes"], n_chips, mf, mb)
    print(compiled.memory_analysis())

    return {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok", "n_chips": int(n_chips),
        "parallel": dataclasses.asdict(pc),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops_per_device": flops, "bytes_per_device": byts,
        "xla_cost_analysis": {"flops": xla_flops, "bytes": xla_bytes},
        "memory": mem, "collectives": coll,
        "model_flops": mf, "roofline": rl.to_dict(),
    }


def cell_path(arch, shape, mesh_name, tag="") -> Path:
    sfx = f"__{tag}" if tag else ""
    return ARTIFACTS / f"{arch}__{shape}__{mesh_name}{sfx}.json"


def run_cell(arch, shape, mesh_name, skip_existing=False, overrides=None,
             tag=""):
    out = cell_path(arch, shape, mesh_name, tag)
    if skip_existing and out.exists():
        print(f"[skip] {out.name}")
        return json.loads(out.read_text())
    t0 = time.time()
    try:
        art = lower_cell(arch, shape, mesh_name == "multi", overrides)
    except Exception as e:  # a failure here is a bug in the system
        art = {"arch": arch, "shape": shape, "mesh": mesh_name,
               "status": f"FAILED: {type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-2000:]}
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(art, indent=1, default=float))
    st = art["status"]
    extra = ""
    if st == "ok":
        r = art["roofline"]
        extra = (f" frac={r['roofline_frac']:.3f} dom={r['bottleneck']}"
                 f" compile={art['compile_s']}s")
    print(f"[{time.strftime('%H:%M:%S')}] {arch} {shape} {mesh_name}: "
          f"{st}{extra} ({time.time()-t0:.0f}s)")
    return art


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--mesh", type=str, default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--tag", type=str, default="")
    ap.add_argument("--set", nargs="*", default=[],
                    help="ParallelConfig overrides k=v")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        if v in ("True", "False"):
            v = v == "True"
        elif v.isdigit():
            v = int(v)
        elif k == "fsdp_axes":
            v = tuple(x for x in v.split(",") if x)
        else:
            try:
                v = float(v)
            except ValueError:
                pass
        overrides[k] = v

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        # iterate the FULL 40-cell grid; lower_cell records explicit
        # "skipped (full attention)" artifacts for the excluded long_500k
        jobs = [(a, s, m) for a in ARCH_IDS for s in SHAPES
                for m in meshes]
    else:
        arch = ALIASES.get(args.arch, args.arch)
        shapes = [args.shape] if args.shape else cells(arch)
        jobs = [(arch, s, m) for s in shapes for m in meshes]

    ok = failed = 0
    for arch, shape, m in jobs:
        art = run_cell(arch, shape, m, args.skip_existing,
                       overrides or None, args.tag)
        if art["status"].startswith("FAILED"):
            failed += 1
        else:
            ok += 1
    print(f"done: {ok} ok, {failed} failed")
    raise SystemExit(1 if failed else 0)


if __name__ == "__main__":
    main()
