import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Must precede all other imports (jax locks device count on first init).

"""Pipeline-parallel dry-run: prove PP composes with DPxTP at 512 chips.

Mesh (stage=4, data=8, model=16) = 512 chips.  A qwen2-72b-class decoder
is split into 4 pipeline stages (20 layers each, stage-sharded weights);
microbatches stream through ``parallel.pipeline.pipeline_forward``
(shard_map + ppermute); the loss+grad of the full pipelined step is lowered
and compiled against ShapeDtypeStructs.  Artifact:
``artifacts/dryrun/pp_qwen2_72b__train_4k.json``.

    PYTHONPATH=src python -m repro.launch.dryrun_pp
"""

import dataclasses
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.launch import hlo_analysis as H
from repro.models import transformer as Tr
from repro.models.config import SHAPES
from repro.parallel.pipeline import pipeline_forward

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

STAGES, DATA, MODEL = 4, 8, 16
MICRO = 8


def main():
    cfg = get_config("qwen2_72b")
    cfg = dataclasses.replace(cfg, remat="full", remat_group=4)
    sc = SHAPES["train_4k"]
    L, d = cfg.n_layers, cfg.d_model
    per_stage = L // STAGES
    mb = sc.global_batch // MICRO

    devs = np.asarray(jax.devices()[: STAGES * DATA * MODEL]).reshape(
        STAGES, DATA, MODEL)
    mesh = Mesh(devs, ("stage", "data", "model"))

    def stage_fn(p_stage, x):
        pos = jnp.broadcast_to(jnp.arange(x.shape[1])[None], x.shape[:2])
        y, _, _ = Tr.stack_apply(p_stage, cfg, "dense", x, pos)
        return y

    # stage-stacked block params: (STAGES, per_stage, ...)
    blocks_sds = jax.eval_shape(
        lambda k: Tr.stack_init(k, cfg, per_stage, "dense"),
        jax.random.PRNGKey(0))
    params_sds = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((STAGES,) + l.shape, l.dtype),
        blocks_sds)
    # weight sharding: stage axis + the usual 2D (fsdp=data, tp=model)
    def spec_for(path, leaf):
        names = [getattr(k, "key", str(k)) for k in path]
        dims = [None] * (leaf.ndim - 2) + [None, None]
        if leaf.ndim >= 4:      # (S, per_stage, din, dout)
            name = names[-1] if names[-1] != "w" else names[-2]
            if name in ("wq", "wk", "wv", "wg", "wu"):
                dims[-2:] = ["data", "model"]
            elif name in ("wo", "wd"):
                dims[-2:] = ["model", "data"]
        return NamedSharding(mesh, P("stage", *dims[1:]))
    p_sh = jax.tree_util.tree_map_with_path(spec_for, params_sds)

    x_sds = jax.ShapeDtypeStruct((MICRO, mb, sc.seq_len, d), jnp.bfloat16)
    x_sh = NamedSharding(mesh, P(None, "data", None, None))

    def step(params, x):
        def loss(p):
            with mesh:
                y = pipeline_forward(stage_fn, mesh, "stage", p, x)
            return jnp.mean(y.astype(jnp.float32) ** 2)
        l, g = jax.value_and_grad(loss)(params)
        return l, g

    t0 = time.time()
    with mesh:
        lowered = jax.jit(step, in_shardings=(p_sh, x_sh),
                          out_shardings=(None, p_sh)).lower(
            params_sds, x_sds)
        compiled = lowered.compile()
    dt = time.time() - t0
    print(compiled.memory_analysis())

    ma = H.ModuleAnalysis(compiled.as_text()).totals()
    mem = H.memory_stats(compiled)
    art = {
        "name": "pp_qwen2_72b__train_4k",
        "mesh": f"stage{STAGES} x data{DATA} x model{MODEL} = 512",
        "status": "ok", "compile_s": round(dt, 1),
        "microbatches": MICRO,
        "bubble_frac": (STAGES - 1) / (MICRO + STAGES - 1),
        "flops_per_device": ma["flops"],
        "collective_permute_wire": ma["wire_bytes"]["collective-permute"],
        "memory": mem,
    }
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    (ARTIFACTS / "pp_qwen2_72b__train_4k.json").write_text(
        json.dumps(art, indent=1, default=float))
    print(f"PP dry-run ok: compile {dt:.0f}s, "
          f"bubble={(STAGES-1)/(MICRO+STAGES-1):.2f}, "
          f"ppermute wire={ma['wire_bytes']['collective-permute']/1e9:.1f}GB")


if __name__ == "__main__":
    main()
