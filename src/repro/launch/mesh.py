"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches jax device
state (device count is locked at first jax init; the dry-run sets
XLA_FLAGS before any import).
"""

from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 = 256 chips per pod; multi-pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devs)} — run under "
            "launch/dryrun.py (it forces 512 host devices)")
    try:
        return jax.make_mesh(shape, axes)
    except (ValueError, TypeError):
        arr = np.asarray(devs[:n]).reshape(shape)
        return Mesh(arr, axes)


def make_host_mesh(model_parallel: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests / examples on CPU)."""
    devs = jax.devices()
    mp = max(1, min(model_parallel, len(devs)))
    dp = len(devs) // mp
    arr = np.asarray(devs[: dp * mp]).reshape(dp, mp)
    return Mesh(arr, ("data", "model"))
