"""ShapeDtypeStruct stand-ins for every model input — weak-type-correct,
shardable, zero allocation.  ``input_specs(arch, shape)`` is the single
source of input shapes for the dry-run, the roofline analysis and the
benchmarks."""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.config import SHAPES, ModelConfig, ShapeConfig
from repro.models.model import build_model

N_PATCHES = 1024          # vision stub: patches spliced into the prefix


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def batch_specs(cfg: ModelConfig, B: int, S: int,
                with_labels: bool) -> Dict:
    out = {"tokens": sds((B, S), jnp.int32)}
    if with_labels:
        out["labels"] = sds((B, S), jnp.int32)
    if cfg.family == "encdec":
        out["audio_embeds"] = sds((B, cfg.enc_positions, cfg.d_model),
                                  jnp.float32)
    if cfg.family == "vlm":
        out["patch_embeds"] = sds((B, min(N_PATCHES, S), cfg.d_model),
                                  jnp.float32)
        out["positions"] = sds((B, S, 3), jnp.int32)
    return out


def params_specs(cfg: ModelConfig, serve: bool = False):
    model = build_model(cfg)
    sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    if serve:
        # serving checkpoints are bf16 (matrices); norms/biases stay f32
        sds = jax.tree.map(
            lambda l: (jax.ShapeDtypeStruct(l.shape, jnp.bfloat16)
                       if l.ndim >= 2 and l.dtype == jnp.float32 else l),
            sds)
    return sds


def cache_specs(cfg: ModelConfig, B: int, max_seq: int):
    model = build_model(cfg)
    return jax.eval_shape(lambda: model.init_cache(B, max_seq))


def input_specs(arch: str, shape_name: str) -> Dict:
    """Everything the lowered step function needs, as ShapeDtypeStructs.

    kind='train':   {params(+opt state via train.py), batch}
    kind='prefill': {params, batch, cache}
    kind='decode':  {params, tokens(B,1), cache(filled to seq_len), index}
    """
    cfg = get_config(arch)
    sc: ShapeConfig = SHAPES[shape_name]
    B, S = sc.global_batch, sc.seq_len
    out: Dict = {"cfg": cfg, "shape": sc,
                 "params": params_specs(cfg, serve=(sc.kind != "train"))}
    if sc.kind == "train":
        out["batch"] = batch_specs(cfg, B, S, with_labels=True)
    elif sc.kind == "prefill":
        out["batch"] = batch_specs(cfg, B, S, with_labels=False)
        out["cache"] = cache_specs(cfg, B, S + cfg.meta_tokens)
    else:  # decode: one new token against a cache of seq_len
        out["tokens"] = sds((B, 1), jnp.int32)
        out["cache"] = cache_specs(cfg, B, S + cfg.meta_tokens)
        out["index"] = sds((), jnp.int32)
    return out
