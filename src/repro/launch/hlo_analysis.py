"""Parse collective traffic + roofline terms out of a compiled module.

``collective_bytes`` walks the optimized (post-SPMD) HLO text and prices
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute by its printed result shape (per-device), converted to
*wire bytes per device* with the standard ring-algorithm factors:

    all-reduce        2 * size * (n-1)/n      (reduce-scatter + all-gather)
    all-gather        out  * (n-1)/n
    reduce-scatter    in   * (n-1)/n  (printed result is the scatter output
                                       -> in = out * n)
    all-to-all        size * (n-1)/n
    collective-permute size

n = replica-group size parsed from the op's replica_groups attribute.

``roofline`` combines those with cost_analysis FLOPs/bytes and the TPU
target constants into the three-term model of EXPERIMENTS.md §Roofline.
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.constants import DEFAULT_TPU, TPUTarget

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}


# ---------------------------------------------------------------------------
# loop-aware module analysis
#
# XLA's compiled.cost_analysis() counts a `while` body ONCE, ignoring the
# trip count — fatal for scan-over-layers models (an 80-layer step would be
# undercounted 80x).  This analyzer parses the optimized HLO, builds the
# computation call graph (fusion/call/while/conditional), extracts static
# while trip counts from the loop-condition constants, and accumulates
# dot FLOPs / fusion I/O bytes / collective wire bytes weighted by the
# product of enclosing trip counts.
# ---------------------------------------------------------------------------
_COMP_HEAD = re.compile(r"^(ENTRY\s+)?%?([\w\.\-_]+)\s*\(")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-_]+)\s*=\s*(.*)$")
_CALLSITE = re.compile(
    r"(?:calls=|to_apply=|body=)%?([\w\.\-_]+)")
_COND = re.compile(r"condition=%?([\w\.\-_]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_DOT_LHS_NAME = re.compile(
    r"\bdot\(\s*(?:\w+\[[\d,]*\](?:\{[^}]*\})?\s+)?%([\w\.\-_]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_OPERANDS = re.compile(r"%([\w\.\-_]+)")
# ops whose HBM I/O we price for the memory roofline term.  Pure-elementwise
# fusions are skipped: the CPU backend fragments elementwise chains into many
# small fusions that a TPU compilation folds into their producers — counting
# them would overstate HBM traffic ~50x (measured on qwen2-72b train).
_HEAVY_KINDS = (" dot(", " gather(", " scatter(",
                " dynamic-slice(", " dynamic-update-slice(",
                " all-reduce(", " all-gather(", " reduce-scatter(",
                " all-to-all(", " collective-permute(")
_FUSION = re.compile(r"\bfusion\(")


def _dims(s: str):
    return [int(d) for d in s.split(",") if d] if s else []


class ModuleAnalysis:
    def __init__(self, hlo_text: str):
        self.comps: Dict[str, List[str]] = {}
        self.entry: Optional[str] = None
        cur = None
        for line in hlo_text.splitlines():
            m = _COMP_HEAD.match(line.strip())
            if (m and line.rstrip().endswith("{") and "->" in line
                    and not line.startswith(" ")):
                cur = m.group(2)
                self.comps[cur] = []
                if m.group(1):
                    self.entry = cur
                continue
            if cur is not None:
                if line.strip() == "}":
                    cur = None
                else:
                    self.comps[cur].append(line)
        # per-computation symbol table: instruction name -> output bytes /
        # dims (operand shapes are not printed inline in optimized HLO)
        self.symtab: Dict[str, Dict[str, Tuple[str, List[int]]]] = {}
        for name, lines in self.comps.items():
            tab = {}
            for line in lines:
                im = _INSTR.match(line)
                if not im:
                    continue
                sh = _SHAPE_RE.search(im.group(2))
                if sh:
                    tab[im.group(1)] = (sh.group(1), _dims(sh.group(2)))
            self.symtab[name] = tab
        # computations containing heavy ops (for fusion I/O pricing)
        self._heavy: Dict[str, bool] = {}
        for name, lines in self.comps.items():
            self._heavy[name] = any(
                any(k in ln for k in _HEAVY_KINDS) for ln in lines)
        self._mult: Dict[str, float] = {}
        self._analyze()

    def _sym_bytes(self, comp: str, ref: str) -> float:
        ent = self.symtab.get(comp, {}).get(ref)
        if not ent:
            return 0.0
        dt, dims = ent
        if dt not in _DTYPE_BYTES:
            return 0.0
        n = 1
        for d in dims:
            n *= d
        return float(n * _DTYPE_BYTES[dt])

    # ---- per-computation raw costs -------------------------------------
    def _line_flops(self, comp: str, body: str) -> float:
        if " dot(" not in body and not body.startswith("dot("):
            return 0.0
        out = _SHAPE_RE.search(body)
        lhs = _DOT_LHS_NAME.search(body)
        con = _CONTRACT.search(body)
        if not (out and con):
            return 0.0
        out_n = float(np.prod(_dims(out.group(2)) or [1]))
        lhs_dims = []
        if lhs:
            ent = self.symtab.get(comp, {}).get(lhs.group(1))
            if ent:
                lhs_dims = ent[1]
        kn = 1.0
        for ci in _dims(con.group(1)):
            if ci < len(lhs_dims):
                kn *= lhs_dims[ci]
        return 2.0 * out_n * kn

    def _line_bytes(self, comp: str, body: str) -> float:
        # in-place / sparse-access ops: traffic = the moved slice, not the
        # full buffer (XLA aliases DUS in place)
        if " dynamic-update-slice(" in body:
            ops = self._operand_refs(comp, body)
            return 2.0 * (ops[1] if len(ops) > 1 else 0.0)
        if " dynamic-slice(" in body or " gather(" in body:
            out = _shape_bytes(body.split("),")[0] + ")")
            return 2.0 * float(out)
        if _FUSION.search(body):
            # price a fusion by its callee's internal heavy ops: a fusion
            # whose only heavy op is a small DUS must not be charged its
            # big aliased stack operands
            cs = _CALLSITE.search(body)
            if not cs or not self._heavy.get(cs.group(1)):
                return 0.0
            callee = cs.group(1)
            return sum(self._line_bytes(callee, i.group(2))
                       for i in map(_INSTR.match, self.comps[callee]) if i)
        if not any(k in body for k in _HEAVY_KINDS):
            return 0.0
        total = float(_shape_bytes(body.split("),")[0] + ")"))
        total += sum(self._operand_refs(comp, body))
        return total

    def _operand_refs(self, comp: str, body: str):
        """Byte sizes of the operands in the first parens group."""
        lp = body.find("(")
        if lp < 0:
            return []
        depth = 0
        rp = lp
        for i, ch in enumerate(body[lp:], lp):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    rp = i
                    break
        return [self._sym_bytes(comp, ref)
                for ref in _OPERANDS.findall(body[lp:rp + 1])]

    def _line_collective(self, body: str):
        m = _COLL_RE.search("= " + body) or _COLL_RE.search(body)
        if not m:
            return None
        kind = m.group(2)
        size = _shape_bytes(m.group(1))
        n = 1
        g = _GROUPS_RE.search(body)
        if g:
            n = len([x for x in g.group(1).split(",") if x.strip() != ""])
        else:
            gi = _GROUPS_IOTA_RE.search(body)
            if gi:
                n = int(gi.group(2))
        n = max(n, 1)
        f = (n - 1) / n
        wire = {"all-reduce": 2.0 * size * f, "all-gather": size * f,
                "reduce-scatter": size * n * f, "all-to-all": size * f,
                "collective-permute": float(size)}[kind]
        return kind, wire

    def _trip_count(self, cond_comp: str) -> float:
        consts = []
        for line in self.comps.get(cond_comp, []):
            for c in _CONST_INT.findall(line):
                consts.append(int(c))
        return float(max(consts)) if consts else 1.0

    # ---- multiplicity propagation ----------------------------------------
    def _analyze(self):
        entry = self.entry or (next(iter(self.comps)) if self.comps else None)
        mult: Dict[str, float] = {}

        def visit(name: str, m: float):
            mult[name] = mult.get(name, 0.0) + m
            for line in self.comps.get(name, []):
                im = _INSTR.match(line)
                if not im:
                    continue
                body = im.group(2)
                trip = 1.0
                if " while(" in body or body.startswith("while("):
                    c = _COND.search(body)
                    if c:
                        trip = self._trip_count(c.group(1))
                br = _BRANCHES.search(body)
                callees = list(_CALLSITE.findall(body))
                if br:
                    callees += [x.strip().lstrip("%")
                                for x in br.group(1).split(",")]
                seen = set()
                for cal in callees:
                    if cal in seen or cal not in self.comps:
                        continue
                    seen.add(cal)
                    visit(cal, m * trip)

        if entry:
            visit(entry, 1.0)
        self._mult = mult

    # ---- public totals ------------------------------------------------------
    def totals(self) -> Dict:
        flops = byts = 0.0
        wire = {"all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
                "all-to-all": 0.0, "collective-permute": 0.0}
        counts = dict.fromkeys(wire, 0)
        for name, m in self._mult.items():
            for line in self.comps.get(name, []):
                im = _INSTR.match(line)
                if not im:
                    continue
                body = im.group(2)
                flops += m * self._line_flops(name, body)
                byts += m * self._line_bytes(name, body)
                col = self._line_collective(body)
                if col:
                    wire[col[0]] += m * col[1]
                    counts[col[0]] += int(m)
        return {"flops": flops, "bytes": byts, "wire_bytes": wire,
                "counts": counts,
                "total_wire_bytes": float(sum(wire.values()))}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", )
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict:
    """Per-device wire bytes by collective kind + op counts."""
    out = {"all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    counts = dict.fromkeys(out, 0)
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_txt, kind = m.group(1), m.group(2)
        size = _shape_bytes(shape_txt)
        n = 1
        g = _GROUPS_RE.search(line)
        if g:
            n = len([x for x in g.group(1).split(",") if x.strip() != ""])
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            if gi:
                n = int(gi.group(2))
        n = max(n, 1)
        f = (n - 1) / n
        if kind == "all-reduce":
            wire = 2.0 * size * f
        elif kind == "all-gather":
            wire = size * f
        elif kind == "reduce-scatter":
            wire = size * n * f
        elif kind == "all-to-all":
            wire = size * f
        else:
            wire = float(size)
        out[kind] += wire
        counts[kind] += 1
    return {"wire_bytes": out, "counts": counts,
            "total_wire_bytes": float(sum(out.values()))}


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    model_flops: float
    hlo_total_flops: float
    useful_ratio: float
    bottleneck: str
    step_time_s: float
    roofline_frac: float

    def to_dict(self):
        return dataclasses.asdict(self)


def roofline(flops_per_device: float, bytes_per_device: float,
             wire_bytes_per_device: float, n_chips: int,
             model_flops: float, model_min_bytes: float = 0.0,
             tpu: TPUTarget = DEFAULT_TPU) -> Roofline:
    """Three-term roofline (EXPERIMENTS.md §Roofline).

    compute_s    = HLO_FLOPs / peak;  memory_s = HLO bytes / HBM bw;
    collective_s = wire bytes / (links * link bw).  All per chip.

    roofline_frac = ideal_time / max(all three), the score we hillclimb.
    ideal_time is the better of the two hardware floors: useful model FLOPs
    at peak, or the compulsory bytes (weights + caches that MUST stream
    once per step — dominant for decode) at full HBM bandwidth."""
    compute_s = flops_per_device / (tpu.peak_bf16_tflops * 1e12)
    memory_s = bytes_per_device / (tpu.hbm_gbps * 1e9)
    collective_s = wire_bytes_per_device / (
        tpu.ici_links_per_chip * tpu.ici_link_gbps * 1e9)
    hlo_total = flops_per_device * n_chips
    useful = model_flops / max(hlo_total, 1.0)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    step = max(compute_s, memory_s, collective_s)
    ideal = max((model_flops / n_chips) / (tpu.peak_bf16_tflops * 1e12),
                (model_min_bytes / n_chips) / (tpu.hbm_gbps * 1e9))
    return Roofline(
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        flops_per_device=flops_per_device, bytes_per_device=bytes_per_device,
        wire_bytes_per_device=wire_bytes_per_device,
        model_flops=model_flops, hlo_total_flops=hlo_total,
        useful_ratio=useful, bottleneck=bottleneck, step_time_s=step,
        roofline_frac=ideal / max(step, 1e-30))


def cost_analysis_terms(compiled) -> Tuple[float, float]:
    """(flops, bytes accessed) per device from compiled.cost_analysis()."""
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return 0.0, 0.0
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    if byts == 0.0:
        byts = sum(float(v) for k, v in ca.items()
                   if k.startswith("bytes accessed"))
    return flops, byts


def memory_stats(compiled) -> Dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for k in ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "temp_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if out:
        out["total_hbm_bytes"] = (out.get("argument_size_in_bytes", 0)
                                  + out.get("output_size_in_bytes", 0)
                                  + out.get("temp_size_in_bytes", 0)
                                  - out.get("alias_size_in_bytes", 0))
    return out
