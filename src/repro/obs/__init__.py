"""``repro.obs`` — the flight recorder: structured tracing, a process
metrics registry, and crash-safe JSONL run journals.

Dependency-free (stdlib + numpy at the serialization edge) and threaded
through the whole search stack (``repro.explore`` service / api /
archive).  Three layers:

* ``trace``   — nested wall-clock spans (``span("refine", problem=ck)``),
  the enable/disable switch (a shared no-op singleton when disabled:
  results are bit-identical with observability on or off), and the
  record-sink fan-out journals attach to.
* ``metrics`` — process-wide registry of counters / gauges / bounded
  reservoir histograms with exact p50/p90/p99 (``REGISTRY.snapshot()``).
* ``journal`` — append-only JSONL run journals with atomic line writes,
  keyed by ``Problem.key()``-derived cache keys; one record per span
  close, scan segment, plan, and result.  Enable per session
  (``Session(journal=...)``) or fleet-wide via ``$REPRO_JOURNAL_DIR``.
* ``report``  — the CLI renderer: ``python -m repro.obs.report
  <journal>`` prints plan-vs-actual tables and a fleet summary (hit
  rate, evals/sec, p50/p99 time-to-front).
"""

from .journal import (JOURNAL_ENV, Journal, default_journal,  # noqa: F401
                      read_journal, replay, resolve_journal)
from .metrics import (REGISTRY, Counter, Gauge, Histogram,  # noqa: F401
                      MetricsRegistry)
from .trace import (NOOP_SPAN, Span, active, add_sink,  # noqa: F401
                    current_run, disable, emit, enable, enabled, gauge,
                    inc, observe, remove_sink, run_context, sink_attached,
                    span)

__all__ = [
    "JOURNAL_ENV", "Journal", "NOOP_SPAN", "Counter", "Gauge", "Histogram",
    "MetricsRegistry", "REGISTRY", "Span", "active", "add_sink",
    "current_run", "default_journal", "disable", "emit", "enable",
    "enabled", "gauge", "inc", "observe", "read_journal", "remove_sink",
    "replay", "resolve_journal", "run_context", "sink_attached", "span",
]
