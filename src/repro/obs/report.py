"""Journal reporting CLI: plan-vs-actual tables and a fleet summary.

    python -m repro.obs.report <journal.jsonl | journal-dir> [key-prefix]

For every ``plan`` record in the journal, renders the predicted
``SegmentPlan`` schedule against what the run actually did — per-segment
wall-clock (first-call/compile segments flagged), evaluations, and the
archive-projected hypervolume trajectory.  Planned segments with no
observation render as ``-`` (the plateau detector stopped the run
early); reallocation top-ups appear under their own phase.  A fleet
summary follows: query count, cache hit rate, evaluations/second, and
exact p50/p90/p99 time-to-front over the journaled results.

``render(records)`` returns the report as a string (what ``bench_obs``
gates on); ``main`` prints it.
"""

from __future__ import annotations

import sys
from typing import Dict, List, Optional, Sequence

from .journal import read_journal


def _fmt(v, width: int = 10, prec: int = 4) -> str:
    if v is None:
        return "-".rjust(width)
    if isinstance(v, float):
        return f"{v:.{prec}g}".rjust(width)
    return str(v).rjust(width)


def _quantile(sorted_vals: List[float], q: float) -> Optional[float]:
    if not sorted_vals:
        return None
    idx = min(int(q * len(sorted_vals)), len(sorted_vals) - 1)
    return sorted_vals[max(idx, 0)]


def _blocks(records: Sequence[Dict], key_prefix: str = ""):
    """Walk the record stream in order, pairing each ``plan`` record with
    the ``refine``-phase segments that executed it (the segments of that
    key until its next plan); ``realloc`` segments attach to the key's
    most recent block.  Returns (blocks, results, last metrics snapshot)."""
    blocks: List[Dict] = []
    current: Dict[str, Dict] = {}       # key -> its open block
    results: List[Dict] = []
    metrics: Optional[Dict] = None
    for rec in records:
        typ = rec.get("type")
        key = rec.get("key", "")
        if key_prefix and isinstance(key, str) \
                and not key.startswith(key_prefix) and typ != "metrics":
            continue
        if typ == "plan":
            blk = dict(plan=rec, refine=[], realloc=[])
            blocks.append(blk)
            current[key] = blk
        elif typ == "segment":
            blk = current.get(key)
            if blk is None:             # segments with no plan record
                blk = dict(plan=None, key=key, refine=[], realloc=[])
                blocks.append(blk)
                current[key] = blk
            phase = rec.get("phase", "refine")
            blk["realloc" if phase == "realloc" else "refine"].append(rec)
        elif typ == "result":
            results.append(rec)
        elif typ == "metrics":
            metrics = rec.get("snapshot", rec)
    return blocks, results, metrics


def _render_block(blk: Dict, out: List[str]) -> None:
    plan = blk.get("plan")
    key = (plan or blk).get("key", "?")
    head = f"problem {key}"
    if plan is not None:
        head += (f"  engine={plan.get('engine')} "
                 f"budget={plan.get('budget')} "
                 f"cache_hit={plan.get('cache_hit')}")
    out.append(head)
    planned = list((plan or {}).get("segments") or [])
    observed = {int(s.get("segment", -1)): s for s in blk["refine"]}
    if plan is not None and plan.get("cache_hit") and not planned:
        out.append("  (warm serve: no segments planned, none run)")
    if planned or observed:
        out.append("  phase    seg  pop  gens  plan_evals    actual_s"
                   "  compile          hv  front")
        idx = sorted(set(range(len(planned))) | set(observed))
        for i in idx:
            p = planned[i] if i < len(planned) else None
            o = observed.get(i)
            hv = (o or {}).get("hv") or []
            out.append(
                "  refine " + _fmt(i, 5)
                + _fmt(p and p.get("pop"), 5)
                + _fmt(p and p.get("generations"), 6)
                + _fmt(p and p.get("n_evals"), 12)
                + _fmt(o and float(o.get("elapsed_s", 0.0)), 12)
                + _fmt("*" if (o or {}).get("compile") else "", 9)
                + _fmt(float(hv[0]) if hv else None, 12)
                + _fmt(o and o.get("front_size"), 7))
        for s in blk["realloc"]:
            hv = s.get("hv") or []
            out.append(
                "  realloc" + _fmt(int(s.get("segment", -1)), 5)
                + _fmt(None, 5) + _fmt(None, 6) + _fmt(None, 12)
                + _fmt(float(s.get("elapsed_s", 0.0)), 12)
                + _fmt("*" if s.get("compile") else "", 9)
                + _fmt(float(hv[0]) if hv else None, 12)
                + _fmt(s.get("front_size"), 7))
    if plan is not None and plan.get("neighbors"):
        for n in plan["neighbors"]:
            out.append(f"  seed<- {n.get('key')}  "
                       f"dist={n.get('distance'):.4g} "
                       f"quota={n.get('quota')}")
    out.append("")


def render(records: Sequence[Dict], key_prefix: str = "") -> str:
    """The full report over an in-memory record list."""
    records = list(records)
    blocks, results, metrics = _blocks(records, key_prefix)
    out: List[str] = ["== plan vs actual =="]
    if not blocks:
        out.append("(no planned or executed runs in journal)")
        out.append("")
    for blk in blocks:
        _render_block(blk, out)

    out.append("== fleet summary ==")
    n = len(results)
    hits = sum(1 for r in results if r.get("from_cache"))
    evals = sum(int(r.get("n_evals", 0)) for r in records
                if r.get("type") == "segment")
    seg_s = sum(float(r.get("elapsed_s", 0.0)) for r in records
                if r.get("type") == "segment")
    ttf = sorted(float(r.get("elapsed_s", 0.0)) for r in results)
    out.append(f"queries={n}  cache_hits={hits}"
               + (f" (hit rate {hits / n:.2f})" if n else ""))
    out.append(f"evals={evals}  segment_s={seg_s:.3f}"
               + (f"  evals/sec={evals / seg_s:.1f}" if seg_s > 0 else ""))
    out.append("time-to-front"
               + f"  p50={_fmt(_quantile(ttf, 0.50), 0)}s"
               + f"  p90={_fmt(_quantile(ttf, 0.90), 0)}s"
               + f"  p99={_fmt(_quantile(ttf, 0.99), 0)}s")
    if metrics:
        interesting = ("obs.on_segment_errors", "obs.sink_errors",
                       "explore.cache.hit", "explore.cache.miss",
                       "explore.plateau_stops",
                       "explore.manifest.reloads",
                       "explore.manifest.evictions",
                       "explore.transfer.seeds_injected",
                       "explore.transfer.seeds_deduped")
        parts = [f"{k.split('.', 1)[1]}={metrics[k]['value']}"
                 for k in interesting if k in metrics]
        if parts:
            out.append("counters: " + "  ".join(parts))
    return "\n".join(out) + "\n"


def main(argv: Optional[Sequence[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv or argv[0] in ("-h", "--help"):
        print(__doc__)
        return 0 if argv else 2
    key_prefix = argv[1] if len(argv) > 1 else ""
    print(render(list(read_journal(argv[0])), key_prefix), end="")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
