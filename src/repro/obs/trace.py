"""Tracing core: nested wall-clock spans, the enable/disable switch, and
the record-sink fan-out that feeds run journals.

The contract the instrumented search stack relies on:

* ``span("refine", problem=ck)`` is a context manager measuring
  monotonic wall-clock; spans nest (a thread-local stack tracks depth
  and parent), and every close feeds a ``span.<name>`` histogram in the
  process-wide metrics registry plus — when a journal is attached — one
  ``span`` record.
* **Zero cost when disabled**: ``disable()`` flips one module-level
  flag; ``span(...)`` then returns a shared no-op singleton and
  ``inc``/``observe``/``emit`` return immediately.  Instrumentation
  never touches PRNG keys or numeric state, so results are bit-identical
  with observability on or off — disabling only removes the clock reads.
* ``emit(record)`` fans a dict record out to the attached sinks (the
  crash-safe JSONL journals of ``repro.obs.journal``); ``add_sink`` /
  ``remove_sink`` / the ``sink_attached`` context manager manage the
  active set.  ``active()`` is the cheap "is anyone listening" check
  call sites use before assembling a record.
"""

from __future__ import annotations

import contextlib
import threading
import time
from typing import Callable, Dict, List, Optional

from .metrics import REGISTRY

_ENABLED = True
_SINKS: List[Callable[[Dict], None]] = []
_SINK_LOCK = threading.Lock()
# sink -> live sink_attached count.  Keyed by the sink itself (not id):
# bound methods compare and hash by (self, func), so two accesses of the
# same `journal.write` count as one attachment, matching add_sink's
# equality check.
_SINK_REFS: Dict[Callable[[Dict], None], int] = {}
_TLS = threading.local()


def enable() -> None:
    """Turn instrumentation on (the default)."""
    global _ENABLED
    _ENABLED = True


def disable() -> None:
    """Turn instrumentation off: spans become a shared no-op, metric and
    record emission return immediately."""
    global _ENABLED
    _ENABLED = False


def enabled() -> bool:
    return _ENABLED


def active() -> bool:
    """True when a record sink (journal) is attached AND instrumentation
    is enabled — the guard for any work done only to build records."""
    return _ENABLED and bool(_SINKS)


# ---------------------------------------------------------------------------
# record sinks (journals attach here)
# ---------------------------------------------------------------------------
def add_sink(sink: Callable[[Dict], None]) -> None:
    if sink not in _SINKS:
        _SINKS.append(sink)


def remove_sink(sink: Callable[[Dict], None]) -> None:
    try:
        _SINKS.remove(sink)
    except ValueError:
        pass


@contextlib.contextmanager
def sink_attached(sink: Optional[Callable[[Dict], None]]):
    """Attach one sink for the duration of a ``with`` block (``None`` is
    a no-op — callers pass their maybe-configured journal straight in).
    Attachment is REFERENCE-COUNTED per sink, so the block is safe to
    nest AND to overlap across threads: two concurrent submissions
    sharing one fleet journal (``$REPRO_JOURNAL_DIR``) each hold a
    reference, and the journal detaches only when the last one exits —
    the first submission finishing must not silence the one still
    running."""
    if sink is None:
        yield
        return
    with _SINK_LOCK:
        _SINK_REFS[sink] = _SINK_REFS.get(sink, 0) + 1
        add_sink(sink)
    try:
        yield
    finally:
        with _SINK_LOCK:
            n = _SINK_REFS.get(sink, 1) - 1
            if n <= 0:
                _SINK_REFS.pop(sink, None)
                remove_sink(sink)
            else:
                _SINK_REFS[sink] = n


# ---------------------------------------------------------------------------
# run identity: which submission a record belongs to
# ---------------------------------------------------------------------------
def current_run() -> Optional[str]:
    """The run id records emitted by THIS thread are stamped with, or
    ``None`` outside any ``run_context``."""
    return getattr(_TLS, "run", None)


@contextlib.contextmanager
def run_context(run_id: Optional[str]):
    """Stamp every record this thread emits with ``run=run_id`` for the
    duration of the block (``None`` is a no-op).  Thread-local, so
    overlapping submissions sharing one fleet journal each stamp their
    own records — ``replay()`` partitions on the stamp instead of
    guessing from record order.  Nests: the innermost context wins
    (records of a sub-operation belong to the run that issued it)."""
    if run_id is None:
        yield
        return
    prev = getattr(_TLS, "run", None)
    _TLS.run = str(run_id)
    try:
        yield
    finally:
        _TLS.run = prev


def emit(record: Dict) -> None:
    """Fan one record out to every attached sink, stamped with the
    thread's current run id (see ``run_context``) when one is set and
    the record doesn't carry its own.  A sink failure is contained
    (observability must never fail the work it observes): the sink is
    dropped for the rest of the run and an ``obs.sink_errors`` counter
    records the loss."""
    if not _ENABLED or not _SINKS:
        return
    run = getattr(_TLS, "run", None)
    if run is not None and "run" not in record:
        record = dict(record, run=run)
    for sink in list(_SINKS):
        try:
            sink(record)
        except Exception:
            remove_sink(sink)
            REGISTRY.counter("obs.sink_errors").inc()


# ---------------------------------------------------------------------------
# metric conveniences (gated on the enable flag)
# ---------------------------------------------------------------------------
def inc(name: str, n: int = 1) -> None:
    if _ENABLED:
        REGISTRY.counter(name).inc(n)


def observe(name: str, v: float) -> None:
    if _ENABLED:
        REGISTRY.histogram(name).observe(v)


def gauge(name: str, v: float) -> None:
    if _ENABLED:
        REGISTRY.gauge(name).set(v)


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------
def _stack() -> List[str]:
    s = getattr(_TLS, "stack", None)
    if s is None:
        s = _TLS.stack = []
    return s


class Span:
    """One live span: monotonic start on ``__enter__``; on ``__exit__``
    the duration lands in the ``span.<name>`` histogram and (when a
    journal is attached) one ``span`` record with the span's attrs,
    depth, and parent span name.  ``set(**attrs)`` adds attributes to a
    live span (e.g. an outcome computed mid-block)."""

    __slots__ = ("name", "attrs", "t0", "elapsed_s")

    def __init__(self, name: str, attrs: Dict):
        self.name = name
        self.attrs = attrs
        self.t0 = 0.0
        self.elapsed_s = 0.0

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        _stack().append(self.name)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        self.elapsed_s = time.perf_counter() - self.t0
        stack = _stack()
        stack.pop()
        REGISTRY.histogram(f"span.{self.name}").observe(self.elapsed_s)
        if _SINKS:
            rec = dict(type="span", name=self.name,
                       elapsed_s=self.elapsed_s, depth=len(stack),
                       parent=stack[-1] if stack else None)
            if exc_type is not None:
                rec["error"] = exc_type.__name__
            if self.attrs:
                rec["attrs"] = self.attrs
            emit(rec)
        return False


class _NoopSpan:
    """The disabled-mode singleton: every method is a constant-time
    no-op, so an instrumented hot path costs one flag check."""

    __slots__ = ()

    def set(self, **attrs) -> "_NoopSpan":
        return self

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


NOOP_SPAN = _NoopSpan()


def span(name: str, **attrs):
    """Open a nested wall-clock span (context manager).  Returns the
    shared no-op singleton when instrumentation is disabled."""
    if not _ENABLED:
        return NOOP_SPAN
    return Span(name, attrs)


__all__ = ["NOOP_SPAN", "Span", "active", "add_sink", "current_run",
           "disable", "emit", "enable", "enabled", "gauge", "inc",
           "observe", "remove_sink", "run_context", "sink_attached",
           "span"]
