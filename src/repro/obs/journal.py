"""Crash-safe JSONL run journals: the persistent half of the flight
recorder.

A ``Journal`` is an append-only ``.jsonl`` file of one JSON record per
line.  Writes are *atomic at line granularity*: each record is a single
``os.write`` to an ``O_APPEND`` descriptor, so concurrent writers (the
benchmark suite runs service queries on background threads) interleave
whole lines and a crash mid-run leaves at worst one truncated final
line — which ``read_journal`` tolerates and skips.  The journal is
opened lazily on the first record, so configuring one costs nothing
until something is actually observed.

Record vocabulary (all records carry ``t`` wall-clock seconds, and the
run-scoped ones carry ``key`` — the ``Problem.key()``-derived archive
cache key):

* ``plan``    — what ``Session.submit`` is about to do for one query:
  engine, budget, cache verdict, the quantized ``SegmentPlan`` schedule
  and predicted transfer neighbors.
* ``segment`` — one closed scan segment: phase (``refine``/``realloc``),
  per-phase segment index, stream-monotone ``seq``, wall-clock
  ``elapsed_s``, evaluations, archive-projected hypervolume row, and
  ``compile`` marking a first-call (lowering-inclusive) execution.
* ``result``  — one finished query: provenance accounting + final
  hypervolume / front size + ``elapsed_s`` (time-to-front).
* ``span`` / ``metrics`` / ``callback_error`` — tracing spans, registry
  snapshots, and dropped ``on_segment`` deliveries.

``replay`` folds a record stream back into per-key run summaries — the
completeness check ``benchmarks.bench_obs`` gates on (journal segment
count and final hypervolume must match the in-memory ``Result``).

Enable journaling per session (``Session(journal=...)``) or fleet-wide
via ``$REPRO_JOURNAL_DIR`` — ``default_journal()`` lazily creates one
process-wide journal file inside that directory.
"""

from __future__ import annotations

import json
import os
import threading
import time
import warnings
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Sequence, Union

JOURNAL_ENV = "REPRO_JOURNAL_DIR"


def _json_default(o):
    """Serialize the numpy scalars/arrays that ride in trace records."""
    import numpy as np
    if isinstance(o, np.ndarray):
        return o.tolist()
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, (np.bool_,)):
        return bool(o)
    if isinstance(o, (set, tuple)):
        return list(o)
    return str(o)


class Journal:
    """Append-only JSONL journal with atomic line writes.

    ``write(record)`` stamps ``t`` (wall clock) and appends one line;
    the file descriptor is opened ``O_APPEND`` on first use and every
    record is one ``write(2)`` call, so lines are never interleaved or
    half-flushed through Python buffering.  ``fsync=True`` additionally
    syncs every line — crash-safe against power loss, at a per-record
    cost (the default relies on the kernel page cache, which survives
    process crashes, the case the run journal is for)."""

    def __init__(self, path, fsync: bool = False):
        self.path = Path(path)
        self.fsync = bool(fsync)
        self._fd: Optional[int] = None
        self._lock = threading.Lock()

    def _ensure_open(self) -> int:
        if self._fd is None:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            self._fd = os.open(
                str(self.path),
                os.O_APPEND | os.O_CREAT | os.O_WRONLY, 0o644)
        return self._fd

    def write(self, record: Dict) -> None:
        rec = dict(record)
        rec.setdefault("t", time.time())
        line = json.dumps(rec, default=_json_default,
                          separators=(",", ":")) + "\n"
        data = line.encode()
        with self._lock:
            fd = self._ensure_open()
            os.write(fd, data)
            if self.fsync:
                os.fsync(fd)

    # journals ARE record sinks: ``obs.trace.emit`` calls each attached
    # sink as ``sink(record)``
    def __call__(self, record: Dict) -> None:
        self.write(record)

    def close(self) -> None:
        with self._lock:
            if self._fd is not None:
                os.close(self._fd)
                self._fd = None

    def __enter__(self) -> "Journal":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    def records(self) -> List[Dict]:
        return list(read_journal(self.path))


# ---------------------------------------------------------------------------
# reading + replay
# ---------------------------------------------------------------------------
def read_journal(path) -> Iterator[Dict]:
    """Yield the records of one journal file (or every ``*.jsonl`` under
    a directory, in name order).  Unparseable MID-FILE lines — foreign
    garbage, a corrupted record — are skipped with one summary warning,
    never fatal: a journal must be readable after any crash.  A partial
    FINAL line that the file does not terminate with a newline is
    skipped silently: that is the normal in-flight write of a live
    appender (or the truncated tail of a crash), not damage — readers
    polling a journal a writer is still appending to must not warn on
    every poll."""
    path = Path(path)
    files = sorted(path.glob("*.jsonl")) if path.is_dir() else [path]
    bad = 0
    for f in files:
        try:
            text = f.read_text()
        except OSError as e:
            warnings.warn(f"unreadable journal {f}: {e}")
            continue
        lines = text.split("\n")
        live_tail = lines.pop() if lines else ""    # "" when the file
        #                                 ends in \n; else an in-flight
        #                                 or truncated final line
        for line in lines:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                bad += 1
                continue
            if isinstance(rec, dict):
                yield rec
            else:
                bad += 1
        live_tail = live_tail.strip()
        if live_tail:                   # salvage a complete-but-unflushed
            try:                        # record; else drop it silently
                rec = json.loads(live_tail)
                if isinstance(rec, dict):
                    yield rec
            except json.JSONDecodeError:
                pass
    if bad:
        warnings.warn(f"journal {path}: skipped {bad} unparseable "
                      f"line(s)")


def _replay_slot() -> Dict:
    return dict(segments=0, segments_by_phase={}, n_evals=0,
                final_hv=None, hv_path=[], results=[], plans=[],
                planned_segments=0, elapsed_s=0.0)


def replay(records: Union[Sequence[Dict], Iterator[Dict]]) -> Dict[str, Dict]:
    """Fold a record stream into per-key run summaries:

    ``{key: {segments, segments_by_phase, n_evals, final_hv, hv_path,
    results, planned_segments, plans, elapsed_s, runs}}``

    Records are PARTITIONED by the ``run`` stamp each submission's
    ``obs.run_context`` put on them (records without one share a single
    legacy partition), not by record order: overlapping submissions
    interleave their records in a shared fleet journal, so order-based
    attribution would splice one run's segments into another's.  Each
    partition is summarized independently under ``runs[run_id]``; the
    per-key top level aggregates them — counters sum, ``results`` /
    ``plans`` concatenate (partition-ordered), while ``final_hv`` /
    ``hv_path`` come from the run with the LATEST record (a hypervolume
    trajectory only means something within one run; summing two runs'
    paths would fabricate a trajectory nobody searched).  With a single
    run in the journal the aggregate equals the partition, so
    single-submission consumers are unchanged.

    ``segments`` counts every segment record of the key (all phases);
    ``final_hv`` is the first column of the last segment's
    archive-projected hypervolume row (the quantity the plateau detector
    monitors and ``ConvergenceTrace.archive_hv`` carries in memory) —
    the invariant ``bench_obs`` replays against the in-memory result."""
    out: Dict[str, Dict] = {}
    last_t: Dict[str, Dict] = {}

    def slot(key: str, run) -> Dict:
        k = out.setdefault(key, dict(_replay_slot(), runs={}))
        if run not in k["runs"]:
            k["runs"][run] = _replay_slot()
            last_t.setdefault(key, {})[run] = float("-inf")
        return k["runs"][run]

    for rec in records:
        key = rec.get("key")
        typ = rec.get("type")
        if key is None:
            continue
        run = rec.get("run")
        if typ in ("segment", "result", "plan"):
            s = slot(key, run)
            last_t[key][run] = max(last_t[key][run],
                                   float(rec.get("t", 0.0)))
        if typ == "segment":
            s["segments"] += 1
            ph = rec.get("phase", "refine")
            s["segments_by_phase"][ph] = \
                s["segments_by_phase"].get(ph, 0) + 1
            s["n_evals"] += int(rec.get("n_evals", 0))
            s["elapsed_s"] += float(rec.get("elapsed_s", 0.0))
            hv = rec.get("hv")
            if hv:
                s["hv_path"].append(float(hv[0]))
                s["final_hv"] = float(hv[0])
        elif typ == "result":
            s["results"].append(rec)
        elif typ == "plan":
            s["plans"].append(rec)
            s["planned_segments"] += len(rec.get("segments", ()))
    for key, k in out.items():
        for run, s in k["runs"].items():
            k["segments"] += s["segments"]
            for ph, n in s["segments_by_phase"].items():
                k["segments_by_phase"][ph] = \
                    k["segments_by_phase"].get(ph, 0) + n
            k["n_evals"] += s["n_evals"]
            k["elapsed_s"] += s["elapsed_s"]
            k["planned_segments"] += s["planned_segments"]
            k["results"].extend(s["results"])
            k["plans"].extend(s["plans"])
        with_hv = [r for r, s in k["runs"].items()
                   if s["final_hv"] is not None]
        if with_hv:
            latest = max(with_hv, key=lambda r: last_t[key][r])
            k["final_hv"] = k["runs"][latest]["final_hv"]
            k["hv_path"] = list(k["runs"][latest]["hv_path"])
    return out


# ---------------------------------------------------------------------------
# the process-wide env-configured default journal
# ---------------------------------------------------------------------------
_DEFAULT: Optional[Journal] = None
_DEFAULT_LOCK = threading.Lock()


def default_journal() -> Optional[Journal]:
    """The process-wide journal ``$REPRO_JOURNAL_DIR`` configures, or
    ``None`` when the env var is unset.  One file per process
    (``run-<timestamp>-<pid>.jsonl``), created lazily on first write —
    every ``Session`` without an explicit ``journal=`` shares it, so a
    benchmark run lands in one journal however many sessions it opens."""
    global _DEFAULT
    root = os.environ.get(JOURNAL_ENV)
    if not root:
        return None
    with _DEFAULT_LOCK:
        if _DEFAULT is None or Path(root) != _DEFAULT.path.parent:
            stamp = time.strftime("%Y%m%d-%H%M%S")
            _DEFAULT = Journal(
                Path(root) / f"run-{stamp}-{os.getpid()}.jsonl")
    return _DEFAULT


def resolve_journal(journal) -> Optional[Journal]:
    """Normalize a ``Session(journal=...)`` argument: a ``Journal`` is
    used as-is, a path-like creates one there, ``None`` falls back to
    the ``$REPRO_JOURNAL_DIR`` default journal (or no journal at all),
    and ``False`` explicitly disables journaling for the session even
    when the env var is set."""
    if journal is False:
        return None
    if journal is None:
        return default_journal()
    if isinstance(journal, Journal):
        return journal
    return Journal(journal)


__all__ = ["JOURNAL_ENV", "Journal", "default_journal", "read_journal",
           "replay", "resolve_journal"]
