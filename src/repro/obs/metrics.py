"""Process-wide metrics registry: counters, gauges, and reservoir
histograms with exact quantiles.

Dependency-free (stdlib + optional numpy only at call sites) and
thread-safe: the exploration benchmarks run service queries on
background threads, so every mutation takes the registry's lock.  The
registry is a flat namespace of dotted metric names — the catalog the
search stack emits is documented in the README's "Observability"
section (``explore.cache.hit``, ``explore.evals.spent``, ...).

Histograms keep a *bounded reservoir* of observations: quantiles are
EXACT while the observation count stays within the reservoir capacity
(the common case — a session observes hundreds of segments, not
millions), and degrade to uniform reservoir sampling (Algorithm R with
a deterministic per-histogram PRNG) beyond it, so memory stays bounded
however long a service lives.
"""

from __future__ import annotations

import random
import threading
from typing import Dict, List, Optional, Tuple


class Counter:
    """Monotone event counter (``inc`` only)."""

    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str, lock: threading.Lock):
        self.name = name
        self.value = 0
        self._lock = lock

    def inc(self, n: int = 1) -> "Counter":
        with self._lock:
            self.value += int(n)
        return self


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str, lock: threading.Lock):
        self.name = name
        self.value = 0.0
        self._lock = lock

    def set(self, v: float) -> "Gauge":
        with self._lock:
            self.value = float(v)
        return self


class Histogram:
    """Bounded-reservoir distribution of float observations.

    ``quantile(q)`` is exact (a sorted-order statistic over everything
    observed) while ``count <= capacity``; past that the reservoir is a
    uniform sample (Algorithm R) and quantiles are estimates over it.
    The per-histogram PRNG is seeded from the metric name, so a re-run
    of the same workload reproduces the same reservoir."""

    __slots__ = ("name", "capacity", "count", "total", "vmin", "vmax",
                 "_res", "_rng", "_lock")

    def __init__(self, name: str, lock: threading.Lock,
                 capacity: int = 1024):
        self.name = name
        self.capacity = max(int(capacity), 1)
        self.count = 0
        self.total = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")
        self._res: List[float] = []
        self._rng = random.Random(name)
        self._lock = lock

    def observe(self, v: float) -> "Histogram":
        v = float(v)
        with self._lock:
            self.count += 1
            self.total += v
            self.vmin = min(self.vmin, v)
            self.vmax = max(self.vmax, v)
            if len(self._res) < self.capacity:
                self._res.append(v)
            else:                        # Algorithm R: keep a uniform
                j = self._rng.randrange(self.count)     # sample of size
                if j < self.capacity:                   # ``capacity``
                    self._res[j] = v
        return self

    def quantile(self, q: float) -> Optional[float]:
        """The ``q``-quantile (0 <= q <= 1) of the reservoir — exact
        while ``count <= capacity``.  ``None`` before any observation."""
        with self._lock:
            if not self._res:
                return None
            s = sorted(self._res)
        idx = min(int(q * len(s)), len(s) - 1)
        return s[max(idx, 0)]

    def quantiles(self, qs: Tuple[float, ...] = (0.5, 0.9, 0.99)
                  ) -> Dict[str, Optional[float]]:
        return {f"p{int(q * 100)}": self.quantile(q) for q in qs}

    @property
    def mean(self) -> Optional[float]:
        return self.total / self.count if self.count else None


class MetricsRegistry:
    """One flat, thread-safe namespace of named metrics.  ``counter`` /
    ``gauge`` / ``histogram`` create-or-return (a name is permanently
    bound to its first kind — asking for the same name as a different
    kind is a bug and raises)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, object] = {}

    def _get(self, name: str, cls, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, self._lock, **kw)
                self._metrics[name] = m
        if not isinstance(m, cls):
            raise TypeError(f"metric {name!r} is a "
                            f"{type(m).__name__}, not a {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, capacity: int = 1024) -> Histogram:
        return self._get(name, Histogram, capacity=capacity)

    def peek(self, name: str) -> Optional[object]:
        """The metric registered under ``name``, WITHOUT creating it —
        for read-only consumers (e.g. ``Plan`` wall-clock prediction off
        the segment-time histograms) that must not pollute the namespace
        with empty metrics just by asking."""
        with self._lock:
            return self._metrics.get(name)

    def snapshot(self) -> Dict[str, Dict]:
        """JSON-serializable dump of every metric: counters/gauges carry
        ``value``; histograms carry count/mean/min/max and exact(-ish)
        p50/p90/p99."""
        with self._lock:
            items = list(self._metrics.items())
        out: Dict[str, Dict] = {}
        for name, m in sorted(items):
            if isinstance(m, Counter):
                out[name] = dict(kind="counter", value=m.value)
            elif isinstance(m, Gauge):
                out[name] = dict(kind="gauge", value=m.value)
            else:
                h: Histogram = m            # type: ignore[assignment]
                out[name] = dict(kind="histogram", count=h.count,
                                 mean=h.mean,
                                 min=h.vmin if h.count else None,
                                 max=h.vmax if h.count else None,
                                 **h.quantiles())
        return out

    def reset(self) -> None:
        """Drop every metric (tests and fresh benchmark arms)."""
        with self._lock:
            self._metrics.clear()


# the process-wide registry every instrumentation site writes into
REGISTRY = MetricsRegistry()

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry", "REGISTRY"]
