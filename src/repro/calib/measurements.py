"""Measurement records + loaders for the three ground-truth sources.

A :class:`Measurement` is one externally measured (or published) PPA number
together with enough declarative metadata for the fit to rebuild the model's
prediction of the same quantity:

* ``kind="chiplet_matmul"`` — a single-chiplet matmul latency, predicted by
  ``analyze_chiplet`` under the ScaleSim-matched configuration that
  ``benchmarks/bench_validation.py`` uses (one 8x8 core, chiplet tile = one
  output fold).  Meta: ``M, N, K, bw`` (+ optional ``ax, ay`` array dims).
* ``kind="system"`` — a full-system metric of a *frozen baseline design*
  (Simba / NN-Baton / Monad class geometry from ``core.baselines``),
  predicted by ``evaluate_system``.  Meta: ``graph`` (a ``fig7_suite`` name),
  ``baseline``, ``pe_budget`` (+ optional ``ch_max, seed``).

Meta is stored as a sorted tuple of pairs so records stay hashable and
deterministic; ``measurements_digest`` gives the provenance digest carried
by :class:`~repro.calib.preset.CalibratedTech` artifacts.
"""

from __future__ import annotations

import csv
import dataclasses
import hashlib
import json
from typing import Dict, Iterable, List, Sequence, Tuple

KINDS = ("chiplet_matmul", "system")

#: default shape sweep — matches benchmarks/bench_validation.SHAPES
SWEEP_SHAPES = [(64, 64, 64), (128, 128, 128), (128, 512, 256),
                (256, 256, 256), (512, 512, 128), (512, 64, 512),
                (100, 100, 100), (72, 56, 40), (320, 192, 96)]
SWEEP_BWS = (128.0, 16.0)


@dataclasses.dataclass(frozen=True)
class Measurement:
    """One measured PPA number with declarative model-rebuild metadata."""
    kind: str                    # one of KINDS
    metric: str                  # latency_ns | energy_pj | area_mm2 | cost_usd
    value: float                 # measured ground truth (> 0)
    source: str = "external"     # provenance tag
    meta: Tuple[Tuple[str, object], ...] = ()

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown measurement kind {self.kind!r}")
        if not (float(self.value) > 0):
            raise ValueError(f"measurement value must be > 0: {self.value}")

    @classmethod
    def make(cls, kind: str, metric: str, value: float,
             source: str = "external", **meta) -> "Measurement":
        return cls(kind, metric, float(value), source,
                   tuple(sorted(meta.items())))

    @property
    def info(self) -> Dict[str, object]:
        return dict(self.meta)

    def to_dict(self) -> Dict[str, object]:
        d = {"kind": self.kind, "metric": self.metric,
             "value": float(self.value), "source": self.source}
        d.update(self.info)
        return d


def measurements_digest(ms: Sequence[Measurement]) -> str:
    """Order-insensitive sha256 content digest of a measurement set."""
    rows = sorted(json.dumps(m.to_dict(), sort_keys=True, default=repr)
                  for m in ms)
    return hashlib.sha256("\n".join(rows).encode()).hexdigest()


# ---------------------------------------------------------------------------
# source 1: the cycle-approximate systolic simulator (ScaleSim stand-in)
# ---------------------------------------------------------------------------
def simulator_sweep(shapes: Iterable[Tuple[int, int, int]] = None,
                    bws: Iterable[float] = SWEEP_BWS,
                    array: Tuple[int, int] = (8, 8)) -> List[Measurement]:
    """Run ``simulate_matmul`` over a shape x bandwidth sweep and wrap each
    latency as a measurement (the Sec. V-A validation protocol)."""
    from repro.core.simulator import SystolicConfig, simulate_matmul
    shapes = SWEEP_SHAPES if shapes is None else list(shapes)
    out = []
    for bw in bws:
        for (M, N, K) in shapes:
            cfg = SystolicConfig(array[0], array[1], dram_bw_gbps=float(bw))
            sim = simulate_matmul(M, N, K, cfg)
            out.append(Measurement.make(
                "chiplet_matmul", "latency_ns", sim["latency_ns"],
                source="simulator", M=M, N=N, K=K, bw=float(bw),
                ax=array[0], ay=array[1]))
    return out


# ---------------------------------------------------------------------------
# source 2: published Simba / NN-Baton baseline numbers
# ---------------------------------------------------------------------------
#: Published-literature system numbers for the two baseline architectures the
#: paper compares against (Sec. V-B), mapped onto the frozen baseline-class
#: designs that ``core.baselines.make_baseline`` realizes in this framework.
#: Simba (Shao et al., MICRO'19): 36-chiplet MCM, 6 mm^2 per chiplet in
#: 16 nm -> 216 mm^2 total silicon; package-level prototype cost class ~$100.
#: NN-Baton (Tan et al., ISCA'21): 4-chiplet-class organic package, ~20 mm^2
#: chiplets.  These are *class* numbers (the papers' nodes differ from the
#: 28 nm constants here) — exactly what the corr_area / corr_cost factors
#: absorb.
PUBLISHED_BASELINES = (
    dict(baseline="simba", graph="res4", pe_budget=1024,
         metric="area_mm2", value=216.0, source="published:simba-micro19"),
    dict(baseline="simba", graph="res4", pe_budget=1024,
         metric="cost_usd", value=110.0, source="published:simba-micro19"),
    dict(baseline="nn-baton", graph="res4", pe_budget=1024,
         metric="area_mm2", value=80.0, source="published:nnbaton-isca21"),
    dict(baseline="nn-baton", graph="res4", pe_budget=1024,
         metric="cost_usd", value=60.0, source="published:nnbaton-isca21"),
)


def baseline_measurements(rows: Iterable[dict] = PUBLISHED_BASELINES
                          ) -> List[Measurement]:
    """Wrap published baseline numbers as ``kind="system"`` measurements.

    Each row names a ``fig7_suite`` graph and a ``core.baselines`` baseline;
    the fit rebuilds the frozen baseline design deterministically (fixed
    PRNG seed) and compares ``evaluate_system`` output against the published
    value."""
    out = []
    for r in rows:
        r = dict(r)
        metric, value = r.pop("metric"), r.pop("value")
        source = r.pop("source", "published")
        out.append(Measurement.make("system", metric, value,
                                    source=source, **r))
    return out


# ---------------------------------------------------------------------------
# source 3: zamlet-style synthesis / measurement reports (CSV or JSON)
# ---------------------------------------------------------------------------
def load_report(path: str) -> List[Measurement]:
    """Load measurements from a synthesis/measurement report file.

    Two formats, keyed by extension:

    * ``.json`` — either ``{"rows": [...]}`` or a bare list, each row a dict
      with ``kind``, ``metric``, ``value`` and optional ``source`` plus any
      meta keys (``M``, ``N``, ``K``, ``bw``, ``graph``, ``baseline``, ...).
    * ``.csv``  — header row ``kind,metric,value,source,<meta...>``; empty
      meta cells are skipped, numeric-looking cells are parsed as numbers.

    This mirrors how the zamlet DSE flow ingests OpenLane area/timing
    reports: one row per measured quantity, tool-agnostic columns.
    """
    if path.endswith(".json"):
        with open(path) as f:
            doc = json.load(f)
        rows = doc["rows"] if isinstance(doc, dict) else doc
    elif path.endswith(".csv"):
        with open(path, newline="") as f:
            rows = list(csv.DictReader(f))
    else:
        raise ValueError(f"unsupported report format: {path!r} "
                         "(expected .json or .csv)")
    out = []
    for i, row in enumerate(rows):
        row = {k: v for k, v in row.items() if v not in (None, "")}
        try:
            kind = row.pop("kind")
            metric = row.pop("metric")
            value = float(row.pop("value"))
        except KeyError as e:
            raise ValueError(f"report row {i} missing column: {e}") from e
        source = row.pop("source", f"report:{path}")
        meta = {k: _coerce(v) for k, v in row.items()}
        out.append(Measurement.make(kind, metric, value, source=source,
                                    **meta))
    return out


def _coerce(v):
    if isinstance(v, str):
        try:
            f = float(v)
            return int(f) if f == int(f) else f
        except ValueError:
            return v
    return v
