"""Versioned calibrated-constant artifacts.

A :class:`CalibratedTech` bundles fitted constants with everything needed to
trust (or reject) them later: the content digest of the constants, the
digest + source tags of the measurements they were fitted on, the free-field
list, and the before/after error report.  Artifacts serialize to JSON
(atomic write), load by path or — via ``$REPRO_CALIB_DIR`` — by name through
``core.presets.tech_preset``, and register themselves so
``Session(tech="<name>")`` resolves them anywhere in the stack (workers
included).
"""

from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import time
from typing import Dict, Optional, Tuple

from repro.core.constants import (TechConstants, tech_from_dict, tech_key,
                                  tech_to_dict)
from repro.core.presets import register_tech

SCHEMA = 1


@dataclasses.dataclass(frozen=True)
class CalibratedTech:
    """A named, versioned, provenance-carrying TechConstants artifact."""
    name: str
    tech: TechConstants
    base_digest: str                    # tech_key of the starting constants
    source_digest: str                  # measurements content digest
    sources: Tuple[str, ...]            # measurement source tags
    free: Tuple[str, ...]               # fields the fit was allowed to move
    fitted: Dict[str, float]            # field -> fitted value
    errors: Dict[str, Dict[str, float]]  # split -> per-metric rel error
    created: float = 0.0                # unix seconds

    @property
    def digest(self) -> str:
        return tech_key(self.tech)

    @classmethod
    def from_fit(cls, name: str, res) -> "CalibratedTech":
        """Wrap a :class:`repro.calib.fit.FitResult` as a named artifact."""
        return cls(name=str(name), tech=res.tech,
                   base_digest=tech_key(res.tech0),
                   source_digest=res.source_digest, sources=res.sources,
                   free=res.free, fitted=dict(res.fitted),
                   errors={k: dict(v) for k, v in res.errors.items()},
                   created=time.time())

    def to_dict(self) -> Dict:
        return {
            "schema": SCHEMA,
            "name": self.name,
            "digest": self.digest,
            "base_digest": self.base_digest,
            "source_digest": self.source_digest,
            "sources": list(self.sources),
            "free": list(self.free),
            "fitted": self.fitted,
            "errors": self.errors,
            "created": self.created,
            "tech": tech_to_dict(self.tech),
        }

    @classmethod
    def from_dict(cls, doc: Dict) -> "CalibratedTech":
        tech = tech_from_dict(doc["tech"])
        stored = doc.get("digest")
        if stored and stored != tech_key(tech):
            raise ValueError(
                f"calibrated artifact {doc.get('name')!r} digest mismatch: "
                f"stored {stored[:12]} != content {tech_key(tech)[:12]}")
        return cls(name=str(doc["name"]), tech=tech,
                   base_digest=doc.get("base_digest", ""),
                   source_digest=doc.get("source_digest", ""),
                   sources=tuple(doc.get("sources", ())),
                   free=tuple(doc.get("free", ())),
                   fitted=dict(doc.get("fitted", {})),
                   errors={k: dict(v)
                           for k, v in doc.get("errors", {}).items()},
                   created=float(doc.get("created", 0.0)))

    def save(self, out_dir: str) -> str:
        """Atomically write ``<out_dir>/<name>.json`` and register the
        preset in-process; returns the path."""
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir, f"{self.name}.json")
        fd, tmp = tempfile.mkstemp(dir=out_dir, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self.to_dict(), f, indent=1, sort_keys=True)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        self.register()
        return path

    def register(self) -> "CalibratedTech":
        register_tech(self.name, self.tech)
        return self


def load_calibrated(path: str) -> CalibratedTech:
    """Load + digest-verify + register a CalibratedTech artifact."""
    with open(path) as f:
        doc = json.load(f)
    art = CalibratedTech.from_dict(doc)
    art.register()
    return art
