"""``repro.calib`` — fit the analytical model to measured ground truth.

Closes the model-to-silicon loop (ROADMAP direction 5): ingest external PPA
measurements and fit a whitelisted subset of :class:`~repro.core.constants.
TechConstants` fields — plus per-metric multiplicative correction factors —
by gradient descent *through the existing differentiable pure-JAX evaluation
path* (``analyze_chiplet`` / ``evaluate_system``).  Lifecycle::

    measure -> fit -> preset -> search

* ``measurements`` — the :class:`Measurement` record and loaders for three
  sources: ``simulator.simulate_matmul`` sweeps, published Simba/NN-Baton
  baseline numbers (via ``core/baselines.py``), and a zamlet-style CSV/JSON
  synthesis-report format.
* ``fit`` — ``fit(measurements, free=...)``: log-space reparameterized Adam
  in a single ``lax.scan`` minimizing squared log error, with per-metric
  relative-error reports before/after on a held-out split.  Also the CLI:
  ``python -m repro.calib.fit``.
* ``preset`` — :class:`CalibratedTech` artifacts (content digest, source
  provenance, error report), saved as JSON and loadable by name through
  ``core.presets.tech_preset`` / ``Session(tech=...)``.
"""

from .fit import FitResult, error_report, fit, predict  # noqa: F401
from .measurements import (Measurement, baseline_measurements,  # noqa: F401
                           load_report, measurements_digest, simulator_sweep)
from .preset import CalibratedTech, load_calibrated  # noqa: F401

__all__ = [
    "CalibratedTech", "FitResult", "Measurement", "baseline_measurements",
    "error_report", "fit", "load_calibrated", "load_report",
    "measurements_digest", "predict", "simulator_sweep",
]
