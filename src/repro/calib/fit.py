"""Gradient-descent calibration of TechConstants against measurements.

``fit(measurements, free=...)`` reparameterizes a whitelisted subset of
:class:`TechConstants` fields in log-space (positivity is structural), then
minimizes mean squared *log* error — smooth, scale-free, equivalent to
relative error for small residuals — with full-batch Adam in one jitted
``lax.scan`` (the ``explore/surrogate.py`` training idiom).  The model side
of every residual is computed through the existing differentiable pure-JAX
evaluation path: ``analyze_chiplet`` for ``chiplet_matmul`` measurements,
``evaluate_system`` for ``system`` ones.

CLI::

    PYTHONPATH=src python -m repro.calib.fit --source simulator \
        --free t_tile_overhead_ns,corr_latency --name sim28 --out artifacts/calib

Obs surface: ``calib.fit_loss``, ``calib.error_before`` / ``calib.error_after``
histograms, a ``calib.fits`` counter, and a ``type="calib_fit"`` journal
record per fit.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.constants import (DEFAULT_TECH, FITTABLE_FIELDS,
                                  TechConstants, tech_key)
from repro.core.dataflow import analyze_chiplet
from repro.core.workload import MAX_LOOPS, matmul

from .measurements import Measurement, measurements_digest

F = jnp.float32

#: default free set: the additive per-tile overhead the pure pipeline model
#: omits plus the four per-metric corrections — enough to absorb systematic
#: scale error in every metric without disturbing model structure.
DEFAULT_FREE = ("t_tile_overhead_ns", "corr_latency", "corr_energy",
                "corr_area", "corr_cost")

#: log-space floor: fields whose current value is 0 (e.g. the overhead's
#: neutral default) start here instead of log(0).
_FLOOR = 1e-3


# ---------------------------------------------------------------------------
# measurement -> differentiable model prediction
# ---------------------------------------------------------------------------
def _chiplet_predictor(ms: Sequence[Measurement], idx: List[int]):
    """Batched ``analyze_chiplet`` predictor for ``chiplet_matmul`` rows.

    All rows share padded array shapes, so one vmapped call covers every
    (M, N, K, bw) regardless of shape — a single compile for the whole
    sweep.  Configuration matches ``benchmarks/bench_validation``: one
    ax x ay core, chiplet tile = one output fold.
    """
    wls, tis, bws, shs = [], [], [], []
    for m in ms:
        info = m.info
        if m.metric != "latency_ns":
            raise ValueError(
                f"chiplet_matmul supports latency_ns only, got {m.metric!r}")
        M_, N_, K_ = int(info["M"]), int(info["N"]), int(info["K"])
        ax, ay = int(info.get("ax", 8)), int(info.get("ay", 8))
        wls.append(matmul("mm", M_, N_, K_).to_arrays())
        tis.append([[ax, ay, K_] + [1] * (MAX_LOOPS - 3)] * 2)
        bws.append(float(info.get("bw", 128.0)))
        shs.append([ax, ay, 1, 1, 1, 1])
    wl_b = {k: jnp.asarray(np.stack([w[k] for w in wls])) for k in wls[0]}
    ti_b = jnp.asarray(np.asarray(tis), jnp.int32)
    sh_b = jnp.asarray(np.asarray(shs), jnp.int32)
    bw_b = jnp.asarray(np.asarray(bws), F)
    sp = jnp.asarray([0, 1, 0, 1, 0, 1], jnp.int32)
    od = jnp.asarray([list(range(MAX_LOOPS))] * 3, jnp.int32)
    idx_b = jnp.asarray(np.asarray(idx), jnp.int32)

    def predict(tech):
        def one(wl, sh, ti, bw):
            an = analyze_chiplet(wl, sh, sp, od, ti, tech, ext_bw_gbps=bw)
            return an["delay_ns"] * F(tech.corr_latency)
        return idx_b, jax.vmap(one)(wl_b, sh_b, ti_b, bw_b)

    return predict


def _system_predictor(ms: Sequence[Measurement], idx: List[int]):
    """``evaluate_system`` predictor for ``system`` rows sharing one frozen
    baseline configuration (graph, baseline, pe_budget, ch_max, seed)."""
    from repro.core.baselines import make_baseline
    from repro.core.evaluate import SystemSpec, evaluate_system
    from repro.core.presets import fig7_suite

    info = ms[0].info
    graphs = fig7_suite()
    gname = str(info["graph"])
    if gname not in graphs:
        raise KeyError(f"unknown graph {gname!r}; known: {sorted(graphs)}")
    spec = SystemSpec.build(graphs[gname], ch_max=int(info.get("ch_max", 4)))
    bl = make_baseline(str(info.get("baseline", "monad")), spec,
                       jax.random.PRNGKey(int(info.get("seed", 0))),
                       pe_budget=int(info.get("pe_budget", 1024)))
    design = jax.tree.map(jnp.asarray, bl.init)
    metrics = [m.metric for m in ms]
    idx_b = jnp.asarray(np.asarray(idx), jnp.int32)

    def predict(tech):
        res = evaluate_system(spec, design, tech)
        return idx_b, jnp.stack([res[k] for k in metrics])

    return predict


def _system_group_key(m: Measurement) -> tuple:
    info = m.info
    return ("system", str(info.get("graph")), str(info.get("baseline")),
            int(info.get("pe_budget", 1024)), int(info.get("ch_max", 4)),
            int(info.get("seed", 0)))


def _build_predictor(ms: Sequence[Measurement]):
    """Compile-friendly predictor over a mixed measurement list: returns
    ``predict(tech) -> (n,) jnp array`` aligned with ``ms`` order."""
    groups: Dict[tuple, Tuple[List[Measurement], List[int]]] = {}
    for i, m in enumerate(ms):
        gk = (("chiplet",) if m.kind == "chiplet_matmul"
              else _system_group_key(m))
        groups.setdefault(gk, ([], []))
        groups[gk][0].append(m)
        groups[gk][1].append(i)
    preds = []
    for gk, (gms, idx) in groups.items():
        if gk[0] == "chiplet":
            preds.append(_chiplet_predictor(gms, idx))
        else:
            preds.append(_system_predictor(gms, idx))
    n = len(ms)

    def predict(tech):
        out = jnp.zeros((n,), F)
        for p in preds:
            ib, vb = p(tech)
            out = out.at[ib].set(vb)
        return out

    return predict


def _tech_with(tech0: TechConstants, theta: Dict[str, jnp.ndarray]
               ) -> TechConstants:
    return dataclasses.replace(
        tech0, **{k: jnp.exp(v) for k, v in theta.items()})


def predict(ms: Sequence[Measurement],
            tech: TechConstants = DEFAULT_TECH) -> np.ndarray:
    """Model predictions for a measurement list under ``tech`` (n,)."""
    return np.asarray(_build_predictor(ms)(tech))


def error_report(ms: Sequence[Measurement],
                 tech: TechConstants = DEFAULT_TECH,
                 pred: Optional[np.ndarray] = None) -> Dict[str, float]:
    """Per-metric mean relative error |pred - meas| / meas, plus ``mean``."""
    if not ms:
        return {}
    p = predict(ms, tech) if pred is None else np.asarray(pred)
    meas = np.asarray([m.value for m in ms])
    rel = np.abs(p - meas) / meas
    out = {}
    for metric in sorted({m.metric for m in ms}):
        sel = np.asarray([m.metric == metric for m in ms])
        out[metric] = float(np.mean(rel[sel]))
    out["mean"] = float(np.mean(rel))
    return out


# ---------------------------------------------------------------------------
# the fit
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FitResult:
    """A completed calibration fit: fitted constants + provenance + errors."""
    tech: TechConstants
    tech0: TechConstants
    free: Tuple[str, ...]
    fitted: Dict[str, float]           # field -> fitted value
    errors: Dict[str, Dict[str, float]]  # split -> per-metric relative error
    loss: Tuple[float, float]          # (initial, final) train loss
    n_train: int
    n_holdout: int
    steps: int
    lr: float
    seed: int
    source_digest: str
    sources: Tuple[str, ...]

    @property
    def digest(self) -> str:
        return tech_key(self.tech)


def fit(measurements: Sequence[Measurement],
        free: Sequence[str] = DEFAULT_FREE,
        holdout: Optional[Sequence[Measurement]] = None,
        holdout_frac: float = 0.25,
        steps: int = 400,
        lr: float = 0.05,
        seed: int = 0,
        tech0: TechConstants = DEFAULT_TECH) -> FitResult:
    """Fit ``free`` TechConstants fields to ``measurements``.

    ``holdout`` pins an explicit held-out set (the bench_validation gate
    splits by shape); otherwise a deterministic ``holdout_frac`` split of
    ``measurements`` is used.  Returns a :class:`FitResult` whose ``errors``
    dict reports per-metric mean relative error for ``train_before/after``
    and ``holdout_before/after``.
    """
    free = tuple(free)
    bad = set(free) - set(FITTABLE_FIELDS)
    if bad:
        raise ValueError(f"non-whitelisted fit fields: {sorted(bad)}; "
                         f"allowed: {FITTABLE_FIELDS}")
    if not measurements:
        raise ValueError("no measurements")

    if holdout is None:
        rng = np.random.default_rng(seed)
        perm = rng.permutation(len(measurements))
        n_hold = int(round(len(measurements) * holdout_frac))
        hold_i = set(perm[:n_hold].tolist())
        train = [m for i, m in enumerate(measurements) if i not in hold_i]
        hold = [m for i, m in enumerate(measurements) if i in hold_i]
    else:
        train, hold = list(measurements), list(holdout)
    if not train:
        raise ValueError("empty training split")

    all_ms = train + hold
    with obs.span("calib.fit", n_train=len(train), n_holdout=len(hold),
                  free=",".join(free), steps=steps):
        predict_fn = _build_predictor(all_ms)
        meas = jnp.asarray([m.value for m in all_ms], F)
        n_train = len(train)

        theta0 = {f: jnp.log(jnp.maximum(
            jnp.asarray(getattr(tech0, f), F), _FLOOR)) for f in free}

        def loss_fn(theta):
            pred = predict_fn(_tech_with(tech0, theta))
            r = jnp.log(jnp.maximum(pred[:n_train], 1e-9)) \
                - jnp.log(meas[:n_train])
            return jnp.mean(r * r)

        b1, b2, eps = 0.9, 0.999, 1e-8
        m0 = jax.tree.map(jnp.zeros_like, theta0)
        v0 = jax.tree.map(jnp.zeros_like, theta0)

        def step(carry, t):
            th, m, v = carry
            lval, g = jax.value_and_grad(loss_fn)(th)
            m = jax.tree.map(lambda a, b_: b1 * a + (1 - b1) * b_, m, g)
            v = jax.tree.map(lambda a, b_: b2 * a + (1 - b2) * b_ ** 2, v, g)
            c1 = 1 - b1 ** (t + 1)
            c2 = 1 - b2 ** (t + 1)
            th = jax.tree.map(
                lambda w, mm, vv: w - lr * (mm / c1)
                / (jnp.sqrt(vv / c2) + eps), th, m, v)
            return (th, m, v), lval

        (theta, _, _), losses = jax.jit(lambda c: jax.lax.scan(
            step, c, jnp.arange(steps, dtype=F)))((theta0, m0, v0))

        fitted = {f: float(np.exp(np.asarray(theta[f]))) for f in free}
        tech_fit = dataclasses.replace(tech0, **fitted)

        pred0 = np.asarray(predict_fn(tech0))
        pred1 = np.asarray(predict_fn(tech_fit))
        errors = {
            "train_before": error_report(train, tech0, pred0[:n_train]),
            "train_after": error_report(train, tech_fit, pred1[:n_train]),
            "holdout_before": error_report(hold, tech0, pred0[n_train:]),
            "holdout_after": error_report(hold, tech_fit, pred1[n_train:]),
        }
        loss_i, loss_f = float(losses[0]), float(losses[-1])

        obs.inc("calib.fits")
        obs.observe("calib.fit_loss", loss_f)
        obs.observe("calib.error_before",
                    errors["train_before"].get("mean", 0.0))
        obs.observe("calib.error_after",
                    errors["train_after"].get("mean", 0.0))
        result = FitResult(
            tech=tech_fit, tech0=tech0, free=free, fitted=fitted,
            errors=errors, loss=(loss_i, loss_f),
            n_train=n_train, n_holdout=len(hold), steps=steps, lr=lr,
            seed=seed, source_digest=measurements_digest(all_ms),
            sources=tuple(sorted({m.source for m in all_ms})))
        obs.emit({"type": "calib_fit", "free": list(free),
                  "fitted": fitted, "errors": errors,
                  "loss": [loss_i, loss_f], "n_train": n_train,
                  "n_holdout": len(hold), "steps": steps, "lr": lr,
                  "seed": seed, "source_digest": result.source_digest,
                  "tech_digest": result.digest})
    return result


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse
    import os

    from .measurements import (baseline_measurements, load_report,
                               simulator_sweep)
    from .preset import CalibratedTech

    ap = argparse.ArgumentParser(
        prog="python -m repro.calib.fit",
        description="Fit TechConstants to measured ground truth.")
    ap.add_argument("--source", action="append", default=[],
                    help="'simulator', 'baselines', or a report path "
                         "(.csv/.json); repeatable; default: simulator")
    ap.add_argument("--free", action="append", default=[],
                    help="TechConstants field to fit; repeatable, each "
                         "occurrence may also be comma-separated "
                         f"(default: {','.join(DEFAULT_FREE)})")
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--holdout-frac", type=float, default=0.25)
    ap.add_argument("--name", default="calibrated",
                    help="preset name for the saved artifact")
    ap.add_argument("--out", default=os.environ.get("REPRO_CALIB_DIR",
                                                    "artifacts/calib"),
                    help="output directory for the CalibratedTech JSON")
    args = ap.parse_args(argv)

    ms: List[Measurement] = []
    for src in (args.source or ["simulator"]):
        if src == "simulator":
            ms += simulator_sweep()
        elif src == "baselines":
            ms += baseline_measurements()
        else:
            ms += load_report(src)
    free = tuple(f.strip() for part in (args.free or [",".join(DEFAULT_FREE)])
                 for f in part.split(",") if f.strip())

    res = fit(ms, free=free, holdout_frac=args.holdout_frac,
              steps=args.steps, lr=args.lr, seed=args.seed)

    art = CalibratedTech.from_fit(args.name, res)
    path = art.save(args.out)

    print(f"fit: {len(ms)} measurements "
          f"({res.n_train} train / {res.n_holdout} held out), "
          f"free={','.join(free)}")
    for f, v in res.fitted.items():
        print(f"  {f}: {getattr(res.tech0, f)} -> {v:.6g}")
    for split in ("train", "holdout"):
        b = res.errors[f"{split}_before"].get("mean")
        a = res.errors[f"{split}_after"].get("mean")
        if b is not None:
            print(f"  {split}: mean rel err {b*100:.2f}% -> {a*100:.2f}%")
    print(f"saved: {path} (preset '{args.name}', digest "
          f"{art.digest[:12]})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
