"""Deterministic synthetic token pipeline (host-sharded, resumable).

Production shape: each host owns a disjoint shard of the global batch
(``host_id``/``n_hosts``), batches are a pure function of (seed, step) so a
restart at step k reproduces the exact stream — the checkpoint only needs to
store the step counter.  The synthetic distribution is a Zipfian unigram
mixture with Markov bigram structure, enough for loss curves to be
meaningfully decreasing (used by the convergence tests and the train
example)."""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    host_id: int = 0
    n_hosts: int = 1
    zipf_a: float = 1.2


class SyntheticLM:
    """tokens[t+1] depends on tokens[t] through a fixed random permutation
    plus Zipf noise — learnable structure with a closed-form entropy gap."""

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.n_hosts == 0
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        self.perm = rng.permutation(cfg.vocab)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self.unigram = p / p.sum()

    @property
    def host_batch(self) -> int:
        return self.cfg.global_batch // self.cfg.n_hosts

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Pure function of (seed, step, host) -> one host's batch."""
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed, step, cfg.host_id, 0xD0E5))
        B, S = self.host_batch, cfg.seq_len
        toks = np.empty((B, S), np.int32)
        toks[:, 0] = rng.choice(cfg.vocab, size=B, p=self.unigram)
        noise = rng.random((B, S))
        fresh = rng.choice(cfg.vocab, size=(B, S), p=self.unigram)
        for t in range(1, S):
            follow = self.perm[toks[:, t - 1]]
            toks[:, t] = np.where(noise[:, t] < 0.75, follow, fresh[:, t])
        labels = np.concatenate(
            [toks[:, 1:], np.zeros((B, 1), np.int32)], axis=1)
        mask = np.ones((B, S), np.float32)
        mask[:, -1] = 0.0
        return {"tokens": toks, "labels": labels, "loss_mask": mask}

    def stream(self, start_step: int = 0,
               num_steps: Optional[int] = None) -> Iterator[Dict]:
        step = start_step
        while num_steps is None or step < start_step + num_steps:
            yield self.batch_at(step)
            step += 1
