"""NSGA-II-style evolutionary front explorer (Gemini-style co-exploration).

Where ``repro.core.optimizer`` scalarizes the four objectives into one
number, this engine keeps the whole population nondominated-ranked and
returns a *front*.  The entire evolution is a single jitted ``lax.scan``
over vmapped populations:

    generation = variate (field crossover + ``encoding.mutate`` moves)
               -> evaluate (vmapped ``evaluate_arrays``)
               -> environmental selection over parents+children
                  (dominance counts, crowding-distance tie-break)

Evaluation and objectives are the same path the scalarized engines use
(``log_metric_stack`` + ``feasibility_penalty``), so a design judged good
here is good there and vice versa.  Compiled runners are cached on the
padded workload dims exactly like ``make_sa`` — every graph with equal
(W, CH, E) shares one compilation.

Two scaling layers sit on top of the single scan:

* **island sharding** (``make_nsga(..., mesh=...)``) — the population axis
  is sharded across a device mesh with ``shard_map``; each device evolves
  an island and a ``lax.ppermute`` ring exchanges elite migrants every
  ``cfg.migration_interval`` generations.  On a 1-device mesh the body
  statically reduces to the unsharded step, so results are bit-identical
  to the plain scan.
* **cross-problem lanes** (``make_nsga_fused(..., lanes=L)``) — the whole
  run is vmapped over a stacked lane axis so ``L`` *distinct* problems
  (same padded dims / space statics / schedule) evaluate in one compiled
  dispatch; per-lane keys, populations, spec arrays and immigrants ride
  the lane axis.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec

from ..core.encoding import (ALL_FIELDS, DesignSpace, feasibility_penalty,
                             mutate, random_design)
from ..core.evaluate import SystemSpec, evaluate_arrays
from ..core.optimizer import METRIC_KEYS, log_metric_stack, metric_stack
from .archive import (BIG, HV_LOG_REF, crowding_distance, dominance_counts,
                      flatten_design, hypervolume_2d_jit, objective_pairs)

F = jnp.float32

# design fields, in a fixed order, for the field-level crossover
_DESIGN_KEYS = ("shape", "spatial", "order", "tiling", "pipe", "logB",
                "packaging", "family", "placement")

# the mesh axis the island model shards the population over
ISLAND_AXIS = "islands"


@dataclasses.dataclass(frozen=True)
class NSGAConfig:
    pop: int = 64                 # population size (vmapped width)
    generations: int = 32         # scan length; evals = pop * generations
    fields: Tuple[str, ...] = ALL_FIELDS
    crossover_rate: float = 0.35  # per-field probability of taking the mate
    mutations: int = 2            # chained encoding.mutate moves per child
    immigrants: float = 0.125     # fraction of children replaced by fresh
    #                               random designs (keeps the front spread)
    pmx_placement: bool = False   # placement crossover MIXES both parents'
    #                               permutations (PMX) instead of taking one
    #                               wholesale — permutation validity kept
    # --- island mode (only active under make_nsga(..., mesh=...)) -------
    migration_interval: int = 4   # ppermute a migrant ring every K
    #                               generations
    migration_frac: float = 0.125  # fraction of each island's population
    #                                sent around the ring (its elite head,
    #                                replacing the neighbor's worst tail)


def pmx(key, a, b):
    """Partially-mapped crossover of two permutations (jit/vmap-safe).

    A random segment ``[lo, hi)`` of ``b`` is worked into a child that
    otherwise inherits ``a``: walking the segment, ``b[k]`` is swapped into
    position ``k`` (the classic in-place PMX formulation), so the result
    is always a valid permutation carrying ``b``'s segment and ``a``'s
    relative order elsewhere."""
    n = a.shape[0]
    k1, k2 = jax.random.split(jnp.asarray(key))
    i = jax.random.randint(k1, (), 0, n)
    j = jax.random.randint(k2, (), 0, n + 1)
    lo, hi = jnp.minimum(i, j), jnp.maximum(i, j)

    def body(k, child):
        def swap(c):
            v = b[k]
            pos = jnp.argmax(c == v)
            return c.at[pos].set(c[k]).at[k].set(v)
        return jax.lax.cond((k >= lo) & (k < hi), swap, lambda c: c, child)

    return jax.lax.fori_loop(0, n, body, a)


# compiled runners keyed like the SA cache: padded dims + static config
_NSGA_CACHE: dict = {}


def _static_key(dims, idx, cfg, tech, space):
    """Everything compile-relevant about one scan variant EXCEPT how it is
    laid out over devices (mesh) or lanes — the shared stem of the
    single-run, island and fused cache keys.

    Workload CONTENT (bounds/loopmask/...) is deliberately absent: every
    cached closure takes it at runtime via the arrays dict (evaluation,
    mutation, and immigrant sampling alike), so a cache hit for a
    statics-equal but different problem is content-correct.  Keep it that
    way — baking any ``space.spec`` array into a closure here would make
    results depend on which problem first populated the cache."""
    return (dims, idx, cfg, tech, space.max_shape, space.max_logB,
            space.max_total_pes, space.fixed_packaging,
            space.fixed_family, space.allow_pipeline)


def make_nsga(spec: SystemSpec, space: DesignSpace,
              objectives: Tuple[str, ...] = METRIC_KEYS,
              cfg: NSGAConfig = NSGAConfig(), tech=None, mesh=None):
    """Build a jitted front explorer.

    Returns ``run(key, pop0, arrays=None) ->
    (pop, raw, sel, ev_designs, ev_raw, ev_feas, trace)`` where ``pop0``
    is a stacked design pytree of width ``cfg.pop``; ``raw`` is the
    (pop, 4) matrix of raw metrics in ``METRIC_KEYS`` order and ``sel``
    the (pop, n_obj) penalized log-objectives selection ranked on.
    ``ev_designs`` / ``ev_raw`` / ``ev_feas`` are EVERY evaluated design
    of the run, stacked (generations, pop, ...) — the archive fodder:
    nothing the explorer paid for is thrown away.  ``ev_feas`` marks
    designs with no feasibility penalty; infeasible points may stay in
    the evolving population (the penalty steers them out) but must not be
    archived or served.  The population is elitist (nondominated parents
    survive unless crowd-pruned), so ``pop`` carries the running front;
    total evaluations = ``cfg.pop * cfg.generations``.

    ``trace`` is the per-generation convergence telemetry, scanned out of
    the same ``lax.scan`` with ZERO extra evaluations (pure dominance
    math over objective vectors the run already paid for): a dict of
    stacked arrays — ``front_size`` (G,) feasible nondominated count of
    the post-selection population, ``hypervolume`` (G, P) running
    (cumulative-best) 2-D hypervolume per objective pair over clipped
    log-metrics w.r.t. ``HV_LOG_REF`` (monotone non-decreasing by
    construction), ``best`` (G,) running best penalized scalarized
    objective (monotone non-increasing), and ``feasible_frac`` (G,) the
    feasible fraction of each generation's children.  Feed it to
    ``ConvergenceTrace.from_scan`` for the host-side view.

    ``mesh`` (a ``jax.sharding.Mesh`` with an ``"islands"`` axis) turns on
    the island model: the population axis is sharded across the mesh with
    ``shard_map``, each device evolves its own island (per-island PRNG
    streams fold in the island index) and every ``cfg.migration_interval``
    generations each island's ``cfg.migration_frac`` elite head rotates
    one hop around a ``lax.ppermute`` ring, replacing the receiver's worst
    tail.  Telemetry stays GLOBAL (the trace is computed over the
    all-gathered population, so front size / hypervolume mean the same
    thing sharded or not).  On a 1-device mesh every island construct is
    statically skipped and the result is bit-identical to ``mesh=None``.
    """
    from ..core.constants import DEFAULT_TECH
    tech = tech or DEFAULT_TECH
    dims = (spec.W, spec.CH, spec.E)
    idx = tuple(METRIC_KEYS.index(o) for o in objectives)
    if not idx:
        raise ValueError("objectives must name at least one metric")

    n_isl = 1
    if mesh is not None:
        if ISLAND_AXIS not in mesh.shape:
            raise ValueError(f"island mesh must name a {ISLAND_AXIS!r} "
                             f"axis; got {tuple(mesh.shape)}")
        n_isl = int(mesh.shape[ISLAND_AXIS])
        if cfg.pop % n_isl or cfg.pop // n_isl < 2:
            raise ValueError(f"pop={cfg.pop} cannot shard into {n_isl} "
                             f"islands of at least 2 designs")

    cache_key = _static_key(dims, idx, cfg, tech, space) + (mesh,)
    if cache_key not in _NSGA_CACHE:
        n_imm = int(round((cfg.pop // n_isl) * cfg.immigrants)) * n_isl
        # immigrants are drawn OUTSIDE the scanned/jitted evolution (as a
        # scan input) — random_design's permutation sorts are expensive to
        # compile and belong in one small vmapped kernel, not in the body.
        # nl/bounds come in as runtime arrays (not baked from `space`) so
        # the cached sampler carries NO workload content: a cache hit for
        # a statics-equal but different problem stays content-correct
        imm_fn = jax.jit(jax.vmap(jax.vmap(
            lambda k, nl, b: random_design(k, space, nl=nl, bounds=b),
            in_axes=(0, None, None)),
            in_axes=(0, None, None))) if n_imm else None
        body = _build_run(space, dims, idx, cfg, tech, n_isl=n_isl)
        if mesh is not None:
            P = PartitionSpec
            body = shard_map(
                body, mesh=mesh,
                # (key, pop0, arr, imm): key + spec arrays replicated,
                # population sharded on its leading axis, immigrants on
                # their per-generation axis 1
                in_specs=(P(), P(ISLAND_AXIS), P(),
                          P(None, ISLAND_AXIS) if n_imm else P()),
                # (pop, raw, sel, ev_designs, ev_raw, ev_feas, trace):
                # per-generation stacks shard on axis 1 (axis 0 is the
                # scan); the trace is computed over the gathered global
                # population, hence replicated
                out_specs=(P(ISLAND_AXIS), P(ISLAND_AXIS), P(ISLAND_AXIS),
                           P(None, ISLAND_AXIS), P(None, ISLAND_AXIS),
                           P(None, ISLAND_AXIS), P()),
                check_rep=False)
        _NSGA_CACHE[cache_key] = (
            jax.jit(body), imm_fn, n_imm, dict(executed=False))
    jitted, imm_fn, n_imm, state = _NSGA_CACHE[cache_key]

    def runner(key, pop0, arrays=None):
        arr = {k: jnp.asarray(v) for k, v in (arrays or spec.arrays).items()}
        k_run, k_imm = jax.random.split(jnp.asarray(key))
        imm = None
        if n_imm:
            kk = jax.random.split(k_imm, cfg.generations * n_imm)
            nl = jnp.sum(arr["loopmask"], axis=1).astype(jnp.int32)
            imm = imm_fn(kk.reshape(cfg.generations, n_imm, *kk.shape[1:]),
                         nl, arr["bounds"])
        out = jitted(k_run, pop0, arr, imm)
        state["executed"] = True
        return out

    # first-call attribution for the observability layer: a scan variant
    # that has never executed in this process pays XLA lowering on its
    # first call, which per-segment wall-clock must attribute separately
    # (the raw material for plan-cost estimates)
    runner.compile_state = state
    return runner


def make_nsga_fused(spec: SystemSpec, space: DesignSpace,
                    objectives: Tuple[str, ...] = METRIC_KEYS,
                    cfg: NSGAConfig = NSGAConfig(), tech=None,
                    lanes: int = 1):
    """Build a jitted MULTI-PROBLEM front explorer: the whole ``make_nsga``
    run vmapped over a stacked lane axis, so ``lanes`` independent
    populations — typically *different* problems whose spec arrays share
    one padded shape — evolve in one compiled dispatch.

    Returns ``run(keys, pops, arrays_seq)`` where ``keys`` is a sequence
    of ``lanes`` PRNG keys, ``pops`` a stacked design pytree of shape
    ``(lanes, cfg.pop, ...)`` and ``arrays_seq`` a sequence of ``lanes``
    spec-array dicts (equal shapes; e.g. each problem's ``spec.arrays``).
    Outputs match ``make_nsga`` with a leading lane axis.  Per-lane PRNG
    handling is identical to the single-lane runner (same split/fold
    chain), so lane ``i``'s results correspond exactly to an unbatched
    ``make_nsga(...)(keys[i], pops[i], arrays_seq[i])`` run.

    Compiled variants are cached per (statics, lanes); callers should
    pow2-pad the lane count (``quantize.bucket_lanes``) and discard the
    padding lanes' outputs, so a long-lived service compiles O(log(max
    batch)) fused variants.  Mutually exclusive with island sharding.
    """
    from ..core.constants import DEFAULT_TECH
    tech = tech or DEFAULT_TECH
    dims = (spec.W, spec.CH, spec.E)
    idx = tuple(METRIC_KEYS.index(o) for o in objectives)
    if not idx:
        raise ValueError("objectives must name at least one metric")
    if lanes < 1:
        raise ValueError("lanes must be >= 1")

    cache_key = _static_key(dims, idx, cfg, tech, space) + ("lanes", lanes)
    if cache_key not in _NSGA_CACHE:
        n_imm = int(round(cfg.pop * cfg.immigrants))
        imm_fn = jax.jit(jax.vmap(jax.vmap(
            lambda k, nl, b: random_design(k, space, nl=nl, bounds=b),
            in_axes=(0, None, None)),
            in_axes=(0, None, None))) if n_imm else None
        _NSGA_CACHE[cache_key] = (
            jax.jit(jax.vmap(_build_run(space, dims, idx, cfg, tech))),
            imm_fn, n_imm, dict(executed=False))
    jitted, imm_fn, n_imm, state = _NSGA_CACHE[cache_key]

    def runner(keys, pops, arrays_seq):
        if len(keys) != lanes or len(arrays_seq) != lanes:
            raise ValueError(f"expected {lanes} keys/array dicts")
        arr = {k: jnp.stack([jnp.asarray(a[k]) for a in arrays_seq])
               for k in arrays_seq[0]}
        k_runs, imms = [], []
        for i, key in enumerate(keys):
            # the exact single-lane key chain, per lane; immigrants are
            # drawn from lane i's OWN workload arrays, matching what an
            # unbatched run of that lane's problem would draw
            k_run, k_imm = jax.random.split(jnp.asarray(key))
            k_runs.append(k_run)
            if n_imm:
                kk = jax.random.split(k_imm, cfg.generations * n_imm)
                nl = jnp.sum(arr["loopmask"][i], axis=1).astype(jnp.int32)
                imms.append(imm_fn(
                    kk.reshape(cfg.generations, n_imm, *kk.shape[1:]),
                    nl, arr["bounds"][i]))
        imm = jax.tree.map(lambda *xs: jnp.stack(xs), *imms) \
            if n_imm else None
        out = jitted(jnp.stack(k_runs), pops, arr, imm)
        state["executed"] = True
        return out

    runner.compile_state = state
    return runner


def make_nsga_gated(spec: SystemSpec, space: DesignSpace,
                    objectives: Tuple[str, ...] = METRIC_KEYS,
                    cfg: NSGAConfig = NSGAConfig(), tech=None,
                    n_exact: int = 1, beta: float = 1.0,
                    tau: float = 1.0):
    """Build a SURROGATE-GATED front explorer: each generation produces
    the same ``cfg.pop`` candidate children as the plain scan (identical
    variation PRNG chain), but only the ``n_exact`` most promising —
    ranked by predicted-Pareto optimism over the surrogate ensemble's
    lower-confidence-bound objectives (mean − ``beta``·ensemble std,
    dominance-counted + crowding tie-broken) — get exact evaluations.
    Candidates whose normalized ensemble disagreement exceeds ``tau``
    are FORCED into the exact slots whatever their rank: the surrogate
    never silently decides where it is least sure.

    Returns ``run(key, pop0, sur, arrays=None)`` shaped like the
    ``make_nsga`` runner except ``ev_designs``/``ev_raw``/``ev_feas``
    stack (generations, n_exact, ...) — only exact evaluations are
    archive fodder — and ``trace`` gains ``forced_exact`` (G,) and
    ``disagreement`` (G,) gate telemetry.  ``sur`` is
    ``Surrogate.scan_arrays(embedding)``: ensemble weights ride as
    RUNTIME operands, so refitting the surrogate reuses the compiled
    scan (a new static_shape merely retraces).  Ranking happens in the
    surrogate's normalized output space (dominance is invariant under
    per-column positive affine maps) and knows nothing of feasibility
    penalties — infeasible optimists cost one exact evaluation and are
    then selected out exactly as in the plain scan.  Mutually exclusive
    with island sharding and megabatch fusion; ``surrogate=off`` paths
    never construct this runner."""
    from ..core.constants import DEFAULT_TECH
    tech = tech or DEFAULT_TECH
    dims = (spec.W, spec.CH, spec.E)
    idx = tuple(METRIC_KEYS.index(o) for o in objectives)
    if not idx:
        raise ValueError("objectives must name at least one metric")
    n_exact = min(max(int(n_exact), 1), cfg.pop)

    cache_key = _static_key(dims, idx, cfg, tech, space) + (
        "gate", n_exact, float(beta), float(tau))
    if cache_key not in _NSGA_CACHE:
        n_imm = int(round(cfg.pop * cfg.immigrants))
        imm_fn = jax.jit(jax.vmap(jax.vmap(
            lambda k, nl, b: random_design(k, space, nl=nl, bounds=b),
            in_axes=(0, None, None)),
            in_axes=(0, None, None))) if n_imm else None
        body = _build_run_gated(space, dims, idx, cfg, tech, n_exact,
                                float(beta), float(tau))
        _NSGA_CACHE[cache_key] = (
            jax.jit(body), imm_fn, n_imm, dict(executed=False))
    jitted, imm_fn, n_imm, state = _NSGA_CACHE[cache_key]

    def runner(key, pop0, sur, arrays=None):
        # the exact make_nsga key chain: gating changes WHICH children
        # get exact evaluations, never which children are generated
        arr = {k: jnp.asarray(v) for k, v in (arrays or spec.arrays).items()}
        k_run, k_imm = jax.random.split(jnp.asarray(key))
        imm = None
        if n_imm:
            kk = jax.random.split(k_imm, cfg.generations * n_imm)
            nl = jnp.sum(arr["loopmask"], axis=1).astype(jnp.int32)
            imm = imm_fn(kk.reshape(cfg.generations, n_imm, *kk.shape[1:]),
                         nl, arr["bounds"])
        out = jitted(k_run, pop0, arr, imm,
                     {k: jnp.asarray(v) for k, v in sur.items()})
        state["executed"] = True
        return out

    runner.compile_state = state
    runner.n_exact = n_exact
    return runner


_SUR_WEIGHT_KEYS = ("W1", "b1", "W2", "b2", "W3", "b3")


def _build_run_gated(space, dims, idx, cfg, tech, n_exact: int,
                     beta: float, tau: float):
    """The gated twin of ``_build_run`` (no islands, no migration): same
    variation and environmental-selection math, with the surrogate
    pre-filter between them."""
    N = cfg.pop
    obj_idx = jnp.asarray(idx, jnp.int32)
    pairs = objective_pairs(len(idx))
    hv_ref = jnp.asarray([HV_LOG_REF, HV_LOG_REF], F)

    def eval_one(d, arr):
        m = evaluate_arrays(arr, d, dims, tech)
        raw = metric_stack(m)
        p = feasibility_penalty(space, d, m)
        sel = log_metric_stack(m)[obj_idx] + 8.0 * jnp.log(p)
        return raw, sel, p <= 1.0 + 1e-6

    def eval_pop(pop, arr):
        return jax.vmap(lambda d: eval_one(d, arr))(pop)

    def crossover(key, a, b):
        ks = jax.random.split(key, len(_DESIGN_KEYS) + 1)
        out = {}
        for i, f in enumerate(_DESIGN_KEYS):
            take = jax.random.uniform(ks[i]) < cfg.crossover_rate
            if f == "placement" and cfg.pmx_placement:
                out[f] = jnp.where(take, pmx(ks[-1], a[f], b[f]), a[f])
            else:
                out[f] = jnp.where(take, b[f], a[f])
        return out

    n_imm = int(round(N * cfg.immigrants))

    def gate(sur, children):
        """Rank all N candidates on the surrogate, pick the ``n_exact``
        exact-evaluation slots: forced-by-disagreement first, then
        predicted-Pareto optimists."""
        X = jax.vmap(flatten_design)(children)              # (N, Dd)
        X = jnp.concatenate(
            [X, jnp.broadcast_to(sur["emb"], (N,) + sur["emb"].shape)],
            axis=1)
        Xn = (X - sur["x_mean"]) / sur["x_std"]

        def member(p):
            h = jnp.tanh(Xn @ p["W1"] + p["b1"])
            h = jnp.tanh(h @ p["W2"] + p["b2"])
            return h @ p["W3"] + p["b3"]

        out = jax.vmap(member)(
            {k: sur[k] for k in _SUR_WEIGHT_KEYS})          # (M, N, 4)
        mean_n = jnp.mean(out, 0)
        std_n = jnp.std(out, 0)
        dis = jnp.mean(std_n, axis=1)                       # (N,)
        # optimism: LCB dominance rank in normalized output space
        # (dominance is invariant under per-column positive affine maps)
        lcb = (mean_n - F(beta) * std_n)[:, obj_idx]
        ones = jnp.ones((N,), bool)
        nd = dominance_counts(lcb, ones)
        crowd = crowding_distance(lcb, ones)
        score = nd.astype(F) * F(1e6) - jnp.minimum(crowd, F(1e5))
        forced = dis > F(tau)
        score = jnp.where(forced, -F(BIG), score)
        order = jnp.argsort(score)[:n_exact]
        return order, jnp.sum(forced).astype(jnp.int32), jnp.mean(dis)

    def telemetry(sel_n, feas_n, cfeas, hv_run, best_run):
        finite = jnp.all(jnp.isfinite(sel_n), axis=-1)
        ok = finite & feas_n
        sane = jnp.where(jnp.isfinite(sel_n), sel_n, F(BIG))
        nd = dominance_counts(sane, ok)
        front_size = jnp.sum((nd == 0) & ok).astype(jnp.int32)
        hv_now = hv_run
        if pairs:
            hv_now = jnp.stack([
                hypervolume_2d_jit(sel_n[:, [i, j]], hv_ref, valid=ok)
                for i, j in pairs])
            hv_run = jnp.maximum(hv_run, hv_now)
        scal = jnp.where(finite, jnp.sum(sane, axis=-1), F(BIG))
        best_run = jnp.minimum(best_run, jnp.min(scal))
        tr = dict(front_size=front_size, hypervolume=hv_run, hv_now=hv_now,
                  best=best_run, feasible_frac=jnp.mean(cfeas.astype(F)))
        return hv_run, best_run, tr

    def step(arr, sur, carry, k, imm_g):
        pop, raw, sel, feas, hv_run, best_run = carry
        k_mate, k_cx, k_mut = jax.random.split(k, 3)
        nl = jnp.sum(arr["loopmask"], axis=1).astype(jnp.int32)

        # --- variation: IDENTICAL to the ungated scan (same PRNG uses)
        partners = jax.random.randint(k_mate, (N,), 0, N)
        mates = jax.tree.map(lambda x: x[partners], pop)
        children = jax.vmap(crossover)(jax.random.split(k_cx, N), pop, mates)
        for r in range(cfg.mutations):
            kr = jax.random.split(jax.random.fold_in(k_mut, r), N)
            children = jax.vmap(
                lambda kk, d: mutate(kk, d, space, cfg.fields,
                                     nl=nl, bounds=arr["bounds"]))(
                kr, children)
        if n_imm:
            children = jax.tree.map(
                lambda c, f: c.at[:n_imm].set(f), children, imm_g)

        # --- surrogate pre-filter: exact-evaluate only the chosen slots
        order, n_forced, dis_mean = gate(sur, children)
        picked = jax.tree.map(lambda x: x[order], children)
        craw, csel, cfeas = eval_pop(picked, arr)

        # --- environmental selection over the N + n_exact pool
        a_pop = jax.tree.map(lambda x, y: jnp.concatenate([x, y]),
                             pop, picked)
        a_raw = jnp.concatenate([raw, craw])
        a_sel = jnp.concatenate([sel, csel])
        a_feas = jnp.concatenate([feas, cfeas])
        finite = jnp.all(jnp.isfinite(a_sel), axis=-1)
        a_sane = jnp.where(jnp.isfinite(a_sel), a_sel, F(BIG))
        nd = dominance_counts(a_sane, finite)
        crowd = crowding_distance(a_sane, finite)
        keyv = jnp.where(finite,
                         nd.astype(F) * F(1e6) - jnp.minimum(crowd, F(1e5)),
                         F(BIG))
        order_s = jnp.argsort(keyv)[:N]
        pop_n = jax.tree.map(lambda x: x[order_s], a_pop)
        raw_n = a_raw[order_s]
        sel_n, feas_n = a_sel[order_s], a_feas[order_s]
        hv_run, best_run, tr = telemetry(sel_n, feas_n, cfeas,
                                         hv_run, best_run)
        tr["forced_exact"] = n_forced
        tr["disagreement"] = dis_mean
        return ((pop_n, raw_n, sel_n, feas_n, hv_run, best_run),
                (picked, craw, cfeas, tr))

    def run(key, pop0, arr, imm, sur):
        raw0 = jnp.full((N, len(METRIC_KEYS)), jnp.inf, F)
        sel0 = jnp.full((N, len(idx)), jnp.inf, F)
        feas0 = jnp.zeros((N,), bool)
        hv0 = jnp.zeros((len(pairs),), F)
        best0 = jnp.asarray(jnp.inf, F)
        keys = jax.random.split(key, cfg.generations)
        carry0 = (pop0, raw0, sel0, feas0, hv0, best0)
        ((pop, raw, sel, _feas, _hv, _best),
         (ev_designs, ev_raw, ev_feas, trace)) = jax.lax.scan(
            lambda c, xs: step(arr, sur, c, *xs), carry0, (keys, imm))
        return pop, raw, sel, ev_designs, ev_raw, ev_feas, trace

    return run


def _build_run(space, dims, idx, cfg, tech, n_isl: int = 1):
    # per-island population width; with n_isl == 1 (the unsharded path and
    # the 1-device mesh) every island construct below is STATICALLY
    # elided, so the built computation is exactly the historical one
    N = cfg.pop // n_isl
    n_mig = min(int(round(N * cfg.migration_frac)), N - 1) if n_isl > 1 \
        else 0
    mig_k = max(1, int(cfg.migration_interval))
    obj_idx = jnp.asarray(idx, jnp.int32)
    pairs = objective_pairs(len(idx))
    hv_ref = jnp.asarray([HV_LOG_REF, HV_LOG_REF], F)

    def eval_one(d, arr):
        m = evaluate_arrays(arr, d, dims, tech)
        raw = metric_stack(m)
        p = feasibility_penalty(space, d, m)
        sel = log_metric_stack(m)[obj_idx] + 8.0 * jnp.log(p)
        return raw, sel, p <= 1.0 + 1e-6       # feasible <=> no penalty

    def eval_pop(pop, arr):
        return jax.vmap(lambda d: eval_one(d, arr))(pop)

    def crossover(key, a, b):
        ks = jax.random.split(key, len(_DESIGN_KEYS) + 1)
        out = {}
        for i, f in enumerate(_DESIGN_KEYS):
            take = jax.random.uniform(ks[i]) < cfg.crossover_rate
            if f == "placement" and cfg.pmx_placement:
                # PMX keeps the child a valid permutation while actually
                # mixing both parents' placements (whole-field take can
                # only copy one of them)
                out[f] = jnp.where(take, pmx(ks[-1], a[f], b[f]), a[f])
            else:
                out[f] = jnp.where(take, b[f], a[f])
        return out

    n_imm = int(round(N * cfg.immigrants))

    def telemetry(sel_n, feas_n, cfeas, hv_run, best_run):
        """Per-generation convergence stats over the selected population —
        dominance/staircase math only, no design evaluations.  ``hv_now``
        (the instantaneous, non-running front hypervolume) is traced
        alongside the running max: it resolves WHEN quality arrived, the
        signal the transfer trust calibration regresses on.  Under island
        sharding the stats are computed over the all-gathered GLOBAL
        population (replicated on every device), so the trace means the
        same thing at any island count."""
        if n_isl > 1:
            sel_n = jax.lax.all_gather(sel_n, ISLAND_AXIS, tiled=True)
            feas_n = jax.lax.all_gather(feas_n, ISLAND_AXIS, tiled=True)
            cfeas = jax.lax.all_gather(cfeas, ISLAND_AXIS, tiled=True)
        finite = jnp.all(jnp.isfinite(sel_n), axis=-1)
        ok = finite & feas_n
        sane = jnp.where(jnp.isfinite(sel_n), sel_n, F(BIG))
        nd = dominance_counts(sane, ok)
        front_size = jnp.sum((nd == 0) & ok).astype(jnp.int32)
        hv_now = hv_run
        if pairs:
            hv_now = jnp.stack([
                hypervolume_2d_jit(sel_n[:, [i, j]], hv_ref, valid=ok)
                for i, j in pairs])
            hv_run = jnp.maximum(hv_run, hv_now)
        scal = jnp.where(finite, jnp.sum(sane, axis=-1), F(BIG))
        best_run = jnp.minimum(best_run, jnp.min(scal))
        tr = dict(front_size=front_size, hypervolume=hv_run, hv_now=hv_now,
                  best=best_run, feasible_frac=jnp.mean(cfeas.astype(F)))
        return hv_run, best_run, tr

    def step(arr, carry, k, imm_g, g):
        pop, raw, sel, feas, hv_run, best_run = carry
        k_mate, k_cx, k_mut = jax.random.split(k, 3)
        nl = jnp.sum(arr["loopmask"], axis=1).astype(jnp.int32)

        # --- variation: whole-field crossover with a random mate, then a
        # few chained single-field mutate moves (the SA neighborhood)
        partners = jax.random.randint(k_mate, (N,), 0, N)
        mates = jax.tree.map(lambda x: x[partners], pop)
        children = jax.vmap(crossover)(jax.random.split(k_cx, N), pop, mates)
        for r in range(cfg.mutations):
            kr = jax.random.split(jax.random.fold_in(k_mut, r), N)
            children = jax.vmap(
                lambda kk, d: mutate(kk, d, space, cfg.fields,
                                     nl=nl, bounds=arr["bounds"]))(
                kr, children)
        if n_imm:
            # random immigrants fight convergence collapse of the front
            children = jax.tree.map(
                lambda c, f: c.at[:n_imm].set(f), children, imm_g)
        craw, csel, cfeas = eval_pop(children, arr)

        # --- environmental selection over the 2N parent+child pool
        a_pop = jax.tree.map(lambda x, y: jnp.concatenate([x, y]),
                             pop, children)
        a_raw = jnp.concatenate([raw, craw])
        a_sel = jnp.concatenate([sel, csel])
        a_feas = jnp.concatenate([feas, cfeas])
        finite = jnp.all(jnp.isfinite(a_sel), axis=-1)
        a_sane = jnp.where(jnp.isfinite(a_sel), a_sel, F(BIG))
        nd = dominance_counts(a_sane, finite)
        crowd = crowding_distance(a_sane, finite)
        # ascending rank: fewer dominators first, crowding breaks ties;
        # non-finite rows sort last
        keyv = jnp.where(finite,
                         nd.astype(F) * F(1e6) - jnp.minimum(crowd, F(1e5)),
                         F(BIG))
        order = jnp.argsort(keyv)[:N]
        pop_n = jax.tree.map(lambda x: x[order], a_pop)
        raw_n = raw_n0 = a_raw[order]
        sel_n, feas_n = a_sel[order], a_feas[order]
        if n_mig:
            # --- island migration: the rank-sorted population's elite
            # head rotates one hop around the device ring; it replaces
            # the receiver's worst tail, but only on migration
            # generations (the ppermute itself runs unconditionally —
            # collectives must not hide inside lax.cond — and jnp.where
            # keeps or discards the migrants)
            do_mig = (g % mig_k) == (mig_k - 1)
            ring = [(i, (i + 1) % n_isl) for i in range(n_isl)]
            head = (jax.tree.map(lambda x: x[:n_mig], pop_n),
                    raw_n[:n_mig], sel_n[:n_mig], feas_n[:n_mig])
            r_pop, r_raw, r_sel, r_feas = jax.lax.ppermute(
                head, ISLAND_AXIS, ring)

            def splice(x, r):
                return jnp.concatenate(
                    [x[:N - n_mig], jnp.where(do_mig, r, x[N - n_mig:])])

            pop_n = jax.tree.map(splice, pop_n, r_pop)
            raw_n = splice(raw_n0, r_raw)
            sel_n = splice(sel_n, r_sel)
            feas_n = splice(feas_n, r_feas)
        hv_run, best_run, tr = telemetry(sel_n, feas_n, cfeas,
                                         hv_run, best_run)
        return ((pop_n, raw_n, sel_n, feas_n, hv_run, best_run),
                (children, craw, cfeas, tr))

    def run(key, pop0, arr, imm):
        # the initial population carries +inf objectives: its (variated)
        # offspring are evaluated in generation 0 and unevaluated parents
        # rank last.  Keeping ALL evaluation inside the scan body means the
        # (large) evaluate_arrays graph is compiled exactly once.
        raw0 = jnp.full((N, len(METRIC_KEYS)), jnp.inf, F)
        sel0 = jnp.full((N, len(idx)), jnp.inf, F)
        feas0 = jnp.zeros((N,), bool)
        hv0 = jnp.zeros((len(pairs),), F)
        best0 = jnp.asarray(jnp.inf, F)
        if n_isl > 1:
            # islands draw from diverged PRNG streams; skipped statically
            # at n_isl == 1 so the 1-device mesh replays the plain chain
            key = jax.random.fold_in(key, jax.lax.axis_index(ISLAND_AXIS))
        keys = jax.random.split(key, cfg.generations)
        gens = jnp.arange(cfg.generations, dtype=jnp.int32)
        carry0 = (pop0, raw0, sel0, feas0, hv0, best0)
        ((pop, raw, sel, _feas, _hv, _best),
         (ev_designs, ev_raw, ev_feas, trace)) = jax.lax.scan(
            lambda c, xs: step(arr, c, *xs), carry0, (keys, imm, gens))
        return pop, raw, sel, ev_designs, ev_raw, ev_feas, trace

    return run
