"""NSGA-II-style evolutionary front explorer (Gemini-style co-exploration).

Where ``repro.core.optimizer`` scalarizes the four objectives into one
number, this engine keeps the whole population nondominated-ranked and
returns a *front*.  The entire evolution is a single jitted ``lax.scan``
over vmapped populations:

    generation = variate (field crossover + ``encoding.mutate`` moves)
               -> evaluate (vmapped ``evaluate_arrays``)
               -> environmental selection over parents+children
                  (dominance counts, crowding-distance tie-break)

Evaluation and objectives are the same path the scalarized engines use
(``log_metric_stack`` + ``feasibility_penalty``), so a design judged good
here is good there and vice versa.  Compiled runners are cached on the
padded workload dims exactly like ``make_sa`` — every graph with equal
(W, CH, E) shares one compilation.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..core.encoding import (ALL_FIELDS, DesignSpace, feasibility_penalty,
                             mutate, random_design)
from ..core.evaluate import SystemSpec, evaluate_arrays
from ..core.optimizer import METRIC_KEYS, log_metric_stack, metric_stack
from .archive import (BIG, HV_LOG_REF, crowding_distance, dominance_counts,
                      hypervolume_2d_jit, objective_pairs)

F = jnp.float32

# design fields, in a fixed order, for the field-level crossover
_DESIGN_KEYS = ("shape", "spatial", "order", "tiling", "pipe", "logB",
                "packaging", "family", "placement")


@dataclasses.dataclass(frozen=True)
class NSGAConfig:
    pop: int = 64                 # population size (vmapped width)
    generations: int = 32         # scan length; evals = pop * generations
    fields: Tuple[str, ...] = ALL_FIELDS
    crossover_rate: float = 0.35  # per-field probability of taking the mate
    mutations: int = 2            # chained encoding.mutate moves per child
    immigrants: float = 0.125     # fraction of children replaced by fresh
    #                               random designs (keeps the front spread)
    pmx_placement: bool = False   # placement crossover MIXES both parents'
    #                               permutations (PMX) instead of taking one
    #                               wholesale — permutation validity kept


def pmx(key, a, b):
    """Partially-mapped crossover of two permutations (jit/vmap-safe).

    A random segment ``[lo, hi)`` of ``b`` is worked into a child that
    otherwise inherits ``a``: walking the segment, ``b[k]`` is swapped into
    position ``k`` (the classic in-place PMX formulation), so the result
    is always a valid permutation carrying ``b``'s segment and ``a``'s
    relative order elsewhere."""
    n = a.shape[0]
    k1, k2 = jax.random.split(jnp.asarray(key))
    i = jax.random.randint(k1, (), 0, n)
    j = jax.random.randint(k2, (), 0, n + 1)
    lo, hi = jnp.minimum(i, j), jnp.maximum(i, j)

    def body(k, child):
        def swap(c):
            v = b[k]
            pos = jnp.argmax(c == v)
            return c.at[pos].set(c[k]).at[k].set(v)
        return jax.lax.cond((k >= lo) & (k < hi), swap, lambda c: c, child)

    return jax.lax.fori_loop(0, n, body, a)


# compiled runners keyed like the SA cache: padded dims + static config
_NSGA_CACHE: dict = {}


def make_nsga(spec: SystemSpec, space: DesignSpace,
              objectives: Tuple[str, ...] = METRIC_KEYS,
              cfg: NSGAConfig = NSGAConfig(), tech=None):
    """Build a jitted front explorer.

    Returns ``run(key, pop0, arrays=None) ->
    (pop, raw, sel, ev_designs, ev_raw, ev_feas, trace)`` where ``pop0``
    is a stacked design pytree of width ``cfg.pop``; ``raw`` is the
    (pop, 4) matrix of raw metrics in ``METRIC_KEYS`` order and ``sel``
    the (pop, n_obj) penalized log-objectives selection ranked on.
    ``ev_designs`` / ``ev_raw`` / ``ev_feas`` are EVERY evaluated design
    of the run, stacked (generations, pop, ...) — the archive fodder:
    nothing the explorer paid for is thrown away.  ``ev_feas`` marks
    designs with no feasibility penalty; infeasible points may stay in
    the evolving population (the penalty steers them out) but must not be
    archived or served.  The population is elitist (nondominated parents
    survive unless crowd-pruned), so ``pop`` carries the running front;
    total evaluations = ``cfg.pop * cfg.generations``.

    ``trace`` is the per-generation convergence telemetry, scanned out of
    the same ``lax.scan`` with ZERO extra evaluations (pure dominance
    math over objective vectors the run already paid for): a dict of
    stacked arrays — ``front_size`` (G,) feasible nondominated count of
    the post-selection population, ``hypervolume`` (G, P) running
    (cumulative-best) 2-D hypervolume per objective pair over clipped
    log-metrics w.r.t. ``HV_LOG_REF`` (monotone non-decreasing by
    construction), ``best`` (G,) running best penalized scalarized
    objective (monotone non-increasing), and ``feasible_frac`` (G,) the
    feasible fraction of each generation's children.  Feed it to
    ``ConvergenceTrace.from_scan`` for the host-side view.
    """
    from ..core.constants import DEFAULT_TECH
    tech = tech or DEFAULT_TECH
    dims = (spec.W, spec.CH, spec.E)
    idx = tuple(METRIC_KEYS.index(o) for o in objectives)
    if not idx:
        raise ValueError("objectives must name at least one metric")

    cache_key = (dims, idx, cfg, tech, space.max_shape, space.max_logB,
                 space.max_total_pes, space.fixed_packaging,
                 space.fixed_family, space.allow_pipeline)
    if cache_key not in _NSGA_CACHE:
        n_imm = int(round(cfg.pop * cfg.immigrants))
        # immigrants are drawn OUTSIDE the scanned/jitted evolution (as a
        # scan input) — random_design's permutation sorts are expensive to
        # compile and belong in one small vmapped kernel, not in the body
        imm_fn = jax.jit(jax.vmap(jax.vmap(
            lambda k: random_design(k, space)))) if n_imm else None
        _NSGA_CACHE[cache_key] = (
            jax.jit(_build_run(space, dims, idx, cfg, tech)), imm_fn, n_imm,
            dict(executed=False))
    jitted, imm_fn, n_imm, state = _NSGA_CACHE[cache_key]

    def runner(key, pop0, arrays=None):
        arr = {k: jnp.asarray(v) for k, v in (arrays or spec.arrays).items()}
        k_run, k_imm = jax.random.split(jnp.asarray(key))
        imm = None
        if n_imm:
            kk = jax.random.split(k_imm, cfg.generations * n_imm)
            imm = imm_fn(kk.reshape(cfg.generations, n_imm, *kk.shape[1:]))
        out = jitted(k_run, pop0, arr, imm)
        state["executed"] = True
        return out

    # first-call attribution for the observability layer: a scan variant
    # that has never executed in this process pays XLA lowering on its
    # first call, which per-segment wall-clock must attribute separately
    # (the raw material for plan-cost estimates)
    runner.compile_state = state
    return runner


def _build_run(space, dims, idx, cfg, tech):
    N = cfg.pop
    obj_idx = jnp.asarray(idx, jnp.int32)
    pairs = objective_pairs(len(idx))
    hv_ref = jnp.asarray([HV_LOG_REF, HV_LOG_REF], F)

    def eval_one(d, arr):
        m = evaluate_arrays(arr, d, dims, tech)
        raw = metric_stack(m)
        p = feasibility_penalty(space, d, m)
        sel = log_metric_stack(m)[obj_idx] + 8.0 * jnp.log(p)
        return raw, sel, p <= 1.0 + 1e-6       # feasible <=> no penalty

    def eval_pop(pop, arr):
        return jax.vmap(lambda d: eval_one(d, arr))(pop)

    def crossover(key, a, b):
        ks = jax.random.split(key, len(_DESIGN_KEYS) + 1)
        out = {}
        for i, f in enumerate(_DESIGN_KEYS):
            take = jax.random.uniform(ks[i]) < cfg.crossover_rate
            if f == "placement" and cfg.pmx_placement:
                # PMX keeps the child a valid permutation while actually
                # mixing both parents' placements (whole-field take can
                # only copy one of them)
                out[f] = jnp.where(take, pmx(ks[-1], a[f], b[f]), a[f])
            else:
                out[f] = jnp.where(take, b[f], a[f])
        return out

    n_imm = int(round(N * cfg.immigrants))

    def telemetry(sel_n, feas_n, cfeas, hv_run, best_run):
        """Per-generation convergence stats over the selected population —
        dominance/staircase math only, no design evaluations.  ``hv_now``
        (the instantaneous, non-running front hypervolume) is traced
        alongside the running max: it resolves WHEN quality arrived, the
        signal the transfer trust calibration regresses on."""
        finite = jnp.all(jnp.isfinite(sel_n), axis=-1)
        ok = finite & feas_n
        sane = jnp.where(jnp.isfinite(sel_n), sel_n, F(BIG))
        nd = dominance_counts(sane, ok)
        front_size = jnp.sum((nd == 0) & ok).astype(jnp.int32)
        hv_now = hv_run
        if pairs:
            hv_now = jnp.stack([
                hypervolume_2d_jit(sel_n[:, [i, j]], hv_ref, valid=ok)
                for i, j in pairs])
            hv_run = jnp.maximum(hv_run, hv_now)
        scal = jnp.where(finite, jnp.sum(sane, axis=-1), F(BIG))
        best_run = jnp.minimum(best_run, jnp.min(scal))
        tr = dict(front_size=front_size, hypervolume=hv_run, hv_now=hv_now,
                  best=best_run, feasible_frac=jnp.mean(cfeas.astype(F)))
        return hv_run, best_run, tr

    def step(arr, carry, k, imm_g):
        pop, raw, sel, feas, hv_run, best_run = carry
        k_mate, k_cx, k_mut = jax.random.split(k, 3)
        nl = jnp.sum(arr["loopmask"], axis=1).astype(jnp.int32)

        # --- variation: whole-field crossover with a random mate, then a
        # few chained single-field mutate moves (the SA neighborhood)
        partners = jax.random.randint(k_mate, (N,), 0, N)
        mates = jax.tree.map(lambda x: x[partners], pop)
        children = jax.vmap(crossover)(jax.random.split(k_cx, N), pop, mates)
        for r in range(cfg.mutations):
            kr = jax.random.split(jax.random.fold_in(k_mut, r), N)
            children = jax.vmap(
                lambda kk, d: mutate(kk, d, space, cfg.fields,
                                     nl=nl, bounds=arr["bounds"]))(
                kr, children)
        if n_imm:
            # random immigrants fight convergence collapse of the front
            children = jax.tree.map(
                lambda c, f: c.at[:n_imm].set(f), children, imm_g)
        craw, csel, cfeas = eval_pop(children, arr)

        # --- environmental selection over the 2N parent+child pool
        a_pop = jax.tree.map(lambda x, y: jnp.concatenate([x, y]),
                             pop, children)
        a_raw = jnp.concatenate([raw, craw])
        a_sel = jnp.concatenate([sel, csel])
        a_feas = jnp.concatenate([feas, cfeas])
        finite = jnp.all(jnp.isfinite(a_sel), axis=-1)
        a_sane = jnp.where(jnp.isfinite(a_sel), a_sel, F(BIG))
        nd = dominance_counts(a_sane, finite)
        crowd = crowding_distance(a_sane, finite)
        # ascending rank: fewer dominators first, crowding breaks ties;
        # non-finite rows sort last
        keyv = jnp.where(finite,
                         nd.astype(F) * F(1e6) - jnp.minimum(crowd, F(1e5)),
                         F(BIG))
        order = jnp.argsort(keyv)[:N]
        sel_n, feas_n = a_sel[order], a_feas[order]
        hv_run, best_run, tr = telemetry(sel_n, feas_n, cfeas,
                                         hv_run, best_run)
        return ((jax.tree.map(lambda x: x[order], a_pop),
                 a_raw[order], sel_n, feas_n, hv_run, best_run),
                (children, craw, cfeas, tr))

    def run(key, pop0, arr, imm):
        # the initial population carries +inf objectives: its (variated)
        # offspring are evaluated in generation 0 and unevaluated parents
        # rank last.  Keeping ALL evaluation inside the scan body means the
        # (large) evaluate_arrays graph is compiled exactly once.
        raw0 = jnp.full((N, len(METRIC_KEYS)), jnp.inf, F)
        sel0 = jnp.full((N, len(idx)), jnp.inf, F)
        feas0 = jnp.zeros((N,), bool)
        hv0 = jnp.zeros((len(pairs),), F)
        best0 = jnp.asarray(jnp.inf, F)
        keys = jax.random.split(key, cfg.generations)
        carry0 = (pop0, raw0, sel0, feas0, hv0, best0)
        ((pop, raw, sel, _feas, _hv, _best),
         (ev_designs, ev_raw, ev_feas, trace)) = jax.lax.scan(
            lambda c, xs: step(arr, c, *xs), carry0, (keys, imm))
        return pop, raw, sel, ev_designs, ev_raw, ev_feas, trace

    return run
