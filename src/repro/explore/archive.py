"""Pareto archive: the canonical dominance math + a fixed-capacity,
jit-compatible nondominated archive with a persistent on-disk cache.

This module is deliberately standalone (jax/numpy plus the equally
dependency-free ``repro.obs`` tracing layer — no ``repro.core`` imports)
so both the optimizer (``repro.core.optimizer``) and the benchmark
suite can use one dominance convention without import cycles:

    a dominates b  <=>  all(a <= b) and any(a < b)      (all minimized)

Layers:

* ``pareto_front`` / ``dominance_counts`` / ``crowding_distance`` — the
  vectorized dominance primitives (vmapped O(n^2) comparisons; each
  insertion is a single fused comparison against the whole archive).
* ``ParetoArchive`` — fixed-capacity archive over stacked design pytrees
  plus an (n, k) objective matrix.  Insertion concatenates the batch,
  recomputes the nondominated mask and prunes to capacity by crowding
  distance (boundary points carry infinite crowding, so extremes survive).
* ``spec_space_key`` / ``save`` / ``load`` — persistence keyed by a
  canonical hash of the (SystemSpec, DesignSpace) pair, so a re-run of the
  same exploration problem warm-starts from disk instead of recomputing.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import threading
import warnings
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..obs import trace as obs

F = jnp.float32
BIG = 1e30         # sentinel objective for invalid / non-finite rows

# shared log-space hypervolume reference: all convergence telemetry (the
# in-scan NSGA trace and the archive-projected plateau checks) measures
# 2-D hypervolume over clipped log-metrics against (HV_LOG_REF,)*2, so
# values are directly comparable across generations, scan segments and
# the host/device implementations.  e^41 ~ 6e17 comfortably exceeds every
# feasible raw metric; points beyond the reference contribute nothing.
HV_LOG_REF = 41.0


# ---------------------------------------------------------------------------
# dominance primitives (host + jit variants share one convention)
# ---------------------------------------------------------------------------
def pareto_front(points) -> List[int]:
    """Indices of the Pareto-optimal rows of an (n, k) objective array
    (all objectives minimized).  Duplicate points are all kept — neither
    strictly dominates the other.  This is THE canonical implementation;
    ``repro.core.optimizer.pareto_front`` and ``benchmarks.bench_pareto``
    both delegate here."""
    pts = np.asarray(points, np.float64)
    if pts.ndim == 1:
        pts = pts[:, None]
    n = len(pts)
    if n == 0:
        return []
    le = np.all(pts[:, None, :] <= pts[None, :, :], axis=-1)   # le[i,j]: i<=j
    lt = np.any(pts[:, None, :] < pts[None, :, :], axis=-1)
    dominated = np.any(le & lt, axis=0)                        # any i dom j
    return [int(i) for i in np.flatnonzero(~dominated)]


def dominates(a, b):
    """True iff point ``a`` dominates ``b`` (jnp, all minimized)."""
    return jnp.all(a <= b) & jnp.any(a < b)


# pools at least this large route dominance counting through the tiled
# ``kernels/pareto_rank`` dispatcher (Pallas on TPU / interpret mode, the
# identical jnp math elsewhere) instead of materializing the fused
# (n, n, k) comparison in one shot — the only O(n^2) step in selection
_PARETO_RANK_MIN_N = int(os.environ.get("REPRO_PARETO_RANK_MIN_N", "128"))


def dominance_counts(objs, valid):
    """(n,) number of *valid* points dominating each row of ``objs`` (n, k).
    Zero => nondominated.  One fused (n, n, k) comparison — the vmapped
    'O(1) scans' insertion primitive — below ``_PARETO_RANK_MIN_N``; the
    tiled ``pareto_rank`` kernel above it.  Every ranking consumer (NSGA
    environmental selection, ``ParetoArchive.insert``) funnels through
    here, so the kernel serves the whole search path."""
    n = int(objs.shape[0])
    if n >= _PARETO_RANK_MIN_N:
        # local import: the kernel layer is optional compute, and this
        # module stays importable standalone
        from ..kernels.pareto_rank.ops import \
            dominance_counts as _tiled_counts
        return _tiled_counts(objs, valid)
    le = jnp.all(objs[:, None, :] <= objs[None, :, :], axis=-1)
    lt = jnp.any(objs[:, None, :] < objs[None, :, :], axis=-1)
    dom = le & lt & valid[:, None]
    return jnp.sum(dom, axis=0)


def crowding_distance(objs, valid):
    """NSGA-II crowding distance over the ``valid`` subset of ``objs`` (n, k).
    Boundary points (per-objective min/max among valid rows) get +inf;
    invalid rows get 0.  jit/vmap-safe (fixed shapes, argsort-based)."""
    n = objs.shape[0]
    nv = jnp.sum(valid)

    def per_objective(col):
        c = jnp.where(valid, col, jnp.inf)         # invalid rows sort last
        order = jnp.argsort(c)
        s = c[order]
        lo = s[0]
        hi = s[jnp.clip(nv - 1, 0, n - 1)]
        rng = jnp.maximum(hi - lo, 1e-12)
        prev = jnp.concatenate([s[:1], s[:-1]])
        nxt = jnp.concatenate([s[1:], s[-1:]])
        i = jnp.arange(n)
        gap = (nxt - prev) / rng
        gap = jnp.where((i == 0) | (i == nv - 1), jnp.inf, gap)
        gap = jnp.where(i < nv, gap, 0.0)
        return jnp.zeros(n, F).at[order].set(gap.astype(F))

    return jnp.sum(jax.vmap(per_objective, in_axes=1, out_axes=1)(
        objs.astype(F)), axis=1)


def hypervolume_2d(points, ref) -> float:
    """Exact 2-D hypervolume (area dominated w.r.t. ``ref``, both objectives
    minimized).  Non-finite points and points not dominating ``ref`` are
    ignored; dominated points contribute nothing."""
    pts = np.asarray(points, np.float64).reshape(-1, 2)
    ref = np.asarray(ref, np.float64)
    ok = np.all(np.isfinite(pts), axis=1) & np.all(pts < ref[None, :], axis=1)
    pts = pts[ok]
    if len(pts) == 0:
        return 0.0
    pts = pts[np.argsort(pts[:, 0], kind="stable")]
    hv, ymin = 0.0, ref[1]
    for x, y in pts:
        if y < ymin:
            hv += (ref[0] - x) * (ymin - y)
            ymin = y
    return float(hv)


def hypervolume_2d_jit(points, ref, valid=None):
    """jit/vmap-safe exact 2-D hypervolume (both objectives minimized).

    Same staircase as ``hypervolume_2d`` but fixed-shape jnp: filtered
    points (non-finite, not dominating ``ref``, or masked out by
    ``valid``) are moved onto the reference point where they contribute
    zero area.  Used by the NSGA scan body to trace per-generation front
    hypervolume with no host round-trip and no extra evaluations."""
    pts = jnp.asarray(points, F).reshape(-1, 2)
    ref = jnp.asarray(ref, F).reshape(2)
    ok = jnp.all(jnp.isfinite(pts), axis=1) & jnp.all(pts < ref[None, :],
                                                     axis=1)
    if valid is not None:
        ok = ok & jnp.asarray(valid, bool)
    x = jnp.where(ok, pts[:, 0], ref[0])
    y = jnp.where(ok, pts[:, 1], ref[1])
    order = jnp.argsort(x)
    xs, ys = x[order], y[order]
    # running staircase minimum BEFORE each point (ref height to start)
    ymin_prev = jnp.concatenate([ref[1:2], jax.lax.cummin(ys)[:-1]])
    return jnp.sum((ref[0] - xs) * jnp.maximum(ymin_prev - ys, 0.0))


def objective_pairs(n: int) -> Tuple[Tuple[int, int], ...]:
    """All C(n, 2) index pairs (i < j) — the 2-D hypervolume projections
    traced for an ``n``-objective exploration.  Empty for n < 2."""
    return tuple((i, j) for i in range(n) for j in range(i + 1, n))


# ---------------------------------------------------------------------------
# convergence telemetry (shared by repro.explore.nsga / .service and the
# scalarized repro.core.optimizer loop)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ConvergenceTrace:
    """Per-generation convergence telemetry of one search run.

    All arrays are indexed by generation (length ``G``).  ``hypervolume``
    carries one column per objective *pair* (``pairs`` labels them): the
    running (cumulative-best) 2-D hypervolume of the population's feasible
    front over clipped log-metrics w.r.t. ``(HV_LOG_REF,)*2`` — monotone
    non-decreasing by construction, so a plateau is a genuine convergence
    signal rather than crowding-pruning noise.  ``best`` is the running
    best penalized scalarized objective (monotone non-increasing).
    ``archive_hv`` (optional, one row per scan *segment*) is the
    archive-projected hypervolume the service's plateau detector ranks on.
    ``hv_gen`` (optional) is the *instantaneous* (non-cumulative) front
    hypervolume of each generation's population — unlike the running
    ``hypervolume`` it resolves WHEN quality arrived, which is what the
    transfer trust calibration measures (a seeded run front-loads its
    gains into the earliest generations).
    """
    objectives: Tuple[str, ...]
    pairs: Tuple[Tuple[str, str], ...]
    front_size: np.ndarray          # (G,) population front size
    hypervolume: np.ndarray         # (G, P) running log-space hv per pair
    best: np.ndarray                # (G,) running best scalarized objective
    feasible_frac: np.ndarray       # (G,) feasible fraction of the children
    n_evals: np.ndarray             # (G,) cumulative evaluations
    archive_hv: Optional[np.ndarray] = None     # (S, P) per scan segment
    hv_gen: Optional[np.ndarray] = None         # (G, P) instantaneous per
    #                                 generation (not running max)

    def __post_init__(self):
        self.objectives = tuple(self.objectives)
        self.pairs = tuple(tuple(p) for p in self.pairs)

    @property
    def generations(self) -> int:
        return len(self.front_size)

    @classmethod
    def from_scan(cls, objectives: Sequence[str], scan_trace: Dict,
                  evals_per_generation: int) -> "ConvergenceTrace":
        """Adopt the stacked (G, ...) telemetry a ``make_nsga`` run scanned
        out (zero extra evaluations were spent producing it)."""
        objectives = tuple(objectives)
        g = np.asarray(scan_trace["front_size"]).shape[0]
        return cls(
            objectives=objectives,
            pairs=tuple((objectives[i], objectives[j])
                        for i, j in objective_pairs(len(objectives))),
            front_size=np.asarray(scan_trace["front_size"], np.int64),
            hypervolume=np.asarray(scan_trace["hypervolume"], np.float64),
            best=np.asarray(scan_trace["best"], np.float64),
            feasible_frac=np.asarray(scan_trace["feasible_frac"],
                                     np.float64),
            n_evals=(np.arange(g, dtype=np.int64) + 1)
            * int(evals_per_generation),
            hv_gen=(np.asarray(scan_trace["hv_now"], np.float64)
                    if "hv_now" in scan_trace else None))

    @classmethod
    def from_history(cls, history: Sequence, evals_per_step: int = 1,
                     objectives: Sequence[str] = ("objective",)
                     ) -> "ConvergenceTrace":
        """Adapt a scalarized engine's ``(iteration, best)`` history (the
        BO x SA loop tracks one incumbent, so ``front_size`` is 1 and there
        are no hypervolume pairs)."""
        vals = [float(v) for i, v in history
                if isinstance(i, (int, np.integer))]
        g = len(vals)
        best = (np.minimum.accumulate(np.asarray(vals, np.float64))
                if g else np.zeros(0))
        return cls(objectives=tuple(objectives), pairs=(),
                   front_size=np.ones(g, np.int64),
                   hypervolume=np.zeros((g, 0)),
                   best=best, feasible_frac=np.ones(g),
                   n_evals=(np.arange(g, dtype=np.int64) + 1)
                   * int(evals_per_step))

    def extend(self, other: "ConvergenceTrace") -> "ConvergenceTrace":
        """Concatenate a follow-on segment: evaluation counts accumulate,
        and the running hv / best stay monotone across the seam."""
        if other.objectives != self.objectives:
            raise ValueError("cannot extend a trace across objective sets")
        off = int(self.n_evals[-1]) if len(self.n_evals) else 0
        cat = lambda a, b: np.concatenate([np.asarray(a), np.asarray(b)])
        hv = np.maximum.accumulate(
            cat(self.hypervolume, other.hypervolume), axis=0)
        ahv = [a for a in (self.archive_hv, other.archive_hv)
               if a is not None]
        hvg = [a for a in (self.hv_gen, other.hv_gen) if a is not None]
        return ConvergenceTrace(
            objectives=self.objectives, pairs=self.pairs,
            front_size=cat(self.front_size, other.front_size),
            hypervolume=hv,
            best=np.minimum.accumulate(cat(self.best, other.best)),
            feasible_frac=cat(self.feasible_frac, other.feasible_frac),
            n_evals=cat(self.n_evals, np.asarray(other.n_evals) + off),
            archive_hv=np.concatenate(ahv, axis=0) if ahv else None,
            hv_gen=np.concatenate(hvg, axis=0) if hvg else None)

    def summary(self) -> Dict:
        """JSON-serializable digest persisted alongside the archive npz."""
        g = self.generations
        return dict(
            generations=int(g),
            n_evals=int(self.n_evals[-1]) if g else 0,
            objectives=list(self.objectives),
            pairs=[list(p) for p in self.pairs],
            front_size_final=int(self.front_size[-1]) if g else 0,
            hypervolume_final=[float(v) for v in self.hypervolume[-1]]
            if g else [],
            best_final=float(self.best[-1]) if g else None,
            feasible_frac_mean=float(np.mean(self.feasible_frac))
            if g else 0.0)


# ---------------------------------------------------------------------------
# crash-safe npz persistence (archives + the cross-spec manifest)
# ---------------------------------------------------------------------------
def atomic_savez(path, **arrays) -> Path:
    """``np.savez_compressed`` through a same-directory temp file and an
    atomic ``os.replace``: a crash or kill mid-write leaves the previous
    file (or nothing) in place, never a truncated npz.  The temp file is
    opened explicitly so numpy cannot append a second ``.npz`` suffix."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_name(
        f".{path.name}.tmp{os.getpid()}.{threading.get_ident()}")
    try:
        with open(tmp, "wb") as f:
            np.savez_compressed(f, **arrays)
        os.replace(tmp, path)
    finally:
        tmp.unlink(missing_ok=True)
    return path


# ---------------------------------------------------------------------------
# jit-compatible archive update
# ---------------------------------------------------------------------------
def _sanitize(objs):
    return jnp.where(jnp.isfinite(objs), objs.astype(F), F(BIG))


@jax.jit
def _archive_update(objs, valid, designs, new_objs, new_valid, new_designs):
    """Merge a batch into the archive state and prune to capacity.

    All shapes static (capacity from ``objs.shape[0]``, batch from
    ``new_objs.shape[0]``); one call = one vmapped dominance pass over
    archive+batch, so insertion cost is independent of insertion history."""
    cap = objs.shape[0]
    a_objs = jnp.concatenate([objs, _sanitize(new_objs)], axis=0)
    a_valid = jnp.concatenate([valid, new_valid], axis=0)
    a_valid = a_valid & jnp.all(a_objs < BIG, axis=-1)
    a_designs = jax.tree.map(
        lambda x, y: jnp.concatenate([x, y], axis=0), designs, new_designs)

    nd = dominance_counts(a_objs, a_valid)
    front = (nd == 0) & a_valid
    crowd = crowding_distance(a_objs, front)
    # ranking (ascending): nondominated by descending crowding (boundary
    # points carry inf crowding => kept first), then dominated/invalid rows.
    keyv = jnp.where(front, -jnp.minimum(crowd, F(1e9)),
                     F(BIG) + nd.astype(F))
    order = jnp.argsort(keyv)[:cap]
    return (a_objs[order], front[order],
            jax.tree.map(lambda x: x[order], a_designs))


def flatten_design(design: Dict) -> jnp.ndarray:
    """One design pytree -> a flat float32 feature vector, leaves raveled
    in CANONICAL sorted-key order.  jit/vmap-safe (shape is static per
    design template).  This layout IS the surrogate dataset contract:
    ``ParetoArchive.export_rows`` emits training rows in exactly this
    order, and the gated NSGA scan encodes candidates with this function
    — the two must never diverge."""
    return jnp.concatenate([jnp.ravel(jnp.asarray(design[k])).astype(F)
                            for k in sorted(design)])


def design_encoding_dim(template: Dict) -> int:
    """Length of ``flatten_design`` output for one design template."""
    return int(sum(np.asarray(v).size for v in template.values()))


class ParetoArchive:
    """Fixed-capacity nondominated archive over stacked design pytrees.

    ``template`` is one design point (a dict of arrays) fixing the leaf
    shapes/dtypes; objectives are an (n, ``n_obj``) matrix, all minimized.
    After every ``insert`` the archive contains only mutually nondominated
    points (capacity permitting — overflow is pruned by crowding distance,
    which always preserves per-objective boundary points)."""

    def __init__(self, capacity: int, template: Dict, n_obj: int = 4,
                 obj_keys: Optional[Sequence[str]] = None):
        self.capacity = int(capacity)
        self.n_obj = int(n_obj)
        self.obj_keys = tuple(obj_keys) if obj_keys else None
        self.objs = np.full((capacity, n_obj), BIG, np.float32)
        self.valid = np.zeros(capacity, bool)
        self.designs = {
            k: np.zeros((capacity,) + np.asarray(v).shape,
                        np.asarray(v).dtype)
            for k, v in template.items()}
        self.n_evals = 0            # total evaluations recorded against this
        #                             archive (cache-freshness metadata)
        self.searched = ()          # objective names search effort was ever
        #                             spent on (cache-coverage metadata)
        self.budget_covered = 0     # largest query budget this archive has
        #                             answered: plateau early-stopping may
        #                             spend FEWER than ``n_evals`` requested
        #                             evaluations, yet the query counts as
        #                             covered (the front had converged)
        self.trace_summary = {}     # last refinement's ConvergenceTrace
        #                             .summary(), persisted for dashboards

    def __len__(self) -> int:
        return int(self.valid.sum())

    def insert(self, designs: Dict, objs, mask=None, count_evals=True):
        """Insert a stacked batch: ``designs`` leaves (m, ...), ``objs``
        (m, n_obj).  Non-finite objective rows are dropped."""
        objs = jnp.asarray(objs, F).reshape(-1, self.n_obj)
        m = objs.shape[0]
        new_valid = (jnp.ones(m, bool) if mask is None
                     else jnp.asarray(mask, bool))
        new_designs = {k: jnp.asarray(v).reshape((m,) + self.designs[k].shape[1:])
                       for k, v in designs.items()}
        o, v, d = _archive_update(
            jnp.asarray(self.objs), jnp.asarray(self.valid),
            {k: jnp.asarray(v) for k, v in self.designs.items()},
            objs, new_valid, new_designs)
        self.objs = np.asarray(o)
        self.valid = np.asarray(v)
        self.designs = {k: np.asarray(x) for k, x in d.items()}
        if count_evals:
            self.n_evals += int(m)
        return self

    def merge(self, other: "ParetoArchive") -> "ParetoArchive":
        """Fold another archive of the SAME problem into this one — the
        reload-under-lock half of the shared-cache write path: a writer
        about to ``save`` merges whatever a peer process put on disk
        since it last loaded, so concurrent refinements union instead of
        last-``os.replace``-wins.

        Only rows not already present are inserted (exact objective-row
        bytes; nondominated duplicates would otherwise coexist, since
        neither dominates the other), with ``count_evals=False`` — the
        evaluations behind ``other``'s rows were counted by the process
        that paid for them.  Counters take the element-wise max (both
        sides descend from a common disk state, so max is the tightest
        merge that never *under*-reports coverage), ``searched`` is the
        union."""
        if set(other.designs) != set(self.designs):
            raise ValueError("cannot merge archives of different design "
                             "templates")
        have = {r.tobytes() for r in self.objs[self.valid]}
        sel = np.flatnonzero(other.valid)
        sel = np.asarray([i for i in sel
                          if other.objs[i].tobytes() not in have], int)
        if sel.size:
            self.insert({k: v[sel] for k, v in other.designs.items()},
                        other.objs[sel], count_evals=False)
        self.n_evals = max(self.n_evals, other.n_evals)
        self.budget_covered = max(self.budget_covered, other.budget_covered)
        self.searched = tuple(sorted(set(self.searched)
                                     | set(other.searched)))
        if not self.trace_summary:
            self.trace_summary = dict(other.trace_summary)
        return self

    def front(self) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
        """(stacked designs of the valid rows, their (n, n_obj) objectives)."""
        sel = np.flatnonzero(self.valid)
        return ({k: v[sel] for k, v in self.designs.items()},
                self.objs[sel].astype(np.float64))

    def export_rows(self) -> Tuple[np.ndarray, np.ndarray]:
        """Surrogate training rows from this archive: ``(X, Y)`` where
        ``X`` is the (n, D) float32 matrix of flattened design encodings
        (``flatten_design`` layout — canonical sorted-key order) of every
        valid row and ``Y`` the matching (n, n_obj) float64 raw-metric
        matrix.  Every evaluation the fleet ever archived is a free
        labelled example; cold archives export ``(0, D)``/``(0, n_obj)``
        so callers can concatenate unconditionally."""
        D = design_encoding_dim({k: v[0] for k, v in self.designs.items()})
        sel = np.flatnonzero(self.valid)
        if not sel.size:
            return (np.zeros((0, D), np.float32),
                    np.zeros((0, self.n_obj), np.float64))
        X = np.stack([
            np.concatenate([np.ravel(self.designs[k][i]).astype(np.float32)
                            for k in sorted(self.designs)])
            for i in sel])
        return X, self.objs[sel].astype(np.float64)

    def projected_hypervolume(self, pair: Tuple[int, int],
                              ref: float = HV_LOG_REF) -> float:
        """2-D hypervolume of the archived front projected onto a pair of
        objective columns, over clipped log-metrics w.r.t. ``(ref, ref)`` —
        the same scale the NSGA scan traces, so the service's plateau
        detector compares archive state across scan segments directly."""
        i, j = pair
        pts = self.objs[self.valid][:, [i, j]].astype(np.float64)
        return hypervolume_2d(np.log(np.maximum(pts, 1e-3)), (ref, ref))

    # ---- persistence -------------------------------------------------------
    def save(self, path) -> Path:
        meta = dict(capacity=self.capacity, n_obj=self.n_obj,
                    n_evals=self.n_evals, searched=list(self.searched),
                    obj_keys=list(self.obj_keys or ()),
                    budget_covered=self.budget_covered,
                    trace_summary=self.trace_summary)
        with obs.span("archive.save", key=Path(path).stem,
                      n_front=len(self)):
            return atomic_savez(
                path, __meta=np.frombuffer(
                    json.dumps(meta).encode(), dtype=np.uint8),
                objs=self.objs, valid=self.valid,
                **{f"d_{k}": v for k, v in self.designs.items()})

    @classmethod
    def load(cls, path) -> "ParetoArchive":
        with obs.span("archive.load", key=Path(path).stem), \
                np.load(Path(path)) as z:
            meta = json.loads(bytes(z["__meta"]).decode())
            designs = {k[2:]: z[k] for k in z.files if k.startswith("d_")}
            template = {k: v[0] for k, v in designs.items()}
            arc = cls(meta["capacity"], template, n_obj=meta["n_obj"],
                      obj_keys=meta["obj_keys"] or None)
            arc.objs = z["objs"].copy()
            arc.valid = z["valid"].copy()
            arc.designs = {k: v.copy() for k, v in designs.items()}
            arc.n_evals = int(meta["n_evals"])
            arc.searched = tuple(meta.get("searched", ()))
            # archives written before budget accounting: evaluations
            # recorded then were always full-budget spends
            arc.budget_covered = int(meta.get("budget_covered",
                                              meta["n_evals"]))
            arc.trace_summary = dict(meta.get("trace_summary", {}))
        return arc


# ---------------------------------------------------------------------------
# canonical (SystemSpec, DesignSpace) hashing for the on-disk cache
# ---------------------------------------------------------------------------
def spec_space_key(spec, space, extra=None) -> str:
    """Stable content hash of an exploration problem: the padded workload
    arrays plus every static ``DesignSpace`` bound.  Equal workload graphs
    explored under equal bounds share one archive file, whatever Python
    objects they were built from.  ``extra`` folds any further
    cache-identity into the key; callers pass a STABLE string digest — the
    evaluator's tech identity is ``core.constants.tech_key(tech)``, never
    the object's ``repr`` (see ``ExplorationService.problem_key`` /
    ``Session._cache_key``).  Duck-typed so this module stays free of
    ``repro.core`` imports."""
    h = hashlib.sha256()
    if extra is not None:
        h.update(repr(extra).encode())
    h.update(repr((int(spec.W), int(spec.CH), int(spec.E))).encode())
    for k in sorted(spec.arrays):
        a = np.asarray(spec.arrays[k])
        h.update(k.encode())
        h.update(str(a.dtype).encode())
        h.update(repr(a.shape).encode())
        h.update(a.tobytes())
    h.update(repr((tuple(space.max_shape), int(space.max_logB),
                   int(space.max_total_pes), int(space.fixed_packaging),
                   int(space.fixed_family),
                   bool(space.allow_pipeline))).encode())
    return h.hexdigest()[:20]


# ---------------------------------------------------------------------------
# cross-spec archive manifest: the nearest-neighbor index over every cached
# exploration problem, keyed by workload-feature embedding
# ---------------------------------------------------------------------------
MANIFEST_NAME = "manifest.npz"


@dataclasses.dataclass(frozen=True)
class ManifestPolicy:
    """Growth policy of the cross-spec manifest index.

    ``max_entries`` bounds the index: past it, the least-recently-*used*
    entry (lowest ``last_used`` tick; transfer lookups and refreshes both
    count as use) is evicted — index entries only, the archive npz files
    they pointed at stay on disk and are re-indexed on their next use.
    ``dedup_radius`` > 0 merges entries whose embeddings are within that
    Euclidean distance (the better-explored twin survives, counters are
    merged), so a fleet cache full of near-identical problems does not
    crowd genuinely different neighbors out of ``nearest``.
    ``max_trust_records`` bounds the per-(src, dst) transfer-outcome table
    (oldest records dropped first).
    ``reap_evicted_after`` > 0 opts into archive-file GC: an archive npz
    whose manifest entry stayed evicted (LRU-evicted or dedup-merged away,
    and never re-indexed) for that many LRU ticks is deleted from disk at
    the next ``reap_evicted`` sweep.  The default 0 keeps the historic
    behavior — eviction bounds the index only, files stay."""
    max_entries: int = 64
    dedup_radius: float = 0.0
    max_trust_records: int = 256
    reap_evicted_after: int = 0


@dataclasses.dataclass(frozen=True)
class TrustModel:
    """Ridge regression ``lift ~ w0 + w . |embedding delta|`` fitted over
    recorded transfer outcomes: how much of a seeded run's hypervolume
    gain arrived in its earliest generations, as a function of how far the
    seed's source workload sat from the destination in embedding space.
    ``predict`` returns the expected lift for a candidate (src, dst) pair;
    callers treat larger as more trustworthy (clamping at 0)."""
    weights: np.ndarray                # (D + 1,) intercept first

    def predict(self, delta) -> float:
        d = np.abs(np.asarray(delta, np.float64).ravel())
        if d.shape[0] + 1 != self.weights.shape[0]:
            return 0.0                 # embedding layout drifted: neutral
        # clamp at 0, as promised: a linear extrapolation far outside the
        # fitted delta range can go arbitrarily negative, and consumers
        # divide distances by (1 + lift) — a lift <= -1 would flip or
        # explode the ranking instead of merely zeroing the reweighting
        return float(max(self.weights[0] + self.weights[1:] @ d, 0.0))


def fit_trust_model(records: Sequence[Dict], dim: Optional[int] = None,
                    ridge: float = 1.0,
                    min_records: int = 3) -> Optional[TrustModel]:
    """Fit a ``TrustModel`` over transfer-outcome records (dicts with
    ``delta`` (D,) and ``lift`` float).  Records whose delta dimension
    disagrees with ``dim`` (default: the *modal* dimension across the
    records — one drifted-layout straggler must not silently disqualify
    the whole majority-dim history) are skipped and counted on the
    ``explore.trust.skipped_records`` counter; fewer than
    ``min_records`` usable records yields ``None`` — callers fall back
    to unweighted Euclidean ranking."""
    usable = [r for r in records
              if np.all(np.isfinite(np.asarray(r["delta"], np.float64)))
              and np.isfinite(r["lift"])]
    if not usable:
        return None
    if dim is None:
        sizes = [np.asarray(r["delta"]).size for r in usable]
        # modal dim, newest-layout wins ties: count per dim, then prefer
        # the dim seen most; among equally-common dims the one appearing
        # latest in the record stream (the freshest layout)
        counts: Dict[int, int] = {}
        for s in sizes:
            counts[s] = counts.get(s, 0) + 1
        dim = max(counts, key=lambda s: (counts[s],
                                         max(i for i, sz in enumerate(sizes)
                                             if sz == s)))
    kept = [r for r in usable
            if np.asarray(r["delta"]).size == dim]
    if len(kept) < len(usable):
        obs.inc("explore.trust.skipped_records", len(usable) - len(kept))
    usable = kept
    if len(usable) < max(int(min_records), 1):
        return None
    X = np.stack([np.concatenate(
        [[1.0], np.abs(np.asarray(r["delta"], np.float64).ravel())])
        for r in usable])
    y = np.asarray([float(r["lift"]) for r in usable])
    A = X.T @ X + ridge * np.eye(X.shape[1])
    A[0, 0] -= ridge                   # don't shrink the intercept
    try:
        w = np.linalg.solve(A, X.T @ y)
    except np.linalg.LinAlgError:
        return None
    return TrustModel(weights=w)


class ArchiveManifest:
    """Index of an explore cache directory: one entry per archived problem
    key, carrying the problem's workload-feature embedding (fixed-dim; see
    ``repro.core.workload.workload_features``), its padded dims, freshness
    counters, an LRU ``last_used`` tick, and an opaque JSON-portable
    *space digest* (everything ``repro.core.encoding.migrate`` needs to
    move designs OUT of that archive without reconstructing the source
    graph).  A ``ManifestPolicy`` bounds growth (LRU eviction +
    embedding-space dedup, see there), and a *trust table* of per-(src,
    dst) transfer outcomes rides along for ``fit_trust_model``.

    ``nearest(embedding, k)`` ranks cached problems by Euclidean distance
    in embedding space — the cross-workload transfer lookup; with
    ``trust=`` a fitted ``TrustModel``, distances are reweighted by
    predicted lift so calibrated-useful neighbors rank ahead of merely
    geometrically-close ones.  Persistence is a single atomically-written
    npz; a damaged or truncated manifest is discarded with a warning,
    never fatal (a cache index is disposable).  This module stays free of
    ``repro.core`` imports: digests are stored and returned as plain
    dicts."""

    def __init__(self, path=None, policy: ManifestPolicy = ManifestPolicy()):
        self.path = Path(path) if path is not None else None
        self.policy = policy
        self.entries: Dict[str, Dict] = {}
        self.trust: List[Dict] = []    # per-(src, dst) transfer outcomes
        self.clock = 0                 # monotone LRU tick
        self.evicted: Dict[str, int] = {}   # key -> tick it left the index
        #                                     (LRU eviction or dedup merge);
        #                                     cleared on re-index, consumed
        #                                     by ``reap_evicted``

    def __len__(self) -> int:
        return len(self.entries)

    def _tick(self) -> int:
        self.clock += 1
        return self.clock

    def touch(self, key: str):
        """Mark one entry as just-used (transfer lookups call this for the
        neighbors they actually seeded from, so useful sources stay
        resident under LRU pressure)."""
        if key in self.entries:
            self.entries[key]["last_used"] = self._tick()
        return self

    def update(self, key: str, embedding, dims: Tuple[int, int, int],
               n_evals: int, budget_covered: int,
               searched: Sequence[str], digest: Optional[Dict] = None):
        """Insert or refresh one problem's entry (digest kept from the
        previous entry when not re-supplied), then enforce the growth
        policy — the entry being written is never the one evicted or
        merged away."""
        prev = self.entries.get(key, {})
        self.evicted.pop(key, None)    # re-indexed: no longer a GC victim
        self.entries[key] = dict(
            embedding=np.asarray(embedding, np.float64),
            dims=tuple(int(v) for v in dims),
            n_evals=int(n_evals), budget_covered=int(budget_covered),
            searched=tuple(searched),
            digest=digest if digest is not None else prev.get("digest"),
            last_used=self._tick())
        self.enforce(protect=(key,))
        return self

    # ---- growth policy -----------------------------------------------------
    def enforce(self, protect: Sequence[str] = ()):
        """Apply the growth policy: embedding-space dedup first (merging
        frees room without losing coverage), then LRU eviction down to
        ``max_entries``.  ``protect`` keys are never removed."""
        self.dedup(protect=protect)
        prot = set(protect)
        while len(self.entries) > max(int(self.policy.max_entries), 1):
            victims = [k for k in self.entries if k not in prot]
            if not victims:
                break
            victim = min(victims, key=lambda k: (
                self.entries[k].get("last_used", 0), k))
            del self.entries[victim]
            self.evicted[victim] = self.clock
            obs.inc("explore.manifest.evictions")
        return self

    def reap_evicted(self, cache_dir=None) -> Tuple[str, ...]:
        """Opt-in archive-file GC (``policy.reap_evicted_after`` > 0):
        delete the archive npz of every key that left the index at least
        that many LRU ticks ago and was never re-indexed since.  Returns
        the reaped keys; their eviction records are dropped (nothing left
        to reap).  A no-op under the default policy, and never touches
        keys currently in the index."""
        after = int(self.policy.reap_evicted_after)
        if after <= 0 or not self.evicted:
            return ()
        root = Path(cache_dir) if cache_dir is not None else (
            self.path.parent if self.path is not None else None)
        if root is None:
            return ()
        reaped = []
        for key, tick in list(self.evicted.items()):
            if key in self.entries:          # defensive: indexed keys are
                self.evicted.pop(key)        # never GC victims
                continue
            if self.clock - int(tick) < after:
                continue
            (root / f"{key}.npz").unlink(missing_ok=True)
            self.evicted.pop(key)
            reaped.append(key)
        return tuple(reaped)

    def _survivor(self, a: str, b: str, protect: Sequence[str]) -> str:
        """Which of two near-identical entries survives a merge: protected
        keys always win, then the better-explored one, ties broken on the
        key alone — never on insertion order or LRU ticks, so merging is
        commutative (the same survivor whichever order the entries
        arrived in)."""
        if (a in protect) != (b in protect):
            return a if a in protect else b
        score = lambda k: (self.entries[k]["n_evals"],
                           self.entries[k]["budget_covered"],
                           k)
        return max((a, b), key=score)

    def dedup(self, protect: Sequence[str] = ()):
        """Merge entries whose embeddings are within ``dedup_radius`` of
        each other.  The survivor keeps its own key/embedding/digest and
        absorbs the max of both freshness counters and the union of their
        searched objectives.  Scanning key-sorted pairs with a symmetric
        survivor rule makes the merge idempotent, commutative, and
        invariant under entry-insertion order."""
        radius = float(self.policy.dedup_radius)
        if radius <= 0 or len(self.entries) < 2:
            return self
        keys = sorted(self.entries)
        gone: set = set()
        for i, a in enumerate(keys):
            if a in gone:
                continue
            for b in keys[i + 1:]:
                if a in gone:
                    break
                if b in gone:
                    continue
                ea, eb = self.entries[a], self.entries[b]
                if ea["embedding"].shape != eb["embedding"].shape:
                    continue
                if np.linalg.norm(ea["embedding"]
                                  - eb["embedding"]) > radius:
                    continue
                keep = self._survivor(a, b, protect)
                drop = b if keep == a else a
                ek, ed = self.entries[keep], self.entries[drop]
                ek["n_evals"] = max(ek["n_evals"], ed["n_evals"])
                ek["budget_covered"] = max(ek["budget_covered"],
                                           ed["budget_covered"])
                ek["searched"] = tuple(sorted(
                    set(ek["searched"]) | set(ed["searched"])))
                ek["last_used"] = max(ek.get("last_used", 0),
                                      ed.get("last_used", 0))
                gone.add(drop)
        for k in gone:
            del self.entries[k]
            self.evicted[k] = self.clock    # merged away counts as evicted
            #                                 for the opt-in file GC too
            obs.inc("explore.manifest.dedup_merges")
        return self

    def merge(self, other: "ArchiveManifest") -> "ArchiveManifest":
        """Fold another manifest into this one — the reload-under-lock
        half of the shared-index write path (see ``ParetoArchive.merge``
        for the race it closes).  Typically ``self`` is the manifest
        just re-read from disk and ``other`` carries this process's
        pending mutations; the merge is field-wise so neither side's
        records are dropped:

        * entries: union by key; a key present on both sides keeps
          ``self``'s embedding/dims/digest (same problem, same content)
          and takes the max of the freshness counters and LRU tick, and
          the union of ``searched`` — counters only ever grow, so max
          never un-covers a budget a peer already paid for.
        * trust records: union, deduplicated by full record identity
          (two processes recording the same outcome from a shared
          journal must not double-weight the fit).
        * ``clock``/``evicted``: max tick wins; a key any side currently
          indexes is not evicted.

        Growth-policy enforcement is the CALLER's job (the writer holds
        the lock and knows which key to protect)."""
        for key, e in other.entries.items():
            mine = self.entries.get(key)
            if mine is None:
                self.entries[key] = dict(
                    e, embedding=np.asarray(e["embedding"], np.float64),
                    searched=tuple(e["searched"]))
                continue
            mine["n_evals"] = max(mine["n_evals"], e["n_evals"])
            mine["budget_covered"] = max(mine["budget_covered"],
                                         e["budget_covered"])
            mine["searched"] = tuple(sorted(set(mine["searched"])
                                            | set(e["searched"])))
            mine["last_used"] = max(mine.get("last_used", 0),
                                    e.get("last_used", 0))
            if mine.get("digest") is None:
                mine["digest"] = e.get("digest")
        seen = {(r["src"], r["dst"], r["lift"], r["delta"].tobytes())
                for r in self.trust}
        for r in other.trust:
            ident = (r["src"], r["dst"], r["lift"], r["delta"].tobytes())
            if ident not in seen:
                seen.add(ident)
                self.trust.append(dict(r))
        keep = max(int(self.policy.max_trust_records), 1)
        if len(self.trust) > keep:
            self.trust = self.trust[-keep:]
        self.clock = max(self.clock, other.clock)
        for k, t in other.evicted.items():
            self.evicted[k] = max(self.evicted.get(k, 0), int(t))
        for k in list(self.evicted):
            if k in self.entries:
                del self.evicted[k]
        return self

    # ---- trust table -------------------------------------------------------
    def record_transfer(self, src: str, dst: str, delta, lift: float):
        """Append one observed transfer outcome: seeds migrated from
        ``src`` into ``dst``'s run, whose workload embeddings differ by
        ``delta`` (per-dimension absolute difference), produced ``lift``
        (fraction of the run's hypervolume gain landed in its earliest
        generations — measured from the run's own ``ConvergenceTrace``,
        zero extra evaluations).  Oldest records roll off past
        ``max_trust_records``."""
        self.trust.append(dict(
            src=str(src), dst=str(dst),
            delta=np.asarray(delta, np.float64).ravel(),
            lift=float(lift)))
        keep = max(int(self.policy.max_trust_records), 1)
        if len(self.trust) > keep:
            self.trust = self.trust[-keep:]
        return self

    def export_index(self, exclude: Sequence[str] = ()
                     ) -> List[Tuple[str, np.ndarray]]:
        """The surrogate-dataset half of the manifest: ``(key,
        embedding)`` for every indexed problem whose archive holds paid
        evaluations on disk, sorted by key (deterministic harvest order),
        minus ``exclude`` — the target problem itself, or holdout graphs
        a benchmark keeps out of training."""
        skip = set(exclude)
        return [(k, e["embedding"]) for k, e in sorted(self.entries.items())
                if k not in skip and e["n_evals"] > 0
                and e.get("digest") is not None]

    def trust_model(self, dim: Optional[int] = None):
        """The fitted trust model over this manifest's recorded outcomes
        (``None`` until enough records accumulate).  With a deep record
        table (>= ``surrogate.NONLINEAR_TRUST_MIN``) the non-linear
        MLP head takes over from the ridge ``TrustModel`` — same
        ``predict(delta) -> lift >= 0`` contract, but it can learn that
        e.g. only SOME embedding axes predict transfer failure.  Falls
        back to the ridge fit whenever the MLP cannot be fit."""
        from .surrogate import NONLINEAR_TRUST_MIN, fit_nonlinear_trust
        if len(self.trust) >= NONLINEAR_TRUST_MIN:
            tm = fit_nonlinear_trust(self.trust, dim=dim)
            if tm is not None:
                return tm
        return fit_trust_model(self.trust, dim=dim)

    def nearest(self, embedding, k: int = 3,
                exclude: Sequence[str] = (),
                trust: Optional[TrustModel] = None
                ) -> List[Tuple[str, float]]:
        """The ``k`` cached problems closest to ``embedding`` (ascending
        effective distance), skipping excluded keys, empty archives and
        entries whose embedding dimension does not match the query's.
        Plain Euclidean by default; with ``trust``, each distance is
        divided by ``1 + max(predicted lift, 0)`` so neighbors the model
        learned to trust rank closer.  Ties break on key, so the result
        is invariant under entry-insertion order."""
        q = np.asarray(embedding, np.float64).ravel()
        out = []
        for key, e in self.entries.items():
            if key in exclude or e["n_evals"] <= 0:
                continue
            emb = e["embedding"]
            if emb.shape != q.shape:
                continue
            dist = float(np.linalg.norm(emb - q))
            if trust is not None:
                dist = dist / (1.0 + max(trust.predict(q - emb), 0.0))
            out.append((key, dist))
        out.sort(key=lambda t: (t[1], t[0]))
        return out[:max(int(k), 0)]

    # ---- persistence -------------------------------------------------------
    def save(self, path=None) -> Path:
        path = Path(path) if path is not None else self.path
        if path is None:
            raise ValueError("manifest has no path")
        keys = sorted(self.entries)
        meta = dict(
            version=3,
            keys=keys,
            clock=int(self.clock),
            evicted={k: int(t) for k, t in self.evicted.items()},
            entries={k: dict(
                dims=list(self.entries[k]["dims"]),
                n_evals=self.entries[k]["n_evals"],
                budget_covered=self.entries[k]["budget_covered"],
                searched=list(self.entries[k]["searched"]),
                last_used=int(self.entries[k].get("last_used", 0)),
                digest=self.entries[k]["digest"]) for k in keys},
            trust=[dict(src=r["src"], dst=r["dst"], lift=r["lift"],
                        delta=[float(v) for v in r["delta"]])
                   for r in self.trust])
        # one array per entry, NOT one stacked matrix: entries written
        # under different embedding layouts (a WL_EMBED_DIM upgrade) must
        # not wedge persistence with a ragged np.stack
        emb = {f"emb_{i}": np.asarray(self.entries[k]["embedding"],
                                      np.float64)
               for i, k in enumerate(keys)}
        return atomic_savez(
            path, __meta=np.frombuffer(json.dumps(meta).encode(),
                                       dtype=np.uint8),
            **emb)

    @classmethod
    def load(cls, path,
             policy: ManifestPolicy = ManifestPolicy()) -> "ArchiveManifest":
        """Load a manifest, tolerating absence and damage: anything
        unreadable yields an EMPTY manifest (with a warning) so one bad
        write can never take the exploration service down.  Version-1
        manifests (no LRU ticks, no trust table) load with zeroed
        ``last_used`` and an empty trust table."""
        path = Path(path)
        m = cls(path, policy=policy)
        if not path.exists():
            return m
        try:
            with np.load(path) as z:
                meta = json.loads(bytes(z["__meta"]).decode())
                if "embeddings" in z.files:     # stacked pre-v2 layout
                    stacked = np.asarray(z["embeddings"], np.float64)
                    emb = [stacked[i] for i in range(len(meta["keys"]))]
                else:
                    emb = [np.asarray(z[f"emb_{i}"], np.float64)
                           for i in range(len(meta["keys"]))]
            for i, k in enumerate(meta["keys"]):
                e = meta["entries"][k]
                m.entries[k] = dict(
                    embedding=emb[i],
                    dims=tuple(e["dims"]),
                    n_evals=int(e["n_evals"]),
                    budget_covered=int(e["budget_covered"]),
                    searched=tuple(e["searched"]),
                    digest=e.get("digest"),
                    last_used=int(e.get("last_used", 0)))
            m.clock = int(meta.get("clock", 0))
            m.evicted = {str(k): int(t)
                         for k, t in meta.get("evicted", {}).items()}
            m.trust = [dict(src=r["src"], dst=r["dst"],
                            delta=np.asarray(r["delta"], np.float64),
                            lift=float(r["lift"]))
                       for r in meta.get("trust", [])]
        except Exception as exc:        # disposable index: never fatal
            warnings.warn(f"discarding unreadable explore manifest "
                          f"{path}: {exc}")
            m.entries = {}
            m.trust = []
            m.clock = 0
            m.evicted = {}
        # honor THIS reader's policy immediately: a file written under a
        # laxer bound (or unbounded v1) must not keep a read-mostly
        # service over budget until its first write
        m.enforce()
        return m
