"""Pareto archive: the canonical dominance math + a fixed-capacity,
jit-compatible nondominated archive with a persistent on-disk cache.

This module is deliberately standalone (jax/numpy only, no ``repro.core``
imports) so both the optimizer (``repro.core.optimizer``) and the benchmark
suite can use one dominance convention without import cycles:

    a dominates b  <=>  all(a <= b) and any(a < b)      (all minimized)

Layers:

* ``pareto_front`` / ``dominance_counts`` / ``crowding_distance`` — the
  vectorized dominance primitives (vmapped O(n^2) comparisons; each
  insertion is a single fused comparison against the whole archive).
* ``ParetoArchive`` — fixed-capacity archive over stacked design pytrees
  plus an (n, k) objective matrix.  Insertion concatenates the batch,
  recomputes the nondominated mask and prunes to capacity by crowding
  distance (boundary points carry infinite crowding, so extremes survive).
* ``spec_space_key`` / ``save`` / ``load`` — persistence keyed by a
  canonical hash of the (SystemSpec, DesignSpace) pair, so a re-run of the
  same exploration problem warm-starts from disk instead of recomputing.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import warnings
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

F = jnp.float32
BIG = 1e30         # sentinel objective for invalid / non-finite rows

# shared log-space hypervolume reference: all convergence telemetry (the
# in-scan NSGA trace and the archive-projected plateau checks) measures
# 2-D hypervolume over clipped log-metrics against (HV_LOG_REF,)*2, so
# values are directly comparable across generations, scan segments and
# the host/device implementations.  e^41 ~ 6e17 comfortably exceeds every
# feasible raw metric; points beyond the reference contribute nothing.
HV_LOG_REF = 41.0


# ---------------------------------------------------------------------------
# dominance primitives (host + jit variants share one convention)
# ---------------------------------------------------------------------------
def pareto_front(points) -> List[int]:
    """Indices of the Pareto-optimal rows of an (n, k) objective array
    (all objectives minimized).  Duplicate points are all kept — neither
    strictly dominates the other.  This is THE canonical implementation;
    ``repro.core.optimizer.pareto_front`` and ``benchmarks.bench_pareto``
    both delegate here."""
    pts = np.asarray(points, np.float64)
    if pts.ndim == 1:
        pts = pts[:, None]
    n = len(pts)
    if n == 0:
        return []
    le = np.all(pts[:, None, :] <= pts[None, :, :], axis=-1)   # le[i,j]: i<=j
    lt = np.any(pts[:, None, :] < pts[None, :, :], axis=-1)
    dominated = np.any(le & lt, axis=0)                        # any i dom j
    return [int(i) for i in np.flatnonzero(~dominated)]


def dominates(a, b):
    """True iff point ``a`` dominates ``b`` (jnp, all minimized)."""
    return jnp.all(a <= b) & jnp.any(a < b)


def dominance_counts(objs, valid):
    """(n,) number of *valid* points dominating each row of ``objs`` (n, k).
    Zero => nondominated.  One fused (n, n, k) comparison — the vmapped
    'O(1) scans' insertion primitive."""
    le = jnp.all(objs[:, None, :] <= objs[None, :, :], axis=-1)
    lt = jnp.any(objs[:, None, :] < objs[None, :, :], axis=-1)
    dom = le & lt & valid[:, None]
    return jnp.sum(dom, axis=0)


def crowding_distance(objs, valid):
    """NSGA-II crowding distance over the ``valid`` subset of ``objs`` (n, k).
    Boundary points (per-objective min/max among valid rows) get +inf;
    invalid rows get 0.  jit/vmap-safe (fixed shapes, argsort-based)."""
    n = objs.shape[0]
    nv = jnp.sum(valid)

    def per_objective(col):
        c = jnp.where(valid, col, jnp.inf)         # invalid rows sort last
        order = jnp.argsort(c)
        s = c[order]
        lo = s[0]
        hi = s[jnp.clip(nv - 1, 0, n - 1)]
        rng = jnp.maximum(hi - lo, 1e-12)
        prev = jnp.concatenate([s[:1], s[:-1]])
        nxt = jnp.concatenate([s[1:], s[-1:]])
        i = jnp.arange(n)
        gap = (nxt - prev) / rng
        gap = jnp.where((i == 0) | (i == nv - 1), jnp.inf, gap)
        gap = jnp.where(i < nv, gap, 0.0)
        return jnp.zeros(n, F).at[order].set(gap.astype(F))

    return jnp.sum(jax.vmap(per_objective, in_axes=1, out_axes=1)(
        objs.astype(F)), axis=1)


def hypervolume_2d(points, ref) -> float:
    """Exact 2-D hypervolume (area dominated w.r.t. ``ref``, both objectives
    minimized).  Non-finite points and points not dominating ``ref`` are
    ignored; dominated points contribute nothing."""
    pts = np.asarray(points, np.float64).reshape(-1, 2)
    ref = np.asarray(ref, np.float64)
    ok = np.all(np.isfinite(pts), axis=1) & np.all(pts < ref[None, :], axis=1)
    pts = pts[ok]
    if len(pts) == 0:
        return 0.0
    pts = pts[np.argsort(pts[:, 0], kind="stable")]
    hv, ymin = 0.0, ref[1]
    for x, y in pts:
        if y < ymin:
            hv += (ref[0] - x) * (ymin - y)
            ymin = y
    return float(hv)


def hypervolume_2d_jit(points, ref, valid=None):
    """jit/vmap-safe exact 2-D hypervolume (both objectives minimized).

    Same staircase as ``hypervolume_2d`` but fixed-shape jnp: filtered
    points (non-finite, not dominating ``ref``, or masked out by
    ``valid``) are moved onto the reference point where they contribute
    zero area.  Used by the NSGA scan body to trace per-generation front
    hypervolume with no host round-trip and no extra evaluations."""
    pts = jnp.asarray(points, F).reshape(-1, 2)
    ref = jnp.asarray(ref, F).reshape(2)
    ok = jnp.all(jnp.isfinite(pts), axis=1) & jnp.all(pts < ref[None, :],
                                                     axis=1)
    if valid is not None:
        ok = ok & jnp.asarray(valid, bool)
    x = jnp.where(ok, pts[:, 0], ref[0])
    y = jnp.where(ok, pts[:, 1], ref[1])
    order = jnp.argsort(x)
    xs, ys = x[order], y[order]
    # running staircase minimum BEFORE each point (ref height to start)
    ymin_prev = jnp.concatenate([ref[1:2], jax.lax.cummin(ys)[:-1]])
    return jnp.sum((ref[0] - xs) * jnp.maximum(ymin_prev - ys, 0.0))


def objective_pairs(n: int) -> Tuple[Tuple[int, int], ...]:
    """All C(n, 2) index pairs (i < j) — the 2-D hypervolume projections
    traced for an ``n``-objective exploration.  Empty for n < 2."""
    return tuple((i, j) for i in range(n) for j in range(i + 1, n))


# ---------------------------------------------------------------------------
# convergence telemetry (shared by repro.explore.nsga / .service and the
# scalarized repro.core.optimizer loop)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ConvergenceTrace:
    """Per-generation convergence telemetry of one search run.

    All arrays are indexed by generation (length ``G``).  ``hypervolume``
    carries one column per objective *pair* (``pairs`` labels them): the
    running (cumulative-best) 2-D hypervolume of the population's feasible
    front over clipped log-metrics w.r.t. ``(HV_LOG_REF,)*2`` — monotone
    non-decreasing by construction, so a plateau is a genuine convergence
    signal rather than crowding-pruning noise.  ``best`` is the running
    best penalized scalarized objective (monotone non-increasing).
    ``archive_hv`` (optional, one row per scan *segment*) is the
    archive-projected hypervolume the service's plateau detector ranks on.
    """
    objectives: Tuple[str, ...]
    pairs: Tuple[Tuple[str, str], ...]
    front_size: np.ndarray          # (G,) population front size
    hypervolume: np.ndarray         # (G, P) running log-space hv per pair
    best: np.ndarray                # (G,) running best scalarized objective
    feasible_frac: np.ndarray       # (G,) feasible fraction of the children
    n_evals: np.ndarray             # (G,) cumulative evaluations
    archive_hv: Optional[np.ndarray] = None     # (S, P) per scan segment

    def __post_init__(self):
        self.objectives = tuple(self.objectives)
        self.pairs = tuple(tuple(p) for p in self.pairs)

    @property
    def generations(self) -> int:
        return len(self.front_size)

    @classmethod
    def from_scan(cls, objectives: Sequence[str], scan_trace: Dict,
                  evals_per_generation: int) -> "ConvergenceTrace":
        """Adopt the stacked (G, ...) telemetry a ``make_nsga`` run scanned
        out (zero extra evaluations were spent producing it)."""
        objectives = tuple(objectives)
        g = np.asarray(scan_trace["front_size"]).shape[0]
        return cls(
            objectives=objectives,
            pairs=tuple((objectives[i], objectives[j])
                        for i, j in objective_pairs(len(objectives))),
            front_size=np.asarray(scan_trace["front_size"], np.int64),
            hypervolume=np.asarray(scan_trace["hypervolume"], np.float64),
            best=np.asarray(scan_trace["best"], np.float64),
            feasible_frac=np.asarray(scan_trace["feasible_frac"],
                                     np.float64),
            n_evals=(np.arange(g, dtype=np.int64) + 1)
            * int(evals_per_generation))

    @classmethod
    def from_history(cls, history: Sequence, evals_per_step: int = 1,
                     objectives: Sequence[str] = ("objective",)
                     ) -> "ConvergenceTrace":
        """Adapt a scalarized engine's ``(iteration, best)`` history (the
        BO x SA loop tracks one incumbent, so ``front_size`` is 1 and there
        are no hypervolume pairs)."""
        vals = [float(v) for i, v in history
                if isinstance(i, (int, np.integer))]
        g = len(vals)
        best = (np.minimum.accumulate(np.asarray(vals, np.float64))
                if g else np.zeros(0))
        return cls(objectives=tuple(objectives), pairs=(),
                   front_size=np.ones(g, np.int64),
                   hypervolume=np.zeros((g, 0)),
                   best=best, feasible_frac=np.ones(g),
                   n_evals=(np.arange(g, dtype=np.int64) + 1)
                   * int(evals_per_step))

    def extend(self, other: "ConvergenceTrace") -> "ConvergenceTrace":
        """Concatenate a follow-on segment: evaluation counts accumulate,
        and the running hv / best stay monotone across the seam."""
        if other.objectives != self.objectives:
            raise ValueError("cannot extend a trace across objective sets")
        off = int(self.n_evals[-1]) if len(self.n_evals) else 0
        cat = lambda a, b: np.concatenate([np.asarray(a), np.asarray(b)])
        hv = np.maximum.accumulate(
            cat(self.hypervolume, other.hypervolume), axis=0)
        ahv = [a for a in (self.archive_hv, other.archive_hv)
               if a is not None]
        return ConvergenceTrace(
            objectives=self.objectives, pairs=self.pairs,
            front_size=cat(self.front_size, other.front_size),
            hypervolume=hv,
            best=np.minimum.accumulate(cat(self.best, other.best)),
            feasible_frac=cat(self.feasible_frac, other.feasible_frac),
            n_evals=cat(self.n_evals, np.asarray(other.n_evals) + off),
            archive_hv=np.concatenate(ahv, axis=0) if ahv else None)

    def summary(self) -> Dict:
        """JSON-serializable digest persisted alongside the archive npz."""
        g = self.generations
        return dict(
            generations=int(g),
            n_evals=int(self.n_evals[-1]) if g else 0,
            objectives=list(self.objectives),
            pairs=[list(p) for p in self.pairs],
            front_size_final=int(self.front_size[-1]) if g else 0,
            hypervolume_final=[float(v) for v in self.hypervolume[-1]]
            if g else [],
            best_final=float(self.best[-1]) if g else None,
            feasible_frac_mean=float(np.mean(self.feasible_frac))
            if g else 0.0)


# ---------------------------------------------------------------------------
# crash-safe npz persistence (archives + the cross-spec manifest)
# ---------------------------------------------------------------------------
def atomic_savez(path, **arrays) -> Path:
    """``np.savez_compressed`` through a same-directory temp file and an
    atomic ``os.replace``: a crash or kill mid-write leaves the previous
    file (or nothing) in place, never a truncated npz.  The temp file is
    opened explicitly so numpy cannot append a second ``.npz`` suffix."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_name(f".{path.name}.tmp{os.getpid()}")
    try:
        with open(tmp, "wb") as f:
            np.savez_compressed(f, **arrays)
        os.replace(tmp, path)
    finally:
        tmp.unlink(missing_ok=True)
    return path


# ---------------------------------------------------------------------------
# jit-compatible archive update
# ---------------------------------------------------------------------------
def _sanitize(objs):
    return jnp.where(jnp.isfinite(objs), objs.astype(F), F(BIG))


@jax.jit
def _archive_update(objs, valid, designs, new_objs, new_valid, new_designs):
    """Merge a batch into the archive state and prune to capacity.

    All shapes static (capacity from ``objs.shape[0]``, batch from
    ``new_objs.shape[0]``); one call = one vmapped dominance pass over
    archive+batch, so insertion cost is independent of insertion history."""
    cap = objs.shape[0]
    a_objs = jnp.concatenate([objs, _sanitize(new_objs)], axis=0)
    a_valid = jnp.concatenate([valid, new_valid], axis=0)
    a_valid = a_valid & jnp.all(a_objs < BIG, axis=-1)
    a_designs = jax.tree.map(
        lambda x, y: jnp.concatenate([x, y], axis=0), designs, new_designs)

    nd = dominance_counts(a_objs, a_valid)
    front = (nd == 0) & a_valid
    crowd = crowding_distance(a_objs, front)
    # ranking (ascending): nondominated by descending crowding (boundary
    # points carry inf crowding => kept first), then dominated/invalid rows.
    keyv = jnp.where(front, -jnp.minimum(crowd, F(1e9)),
                     F(BIG) + nd.astype(F))
    order = jnp.argsort(keyv)[:cap]
    return (a_objs[order], front[order],
            jax.tree.map(lambda x: x[order], a_designs))


class ParetoArchive:
    """Fixed-capacity nondominated archive over stacked design pytrees.

    ``template`` is one design point (a dict of arrays) fixing the leaf
    shapes/dtypes; objectives are an (n, ``n_obj``) matrix, all minimized.
    After every ``insert`` the archive contains only mutually nondominated
    points (capacity permitting — overflow is pruned by crowding distance,
    which always preserves per-objective boundary points)."""

    def __init__(self, capacity: int, template: Dict, n_obj: int = 4,
                 obj_keys: Optional[Sequence[str]] = None):
        self.capacity = int(capacity)
        self.n_obj = int(n_obj)
        self.obj_keys = tuple(obj_keys) if obj_keys else None
        self.objs = np.full((capacity, n_obj), BIG, np.float32)
        self.valid = np.zeros(capacity, bool)
        self.designs = {
            k: np.zeros((capacity,) + np.asarray(v).shape,
                        np.asarray(v).dtype)
            for k, v in template.items()}
        self.n_evals = 0            # total evaluations recorded against this
        #                             archive (cache-freshness metadata)
        self.searched = ()          # objective names search effort was ever
        #                             spent on (cache-coverage metadata)
        self.budget_covered = 0     # largest query budget this archive has
        #                             answered: plateau early-stopping may
        #                             spend FEWER than ``n_evals`` requested
        #                             evaluations, yet the query counts as
        #                             covered (the front had converged)
        self.trace_summary = {}     # last refinement's ConvergenceTrace
        #                             .summary(), persisted for dashboards

    def __len__(self) -> int:
        return int(self.valid.sum())

    def insert(self, designs: Dict, objs, mask=None, count_evals=True):
        """Insert a stacked batch: ``designs`` leaves (m, ...), ``objs``
        (m, n_obj).  Non-finite objective rows are dropped."""
        objs = jnp.asarray(objs, F).reshape(-1, self.n_obj)
        m = objs.shape[0]
        new_valid = (jnp.ones(m, bool) if mask is None
                     else jnp.asarray(mask, bool))
        new_designs = {k: jnp.asarray(v).reshape((m,) + self.designs[k].shape[1:])
                       for k, v in designs.items()}
        o, v, d = _archive_update(
            jnp.asarray(self.objs), jnp.asarray(self.valid),
            {k: jnp.asarray(v) for k, v in self.designs.items()},
            objs, new_valid, new_designs)
        self.objs = np.asarray(o)
        self.valid = np.asarray(v)
        self.designs = {k: np.asarray(x) for k, x in d.items()}
        if count_evals:
            self.n_evals += int(m)
        return self

    def front(self) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
        """(stacked designs of the valid rows, their (n, n_obj) objectives)."""
        sel = np.flatnonzero(self.valid)
        return ({k: v[sel] for k, v in self.designs.items()},
                self.objs[sel].astype(np.float64))

    def projected_hypervolume(self, pair: Tuple[int, int],
                              ref: float = HV_LOG_REF) -> float:
        """2-D hypervolume of the archived front projected onto a pair of
        objective columns, over clipped log-metrics w.r.t. ``(ref, ref)`` —
        the same scale the NSGA scan traces, so the service's plateau
        detector compares archive state across scan segments directly."""
        i, j = pair
        pts = self.objs[self.valid][:, [i, j]].astype(np.float64)
        return hypervolume_2d(np.log(np.maximum(pts, 1e-3)), (ref, ref))

    # ---- persistence -------------------------------------------------------
    def save(self, path) -> Path:
        meta = dict(capacity=self.capacity, n_obj=self.n_obj,
                    n_evals=self.n_evals, searched=list(self.searched),
                    obj_keys=list(self.obj_keys or ()),
                    budget_covered=self.budget_covered,
                    trace_summary=self.trace_summary)
        return atomic_savez(
            path, __meta=np.frombuffer(
                json.dumps(meta).encode(), dtype=np.uint8),
            objs=self.objs, valid=self.valid,
            **{f"d_{k}": v for k, v in self.designs.items()})

    @classmethod
    def load(cls, path) -> "ParetoArchive":
        with np.load(Path(path)) as z:
            meta = json.loads(bytes(z["__meta"]).decode())
            designs = {k[2:]: z[k] for k in z.files if k.startswith("d_")}
            template = {k: v[0] for k, v in designs.items()}
            arc = cls(meta["capacity"], template, n_obj=meta["n_obj"],
                      obj_keys=meta["obj_keys"] or None)
            arc.objs = z["objs"].copy()
            arc.valid = z["valid"].copy()
            arc.designs = {k: v.copy() for k, v in designs.items()}
            arc.n_evals = int(meta["n_evals"])
            arc.searched = tuple(meta.get("searched", ()))
            # archives written before budget accounting: evaluations
            # recorded then were always full-budget spends
            arc.budget_covered = int(meta.get("budget_covered",
                                              meta["n_evals"]))
            arc.trace_summary = dict(meta.get("trace_summary", {}))
        return arc


# ---------------------------------------------------------------------------
# canonical (SystemSpec, DesignSpace) hashing for the on-disk cache
# ---------------------------------------------------------------------------
def spec_space_key(spec, space, extra=None) -> str:
    """Stable content hash of an exploration problem: the padded workload
    arrays plus every static ``DesignSpace`` bound.  Equal workload graphs
    explored under equal bounds share one archive file, whatever Python
    objects they were built from.  ``extra`` folds any further
    cache-identity (e.g. the evaluator's ``TechConstants``, whose ``repr``
    is stable for a frozen dataclass) into the key.  Duck-typed so this
    module stays free of ``repro.core`` imports."""
    h = hashlib.sha256()
    if extra is not None:
        h.update(repr(extra).encode())
    h.update(repr((int(spec.W), int(spec.CH), int(spec.E))).encode())
    for k in sorted(spec.arrays):
        a = np.asarray(spec.arrays[k])
        h.update(k.encode())
        h.update(str(a.dtype).encode())
        h.update(repr(a.shape).encode())
        h.update(a.tobytes())
    h.update(repr((tuple(space.max_shape), int(space.max_logB),
                   int(space.max_total_pes), int(space.fixed_packaging),
                   int(space.fixed_family),
                   bool(space.allow_pipeline))).encode())
    return h.hexdigest()[:20]


# ---------------------------------------------------------------------------
# cross-spec archive manifest: the nearest-neighbor index over every cached
# exploration problem, keyed by workload-feature embedding
# ---------------------------------------------------------------------------
MANIFEST_NAME = "manifest.npz"


class ArchiveManifest:
    """Index of an explore cache directory: one entry per archived problem
    key, carrying the problem's workload-feature embedding (fixed-dim; see
    ``repro.core.workload.workload_features``), its padded dims, freshness
    counters, and an opaque JSON-portable *space digest* (everything
    ``repro.core.encoding.migrate`` needs to move designs OUT of that
    archive without reconstructing the source graph).

    ``nearest(embedding, k)`` ranks cached problems by Euclidean distance
    in embedding space — the cross-workload transfer lookup.  Persistence
    is a single atomically-written npz; a damaged or truncated manifest is
    discarded with a warning, never fatal (a cache index is disposable).
    This module stays free of ``repro.core`` imports: digests are stored
    and returned as plain dicts."""

    def __init__(self, path=None):
        self.path = Path(path) if path is not None else None
        self.entries: Dict[str, Dict] = {}

    def __len__(self) -> int:
        return len(self.entries)

    def update(self, key: str, embedding, dims: Tuple[int, int, int],
               n_evals: int, budget_covered: int,
               searched: Sequence[str], digest: Optional[Dict] = None):
        """Insert or refresh one problem's entry (digest kept from the
        previous entry when not re-supplied)."""
        prev = self.entries.get(key, {})
        self.entries[key] = dict(
            embedding=np.asarray(embedding, np.float64),
            dims=tuple(int(v) for v in dims),
            n_evals=int(n_evals), budget_covered=int(budget_covered),
            searched=tuple(searched),
            digest=digest if digest is not None else prev.get("digest"))
        return self

    def nearest(self, embedding, k: int = 3,
                exclude: Sequence[str] = ()) -> List[Tuple[str, float]]:
        """The ``k`` cached problems closest to ``embedding`` (Euclidean,
        ascending), skipping excluded keys, empty archives and entries
        whose embedding dimension does not match the query's."""
        q = np.asarray(embedding, np.float64).ravel()
        out = []
        for key, e in self.entries.items():
            if key in exclude or e["n_evals"] <= 0:
                continue
            emb = e["embedding"]
            if emb.shape != q.shape:
                continue
            out.append((key, float(np.linalg.norm(emb - q))))
        out.sort(key=lambda t: (t[1], t[0]))
        return out[:max(int(k), 0)]

    # ---- persistence -------------------------------------------------------
    def save(self, path=None) -> Path:
        path = Path(path) if path is not None else self.path
        if path is None:
            raise ValueError("manifest has no path")
        keys = sorted(self.entries)
        meta = dict(
            version=1,
            keys=keys,
            entries={k: dict(
                dims=list(self.entries[k]["dims"]),
                n_evals=self.entries[k]["n_evals"],
                budget_covered=self.entries[k]["budget_covered"],
                searched=list(self.entries[k]["searched"]),
                digest=self.entries[k]["digest"]) for k in keys})
        emb = (np.stack([self.entries[k]["embedding"] for k in keys])
               if keys else np.zeros((0, 0)))
        return atomic_savez(
            path, __meta=np.frombuffer(json.dumps(meta).encode(),
                                       dtype=np.uint8),
            embeddings=emb)

    @classmethod
    def load(cls, path) -> "ArchiveManifest":
        """Load a manifest, tolerating absence and damage: anything
        unreadable yields an EMPTY manifest (with a warning) so one bad
        write can never take the exploration service down."""
        path = Path(path)
        m = cls(path)
        if not path.exists():
            return m
        try:
            with np.load(path) as z:
                meta = json.loads(bytes(z["__meta"]).decode())
                emb = np.asarray(z["embeddings"], np.float64)
            for i, k in enumerate(meta["keys"]):
                e = meta["entries"][k]
                m.entries[k] = dict(
                    embedding=emb[i],
                    dims=tuple(e["dims"]),
                    n_evals=int(e["n_evals"]),
                    budget_covered=int(e["budget_covered"]),
                    searched=tuple(e["searched"]),
                    digest=e.get("digest"))
        except Exception as exc:        # disposable index: never fatal
            warnings.warn(f"discarding unreadable explore manifest "
                          f"{path}: {exc}")
            m.entries = {}
        return m
