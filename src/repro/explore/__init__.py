"""``repro.explore`` — multi-objective Pareto-front exploration service.

Three layers (see README "Exploration service"):

* ``archive``  — canonical dominance math + fixed-capacity jit-compatible
  Pareto archive with an on-disk cache keyed by (SystemSpec, DesignSpace).
* ``nsga``     — NSGA-II-style evolutionary front explorer: one
  ``lax.scan`` over vmapped populations, reusing the core encoding's
  ``mutate``/``random_design`` moves and the shared evaluation path.
* ``service``  — the NSGA engine backend (``run_queries``): batching
  concurrent same-spec queries into one vmapped run and serving warm
  queries straight from the archive cache.  The historic ``explore`` /
  ``explore_batch`` entry points live here as deprecation shims.
* ``api``      — the declarative front door (re-exported at
  ``repro.api``): hashable ``Problem``, declarative ``Query``,
  pre-evaluation ``Plan``, and ``Session.submit`` returning one unified
  ``Result`` whichever engine ran.

``archive`` is imported eagerly (it is dependency-free and is the canonical
home of ``pareto_front``, which ``repro.core.optimizer`` re-exports);
``nsga``/``service`` load lazily so importing ``repro.core`` never cycles
back through ``repro.explore``.
"""

import importlib

from .archive import (BIG, HV_LOG_REF, MANIFEST_NAME,  # noqa: F401
                      ArchiveManifest, ConvergenceTrace, ManifestPolicy,
                      ParetoArchive, TrustModel, atomic_savez,
                      crowding_distance, design_encoding_dim,
                      dominance_counts, dominates, fit_trust_model,
                      flatten_design, hypervolume_2d, hypervolume_2d_jit,
                      objective_pairs, pareto_front, spec_space_key)

_LAZY = {
    "NSGAConfig": ".nsga", "make_nsga": ".nsga",
    "make_nsga_gated": ".nsga",
    "Surrogate": ".surrogate", "SurrogateConfig": ".surrogate",
    "fit_surrogate": ".surrogate", "harvest_rows": ".surrogate",
    "NonlinearTrustModel": ".surrogate",
    "fit_nonlinear_trust": ".surrogate",
    "surrogate": ".surrogate",
    "BudgetPolicy": ".service",
    "ExplorationService": ".service", "ExploreQuery": ".service",
    "ExploreResult": ".service", "SegmentEvent": ".service",
    "PlateauState": ".service", "RunControl": ".service",
    "default_service": ".service",
    "explore": ".service",
    "file_lock": ".locks",
    "Problem": ".api", "Query": ".api", "Plan": ".api", "Result": ".api",
    "Session": ".api", "Provenance": ".api", "SegmentPlan": ".api",
    "NeighborPlan": ".api",
    "api": ".api", "nsga": ".nsga", "service": ".service",
}

__all__ = ["ParetoArchive", "pareto_front", "dominates", "dominance_counts",
           "crowding_distance", "hypervolume_2d", "hypervolume_2d_jit",
           "objective_pairs", "spec_space_key", "ConvergenceTrace",
           "HV_LOG_REF", "ArchiveManifest", "ManifestPolicy", "TrustModel",
           "fit_trust_model", "MANIFEST_NAME", "atomic_savez",
           "flatten_design", "design_encoding_dim",
           *sorted(k for k in _LAZY
                   if k not in ("api", "nsga", "service", "surrogate"))]


def __getattr__(name):
    if name in _LAZY:
        mod = importlib.import_module(_LAZY[name], __name__)
        if name in ("api", "nsga", "service", "surrogate"):
            return mod
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
