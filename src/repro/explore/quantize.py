"""Shared pow2 quantization for segment planning and megabatch bucketing.

One home for the "round everything to powers of two" machinery that used
to live as private helpers inside ``service.py`` (and was duplicated in
``api._plan_impl``).  Two layers use it:

* **segment planning** — a query budget is quantized into a
  ``(pop, generations, chunk, n_seg)`` schedule so every NSGA scan the
  service ever compiles comes from a small lattice of shapes, and the
  jit cache is shared across wildly different budgets;
* **megabatch bucketing** — distinct problems fuse into one compiled
  dispatch only when their compile-relevant statics coincide; the lane
  count of a fused dispatch is pow2-padded so the vmapped-run cache is
  keyed on the same small lattice.

Everything here is pure host-side integer math — no JAX imports — so it
can be called from planning code before any device work is traced.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = [
    "pow2_ceil", "pow2_floor", "effective_pop", "Schedule", "schedule",
]

MIN_POP = 8     # population floor: below this, tournament selection and
#                 crowding distance degenerate


def pow2_ceil(n: int) -> int:
    """Smallest power of two >= n (n >= 1)."""
    return 1 << max(0, int(n) - 1).bit_length() if n > 1 else 1


def pow2_floor(n: int) -> int:
    """Largest power of two <= n (n >= 1)."""
    return 1 << max(0, int(n).bit_length() - 1)


def effective_pop(budget: int, pop_ceiling: int,
                  quantize_down: bool = False) -> int:
    """The population width a refinement will actually run for one
    budget: sub-ceiling budgets shrink the population (pow2 ceil
    normally, pow2 floor when the budget is a hard cap; floored at
    ``MIN_POP``)."""
    pop = pop_ceiling
    if budget < pop:
        p = pow2_ceil(budget)
        if quantize_down and p > budget:
            p >>= 1
        pop = min(pop, max(MIN_POP, p))
    return pop


@dataclasses.dataclass(frozen=True)
class Schedule:
    """One quantized refinement schedule: ``generations`` total, run as
    ``n_seg`` segments of ``chunk`` generations each over a ``pop``-wide
    population (all powers of two; ``n_seg * chunk == generations``)."""
    pop: int
    generations: int
    chunk: int
    n_seg: int

    @property
    def evals(self) -> int:
        return self.pop * self.generations


def schedule(budget: int, pop_ceiling: int, chunk_generations: int,
             quantize_down: bool = False) -> Schedule:
    """Quantize a raw evaluation budget into the pow2 lattice schedule
    the service executes.  ``quantize_down`` floors instead of ceils the
    generation quantization, guaranteeing the run never spends more than
    ``budget`` — used when spending ledger credit, which must not be
    exceeded."""
    pop = effective_pop(budget, pop_ceiling, quantize_down)
    if quantize_down:           # largest pow2 <= budget/pop, floored at 1
        generations = 1 << max(0, (budget // pop).bit_length() - 1)
    else:
        generations = pow2_ceil(-(-budget // pop))      # ceil, then pow2
    chunk = min(pow2_ceil(chunk_generations), generations)
    return Schedule(pop=pop, generations=generations, chunk=chunk,
                    n_seg=generations // chunk)         # pow2 => divides


def bucket_lanes(n: int, max_lanes: Optional[int] = None) -> int:
    """Padded lane count for a fused megabatch dispatch: pow2 ceil,
    optionally clamped to ``max_lanes`` (itself expected to be pow2)."""
    lanes = pow2_ceil(n)
    if max_lanes is not None:
        lanes = min(lanes, pow2_ceil(max_lanes))
    return lanes
