"""Inter-process file locks for the fleet-shared cache directory.

A cache directory is shared by every service process pointed at it
(``REPRO_CACHE_DIR``), so manifest and archive writes are read-modify-
write cycles that can race: two services each reload, mutate their own
copy, and ``os.replace`` — the slower writer silently drops the faster
one's records.  ``file_lock`` arbitrates those cycles: writers take an
exclusive lock on a ``.lock`` sibling, reload the file *under the lock*,
merge their mutations into what is really on disk, and only then
replace.  The data file itself is still written atomically
(``atomic_savez``), so lock-free *readers* keep working unchanged —
locks order writers against writers, never block readers.

POSIX ``flock`` is used where available (the lock dies with the process,
so a SIGKILLed writer can never wedge the fleet); elsewhere an
exclusive-create lockfile with a stale-age takeover provides the same
mutual exclusion, with the takeover bounding how long a crashed writer's
leftover lockfile can block progress.
"""

from __future__ import annotations

import contextlib
import os
import time
import warnings
from pathlib import Path

try:
    import fcntl
except ImportError:                    # non-POSIX: lockfile fallback below
    fcntl = None

# how long a writer waits for the lock before giving up.  Cache writes
# are index-sized (milliseconds); a multi-second wait means a wedged
# peer, and failing loudly beats deadlocking a query.
DEFAULT_TIMEOUT_S = 30.0
_POLL_S = 0.01
# lockfile fallback only: a lockfile older than this is presumed to
# belong to a crashed writer and is taken over
_STALE_S = 60.0


class LockTimeout(TimeoutError):
    """The lock could not be acquired within the timeout."""


def lock_path(target) -> Path:
    """The lock sibling guarding writes to ``target`` (one lock per data
    file, so archives of different problems never serialize each
    other)."""
    target = Path(target)
    return target.with_name(target.name + ".lock")


@contextlib.contextmanager
def file_lock(path, timeout: float = DEFAULT_TIMEOUT_S):
    """Hold an exclusive inter-process lock on ``path`` (the lock file
    itself, typically ``lock_path(data_file)``) for the duration of the
    ``with`` block.  Re-entrant across *processes* only in the trivial
    sense that each holds its own descriptor — do not nest the same lock
    in one thread."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    if fcntl is not None:
        fd = os.open(str(path), os.O_CREAT | os.O_RDWR, 0o644)
        try:
            deadline = time.monotonic() + timeout
            while True:
                try:
                    fcntl.flock(fd, fcntl.LOCK_EX | fcntl.LOCK_NB)
                    break
                except OSError:
                    if time.monotonic() >= deadline:
                        raise LockTimeout(
                            f"could not lock {path} within {timeout:.0f}s "
                            f"(wedged peer process?)") from None
                    time.sleep(_POLL_S)
            try:
                yield
            finally:
                fcntl.flock(fd, fcntl.LOCK_UN)
        finally:
            os.close(fd)
        return
    # fallback: exclusive-create lockfile.  Unlike flock it survives its
    # owner's death, so a stale-age takeover keeps a crash from wedging
    # every later writer.
    deadline = time.monotonic() + timeout
    while True:
        try:
            fd = os.open(str(path), os.O_CREAT | os.O_EXCL | os.O_WRONLY,
                         0o644)
            os.write(fd, str(os.getpid()).encode())
            os.close(fd)
            break
        except FileExistsError:
            try:
                age = time.time() - path.stat().st_mtime
            except OSError:
                continue               # vanished between create and stat
            if age > _STALE_S:
                warnings.warn(f"taking over stale lock {path} "
                              f"(age {age:.0f}s)")
                path.unlink(missing_ok=True)
                continue
            if time.monotonic() >= deadline:
                raise LockTimeout(
                    f"could not lock {path} within {timeout:.0f}s") from None
            time.sleep(_POLL_S)
    try:
        yield
    finally:
        path.unlink(missing_ok=True)


__all__ = ["DEFAULT_TIMEOUT_S", "LockTimeout", "file_lock", "lock_path"]
