"""One front door: the declarative Problem / Query / Plan / Session API.

Monad's claim is one *uniform encoding* across the architecture and
integration spaces — this module gives the user-facing surface the same
property.  The four entry points that accreted across the early PRs
(``ExplorationService.explore`` / ``explore_batch``,
``core.optimizer.optimize`` / ``two_stage_optimize``) are now thin
deprecation shims over ONE declarative request path:

* ``Problem``  — a canonical, hashable statement of *what* to search:
  workload graph + objectives + constraints (the ``DesignSpace`` bounds)
  + padded spec space.  Content-addressed (``Problem.key()``), so equal
  problems built from different Python objects compare and hash equal.
* ``Query``    — a declarative request against a problem: evaluation
  ``budget``, ``engine`` selector (``nsga | bo_sa | two_stage | auto``),
  transfer/seed/policy options, per-engine knobs in ``engine_opts``.
* ``Plan``     — what ``Session.plan(query)`` returns *before* any
  evaluation is spent: the engine chosen, the cache-hit verdict, the
  quantized scan-segment schedule, and the predicted transfer neighbors
  with their trust-weighted seed quotas.
* ``Session``  — owns the cache directory / engines / budget policy
  (wrapping an ``ExplorationService``); ``submit(query | [queries])``
  executes plans and returns one unified ``Result`` per query whatever
  engine ran — front, designs, trace, and a ``Provenance`` record of
  the cache/transfer/reallocation accounting.

Streaming is part of the contract: ``submit(..., on_segment=cb)`` fires
``cb(SegmentEvent)`` at every scan-segment boundary with the incremental
``ConvergenceTrace`` slice (scalarized engines fire once, on completion),
so dashboards and async serving observe a run without waiting for it.

So is observability (``repro.obs``): ``Session(journal=...)`` — or the
``$REPRO_JOURNAL_DIR`` env var — attaches a crash-safe JSONL journal to
every ``plan``/``submit`` of the session, recording one line per plan,
scan segment, result and span close (``python -m repro.obs.report``
renders them).  Instrumentation only reads clocks: fronts are
bit-identical with observability on or off.
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
import time
import uuid
import zlib
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np

from .. import obs
from ..core.constants import DEFAULT_TECH, tech_key
from ..core.presets import tech_label
from ..core.encoding import DesignSpace
from ..core.evaluate import SystemSpec
from ..core.optimizer import METRIC_KEYS, OBJ_EDP
from ..core.workload import WorkloadGraph, workload_features
from . import quantize
from .archive import ConvergenceTrace, pareto_front, spec_space_key
from .nsga import ISLAND_AXIS, make_nsga
from .service import (DEFAULT_OBJECTIVES, BudgetPolicy, ExplorationService,
                      ExploreQuery, ExploreResult, SegmentEvent)

ENGINES = ("nsga", "bo_sa", "two_stage", "auto")


class Problem:
    """A canonical, hashable exploration problem: *what* to search.

    ``graph`` + ``objectives`` + the ``DesignSpace`` constraint kwargs
    (``space_kwargs``: ``max_shape``, ``max_total_pes``, ...) + the padded
    spec space (``ch_max``).  Identity is content-addressed: two Problems
    built from equal workloads under equal bounds are ``==`` and hash
    equal whatever Python objects they came from (``spec_space_key`` over
    the padded arrays and static bounds, plus the objective tuple) — the
    derivation ``ExplorationService`` used to re-do inline per query.

    ``Problem.from_spec(spec, space)`` adopts a prebuilt pair instead
    (the scalarized engines' historic calling convention)."""

    __slots__ = ("graph", "objectives", "ch_max", "space_kwargs",
                 "spec", "space", "_key")

    def __init__(self, graph: WorkloadGraph,
                 objectives: Sequence[str] = DEFAULT_OBJECTIVES,
                 ch_max: int = 4,
                 space_kwargs: Optional[Dict] = None, *,
                 spec: Optional[SystemSpec] = None,
                 space: Optional[DesignSpace] = None):
        self.objectives = tuple(objectives)
        if not self.objectives:
            raise ValueError("at least one objective required")
        bad = [o for o in self.objectives if o not in METRIC_KEYS]
        if bad:
            raise ValueError(f"unknown objectives {bad}; pick from "
                             f"{METRIC_KEYS}")
        self.spec = spec if spec is not None \
            else SystemSpec.build(graph, ch_max=ch_max)
        self.graph = self.spec.graph
        self.ch_max = int(self.spec.CH)
        self.space = space if space is not None \
            else DesignSpace(self.spec, **(space_kwargs or {}))
        # the full constraint set, reconstructable whichever constructor
        # ran — the NSGA backend rebuilds the space from these
        self.space_kwargs = dict(
            max_shape=tuple(self.space.max_shape),
            max_logB=int(self.space.max_logB),
            max_total_pes=int(self.space.max_total_pes),
            fixed_packaging=int(self.space.fixed_packaging),
            fixed_family=int(self.space.fixed_family),
            allow_pipeline=bool(self.space.allow_pipeline))
        h = hashlib.sha256()
        h.update(spec_space_key(self.spec, self.space).encode())
        h.update(repr(self.objectives).encode())
        self._key = h.hexdigest()[:20]

    @classmethod
    def from_spec(cls, spec: SystemSpec, space: DesignSpace,
                  objectives: Sequence[str] = DEFAULT_OBJECTIVES
                  ) -> "Problem":
        """Adopt a prebuilt (SystemSpec, DesignSpace) pair."""
        return cls(spec.graph, objectives=objectives, spec=spec,
                   space=space)

    def key(self) -> str:
        """Content hash of this problem (tech-independent; the archive
        cache key additionally folds the session's ``TechConstants`` in —
        see ``Session.plan``)."""
        return self._key

    def __hash__(self):
        return hash(self._key)

    def __eq__(self, other):
        return isinstance(other, Problem) and self._key == other._key

    def __repr__(self):
        return (f"Problem({self._key}, W={self.spec.W}, "
                f"objectives={self.objectives})")


@dataclasses.dataclass
class Query:
    """One declarative search request against a ``Problem``.

    ``engine`` selects the backend: ``"nsga"`` (multi-objective front
    explorer, cache/batch/transfer-aware), ``"bo_sa"`` (the paper's nested
    BO x SA scalarized engine), ``"two_stage"`` (the paper's Sec. IV-A
    architecture-then-integration flow), or ``"auto"`` — ``bo_sa`` when
    ``weights`` are given, else ``nsga``.

    ``budget`` is the evaluation budget for the NSGA engine (scalarized
    engines derive their spend from ``engine_opts``: ``n_init``/``n_iter``
    /``sa`` for ``bo_sa``; ``n_candidates``/``sa`` for ``two_stage``).
    ``transfer`` opts the NSGA engine into cross-workload seed migration;
    ``seed_designs`` warm-starts the scalarized engines; ``policy``
    overrides the session's ``BudgetPolicy`` for this submission;
    ``archive`` lets a scalarized run record into a ``ParetoArchive``."""
    problem: Problem
    budget: int = 2048
    engine: str = "auto"
    transfer: bool = False
    weights: Optional[Tuple[float, ...]] = None
    seed_designs: Optional[Sequence[Dict]] = None
    policy: Optional[BudgetPolicy] = None
    archive: Optional[object] = None            # ParetoArchive passthrough
    engine_opts: Optional[Dict] = None
    megabatch: bool = True          # allow this query to fuse with OTHER
    #                                 problems of equal padded shape into
    #                                 one compiled megabatch dispatch
    #                                 (nsga engine; see
    #                                 BudgetPolicy.megabatch)
    tech: Optional[object] = None   # per-query TechConstants override: a
    #                                 preset name / artifact path (str), a
    #                                 TechConstants, or a repro.calib
    #                                 CalibratedTech.  None = the
    #                                 session's tech.  Calibrated and
    #                                 default fronts never mix — the
    #                                 archive cache key folds in the tech
    #                                 content digest.

    def __post_init__(self):
        if self.engine not in ENGINES:
            raise ValueError(f"unknown engine {self.engine!r}; pick from "
                             f"{ENGINES}")
        if self.weights is not None:
            self.weights = tuple(float(w) for w in self.weights)

    def resolved_engine(self) -> str:
        if self.engine != "auto":
            return self.engine
        return "bo_sa" if self.weights is not None else "nsga"


@dataclasses.dataclass(frozen=True)
class SegmentPlan:
    """One planned scan segment: ``pop`` designs evaluated per generation
    for ``generations`` generations (``n_evals`` total)."""
    index: int
    pop: int
    generations: int
    n_evals: int


@dataclasses.dataclass(frozen=True)
class NeighborPlan:
    """One predicted transfer source: the neighbor's archive ``key``, its
    trust-reweighted embedding ``distance``, and the seed ``quota`` it
    earned out of the injection cap."""
    key: str
    distance: float
    quota: int


@dataclasses.dataclass(frozen=True)
class Plan:
    """What a query WILL do, before any evaluation is spent.

    ``cache_hit`` is the warm-serve verdict (the archive already covers
    the budget and objectives — ``segments`` is empty and submitting
    costs nothing).  ``segments`` is the quantized scan schedule the NSGA
    engine will run (or the scalarized engine's estimated spend, one
    segment per planned engine invocation).  ``neighbors`` are the
    predicted transfer sources with their trust-weighted seed quotas
    (``seed_cap`` bounds the total injection).  A plan is advisory on a
    shared cache — a concurrent service may warm the archive between
    ``plan`` and ``submit`` — and per-query: batched same-problem queries
    share one run sized by their union/max.

    ``islands`` is how many mesh islands the NSGA scan will shard over
    (1 = the plain single-device scan).  ``predicted_s`` is the wall-clock
    estimate from the session's segment-time histograms
    (``explore.segment_s`` / ``explore.segment_compile_s`` medians; the
    first segment is costed at the compile median when this scan variant
    has not yet compiled in-process) — ``None`` until those histograms
    hold at least one observation, ``0.0`` on a cache hit."""
    engine: str
    cache_key: str
    cache_hit: bool
    budget: int
    objectives: Tuple[str, ...]
    segments: Tuple[SegmentPlan, ...]
    neighbors: Tuple[NeighborPlan, ...] = ()
    seed_cap: int = 0
    islands: int = 1
    predicted_s: Optional[float] = None
    surrogate: bool = False         # the query asked for surrogate-gated
    #                                 evaluation (engine_opts)
    predicted_eval_savings: int = 0     # evaluations the gate WOULD skip
    #                                 if the fleet cache yields a fit —
    #                                 advisory like the rest of the plan:
    #                                 a cold cache (or mid-run fallback)
    #                                 spends up to the full schedule

    @property
    def n_evals_planned(self) -> int:
        return sum(s.n_evals for s in self.segments)


@dataclasses.dataclass(frozen=True)
class Provenance:
    """Where a ``Result`` came from: the engine that ran, the archive it
    was served from, and the full cache / transfer / reallocation
    accounting — uniform across engines."""
    cache_key: str
    engine: str
    from_cache: bool
    n_evals_run: int
    n_evals_banked: int
    n_evals_realloc: int
    transferred_from: Tuple[str, ...]
    n_transfer_seeds: int
    plateaued: bool
    elapsed_s: float
    interrupted: bool = False       # a cooperative stop (RunControl) ended
    #                                 the run early; resumable checkpoint
    #                                 state may remain on disk
    stale: bool = False             # served from the archive WITHOUT the
    #                                 budget being covered — the overload
    #                                 degradation path (freshest cached
    #                                 front now, refinement banked)
    surrogate_used: bool = False    # a fleet surrogate gated this run's
    #                                 evaluations (False when not asked
    #                                 for OR the cache was too cold)
    surrogate_hits: int = 0         # evaluations skipped on the
    #                                 surrogate's say-so
    surrogate_fallbacks: int = 0    # ensemble disagreement abandoned the
    #                                 surrogate mid-run
    tech: str = "default"           # the TechConstants identity the
    #                                 metrics were evaluated under:
    #                                 "default", or "<preset>@<digest12>"
    #                                 for a calibrated/custom preset (see
    #                                 core.presets.tech_label)


@dataclasses.dataclass
class Result:
    """The unified answer to one ``Query``, whatever engine ran.

    ``front_*`` is the (possibly single-point) Pareto front over the
    query's objectives; ``best_*`` is the scalarized incumbent (``None``
    for pure front queries); ``trace`` the run's ``ConvergenceTrace``
    (``None`` on pure cache hits); ``provenance`` the accounting; ``raw``
    the engine-native result (``ExploreResult`` / ``SearchResult``) the
    legacy shims return."""
    objectives: Tuple[str, ...]
    front_objs: np.ndarray
    front_metrics: np.ndarray
    front_designs: List[Dict[str, np.ndarray]]
    trace: Optional[ConvergenceTrace]
    provenance: Provenance
    best_design: Optional[Dict] = None
    best_objective: Optional[float] = None
    best_metrics: Optional[Dict] = None
    raw: object = None


class Session:
    """The front door: plan and submit declarative queries.

    Wraps an ``ExplorationService`` (constructed from the given kwargs
    when not supplied), which owns the archive cache directory, the NSGA
    engine configuration, the budget policy, and the transfer manifest;
    the scalarized engines share the session's ``TechConstants``.

    ``journal`` attaches a ``repro.obs`` run journal to every ``plan`` /
    ``submit`` of this session: a ``Journal``, a path (opened append-only
    on first write), ``None`` (the default — the process journal under
    ``$REPRO_JOURNAL_DIR`` when that env var is set, else no journal), or
    ``False`` to opt out even when the env var is set.
    """

    def __init__(self, service: Optional[ExplorationService] = None,
                 journal=None, **service_kwargs):
        # the service is built LAZILY, on the first query that needs the
        # archive cache: purely scalarized sessions (the optimize /
        # two_stage shims) never validate-and-create a cache directory
        # they will not touch
        self._service = service
        self._service_kwargs = dict(service_kwargs)
        # ``tech=`` accepts a preset name / artifact path / CalibratedTech
        # besides a raw TechConstants; resolve once, remember the label
        # ("name@digest12") for provenance and per-query tech routing
        tech_arg = (service.tech if service is not None
                    else self._service_kwargs.get("tech"))
        if tech_arg is not None:
            from ..core.presets import resolve_tech
            self.tech_label = tech_label(tech_arg)
            _, resolved = resolve_tech(tech_arg)
            if service is None:
                self._service_kwargs["tech"] = resolved
        else:
            self.tech_label = "default"
        self._tech_sessions: Dict[str, "Session"] = {}
        self._journal = obs.resolve_journal(journal)
        self._executor = None           # lazy repro.serve.Executor behind
        #                                 submit_async
        # one id per session + a per-submission counter: every submit of
        # this session journals under its own run id, so overlapping
        # submissions sharing one fleet journal replay apart cleanly
        self._sid = uuid.uuid4().hex[:8]
        self._run_seq = itertools.count()

    @property
    def service(self) -> ExplorationService:
        if self._service is None:
            self._service = ExplorationService(**self._service_kwargs)
        return self._service

    def _service_config(self) -> Dict:
        """The service configuration a sibling session needs to point at
        the same cache directory with the same engines/policies."""
        if self._service is None:
            return dict(self._service_kwargs)
        s = self._service
        return dict(cache_dir=s.cache_dir, capacity=s.capacity,
                    nsga=s.nsga, tech=s.tech, policy=s.policy,
                    transfer_k=s.transfer_k,
                    manifest_policy=s.manifest_policy, mesh=s.mesh)

    def clone(self) -> "Session":
        """A sibling session: same configuration, same cache directory
        and journal, its OWN ``ExplorationService``.  Services are
        single-threaded by design — the async executor hands each worker
        thread a clone, and the shared cache directory (file locks +
        reload-merge writes) is the only coordination point, exactly as
        it is between separate processes."""
        return Session(journal=self._journal, **self._service_config())

    @property
    def tech(self):
        if self._service is not None:
            return self._service.tech
        return self._service_kwargs.get("tech")

    def _cache_key(self, p: Problem) -> str:
        """The archive identity of ``p`` under this session's tech — the
        same derivation as ``ExplorationService.problem_key``, computable
        without constructing the service.  The tech folds in as its
        stable ``tech_key()`` content digest (never ``repr``), so
        calibrated and default fronts can never share an archive."""
        return spec_space_key(p.spec, p.space,
                              extra=tech_key(self.tech or DEFAULT_TECH))

    def _session_for(self, tech) -> "Session":
        """The session answering queries under ``tech``: this one when the
        labels match, else a cached sibling sharing the cache directory
        and journal — distinct tech digests key distinct archives, so the
        shared directory never mixes fronts."""
        if tech is None:
            return self
        label = tech_label(tech)
        if label == self.tech_label:
            return self
        if label not in self._tech_sessions:
            cfg = self._service_config()
            cfg["tech"] = tech
            self._tech_sessions[label] = Session(journal=self._journal,
                                                 **cfg)
        return self._tech_sessions[label]

    # ---- planning ----------------------------------------------------------
    def plan(self, query: Query) -> Plan:
        """Inspect what ``submit`` would do for one query, spending no
        evaluations: resolved engine, archive cache key (and warm-serve
        verdict), the quantized segment schedule, and — for transfer
        queries — the predicted neighbors with their seed quotas.

        With observability on and a journal attached, one ``plan`` record
        per call lands in the journal — the "plan" half of the report's
        plan-vs-actual table."""
        with obs.sink_attached(self._journal), \
                obs.span("session.plan", engine=query.resolved_engine()):
            pl = self._plan_impl(query)
            if obs.active():
                obs.emit(dict(
                    type="plan", key=pl.cache_key, engine=pl.engine,
                    budget=pl.budget, cache_hit=pl.cache_hit,
                    objectives=list(pl.objectives),
                    segments=[dict(segment=s.index, pop=s.pop,
                                   generations=s.generations,
                                   n_evals=s.n_evals)
                              for s in pl.segments],
                    neighbors=[dict(key=n.key, distance=n.distance,
                                    quota=n.quota) for n in pl.neighbors],
                    seed_cap=pl.seed_cap, islands=pl.islands,
                    predicted_s=pl.predicted_s))
        return pl

    def _plan_impl(self, query: Query) -> Plan:
        sub = self._session_for(query.tech)
        if sub is not self:
            return sub._plan_impl(query)
        engine = query.resolved_engine()
        p = query.problem
        ck = self._cache_key(p)
        if engine in ("bo_sa", "two_stage"):
            self._validate_scalarized(query)
            return Plan(engine=engine, cache_key=ck, cache_hit=False,
                        budget=self._scalarized_evals(query),
                        objectives=p.objectives,
                        segments=(SegmentPlan(
                            0, 1, 1, self._scalarized_evals(query)),))
        svc = self.service
        arc = svc.archive_for(p.spec, p.space, key=ck)
        budget = int(query.budget)
        if svc.warm_verdict(arc, p.objectives, budget):
            return Plan(engine=engine, cache_key=ck, cache_hit=True,
                        budget=budget, objectives=p.objectives,
                        segments=(), predicted_s=0.0)
        policy = query.policy or svc.policy
        sched = quantize.schedule(budget, svc.nsga.pop,
                                  policy.chunk_generations)
        pop, chunk = sched.pop, sched.chunk
        segments = tuple(
            SegmentPlan(i, pop, chunk, pop * chunk)
            for i in range(sched.n_seg))
        mesh = svc._mesh_for(pop)
        islands = int(mesh.shape[ISLAND_AXIS]) if mesh is not None else 1
        predicted = self._predict_s(p, sched, mesh)
        neighbors, cap = (), 0
        if query.transfer:
            cap = pop if len(arc) == 0 else max(pop // 2, 1)
            m, neigh, quotas = svc._transfer_plan(
                ck, workload_features(p.spec.graph), cap)
            neighbors = tuple(
                NeighborPlan(nk, float(dist), int(quotas.get(nk, 1)))
                for nk, dist in neigh
                if m.entries[nk].get("digest") is not None)
        sur_req = dict(query.engine_opts or {}).get("surrogate", None)
        savings = 0
        if sur_req is not None:
            from .surrogate import SurrogateConfig
            s_opts = {} if sur_req is True else dict(sur_req)
            s_opts.pop("exclude", None)
            scfg = SurrogateConfig(**s_opts)
            savings = (pop - scfg.n_exact(pop)) * chunk * sched.n_seg
        return Plan(engine=engine, cache_key=ck, cache_hit=False,
                    budget=budget, objectives=p.objectives,
                    segments=segments, neighbors=neighbors, seed_cap=cap,
                    islands=islands, predicted_s=predicted,
                    surrogate=sur_req is not None,
                    predicted_eval_savings=savings)

    def _predict_s(self, p: Problem, sched: "quantize.Schedule",
                   mesh) -> Optional[float]:
        """Wall-clock estimate for one NSGA submission, from the
        process-wide segment-time histograms.  The first segment is
        costed at the compile-time median when this exact scan variant
        has not yet executed in-process (``make_nsga`` is cached, so
        probing it here is free and a later ``submit`` reuses the
        runner).  ``None`` while the histograms are empty — a fresh
        process has nothing to extrapolate from.  When only compile
        segments have been observed so far (short early runs), the
        compile median stands in for the steady-state one — a
        conservative over-estimate beats no estimate."""
        seg_h = obs.REGISTRY.peek("explore.segment_s")
        comp_h = obs.REGISTRY.peek("explore.segment_compile_s")
        seg_p50 = seg_h.quantile(0.5) if seg_h is not None else None
        comp_p50 = comp_h.quantile(0.5) if comp_h is not None else None
        if seg_p50 is None and comp_p50 is None:
            return None
        if seg_p50 is None:
            seg_p50 = comp_p50
        cfg = dataclasses.replace(self.service.nsga, pop=sched.pop,
                                  generations=sched.chunk)
        run = make_nsga(p.spec, p.space, p.objectives, cfg,
                        tech=self.tech, mesh=mesh)
        first = seg_p50
        if not run.compile_state["executed"] and comp_p50 is not None:
            first = comp_p50
        return first + (sched.n_seg - 1) * seg_p50

    def _scalarized_evals(self, query: Query) -> int:
        """Planned evaluation spend of a scalarized query (estimate; the
        two-stage selector's stage-2 count is data-dependent)."""
        from ..core.optimizer import SAConfig
        opts = dict(query.engine_opts or {})
        if query.resolved_engine() == "two_stage":
            sa = opts.get("sa", SAConfig(steps=250, chains=4))
            n_scal = max(int(opts.get("n_candidates", 3)), 2)
            per_opt = (4 + 6) * sa.steps * sa.chains   # n_init=4, n_iter=6
            return n_scal * per_opt
        sa = opts.get("sa", SAConfig())
        n_init = int(opts.get("n_init", 8))
        n_iter = int(opts.get("n_iter", 24))
        bo = opts.get("bo_fields", None)
        has_bo = True if bo is None else len(tuple(bo)) > 0
        return (n_init + (n_iter if has_bo else 0)) * sa.steps * sa.chains

    # ---- execution ---------------------------------------------------------
    def submit(self, queries: Union[Query, Sequence[Query]], key=None,
               on_segment=None, resume: bool = False,
               control=None) -> Union[Result, List[Result]]:
        """Execute one query (returns its ``Result``) or a batch (returns
        a ``Result`` per query, in order).  NSGA queries of one batch are
        answered together — same-problem queries merge into one run and
        banked budget reallocates across the batch, exactly the legacy
        ``explore_batch`` semantics.  ``on_segment`` streams every scan
        segment's ``SegmentEvent`` as it completes (scalarized engines
        fire one event on completion).

        With observability on and a journal attached (see ``journal=`` on
        the constructor), the submission journals one ``plan`` record per
        query, one ``segment`` record per scan-segment boundary, one
        ``result`` record per answer, and a final ``metrics`` snapshot —
        everything ``repro.obs.report`` needs.  Every submission journals
        under its own run id (``obs.run_context``), so overlapping
        submissions sharing one fleet journal replay apart cleanly.
        Instrumentation never touches PRNG keys or numeric state: results
        are bit-identical with observability on or off.

        ``resume=True`` turns on per-segment crash checkpointing for the
        NSGA engine: a killed submission re-submitted with the same
        queries and ``key`` restores the last completed segment's state
        and spends only the residual budget (bit-identical final front).
        ``control`` (a ``repro.explore.service.RunControl``) requests a
        cooperative stop at the next segment boundary; interrupted
        results carry ``provenance.interrupted=True``."""
        single = isinstance(queries, Query)
        qs: List[Query] = [queries] if single else list(queries)
        if not qs:
            return []
        rid = f"{self._sid}.{next(self._run_seq)}"
        with obs.sink_attached(self._journal), obs.run_context(rid), \
                obs.span("session.submit", queries=len(qs)):
            out = self._submit_impl(qs, key=key, on_segment=on_segment,
                                    single=single, resume=resume,
                                    control=control)
            if obs.active():
                for r in out:
                    pv = r.provenance
                    obs.emit(dict(
                        type="result", key=pv.cache_key, engine=pv.engine,
                        from_cache=pv.from_cache, n_evals=pv.n_evals_run,
                        n_evals_banked=pv.n_evals_banked,
                        n_evals_realloc=pv.n_evals_realloc,
                        plateaued=pv.plateaued, elapsed_s=pv.elapsed_s,
                        interrupted=pv.interrupted,
                        front_size=int(len(r.front_objs))))
                obs.emit(dict(type="metrics",
                              snapshot=obs.REGISTRY.snapshot()))
            for r in out:
                obs.observe("session.time_to_front_s",
                            r.provenance.elapsed_s)
        return out[0] if single else out

    def submit_async(self, query: Query, key=None,
                     deadline_s: Optional[float] = None):
        """Submit one query asynchronously: returns a
        ``repro.serve.JobHandle`` immediately (poll / ``result(timeout)``
        / ``cancel()`` / streamed ``SegmentEvent``s) while a worker
        thread runs the search.  Jobs are journaled durably under the
        cache directory and keyed on ``Problem.key()``, so a crashed
        process's jobs are recoverable (``Executor.resume_pending``) and
        a killed run resumes from its last completed segment.  Under
        overload (queue full), a query whose archive holds ANY front is
        answered immediately with that possibly-stale front
        (``provenance.stale=True``) and the refinement stays banked in
        the job store.  ``deadline_s`` bounds how long admission may
        defer before degrading."""
        return self.executor().submit(query, key=key,
                                      deadline_s=deadline_s)

    def executor(self, **kwargs):
        """The session-owned ``repro.serve.Executor`` (built lazily, on
        the first ``submit_async``; kwargs accepted only on first
        construction — build an ``Executor`` directly for anything
        fancier)."""
        if self._executor is None:
            from ..serve import Executor
            self._executor = Executor(self, **kwargs)
        elif kwargs:
            raise RuntimeError(
                "this session's executor is already initialized; "
                "construct repro.serve.Executor(session, ...) directly "
                "for a custom configuration")
        return self._executor

    def _submit_impl(self, qs: List[Query], key=None, on_segment=None,
                     single: bool = False, resume: bool = False,
                     control=None) -> List[Result]:
        # ``single`` preserves the legacy key convention: only a bare
        # (non-list) Query takes the caller's key verbatim on the
        # scalarized path — a one-element list still domain-separates
        key = jax.random.PRNGKey(0) if key is None else key
        # per-query tech overrides route to sibling sessions (same cache
        # directory, distinct tech digests — so distinct archives); each
        # non-default group's PRNG stream domain-separates on its label
        routed: Dict[str, Tuple["Session", List[int]]] = {}
        for i, q in enumerate(qs):
            s = self._session_for(q.tech)
            if s is not self:
                routed.setdefault(s.tech_label, (s, []))[1].append(i)
        if routed:
            results: Dict[int, Result] = {}
            mine = [i for i, q in enumerate(qs)
                    if self._session_for(q.tech) is self]
            if mine:
                for i, r in zip(mine, self._submit_impl(
                        [qs[i] for i in mine], key=key,
                        on_segment=on_segment, single=False,
                        resume=resume, control=control)):
                    results[i] = r
            for label, (s, idxs) in routed.items():
                k2 = jax.random.fold_in(
                    key, zlib.crc32(label.encode()) & 0x7FFFFFFF)
                for i, r in zip(idxs, s._submit_impl(
                        [qs[i] for i in idxs], key=k2,
                        on_segment=on_segment,
                        single=single and len(idxs) == len(qs),
                        resume=resume, control=control)):
                    results[i] = r
            return [results[i] for i in range(len(qs))]
        if obs.active():        # journal the plan of record for every
            #                     query before the engines run — read-only
            #                     (archive/manifest inspection), no PRNG
            for q in qs:
                self.plan(q)
        override = {q.policy for q in qs if q.policy is not None}
        if len(override) > 1:
            raise ValueError("one submission takes at most one "
                             "BudgetPolicy override")
        results: Dict[int, Result] = {}
        nsga_idx = [i for i, q in enumerate(qs)
                    if q.resolved_engine() == "nsga"]
        for i, q in enumerate(qs):          # validate the WHOLE batch
            if i in nsga_idx:               # before any engine runs
                self._to_explore_query(q)
            else:
                self._validate_scalarized(q)
        if nsga_idx:
            svc = self.service
            saved_policy = svc.policy
            if override:
                svc.policy = next(iter(override))
            try:
                eqs = [self._to_explore_query(qs[i]) for i in nsga_idx]
                for i, er in zip(nsga_idx, svc.run_queries(
                        eqs, key=key, on_segment=on_segment,
                        resume=resume, control=control)):
                    results[i] = self._wrap_explore(qs[i], er)
            finally:
                svc.policy = saved_policy
        for i, q in enumerate(qs):
            eng = q.resolved_engine()
            if eng == "nsga":
                continue
            # single queries take the caller's key verbatim (the legacy
            # shims rely on it, bit for bit); batched scalarized queries
            # draw from a domain-separated stream so they can never
            # collide with run_queries' per-group / reallocation folds
            k = key if single else jax.random.fold_in(
                jax.random.fold_in(key, 0x5ca1a2), i)
            results[i] = self._run_scalarized(q, eng, k, on_segment)
        return [results[i] for i in range(len(qs))]

    @staticmethod
    def _validate_scalarized(q: Query) -> None:
        """Scalarized engines reject the nsga-only options as loudly as
        ``_to_explore_query`` rejects the scalarized-only ones — a
        transfer or policy request must never be silently dropped.
        (``budget`` stays nsga-only by documented contract: scalarized
        spend derives from ``engine_opts``.)"""
        if q.transfer:
            raise ValueError(
                "transfer=True applies to the nsga engine only; seed "
                "scalarized engines explicitly via seed_designs=")
        if q.policy is not None:
            raise ValueError(
                "BudgetPolicy applies to the nsga engine only; size "
                "scalarized engines via engine_opts (n_init/n_iter/sa)")

    @staticmethod
    def _to_explore_query(q: Query) -> ExploreQuery:
        p = q.problem
        opts = dict(q.engine_opts or {})
        # the one engine_opts key the nsga engine owns: surrogate-gated
        # evaluation (True or a SurrogateConfig-override dict; see
        # ExploreQuery.surrogate).  Everything else is scalarized-only.
        surrogate = opts.pop("surrogate", None)
        if q.weights is not None or q.seed_designs or q.archive or opts:
            raise ValueError(
                "weights / seed_designs / archive / engine_opts apply to "
                "the scalarized engines; the nsga engine takes budget / "
                "transfer / policy / engine_opts={'surrogate': ...}")
        return ExploreQuery(p.graph, p.objectives, int(q.budget),
                            p.ch_max, p.space_kwargs, q.transfer,
                            spec=p.spec, space=p.space,
                            megabatch=q.megabatch,
                            surrogate=surrogate)

    def _wrap_explore(self, q: Query, er: ExploreResult) -> Result:
        return Result(
            objectives=er.objectives,
            front_objs=er.front_objs, front_metrics=er.front_metrics,
            front_designs=er.front_designs, trace=er.trace,
            provenance=Provenance(
                cache_key=er.cache_key, engine="nsga",
                from_cache=er.from_cache, n_evals_run=er.n_evals_run,
                n_evals_banked=er.n_evals_banked,
                n_evals_realloc=er.n_evals_realloc,
                transferred_from=er.transferred_from,
                n_transfer_seeds=er.n_transfer_seeds,
                plateaued=er.plateaued, elapsed_s=er.elapsed_s,
                interrupted=er.interrupted,
                surrogate_used=er.surrogate_used,
                surrogate_hits=er.surrogate_hits,
                surrogate_fallbacks=er.surrogate_fallbacks,
                tech=self.tech_label),
            raw=er)

    def _run_scalarized(self, q: Query, engine: str, key,
                        on_segment=None) -> Result:
        from ..core.optimizer import _optimize_impl, _two_stage_impl
        p = q.problem
        ck = self._cache_key(p)     # no service: scalarized runs never
        #                             touch the archive cache directory
        opts = dict(q.engine_opts or {})
        t0 = time.perf_counter()
        if engine == "two_stage":
            sr = _two_stage_impl(p.spec, p.space, key, tech=self.tech,
                                 archive=q.archive,
                                 seed_designs=q.seed_designs, **opts)
        else:
            sr = _optimize_impl(p.spec, p.space, key,
                                weights=q.weights or OBJ_EDP,
                                tech=self.tech, archive=q.archive,
                                seed_designs=q.seed_designs, **opts)
        elapsed = time.perf_counter() - t0
        cb = ExplorationService._segment_cb(on_segment, ck, engine)
        if cb is not None and sr.trace is not None:
            # one completion event: scalarized engines have no scan
            # segments.  The shared wrapper tags the event with the
            # engine phase and wall-clock, journals it, and keeps
            # callback failures non-fatal (warned with phase/segment
            # coordinates, counted on obs.on_segment_errors)
            cb(0, sr.trace, elapsed, False)
        n_evals = int(sr.trace.n_evals[-1]) if sr.trace is not None \
            and len(sr.trace.n_evals) else 0
        idx = [METRIC_KEYS.index(o) for o in p.objectives]
        if q.archive is not None and len(q.archive) > 0:
            designs, metrics = q.archive.front()
            cols = metrics[:, idx]
            keep = pareto_front(cols) if len(cols) else []
            front_objs = cols[keep]
            front_metrics = metrics[keep]
            front_designs = [{k2: v[i] for k2, v in designs.items()}
                             for i in keep]
        else:                           # single-incumbent front
            row = np.asarray([[float(sr.metrics[k2])
                               for k2 in METRIC_KEYS]], np.float64)
            front_objs = row[:, idx]
            front_metrics = row
            front_designs = [{k2: np.asarray(v)
                              for k2, v in sr.design.items()}]
        return Result(
            objectives=p.objectives,
            front_objs=front_objs, front_metrics=front_metrics,
            front_designs=front_designs, trace=sr.trace,
            provenance=Provenance(
                cache_key=ck, engine=engine, from_cache=False,
                n_evals_run=n_evals, n_evals_banked=0, n_evals_realloc=0,
                transferred_from=(),
                n_transfer_seeds=len(q.seed_designs or ()),
                plateaued=False, elapsed_s=elapsed,
                tech=self.tech_label),
            best_design=sr.design, best_objective=sr.objective,
            best_metrics=sr.metrics, raw=sr)


# ---------------------------------------------------------------------------
# module-level conveniences over a process-wide default session
# ---------------------------------------------------------------------------
_DEFAULT_SESSION: Optional[Session] = None


def session(**kwargs) -> Session:
    """The process-wide default ``Session`` (mirrors
    ``service.default_service``: kwargs only on first construction)."""
    global _DEFAULT_SESSION
    if _DEFAULT_SESSION is None:
        _DEFAULT_SESSION = Session(**kwargs)
    elif kwargs:
        raise RuntimeError(
            "the default session is already initialized; construct "
            "Session(...) directly for a custom configuration")
    return _DEFAULT_SESSION


def plan(query: Query) -> Plan:
    """``session().plan(query)``."""
    return session().plan(query)


def submit(queries: Union[Query, Sequence[Query]], key=None,
           on_segment=None) -> Union[Result, List[Result]]:
    """``session().submit(queries)``."""
    return session().submit(queries, key=key, on_segment=on_segment)


__all__ = [
    "ENGINES", "NeighborPlan", "Plan", "Problem", "Provenance", "Query",
    "Result", "SegmentEvent", "SegmentPlan", "Session", "plan", "session",
    "submit",
]
