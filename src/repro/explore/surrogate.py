"""Surrogate-gated evaluation over the fleet cache.

Monad's search pays one exact analytical-model evaluation per candidate
design, but the fleet cache accumulates every (design encoding, workload
embedding) -> 4-metric evaluation the fleet has ever paid for — a free
training set (the move Chiplet-Gym makes with its proxy cost model and
Gemini with cheap pre-mapping bounds).  This module turns those rows
into an ensemble-MLP surrogate fit in pure JAX:

* ``harvest_rows`` — walk the manifest's ``export_index``, load each
  cached archive and stack its ``ParetoArchive.export_rows`` output with
  the problem's workload embedding appended: ``X = [flatten_design |
  embedding]``, ``Y = raw 4-metric rows``.
* ``fit_surrogate`` — normalize (zero-variance guarded), bootstrap-
  resample one dataset per ensemble member, and train all members in one
  jitted vmapped Adam loop over log-metrics.  Ensemble spread IS the
  uncertainty signal: the gated NSGA scan forces exact evaluation of any
  candidate the members disagree on.
* ``Surrogate`` — the fitted model: ``predict`` (log-metric mean/std),
  ``disagreement`` (mean normalized ensemble std), ``scan_arrays`` (the
  runtime operand dict the gated scan consumes — the compiled runner is
  cached on the surrogate's SHAPES, never its values), ``digest``
  (checkpoint-signature identity).
* ``NonlinearTrustModel`` / ``fit_nonlinear_trust`` — the same MLP
  machinery applied to the manifest's transfer-outcome table, replacing
  the ridge ``TrustModel`` once records are deep enough
  (``NONLINEAR_TRUST_MIN``): same ``predict(delta) -> lift >= 0``
  contract, but free to learn that only SOME embedding axes predict
  transfer failure.

The gating itself lives in ``nsga.make_nsga_gated`` (in-scan candidate
ranking by predicted-Pareto optimism) and ``service._refine`` (segment-
level fallback to exact evaluation when mean disagreement says the
surrogate is out of its depth).  ``surrogate=off`` never touches any of
this: the exact path is byte-for-byte the historical one.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..obs import trace as obs
from .archive import design_encoding_dim, flatten_design  # noqa: F401

F = jnp.float32

# transfer-outcome records needed before the non-linear trust head takes
# over from the ridge TrustModel (below it, a 2-layer MLP just memorizes)
NONLINEAR_TRUST_MIN = 32

_EPS = 1e-8
_METRIC_FLOOR = 1e-6        # raw metrics are positive; the clip only
#                             guards degenerate/penalized rows


@dataclasses.dataclass(frozen=True)
class SurrogateConfig:
    """Gating + fitting knobs (hashable: rides compile cache keys).

    ``exact_frac`` of each generation's candidate children get exact
    evaluations (the rest live or die on the surrogate's ranking);
    ``beta`` sets LCB optimism (predicted mean − beta·ensemble std);
    ``tau`` is the per-candidate normalized-disagreement level above
    which a candidate is FORCED into the exact-evaluation slots whatever
    its rank; ``fallback_tau`` is the segment-mean disagreement above
    which the service abandons the surrogate for the rest of the run
    (counted as a fallback).  ``min_rows`` gates fitting itself: below
    it the query runs the exact path, bit-identical to surrogate=off."""
    exact_frac: float = 0.5
    beta: float = 1.0
    tau: float = 1.0
    fallback_tau: float = 1.5
    min_rows: int = 64
    members: int = 4
    hidden: int = 48
    epochs: int = 300
    lr: float = 3e-3
    seed: int = 0

    def n_exact(self, pop: int) -> int:
        """Exact-evaluation slots per generation for a ``pop``-wide
        candidate batch: at least 1, at most the whole batch."""
        return min(max(int(round(pop * self.exact_frac)), 1), pop)


# ---------------------------------------------------------------------------
# ensemble MLP core (shared by the metric surrogate and the trust head)
# ---------------------------------------------------------------------------
def _init_params(key, members: int, din: int, hidden: int, dout: int
                 ) -> Dict[str, jnp.ndarray]:
    def member(k):
        k1, k2, k3 = jax.random.split(k, 3)
        s1 = jnp.sqrt(2.0 / din)
        s2 = jnp.sqrt(2.0 / hidden)
        return dict(
            W1=jax.random.normal(k1, (din, hidden), F) * s1,
            b1=jnp.zeros((hidden,), F),
            W2=jax.random.normal(k2, (hidden, hidden), F) * s2,
            b2=jnp.zeros((hidden,), F),
            W3=jax.random.normal(k3, (hidden, dout), F) * s2,
            b3=jnp.zeros((dout,), F))
    return jax.vmap(member)(jax.random.split(key, members))


def ensemble_forward(params: Dict, Xn) -> jnp.ndarray:
    """(M, n, dout) member outputs for normalized inputs ``Xn`` (n, din).
    The exact math the gated NSGA scan inlines — two tanh hidden layers,
    linear head."""
    def one(p):
        h = jnp.tanh(Xn @ p["W1"] + p["b1"])
        h = jnp.tanh(h @ p["W2"] + p["b2"])
        return h @ p["W3"] + p["b3"]
    return jax.vmap(one)(params)


def _fit_ensemble(Xn, Yn, members: int, hidden: int, epochs: int,
                  lr: float, key) -> Dict[str, jnp.ndarray]:
    """Train ``members`` MLPs on bootstrap resamples of (Xn, Yn) with one
    jitted full-batch Adam scan — ensemble diversity comes from both the
    per-member init and the per-member resample."""
    n, din = Xn.shape
    dout = Yn.shape[1]
    k_init, k_boot = jax.random.split(jnp.asarray(key))
    params = _init_params(k_init, members, din, hidden, dout)
    idx = jax.vmap(lambda k: jax.random.randint(k, (n,), 0, n))(
        jax.random.split(k_boot, members))
    Xb = jnp.asarray(Xn, F)[idx]          # (M, n, din)
    Yb = jnp.asarray(Yn, F)[idx]          # (M, n, dout)

    def loss(p, X, Y):
        h = jnp.tanh(X @ p["W1"] + p["b1"])
        h = jnp.tanh(h @ p["W2"] + p["b2"])
        return jnp.mean((h @ p["W3"] + p["b3"] - Y) ** 2)

    b1, b2, eps = 0.9, 0.999, 1e-8

    def train_member(p0, X, Y):
        m0 = jax.tree.map(jnp.zeros_like, p0)
        v0 = jax.tree.map(jnp.zeros_like, p0)

        def step(carry, t):
            p, m, v = carry
            g = jax.grad(loss)(p, X, Y)
            m = jax.tree.map(lambda a, b_: b1 * a + (1 - b1) * b_, m, g)
            v = jax.tree.map(lambda a, b_: b2 * a + (1 - b2) * b_ ** 2,
                             v, g)
            c1 = 1 - b1 ** (t + 1)
            c2 = 1 - b2 ** (t + 1)
            p = jax.tree.map(
                lambda w, mm, vv: w - lr * (mm / c1)
                / (jnp.sqrt(vv / c2) + eps), p, m, v)
            return (p, m, v), ()

        (p, _, _), _ = jax.lax.scan(step, (p0, m0, v0),
                                    jnp.arange(epochs, dtype=F))
        return p

    return jax.jit(jax.vmap(train_member))(params, Xb, Yb)


# ---------------------------------------------------------------------------
# the metric surrogate
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class Surrogate:
    """A fitted ensemble surrogate: (design encoding | workload
    embedding) -> log 4-metric vector, with ensemble spread as the
    uncertainty signal.  ``params`` leaves carry a leading member axis;
    normalization statistics make the model portable across metric
    scales (zero-variance columns normalize to exactly 0, never NaN)."""
    params: Dict[str, np.ndarray]
    x_mean: np.ndarray
    x_std: np.ndarray
    y_mean: np.ndarray
    y_std: np.ndarray
    config: SurrogateConfig
    n_rows: int

    @property
    def in_dim(self) -> int:
        return int(self.x_mean.shape[0])

    @property
    def static_shape(self) -> Tuple[int, int, int]:
        """(members, hidden, in_dim): everything the gated scan compiles
        against — two surrogates of equal static_shape share a runner."""
        return (int(self.params["b1"].shape[0]),
                int(self.params["W1"].shape[2]), self.in_dim)

    def _normalize(self, X):
        return (jnp.asarray(X, F) - self.x_mean) / self.x_std

    def predict(self, X) -> Tuple[np.ndarray, np.ndarray]:
        """(mean, std) of the predicted LOG-metric vectors, (n, n_obj)
        each — std is the de-normalized ensemble spread."""
        out = ensemble_forward(
            jax.tree.map(jnp.asarray, self.params), self._normalize(X))
        mean = np.asarray(jnp.mean(out, 0)) * self.y_std + self.y_mean
        std = np.asarray(jnp.std(out, 0)) * self.y_std
        return mean, std

    def disagreement(self, X) -> np.ndarray:
        """(n,) mean NORMALIZED ensemble std per candidate — the scale-
        free signal the gate thresholds (``config.tau``)."""
        out = ensemble_forward(
            jax.tree.map(jnp.asarray, self.params), self._normalize(X))
        return np.asarray(jnp.mean(jnp.std(out, 0), axis=-1))

    def scan_arrays(self, embedding) -> Dict[str, jnp.ndarray]:
        """The runtime operand dict the gated NSGA runner consumes: the
        ensemble weights, input normalization, and this problem's
        workload embedding.  Values ride as arrays — refitting the
        surrogate never recompiles the scan."""
        d = {k: jnp.asarray(v) for k, v in self.params.items()}
        d["x_mean"] = jnp.asarray(self.x_mean, F)
        d["x_std"] = jnp.asarray(self.x_std, F)
        d["emb"] = jnp.asarray(np.asarray(embedding).ravel(), F)
        return d

    def digest(self) -> str:
        """Content hash of the fitted model — part of the resume-
        checkpoint signature: a checkpoint written under a different
        surrogate answers a DIFFERENT numeric stream."""
        h = hashlib.sha256()
        for k in sorted(self.params):
            h.update(k.encode())
            h.update(np.ascontiguousarray(self.params[k]).tobytes())
        for a in (self.x_mean, self.x_std, self.y_mean, self.y_std):
            h.update(np.ascontiguousarray(a).tobytes())
        h.update(repr(self.config).encode())
        return h.hexdigest()[:16]


def _norm_stats(A) -> Tuple[np.ndarray, np.ndarray]:
    """(mean, std) with the zero-variance guard: constant columns get
    std 1, so they normalize to exactly 0 instead of NaN."""
    mean = A.mean(axis=0)
    std = A.std(axis=0)
    return mean.astype(np.float32), np.where(
        std < _EPS, 1.0, std).astype(np.float32)


def fit_surrogate(X, Y, cfg: SurrogateConfig = SurrogateConfig(),
                  key=None) -> Optional[Surrogate]:
    """Fit the ensemble on harvested rows: ``X`` (n, din) float design
    encodings + embeddings, ``Y`` (n, n_obj) RAW metric rows (trained in
    log space — the same scale the NSGA selection ranks on).  ``None``
    below ``cfg.min_rows`` usable rows; non-finite rows are dropped."""
    X = np.asarray(X, np.float32)
    Y = np.asarray(Y, np.float64)
    if X.ndim != 2 or Y.ndim != 2 or X.shape[0] != Y.shape[0]:
        raise ValueError(f"bad dataset shapes {X.shape} / {Y.shape}")
    ylog = np.log(np.maximum(Y, _METRIC_FLOOR))
    ok = np.all(np.isfinite(X), axis=1) & np.all(np.isfinite(ylog), axis=1)
    X, ylog = X[ok], ylog[ok]
    if X.shape[0] < max(int(cfg.min_rows), 2):
        return None
    x_mean, x_std = _norm_stats(X)
    y_mean, y_std = _norm_stats(ylog)
    Xn = (X - x_mean) / x_std
    Yn = ((ylog - y_mean) / y_std).astype(np.float32)
    key = jax.random.PRNGKey(cfg.seed) if key is None else key
    with obs.span("surrogate.fit", rows=int(X.shape[0]),
                  members=cfg.members):
        params = _fit_ensemble(Xn, Yn, cfg.members, cfg.hidden,
                               cfg.epochs, cfg.lr, key)
    obs.inc("explore.surrogate.fits")
    obs.inc("explore.surrogate.rows", int(X.shape[0]))
    return Surrogate(
        params={k: np.asarray(v) for k, v in params.items()},
        x_mean=x_mean, x_std=x_std,
        y_mean=y_mean.astype(np.float32), y_std=y_std,
        config=cfg, n_rows=int(X.shape[0]))


def harvest_rows(index: Sequence[Tuple[str, np.ndarray]],
                 load_archive: Callable[[str], Optional[object]],
                 design_dim: int, embed_dim: int
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Assemble the surrogate training set from cached archives.

    ``index`` is ``ArchiveManifest.export_index`` output; ``load_archive``
    resolves a key to a ``ParetoArchive`` (or ``None`` — broken/absent
    archives are skipped, counted on
    ``explore.surrogate.skipped_archives``).  Archives whose design
    encoding or embedding dimension disagrees with the target problem's
    are skipped the same way — a drifted-layout neighbor must not poison
    (or crash) the fit.  Returns ``X`` (n, design_dim + embed_dim)
    float32 and ``Y`` (n, n_obj) float64 raw metrics."""
    Xs: List[np.ndarray] = []
    Ys: List[np.ndarray] = []
    skipped = 0
    for key, emb in index:
        emb = np.asarray(emb, np.float64).ravel()
        if emb.size != embed_dim:
            skipped += 1
            continue
        arc = load_archive(key)
        if arc is None:
            skipped += 1
            continue
        Xd, Y = arc.export_rows()
        if Xd.shape[1] != design_dim:
            skipped += 1
            continue
        if not len(Xd):
            continue
        Xs.append(np.concatenate(
            [Xd, np.tile(emb.astype(np.float32), (len(Xd), 1))], axis=1))
        Ys.append(Y)
    if skipped:
        obs.inc("explore.surrogate.skipped_archives", skipped)
    if not Xs:
        return (np.zeros((0, design_dim + embed_dim), np.float32),
                np.zeros((0, 4), np.float64))
    return np.concatenate(Xs), np.concatenate(Ys)


# ---------------------------------------------------------------------------
# the non-linear transfer-trust head
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class NonlinearTrustModel:
    """MLP lift model over |embedding delta| features — the deep-record
    replacement for the ridge ``TrustModel``, same contract: ``predict``
    clamps at 0 and answers dimension-mismatched deltas with a neutral
    0.0 (consumers divide distances by ``1 + lift``)."""
    params: Dict[str, np.ndarray]
    x_mean: np.ndarray
    x_std: np.ndarray
    y_mean: float
    y_std: float
    dim: int

    def predict(self, delta) -> float:
        d = np.abs(np.asarray(delta, np.float64).ravel())
        if d.shape[0] != self.dim:
            return 0.0
        xn = (d.astype(np.float32) - self.x_mean) / self.x_std
        out = ensemble_forward(
            jax.tree.map(jnp.asarray, self.params), jnp.asarray(xn[None]))
        lift = float(jnp.mean(out)) * self.y_std + self.y_mean
        return max(lift, 0.0)


def fit_nonlinear_trust(records: Sequence[Dict],
                        dim: Optional[int] = None,
                        min_records: int = NONLINEAR_TRUST_MIN,
                        members: int = 2, hidden: int = 16,
                        epochs: int = 300, lr: float = 1e-2,
                        seed: int = 0) -> Optional[NonlinearTrustModel]:
    """Fit the non-linear trust head on transfer-outcome records (dicts
    with ``delta`` and ``lift``), modal-dim filtered exactly like
    ``fit_trust_model`` (skips counted on the same
    ``explore.trust.skipped_records`` counter).  ``None`` below
    ``min_records`` usable rows — the caller falls back to the ridge."""
    usable = [r for r in records
              if np.all(np.isfinite(np.asarray(r["delta"], np.float64)))
              and np.isfinite(r["lift"])]
    if not usable:
        return None
    sizes = [np.asarray(r["delta"]).size for r in usable]
    if dim is None:
        counts: Dict[int, int] = {}
        for s in sizes:
            counts[s] = counts.get(s, 0) + 1
        dim = max(counts, key=lambda s: (counts[s],
                                         max(i for i, sz in enumerate(sizes)
                                             if sz == s)))
    kept = [r for r in usable if np.asarray(r["delta"]).size == dim]
    if len(kept) < len(usable):
        obs.inc("explore.trust.skipped_records", len(usable) - len(kept))
    if len(kept) < max(int(min_records), 2):
        return None
    X = np.stack([np.abs(np.asarray(r["delta"], np.float64).ravel())
                  for r in kept]).astype(np.float32)
    y = np.asarray([float(r["lift"]) for r in kept], np.float64)[:, None]
    x_mean, x_std = _norm_stats(X)
    y_mean, y_std = _norm_stats(y)
    Xn = (X - x_mean) / x_std
    Yn = ((y - y_mean) / y_std).astype(np.float32)
    params = _fit_ensemble(Xn, Yn, members, hidden, epochs, lr,
                           jax.random.PRNGKey(seed))
    obs.inc("explore.trust.nonlinear_fits")
    return NonlinearTrustModel(
        params={k: np.asarray(v) for k, v in params.items()},
        x_mean=x_mean, x_std=x_std,
        y_mean=float(y_mean[0]), y_std=float(y_std[0]), dim=int(dim))
