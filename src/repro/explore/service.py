"""The exploration *service*: ``explore(graph, objectives, budget)``.

Turns the one-shot DSE scripts into a reusable, cache-accelerated query
API.  Three tricks make repeated / concurrent exploration cheap:

* **Query batching** — ``explore_batch`` groups concurrent queries whose
  (SystemSpec, DesignSpace) hash matches into ONE NSGA-II run over the
  union of their objectives and the max of their budgets; every query then
  projects its own front out of the shared archive.  One vmapped
  evaluation serves the whole group.
* **Archive cache** — before spending compute, the service consults the
  per-problem ``ParetoArchive`` (in memory, then on disk under
  ``cache_dir``).  A query whose budget is already covered by recorded
  evaluations is answered straight from the archive: no evaluator, no jit.
* **Warm starts** — when compute IS needed, the initial population is
  seeded from the cached front (topped up with ``random_design`` samples),
  so follow-up queries with bigger budgets refine rather than restart.

The archive rows are always the full 4-metric vector (``METRIC_KEYS``), so
one cache serves latency-energy, latency-cost, ... projections alike.
"""

from __future__ import annotations

import dataclasses
import os
import time
import warnings
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.constants import DEFAULT_TECH
from ..core.encoding import DesignSpace, random_design
from ..core.evaluate import SystemSpec
from ..core.optimizer import METRIC_KEYS
from ..core.workload import WorkloadGraph
from .archive import ParetoArchive, pareto_front, spec_space_key
from .nsga import NSGAConfig, make_nsga

DEFAULT_CACHE_DIR = "artifacts/explore_cache"
DEFAULT_OBJECTIVES = ("latency_ns", "cost_usd")


@dataclasses.dataclass
class ExploreQuery:
    """One front request.  ``space_kwargs`` are forwarded to ``DesignSpace``
    (e.g. ``max_shape``, ``max_total_pes``) and participate in the cache
    key, so differently-bounded explorations never share an archive."""
    graph: WorkloadGraph
    objectives: Tuple[str, ...] = DEFAULT_OBJECTIVES
    budget: int = 2048              # total design evaluations this query
    #                                 is willing to pay for (cold)
    ch_max: int = 4
    space_kwargs: Optional[Dict] = None

    def __post_init__(self):
        self.objectives = tuple(self.objectives)
        if not self.objectives:
            raise ValueError("at least one objective required")
        bad = [o for o in self.objectives if o not in METRIC_KEYS]
        if bad:
            raise ValueError(f"unknown objectives {bad}; pick from "
                             f"{METRIC_KEYS}")


@dataclasses.dataclass
class ExploreResult:
    objectives: Tuple[str, ...]
    front_objs: np.ndarray          # (n, len(objectives)) nondominated rows
    front_metrics: np.ndarray       # (n, 4) full METRIC_KEYS rows
    front_designs: List[Dict[str, np.ndarray]]
    from_cache: bool                # True => served without any evaluation
    n_evals_run: int                # evaluations spent by the shared run
    #                                 that answered this query's GROUP (the
    #                                 cost is reported on every result of
    #                                 the group, booked once in the
    #                                 archive); 0 when served from cache
    elapsed_s: float                # wall time of the group's answer
    cache_key: str


class ExplorationService:
    """Holds per-problem archives (memory + disk) and a shared NSGA engine.

    ``cache_dir`` defaults to ``$REPRO_EXPLORE_CACHE`` or
    ``artifacts/explore_cache``; archives live at ``<cache_dir>/<key>.npz``.
    """

    def __init__(self, cache_dir=None, capacity: int = 256,
                 nsga: NSGAConfig = NSGAConfig(), tech=None):
        # nsga.generations is not used on the query path — each query's
        # budget sets the scan length (see _refine); the config's pop /
        # fields / crossover / mutation / immigrant knobs apply as given.
        self.cache_dir = Path(
            cache_dir or os.environ.get("REPRO_EXPLORE_CACHE",
                                        DEFAULT_CACHE_DIR))
        self.capacity = int(capacity)
        self.nsga = nsga
        self.tech = tech
        self._archives: Dict[str, ParetoArchive] = {}

    # ---- cache plumbing ----------------------------------------------------
    def _path(self, key: str) -> Path:
        return self.cache_dir / f"{key}.npz"

    def problem_key(self, spec: SystemSpec, space: DesignSpace) -> str:
        """Archive identity for one exploration problem under THIS
        service's tech constants — metrics evaluated under a different
        ``TechConstants`` must never be served as this problem's front."""
        return spec_space_key(spec, space, extra=self.tech or DEFAULT_TECH)

    def archive_for(self, spec: SystemSpec, space: DesignSpace,
                    key: Optional[str] = None) -> ParetoArchive:
        """The (possibly empty) archive for one exploration problem —
        memory first, then disk, else freshly created."""
        key = key or self.problem_key(spec, space)
        if key in self._archives:
            return self._archives[key]
        arc = None
        p = self._path(key)
        if p.exists():
            try:
                arc = ParetoArchive.load(p)
            except Exception as e:          # a cache is disposable: never
                #                             let a damaged file kill a query
                warnings.warn(f"discarding unreadable explore cache {p}: {e}")
                p.unlink(missing_ok=True)
        if arc is None:
            template = jax.tree.map(
                np.asarray, random_design(jax.random.PRNGKey(0), space))
            arc = ParetoArchive(self.capacity, template,
                                n_obj=len(METRIC_KEYS),
                                obj_keys=METRIC_KEYS)
        self._archives[key] = arc
        return arc

    def save(self, key: str):
        if key in self._archives:
            self._archives[key].save(self._path(key))

    # ---- the query API -----------------------------------------------------
    def explore(self, graph: WorkloadGraph,
                objectives: Sequence[str] = DEFAULT_OBJECTIVES,
                budget: int = 2048, ch_max: int = 4,
                space_kwargs: Optional[Dict] = None,
                key=None) -> ExploreResult:
        q = ExploreQuery(graph, tuple(objectives), budget, ch_max,
                         space_kwargs)
        return self.explore_batch([q], key=key)[0]

    def explore_batch(self, queries: Sequence[ExploreQuery],
                      key=None) -> List[ExploreResult]:
        """Answer a batch of queries, merging same-problem queries into one
        vmapped NSGA run (union objectives, max budget)."""
        key = jax.random.PRNGKey(0) if key is None else key
        # group by canonical problem hash
        groups: Dict[str, Dict] = {}
        order: List[Tuple[str, int]] = []      # (cache_key, slot in group)
        for q in queries:
            spec = SystemSpec.build(q.graph, ch_max=q.ch_max)
            space = DesignSpace(spec, **(q.space_kwargs or {}))
            ck = self.problem_key(spec, space)
            g = groups.setdefault(ck, dict(spec=spec, space=space,
                                           queries=[]))
            order.append((ck, len(g["queries"])))
            g["queries"].append(q)

        group_results: Dict[str, List[ExploreResult]] = {}
        for i, (ck, g) in enumerate(groups.items()):
            group_results[ck] = self._run_group(
                ck, g["spec"], g["space"], g["queries"],
                jax.random.fold_in(key, i))
        return [group_results[ck][slot] for ck, slot in order]

    # ---- one problem group -------------------------------------------------
    def _run_group(self, ck: str, spec: SystemSpec, space: DesignSpace,
                   queries: List[ExploreQuery], key) -> List[ExploreResult]:
        t0 = time.perf_counter()
        arc = self.archive_for(spec, space, key=ck)
        budget = max(q.budget for q in queries)
        union = tuple(k for k in METRIC_KEYS
                      if any(k in q.objectives for q in queries))
        # warm only when the recorded evaluations cover BOTH the budget and
        # every queried objective — points found while optimizing other
        # axes are no substitute for search effort on these ones
        warm = (len(arc) > 0 and arc.n_evals >= budget
                and all(o in arc.searched for o in union))

        n_run = 0
        if not warm:
            n_run = self._refine(arc, spec, space, union, budget, key)
            arc.searched = tuple(k for k in METRIC_KEYS
                                 if k in arc.searched or k in union)
            self.save(ck)

        elapsed = time.perf_counter() - t0
        designs, metrics = arc.front()
        results = []
        for q in queries:
            idx = [METRIC_KEYS.index(o) for o in q.objectives]
            cols = metrics[:, idx]
            keep = pareto_front(cols) if len(cols) else []
            results.append(ExploreResult(
                objectives=q.objectives,
                front_objs=cols[keep],
                front_metrics=metrics[keep],
                front_designs=[{k: v[i] for k, v in designs.items()}
                               for i in keep],
                from_cache=warm, n_evals_run=n_run,
                elapsed_s=elapsed, cache_key=ck))
        return results

    def _refine(self, arc: ParetoArchive, spec: SystemSpec,
                space: DesignSpace, objectives: Tuple[str, ...],
                budget: int, key) -> int:
        """Spend ~``budget`` evaluations improving the archive: warm-start
        the population from the cached front, evolve, re-insert.

        The query budget — not ``self.nsga.generations`` — fixes the scan
        length here; both the population (for sub-``nsga.pop`` budgets) and
        the generation count are quantized to powers of two, so a
        long-lived service compiles O(log^2(max_budget)) scan variants
        instead of one per distinct budget; the service's ``nsga`` config
        supplies the population ceiling and variation knobs.
        """
        pop = self.nsga.pop
        if budget < pop:        # pow2 >= budget, floored at 8
            pop = min(pop, max(8, 1 << max(0, budget - 1).bit_length()))
        generations = -(-budget // pop)                 # ceil(budget / pop)
        generations = 1 << max(0, generations - 1).bit_length() \
            if generations > 1 else 1
        cfg = dataclasses.replace(self.nsga, pop=pop,
                                  generations=generations)
        k_init, k_run = jax.random.split(key)

        pop0 = jax.vmap(lambda k: random_design(k, space))(
            jax.random.split(k_init, pop))
        fr_designs, _ = arc.front()
        n_warm = min(len(arc), pop)
        if n_warm:
            pop0 = {k: jnp.concatenate(
                [jnp.asarray(fr_designs[k][:n_warm]),
                 jnp.asarray(v)[n_warm:]])
                for k, v in pop0.items()}

        run = make_nsga(spec, space, objectives, cfg, tech=self.tech)
        _pop, _raw, _sel, ev_designs, ev_raw, ev_feas = run(k_run, pop0)
        # archive EVERY evaluation of the run, not just the survivors —
        # masked to feasible designs so the archive (and every front served
        # from it) never carries a constraint-violating point
        arc.insert(
            jax.tree.map(lambda x: x.reshape((-1,) + x.shape[2:]),
                         ev_designs),
            ev_raw.reshape(-1, ev_raw.shape[-1]),
            mask=ev_feas.reshape(-1), count_evals=False)
        n_run = pop * generations      # one vmapped evaluation per scan step
        arc.n_evals += n_run
        return n_run


# ---------------------------------------------------------------------------
# module-level convenience: a default singleton service
# ---------------------------------------------------------------------------
_DEFAULT: Optional[ExplorationService] = None


def default_service(**kwargs) -> ExplorationService:
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = ExplorationService(**kwargs)
    elif kwargs:
        raise RuntimeError(
            "the default exploration service is already initialized; "
            "construct ExplorationService(...) directly for a custom "
            "configuration")
    return _DEFAULT


def explore(graph: WorkloadGraph,
            objectives: Sequence[str] = DEFAULT_OBJECTIVES,
            budget: int = 2048, ch_max: int = 4,
            space_kwargs: Optional[Dict] = None,
            service: Optional[ExplorationService] = None,
            key=None) -> ExploreResult:
    """One-call front query against the process-wide default service."""
    svc = service or default_service()
    return svc.explore(graph, objectives, budget, ch_max, space_kwargs,
                       key=key)
