"""The exploration *service*: the NSGA engine backend behind
``repro.api.Session.submit`` (``run_queries``), plus the historic
``explore`` / ``explore_batch`` entry points as deprecation shims.

Turns the one-shot DSE scripts into a reusable, cache-accelerated query
backend.  Four tricks make repeated / concurrent exploration cheap:

* **Query batching** — ``explore_batch`` groups concurrent queries whose
  (SystemSpec, DesignSpace) hash matches into ONE NSGA-II run over the
  union of their objectives and the max of their budgets; every query then
  projects its own front out of the shared archive.  One vmapped
  evaluation serves the whole group.
* **Archive cache** — before spending compute, the service consults the
  per-problem ``ParetoArchive`` (in memory, then on disk under
  ``cache_dir``).  A query whose budget is already covered by recorded
  evaluations is answered straight from the archive: no evaluator, no jit.
* **Warm starts** — when compute IS needed, the initial population is
  seeded from the cached front (topped up with ``random_design`` samples),
  so follow-up queries with bigger budgets refine rather than restart.
* **Adaptive budgets** (``BudgetPolicy``) — a query's budget is spent in
  quantized scan *segments*; after each segment the archive-projected
  hypervolume of the queried objective pairs is checked, and once its
  relative improvement stays below ``plateau_rel`` for ``patience``
  consecutive segments the refinement stops early.  The unspent
  evaluations are *banked* in a per-problem budget ledger, and
  ``explore_batch`` reallocates banked credit to the batch's
  under-explored, still-improving archives (lowest eval-count first).

The archive rows are always the full 4-metric vector (``METRIC_KEYS``), so
one cache serves latency-energy, latency-cost, ... projections alike.
Every cold answer carries a ``ConvergenceTrace`` — the per-generation
telemetry the NSGA scan emits for free — and a summary is persisted with
the archive npz.

* **Cross-workload transfer v2** — ``transfer=True`` seeds cold starts
  AND budget-increase refinements from the migrated fronts of the best
  cached neighbors (``ArchiveManifest.nearest``, reweighted by the
  manifest's fitted ``TrustModel`` once enough per-(src, dst) outcomes
  accumulate); seeds dedup against the destination archive's own front
  (``portable_signature``) and every seeded run books its observed
  hypervolume lift back into the trust table at zero extra evaluations.
  The manifest itself is growth-bounded (``ManifestPolicy``: LRU
  eviction + embedding-space dedup) and mtime-reloaded, so fleet-shared
  cache directories stay consistent.
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
import os
import threading
import time
import warnings
from collections import OrderedDict
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import obs
from ..core.constants import DEFAULT_TECH, TechConstants, tech_key
from ..core.encoding import (DesignSpace, balanced_init, migrate,
                             portable_signature, random_design, repair,
                             space_digest)
from ..core.evaluate import SystemSpec
from ..core.optimizer import METRIC_KEYS
from ..core.workload import (WorkloadGraph, embedding_delta,
                             workload_features)
from .archive import (MANIFEST_NAME, ArchiveManifest, ConvergenceTrace,
                      ManifestPolicy, ParetoArchive, atomic_savez,
                      design_encoding_dim, objective_pairs, pareto_front,
                      spec_space_key)
from . import quantize
from .locks import LockTimeout, file_lock, lock_path
from .nsga import (ISLAND_AXIS, NSGAConfig, _static_key, make_nsga,
                   make_nsga_fused, make_nsga_gated)
from .surrogate import Surrogate, SurrogateConfig, fit_surrogate, harvest_rows

# the default archive cache is anchored to the repo root (four levels above
# this file: src/repro/explore/service.py), NOT the process CWD — otherwise
# every working directory silently grows its own fragmented cache.
# $REPRO_EXPLORE_CACHE (the historic name), $REPRO_CACHE_DIR (the fleet-wide
# name) or an explicit ``cache_dir`` override it, in that order.
DEFAULT_CACHE_DIR = (Path(__file__).resolve().parents[3]
                     / "artifacts" / "explore_cache")
DEFAULT_OBJECTIVES = ("latency_ns", "cost_usd")


def resolve_cache_dir(cache_dir=None) -> Path:
    """The cache directory a service will really use, validated: an
    explicit ``cache_dir`` wins, then ``$REPRO_EXPLORE_CACHE``, then
    ``$REPRO_CACHE_DIR``, then the repo-anchored default.  The directory
    is created here (so a fleet-wide env var pointing somewhere unwritable
    fails loudly at service CONSTRUCTION, not at the first archive save
    deep inside a query)."""
    p = Path(cache_dir
             or os.environ.get("REPRO_EXPLORE_CACHE")
             or os.environ.get("REPRO_CACHE_DIR")
             or DEFAULT_CACHE_DIR).expanduser()
    try:
        p.mkdir(parents=True, exist_ok=True)
    except OSError as e:
        raise ValueError(f"explore cache directory {p} is unusable "
                         f"(check REPRO_CACHE_DIR / REPRO_EXPLORE_CACHE / "
                         f"cache_dir): {e}") from e
    if not os.access(p, os.W_OK):      # mkdir(exist_ok) is a silent no-op
        #                                on a pre-existing read-only dir
        raise ValueError(f"explore cache directory {p} is not writable "
                         f"(check REPRO_CACHE_DIR / REPRO_EXPLORE_CACHE / "
                         f"cache_dir)")
    return p


# `_pow2` kept as a module-level alias: the quantization lattice now
# lives in `repro.explore.quantize` (shared with megabatch bucketing and
# `api` plan math), but external callers historically import it from here.
_pow2 = quantize.pow2_ceil


def _transfer_lift(trace: ConvergenceTrace) -> float:
    """Front-loadedness of one seeded run, in [0, 1]: the mean of the
    per-generation population-front hypervolume (``hv_gen``) normalized
    into the run's own [min, max] range — the area under the normalized
    trajectory.  A run whose seeded start already carried the quality
    spends every generation near its own maximum (→ 1); a run that had
    to search for everything climbs slowly (≈ 0.5 for a linear climb,
    lower for a late jump).  Self-normalized per run, so values compare
    across problems and archive maturities, at zero extra evaluations;
    a flat trajectory carries no temporal signal either way and records
    a neutral 0.5.  (Under elitist selection the trajectory is
    near-monotone, so any single-generation statistic — e.g. generation
    0's own position — degenerates to ~0 for every run; the area does
    not.)"""
    hv = trace.hv_gen if trace.hv_gen is not None else trace.hypervolume
    if hv is None or hv.size == 0:
        return 0.0
    col = np.asarray(hv[:, 0], np.float64)
    lo, hi = float(col.min()), float(col.max())
    if hi - lo <= 1e-9 * max(abs(hi), 1.0):
        return 0.5                  # flat run: no temporal signal at all
    return float(np.clip(np.mean((col - lo) / (hi - lo)), 0.0, 1.0))


@dataclasses.dataclass(frozen=True)
class BudgetPolicy:
    """How a query's evaluation budget is spent.

    ``chunk_generations`` splits the NSGA scan into segments of that many
    generations (quantized to a power of two, so segment runners compile
    once per size); between segments the service is on the host and can
    observe the archive.  With ``adaptive`` on, refinement stops early
    once EVERY queried objective pair's archive-projected hypervolume
    improved by less than ``plateau_rel`` (relative) for ``patience``
    consecutive segments; the unspent evaluations are banked in the
    service's per-problem ledger.  ``reallocate`` lets ``explore_batch``
    spend banked credit on the batch's under-explored, still-improving
    archives.  Single-objective queries have no hypervolume pairs and
    never stop early.

    ``megabatch`` lets ``run_queries`` fuse DIFFERENT problems whose spec
    arrays and quantized schedules coincide into one vmapped dispatch
    (lane counts pow2-padded, capped at ``megabatch_lanes``); individual
    queries opt out via ``ExploreQuery.megabatch=False``, and the fused
    path is skipped entirely under ``resume=True`` (checkpoints stay
    per-group) or when the service shards over a device mesh."""
    chunk_generations: int = 8
    plateau_rel: float = 0.005
    patience: int = 2
    adaptive: bool = True
    reallocate: bool = True
    megabatch: bool = True
    megabatch_lanes: int = 8


@dataclasses.dataclass
class PlateauState:
    """The plateau detector's memory across the scan segments refining
    ONE archive: the previous segment's archive-projected hypervolume
    vector and the current below-threshold streak.

    Held per problem *group* (not per ``_refine`` call) so a
    checkpointed resume continues the streak exactly where the killed
    run left it, and so the detector's history is an explicit object
    with an explicit lifetime: ``reset()`` forgets it, and is called
    when a reallocation top-up grants fresh budget — a topped-up archive
    must earn a NEW streak before being declared plateaued, never be
    stopped one segment into its top-up on the strength of pre-top-up
    stagnation."""
    last_hv: Optional[np.ndarray] = None
    streak: int = 0

    def observe(self, hv_now, rel_tol: float, count: bool = True) -> int:
        """Record one segment's hypervolume vector and return the
        updated streak.  ``count=False`` records the vector without
        judging it (the empty-archive case: nothing found yet is
        stagnation, not convergence — it must never feed the streak,
        but the NEXT segment still compares against this one)."""
        hv_now = np.asarray(hv_now, np.float64)
        if (count and self.last_hv is not None
                and self.last_hv.shape == hv_now.shape):
            rel = (hv_now - self.last_hv) / np.maximum(
                np.abs(self.last_hv), 1e-9)
            self.streak = self.streak + 1 if np.all(rel < rel_tol) else 0
        self.last_hv = hv_now
        return self.streak

    def reset(self) -> "PlateauState":
        self.last_hv = None
        self.streak = 0
        return self


class RunControl:
    """Cooperative stop token for a running submission.  ``stop()``
    (from any thread) makes the engine break at the NEXT scan-segment
    boundary: the segment in flight completes, the resume checkpoint
    stays on disk, and every result of the interrupted submission
    carries ``interrupted=True`` with ``budget_covered`` NOT bumped — a
    later ``resume=True`` submission of the same problem picks up from
    that checkpoint and spends only the residual budget."""

    __slots__ = ("_stop",)

    def __init__(self):
        self._stop = threading.Event()

    def stop(self) -> None:
        self._stop.set()

    @property
    def stopped(self) -> bool:
        return self._stop.is_set()


@dataclasses.dataclass
class ExploreQuery:
    """One front request.  ``space_kwargs`` are forwarded to ``DesignSpace``
    (e.g. ``max_shape``, ``max_total_pes``) and participate in the cache
    key, so differently-bounded explorations never share an archive.

    ``spec``/``space`` optionally carry a prebuilt problem (the
    ``repro.api`` path builds them once on its ``Problem``); when absent
    the service derives them from ``graph``/``ch_max``/``space_kwargs``."""
    graph: WorkloadGraph
    objectives: Tuple[str, ...] = DEFAULT_OBJECTIVES
    budget: int = 2048              # total design evaluations this query
    #                                 is willing to pay for (cold)
    ch_max: int = 4
    space_kwargs: Optional[Dict] = None
    transfer: bool = False          # seed cold starts AND budget-increase
    #                                 refinements from migrated fronts of
    #                                 the trust-ranked nearest cached specs
    #                                 (balanced_init fallback on a cold
    #                                 start with no neighbor; resumed
    #                                 archives dedup seeds against their
    #                                 own front and take no fallback)
    spec: Optional[SystemSpec] = None
    space: Optional[DesignSpace] = None
    megabatch: bool = True          # allow this query's group to fuse with
    #                                 other problems into one compiled
    #                                 dispatch (see BudgetPolicy.megabatch)
    surrogate: Optional[Dict] = None    # surrogate-gated evaluation: None
    #                                 (off — the exact path, byte-for-byte
    #                                 historical), or a dict of
    #                                 ``SurrogateConfig`` overrides (``{}``
    #                                 for defaults; ``True`` normalizes to
    #                                 ``{}``).  An extra ``"exclude"`` key
    #                                 lists archive keys held out of
    #                                 surrogate training (benchmark
    #                                 holdouts).  With no usable training
    #                                 rows in the fleet cache the query
    #                                 silently runs exact — bit-identical
    #                                 to surrogate=None.

    def __post_init__(self):
        self.objectives = tuple(self.objectives)
        if not self.objectives:
            raise ValueError("at least one objective required")
        bad = [o for o in self.objectives if o not in METRIC_KEYS]
        if bad:
            raise ValueError(f"unknown objectives {bad}; pick from "
                             f"{METRIC_KEYS}")
        if self.surrogate is True:
            self.surrogate = {}
        if self.surrogate is not None and not isinstance(self.surrogate,
                                                         dict):
            raise ValueError("surrogate must be None, True or a dict of "
                             "SurrogateConfig overrides")

    def build(self) -> Tuple[SystemSpec, DesignSpace]:
        """This query's (spec, space), built on demand and memoized."""
        if self.spec is None:
            self.spec = SystemSpec.build(self.graph, ch_max=self.ch_max)
        if self.space is None:
            self.space = DesignSpace(self.spec, **(self.space_kwargs or {}))
        return self.spec, self.space


@dataclasses.dataclass(frozen=True)
class SegmentEvent:
    """One streamed scan-segment boundary (see ``run_queries``'s
    ``on_segment``): the archive ``cache_key`` being refined, the segment
    index within its phase, the segment's incremental ``ConvergenceTrace``
    slice (extend the slices to recover the run's full trace), and the
    phase — ``"refine"`` for a group's own budget, ``"realloc"`` for a
    reallocation top-up spending banked ledger credit (scalarized engines
    fire one completion event tagged with the engine name).

    ``elapsed_s`` is the segment's wall-clock, measured once at the scan
    boundary from the same monotonic clock as the result's ``elapsed_s``
    accounting — consumers get per-segment timing without running their
    own timers or a journal.  ``seq`` totally orders the events of one
    execution stream (monotone across ALL phases of a ``run_queries`` /
    ``Session.submit`` call, while ``segment`` restarts per phase)."""
    cache_key: str
    segment: int
    trace: ConvergenceTrace
    phase: str = "refine"
    elapsed_s: float = 0.0
    seq: int = 0


@dataclasses.dataclass
class ExploreResult:
    objectives: Tuple[str, ...]
    front_objs: np.ndarray          # (n, len(objectives)) nondominated rows
    front_metrics: np.ndarray       # (n, 4) full METRIC_KEYS rows
    front_designs: List[Dict[str, np.ndarray]]
    from_cache: bool                # True => served without any evaluation
    n_evals_run: int                # evaluations spent by the shared run
    #                                 that answered this query's GROUP (the
    #                                 cost is reported on every result of
    #                                 the group, booked once in the
    #                                 archive); 0 when served from cache
    elapsed_s: float                # wall time of the group's answer
    cache_key: str
    trace: Optional[ConvergenceTrace] = None    # per-generation telemetry
    #                                 of the group's run (None on pure
    #                                 cache hits — see the archive's
    #                                 persisted ``trace_summary``)
    plateaued: bool = False         # hypervolume plateaued => stopped early
    n_evals_banked: int = 0         # evaluations the early stop banked
    #                                 into the budget ledger
    n_evals_realloc: int = 0        # extra evaluations this group received
    #                                 from the batch's banked credit
    transferred_from: Tuple[str, ...] = ()      # neighbor archive keys whose
    #                                 migrated fronts seeded this cold run
    n_transfer_seeds: int = 0       # seed designs injected into the initial
    #                                 population (migrated or balanced_init)
    interrupted: bool = False       # a RunControl stop (or checkpointed
    #                                 kill) ended the run before its budget:
    #                                 the front reflects partial progress
    #                                 and budget_covered was NOT bumped
    surrogate_used: bool = False    # a fleet surrogate gated this group's
    #                                 evaluations (False when not requested
    #                                 OR the cache was too cold to fit one
    #                                 — the latter runs the exact path,
    #                                 bit-identical to surrogate=None)
    surrogate_hits: int = 0         # candidate evaluations skipped on the
    #                                 surrogate's say-so (the realized eval
    #                                 savings)
    surrogate_fallbacks: int = 0    # 1 when segment-mean ensemble
    #                                 disagreement abandoned the surrogate
    #                                 mid-run (exact for the remainder)


@dataclasses.dataclass
class SurrogateGate:
    """A fitted fleet surrogate bound to one group's workload embedding —
    everything ``_refine`` needs to gate a refinement's evaluations."""
    model: Surrogate
    embedding: np.ndarray
    cfg: SurrogateConfig


class ExplorationService:
    """Holds per-problem archives (memory + disk) and a shared NSGA engine.

    ``cache_dir`` defaults to ``$REPRO_EXPLORE_CACHE`` or the repo-anchored
    ``artifacts/explore_cache``; archives live at ``<cache_dir>/<key>.npz``.
    ``policy`` governs adaptive budget spending (see ``BudgetPolicy``);
    ``ledger`` maps problem key -> evaluations banked by plateau early
    stops, spendable by later batches' under-explored problems.
    """

    def __init__(self, cache_dir=None, capacity: int = 256,
                 nsga: NSGAConfig = NSGAConfig(), tech=None,
                 policy: BudgetPolicy = BudgetPolicy(),
                 transfer_k: int = 3,
                 manifest_policy: ManifestPolicy = ManifestPolicy(),
                 mesh=None):
        # nsga.generations is not used on the query path — each query's
        # budget sets the scan length (see _refine); the config's pop /
        # fields / crossover / mutation / immigrant knobs apply as given.
        # ``mesh`` (a jax.sharding.Mesh with an "islands" axis) shards
        # every refinement's population across the mesh as island-model
        # NSGA (see make_nsga); quantized populations too small to shard
        # fall back to the single-device scan, and megabatching is
        # disabled while a mesh is set (the two layouts are mutually
        # exclusive — fusing sharded runs is a follow-on).
        self.cache_dir = resolve_cache_dir(cache_dir)
        self.capacity = int(capacity)
        self.nsga = nsga
        if tech is not None and not isinstance(tech, TechConstants):
            # preset name / artifact path / CalibratedTech -> constants
            from ..core.presets import resolve_tech
            _, tech = resolve_tech(tech)
        self.tech = tech
        self.policy = policy
        self.mesh = mesh
        self.transfer_k = int(transfer_k)
        self.manifest_policy = manifest_policy
        self.ledger: Dict[str, int] = {}
        self._archives: Dict[str, ParetoArchive] = {}
        # neighbor archives loaded ONLY to migrate seeds out of live in a
        # small LRU side-cache keyed on the npz mtime (stale fronts are
        # re-read): repeated transfer queries don't re-read the npz, yet
        # a churning fleet can't grow memory without bound
        self._neighbor_cache: \
            "OrderedDict[str, Tuple[int, ParetoArchive]]" = OrderedDict()
        self._neighbor_cache_cap = max(8, 2 * self.transfer_k)
        self._manifest: Optional[ArchiveManifest] = None
        self._manifest_mtime: Optional[int] = None
        # per-key npz mtime at the last load/save THIS service performed:
        # a differing disk mtime at save time means a peer process wrote
        # the archive since, and the locked save merges before replacing
        self._archive_sync: Dict[str, Optional[int]] = {}

    def _manifest_stat(self) -> Optional[int]:
        try:
            return (self.cache_dir / MANIFEST_NAME).stat().st_mtime_ns
        except OSError:
            return None

    @property
    def manifest(self) -> ArchiveManifest:
        """The cross-spec index of this cache directory (lazy-loaded;
        damaged or absent files yield an empty manifest).  The file's
        mtime is checked on EVERY access: a second service writing the
        same cache directory invalidates this one's in-memory copy, so
        eviction/dedup/transfer decisions never act on a stale index.
        Multi-step operations (seeding, trust recording) snapshot the
        property ONCE and work on that object — a mid-operation reload
        must never yank entries out from under an iteration; the
        snapshot's mutations are saved at the end (last writer wins)."""
        mtime = self._manifest_stat()
        if self._manifest is None or mtime != self._manifest_mtime:
            if self._manifest is not None:      # a genuine staleness
                obs.inc("explore.manifest.reloads")     # reload, not the
            #                                     first lazy load
            with obs.span("manifest.reload"):
                self._manifest = ArchiveManifest.load(
                    self.cache_dir / MANIFEST_NAME,
                    policy=self.manifest_policy)
            self._manifest_mtime = mtime
        return self._manifest

    # ---- cache plumbing ----------------------------------------------------
    def _path(self, key: str) -> Path:
        return self.cache_dir / f"{key}.npz"

    def problem_key(self, spec: SystemSpec, space: DesignSpace) -> str:
        """Archive identity for one exploration problem under THIS
        service's tech constants — metrics evaluated under a different
        ``TechConstants`` (including a calibrated preset) must never be
        served as this problem's front.  The tech folds in as its stable
        ``tech_key()`` content digest, not its repr."""
        return spec_space_key(spec, space,
                              extra=tech_key(self.tech or DEFAULT_TECH))

    def archive_for(self, spec: SystemSpec, space: DesignSpace,
                    key: Optional[str] = None) -> ParetoArchive:
        """The (possibly empty) archive for one exploration problem —
        memory first, then disk, else freshly created."""
        key = key or self.problem_key(spec, space)
        if key in self._archives:
            return self._archives[key]
        arc = None
        p = self._path(key)
        if p.exists():
            try:
                arc = ParetoArchive.load(p)
            except Exception as e:          # a cache is disposable: never
                #                             let a damaged file kill a query
                warnings.warn(f"discarding unreadable explore cache {p}: {e}")
                p.unlink(missing_ok=True)
        if arc is None:
            template = jax.tree.map(
                np.asarray, random_design(jax.random.PRNGKey(0), space))
            arc = ParetoArchive(self.capacity, template,
                                n_obj=len(METRIC_KEYS),
                                obj_keys=METRIC_KEYS)
        else:
            self._mark_sync(key, p)
        self._archives[key] = arc
        return arc

    def _mark_sync(self, key: str, p: Path) -> None:
        try:
            self._archive_sync[key] = p.stat().st_mtime_ns
        except OSError:
            self._archive_sync.pop(key, None)

    def _merge_disk(self, key: str, arc: ParetoArchive, p: Path) -> None:
        """Fold a peer process's on-disk archive state into ``arc`` when
        the npz changed since this service last synced it.  Unreadable
        peer state is skipped with a warning — a cache merge must never
        fail the query riding on it."""
        try:
            mt = p.stat().st_mtime_ns
        except OSError:
            return
        if mt == self._archive_sync.get(key):
            return
        try:
            arc.merge(ParetoArchive.load(p))
            self._archive_sync[key] = mt
            obs.inc("explore.archive.merges")
        except Exception as e:
            warnings.warn(f"could not merge peer archive state {p}: {e}")

    def save(self, key: str):
        """Persist one archive, lock → reload → merge → replace: under
        the per-archive file lock, anything a peer process put on disk
        since this service last synced is merged in before the atomic
        replace, so concurrent refinements of one problem union instead
        of last-``os.replace``-wins.  A lock timeout degrades to the
        historic unmerged save with a warning — a wedged peer must never
        fail the query whose results are being persisted."""
        arc = self._archives.get(key)
        if arc is None:
            return
        p = self._path(key)
        try:
            with file_lock(lock_path(p)):
                self._merge_disk(key, arc, p)
                arc.save(p)
                self._mark_sync(key, p)
        except LockTimeout as e:
            warnings.warn(f"archive lock busy for {key} ({e}); "
                          f"saving without peer merge")
            arc.save(p)
            self._mark_sync(key, p)

    def refresh_archive(self, spec: SystemSpec, space: DesignSpace,
                        key: Optional[str] = None) -> ParetoArchive:
        """The freshest known archive for one problem: the in-memory
        copy merged with whatever peer processes have put on disk since
        this service last synced it.  The overload/degradation path
        serves (possibly stale) fronts straight from here, spending zero
        evaluations — fresh enough beats perfectly fresh when the
        alternative is an unbounded queue."""
        key = key or self.problem_key(spec, space)
        arc = self.archive_for(spec, space, key=key)
        self._merge_disk(key, arc, self._path(key))
        return arc

    def _ckpt_path(self, key: str) -> Path:
        """Where a resumable submission checkpoints mid-run state (one
        atomic npz beside the archive; deleted on normal completion)."""
        return self.cache_dir / f"{key}.ckpt.npz"

    # ---- the query API -----------------------------------------------------
    def explore(self, graph: WorkloadGraph,
                objectives: Sequence[str] = DEFAULT_OBJECTIVES,
                budget: int = 2048, ch_max: int = 4,
                space_kwargs: Optional[Dict] = None,
                transfer: bool = False, key=None) -> ExploreResult:
        """DEPRECATED shim — routes through ``repro.api.Session.submit``
        (``Query(Problem(...), engine="nsga")``) and returns the same
        ``ExploreResult`` the NSGA backend produced."""
        warnings.warn(
            "legacy entry point ExplorationService.explore() is "
            "deprecated; use repro.api: Session(...).submit(Query("
            "Problem(graph, objectives, ...), budget=..., transfer=...))",
            DeprecationWarning, stacklevel=2)
        from .api import Problem, Query, Session
        q = Query(Problem(graph, objectives=tuple(objectives),
                          ch_max=ch_max, space_kwargs=space_kwargs),
                  budget=budget, engine="nsga", transfer=transfer)
        return Session(service=self).submit(q, key=key).raw

    def explore_batch(self, queries: Sequence[ExploreQuery],
                      key=None) -> List[ExploreResult]:
        """DEPRECATED shim — routes through ``repro.api.Session.submit``
        with one ``Query`` per legacy ``ExploreQuery`` (same grouping,
        batching and reallocation semantics; see ``run_queries``)."""
        warnings.warn(
            "legacy entry point ExplorationService.explore_batch() is "
            "deprecated; use repro.api: Session(...).submit([Query(...), "
            "...])",
            DeprecationWarning, stacklevel=2)
        from .api import Problem, Query, Session
        qs = [Query(Problem(q.graph, objectives=q.objectives,
                            ch_max=q.ch_max, space_kwargs=q.space_kwargs),
                    budget=q.budget, engine="nsga", transfer=q.transfer,
                    engine_opts=({"surrogate": q.surrogate}
                                 if q.surrogate is not None else None))
              for q in queries]
        return [r.raw for r in Session(service=self).submit(qs, key=key)]

    def run_queries(self, queries: Sequence[ExploreQuery], key=None,
                    on_segment=None, resume: bool = False,
                    control: Optional[RunControl] = None
                    ) -> List[ExploreResult]:
        """The NSGA engine backend: answer a batch of queries, merging
        same-problem queries into one vmapped NSGA run (union objectives,
        max budget).  This is the execution path behind
        ``repro.api.Session.submit``; the legacy ``explore`` /
        ``explore_batch`` shims arrive here too.

        After every group has spent (or banked) its own budget, banked
        credit — this batch's plus any ledger balance carried over from
        earlier early stops — is reallocated to the batch's still-improving
        groups (the ones that exhausted their budget without plateauing),
        lowest recorded eval-count first.

        ``on_segment`` (callable taking one ``SegmentEvent``) streams each
        scan segment's incremental ``ConvergenceTrace`` slice as soon as
        the segment finishes — the dashboard/async-serving hook.  Callback
        failures are warned about (with phase and segment index), counted
        on the ``obs.on_segment_errors`` counter, and journaled as
        ``callback_error`` records — never fatal to the query.

        ``resume=True`` makes every cold group checkpoint its mid-run
        state after each segment (one atomic npz beside the archive) and
        restore from a matching checkpoint on entry: a killed run
        re-submitted with the same queries and ``key`` replays from the
        last completed segment, spends only the residual budget, and
        lands on the bit-identical final front (the PRNG chain folds the
        segment index, so segment ``s`` draws the same keys whichever
        attempt runs it).  ``control`` (a ``RunControl``) requests a
        cooperative stop at the next segment boundary — interrupted
        results carry ``interrupted=True`` and do NOT mark the budget
        covered."""
        key = jax.random.PRNGKey(0) if key is None else key
        # group by canonical problem hash
        groups: Dict[str, Dict] = {}
        order: List[Tuple[str, int]] = []      # (cache_key, slot in group)
        for q in queries:
            spec, space = q.build()
            ck = self.problem_key(spec, space)
            g = groups.setdefault(ck, dict(spec=spec, space=space,
                                           queries=[]))
            order.append((ck, len(g["queries"])))
            g["queries"].append(q)

        # one monotone event sequence across every phase of this batch
        seq = itertools.count()
        with obs.span("explore.run_queries", queries=len(queries),
                      groups=len(groups)):
            # per-group keys are fixed by enumeration order BEFORE any
            # batching decision, so a group's PRNG chain — and therefore
            # its refined front — is identical whether it runs
            # sequentially or fused into a megabatch lane
            gkeys = {ck: jax.random.fold_in(key, i)
                     for i, ck in enumerate(groups)}
            fused = set()
            if (self.policy.megabatch and not resume and self.mesh is None
                    and len(groups) > 1):
                fused = self._megabatch_pass(groups, gkeys, on_segment,
                                             seq, control)
            for ck, g in groups.items():
                if ck in fused:
                    continue
                self._refine_group(ck, g, gkeys[ck],
                                   on_segment=on_segment, seq=seq,
                                   resume=resume, control=control)
            if self.policy.reallocate:
                self._reallocate(groups,
                                 jax.random.fold_in(key, len(groups)),
                                 on_segment=on_segment, seq=seq,
                                 control=control)

        group_results = {ck: self._project_group(ck, g)
                         for ck, g in groups.items()}
        return [group_results[ck][slot] for ck, slot in order]

    @staticmethod
    def _segment_cb(on_segment, ck: str, phase: str, seq=None):
        """Wrap the user callback for one group's refinement: tag events
        with the archive key, phase, stream sequence number and the
        segment's wall-clock (measured once, at the scan boundary in
        ``_refine``), journal one ``segment`` record per boundary, and
        never let a callback failure kill the query it was observing —
        failures are warned about with their phase/segment coordinates,
        counted (``obs.on_segment_errors``) and journaled so telemetry
        consumers can see the events they lost.  ``None`` (skip event
        assembly entirely) when nobody is listening."""
        if on_segment is None and not obs.active():
            return None
        seq = seq if seq is not None else itertools.count()

        def cb(s: int, tr: ConvergenceTrace, elapsed_s: float,
               compiled: bool):
            ev = SegmentEvent(ck, s, tr, phase, elapsed_s=elapsed_s,
                              seq=next(seq))
            if obs.active():
                hv = (tr.archive_hv[-1] if tr.archive_hv is not None
                      and len(tr.archive_hv) else None)
                obs.emit(dict(
                    type="segment", key=ck, phase=phase, segment=s,
                    seq=ev.seq, elapsed_s=elapsed_s, compile=compiled,
                    n_evals=int(tr.n_evals[-1]) if len(tr.n_evals) else 0,
                    front_size=(int(tr.front_size[-1])
                                if len(tr.front_size) else 0),
                    hv=[float(v) for v in hv] if hv is not None else None))
            if on_segment is None:
                return
            try:
                on_segment(ev)
            except Exception as e:
                obs.inc("obs.on_segment_errors")
                if obs.active():
                    obs.emit(dict(type="callback_error", key=ck,
                                  phase=phase, segment=s, seq=ev.seq,
                                  error=repr(e)))
                warnings.warn(
                    f"on_segment callback failed for {ck} "
                    f"(phase={phase}, segment={s}): {e}")
        return cb

    # ---- one problem group -------------------------------------------------
    def _open_group(self, ck: str, g: Dict) -> bool:
        """Shared prologue of one group's refinement (sequential OR
        megabatched): resolve the archive, record the query facts on
        ``g`` and return the warm verdict (True => served straight from
        cache, nothing to refine).  Idempotent — the megabatch pre-pass
        may open a group the sequential loop later revisits."""
        if "warm" in g:
            return g["warm"]
        arc = g["arc"] = self.archive_for(g["spec"], g["space"], key=ck)
        g["embedding"] = workload_features(g["spec"].graph)
        budget = g["budget"] = max(q.budget for q in g["queries"])
        union = g["union"] = tuple(
            k for k in METRIC_KEYS
            if any(k in q.objectives for q in g["queries"]))
        warm = self.warm_verdict(arc, union, budget)
        obs.inc("explore.cache.hit" if warm else "explore.cache.miss")
        g.update(warm=warm, n_run=0, trace=None, plateaued=False,
                 banked=0, realloc=0, transferred_from=(), n_seeds=0,
                 interrupted=False, plateau=PlateauState(),
                 # any group member asking for surrogate gating turns it
                 # on for the shared run (like budget: max wins)
                 surrogate=next((q.surrogate for q in g["queries"]
                                 if q.surrogate is not None), None),
                 sur_used=False, sur_hits=0, sur_fallbacks=0)
        if warm and ck not in self.manifest.entries:
            self._update_manifest(ck, g)         # backfill pre-manifest
            #                                      caches into the index
        return warm

    def _group_seeds(self, ck: str, g: Dict, key) -> Optional[Dict]:
        """Transfer seeds for one opened group, when any of its queries
        asked for them.  Cold starts AND warm refinements take seeds: a
        half-explored archive profits from neighbor fronts it has never
        seen, but its own front head keeps at least half the
        population."""
        if not any(q.transfer for q in g["queries"]):
            return None
        arc = g["arc"]
        pop_eff = self._effective_pop(g["budget"])
        cap = pop_eff if len(arc) == 0 else max(pop_eff // 2, 1)
        with obs.span("explore.transfer_seeds", key=ck):
            seeds, srcs = self._transfer_seeds(
                ck, g["space"], g["embedding"],
                jax.random.fold_in(key, 0x7e5), arc=arc, cap=cap)
        g["transferred_from"] = srcs
        g["n_seeds"] = (int(next(iter(seeds.values())).shape[0])
                        if seeds else 0)
        return seeds

    def _book_refinement(self, ck: str, g: Dict, sp, n_run: int, trace,
                         plateaued: bool, banked: int,
                         interrupted: bool) -> None:
        """Shared epilogue of one group's refinement: archive accounting,
        eval/bank counters, trust calibration and manifest/disk sync."""
        arc, union, budget = g["arc"], g["union"], g["budget"]
        arc.searched = tuple(k for k in METRIC_KEYS
                             if k in arc.searched or k in union)
        if not interrupted:
            # an interrupted run must NOT mark the budget covered —
            # the resumed attempt still owes the residual segments
            arc.budget_covered = max(arc.budget_covered, budget)
        obs.inc("explore.evals.spent", n_run)
        if banked:
            obs.inc("explore.evals.banked", banked)
            self.ledger[ck] = self.ledger.get(ck, 0) + banked
        g.update(n_run=n_run, trace=trace, plateaued=plateaued,
                 banked=banked, interrupted=interrupted)
        if sp is not None:
            sp.set(n_run=n_run, plateaued=plateaued, banked=banked,
                   n_seeds=g["n_seeds"], interrupted=interrupted)
        if trace is not None:           # a stop before the first segment
            arc.trace_summary = trace.summary()         # leaves no trace
        self.save(ck)
        m = self.manifest               # ONE snapshot: the trust records
        #                                 land in the same object the
        #                                 index update saves below
        self._record_trust(ck, g, trace, m)
        self._update_manifest(ck, g, m)

    def _refine_group(self, ck: str, g: Dict, key, on_segment=None,
                      seq=None, resume: bool = False,
                      control: Optional[RunControl] = None) -> None:
        """Phase 1: spend (or bank) the group's own budget.  Mutates ``g``
        with the run's accounting; fronts are projected later, after any
        cross-group budget reallocation topped the archive up."""
        t0 = time.perf_counter()
        if self._open_group(ck, g):
            g["elapsed"] = time.perf_counter() - t0
            return
        budget, union, arc = g["budget"], g["union"], g["arc"]
        with obs.span("explore.refine_group", key=ck, budget=budget) as sp:
            seeds = self._group_seeds(ck, g, key)
            gate = (self._fit_gate(ck, g)
                    if g["surrogate"] is not None else None)
            n_run, trace, plateaued, banked, interrupted, sstats = \
                self._refine(
                    arc, g["spec"], g["space"], union, budget, key,
                    seeds=seeds,
                    on_segment=self._segment_cb(on_segment, ck, "refine",
                                                seq=seq),
                    plateau=g["plateau"], control=control,
                    checkpoint=self._ckpt_path(ck) if resume else None,
                    gate=gate)
            g.update(sur_used=sstats["used"], sur_hits=sstats["hits"],
                     sur_fallbacks=sstats["fallbacks"])
            self._book_refinement(ck, g, sp, n_run, trace, plateaued,
                                  banked, interrupted)
        g["elapsed"] = time.perf_counter() - t0

    def _fit_gate(self, ck: str, g: Dict) -> Optional[SurrogateGate]:
        """Fit the evaluation-gating surrogate for one opened group from
        every OTHER cached archive the fleet manifest indexes (plus the
        group's own archived rows, when it is a warm refinement).
        Returns ``None`` when the harvest is too cold to fit
        (``SurrogateConfig.min_rows``) — the caller then runs the exact
        path, bit-identical to ``surrogate=None``."""
        opts = dict(g["surrogate"])
        exclude = tuple(opts.pop("exclude", ()))
        try:
            cfg = SurrogateConfig(**opts)
        except TypeError as e:
            raise ValueError(f"bad surrogate options "
                             f"{sorted(opts)}: {e}") from None
        arc = g["arc"]
        emb = np.asarray(g["embedding"], np.float32).ravel()
        design_dim = design_encoding_dim(
            {k: v[0] for k, v in arc.designs.items()})
        with obs.span("explore.surrogate_fit", key=ck):
            index = self.manifest.export_index(exclude=(ck,) + exclude)
            X, Y = harvest_rows(index, self._load_neighbor, design_dim,
                                emb.size)
            own_X, own_Y = arc.export_rows()
            if len(own_X):
                own = np.concatenate(
                    [own_X, np.tile(emb, (len(own_X), 1))], axis=1)
                X = np.concatenate([X, own]) if len(X) else own
                Y = np.concatenate([Y, own_Y]) if len(Y) else own_Y
            sur = fit_surrogate(X, Y, cfg)
        if sur is None:
            obs.inc("explore.surrogate.cold")
            return None
        return SurrogateGate(model=sur, embedding=emb, cfg=cfg)

    # ---- cross-problem megabatching ----------------------------------------
    def _fuse_signature(self, g: Dict):
        """Everything that must coincide for two problem groups to share
        one fused compiled dispatch: the NSGA scan statics (padded dims,
        space bounds, objective columns, variation config, tech) plus the
        quantized segment schedule.  Spec ARRAY VALUES are free to differ
        — they ride the lane axis."""
        spec, space = g["spec"], g["space"]
        sched = quantize.schedule(g["budget"], self.nsga.pop,
                                  self.policy.chunk_generations)
        idx = tuple(METRIC_KEYS.index(o) for o in g["union"])
        cfg = dataclasses.replace(self.nsga, pop=sched.pop,
                                  generations=sched.chunk)
        return _static_key((spec.W, spec.CH, spec.E), idx, cfg,
                           self.tech or DEFAULT_TECH, space) + (sched,)

    def _megabatch_pass(self, groups: Dict[str, Dict], gkeys, on_segment,
                        seq, control) -> set:
        """Bucket this batch's cold, megabatch-willing groups by fused
        compile signature and answer every bucket of >= 2 problems with
        one vmapped lockstep refinement.  Returns the keys of the groups
        fully handled here (warm groups it served count too); the caller
        runs the rest sequentially."""
        done: set = set()
        buckets: Dict[tuple, List[Tuple[str, Dict]]] = {}
        for ck, g in groups.items():
            if not all(getattr(q, "megabatch", True)
                       for q in g["queries"]):
                continue
            if any(getattr(q, "surrogate", None) is not None
                   for q in g["queries"]):
                continue    # surrogate gating runs the sequential loop —
                #             fusing gated lanes is a follow-on
            t0 = time.perf_counter()
            if self._open_group(ck, g):
                g["elapsed"] = time.perf_counter() - t0     # warm: served
                done.add(ck)
                continue
            buckets.setdefault(self._fuse_signature(g), []).append((ck, g))
        cap = max(2, int(self.policy.megabatch_lanes))
        for bucket in buckets.values():
            for lo in range(0, len(bucket), cap):
                part = bucket[lo:lo + cap]
                if len(part) < 2:       # nothing to fuse with — leave it
                    continue            # to the sequential loop
                self._refine_group_fused(part, gkeys, on_segment, seq,
                                         control)
                done.update(ck for ck, _ in part)
        return done

    def _refine_group_fused(self, bucket: List[Tuple[str, Dict]], gkeys,
                            on_segment, seq, control) -> None:
        """Run one bucket of distinct-problem groups as fused lanes of a
        single vmapped NSGA dispatch, then book each group exactly as the
        sequential path would."""
        t0 = time.perf_counter()
        with obs.span("explore.megabatch", lanes=len(bucket),
                      keys=",".join(ck for ck, _ in bucket)) as sp:
            lanes = []
            for ck, g in bucket:
                lanes.append(dict(
                    ck=ck, g=g, key=gkeys[ck],
                    seeds=self._group_seeds(ck, g, gkeys[ck]),
                    cb=self._segment_cb(on_segment, ck, "refine", seq=seq)))
            results = self._refine_fused(lanes, control=control)
            for (ck, g), r in zip(bucket, results):
                self._book_refinement(ck, g, None, *r)
            sp.set(n_run=sum(r[0] for r in results))
        dt = time.perf_counter() - t0
        for _, g in bucket:     # wall-clock is genuinely shared: every
            g["elapsed"] = dt   # lane waited on the same dispatches

    def _refine_fused(self, lanes: List[Dict], control=None
                      ) -> List[Tuple]:
        """The megabatched ``_refine``: every lane (one problem group)
        shares a single quantized schedule and one ``make_nsga_fused``
        runner; per-lane archives, seeding, plateau streaks, traces and
        banking follow the sequential semantics segment by segment.

        The lane count of each dispatch is pow2-padded
        (``quantize.bucket_lanes``); padding slots replay the first live
        lane and their outputs are DISCARDED — masked per-problem lanes,
        in exchange for a lane-count compile lattice of O(log(batch)).
        When a lane plateaus it stops booking results but the dispatch
        width stays fixed (no recompile mid-run).  No checkpoint support:
        ``run_queries`` only fuses when ``resume`` is off.  Returns one
        ``(n_run, trace, plateaued, banked, interrupted)`` per lane, in
        order."""
        policy = self.policy
        g0 = lanes[0]["g"]
        union = g0["union"]
        sched = quantize.schedule(g0["budget"], self.nsga.pop,
                                  policy.chunk_generations)
        pop, chunk, n_seg = sched.pop, sched.chunk, sched.n_seg
        cfg = dataclasses.replace(self.nsga, pop=pop, generations=chunk)
        lanes_pad = quantize.bucket_lanes(len(lanes))
        run = make_nsga_fused(g0["spec"], g0["space"], union, cfg,
                              tech=self.tech, lanes=lanes_pad)
        hv_pairs = [(METRIC_KEYS.index(union[i]),
                     METRIC_KEYS.index(union[j]))
                    for i, j in objective_pairs(len(union))]
        for ln in lanes:
            k_init, k_run = jax.random.split(ln["key"])
            space = ln["g"]["space"]
            ln.update(
                k_run=k_run, trace=None, plateaued=False,
                interrupted=False, spent_g=0, live=True,
                st=ln["g"]["plateau"],
                filler=jax.vmap(lambda k: random_design(k, space))(
                    jax.random.split(k_init, pop)))
        for s in range(n_seg):
            live = [ln for ln in lanes if ln["live"]]
            if not live:
                break
            if control is not None and control.stopped:
                for ln in live:
                    ln["interrupted"] = True
                break
            t_seg = time.perf_counter()
            compiled = not run.compile_state["executed"]
            slots = live + [live[0]] * (lanes_pad - len(live))
            keys_s = [jax.random.fold_in(ln["k_run"], s) for ln in slots]
            pops = [_seed_population(ln["g"]["arc"], pop, ln["filler"],
                                     ln["seeds"] if s == 0 else None)
                    for ln in slots]
            pop_stack = jax.tree.map(lambda *xs: jnp.stack(xs), *pops)
            pop_s, _raw, _sel, ev_d, ev_r, ev_f, tr = run(
                keys_s, pop_stack,
                [ln["g"]["spec"].arrays for ln in slots])
            # per-lane booking: identical to one sequential _refine
            # segment; padding slots (j >= len(live)) book nothing
            staged = []
            for j, ln in enumerate(live):
                arc = ln["g"]["arc"]
                arc.insert(
                    jax.tree.map(
                        lambda x: x[j].reshape((-1,) + x.shape[3:]), ev_d),
                    ev_r[j].reshape(-1, ev_r.shape[-1]),
                    mask=ev_f[j].reshape(-1), count_evals=False)
                arc.n_evals += pop * chunk
                ln["spent_g"] += chunk
                ln["filler"] = jax.tree.map(lambda x: x[j], pop_s)
                seg_trace = ConvergenceTrace.from_scan(
                    union, {k: v[j] for k, v in tr.items()}, pop)
                hv_now = np.asarray([arc.projected_hypervolume(p)
                                     for p in hv_pairs])
                seg_trace.archive_hv = hv_now[None, :]
                ln["trace"] = (seg_trace if ln["trace"] is None
                               else ln["trace"].extend(seg_trace))
                staged.append((ln, seg_trace, hv_now))
            # all host-side archive work has drained the dispatch by
            # here: dt is the honest wall-clock of the fused segment,
            # reported to every lane (they genuinely shared it)
            dt = time.perf_counter() - t_seg
            obs.inc("explore.segments")
            obs.observe("explore.segment_compile_s" if compiled
                        else "explore.segment_s", dt)
            for ln, seg_trace, hv_now in staged:
                if ln["cb"] is not None:
                    ln["cb"](s, seg_trace, dt, compiled)
                if policy.adaptive and hv_pairs:
                    streak = ln["st"].observe(
                        hv_now, policy.plateau_rel,
                        count=bool(len(ln["g"]["arc"])))
                    if streak >= policy.patience and s + 1 < n_seg:
                        ln["plateaued"] = True
                        ln["live"] = False
                        obs.inc("explore.plateau_stops")
        out = []
        for ln in lanes:
            n_run = ln["spent_g"] * pop
            banked = max(0, ln["g"]["budget"] - n_run) \
                if ln["plateaued"] else 0
            out.append((n_run, ln["trace"], ln["plateaued"], banked,
                        ln["interrupted"]))
        return out

    @staticmethod
    def warm_verdict(arc: ParetoArchive, objectives: Sequence[str],
                     budget: int) -> bool:
        """True when ``arc`` can answer a query over ``objectives`` at
        ``budget`` straight from cache: warm only when the covered budget
        (evaluations recorded, or credited by a plateau early stop) and
        every queried objective are covered — points found while
        optimizing other axes are no substitute for search effort on
        these ones.  The service's cache-hit rule and the one
        ``repro.api.Session.plan`` predicts with."""
        return (len(arc) > 0
                and max(arc.n_evals, arc.budget_covered) >= budget
                and all(o in arc.searched for o in objectives))

    def _record_trust(self, ck: str, g: Dict, trace: ConvergenceTrace,
                      m: Optional[ArchiveManifest] = None) -> None:
        """Book one calibration outcome per seeding neighbor: the run's
        observed hypervolume lift (see ``_transfer_lift``), keyed by the
        (src, dst) embedding delta.  Also LRU-touches the neighbors that
        actually seeded — useful sources stay resident.  Single-objective
        runs have no hypervolume pairs, hence no lift signal: nothing is
        recorded (a meaningless 0 would poison the regression).
        Telemetry bookkeeping must never fail a query."""
        if not g["transferred_from"] or trace is None or not trace.pairs:
            return
        try:
            m = m if m is not None else self.manifest
            lift = _transfer_lift(trace)
            for nk in g["transferred_from"]:
                ent = m.entries.get(nk)
                if ent is None:
                    continue
                m.record_transfer(
                    nk, ck, embedding_delta(g["embedding"],
                                            ent["embedding"]), lift)
                m.touch(nk)
        except Exception as e:
            warnings.warn(f"transfer trust recording failed for {ck}: {e}")

    def _update_manifest(self, ck: str, g: Dict,
                         m: Optional[ArchiveManifest] = None) -> None:
        """Refresh the cross-spec index entry for one problem (embedding,
        freshness counters, migration digest) and persist it, lock →
        reload → merge → replace.  Works on the caller's manifest
        snapshot when given, so a mid-operation mtime reload can't drop
        sibling mutations (trust records) before the save.

        The commit itself runs under the manifest's file lock: when the
        file's mtime moved past the state this snapshot descends from, a
        peer process committed in between — the snapshot is MERGED into
        a fresh read of the disk state instead of replacing it, closing
        the lost-update race where the slower of two writers silently
        dropped the faster one's index entries and trust records.  Index
        maintenance must never fail a query."""
        arc, spec = g["arc"], g["spec"]
        try:
            m = m if m is not None else self.manifest
            m.update(
                ck, embedding=g["embedding"],
                dims=(spec.W, spec.CH, spec.E),
                n_evals=arc.n_evals, budget_covered=arc.budget_covered,
                searched=arc.searched,
                digest=space_digest(g["space"]).to_json_dict())
            path = self.cache_dir / MANIFEST_NAME
            with file_lock(lock_path(path)):
                if self._manifest_stat() != self._manifest_mtime:
                    disk = ArchiveManifest.load(
                        path, policy=self.manifest_policy)
                    disk.merge(m)
                    disk.enforce(protect=(ck,))
                    m = disk
                    obs.inc("explore.manifest.merges")
                m.reap_evicted(self.cache_dir)   # opt-in archive-file GC
                m.save()
                self._manifest = m      # what was just saved IS current
                self._manifest_mtime = self._manifest_stat()
        except LockTimeout as e:        # wedged peer: the historic
            #                             unmerged save beats losing OUR
            #                             records too
            warnings.warn(f"manifest lock busy ({e}); saving unmerged")
            try:
                m.save()
                self._manifest = m
                self._manifest_mtime = self._manifest_stat()
            except Exception as e2:
                warnings.warn(f"explore manifest update failed for "
                              f"{ck}: {e2}")
        except Exception as e:
            warnings.warn(f"explore manifest update failed for {ck}: {e}")

    def _load_neighbor(self, nk: str) -> Optional[ParetoArchive]:
        """A neighbor archive for seed migration, through the bounded LRU
        side-cache.  Entries are keyed on the npz's mtime: when another
        service of a shared cache directory improves a neighbor's
        archive, the next transfer query re-reads the better front
        instead of serving the stale one (mirroring the manifest's
        staleness rule).  ``None`` for absent/unreadable files — a broken
        neighbor must never fail the query it was helping."""
        p = self._path(nk)
        try:
            mt = p.stat().st_mtime_ns
        except OSError:
            return None
        hit = self._neighbor_cache.get(nk)
        if hit is not None and hit[0] == mt:
            self._neighbor_cache.move_to_end(nk)
            return hit[1]
        try:
            arc = ParetoArchive.load(p)
        except Exception as e:
            warnings.warn(f"skipping unreadable neighbor archive {p}: {e}")
            return None
        # LRU side-cache, NOT self._archives: repeat queries skip the npz
        # re-read, but seed-only neighbors can't grow memory without bound
        self._neighbor_cache[nk] = (mt, arc)
        self._neighbor_cache.move_to_end(nk)
        while len(self._neighbor_cache) > self._neighbor_cache_cap:
            self._neighbor_cache.popitem(last=False)
        return arc

    def _transfer_plan(self, ck: str, embedding, cap: int
                       ) -> Tuple[ArchiveManifest,
                                  List[Tuple[str, float]], Dict[str, int]]:
        """The *prediction* half of transfer seeding, evaluation-free: one
        manifest snapshot, the trust-reweighted ``transfer_k`` nearest
        cached neighbors of ``embedding`` (excluding ``ck`` itself), and
        each neighbor's seed quota out of ``cap``.  ``_transfer_seeds``
        executes exactly this plan; ``repro.api.Session.plan`` reports it
        to the caller before any compute is spent."""
        m = self.manifest               # ONE snapshot for the whole
        #                                 lookup: a concurrent service's
        #                                 eviction must not yank entries
        #                                 between nearest() and indexing
        trust = m.trust_model(dim=int(np.asarray(embedding).size))
        neigh = m.nearest(embedding, k=self.transfer_k,
                          exclude=(ck,), trust=trust)
        cap = max(int(cap), 1)
        if trust is not None and neigh:
            w = [1.0 + max(trust.predict(embedding_delta(
                embedding, m.entries[nk]["embedding"])), 0.0)
                for nk, _ in neigh]
            quotas = {nk: max(1, int(round(cap * wi / sum(w))))
                      for (nk, _), wi in zip(neigh, w)}
        else:
            quota = max(1, cap // max(self.transfer_k, 1))
            quotas = {nk: quota for nk, _ in neigh}
        return m, neigh, quotas

    def _transfer_seeds(self, ck: str, space: DesignSpace, embedding,
                        key, arc: Optional[ParetoArchive] = None,
                        cap: Optional[int] = None
                        ) -> Tuple[Optional[Dict], Tuple[str, ...]]:
        """Seed designs for a cold or resumed query: the migrated (and
        repaired) fronts of the ``transfer_k`` best cached neighbors,
        capped at ``cap`` designs.  Neighbor ranking and per-neighbor seed
        quotas are *trust-calibrated* once the manifest's outcome table
        supports a model: distances are reweighted by predicted lift and
        higher-trust neighbors earn proportionally more of the cap.
        Migrated seeds that duplicate the destination archive's own front
        (``portable_signature`` match) are dropped — resuming a problem
        with its own designs injects nothing.  With no usable neighbor, a
        COLD start gets one repaired ``balanced_init`` design (never worse
        off for having asked to transfer); a resumed archive already has
        its front head and gets no filler seed."""
        dst = space_digest(space)
        cap = max(self.nsga.pop, 1) if cap is None else max(int(cap), 1)
        n_front = len(arc) if arc is not None else 0
        m, neigh, quotas = self._transfer_plan(ck, embedding, cap)
        taken: set = set()
        if n_front and neigh:           # hashing the whole front is only
            #                             worth it when there IS a
            #                             neighbor to dedup against
            fr_designs, _ = arc.front()
            for i in range(n_front):
                d = {k2: v[i] for k2, v in fr_designs.items()}
                taken.add(portable_signature(d, dst))
        seeds: List[Dict] = []
        srcs: List[str] = []
        for nk, _dist in neigh:
            ent = m.entries[nk]
            if ent.get("digest") is None:
                continue
            n_arc = self._archives.get(nk)
            if n_arc is None:
                n_arc = self._load_neighbor(nk)
            if n_arc is None:
                continue
            migrated: List[Dict] = []
            try:
                designs, objs = n_arc.front()
                for i in range(len(objs)):
                    if len(migrated) >= quotas.get(nk, 1):
                        break
                    d = {k2: v[i] for k2, v in designs.items()}
                    md = migrate(d, ent["digest"], dst)
                    sig = portable_signature(md, dst)
                    if sig in taken:    # already on the destination front
                        obs.inc("explore.transfer.seeds_deduped")
                        continue        # (or offered by a closer neighbor)
                    taken.add(sig)
                    migrated.append(md)
            except Exception as e:      # a broken neighbor must never
                #                         fail the query it was helping;
                #                         designs migrated before the
                #                         failure are still good seeds
                warnings.warn(f"transfer from {nk} failed: {e}")
            if migrated:                # seeds and telemetry stay
                #                         consistent: nk is credited iff
                #                         its designs were injected
                obs.inc("explore.transfer.seeds_injected", len(migrated))
                seeds.extend(migrated)
                srcs.append(nk)
            if len(seeds) >= cap:
                break
        if not seeds:
            if n_front:
                return None, ()
            bi = jax.tree.map(np.asarray, balanced_init(key, space))
            seeds = [repair(bi, dst)]
        seeds = seeds[:cap]
        return ({k2: np.stack([s[k2] for s in seeds])
                 for k2 in seeds[0]}, tuple(srcs))

    def _reallocate(self, groups: Dict[str, Dict], key,
                    on_segment=None, seq=None,
                    control: Optional[RunControl] = None) -> None:
        """Phase 2: spend the ledger on this batch's under-explored
        archives — groups that ran to budget exhaustion WITHOUT plateauing
        (their front was still improving), lowest eval-count first.  Spent
        credit is drained FIFO from the ledger; credit no group can use
        stays banked for future batches.  Interrupted groups take no
        top-up (their own budget is still owed) and a stopped control
        token ends the phase at the next boundary."""
        pool = sum(self.ledger.values())
        takers = sorted(
            ((ck, g) for ck, g in groups.items()
             if not g["warm"] and g["n_run"] and not g["plateaued"]
             and not g["interrupted"]),
            key=lambda item: item[1]["arc"].n_evals)
        for i, (ck, g) in enumerate(takers):
            if control is not None and control.stopped:
                break
            if pool < 8:                 # below the smallest runnable pop
                break
            arc = g["arc"]
            t0 = time.perf_counter()
            # a top-up is FRESH budget: the plateau streak the group's own
            # refinement accumulated must not carry into the realloc
            # segments, or a topped-up archive gets declared plateaued one
            # segment after receiving credit it never got to spend
            g["plateau"].reset()
            # quantize_down caps the spend at the available credit — the
            # ledger must never be overdrawn by pow2 rounding
            with obs.span("explore.reallocate", key=ck, pool=pool) as sp:
                n_run, trace, plateaued, _, interrupted, _ = self._refine(
                    arc, g["spec"], g["space"], g["union"], pool,
                    jax.random.fold_in(key, i), quantize_down=True,
                    on_segment=self._segment_cb(on_segment, ck, "realloc",
                                                seq=seq),
                    plateau=g["plateau"], control=control)
                sp.set(n_run=n_run)
            obs.inc("explore.evals.realloc", n_run)
            pool -= n_run                # only what was actually spent
            self._drain_ledger(n_run)
            g["elapsed"] += time.perf_counter() - t0
            g["n_run"] += n_run
            g["realloc"] += n_run
            g["plateaued"] = plateaued
            g["interrupted"] = g["interrupted"] or interrupted
            if trace is not None:
                g["trace"] = (g["trace"].extend(trace)
                              if g["trace"] is not None else trace)
            if g["trace"] is not None:
                arc.trace_summary = g["trace"].summary()
            self.save(ck)
            self._update_manifest(ck, g)

    def _drain_ledger(self, spent: int) -> None:
        for ck in list(self.ledger):
            if spent <= 0:
                break
            take = min(self.ledger[ck], spent)
            self.ledger[ck] -= take
            spent -= take
            if self.ledger[ck] <= 0:
                del self.ledger[ck]

    def _project_group(self, ck: str, g: Dict) -> List[ExploreResult]:
        """Phase 3: project every query's front out of the group archive.
        ``elapsed`` covers the group's own refinement (plus any
        reallocation top-up it received), not the whole batch."""
        designs, metrics = g["arc"].front()
        elapsed = g["elapsed"]
        results = []
        for q in g["queries"]:
            idx = [METRIC_KEYS.index(o) for o in q.objectives]
            cols = metrics[:, idx]
            keep = pareto_front(cols) if len(cols) else []
            results.append(ExploreResult(
                objectives=q.objectives,
                front_objs=cols[keep],
                front_metrics=metrics[keep],
                front_designs=[{k: v[i] for k, v in designs.items()}
                               for i in keep],
                from_cache=g["warm"], n_evals_run=g["n_run"],
                elapsed_s=elapsed, cache_key=ck,
                trace=g["trace"], plateaued=g["plateaued"],
                n_evals_banked=g["banked"], n_evals_realloc=g["realloc"],
                transferred_from=g["transferred_from"],
                n_transfer_seeds=g["n_seeds"],
                interrupted=g["interrupted"],
                surrogate_used=g["sur_used"],
                surrogate_hits=g["sur_hits"],
                surrogate_fallbacks=g["sur_fallbacks"]))
        return results

    def _effective_pop(self, budget: int, quantize_down: bool = False
                       ) -> int:
        """The population width ``_refine`` will actually run for one
        budget: sub-``nsga.pop`` budgets shrink the population (pow2 ceil
        normally, pow2 floor when the budget is a hard cap; floored at
        8).  Factored out so the seeding path caps transfer seeds at what
        the run can really inject."""
        return quantize.effective_pop(budget, self.nsga.pop, quantize_down)

    def _mesh_for(self, pop: int):
        """The service mesh, when a ``pop``-wide population can actually
        shard over it (every island at least 2 designs); ``None`` (the
        single-device scan) otherwise — small quantized budgets must not
        fail, they just don't scale."""
        if self.mesh is None:
            return None
        n = int(self.mesh.shape.get(ISLAND_AXIS, 1))
        return self.mesh if (pop % n == 0 and pop // n >= 2) else None

    def _ckpt_signature(self, objectives: Tuple[str, ...], budget: int,
                        pop: int, generations: int, chunk: int, key,
                        seeds: Optional[Dict],
                        gate_digest: Optional[str] = None) -> str:
        """Identity of one deterministic refinement: everything that
        fixes the segment-by-segment PRNG/compute chain.  A checkpoint
        written under a different signature answers a DIFFERENT run and
        is ignored — resuming must never splice two unequal runs."""
        h = hashlib.sha256()
        mesh = self._mesh_for(pop)      # island count changes the PRNG /
        #                                 migration chain: a sharded run's
        #                                 checkpoint answers a different
        #                                 numeric stream than an unsharded
        islands = int(mesh.shape[ISLAND_AXIS]) if mesh is not None else 1
        h.update(repr((tuple(objectives), int(budget), int(pop),
                       int(generations), int(chunk), int(self.capacity),
                       repr(self.nsga), islands,
                       tech_key(self.tech or DEFAULT_TECH),
                       gate_digest)).encode())
        #             gate_digest: a surrogate-gated run's numeric stream
        #             depends on the fitted model — a checkpoint written
        #             under a different (or no) surrogate must not splice
        h.update(np.asarray(key).tobytes())
        if seeds is not None:
            for k in sorted(seeds):
                h.update(k.encode())
                h.update(np.asarray(seeds[k]).tobytes())
        return h.hexdigest()[:16]

    @staticmethod
    def _save_ckpt(path, sig: str, s_next: int, spent_g: int,
                   spent_e: int, fell_back: bool, arc: ParetoArchive,
                   filler: Dict, trace: ConvergenceTrace,
                   st: PlateauState) -> None:
        """One atomic npz holding a CONSISTENT mid-run snapshot: the
        archive state after segment ``s_next - 1``'s insert, the evolving
        population that segment produced, the accumulated trace, and the
        plateau detector's memory.  Written via ``atomic_savez``, so a
        kill mid-checkpoint leaves the previous segment's snapshot — the
        resume replays at most one extra segment, never sees a torn one.
        Checkpoint failure is a warning: losing resumability must not
        fail the run being protected."""
        try:
            meta = dict(
                sig=sig, s_next=int(s_next), spent_g=int(spent_g),
                spent_e=int(spent_e),   # exact evaluations (differs from
                #                         spent_g * pop under gating)
                fell_back=bool(fell_back),  # disagreement abandoned the
                #                         surrogate: a resume must stay
                #                         exact, not re-enable the gate
                streak=int(st.streak),
                last_hv=([float(v) for v in st.last_hv]
                         if st.last_hv is not None else None),
                arc=dict(n_evals=arc.n_evals,
                         budget_covered=arc.budget_covered,
                         searched=list(arc.searched)),
                trace=dict(objectives=list(trace.objectives),
                           pairs=[list(p) for p in trace.pairs],
                           has_archive_hv=trace.archive_hv is not None,
                           has_hv_gen=trace.hv_gen is not None))
            arrays = dict(
                objs=arc.objs, valid=arc.valid,
                t_front_size=np.asarray(trace.front_size),
                t_hypervolume=np.asarray(trace.hypervolume),
                t_best=np.asarray(trace.best),
                t_feasible_frac=np.asarray(trace.feasible_frac),
                t_n_evals=np.asarray(trace.n_evals))
            if trace.archive_hv is not None:
                arrays["t_archive_hv"] = np.asarray(trace.archive_hv)
            if trace.hv_gen is not None:
                arrays["t_hv_gen"] = np.asarray(trace.hv_gen)
            arrays.update({f"d_{k}": np.asarray(v)
                           for k, v in arc.designs.items()})
            arrays.update({f"f_{k}": np.asarray(v)
                           for k, v in filler.items()})
            with obs.span("explore.checkpoint", segment=int(s_next) - 1):
                atomic_savez(path, __meta=np.frombuffer(
                    json.dumps(meta).encode(), dtype=np.uint8), **arrays)
        except Exception as e:
            warnings.warn(f"resume checkpoint write failed ({path}): {e}")

    @staticmethod
    def _load_ckpt(path, sig: str, arc: ParetoArchive, st: PlateauState
                   ) -> Optional[Tuple[int, int, Optional[int], bool,
                                       Dict, ConvergenceTrace]]:
        """Restore a mid-run snapshot into ``arc``/``st`` if ``path``
        holds a checkpoint of THIS run (signature match, compatible
        shapes).  Returns ``(s_next, spent_g, spent_e, fell_back,
        filler, trace)`` — ``spent_e`` is ``None`` for pre-surrogate
        checkpoints (the caller derives ``spent_g * pop``) — or ``None``
        (no/foreign/damaged checkpoint → start from scratch, never
        fatal)."""
        path = Path(path)
        if not path.exists():
            return None
        try:
            with np.load(path) as z:
                meta = json.loads(bytes(z["__meta"]).decode())
                if meta["sig"] != sig:
                    return None
                objs, valid = z["objs"], z["valid"]
                designs = {k[2:]: z[k].copy() for k in z.files
                           if k.startswith("d_")}
                if (objs.shape != arc.objs.shape
                        or set(designs) != set(arc.designs)):
                    return None
                filler = {k[2:]: z[k].copy() for k in z.files
                          if k.startswith("f_")}
                tm = meta["trace"]
                trace = ConvergenceTrace(
                    objectives=tuple(tm["objectives"]),
                    pairs=tuple(tuple(p) for p in tm["pairs"]),
                    front_size=z["t_front_size"].copy(),
                    hypervolume=z["t_hypervolume"].copy(),
                    best=z["t_best"].copy(),
                    feasible_frac=z["t_feasible_frac"].copy(),
                    n_evals=z["t_n_evals"].copy(),
                    archive_hv=(z["t_archive_hv"].copy()
                                if tm["has_archive_hv"] else None),
                    hv_gen=(z["t_hv_gen"].copy()
                            if tm["has_hv_gen"] else None))
            arc.objs = objs.copy()
            arc.valid = valid.copy()
            arc.designs = designs
            arc.n_evals = int(meta["arc"]["n_evals"])
            arc.budget_covered = int(meta["arc"]["budget_covered"])
            arc.searched = tuple(meta["arc"]["searched"])
            st.streak = int(meta["streak"])
            st.last_hv = (np.asarray(meta["last_hv"], np.float64)
                          if meta["last_hv"] is not None else None)
            obs.inc("explore.resume.restored")
            spent_e = meta.get("spent_e")
            return (int(meta["s_next"]), int(meta["spent_g"]),
                    int(spent_e) if spent_e is not None else None,
                    bool(meta.get("fell_back", False)), filler, trace)
        except Exception as e:
            warnings.warn(f"discarding unreadable resume checkpoint "
                          f"{path}: {e}")
            return None

    def _refine(self, arc: ParetoArchive, spec: SystemSpec,
                space: DesignSpace, objectives: Tuple[str, ...],
                budget: int, key, quantize_down: bool = False,
                seeds: Optional[Dict] = None, on_segment=None,
                plateau: Optional[PlateauState] = None,
                control: Optional[RunControl] = None,
                checkpoint=None, gate: Optional[SurrogateGate] = None
                ) -> Tuple[int, Optional[ConvergenceTrace], bool, int,
                           bool, Dict[str, int]]:
        """Spend up to ~``budget`` evaluations improving the archive:
        warm-start the population from the cached front, evolve in scan
        segments, re-insert every evaluation, stop early on plateau.

        The query budget — not ``self.nsga.generations`` — fixes the scan
        length here; the population (for sub-``nsga.pop`` budgets), the
        total generation count and the per-segment chunk are all quantized
        to powers of two, so a long-lived service compiles
        O(log^2(max_budget)) scan variants instead of one per distinct
        budget; the service's ``nsga`` config supplies the population
        ceiling and variation knobs.

        Returns ``(n_run, trace, plateaued, banked, interrupted,
        sur_stats)``: evaluations spent by THIS attempt (a resumed run
        reports only its residual spend; the archive's counters carry
        the total), the
        concatenated per-generation ``ConvergenceTrace`` spanning every
        attempt (with one archive-projected hypervolume row per
        segment; ``None`` if stopped before any segment ran), whether
        the hypervolume plateau stopped the run early, the evaluations
        of the *requested* budget that early stop left unspent (never
        more than the caller offered, however the scan was quantized),
        and whether a ``control`` stop ended the run before its budget.

        ``plateau`` (a ``PlateauState``) carries the streak detector's
        memory across attempts of one group; ``checkpoint`` (a path)
        turns on per-segment crash checkpointing and resume-on-entry;
        ``control`` is polled at each segment boundary.

        ``quantize_down`` floors instead of ceils the pow2 generation
        quantization, guaranteeing the run never spends more than
        ``budget`` — used when spending ledger credit, which must not be
        exceeded.

        ``seeds`` (a stacked numpy design pytree) is injected into segment
        0's population right behind the archive-front head — the transfer
        warm-start path.  Later segments carry the evolving population, so
        a bad seed is selected out after one generation.

        ``gate`` (a ``SurrogateGate``) switches each segment to the
        surrogate-gated scan: only ``cfg.n_exact(pop)`` of every
        generation's candidates get exact evaluations (the rest are
        skipped on the surrogate's ranking and counted as hits), and a
        segment whose mean ensemble disagreement exceeds
        ``gate.cfg.fallback_tau`` abandons the surrogate for the rest of
        the run.  ``gate=None`` is byte-for-byte the historical exact
        path.  The final ``sur_stats`` dict reports ``used`` / ``hits``
        / ``fallbacks``.
        """
        policy = self.policy
        sched = quantize.schedule(budget, self.nsga.pop,
                                  policy.chunk_generations, quantize_down)
        pop, generations = sched.pop, sched.generations
        chunk, n_seg = sched.chunk, sched.n_seg
        cfg = dataclasses.replace(self.nsga, pop=pop, generations=chunk)
        mesh = self._mesh_for(pop)
        run = make_nsga(spec, space, objectives, cfg, tech=self.tech,
                        mesh=mesh)
        sur_stats = dict(used=False, hits=0, fallbacks=0)
        run_g, sur, n_exact = None, None, pop
        if gate is not None:
            n_exact = gate.cfg.n_exact(pop)
            if n_exact < pop and mesh is None:
                # gating is mutually exclusive with island sharding (the
                # gated scan is single-device); a meshed service quietly
                # runs exact rather than fail the query
                run_g = make_nsga_gated(spec, space, objectives, cfg,
                                        tech=self.tech, n_exact=n_exact,
                                        beta=gate.cfg.beta,
                                        tau=gate.cfg.tau)
                sur = gate.model.scan_arrays(gate.embedding)
            else:
                n_exact = pop
        # archive-projected hypervolume pairs, in METRIC_KEYS column space
        hv_pairs = [(METRIC_KEYS.index(objectives[i]),
                     METRIC_KEYS.index(objectives[j]))
                    for i, j in objective_pairs(len(objectives))]
        k_init, k_run = jax.random.split(key)

        def seed(filler, extra=None):
            return _seed_population(arc, pop, filler, extra)

        filler = jax.vmap(lambda k: random_design(k, space))(
            jax.random.split(k_init, pop))
        st = plateau if plateau is not None else PlateauState()
        trace = None
        plateaued, interrupted, spent_g = False, False, 0
        spent_e = 0                     # exact evaluations this attempt
        s0, spent0, sig = 0, 0, None    # spent0: chunks paid for by a
        #                                 killed earlier attempt
        spent0_e = 0
        if checkpoint is not None:
            sig = self._ckpt_signature(
                objectives, budget, pop, generations, chunk, key, seeds,
                gate_digest=(gate.model.digest()
                             if run_g is not None else None))
            rest = self._load_ckpt(checkpoint, sig, arc, st)
            if rest is not None:
                s0, spent0, r_e, fell_back0, filler, trace = rest
                spent0_e = r_e if r_e is not None else spent0 * pop
                if fell_back0 and run_g is not None:
                    run_g = None        # the dead attempt had already
                    sur_stats["used"] = True    # abandoned the surrogate
                    sur_stats["fallbacks"] += 1
        for s in range(s0, n_seg):
            if control is not None and control.stopped:
                interrupted = True      # the checkpoint (if any) stays:
                break                   # a resume picks up right here
            t_seg = time.perf_counter()
            # first call of this scan variant pays XLA lowering — attribute
            # it separately so plan-vs-actual tables and the segment-time
            # histogram aren't polluted by one-off compiles
            active = run_g if run_g is not None else run
            compiled = not active.compile_state["executed"]
            if run_g is not None:
                pop_s, _raw, _sel, ev_designs, ev_raw, ev_feas, tr = run_g(
                    jax.random.fold_in(k_run, s),
                    seed(filler, seeds if s == 0 else None), sur)
                per_gen = n_exact       # only the gate's exact slots cost
            else:
                pop_s, _raw, _sel, ev_designs, ev_raw, ev_feas, tr = run(
                    jax.random.fold_in(k_run, s),
                    seed(filler, seeds if s == 0 else None))
                per_gen = pop
            # archive EVERY evaluation of the segment, not just the
            # survivors — masked to feasible designs so the archive (and
            # every front served from it) never carries a
            # constraint-violating point
            arc.insert(
                jax.tree.map(lambda x: x.reshape((-1,) + x.shape[2:]),
                             ev_designs),
                ev_raw.reshape(-1, ev_raw.shape[-1]),
                mask=ev_feas.reshape(-1), count_evals=False)
            arc.n_evals += per_gen * chunk  # one vmapped evaluation per
            spent_g += chunk                # step (gated: exact slots)
            spent_e += per_gen * chunk
            filler = pop_s
            seg_trace = ConvergenceTrace.from_scan(objectives, tr,
                                                   per_gen)
            if run_g is not None:
                skipped = (pop - n_exact) * chunk
                sur_stats["used"] = True
                sur_stats["hits"] += skipped
                obs.inc("explore.surrogate.hits", skipped)
                obs.inc("explore.surrogate.forced_exact",
                        int(np.sum(np.asarray(tr["forced_exact"]))))
                dis = float(np.mean(np.asarray(tr["disagreement"])))
                if dis > gate.cfg.fallback_tau:
                    # the ensemble is out of its depth on this region of
                    # the design space — exact for the rest of the run
                    run_g = None
                    sur_stats["fallbacks"] += 1
                    obs.inc("explore.surrogate.fallbacks")
            hv_now = np.asarray([arc.projected_hypervolume(p)
                                 for p in hv_pairs])
            seg_trace.archive_hv = hv_now[None, :]
            trace = seg_trace if trace is None else trace.extend(seg_trace)
            # the trace/hypervolume work above runs on the host, so the
            # async dispatch has drained by here: dt is honest wall-clock
            dt = time.perf_counter() - t_seg
            obs.inc("explore.segments")
            obs.observe("explore.segment_compile_s" if compiled
                        else "explore.segment_s", dt)
            if on_segment is not None:     # stream the segment boundary:
                on_segment(s, seg_trace, dt, compiled)     # the
                #                            incremental trace slice
            # ---- plateau check on the archive-projected hypervolume ----
            # an empty archive means NOTHING has been found yet — that is
            # stagnation, not convergence, and must never feed the streak
            # (count=False records the vector without judging it)
            if policy.adaptive and hv_pairs:
                streak = st.observe(hv_now, policy.plateau_rel,
                                    count=bool(len(arc)))
                if streak >= policy.patience and s + 1 < n_seg:
                    plateaued = True
                    obs.inc("explore.plateau_stops")
                    break
            if checkpoint is not None:  # AFTER the plateau observation:
                #                         the snapshot must carry this
                #                         segment's hv as the comparison
                #                         base, or a resume re-judges the
                #                         seam against a stale vector
                self._save_ckpt(checkpoint, sig, s + 1, spent0 + spent_g,
                                spent0_e + spent_e,
                                gate is not None and run_g is None,
                                arc, filler, trace, st)
        n_run = spent_e
        # the ledger may only be fed from budget the CALLER offered and
        # the run — ALL attempts of it — left unspent: the pow2
        # quantization headroom above the requested budget is not real
        # credit, and a resumed attempt's own spend understates the
        # total.  Only a PLATEAU banks — a gated run that merely spent
        # less than its budget reports the savings as surrogate hits,
        # not as ledger credit (reallocation would respend them and
        # erase the saving)
        banked = max(0, budget - (spent0_e + spent_e)) \
            if plateaued else 0
        if checkpoint is not None and not interrupted:
            Path(checkpoint).unlink(missing_ok=True)    # run complete:
            #                                 nothing left to resume
        return n_run, trace, plateaued, banked, interrupted, sur_stats


def _seed_population(arc: ParetoArchive, pop: int, filler: Dict,
                     extra: Optional[Dict] = None) -> Dict:
    """Population for the next segment: archive front head (the all-time
    best designs), then any transfer ``extra`` seeds, ``filler`` tail
    (fresh random samples for segment 0, then the carried evolving
    population).  Transfer seeds reserve their slots FIRST (the caller
    caps them at half the population when the archive is non-empty, see
    ``_group_seeds``), so a warm refinement's large front head cannot
    crowd out the migrated neighbors it asked for.  Shared by the
    sequential ``_refine`` loop and the megabatched lanes — one seeding
    rule, wherever a population is assembled."""
    fr_designs, _ = arc.front()
    n_ext = 0
    if extra is not None:
        # the CALLER caps the seed count (at most half the effective
        # population when the archive is non-empty) — re-deriving the cap
        # here would just be a second copy of that logic waiting to drift
        n_ext = min(int(next(iter(extra.values())).shape[0]), pop)
    n_warm = min(len(arc), pop - n_ext)
    if n_warm + n_ext == 0:
        return filler

    def leaf(k, v):
        parts = []
        if n_warm:
            parts.append(jnp.asarray(fr_designs[k][:n_warm]))
        if n_ext:
            parts.append(jnp.asarray(extra[k][:n_ext]))
        parts.append(jnp.asarray(v)[n_warm + n_ext:])
        return jnp.concatenate(parts)

    return {k: leaf(k, v) for k, v in filler.items()}


# ---------------------------------------------------------------------------
# module-level convenience: a default singleton service
# ---------------------------------------------------------------------------
_DEFAULT: Optional[ExplorationService] = None


def default_service(**kwargs) -> ExplorationService:
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = ExplorationService(**kwargs)
    elif kwargs:
        raise RuntimeError(
            "the default exploration service is already initialized; "
            "construct ExplorationService(...) directly for a custom "
            "configuration")
    return _DEFAULT


def explore(graph: WorkloadGraph,
            objectives: Sequence[str] = DEFAULT_OBJECTIVES,
            budget: int = 2048, ch_max: int = 4,
            space_kwargs: Optional[Dict] = None,
            transfer: bool = False,
            service: Optional[ExplorationService] = None,
            key=None) -> ExploreResult:
    """One-call front query against the process-wide default service.

    DEPRECATED — delegates to the ``ExplorationService.explore`` shim
    (one ``DeprecationWarning``); use ``repro.api.submit`` instead."""
    svc = service or default_service()
    return svc.explore(graph, objectives, budget, ch_max, space_kwargs,
                       transfer=transfer, key=key)
