"""AdamW from scratch (no optax offline) + schedules + global-norm clipping.

Moments default to bfloat16 storage (configurable): at 314B params the f32
m/v pair alone exceeds a 16 GB/chip HBM budget at 256-way sharding; bf16
moments halve that (quantized-moment Adam in the 8-bit-Adam tradition).
The moments inherit the parameters' PartitionSpecs, so optimizer state is
ZeRO-sharded with the weights.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "bfloat16"
    schedule: str = "cosine"        # constant | linear | cosine
    warmup_steps: int = 100
    total_steps: int = 10_000


def schedule_lr(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        decay = 1.0
    elif cfg.schedule == "linear":
        decay = jnp.maximum(
            1.0 - (step - cfg.warmup_steps)
            / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0)
    else:
        t = jnp.clip((step - cfg.warmup_steps)
                     / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                     0.0, 1.0)
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * decay


def global_norm(tree):
    return jnp.sqrt(jax.tree.reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))),
        tree, jnp.zeros((), jnp.float32)))


def adamw_init(cfg: AdamWConfig, params) -> Dict:
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros_like(p, dtype=mdt)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(cfg: AdamWConfig, grads, opt_state, params):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9)) \
        if cfg.clip_norm > 0 else 1.0
    lr = schedule_lr(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
        mhat = m32 / b1c
        vhat = v32 / b2c
        dp = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * dp).astype(p.dtype),
                m32.astype(mdt), v32.astype(mdt))

    out = jax.tree.map(upd, params, grads, opt_state["mu"], opt_state["nu"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}, \
        {"grad_norm": gn, "lr": lr}
