"""Int8 gradient compression with error feedback (distributed-optimization
trick for cross-pod gradient reduction).

On a multi-pod mesh the pod-axis all-reduce crosses the slowest links; int8
quantization cuts those bytes 4x.  Error feedback (Karimireddy et al.) keeps
the quantization bias out of the optimization path: the residual of each
quantization is added back before the next one, making the scheme
convergent.  Unit-tested for convergence on a quadratic in
tests/test_substrates.py.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def init_error_state(params) -> Dict:
    return jax.tree.map(
        lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)


def compress_grads(grads, err_state):
    """Quantize (grad + error) per leaf; returns (int8 tree, scales tree,
    new error state)."""
    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, s = quantize_int8(x)
        deq = dequantize_int8(q, s)
        return q, s, x - deq

    out = jax.tree.map(one, grads, err_state)
    istup = lambda t: isinstance(t, tuple)
    qs = jax.tree.map(lambda t: t[0], out, is_leaf=istup)
    ss = jax.tree.map(lambda t: t[1], out, is_leaf=istup)
    es = jax.tree.map(lambda t: t[2], out, is_leaf=istup)
    return qs, ss, es


def decompress_grads(qs, ss):
    return jax.tree.map(dequantize_int8, qs, ss)
