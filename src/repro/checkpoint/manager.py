"""Sharded, checksummed, async checkpointing with elastic restore.

Layout (no tensorstore offline — plain npz shards):

    <dir>/step_000100/
        meta.json            {step, n_shards, tree structure, checksums}
        shard_00000.npz      flat {leaf-path: local array block}
        ...
        COMMIT               written LAST (atomic-rename publish)

* every leaf is saved as the FULL (addressable-combined) array by the host
  that owns it — on a real multi-host fleet each host saves its addressable
  slice; on this single-host container that degenerates to one shard;
* ``COMMIT`` + per-shard sha256 make torn/corrupt checkpoints detectable:
  ``latest_step`` skips uncommitted or corrupt directories (crash-mid-save
  is unit-tested);
* restore is ELASTIC: arrays are re-laid-out onto whatever mesh/sharding
  the restoring job provides (jax.device_put with the new sharding), so a
  checkpoint from an N-chip run loads on an M-chip run;
* the async writer moves the device->host copy + file I/O off the training
  loop; ``wait()`` joins before the next save (single outstanding save).
"""

from __future__ import annotations

import hashlib
import json
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def _sha(arr: np.ndarray) -> str:
    return hashlib.sha256(arr.tobytes()).hexdigest()[:16]


class CheckpointManager:
    def __init__(self, directory, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- save
    def save(self, step: int, state, blocking: bool = False):
        self.wait()
        host = jax.tree.map(lambda x: np.asarray(x), state)

        def write():
            tmp = self.dir / f".tmp_step_{step:09d}"
            final = self.dir / f"step_{step:09d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            flat = _flatten(host)
            np.savez(tmp / "shard_00000.npz", **flat)
            meta = {
                "step": step,
                "n_shards": 1,
                "checksums": {k: _sha(v) for k, v in flat.items()},
                "shapes": {k: list(v.shape) for k, v in flat.items()},
            }
            (tmp / "meta.json").write_text(json.dumps(meta))
            (tmp / "COMMIT").write_text("ok")
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self._committed())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)

    # ---------------------------------------------------------- restore
    def _committed(self):
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "COMMIT").exists() and (p / "meta.json").exists():
                out.append(int(p.name.split("_")[1]))
        return out

    def latest_step(self) -> Optional[int]:
        steps = self._committed()
        return max(steps) if steps else None

    def restore(self, step: int, target, shardings=None):
        """Load into the structure of ``target`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings``: matching pytree of NamedShardings
        for elastic re-layout; None keeps host arrays."""
        d = self.dir / f"step_{step:09d}"
        meta = json.loads((d / "meta.json").read_text())
        with np.load(d / "shard_00000.npz") as z:
            flat = {k: z[k] for k in z.files}
        for k, v in flat.items():
            if _sha(v) != meta["checksums"][k]:
                raise IOError(f"checkpoint shard corrupt at leaf {k}")

        paths = jax.tree_util.tree_flatten_with_path(target)[0]
        treedef = jax.tree_util.tree_structure(target)
        leaves = []
        for path, leaf in paths:
            key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                           for k in path)
            if key not in flat:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = flat[key]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"{key}: checkpoint shape {arr.shape} != {leaf.shape}")
            leaves.append(arr.astype(leaf.dtype))
        state = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        return state
