"""Straggler mitigation: per-step wall-time EWMA monitor.

On a 1000+ node fleet, consistently-slow hosts are the main silent
throughput killer (a synchronous step runs at the speed of the slowest
participant).  The monitor keeps an exponentially-weighted mean/variance of
step times and flags steps slower than ``mean + nsigma * std`` (with a
relative floor) — exactly the signal a fleet controller uses to cordon a
host and trigger an elastic restart without it.  Here the flag is surfaced
to the driver and tested with injected delays."""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass
class StragglerMonitor:
    alpha: float = 0.1           # EWMA weight
    nsigma: float = 4.0
    rel_floor: float = 1.5       # never flag below 1.5x the mean
    warmup: int = 5              # first steps include compile time

    mean: float = 0.0
    var: float = 0.0
    n: int = 0

    def observe(self, step: int, dt: float) -> bool:
        """Feed one step time; returns True if it is a straggler step."""
        self.n += 1
        if self.n <= self.warmup:
            self.mean = dt if self.n == 1 else (
                self.mean + (dt - self.mean) / self.n)
            return False
        flagged = False
        std = math.sqrt(max(self.var, 1e-12))
        if (dt > self.mean + self.nsigma * std
                and dt > self.rel_floor * self.mean):
            flagged = True
        else:
            # only fold non-outliers into the statistics
            d = dt - self.mean
            self.mean += self.alpha * d
            self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        return flagged
