"""Fault-tolerant training driver: checkpoint/restart + step retry +
straggler monitoring.

``FaultTolerantTrainer.run`` owns the production loop:
  * restores from the newest COMMITTED checkpoint (torn saves are skipped),
  * saves every ``ckpt_every`` steps through the async CheckpointManager,
  * retries a step on transient failure (re-materializing state from the
    last checkpoint first — on real fleets this is where the job re-admits
    replacement hosts; the re-init path is identical),
  * feeds per-step wall times to the StragglerMonitor; flagged steps are
    surfaced to the caller (on a fleet: to the scheduler).

Crash-recovery semantics are unit-tested in tests/test_substrates.py by
killing the loop mid-run and restarting it.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Iterator, List, Optional

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.runtime.straggler import StragglerMonitor


@dataclasses.dataclass
class RunReport:
    start_step: int
    end_step: int
    losses: List[float]
    restarts: int
    straggler_steps: List[int]
    wall_s: float


class TransientError(RuntimeError):
    """Raised by fault-injection hooks / wrapped device errors."""


class FaultTolerantTrainer:
    def __init__(self, train_step: Callable, ckpt: CheckpointManager,
                 ckpt_every: int = 50, max_retries: int = 3,
                 fault_hook: Optional[Callable[[int], None]] = None):
        self.train_step = jax.jit(train_step, donate_argnums=(0,))
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.max_retries = max_retries
        self.fault_hook = fault_hook
        self.monitor = StragglerMonitor()

    def run(self, state, batch_at: Callable[[int], Dict],
            num_steps: int, start_step: Optional[int] = None) -> tuple:
        restarts = 0
        latest = self.ckpt.latest_step()
        step = start_step if start_step is not None else (
            (latest + 1) if latest is not None else 0)
        if latest is not None and start_step is None:
            state = self.ckpt.restore(latest, state)
            state = jax.tree.map(jax.numpy.asarray, state)
        losses: List[float] = []
        stragglers: List[int] = []
        t0 = time.time()
        end = step + num_steps

        while step < end:
            t_step = time.time()
            tries = 0
            while True:
                try:
                    if self.fault_hook is not None:
                        self.fault_hook(step)
                    new_state, metrics = self.train_step(
                        state, batch_at(step))
                    break
                except TransientError:
                    tries += 1
                    restarts += 1
                    if tries > self.max_retries:
                        raise
                    # recover: reload the last durable state (donated
                    # buffers may be gone) and retry the same step
                    latest = self.ckpt.latest_step()
                    if latest is not None:
                        spec = jax.eval_shape(lambda: state) \
                            if not _is_concrete(state) else state
                        state = self.ckpt.restore(latest, spec)
                        state = jax.tree.map(jax.numpy.asarray, state)
            state = new_state
            losses.append(float(metrics["loss"]))
            if self.monitor.observe(step, time.time() - t_step):
                stragglers.append(step)
            if self.ckpt_every and (step + 1) % self.ckpt_every == 0:
                self.ckpt.save(step, state)
            step += 1

        self.ckpt.save(step - 1, state, blocking=True)
        return RunReport(start_step=end - num_steps, end_step=step,
                         losses=losses, restarts=restarts,
                         straggler_steps=stragglers,
                         wall_s=time.time() - t0), state


def _is_concrete(tree) -> bool:
    leaves = jax.tree_util.tree_leaves(tree)
    return bool(leaves) and not isinstance(
        leaves[0], jax.ShapeDtypeStruct)
