"""``repro.api`` — the one front door to Monad's search engines.

Re-exports the declarative Problem / Query / Plan / Session surface from
``repro.explore.api``: build a hashable ``Problem``, describe a ``Query``
(budget, engine, transfer/seed/policy options), inspect the ``Plan``
before spending anything, and ``submit`` for a unified ``Result`` with
full provenance — whichever engine (NSGA front explorer, nested BO x SA,
or the paper's two-stage flow) answers it.

    from repro.api import Problem, Query, Session

    s = Session()
    q = Query(Problem(graph, objectives=("latency_ns", "cost_usd")),
              budget=2048, transfer=True)
    print(s.plan(q))            # engine, segments, predicted neighbors
    r = s.submit(q)             # unified Result + Provenance
"""

from .explore.api import (ENGINES, NeighborPlan, Plan,  # noqa: F401
                          Problem, Provenance, Query, Result, SegmentEvent,
                          SegmentPlan, Session, plan, session, submit)

__all__ = [
    "ENGINES", "NeighborPlan", "Plan", "Problem", "Provenance", "Query",
    "Result", "SegmentEvent", "SegmentPlan", "Session", "plan", "session",
    "submit",
]
