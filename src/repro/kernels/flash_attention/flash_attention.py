"""Pallas TPU flash-attention kernel (blockwise online softmax).

Canonical 3-D grid formulation: grid = (B * H, num_q_blocks, num_kv_blocks);
the kv-block dimension is innermost so the VMEM scratch accumulators
(running max m, running sum l, output accumulator acc) persist across it
(TPU executes the grid sequentially per core).  BlockSpecs tile Q/K/V into
MXU-aligned (block, head_dim) VMEM tiles; GQA is handled in the index maps
(query head h reads kv head h // (H // KV)) so KV tiles are never
materialized per-query-head in HBM.

Masking (causal / sliding window / cache-validity) is applied blockwise;
fully-masked kv blocks still execute but contribute zeros — block skipping
is a grid-shape optimization left to the caller.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # VMEM scratch: TPU memory space (falls back for interpret mode)
    import jax.experimental.pallas.tpu as pltpu
    def _vmem(shape):
        return pltpu.VMEM(shape, jnp.float32)
except Exception:  # pragma: no cover
    def _vmem(shape):
        return jax.ShapeDtypeStruct(shape, jnp.float32)

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = float(jnp.finfo(jnp.float32).min)


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 block_q: int, block_k: int, sm_scale: float,
                 mask_kind: str, window: int, kv_valid_len, num_kv_blocks,
                 q_offset):
    """One (q_block, kv_block) step of online-softmax attention."""
    kv_i = pl.program_id(2)

    @pl.when(kv_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * sm_scale       # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)

    q_ids = (pl.program_id(1) * block_q
             + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
             + q_offset)
    k_ids = (kv_i * block_k
             + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1))
    mask = jnp.ones((block_q, block_k), bool)
    if kv_valid_len is not None:
        mask &= k_ids < kv_valid_len
    if mask_kind in ("causal", "window"):
        mask &= k_ids <= q_ids
    if mask_kind == "window":
        mask &= (q_ids - k_ids) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                  # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows (m == -inf) against NaNs
    safe_m = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.where(mask, jnp.exp(s - safe_m), 0.0)
    alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - safe_m))
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kv_i == num_kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, mask_kind: str = "causal",
                           window: int = 0,
                           kv_valid_len: Optional[int] = None,
                           block_q: int = DEFAULT_BLOCK_Q,
                           block_k: int = DEFAULT_BLOCK_K,
                           interpret: bool = False):
    """q: (B, Sq, H, D); k/v: (B, Sk, KV, D).  Returns (B, Sq, H, D).

    Requires Sq % block_q == 0 and Sk % block_k == 0 (the ops wrapper pads).
    """
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    Dv = v.shape[-1]                  # may differ from D (MLA)
    rep = H // KV
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    nq = Sq // bq
    nk = Sk // bk
    q_offset = (kv_valid_len - Sq) if kv_valid_len is not None else 0

    qt = q.transpose(0, 2, 1, 3)                         # (B, H, Sq, D)
    kt = k.transpose(0, 2, 1, 3)                         # (B, KV, Sk, D)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _attn_kernel, block_q=bq, block_k=bk,
        sm_scale=1.0 / math.sqrt(D), mask_kind=mask_kind, window=window,
        kv_valid_len=kv_valid_len, num_kv_blocks=nk, q_offset=q_offset)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D),
                         lambda bh, qi, ki: (bh // H, bh % H, qi, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda bh, qi, ki: (bh // H, (bh % H) // rep, ki, 0)),
            pl.BlockSpec((1, 1, bk, Dv),
                         lambda bh, qi, ki: (bh // H, (bh % H) // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, Dv),
                               lambda bh, qi, ki: (bh // H, bh % H, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, Dv), q.dtype),
        scratch_shapes=[_vmem((bq, 1)), _vmem((bq, 1)), _vmem((bq, Dv))],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
