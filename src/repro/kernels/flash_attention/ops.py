"""Dispatching wrapper for flash attention.

* TPU backend -> the Pallas kernel (``flash_attention.py``).
* other backends (this CPU container, dry-runs) -> a *blocked* jnp
  implementation with the same online-softmax structure: ``lax.scan`` over
  KV blocks, O(S * block) live memory, identical FLOP count — so the
  compiled dry-run's cost/memory analysis reflects the kernelized program,
  not a naive O(S^2)-materialized one.
* ``REPRO_PALLAS_INTERPRET=1`` forces the Pallas kernel in interpret mode
  (kernel-correctness tests).
"""

from __future__ import annotations

import math
import os
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_pallas

BLOCK_K = 512
NEG_INF = float(jnp.finfo(jnp.float32).min)


def _use_pallas() -> bool:
    return jax.default_backend() == "tpu"


def _force_interpret() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "0") == "1"


def flash_attention_blocked(q, k, v, mask_kind: str = "causal",
                            window: int = 0,
                            kv_valid_len: Optional[int] = None,
                            block_k: int = BLOCK_K):
    """Online-softmax attention, scanning KV blocks (jnp reference path)."""
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    Dv = v.shape[-1]                  # may differ from D (MLA)
    rep = H // KV
    bk = min(block_k, Sk)
    pad = (-Sk) % bk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nk = (Sk + pad) // bk

    qf = q.astype(jnp.float32) * (1.0 / math.sqrt(D))
    q_pos = (jnp.arange(Sq) if kv_valid_len is None
             else kv_valid_len - Sq + jnp.arange(Sq))
    valid_len = Sk if kv_valid_len is None else kv_valid_len

    kb = k.reshape(B, nk, bk, KV, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nk, bk, KV, Dv).transpose(1, 0, 2, 3, 4)

    def step(carry, xs):
        m, l, acc = carry
        ki, kblk, vblk = xs
        kf = jnp.repeat(kblk, rep, axis=2).astype(jnp.float32)
        vf = jnp.repeat(vblk, rep, axis=2).astype(jnp.float32)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
        k_ids = ki * bk + jnp.arange(bk)
        mask = k_ids[None, :] < valid_len
        if mask_kind in ("causal", "window"):
            mask = mask & (k_ids[None, :] <= q_pos[:, None])
        if mask_kind == "window":
            mask = mask & (q_pos[:, None] - k_ids[None, :] < window)
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m, m_cur)
        safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        p = jnp.where(mask[None, None], jnp.exp(s - safe), 0.0)
        alpha = jnp.where(m <= NEG_INF / 2, 0.0, jnp.exp(m - safe))
        l = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha[..., 0][..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vf)
        return (m_new, l, acc), None

    m0 = jnp.full((B, H, Sq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq, 1), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (jnp.arange(nk), kb, vb))
    out = acc / jnp.maximum(l, 1e-20)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


# ---------------------------------------------------------------------------
# custom VJP: the flash-attention backward recomputes the per-block
# probabilities instead of letting scan stack them (without this, each
# attention op saves O(S^2) f32 residuals for autodiff — the whisper train
# cell hit 37 GB/device of stacked probabilities; see EXPERIMENTS.md §Perf).
# ---------------------------------------------------------------------------
def _fwd_with_lse(q, k, v, mask_kind, window, kv_valid_len, block_k):
    """Blocked forward that also returns the log-sum-exp per query row."""
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    rep = H // KV
    bk = min(block_k, Sk)
    pad = (-Sk) % bk
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else k
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else v
    nk = (Sk + pad) // bk
    qf = q.astype(jnp.float32) * (1.0 / math.sqrt(D))
    q_pos = (jnp.arange(Sq) if kv_valid_len is None
             else kv_valid_len - Sq + jnp.arange(Sq))
    valid_len = Sk if kv_valid_len is None else kv_valid_len
    kb = kp.reshape(B, nk, bk, KV, D).transpose(1, 0, 2, 3, 4)
    vb = vp.reshape(B, nk, bk, KV, Dv).transpose(1, 0, 2, 3, 4)

    def blk_mask(ki):
        k_ids = ki * bk + jnp.arange(bk)
        m = k_ids[None, :] < valid_len
        if mask_kind in ("causal", "window"):
            m = m & (k_ids[None, :] <= q_pos[:, None])
        if mask_kind == "window":
            m = m & (q_pos[:, None] - k_ids[None, :] < window)
        return m

    def step(carry, xs):
        m, l, acc = carry
        ki, kblk, vblk = xs
        kf = jnp.repeat(kblk, rep, axis=2).astype(jnp.float32)
        vf = jnp.repeat(vblk, rep, axis=2).astype(jnp.float32)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
        mask = blk_mask(ki)
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        p = jnp.where(mask[None, None], jnp.exp(s - safe), 0.0)
        alpha = jnp.where(m <= NEG_INF / 2, 0.0, jnp.exp(m - safe))
        l = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha[..., 0][..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vf)
        return (m_new, l, acc), None

    m0 = jnp.full((B, H, Sq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq, 1), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (jnp.arange(nk), kb, vb))
    lse = (m + jnp.log(jnp.maximum(l, 1e-20)))[..., 0]       # (B,H,Sq)
    out = (acc / jnp.maximum(l, 1e-20)).transpose(0, 2, 1, 3)
    return out.astype(q.dtype), lse, blk_mask, (kb, vb, nk, bk, rep)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _fa_diff(q, k, v, mask_kind, window, kv_valid_len, block_k):
    out, _, _, _ = _fwd_with_lse(q, k, v, mask_kind, window, kv_valid_len,
                                 block_k)
    return out


def _fa_diff_fwd(q, k, v, mask_kind, window, kv_valid_len, block_k):
    out, lse, _, _ = _fwd_with_lse(q, k, v, mask_kind, window, kv_valid_len,
                                   block_k)
    return out, (q, k, v, out, lse)


def _fa_diff_bwd(mask_kind, window, kv_valid_len, block_k, res, do):
    q, k, v, out, lse = res
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    rep = H // KV
    bk = min(block_k, Sk)
    pad = (-Sk) % bk
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else k
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else v
    nk = (Sk + pad) // bk
    scale = 1.0 / math.sqrt(D)
    qf = q.astype(jnp.float32)
    dof = do.astype(jnp.float32).transpose(0, 2, 1, 3)        # (B,H,Sq,Dv)
    outf = out.astype(jnp.float32).transpose(0, 2, 1, 3)
    delta = jnp.sum(dof * outf, axis=-1)                      # (B,H,Sq)
    q_pos = (jnp.arange(Sq) if kv_valid_len is None
             else kv_valid_len - Sq + jnp.arange(Sq))
    valid_len = Sk if kv_valid_len is None else kv_valid_len
    kb = kp.reshape(B, nk, bk, KV, D).transpose(1, 0, 2, 3, 4)
    vb = vp.reshape(B, nk, bk, KV, Dv).transpose(1, 0, 2, 3, 4)

    def step(dq, xs):
        ki, kblk, vblk = xs
        kf = jnp.repeat(kblk, rep, axis=2).astype(jnp.float32)
        vf = jnp.repeat(vblk, rep, axis=2).astype(jnp.float32)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf * scale, kf)
        k_ids = ki * bk + jnp.arange(bk)
        mask = k_ids[None, :] < valid_len
        if mask_kind in ("causal", "window"):
            mask = mask & (k_ids[None, :] <= q_pos[:, None])
        if mask_kind == "window":
            mask = mask & (q_pos[:, None] - k_ids[None, :] < window)
        p = jnp.where(mask[None, None], jnp.exp(s - lse[..., None]), 0.0)
        dv_b = jnp.einsum("bhqk,bhqd->bkhd", p, dof)          # (B,bk,H,Dv)
        dp = jnp.einsum("bhqd,bkhd->bhqk", dof, vf)
        ds = p * (dp - delta[..., None])                      # (B,H,Sq,bk)
        dq = dq + scale * jnp.einsum("bhqk,bkhd->bqhd", ds, kf)
        dk_b = scale * jnp.einsum("bhqk,bqhd->bkhd", ds, qf)
        # GQA: fold query-head groups back onto their kv head
        dv_b = dv_b.reshape(B, bk, KV, rep, Dv).sum(3)
        dk_b = dk_b.reshape(B, bk, KV, rep, D).sum(3)
        return dq, (dk_b, dv_b)

    dq0 = jnp.zeros((B, Sq, H, D), jnp.float32)
    dq, (dk_s, dv_s) = jax.lax.scan(step, dq0, (jnp.arange(nk), kb, vb))
    dk = dk_s.transpose(1, 0, 2, 3, 4).reshape(B, nk * bk, KV, D)[:, :Sk]
    dv = dv_s.transpose(1, 0, 2, 3, 4).reshape(B, nk * bk, KV, Dv)[:, :Sk]
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


_fa_diff.defvjp(_fa_diff_fwd, _fa_diff_bwd)


def flash_attention(q, k, v, mask_kind: str = "causal", window: int = 0,
                    kv_valid_len=None):
    """Public op.

    * static kv_valid_len (train / prefill): differentiable custom-VJP
      blocked path (backward recomputes probabilities per kv block);
    * traced kv_valid_len (decode): plain blocked path (never
      differentiated);
    * TPU backend / REPRO_PALLAS_INTERPRET: the Pallas kernel.
    """
    if _force_interpret():
        static_len = int(kv_valid_len) if kv_valid_len is not None else None
        return flash_attention_pallas(q, k, v, mask_kind, window,
                                      static_len, interpret=True)
    if _use_pallas() and (kv_valid_len is None
                          or isinstance(kv_valid_len, int)):
        return flash_attention_pallas(q, k, v, mask_kind, window,
                                      kv_valid_len)
    if kv_valid_len is None or isinstance(kv_valid_len, int):
        return _fa_diff(q, k, v, mask_kind, window, kv_valid_len, BLOCK_K)
    if q.shape[1] == 1:
        # single-token decode: dense (unscanned) attention so a
        # seq-sharded KV cache reduces via DISTRIBUTED partial softmax
        # (flash-decoding) instead of being all-gathered around the
        # sequential kv-block scan — see EXPERIMENTS.md §Perf
        from .ref import attention_ref
        return attention_ref(q, k, v, mask_kind, window, kv_valid_len)
    return flash_attention_blocked(q, k, v, mask_kind, window, kv_valid_len)
