"""Pure-jnp oracle for flash attention (the correctness reference).

Naive O(S^2) materialized-scores attention with GQA head grouping and the
three mask kinds used by the model zoo:

* ``causal``  — key j visible to query at absolute position p iff j <= p
* ``window``  — causal AND p - j < window (sliding-window attention)
* ``none``    — full bidirectional (encoder / cross attention)

Query absolute positions: if ``kv_valid_len`` is given (decode with a KV
cache filled up to kv_valid_len), queries sit at positions
[kv_valid_len - S_q, kv_valid_len); otherwise position i = i.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import jax


def attention_ref(q, k, v, mask_kind: str = "causal", window: int = 0,
                  kv_valid_len: Optional[int] = None):
    """q: (B, Sq, H, D); k/v: (B, Sk, KV, D) with H % KV == 0.
    Returns (B, Sq, H, D) in q.dtype; softmax in float32."""
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    rep = H // KV
    kf = jnp.repeat(k, rep, axis=2).astype(jnp.float32)
    vf = jnp.repeat(v, rep, axis=2).astype(jnp.float32)
    qf = q.astype(jnp.float32) / jnp.sqrt(jnp.float32(D))

    scores = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)

    kpos = jnp.arange(Sk)
    if kv_valid_len is not None:
        qpos = kv_valid_len - Sq + jnp.arange(Sq)
        valid = kpos[None, :] < kv_valid_len
    else:
        qpos = jnp.arange(Sq)
        valid = jnp.ones((1, Sk), bool)
    neg = jnp.finfo(jnp.float32).min
    mask = jnp.broadcast_to(valid, (Sq, Sk))
    if mask_kind == "causal":
        mask = mask & (kpos[None, :] <= qpos[:, None])
    elif mask_kind == "window":
        mask = mask & (kpos[None, :] <= qpos[:, None]) \
            & (qpos[:, None] - kpos[None, :] < window)
    elif mask_kind != "none":
        raise ValueError(mask_kind)
    scores = jnp.where(mask[None, None], scores, neg)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vf)
    return out.astype(q.dtype)
