"""Dispatching wrapper for the GP covariance: Pallas on TPU (padding to the
tile grid), jnp reference elsewhere; REPRO_PALLAS_INTERPRET=1 forces the
kernel in interpret mode."""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from .gp_cov import matern52_pallas
from .ref import matern52_ref


def matern52(X1, X2, lengthscale: float = 0.3):
    use_pallas = (jax.default_backend() == "tpu"
                  or os.environ.get("REPRO_PALLAS_INTERPRET", "0") == "1")
    if not use_pallas:
        return matern52_ref(X1, X2, lengthscale)
    n, m = X1.shape[0], X2.shape[0]
    bn = 128 if n >= 128 else n
    bm = 128 if m >= 128 else m
    pn = (-n) % bn
    pm = (-m) % bm
    X1p = jnp.pad(X1, ((0, pn), (0, 0)))
    X2p = jnp.pad(X2, ((0, pm), (0, 0)))
    K = matern52_pallas(
        X1p, X2p, lengthscale,
        interpret=os.environ.get("REPRO_PALLAS_INTERPRET", "0") == "1")
    return K[:n, :m]
