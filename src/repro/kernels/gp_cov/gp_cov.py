"""Pallas TPU kernel: tiled Matern-5/2 covariance assembly.

The Bayesian engine's inner loop builds K(X, Z) for thousands of candidate
design points per acquisition step.  Squared distances are computed the
MXU-friendly way — |x|^2 + |z|^2 - 2 x.z — so the bulk of the work is one
(bn x d) @ (d x bm) matmul per tile; the Matern polynomial/exponential runs
on the VPU over the same VMEM tile.  Grid tiles K into (bn, bm) VMEM blocks.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 128
SQRT5 = math.sqrt(5.0)


def _cov_kernel(x_ref, z_ref, ls_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)           # (bn, d)
    z = z_ref[...].astype(jnp.float32)           # (bm, d)
    ls = ls_ref[0, 0]                            # (1, 1) scalar operand
    xx = jnp.sum(x * x, axis=1, keepdims=True)   # (bn, 1)
    zz = jnp.sum(z * z, axis=1, keepdims=True).T  # (1, bm)
    xz = jax.lax.dot_general(x, z, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    d2 = jnp.maximum(xx + zz - 2.0 * xz, 1e-12)
    r = jnp.sqrt(d2) / ls
    o_ref[...] = ((1.0 + SQRT5 * r + 5.0 / 3.0 * r * r)
                  * jnp.exp(-SQRT5 * r)).astype(o_ref.dtype)


def matern52_pallas(X1, X2, lengthscale=0.3, block: int = BLOCK,
                    interpret: bool = False):
    """X1: (n, d); X2: (m, d) -> K (n, m) float32.  n, m % block handled by
    padding in the ops wrapper.

    ``lengthscale`` is a runtime operand (Python float or traced scalar),
    not a compile-time static — hyperparameter sweeps reuse one compiled
    kernel instead of recompiling per value."""
    n, d = X1.shape
    m = X2.shape[0]
    bn = min(block, n)
    bm = min(block, m)
    assert n % bn == 0 and m % bm == 0
    ls = jnp.asarray(lengthscale, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _cov_kernel,
        grid=(n // bn, m // bm),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=interpret,
    )(X1.astype(jnp.float32), X2.astype(jnp.float32), ls)
