"""Pure-jnp oracle for the Matern-5/2 GP covariance (BO surrogate)."""

from __future__ import annotations

import math

import jax.numpy as jnp


def matern52_ref(X1, X2, lengthscale: float = 0.3):
    """X1: (n, d); X2: (m, d) -> K (n, m) float32."""
    d2 = jnp.sum((X1[:, None, :] - X2[None, :, :]) ** 2, -1)
    r = jnp.sqrt(jnp.maximum(d2, 1e-12)) / lengthscale
    s5 = math.sqrt(5.0)
    return ((1.0 + s5 * r + 5.0 * r * r / 3.0)
            * jnp.exp(-s5 * r)).astype(jnp.float32)
