"""Dispatching wrapper for the dominance-count kernel: Pallas on TPU
(padding the pool to the tile grid; padded rows are invalid dominators
and their counts are sliced off), jnp reference elsewhere;
REPRO_PALLAS_INTERPRET=1 forces the kernel in interpret mode — how the
CPU CI exercises the Pallas path."""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from .pareto_rank import dominance_counts_pallas
from .ref import dominance_counts_ref


def dominance_counts(objs, valid, block: int = 128):
    use_pallas = (jax.default_backend() == "tpu"
                  or os.environ.get("REPRO_PALLAS_INTERPRET", "0") == "1")
    if not use_pallas:
        return dominance_counts_ref(objs, valid)
    n = objs.shape[0]
    b = min(block, n)
    pn = (-n) % b
    objs_p = jnp.pad(objs, ((0, pn), (0, 0)))
    valid_p = jnp.pad(valid.astype(bool), (0, pn))      # padding rows can
    #                                                     never dominate
    counts = dominance_counts_pallas(
        objs_p, valid_p, block=b,
        interpret=os.environ.get("REPRO_PALLAS_INTERPRET", "0") == "1")
    return counts[:n]
