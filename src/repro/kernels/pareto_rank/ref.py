"""Pure-jnp oracle for the dominance-count kernel (NSGA selection /
archive insertion).  Must stay in lockstep with the historical
``repro.explore.archive.dominance_counts`` math — the archive routes
through this module, so this IS the canonical implementation."""

from __future__ import annotations

import jax.numpy as jnp


def dominance_counts_ref(objs, valid):
    """``objs``: (n, k) objective rows (all minimized); ``valid``: (n,)
    bool rows allowed to dominate.  Returns (n,) int32: for each row, how
    many valid rows dominate it (<= on every objective, < on at least
    one).  Materializes the fused (n, n, k) comparison — the tiled Pallas
    kernel exists precisely to avoid this above a size threshold."""
    le = jnp.all(objs[:, None, :] <= objs[None, :, :], axis=-1)
    lt = jnp.any(objs[:, None, :] < objs[None, :, :], axis=-1)
    dom = le & lt & valid[:, None]
    return jnp.sum(dom, axis=0).astype(jnp.int32)
