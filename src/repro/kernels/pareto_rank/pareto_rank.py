"""Pallas TPU kernel: tiled Pareto dominance counts.

NSGA environmental selection and every archive insertion rank a pool by
its dominance counts — the only O(n^2) step on the search path.  The jnp
reference materializes the fused (n, n, k) comparison tensor; this kernel
tiles it into (bi, bj) VMEM blocks and accumulates the dominator count
over the ``i`` (candidate-dominator) grid dimension, so peak memory is
O(block^2 * k) however large the pool grows.

Grid layout: ``(n/bj, n/bi)`` with the reduction dimension LAST, so every
revisit of one output block is contiguous and the accumulator never
leaves VMEM between visits (init on ``i == 0`` via ``pl.when``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 128


def _rank_kernel(oj_ref, oi_ref, vi_ref, c_ref):
    i = pl.program_id(1)                          # reduction position
    oj = oj_ref[...].astype(jnp.float32)          # (bj, k) the dominated
    oi = oi_ref[...].astype(jnp.float32)          # (bi, k) the dominators
    vi = vi_ref[...]                              # (bi, 1) f32 mask
    le = jnp.all(oi[:, None, :] <= oj[None, :, :], axis=-1)   # (bi, bj)
    lt = jnp.any(oi[:, None, :] < oj[None, :, :], axis=-1)
    dom = jnp.where(le & lt, vi, 0.0)             # mask broadcasts (bi, 1)
    acc = jnp.sum(dom, axis=0)[:, None]           # (bj, 1)

    @pl.when(i == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)

    c_ref[...] += acc


def dominance_counts_pallas(objs, valid, block: int = BLOCK,
                            interpret: bool = False):
    """``objs``: (n, k) float32; ``valid``: (n,) — n must divide by
    ``block`` (the ops wrapper pads).  Returns (n,) int32 dominance
    counts, matching ``ref.dominance_counts_ref``."""
    n, k = objs.shape
    b = min(block, n)
    assert n % b == 0
    vf = valid.astype(jnp.float32).reshape(n, 1)
    counts = pl.pallas_call(
        _rank_kernel,
        grid=(n // b, n // b),
        in_specs=[
            pl.BlockSpec((b, k), lambda j, i: (j, 0)),
            pl.BlockSpec((b, k), lambda j, i: (i, 0)),
            pl.BlockSpec((b, 1), lambda j, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((b, 1), lambda j, i: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        interpret=interpret,
    )(objs.astype(jnp.float32), objs.astype(jnp.float32), vf)
    return counts[:, 0].astype(jnp.int32)
