"""Pallas TPU kernel for the Mamba-1 selective scan (chunked).

Grid = (B, num_chunks); the chunk dimension is innermost/sequential on TPU,
so the running SSM state ``h`` lives in a VMEM scratch that persists across
chunks.  Each grid step loads a (chunk, Di) tile of u/delta and a
(chunk, Ds) tile of B/C into VMEM, then walks the chunk with a fori_loop of
fully-vectorized (Di, Ds) updates — sequential in time (the recurrence is
inherently sequential) but wide on the VPU lanes.

This is the TPU-native adaptation: instead of the GPU kernel's
warp-parallel prefix scan, we exploit the (Di x Ds) vector width per step
and the VMEM-resident state across the whole sequence (HBM traffic is
O(S*(Di+Ds)) for inputs + O(S*Di) outputs; the h state never leaves VMEM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    import jax.experimental.pallas.tpu as pltpu
    def _vmem(shape):
        return pltpu.VMEM(shape, jnp.float32)
except Exception:  # pragma: no cover
    def _vmem(shape):
        return jax.ShapeDtypeStruct(shape, jnp.float32)

DEFAULT_CHUNK = 64


def _scan_kernel(u_ref, d_ref, A_ref, b_ref, c_ref, h0_ref,
                 y_ref, hT_ref, h_ref, *, chunk: int, num_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = h0_ref[0]

    A = A_ref[...]                                   # (Di, Ds)

    def body(t, h):
        u_t = u_ref[0, t, :]                         # (Di,)
        d_t = d_ref[0, t, :]                         # (Di,)
        b_t = b_ref[0, t, :]                         # (Ds,)
        c_t = c_ref[0, t, :]                         # (Ds,)
        dA = jnp.exp(d_t[:, None] * A)               # (Di, Ds)
        h = dA * h + (d_t * u_t)[:, None] * b_t[None, :]
        y_ref[0, t, :] = jnp.sum(h * c_t[None, :], axis=1)
        return h

    h = jax.lax.fori_loop(0, chunk, body, h_ref[...])
    h_ref[...] = h

    @pl.when(ci == num_chunks - 1)
    def _finish():
        hT_ref[0] = h


def selective_scan_pallas(u, delta, A, Bc, Cc, h0=None,
                          chunk: int = DEFAULT_CHUNK,
                          interpret: bool = False):
    """u/delta: (B, S, Di); A: (Di, Ds); Bc/Cc: (B, S, Ds).
    Returns (y (B, S, Di), h_T (B, Di, Ds)), float32.  S % chunk == 0."""
    B, S, Di = u.shape
    Ds = A.shape[1]
    ch = min(chunk, S)
    nc = S // ch
    if h0 is None:
        h0 = jnp.zeros((B, Di, Ds), jnp.float32)

    kernel = functools.partial(_scan_kernel, chunk=ch, num_chunks=nc)
    y, hT = pl.pallas_call(
        kernel,
        grid=(B, nc),
        in_specs=[
            pl.BlockSpec((1, ch, Di), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, ch, Di), lambda b, c: (b, c, 0)),
            pl.BlockSpec((Di, Ds), lambda b, c: (0, 0)),
            pl.BlockSpec((1, ch, Ds), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, ch, Ds), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Di, Ds), lambda b, c: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, ch, Di), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Di, Ds), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, Di), jnp.float32),
            jax.ShapeDtypeStruct((B, Di, Ds), jnp.float32),
        ],
        scratch_shapes=[_vmem((Di, Ds))],
        interpret=interpret,
    )(u.astype(jnp.float32), delta.astype(jnp.float32),
      A.astype(jnp.float32), Bc.astype(jnp.float32), Cc.astype(jnp.float32),
      h0.astype(jnp.float32))
    return y, hT
