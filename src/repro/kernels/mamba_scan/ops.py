"""Dispatching wrapper for the selective scan.

* TPU -> chunked Pallas kernel.
* elsewhere -> associative-scan jnp path: the linear recurrence
  h_t = a_t h_{t-1} + b_t composes associatively ((a1,b1)o(a2,b2) =
  (a1 a2, b1 a2 + b2)), so ``jax.lax.associative_scan`` gives an O(log S)
  depth program — the right lowering for CPU/dry-run and the second
  correctness reference against ``ref.py``.
* ``REPRO_PALLAS_INTERPRET=1`` forces the Pallas kernel in interpret mode.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from .mamba_scan import selective_scan_pallas


def _use_pallas() -> bool:
    return jax.default_backend() == "tpu"


def selective_scan_assoc(u, delta, A, Bc, Cc, h0=None):
    """Associative-scan formulation (parallel prefix over S)."""
    B, S, Di = u.shape
    dA = jnp.exp(delta[..., None] * A[None, None])          # (B,S,Di,Ds)
    dBu = (delta * u)[..., None] * Bc[:, :, None, :]        # (B,S,Di,Ds)
    if h0 is not None:
        # fold h0 into the first element: h_1 = dA_1 h0 + dBu_1
        dBu = dBu.at[:, 0].add(dA[:, 0] * h0)

    def combine(l, r):
        a1, b1 = l
        a2, b2 = r
        return a1 * a2, b1 * a2 + b2

    _, hh = jax.lax.associative_scan(combine, (dA, dBu), axis=1)
    y = jnp.sum(hh * Cc[:, :, None, :], axis=-1)            # (B,S,Di)
    return y, hh[:, -1]


def selective_scan(u, delta, A, Bc, Cc, h0=None):
    if os.environ.get("REPRO_PALLAS_INTERPRET", "0") == "1":
        return selective_scan_pallas(u, delta, A, Bc, Cc, h0, interpret=True)
    if _use_pallas():
        return selective_scan_pallas(u, delta, A, Bc, Cc, h0)
    return selective_scan_assoc(u, delta, A, Bc, Cc, h0)
