"""Pure-jnp oracle for the Mamba-1 selective scan.

    h_t = exp(delta_t * A) * h_{t-1} + (delta_t * B_t) * u_t
    y_t = C_t . h_t

Shapes: u/delta (B, S, Di); A (Di, Ds); Bc/Cc (B, S, Ds); h (B, Di, Ds).
Sequential lax.scan over time — the correctness reference for the chunked
Pallas kernel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(u, delta, A, Bc, Cc, h0=None):
    """Returns (y (B,S,Di) float32, h_T (B,Di,Ds) float32)."""
    B, S, Di = u.shape
    Ds = A.shape[1]
    h = jnp.zeros((B, Di, Ds), jnp.float32) if h0 is None else h0

    def step(h, xs):
        u_t, d_t, b_t, c_t = xs          # (B,Di) (B,Di) (B,Ds) (B,Ds)
        dA = jnp.exp(d_t[..., None] * A[None])             # (B,Di,Ds)
        dBu = (d_t * u_t)[..., None] * b_t[:, None, :]     # (B,Di,Ds)
        h = dA * h + dBu
        y = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y

    xs = (u.transpose(1, 0, 2), delta.transpose(1, 0, 2),
          Bc.transpose(1, 0, 2), Cc.transpose(1, 0, 2))
    h, ys = jax.lax.scan(step, h, xs)
    return ys.transpose(1, 0, 2), h
