"""The in-process async serving layer: ``JobHandle`` + ``Executor``.

``Executor.submit(query)`` returns a ``JobHandle`` immediately and runs
the search on a worker-thread pool.  Each worker thread owns a
``Session.clone()`` (services are single-threaded by design), so the
shared cache directory — file-lock-arbitrated manifest and archive
writes — is the only coordination point between workers, exactly as it
is between separate worker *processes* draining the same ``JobStore``.

Durability: every submission lands in the job store before any work is
scheduled, and workers run it with ``resume=True`` (per-segment engine
checkpoints).  Kill the process mid-run and a restarted executor's
``resume_pending()`` (or the ``repro.serve.worker`` CLI) recovers the
job and resumes from the last completed scan segment, spending only the
residual budget and converging to the bit-identical final front.

Admission control: at most ``max_pending`` jobs are in flight.  Past
that, ``submit`` waits up to ``deadline_s`` for a slot and then
*degrades gracefully* — a query whose archive already holds ANY front is
answered immediately with that possibly-stale front
(``provenance.stale=True``, zero evaluations) while the refinement stays
banked as a PENDING job in the store; a cold query (nothing cached to
serve) is queued anyway, since degrading it would return nothing.
"""

from __future__ import annotations

import concurrent.futures
import queue
import threading
import time
import warnings
from pathlib import Path
from typing import Dict, Iterator, List, Optional

import jax
import numpy as np

from .. import obs
from ..core.optimizer import METRIC_KEYS
from ..explore.api import Problem, Provenance, Query, Result
from ..explore.archive import pareto_front
from ..explore.locks import file_lock
from ..explore.service import RunControl, SegmentEvent
from . import jobs
from .jobs import JobRecord, JobStore, graph_from_json, graph_to_json


class CancelledError(RuntimeError):
    """Raised by ``JobHandle.result()`` when the job was cancelled."""


# ---------------------------------------------------------------------------
# query (de)serialization
# ---------------------------------------------------------------------------
def query_to_payload(query: Query) -> Dict:
    """Serialize a ``Query`` for the durable job store.  Only the
    JSON-clean subset is supported: ``seed_designs`` / ``archive`` /
    ``engine_opts`` / ``policy`` carry live numpy or config objects that
    do not round-trip a crash, so async submission rejects them loudly
    rather than dropping them silently."""
    if query.seed_designs or query.archive is not None \
            or query.engine_opts or query.policy is not None:
        raise ValueError(
            "submit_async supports problem/budget/engine/transfer/"
            "weights queries only; seed_designs / archive / engine_opts "
            "/ policy do not survive the durable job store — use "
            "Session.submit for those")
    if query.tech is not None and not isinstance(query.tech, str):
        raise ValueError(
            "async queries carry tech by NAME (a preset registered via "
            "repro.calib or reachable through $REPRO_CALIB_DIR) so the "
            "worker process can resolve the same constants; pass "
            "tech='<preset>' or use Session.submit for a raw "
            "TechConstants")
    p = query.problem
    return dict(
        graph=graph_to_json(p.graph), objectives=list(p.objectives),
        ch_max=p.ch_max, space_kwargs=dict(p.space_kwargs),
        budget=int(query.budget), engine=query.engine,
        transfer=bool(query.transfer),
        weights=list(query.weights) if query.weights is not None
        else None,
        tech=query.tech)


def query_from_payload(d: Dict) -> Query:
    # JSON turned tuples into lists; the constraint kwargs must come
    # back hashable (they feed the compiled-runner cache key)
    sk = {k: tuple(v) if isinstance(v, list) else v
          for k, v in d["space_kwargs"].items()}
    problem = Problem(graph_from_json(d["graph"]),
                      objectives=tuple(d["objectives"]),
                      ch_max=int(d["ch_max"]), space_kwargs=sk)
    return Query(problem, budget=int(d["budget"]), engine=d["engine"],
                 transfer=bool(d["transfer"]),
                 weights=tuple(d["weights"]) if d.get("weights")
                 is not None else None,
                 tech=d.get("tech"))


def stale_result(session, query: Query, cache_key: str,
                 max_age_s: Optional[float] = None) -> Optional[Result]:
    """The degradation answer: the freshest cached front for the query's
    problem, straight off the shared archive (disk state merged in
    first — another service may have refined it since we last looked),
    re-projected to the query's objectives.  ``None`` when the archive
    is empty — a cold problem has nothing to degrade to.  Costs zero
    evaluations; ``provenance.stale=True`` and the query's whole budget
    shows as banked (the refinement debt the job store still owes).

    ``max_age_s`` bounds how old a served front may be: when the
    archive npz on disk was last refined more than ``max_age_s`` seconds
    ago, the front is TOO stale to degrade to and ``None`` is returned
    (the caller queues the refinement instead).  An archive that exists
    only in this process's memory (no npz yet) is by construction
    current and always serves."""
    p = query.problem
    t0 = time.perf_counter()
    arc = session.service.refresh_archive(p.spec, p.space, key=cache_key)
    if len(arc) == 0:
        return None
    if max_age_s is not None:
        try:
            age = time.time() - session.service._path(cache_key) \
                .stat().st_mtime
        except OSError:
            age = 0.0       # in-memory only: refined by THIS process
        if age > max_age_s:
            obs.inc("serve.stale_expired")
            return None
    designs, metrics = arc.front()
    idx = [METRIC_KEYS.index(o) for o in p.objectives]
    cols = np.asarray(metrics[:, idx], np.float64)
    keep = pareto_front(cols)
    front_designs = [{k: v[i] for k, v in designs.items()} for i in keep]
    obs.inc("serve.stale_served")
    return Result(
        objectives=p.objectives, front_objs=cols[keep],
        front_metrics=metrics[keep], front_designs=front_designs,
        trace=None,
        provenance=Provenance(
            cache_key=cache_key, engine="nsga", from_cache=True,
            n_evals_run=0, n_evals_banked=int(query.budget),
            n_evals_realloc=0, transferred_from=(), n_transfer_seeds=0,
            plateaued=False, elapsed_s=time.perf_counter() - t0,
            stale=True, tech=session.tech_label))


class JobHandle:
    """A client's grip on one async job: poll, await, cancel, stream.

    * ``poll()``    — freshest answer now: the final ``Result`` once the
      job is done, else the stale front admission served (if any), else
      ``None``.  Never blocks.
    * ``result(timeout)`` — block for the FINAL result (a stale front
      never satisfies it); raises ``TimeoutError`` / ``CancelledError``
      / the job's own exception.
    * ``cancel()``  — PENDING jobs are cancelled in the store (never
      run); RUNNING jobs get a cooperative stop at the next segment
      boundary, keeping their resume checkpoint on disk.
    * ``events()``  — iterate the run's ``SegmentEvent`` stream as
      segments complete, ending when the job does.
    """

    def __init__(self, job_id: str, store: JobStore):
        self.job_id = job_id
        self._store = store
        self._events: "queue.Queue[SegmentEvent]" = queue.Queue()
        self._done = threading.Event()
        self._control = RunControl()
        self._result: Optional[Result] = None
        self._stale: Optional[Result] = None
        self._error: Optional[BaseException] = None
        self._cancelled = False

    # ---- state ----------------------------------------------------------
    def record(self) -> Optional[JobRecord]:
        """The job's durable store record, fresh from disk."""
        return self._store.get(self.job_id)

    def state(self) -> str:
        rec = self.record()
        return rec.state if rec is not None else jobs.FAILED

    def done(self) -> bool:
        return self._done.is_set()

    @property
    def stale(self) -> Optional[Result]:
        """The possibly-stale front admission served under overload, or
        ``None`` when the job was scheduled normally."""
        return self._stale

    def poll(self) -> Optional[Result]:
        if self._done.is_set() and self._result is not None:
            return self._result
        return self._stale

    def result(self, timeout: Optional[float] = None) -> Result:
        if not self._done.wait(timeout):
            raise TimeoutError(
                f"job {self.job_id} not done within {timeout}s")
        if self._error is not None:
            raise self._error
        assert self._result is not None
        return self._result

    # ---- events ---------------------------------------------------------
    def events(self, timeout: Optional[float] = None
               ) -> Iterator[SegmentEvent]:
        """Yield ``SegmentEvent``s as the worker streams them; returns
        when the job finishes (or ``timeout`` seconds pass with neither
        an event nor completion)."""
        while True:
            try:
                yield self._events.get(timeout=0.05)
            except queue.Empty:
                if self._done.is_set() and self._events.empty():
                    return
                if timeout is not None:
                    timeout -= 0.05
                    if timeout <= 0:
                        return

    def _push(self, ev: SegmentEvent) -> None:
        self._events.put(ev)

    # ---- cancellation ---------------------------------------------------
    def cancel(self) -> bool:
        """Request cancellation.  Returns ``False`` when the job already
        reached a terminal state."""
        rec = self.record()
        if rec is None or rec.state in jobs.TERMINAL:
            return False
        self._cancelled = True
        if rec.state == jobs.PENDING:
            # flip it in the store under the claim lock; a worker that
            # claims concurrently wins the race and we fall through to
            # the cooperative stop
            with file_lock(self._store._lock):
                rec = self.record()
                if rec is not None and rec.state == jobs.PENDING:
                    self._store.update(rec, state=jobs.CANCELLED)
                    self._finish_cancelled()
                    return True
        self._control.stop()        # RUNNING: stop at the next segment
        return True

    # ---- worker-side finalization ---------------------------------------
    def _finish(self, result: Result) -> None:
        self._result = result
        self._done.set()

    def _fail(self, err: BaseException) -> None:
        self._error = err
        self._done.set()

    def _finish_cancelled(self) -> None:
        self._error = CancelledError(f"job {self.job_id} cancelled")
        self._done.set()


class Executor:
    """Thread-pool job runner over a durable ``JobStore``.

    ``session`` is the configuration template: each worker thread lazily
    takes a ``session.clone()`` of its own.  ``store`` defaults to
    ``<cache_dir>/jobs`` — co-located with the archives so one directory
    is the whole recoverable state of a serving fleet.

    ``stale_ttl_s`` bounds the staleness of overload-served fronts: a
    cached front whose archive was last refined more than ``stale_ttl_s``
    seconds ago is not served as a degradation answer — the query queues
    for fresh refinement instead (``None`` = any cached front serves,
    however old; the historic behavior)."""

    def __init__(self, session, store=None, max_workers: int = 2,
                 max_pending: int = 8,
                 stale_ttl_s: Optional[float] = None):
        self._session = session
        cfg = session._service_config()
        root = store if store is not None \
            else Path(cfg["cache_dir"]) / "jobs"
        self.store = root if isinstance(root, JobStore) else JobStore(root)
        self.max_pending = int(max_pending)
        self.stale_ttl_s = None if stale_ttl_s is None \
            else float(stale_ttl_s)
        self._pool = concurrent.futures.ThreadPoolExecutor(
            max_workers=int(max_workers),
            thread_name_prefix="repro-serve")
        self._tls = threading.local()
        self._handles: Dict[str, JobHandle] = {}
        self._inflight = 0
        self._lock = threading.Lock()

    # ---- worker sessions -------------------------------------------------
    def _thread_session(self):
        s = getattr(self._tls, "session", None)
        if s is None:
            s = self._tls.session = self._session.clone()
        return s

    # ---- submission ------------------------------------------------------
    def submit(self, query: Query, key=None,
               deadline_s: Optional[float] = None) -> JobHandle:
        """Durably record one query and either schedule it or — under
        overload, after waiting up to ``deadline_s`` for a slot — serve
        its freshest cached front immediately (``handle.stale``) and
        leave the refinement banked in the store.

        ``key`` is an integer PRNG seed (default 0): the job store must
        rebuild the exact key chain on a resume or in another process,
        so an opaque key array is not accepted."""
        if query.resolved_engine() != "nsga":
            raise ValueError(
                "submit_async serves the nsga engine (resumable scan "
                "segments); run scalarized engines via Session.submit")
        if key is None:
            seed = 0
        elif isinstance(key, (int, np.integer)):
            seed = int(key)
        else:
            raise ValueError(
                "submit_async takes an integer seed for key= (it must "
                "survive the durable job store); got "
                f"{type(key).__name__}")
        payload = query_to_payload(query)
        # the job's archive identity is derived under the QUERY's tech
        # (a per-query preset routes to a sibling session whose cache key
        # folds in that tech's digest)
        tsess = self._session._session_for(query.tech)
        ck = tsess._cache_key(query.problem)
        rec = self.store.create(payload, query.problem.key(), ck, seed)
        handle = JobHandle(rec.job_id, self.store)
        self._handles[rec.job_id] = handle
        obs.inc("serve.submitted")
        if not self._admit(deadline_s):
            stale = stale_result(tsess, query, ck,
                                 max_age_s=self.stale_ttl_s)
            if stale is not None:
                # overload + warm archive: answer now, bank the job
                handle._stale = stale
                obs.inc("serve.degraded")
                return handle
            obs.inc("serve.overflow")   # cold problem: nothing to serve
            #                             stale — queue it anyway
        self._schedule(handle)
        return handle

    def _admit(self, deadline_s: Optional[float]) -> bool:
        deadline = time.monotonic() + max(0.0, deadline_s or 0.0)
        while True:
            with self._lock:
                if self._inflight < self.max_pending:
                    return True
            if time.monotonic() >= deadline:
                return False
            time.sleep(0.01)

    def _schedule(self, handle: JobHandle) -> None:
        with self._lock:
            self._inflight += 1
        self._pool.submit(self._run_job, handle)

    # ---- recovery --------------------------------------------------------
    def resume_pending(self) -> List[JobHandle]:
        """Recover crashed RUNNING jobs (dead owner PID → PENDING) and
        schedule every PENDING job that has no live handle here —
        including refinements banked by an earlier overload degradation.
        Each resumed job restores its engine checkpoint and spends only
        the residual budget."""
        self.store.recover()
        out = []
        for rec in self.store.pending():
            h = self._handles.get(rec.job_id)
            if h is not None and not h.done() and h.stale is None:
                continue            # already scheduled here
            h = JobHandle(rec.job_id, self.store)
            self._handles[rec.job_id] = h
            self._schedule(h)
            out.append(h)
        return out

    # ---- the worker body -------------------------------------------------
    def _run_job(self, handle: JobHandle) -> None:
        try:
            rec = self.store.claim(handle.job_id)
            if rec is None:         # cancelled, or another worker won
                final = self.store.get(handle.job_id)
                if final is not None and final.state == jobs.CANCELLED:
                    handle._finish_cancelled()
                return
            run_job(self._thread_session(), self.store, rec,
                    handle=handle)
        except BaseException as e:  # never lose a pool thread silently
            handle._fail(e)
            warnings.warn(f"serve worker failed on {handle.job_id}: {e}")
        finally:
            with self._lock:
                self._inflight -= 1

    def shutdown(self, wait: bool = True) -> None:
        self._pool.shutdown(wait=wait)


def run_job(session, store: JobStore, rec: JobRecord,
            handle: Optional[JobHandle] = None,
            on_segment=None) -> Optional[Result]:
    """Run one CLAIMED job record to completion on ``session`` — the
    shared worker body of the in-process ``Executor`` and the
    ``repro.serve.worker`` CLI.  Always ``resume=True``: if a previous
    attempt left an engine checkpoint, this attempt restores it and
    spends only the residual budget.  State transitions written back to
    the store: DONE (with the attempt's eval/elapsed ledger), CANCELLED
    (a cooperative stop requested by the handle), PENDING again (an
    interrupted-but-not-cancelled run, checkpoint kept), or FAILED."""
    control = handle._control if handle is not None else RunControl()
    if handle is not None:
        on_segment = handle._push
    try:
        q = query_from_payload(rec.payload)
        # a tech-named query resolves its preset HERE too — a worker that
        # cannot resolve it (missing $REPRO_CALIB_DIR / artifact) or
        # resolves different constants derives a different key and
        # refuses below, loudly, instead of refining the wrong archive
        ck = session._session_for(q.tech)._cache_key(q.problem)
        if ck != rec.cache_key:
            raise RuntimeError(
                f"job {rec.job_id}: session derives cache key {ck} but "
                f"the job was submitted under {rec.cache_key} — tech/"
                "constraint mismatch, refusing to refine the wrong "
                "archive")
        t0 = time.perf_counter()
        with obs.span("serve.job", job=rec.job_id, attempt=rec.attempts):
            res = session.submit(q, key=jax.random.PRNGKey(rec.seed),
                                 resume=True, control=control,
                                 on_segment=on_segment)
        elapsed = time.perf_counter() - t0
        rec.n_evals_attempts.append(int(res.provenance.n_evals_run))
        rec.elapsed_attempts.append(float(elapsed))
        if res.provenance.interrupted:
            cancelled = handle is not None and handle._cancelled
            store.update(rec,
                         state=jobs.CANCELLED if cancelled
                         else jobs.PENDING,
                         owner_pid=None)
            if handle is not None:
                if cancelled:
                    handle._finish_cancelled()
                else:
                    handle._fail(InterruptedError(
                        f"job {rec.job_id} interrupted; checkpoint kept"))
            obs.inc("serve.interrupted")
            return None
        store.update(rec, state=jobs.DONE, owner_pid=None)
        if handle is not None:
            handle._finish(res)
        obs.inc("serve.completed")
        return res
    except Exception as e:
        store.update(rec, state=jobs.FAILED, owner_pid=None,
                     error=f"{type(e).__name__}: {e}")
        if handle is not None:
            handle._fail(e)
        obs.inc("serve.failed")
        raise


__all__ = ["CancelledError", "Executor", "JobHandle",
           "query_from_payload", "query_to_payload", "run_job",
           "stale_result"]
