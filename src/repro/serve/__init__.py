"""``repro.serve`` — resumable async serving over the exploration stack.

The layer between "a ``Session`` answers one blocking ``submit``" and "a
fleet of services shares one cache directory":

* ``JobHandle`` / ``Executor`` — ``Session.submit_async(query)`` returns
  a handle (poll / await / cancel / streamed ``SegmentEvent``s) while a
  worker-thread pool runs the search; each worker owns a
  ``Session.clone()`` and the lock-arbitrated cache directory is the
  only shared state.
* ``JobStore`` / ``JobRecord`` — the durable job journal: one
  atomically-written JSON file per job, lock-arbitrated claims keyed on
  ``Problem.key()``, PID-liveness crash recovery.  Every job runs with
  ``resume=True``, so a SIGKILLed attempt leaves an engine checkpoint
  the next attempt restores — residual-budget spend, bit-identical final
  front.
* Admission control + graceful degradation — past ``max_pending``
  in-flight jobs, a warm query is answered immediately with its
  freshest cached front (``provenance.stale=True``) and the refinement
  stays banked in the store (``Executor.resume_pending`` or the
  ``python -m repro.serve.worker`` CLI picks it up later).
"""

from .executor import (CancelledError, Executor, JobHandle,  # noqa: F401
                       query_from_payload, query_to_payload, run_job,
                       stale_result)
from .jobs import (CANCELLED, DONE, FAILED, PENDING,  # noqa: F401
                   RUNNING, TERMINAL, JobRecord, JobStore,
                   graph_from_json, graph_to_json)

__all__ = [
    "CANCELLED", "CancelledError", "DONE", "Executor", "FAILED",
    "JobHandle", "JobRecord", "JobStore", "PENDING", "RUNNING",
    "TERMINAL", "graph_from_json", "graph_to_json", "query_from_payload",
    "query_to_payload", "run_job", "stale_result",
]
